# Developer entry points.  `make test` is the tier-1 gate; `make smoke`
# exercises the solver driver end-to-end on a tiny grid (catches regressions
# in the repro.api facade / launch path without the full suite).

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast verify smoke serve-smoke obs-smoke chaos-smoke \
	bench bench-kernels bench-precond autotune-smoke examples lint \
	audit audit-write

test:
	$(PYTHON) -m pytest -x -q

# correctness-critical lint (ruff.toml pins the rule set); CI runs the same
lint:
	ruff check src tests benchmarks examples

# static contract auditor (repro.analysis): registry<->MethodDef sweep,
# MethodDef AST lint, Pallas kernel checks, then the compiled-HLO comms/
# donation audit of every method x mesh against the committed AUDIT.json
# baseline.  CI gate; `make audit-write` refreshes the baseline after a
# deliberate contract change.
audit:
	$(PYTHON) -m repro.analysis --check AUDIT.json

audit-write:
	$(PYTHON) -m repro.analysis --write AUDIT.json

# the tier-1 gate, exactly as ROADMAP.md specifies it (== make test)
verify: test

# quick loop: drop the multi-minute subprocess sweeps (marked `slow`)
test-fast:
	$(PYTHON) -m pytest -x -q -m "not slow"

smoke:
	$(PYTHON) -m repro.launch.solve --maxiter 5 --grid 16 16 16
	$(PYTHON) -m repro.launch.solve --maxiter 5 --grid 16 16 16 \
	    --method cg --no-f64 --batch 4

bench:
	$(PYTHON) -m benchmarks.run

# measured iteration counts with vs without preconditioning (the
# reductions-vs-iterations trade-off; see docs/API.md §Preconditioning)
bench-precond:
	$(PYTHON) -m benchmarks.table_iterations --precond

# per-iteration microbench of the Krylov iteration bodies (classic vs
# merged vs pipelined vs fused kernels); writes BENCH_kernels.json
bench-kernels:
	$(PYTHON) -m benchmarks.bench_kernels

# bounded autotuner sweep over the two CI configs (16³ + 32³, 7pt): tunes
# bz/br and the Pallas-vs-XLA crossover, persists the winners in the tune
# cache (CI points REPRO_AUTOTUNE_CACHE at a workspace file and uploads it)
autotune-smoke:
	$(PYTHON) -m repro.kernels.autotune --smoke --repeats 1

# replay the fixed heterogeneous trace through repro.serve, write
# BENCH_serve.json, then re-assert its SLO gate (zero drops, one compile
# per bucket, qps/p99 bounds) — the CI serving gate
serve-smoke:
	$(PYTHON) -m benchmarks.bench_serve --smoke
	$(PYTHON) -m benchmarks.bench_serve --check BENCH_serve.json

# observability smoke (CI gate): one traced telemetry solve + one traced
# serve replay append to TRACE_obs.jsonl, then the summarizer schema-checks
# every record (`--check` exits non-zero on any violation)
obs-smoke:
	rm -f TRACE_obs.jsonl
	REPRO_TRACE=TRACE_obs.jsonl $(PYTHON) -m repro.launch.solve \
	    --grid 32 32 32 --method cg --maxiter 60 --telemetry --json
	$(PYTHON) -m repro.launch.serve --mode solver --buckets smoke \
	    --trace TRACE_obs.jsonl --json
	$(PYTHON) -m repro.obs summarize --check TRACE_obs.jsonl

# fault-injection smoke (CI gate): the seeded chaos suite — every fault
# class (NaN poison, compile failure, preemption, deadline, quarantine)
# against real solves and a real service, traced to TRACE_chaos.jsonl —
# then the chaos serving bench (broken bucket -> typed rejects, retry
# absorbs the preemption) with its own record gate
chaos-smoke:
	$(PYTHON) -m repro.resilience --smoke --out TRACE_chaos.jsonl
	$(PYTHON) -m benchmarks.bench_serve --chaos
	$(PYTHON) -m benchmarks.bench_serve --check-chaos BENCH_serve_chaos.json

examples:
	$(PYTHON) examples/quickstart.py
	$(PYTHON) examples/solver_scaling.py
	$(PYTHON) examples/serve_batched.py
	$(PYTHON) examples/precond_speedup.py
