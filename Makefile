# Developer entry points.  `make test` is the tier-1 gate; `make smoke`
# exercises the solver driver end-to-end on a tiny grid (catches regressions
# in the repro.api facade / launch path without the full suite).

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test smoke bench examples

test:
	$(PYTHON) -m pytest -x -q

smoke:
	$(PYTHON) -m repro.launch.solve --maxiter 5 --grid 16 16 16
	$(PYTHON) -m repro.launch.solve --maxiter 5 --grid 16 16 16 \
	    --method cg --no-f64 --batch 4

bench:
	$(PYTHON) -m benchmarks.run

examples:
	$(PYTHON) examples/quickstart.py
	$(PYTHON) examples/solver_scaling.py
	$(PYTHON) examples/serve_batched.py
