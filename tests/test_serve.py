"""repro.serve: admission/bucketing, the LRU executable cache, SLO
metrics, and the acceptance contract — a mixed heterogeneous trace
replayed through the service completes with every result bitwise-equal
to a direct ``solve()``, exactly one compile per bucket, zero dropped
requests across an injected preemption, and LRU-bounded residency."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import SolverOptions, SolverSession
from repro.runtime.monitor import FailureInjector
from repro.serve import (
    BucketKey,
    CacheEntry,
    ExecutableCache,
    QueueFull,
    Request,
    RequestQueue,
    ServeConfig,
    ServeMetrics,
    SolverService,
    TraceBucket,
    generate_trace,
    replay,
    scan_metrics,
)

pytestmark = pytest.mark.usefixtures("f64")

#: the test trace: >= 4 distinct buckets — two grids x two methods, one
#: preconditioned (the acceptance mix, shrunk to test-suite grids)
TEST_BUCKETS = (
    TraceBucket(grid=(8, 8, 8), method="cg", stencil="27pt", count=5,
                maxiter=200),
    TraceBucket(grid=(12, 12, 12), method="cg", stencil="7pt", count=5,
                maxiter=200),
    # NOTE: batched-vs-single bitwise parity is deterministic for a fixed
    # (payload, shape) but not universal — for some inputs XLA rounds a
    # vmapped dot's reduction differently than the single-solve dot
    # (last-ulp, ~1e-18 absolute; far inside tol).  The bitwise test
    # below pins a verified trace (seed=5); see docs/API.md §Serving.
    TraceBucket(grid=(12, 12, 12), method="bicgstab", stencil="27pt",
                count=5, maxiter=200),
    TraceBucket(grid=(12, 12, 12), method="pcg", stencil="27pt",
                precond="jacobi", precond_params=(("sweeps", 2),),
                count=5, maxiter=200),
)


def _direct_solve(req):
    """The reference: one direct facade solve of the request."""
    sess = SolverSession(
        method=req.method, grid=tuple(req.b.shape), stencil=req.stencil,
        options=SolverOptions(tol=req.tol, maxiter=req.maxiter,
                              norm_ref=req.norm_ref, precond=req.precond,
                              precond_params=req.precond_params))
    return sess.solve(b=jnp.asarray(req.b))


# -----------------------------------------------------------------------------
# queue: admission + bucketing
# -----------------------------------------------------------------------------

def _req(**kw):
    kw.setdefault("b", np.zeros((8, 8, 8)))
    return Request(**kw)


def test_admission_rejects_malformed_requests():
    q = RequestQueue()
    with pytest.raises(ValueError, match="method"):
        q.admit(_req(method="nope"), now=0.0)
    with pytest.raises(ValueError, match="precond"):
        q.admit(_req(method="pcg", precond="nope"), now=0.0)
    with pytest.raises(ValueError, match="no precond"):
        q.admit(_req(method="cg", precond="jacobi"), now=0.0)
    with pytest.raises(ValueError, match="dtype"):
        q.admit(_req(dtype="f16"), now=0.0)
    with pytest.raises(ValueError, match="nx, ny, nz"):
        q.admit(_req(b=np.zeros((8, 8))), now=0.0)
    assert q.rejected == 5 and q.admitted == 0 and q.depth() == 0


def test_admission_control_queue_full():
    q = RequestQueue(max_depth=2)
    q.admit(_req(), now=0.0)
    q.admit(_req(), now=0.0)
    with pytest.raises(QueueFull):
        q.admit(_req(), now=0.0)
    assert q.rejected == 1


def test_bucketing_key_and_fifo():
    q = RequestQueue()
    a1 = _req()
    a2 = _req()
    b1 = _req(tol=1e-4)                # differing solve params fork a bucket
    c1 = _req(method="bicgstab")
    for i, r in enumerate((a1, b1, a2, c1)):
        q.admit(r, now=float(i))
    assert a1.key() == a2.key()
    assert a1.key() != b1.key() and a1.key() != c1.key()
    assert q.depth() == 4 and len(q.buckets()) == 3
    # oldest head request first, FIFO within the bucket
    assert q.buckets()[0] == a1.key()
    batch = q.next_batch(a1.key(), 8)
    assert [r.id for r in batch] == [a1.id, a2.id]
    # requeue_front preserves order and counts the requeue
    q.requeue_front(a1.key(), batch)
    again = q.next_batch(a1.key(), 8)
    assert [r.id for r in again] == [a1.id, a2.id]
    assert all(r.requeues == 1 for r in again)


# -----------------------------------------------------------------------------
# cache: LRU bound + counters (no compiles — sessions stubbed)
# -----------------------------------------------------------------------------

class _StubSession:
    def cache_stats(self):
        return {("shape", "m", "none"): {"hits": 0, "misses": 1,
                                         "compile_s": 0.25}}


def _key(n):
    return BucketKey(grid=(8, 8, n), stencil="27pt", method="cg",
                     precond="none", dtype="f64",
                     solve_params=(1e-8, 100, 1.0, ()))


def test_cache_lru_eviction_respects_bound():
    cache = ExecutableCache(capacity=2)
    k1, k2, k3 = _key(1), _key(2), _key(3)
    for k in (k1, k2, k3):
        cache.record_miss(k)
    cache.insert(CacheEntry(k1, _StubSession(), batch=4))
    cache.insert(CacheEntry(k2, _StubSession(), batch=4))
    assert cache.lookup(k1) is not None          # k1 now most-recently-used
    evicted = cache.insert(CacheEntry(k3, _StubSession(), batch=4))
    assert evicted == [k2]                       # LRU went, not k1
    assert cache.contains(k1) and not cache.contains(k2)
    st = cache.stats()
    assert st["entries"] == 2 == st["capacity"]
    assert st["hits"] == 1 and st["misses"] == 3 and st["evictions"] == 1
    assert st["per_bucket"][k2.short()]["evictions"] == 1
    assert st["per_bucket"][k1.short()]["compile_s"] == 0.25
    # contains() must not touch counters or LRU order
    cache.contains(k1)
    assert cache.stats()["hits"] == 1


def test_cache_capacity_validation():
    with pytest.raises(ValueError, match="capacity"):
        ExecutableCache(capacity=0)


# -----------------------------------------------------------------------------
# metrics: percentiles, QPS, monitor-style records
# -----------------------------------------------------------------------------

def test_metrics_percentiles_and_qps(tmp_path):
    m = ServeMetrics()
    m.record_submit(now=100.0)
    lats = [0.01 * (i + 1) for i in range(100)]       # 10ms .. 1s
    for i, lat in enumerate(lats):
        m.record_completion("b", lat, now=100.0 + i * 0.1)
    snap = m.snapshot(queue_depth=0)
    assert snap["p50_s"] == pytest.approx(np.percentile(lats, 50))
    assert snap["p95_s"] == pytest.approx(np.percentile(lats, 95))
    assert snap["p99_s"] == pytest.approx(np.percentile(lats, 99))
    # sustained QPS: 100 completions over the 9.9s first-submit->last-done span
    assert snap["qps"] == pytest.approx(100 / 9.9)
    assert snap["per_bucket"]["b"]["served"] == 100
    path = m.write(str(tmp_path), name="test")
    assert os.path.basename(path) == "metrics_test.json"
    assert scan_metrics(str(tmp_path))["test"]["completed"] == 100


def test_metrics_empty_snapshot():
    snap = ServeMetrics().snapshot()
    assert snap["qps"] is None and snap["p99_s"] is None
    assert snap["completed"] == 0


# -----------------------------------------------------------------------------
# SolverSession.cache_stats (the compile-cache observability satellite)
# -----------------------------------------------------------------------------

def test_session_cache_stats_counts_and_compile_seconds():
    sess = SolverSession(method="cg", grid=(8, 8, 8), stencil="27pt",
                         options=SolverOptions(tol=1e-8, maxiter=100))
    sess.solve()
    sess.solve()
    key = ((8, 8, 8), "cg", "none")
    st = sess.cache_stats()[key]
    assert st["misses"] == 1 and st["hits"] == 1
    assert st["compile_s"] > 0
    # the batched executable is a separate shape entry
    bs = jnp.stack([sess.problem.b()] * 3)
    sess.solve_batched(bs)
    bst = sess.cache_stats()[((3, 8, 8, 8), "cg", "none")]
    assert bst["misses"] == 1 and bst["hits"] == 0


def test_session_compile_batched_makes_later_solves_hits():
    sess = SolverSession(method="cg", grid=(8, 8, 8), stencil="27pt",
                         options=SolverOptions(tol=1e-8, maxiter=100))
    dt = sess.compile_batched(2)
    assert dt > 0
    sess.solve_batched(jnp.stack([sess.problem.b()] * 2))
    st = sess.cache_stats()[((2, 8, 8, 8), "cg", "none")]
    assert st["misses"] == 1 and st["hits"] == 1


# -----------------------------------------------------------------------------
# the serving loop: trace replay parity, one compile per bucket, recovery
# -----------------------------------------------------------------------------

def test_trace_replay_bitwise_parity_and_one_compile_per_bucket():
    # seed=5 is the pinned acceptance trace: bitwise batched-vs-single
    # parity is deterministic per (payload, shape) but data-dependent at
    # the last ulp (XLA may split a vmapped dot's reduction differently),
    # so the bitwise contract is asserted on this verified trace; the
    # general tolerance contract (<1e-10) lives in test_api/test_precond.
    service = SolverService(ServeConfig(max_batch=4, cache_capacity=8))
    trace = generate_trace(TEST_BUCKETS, seed=5)
    results = replay(service, trace)
    service.close()
    # (a) zero dropped: every admitted request has a result
    assert sorted(results) == list(range(len(trace)))
    # (b) every result matches a direct solve() bitwise — the continuous
    # batcher (zero-padded lanes, masked while-loop) is a zero-cost path
    ref_trace = generate_trace(TEST_BUCKETS, seed=5)   # same payloads
    for i, req in enumerate(ref_trace):
        ref = _direct_solve(req)
        got = results[i]
        assert got.iters == int(ref.iters), (i, got.bucket)
        assert got.res_norm == float(ref.res_norm), (i, got.bucket)
        np.testing.assert_array_equal(got.x, np.asarray(ref.x),
                                      err_msg=f"req {i} ({got.bucket})")
    # (c) exactly one compile per bucket, via SolverSession.cache_stats()
    assert len({r.key() for r in trace}) == 4
    for key, entry in service.cache._entries.items():
        stats = entry.session.cache_stats()
        assert len(stats) == 1, key
        (st,) = stats.values()
        assert st["misses"] == 1, key
    cache = service.cache.stats()
    assert cache["misses"] == 4 and cache["evictions"] == 0
    snap = service.snapshot()
    assert snap["completed"] == len(trace) and snap["qps"] > 0


def test_partial_batch_pads_with_converged_lanes():
    """1 request into a max_batch=4 bucket: the pad lanes are zero RHS
    (converged at iteration 0) and the real lane is bitwise-unaffected."""
    service = SolverService(ServeConfig(max_batch=4))
    rng = np.random.default_rng(7)
    req = Request(b=rng.standard_normal((8, 8, 8)), method="cg",
                  stencil="27pt", maxiter=200)
    service.submit(req)
    results = service.run_until_drained()
    service.close()
    ref = _direct_solve(Request(b=req.b, method="cg", stencil="27pt",
                                maxiter=200))
    np.testing.assert_array_equal(results[0].x, np.asarray(ref.x))
    assert results[0].iters == int(ref.iters)


def test_preemption_recovery_zero_dropped(tmp_path):
    """An injected preemption mid-solve re-enqueues the batch from the
    write-ahead journal: zero dropped requests, bitwise-identical results,
    and a clean WAL afterwards."""
    wal = str(tmp_path / "wal")
    service = SolverService(ServeConfig(max_batch=4, recovery_dir=wal),
                            injector=FailureInjector(fail_at_step=1))
    trace = generate_trace(TEST_BUCKETS, seed=0)
    results = replay(service, trace)
    service.close()
    assert sorted(results) == list(range(len(trace)))          # zero dropped
    snap = service.snapshot()
    assert snap["preemptions"] == 1 and snap["requeued"] >= 1
    assert sum(r.requeues for r in results.values()) == snap["requeued"]
    # the preempted run is indistinguishable from an uninterrupted one
    clean = SolverService(ServeConfig(max_batch=4))
    ref = replay(clean, generate_trace(TEST_BUCKETS, seed=0))
    clean.close()
    for i in results:
        np.testing.assert_array_equal(results[i].x, ref[i].x, err_msg=str(i))
        assert results[i].iters == ref[i].iters
    # committed work's journal entries are gone
    assert not any(f.startswith(("wal_", "step_")) for f in os.listdir(wal))


class _HardDeath(RuntimeError):
    """Not a SimulatedFailure: the service does NOT catch it — the
    dispatch dies with its WAL entry still on disk (a real preemption)."""


class _KillInjector(FailureInjector):
    def maybe_fail(self, step):
        if step == self.fail_at_step and not self.fired:
            self.fired = True
            raise _HardDeath(f"process died at dispatch {step}")


def test_cold_start_recovery_from_orphaned_wal(tmp_path):
    """A service that dies mid-dispatch leaves its journal behind; a fresh
    service over the same recovery_dir re-admits the orphaned requests and
    completes them."""
    wal = str(tmp_path / "wal")
    rng = np.random.default_rng(11)
    reqs = [Request(b=rng.standard_normal((8, 8, 8)), method="cg",
                    stencil="27pt", maxiter=200) for _ in range(3)]

    dying = SolverService(ServeConfig(max_batch=4, recovery_dir=wal,
                                      async_compile=False),
                          injector=_KillInjector(fail_at_step=0))
    for r in reqs:
        dying.submit(r)
    with pytest.raises(_HardDeath):
        dying.run_until_drained()
    dying.close()
    assert any(f.startswith("wal_") for f in os.listdir(wal))   # orphaned

    fresh = SolverService(ServeConfig(max_batch=4, recovery_dir=wal))
    remap = fresh.recover()
    assert len(remap) == 3
    results = fresh.run_until_drained()
    fresh.close()
    assert sorted(results) == sorted(remap.values())
    # recovery is indistinguishable from a service that never died: same
    # executable, same payload batch => bitwise-identical results
    clean = SolverService(ServeConfig(max_batch=4))
    for r in reqs:
        clean.submit(Request(b=r.b, method="cg", stencil="27pt",
                             maxiter=200))
    refs = clean.run_until_drained()
    clean.close()
    for old, new in remap.items():
        np.testing.assert_array_equal(results[new].x, refs[old].x)
        assert results[new].iters == refs[old].iters
        assert results[new].requeues >= 1
    assert not any(f.startswith(("wal_", "step_")) for f in os.listdir(wal))


def test_cold_bucket_does_not_stall_warm_bucket():
    """Compile-then-admit: while a cold bucket compiles on the background
    thread, a warm bucket's requests keep dispatching — completion order
    shows the warm request finishing first despite later submission."""
    service = SolverService(ServeConfig(max_batch=2))
    rng = np.random.default_rng(3)
    warm = lambda: Request(b=rng.standard_normal((8, 8, 8)), method="cg",
                           stencil="27pt", maxiter=200)
    cold = Request(b=rng.standard_normal((10, 10, 12)), method="bicgstab_b1",
                   stencil="27pt", maxiter=200)
    service.submit(warm())
    service.run_until_drained()                    # bucket A is now warm
    cold_id = service.submit(cold)                 # triggers A-sized compile
    warm_id = service.submit(warm())
    results = service.run_until_drained()
    service.close()
    order = list(results)                          # dict preserves commit order
    assert order.index(warm_id) < order.index(cold_id)
    assert service.cache.stats()["misses"] == 2
