"""The repro.api facade: backend resolution, registry metadata, and —
critically — that the facade is a *zero-cost* abstraction: ``repro.api.solve``
must produce bit-for-bit the same ``SolveResult`` as calling the solver
functions directly, on both the local and the shard_map path, and
``solve_batched`` must match per-RHS single solves."""

import jax.numpy as jnp
import numpy as np
import pytest
from conftest import run_multidevice

from repro.api import (
    REGISTRY,
    SolverOptions,
    SolverSession,
    get_solver,
    resolve_backend,
    solve,
    solve_batched,
    solver_names,
    variant_pairs,
)
from repro.core.problems import make_problem
from repro.core.solvers import SOLVERS, VARIANT_OF, LocalOp

pytestmark = pytest.mark.usefixtures("f64")

SHAPE = (10, 10, 12)


@pytest.fixture(scope="module")
def problem():
    return make_problem(SHAPE, "27pt")


# -----------------------------------------------------------------------------
# local path: facade == direct solver call, bit for bit
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("method", sorted(SOLVERS))
def test_local_matches_direct_bitwise(problem, method):
    res = solve(problem, method=method, tol=1e-8, maxiter=2000)
    ref = SOLVERS[method](LocalOp(problem.stencil), problem.b(), problem.x0(),
                          tol=1e-8, maxiter=2000, norm_ref=1.0)
    assert int(res.iters) == int(ref.iters)
    assert float(res.res_norm) == float(ref.res_norm)
    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(ref.x))
    np.testing.assert_array_equal(np.asarray(res.history),
                                  np.asarray(ref.history))


def test_session_reuses_compiled_fn(problem):
    sess = SolverSession(problem, method="cg",
                         options=SolverOptions(tol=1e-8, maxiter=500))
    r1 = sess.solve()
    fn = sess._executables[tuple(problem.shape)]
    r2 = sess.solve()
    assert sess._executables[tuple(problem.shape)] is fn
    np.testing.assert_array_equal(np.asarray(r1.x), np.asarray(r2.x))
    # the compile-cache observability: one real compile, then hits
    st = sess.cache_stats()[(tuple(problem.shape), "cg", "none")]
    assert st["misses"] == 1 and st["hits"] == 1 and st["compile_s"] > 0


def test_timed_solve_returns_blocked_stats(problem):
    sess = SolverSession(problem, method="jacobi",
                         options=SolverOptions(tol=1e-6, maxiter=50))
    res, stats = sess.timed_solve(repeats=2)
    assert int(res.iters) == 50
    assert stats["median"] > 0.0
    assert stats["q1"] <= stats["median"] <= stats["q3"]


# -----------------------------------------------------------------------------
# batched multi-RHS path (the serving workload)
# -----------------------------------------------------------------------------

def test_solve_batched_matches_single_solves(problem):
    sess = SolverSession(problem, method="cg", options=SolverOptions(
        tol=1e-8, maxiter=400, norm_ref=None))
    rng = np.random.default_rng(0)
    bs = jnp.asarray(rng.standard_normal((8, *SHAPE)))
    bres = sess.solve_batched(bs)            # 8 RHS, ONE compiled call
    assert bres.x.shape == (8, *SHAPE)
    for i in range(8):
        single = sess.solve(b=bs[i])
        assert int(bres.iters[i]) == int(single.iters), i
        np.testing.assert_allclose(np.asarray(bres.x[i]),
                                   np.asarray(single.x), atol=1e-12)


def test_solve_batched_facade_and_validation(problem):
    bs = jnp.stack([problem.b()] * 2)
    res = solve_batched(bs, problem, method="jacobi", maxiter=30)
    assert res.x.shape == (2, *SHAPE)
    sess = SolverSession(problem, method="jacobi")
    with pytest.raises(ValueError, match="batch"):
        sess.solve_batched(problem.b())                 # missing batch axis
    with pytest.raises(ValueError, match="grid"):
        sess.solve_batched(jnp.zeros((2, 4, 4, 4)))     # wrong grid


def test_batched_bicgstab_b1_vmaps(problem):
    """The optimization_barrier in Alg. 2 must be batchable (compat rule)."""
    bs = jnp.stack([problem.b()] * 2)
    res = solve_batched(bs, problem, method="bicgstab_b1", tol=1e-6,
                        maxiter=200)
    ref = solve(problem, method="bicgstab_b1", tol=1e-6, maxiter=200)
    assert int(res.iters[0]) == int(ref.iters)
    np.testing.assert_allclose(np.asarray(res.x[0]), np.asarray(ref.x),
                               atol=1e-12)


# -----------------------------------------------------------------------------
# options / backend / registry
# -----------------------------------------------------------------------------

def test_options_validation():
    with pytest.raises(ValueError, match="layout"):
        SolverOptions(layout="4d")
    with pytest.raises(ValueError, match="maxiter"):
        SolverOptions(maxiter=-1)
    opts = SolverOptions(tol=1e-4).replace(maxiter=7)
    assert opts.maxiter == 7 and opts.tol == 1e-4


def test_backend_resolution_rules():
    assert resolve_backend(SolverOptions(), n_devices=1).kind == "local"
    assert resolve_backend(SolverOptions(layout="local"),
                           n_devices=8).kind == "local"
    with pytest.raises(ValueError):
        resolve_backend(SolverOptions(layout="3d"), n_devices=4)
    # multi-device mesh construction is exercised in the shard_map
    # subprocess below (a 1-device host cannot build an 8-device mesh)


def test_unknown_method_raises(problem):
    with pytest.raises(KeyError, match="unknown method"):
        solve(problem, method="sor")


def test_f64_mismatch_with_prebuilt_problem_raises(problem):
    """An f64 problem + f64=False (or the converse) is a configuration
    error, not something to silently ignore."""
    with pytest.raises(ValueError, match="conflicts"):
        SolverSession(problem, method="cg",
                      options=SolverOptions(f64=False))
    f32_prob = make_problem(SHAPE, "27pt", dtype=jnp.float32)
    with pytest.raises(ValueError, match="conflicts"):
        SolverSession(f32_prob, method="cg")          # default f64=True


def test_facade_never_flips_global_x64():
    """Building an f64 problem without x64 enabled raises instead of
    flipping the process-global flag from inside the constructor."""
    import jax
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", False)
    try:
        with pytest.raises(ValueError, match="enable_f64"):
            SolverSession(method="cg", grid=(4, 4, 4))
        assert jax.config.jax_enable_x64 is False     # untouched
        sess = SolverSession(method="cg", grid=(4, 4, 4),
                             options=SolverOptions(f64=False))
        assert jnp.dtype(sess.problem.dtype) == jnp.dtype(jnp.float32)
    finally:
        jax.config.update("jax_enable_x64", prev)


def test_halo_mode_validation():
    with pytest.raises(ValueError, match="halo_mode"):
        SolverOptions(halo_mode="eager")
    from repro.api.backend import resolve_halo_mode
    assert resolve_halo_mode(SolverOptions()) == "overlap"
    assert resolve_halo_mode(SolverOptions(pallas=True)) == "concat"
    assert resolve_halo_mode(
        SolverOptions(matvec_padded=lambda xp: xp)) == "concat"
    assert resolve_halo_mode(SolverOptions(halo_mode="scatter")) == "scatter"


def test_hpcg_config_wires_into_facade():
    from repro.configs.hpcg import SOLVER_CONFIGS
    cfg = SOLVER_CONFIGS["hpcg-cg-7pt"]
    opts = cfg.to_options(maxiter=30)
    assert opts.tol == cfg.tol and opts.maxiter == 30
    res = cfg.session(grid=(8, 8, 8), maxiter=30).solve()
    assert 0 < int(res.iters) <= 30


def test_registry_subsumes_core_dicts():
    assert set(REGISTRY) == set(SOLVERS)
    assert solver_names() == sorted(SOLVERS)
    for variant, base in VARIANT_OF.items():
        assert get_solver(variant).variant_of == base
    assert (base_variant := dict(variant_pairs())) and all(
        base in REGISTRY for base in base_variant)


def test_registry_consistency_check_raises_real_exceptions():
    """The import-time registry/core cross-check must raise (not assert:
    asserts vanish under ``python -O``, silently disabling the guard)."""
    import dataclasses
    from repro.api import RegistryConsistencyError, check_consistent_with_core

    check_consistent_with_core()                     # current state is good
    missing = dict(REGISTRY)
    missing.pop("cg")
    with pytest.raises(RegistryConsistencyError, match="core-only"):
        check_consistent_with_core(registry=missing)
    wrong_fn = dict(REGISTRY)
    wrong_fn["cg"] = dataclasses.replace(REGISTRY["cg"],
                                         fn=lambda *a, **k: None)
    with pytest.raises(RegistryConsistencyError, match="registered fn"):
        check_consistent_with_core(registry=wrong_fn)
    with pytest.raises(RegistryConsistencyError, match="variant_of"):
        check_consistent_with_core(variant_of={"cg_nb": "bicgstab"})
    # and the guard really is exception-based, not assert-based: it must
    # keep firing when Python strips asserts (compile with optimize=2)
    import inspect
    src = inspect.getsource(check_consistent_with_core)
    assert "assert " not in src


def test_registry_barrier_metadata_matches_paper():
    """Hard-barrier counts per §3.1: CG 1, CG-NB 0, BiCGStab 2, B1 1."""
    assert REGISTRY["cg"].blocking_reductions == 1
    assert REGISTRY["cg_nb"].blocking_reductions == 0
    assert REGISTRY["bicgstab"].blocking_reductions == 2
    assert REGISTRY["bicgstab_b1"].blocking_reductions == 1
    assert REGISTRY["cg"].reductions_per_iter == 2
    assert REGISTRY["bicgstab"].reductions_per_iter == 3
    for m in ("cg", "cg_nb"):
        assert REGISTRY[m].spd_required
    for m in ("jacobi", "gauss_seidel", "gauss_seidel_rb"):
        assert REGISTRY[m].stationary


# -----------------------------------------------------------------------------
# shard_map path (subprocess: the main pytest process must keep 1 device)
# -----------------------------------------------------------------------------

_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "src")
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.api import SolverOptions, SolverSession, solve
from repro.core.distributed import solve_shardmap
from repro.core.problems import make_problem
from repro.core.solvers import SOLVERS
from repro.launch.mesh import make_solver_mesh

from repro.api import resolve_backend

prob = make_problem((12, 12, 16), "27pt")
mesh = make_solver_mesh(8)
opts = SolverOptions(tol=1e-6, maxiter=600)
out = {}

b_auto = resolve_backend(SolverOptions(layout="auto"))
b_2d = resolve_backend(SolverOptions(layout="2d"))
b_3d = resolve_backend(SolverOptions(layout="3d"))
out["backends"] = dict(
    auto_kind=b_auto.kind,
    auto_axes=list(b_auto.mesh.axis_names),
    auto_dim_axes=[a for a in b_auto.layout.dim_axes],
    d2_axes=sorted(b_2d.mesh.axis_names),
    d3_axes=list(b_3d.mesh.axis_names),
)
for m in sorted(SOLVERS):
    res = solve(prob, method=m, mesh=mesh, options=opts)
    fn, layout = solve_shardmap(prob, m, mesh, tol=1e-6, maxiter=600)
    sh = NamedSharding(mesh, layout.spec())
    ref = jax.jit(fn)(jax.device_put(prob.b(), sh),
                      jax.device_put(prob.x0(), sh))
    out[m] = dict(
        iters=int(res.iters), ref_iters=int(ref.iters),
        bitwise=bool(np.array_equal(np.asarray(res.x), np.asarray(ref.x))),
    )
sess = SolverSession(prob, method="cg_nb", mesh=mesh, options=opts)
rng = np.random.default_rng(1)
bs = jnp.asarray(rng.standard_normal((8, 12, 12, 16)))
bres = sess.solve_batched(bs)
dx = max(float(jnp.abs(bres.x[i] - sess.solve(b=bs[i]).x).max())
         for i in (0, 7))
out["batched"] = dict(shape=list(bres.x.shape), max_dx=dx)
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def shard_results():
    return run_multidevice(_SHARD_SCRIPT)


def test_shard_backend_resolution(shard_results):
    b = shard_results["backends"]
    assert b["auto_kind"] == "shard_map"
    assert b["auto_axes"] == ["cells"]             # paper-faithful 1-D z
    assert b["auto_dim_axes"] == [None, None, "cells"]
    assert b["d2_axes"] == ["data", "model"]
    assert b["d3_axes"] == ["pod", "data", "model"]


def test_shard_path_matches_direct_shardmap(shard_results):
    for m in sorted(SOLVERS):
        r = shard_results[m]
        assert r["iters"] == r["ref_iters"], (m, r)
        assert r["bitwise"], m


def test_shard_path_batched(shard_results):
    r = shard_results["batched"]
    assert r["shape"] == [8, 12, 12, 16]
    assert r["max_dx"] < 1e-10
