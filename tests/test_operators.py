"""Operator layer: stencil vs ELL vs dense; SPD structure."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.operators import (
    STENCIL_7PT,
    STENCIL_27PT,
    build_dense_from_stencil,
    build_ell_from_stencil,
    touched_elements_per_iter,
)

SHAPES = [(4, 4, 4), (5, 3, 6), (8, 8, 8)]


@pytest.mark.parametrize("stencil", [STENCIL_7PT, STENCIL_27PT], ids=lambda s: s.name)
@pytest.mark.parametrize("shape", SHAPES)
def test_stencil_matches_ell(stencil, shape):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, shape, jnp.float32)
    y_st = stencil.matvec(x)
    ell = build_ell_from_stencil(stencil, shape)
    y_ell = ell.matvec(x)
    np.testing.assert_allclose(np.asarray(y_st), np.asarray(y_ell),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("stencil", [STENCIL_7PT, STENCIL_27PT], ids=lambda s: s.name)
def test_dense_symmetric_positive_definite(stencil):
    A = build_dense_from_stencil(stencil, (4, 4, 4))
    np.testing.assert_allclose(A, A.T)
    w = np.linalg.eigvalsh(A)
    assert w.min() > 0, "HPCG matrix must be SPD"


@pytest.mark.parametrize("stencil", [STENCIL_7PT, STENCIL_27PT], ids=lambda s: s.name)
def test_matvec_adjoint(stencil):
    """A symmetric => <Ax, y> == <x, Ay>."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(k1, (6, 5, 4), jnp.float32)
    y = jax.random.normal(k2, (6, 5, 4), jnp.float32)
    lhs = jnp.vdot(stencil.matvec(x), y)
    rhs = jnp.vdot(x, stencil.matvec(y))
    np.testing.assert_allclose(float(lhs), float(rhs), rtol=1e-4)


def test_offdiag_consistency():
    x = jax.random.normal(jax.random.PRNGKey(2), (5, 5, 5), jnp.float32)
    xp = jnp.pad(x, 1)
    full = STENCIL_27PT.matvec_padded(xp)
    off = STENCIL_27PT.offdiag_apply_padded(xp)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(off + STENCIL_27PT.diag * x),
                               rtol=1e-5, atol=1e-5)


def test_plane_offdiag_matches_full():
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 4, 6), jnp.float32)
    xp = jnp.pad(x, 1)
    off_full = STENCIL_27PT.offdiag_apply_padded(xp)
    for k in range(6):
        plane = STENCIL_27PT.plane_offdiag_apply(xp, k)
        np.testing.assert_allclose(np.asarray(plane),
                                   np.asarray(off_full[:, :, k]),
                                   rtol=1e-5, atol=1e-5)


def test_touched_elements_paper_table():
    """§3.1: CG (12+n)r vs CG-NB (15+n)r; BiCGStab (21+2n)r vs B1 (24+2n)r."""
    for nbar in (7, 27):
        assert touched_elements_per_iter("cg_nb", nbar) - \
            touched_elements_per_iter("cg", nbar) == 3
        assert touched_elements_per_iter("bicgstab_b1", nbar) - \
            touched_elements_per_iter("bicgstab", nbar) == 3
    # the paper's headline relative increases
    assert abs(3 / (12 + 7) - 0.158) < 1e-2
    assert abs(3 / (21 + 2 * 7) - 0.086) < 1e-2


@pytest.mark.parametrize("stencil", [STENCIL_7PT, STENCIL_27PT],
                         ids=lambda s: s.name)
@pytest.mark.parametrize("formulation", ["slice", "conv"])
@pytest.mark.parametrize("split_dims", [(2,), (0, 2), (0, 1, 2)])
def test_interior_shell_split_matches_monolithic(stencil, formulation,
                                                 split_dims):
    """The overlapped-SpMV decomposition: interior apply on the raw block +
    shell slabs from the padded array must reassemble to exactly the
    monolithic apply, for both stencil formulations and any split set."""
    from repro.core.operators import interior_matvec, shell_assemble

    mv = (stencil.conv_matvec_padded() if formulation == "conv"
          else stencil.matvec_padded)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 8, 10), jnp.float32)
    # an arbitrary "exchanged" padded array: random halos on split dims
    xp = jax.random.normal(jax.random.PRNGKey(2), (8, 10, 12), jnp.float32)
    xp = xp.at[1:-1, 1:-1, 1:-1].set(x)
    for d in range(3):
        if d not in split_dims:     # unsplit dims keep the zero halo
            lo = [slice(None)] * 3
            hi = [slice(None)] * 3
            lo[d], hi[d] = 0, -1
            xp = xp.at[tuple(lo)].set(0.0).at[tuple(hi)].set(0.0)

    y_ref = jax.jit(mv)(xp)
    y_int = jax.jit(lambda a: interior_matvec(mv, a, split_dims))(x)
    y = jax.jit(lambda a, yi: shell_assemble(mv, a, yi, split_dims))(xp, y_int)
    assert y.shape == y_ref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-6, atol=1e-6)
