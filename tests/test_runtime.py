"""Fault tolerance: checkpoint/restore, retention, failure injection + resume
equivalence, heartbeat/straggler detection, elastic reshard, compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (
    dequantize_int8,
    init_error_feedback,
    make_ef_int8_transform,
    quantize_int8,
)
from repro.runtime import checkpoint as ckpt
from repro.runtime.monitor import (
    FailureInjector,
    Heartbeat,
    SimulatedFailure,
    scan_hosts,
    write_host_heartbeat,
)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "layers": {"w": jax.random.normal(k, (4, 8), jnp.float32),
                   "b": jnp.zeros((8,), jnp.bfloat16)},
        "step": jnp.asarray(17, jnp.int32),
    }


def test_checkpoint_roundtrip_bitwise(tmp_path):
    s = _state()
    ckpt.save(s, str(tmp_path), step=5)
    restored, step = ckpt.restore(s, str(tmp_path))
    assert step == 5
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_retention(tmp_path):
    s = _state()
    for step in (1, 2, 3, 4, 5):
        ckpt.save(s, str(tmp_path), step=step, keep=2)
    steps = sorted(os.listdir(tmp_path))
    assert steps == ["step_00000004", "step_00000005"]
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_checkpoint_background_write(tmp_path):
    t = ckpt.save(_state(), str(tmp_path), step=9, background=True)
    t.join(timeout=30)
    assert ckpt.latest_step(str(tmp_path)) == 9


def test_restore_specific_step(tmp_path):
    ckpt.save(_state(0), str(tmp_path), step=1, keep=5)
    ckpt.save(_state(1), str(tmp_path), step=2, keep=5)
    r1, _ = ckpt.restore(_state(), str(tmp_path), step=1)
    np.testing.assert_array_equal(np.asarray(r1["layers"]["w"]),
                                  np.asarray(_state(0)["layers"]["w"]))


def test_failure_injection_and_resume_equivalence(tmp_path):
    """Train 8 steps straight vs fail-at-4 + resume: identical final loss."""
    from repro.launch import train as train_mod

    common = ["--arch", "internlm2-1.8b", "--reduced", "--steps", "6",
              "--batch", "2", "--seq", "32", "--ckpt-every", "2"]
    ref = train_mod.main(common)  # no checkpointing dir: straight run
    d1 = str(tmp_path / "ck")
    out = train_mod.main(common + ["--ckpt-dir", d1, "--fail-at", "3"])
    assert out.get("failed_at") == 3
    resumed = train_mod.main(common + ["--ckpt-dir", d1])
    # the resumed run must replay the same data and land on the same loss
    np.testing.assert_allclose(resumed["losses"][-1], ref["losses"][-1],
                               rtol=1e-4)


def test_heartbeat_straggler_detection():
    hb = Heartbeat(straggler_factor=3.0)
    for _ in range(20):
        hb.times.append(0.1)
    assert hb.check(0.1)["straggler"] is False
    assert hb.check(1.0)["straggler"] is True


def test_heartbeat_cold_start_returns_wellformed_record():
    """The first tick has no interval yet: it must still return a record
    callers can index (it used to return {})."""
    hb = Heartbeat()
    first = hb.tick()
    assert first == {"step_time": None, "straggler": False, "warmup": True}
    second = hb.tick()
    assert second["straggler"] is False
    assert second["step_time"] >= 0.0


def test_heartbeat_identical_window_does_not_flag_median():
    """All window samples identical => MAD == 0; the spread floor must
    keep dt == median from being flagged (and survive median == 0 for
    sub-resolution steps)."""
    hb = Heartbeat(straggler_factor=3.0)
    for _ in range(20):
        hb.times.append(0.1)
    rep = hb.check(0.1)
    assert rep["mad"] == 0.0 and rep["straggler"] is False
    # degenerate all-zero window: dt == 0 is fine, a real step is not
    hb0 = Heartbeat(straggler_factor=3.0)
    for _ in range(20):
        hb0.times.append(0.0)
    assert hb0.check(0.0)["straggler"] is False
    assert hb0.check(0.1)["straggler"] is True


def test_host_scan(tmp_path):
    d = str(tmp_path)
    write_host_heartbeat(d, 0, step=10, step_time=0.5)
    write_host_heartbeat(d, 1, step=12, step_time=0.5)
    rep = scan_hosts(d, timeout_s=60)
    assert rep["alive"] == [0, 1]
    assert rep["min_step"] == 10 and rep["max_step"] == 12


def test_failure_injector():
    inj = FailureInjector(3)
    inj.maybe_fail(2)
    with pytest.raises(SimulatedFailure):
        inj.maybe_fail(3)
    inj.maybe_fail(3)  # fires once


# -----------------------------------------------------------------------------
# Gradient compression
# -----------------------------------------------------------------------------

def test_quantize_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,), jnp.float32) * 3
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) / 2 + 1e-7


def test_error_feedback_tracks_true_sum():
    """EF guarantee: sum of applied grads ~ sum of true grads."""
    tf = make_ef_int8_transform()
    params = {"w": jnp.zeros(64)}
    ef = init_error_feedback(params)
    key = jax.random.PRNGKey(1)
    true_sum = jnp.zeros(64)
    applied_sum = jnp.zeros(64)
    for i in range(20):
        key, k = jax.random.split(key)
        g = {"w": jax.random.normal(k, (64,)) * 0.1}
        gq, ef = tf(g, ef)
        true_sum += g["w"]
        applied_sum += gq["w"]
    # EF invariant: true_sum - applied_sum == carried error (up to assoc.)
    resid = float(jnp.abs((true_sum - applied_sum) - ef["w"]).max())
    assert resid < 1e-4


def test_elastic_reshard_semantics(mesh1):
    """Host checkpoint -> device_put under new shardings: values unchanged."""
    from repro.distributed.sharding import param_shardings
    params = _state()["layers"]
    sh = param_shardings(params, mesh1)
    placed = jax.tree.map(jax.device_put, params, sh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(placed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
