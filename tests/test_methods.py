"""The MethodDef layer (PR 5): the single-source contract.

Covers the pieces the refactor introduced: the declared-state machinery
(init matches the layout, res_scalar resolves), the generic ``run_method``
driver (a brand-new method authored per docs/API.md §"Authoring a new
method" solves the system without touching any driver), the registry's
metadata-vs-definition cross-validation, and the clear-error paths.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.registry import (REGISTRY, RegistryConsistencyError,
                                SolverSpec, _validate_against_method)
from repro.core.methods import (METHODS, MethodDef, Ops, get_method,
                                method_names, register_method, run_method)
from repro.core.problems import make_problem
from repro.core.solvers import SOLVERS, LocalOp

pytestmark = pytest.mark.usefixtures("f64")


# -----------------------------------------------------------------------------
# Contract: declared layouts match what init/step actually produce
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(METHODS))
def test_init_and_step_match_declared_layout(name):
    mdef = METHODS[name]
    prob = make_problem((6, 6, 8), "7pt")
    ops = Ops(LocalOp(prob.stencil), prob.b(), norm_ref=1.0)
    state = mdef.init(ops, prob.x0())
    nvec, nscal = len(mdef.vectors), len(mdef.scalars)
    assert len(state) == nvec + nscal, name
    for v in state[:nvec]:
        assert v.shape == prob.shape, name
    for sc in state[nvec:]:
        assert jnp.shape(sc) == (), name
    out = mdef.step(ops, state)
    assert len(out) == nvec + nscal, name
    assert mdef.res_index == nvec + mdef.scalars.index(mdef.res_scalar)
    # one registered solver callable per definition, and vice versa
    assert set(METHODS) == set(SOLVERS) == set(REGISTRY)
    assert SOLVERS[name].method_def is mdef
    assert REGISTRY[name].method_def is mdef


def test_solver_wrappers_reject_unknown_kwargs():
    """The derived solver callables must keep the old explicit-signature
    behaviour: a typo'd keyword raises instead of being silently ignored."""
    prob = make_problem((6, 6, 8), "7pt")
    A = LocalOp(prob.stencil)
    with pytest.raises(TypeError, match="unexpected keyword"):
        SOLVERS["cg"](A, prob.b(), prob.x0(), maxiters=10)
    with pytest.raises(TypeError, match="no preconditioner"):
        SOLVERS["cg"](A, prob.b(), prob.x0(), M=lambda v: v)
    # declared tuning knobs still pass through (bicgstab_b1's restart eps)
    res = SOLVERS["bicgstab_b1"](A, prob.b(), prob.x0(), tol=1e-6,
                                 maxiter=50, norm_ref=1.0, eps_restart=1e-4)
    assert float(res.res_norm) < 1e-6


def test_get_method_unknown_name_lists_registry():
    with pytest.raises(ValueError, match="unknown method 'sor'"):
        get_method("sor")
    with pytest.raises(ValueError, match="bicgstab_merged"):
        get_method("sor")
    assert method_names() == sorted(METHODS)


def test_method_def_validates_declarations():
    dummy = lambda ops, *a: a  # noqa: E731
    with pytest.raises(ValueError, match="res_scalar"):
        MethodDef(name="bad", vectors=("x",), scalars=("rr",),
                  res_scalar="nope", init=dummy, step=dummy)
    with pytest.raises(ValueError, match="fused"):
        MethodDef(name="bad", vectors=("x",), scalars=("rr",),
                  res_scalar="rr", init=dummy, step=dummy,
                  fused_kernels=("spmv_dots",))


# -----------------------------------------------------------------------------
# Registry metadata is cross-validated against the definitions
# -----------------------------------------------------------------------------

def test_registry_metadata_validated_against_method_def():
    import dataclasses
    spec = REGISTRY["pcg"]
    mdef = METHODS["pcg"]
    _validate_against_method(spec, mdef)            # current state is good
    drifted = dataclasses.replace(spec, accepts_precond=False,
                                  precond_applies_per_iter=0)
    with pytest.raises(RegistryConsistencyError, match="accepts_precond"):
        _validate_against_method(drifted, mdef)
    drifted = dataclasses.replace(REGISTRY["cg_merged"], reduce_hide="none",
                                  reduction_hides=("none", "none"))
    with pytest.raises(RegistryConsistencyError, match="reduce_hide"):
        _validate_against_method(drifted, METHODS["cg_merged"])
    with pytest.raises(RegistryConsistencyError, match="fused_kernels"):
        _validate_against_method(
            dataclasses.replace(REGISTRY["cg_merged"], fused_kernels=()),
            METHODS["cg_merged"])


def test_fused_kernels_require_fused_body_and_pallas_hooks():
    """PR 10 regression: a spec may only advertise ``fused_kernels`` when the
    MethodDef actually carries a fused body, and every advertised hook must
    exist on PallasOp — otherwise session routing would silently fall back."""
    import dataclasses
    # spec claims fused kernels, but the plain-cg MethodDef has no fused body
    spec = dataclasses.replace(REGISTRY["cg"], fused_kernels=("cg_body",))
    with pytest.raises(RegistryConsistencyError, match="no fused body"):
        _validate_against_method(spec, METHODS["cg"])
    # spec/mdef agree on a hook name that PallasOp does not implement
    mdef = dataclasses.replace(METHODS["cg_merged"],
                               fused_kernels=("not_a_hook",))
    spec = dataclasses.replace(REGISTRY["cg_merged"],
                               fused_kernels=("not_a_hook",))
    with pytest.raises(RegistryConsistencyError, match="PallasOp"):
        _validate_against_method(spec, mdef)


def test_register_solver_requires_a_method_def():
    from repro.api.registry import register_solver
    with pytest.raises(RegistryConsistencyError, match="no MethodDef"):
        register_solver(SolverSpec(
            name="sor_unregistered", fn=lambda *a, **k: None,
            reduction_hides=("none",), spmvs_per_iter=1))


# -----------------------------------------------------------------------------
# Authoring path: the docs' toy Richardson iteration, end to end
# -----------------------------------------------------------------------------

def _richardson_def(omega: float = 0.035) -> MethodDef:
    """The worked example from docs/API.md §"Authoring a new method"."""
    def init(ops, x0):
        r = ops.b - ops.matvec(x0)
        return (x0, r, ops.dot(r, r))

    def step(ops, state):
        x, r, rr = state
        x = x + omega * r
        r = ops.b - ops.matvec(x)
        return (x, r, ops.dot(r, r))

    return MethodDef(name="richardson", vectors=("x", "r"), scalars=("rr",),
                     res_scalar="rr", init=init, step=step, stationary=True,
                     default_maxiter=5000)


def test_toy_richardson_solves_via_generic_driver():
    """A new method is ONE MethodDef: run_method drives it to convergence
    with no solver-, distributed- or facade-layer code."""
    mdef = _richardson_def()
    prob = make_problem((12, 12, 12), "7pt")
    A = LocalOp(prob.stencil)
    ops = Ops(A, prob.b(), norm_ref=1.0)
    res = run_method(mdef, ops, prob.x0(), tol=1e-8)
    assert float(res.res_norm) < 1e-8
    assert int(res.iters) < 5000
    true_r = float(jnp.linalg.norm(
        (prob.b() - A.matvec(res.x)).reshape(-1)))
    assert true_r < 1e-7


def test_registered_method_drives_step_backend_too():
    """Registering the toy method makes the STEP machinery (the dry-run's
    analysis surface) pick it up with zero extra code."""
    from repro.core.distributed import (init_step_state, solve_step_shardmap,
                                        step_state_layout)
    from repro.core.compat import make_mesh
    mdef = _richardson_def()
    register_method(mdef)
    try:
        prob = make_problem((6, 6, 8), "7pt")
        A = LocalOp(prob.stencil)
        assert step_state_layout("richardson") == (("x", "r"), ("rr",))
        mesh = make_mesh((1, 1), ("data", "model"))
        fn, _ = solve_step_shardmap(prob, "richardson", mesh)
        state = init_step_state("richardson", A, prob.b(), prob.x0())
        out = fn(*state)
        ref = mdef.step(Ops(A, prob.b(), norm_ref=1.0), state[1:])
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]),
                                   rtol=1e-13, atol=1e-13)
    finally:
        METHODS.pop("richardson")
