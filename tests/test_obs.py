"""repro.obs: tracing, convergence telemetry, cost attribution.

The load-bearing contracts:

  * telemetry OFF is a bitwise no-op — same ``SolveResult`` leaves as a
    solve that never heard of telemetry (``SolveResult.telemetry is None``
    keeps the pytree shape identical, so the lowered HLO is too — the
    ``make audit`` baseline pins that);
  * telemetry ON reports the truth — the buffered residual curve equals
    the driver's ``history`` and the final entry matches an *offline*
    ``||b - A x||`` recompute, for every registry method on the local and
    the shard_map backend;
  * the span stream round-trips — records written by an instrumented
    solve validate against the schema and aggregate through the CLI
    summarizer;
  * attribution's phases sum to ``t_iter`` exactly (t_compute is the raw
    remainder by construction);
  * the serve/monitor record unification keeps old readers working —
    pre-PR-8 heartbeat/metrics shapes still parse, and the committed
    PR-6-era ``BENCH_serve.json`` still passes its gate.
"""

import json
import os
import time

import numpy as np
import pytest
from conftest import REPO_ROOT, run_multidevice

from repro.api import SolverOptions, SolverSession, solve
from repro.core.problems import make_problem
from repro.core.solvers import SOLVERS, LocalOp
from repro.obs import trace as obs
from repro.obs.convergence import (curve_record, effective_rows,
                                   residual_curve, scalar_history,
                                   telemetry_residuals, true_residual_norm)

pytestmark = pytest.mark.usefixtures("f64")

SHAPE = (10, 10, 12)


@pytest.fixture(scope="module")
def problem():
    return make_problem(SHAPE, "27pt")


@pytest.fixture()
def tracer_path(tmp_path):
    """An enabled tracer for the test body, torn down unconditionally so
    the module-global tracer never leaks into other tests."""
    path = str(tmp_path / "trace.jsonl")
    obs.enable(path)
    yield path
    obs.disable()


def _tele_opts(**kw):
    base = dict(tol=1e-8, maxiter=2000, telemetry=True,
                telemetry_buffer=4096)
    base.update(kw)
    return SolverOptions(**base)


# -----------------------------------------------------------------------------
# telemetry off == bitwise no-op
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["cg", "cg_merged", "bicgstab"])
def test_telemetry_off_is_bitwise_noop(problem, method):
    off = solve(problem, method=method, tol=1e-8, maxiter=2000)
    on = solve(problem, method=method, options=_tele_opts())
    assert off.telemetry is None
    assert on.telemetry is not None
    assert int(off.iters) == int(on.iters)
    assert float(off.res_norm) == float(on.res_norm)
    np.testing.assert_array_equal(np.asarray(off.x), np.asarray(on.x))
    np.testing.assert_array_equal(np.asarray(off.history),
                                  np.asarray(on.history))


def test_telemetry_off_matches_direct_solver_bitwise(problem):
    """The facade with telemetry off == the raw solver fn that never took
    a telemetry kwarg (the zero-cost-abstraction contract extended)."""
    res = solve(problem, method="cg", tol=1e-8, maxiter=2000)
    ref = SOLVERS["cg"](LocalOp(problem.stencil), problem.b(), problem.x0(),
                        tol=1e-8, maxiter=2000, norm_ref=1.0)
    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(ref.x))
    assert res.telemetry is None and ref.telemetry is None


# -----------------------------------------------------------------------------
# telemetry on: the curves are true
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("method,precond", [
    ("cg", None), ("bicgstab", None), ("pcg", "jacobi"),
])
def test_telemetry_matches_offline_residual(problem, method, precond):
    """The buffered curve's final entry == an offline ||b - A x|| recompute
    (recurrence drift is O(eps * kappa) — loose relative tolerance)."""
    kw = {"precond": precond} if precond else {}
    res = solve(problem, method=method,
                options=_tele_opts(maxiter=400, **kw))
    tele_res = telemetry_residuals(res, method)
    true_res = true_residual_norm(LocalOp(problem.stencil), problem.b(),
                                  res.x)
    assert tele_res.shape == (int(res.iters) + 1,)
    assert float(tele_res[-1]) == pytest.approx(float(res.res_norm))
    assert float(tele_res[-1]) == pytest.approx(true_res, rel=1e-3,
                                                abs=1e-10)


@pytest.mark.parametrize("method", sorted(SOLVERS))
def test_telemetry_all_methods_local(problem, method):
    """Every registry method carries a telemetry buffer whose residual
    column reproduces the driver's history curve."""
    from repro.core.methods import get_method
    mdef = get_method(method)
    res = solve(problem, method=method, options=_tele_opts(maxiter=600))
    tele = np.asarray(res.telemetry)
    assert tele.shape == (601, len(mdef.scalars))
    rows = effective_rows(res)
    assert rows == int(res.iters) + 1
    np.testing.assert_allclose(telemetry_residuals(res, method),
                               np.asarray(res.history)[:rows],
                               rtol=1e-12, atol=0)
    hist = scalar_history(res, method)
    assert set(hist) == set(mdef.scalars)
    assert all(v.shape == (rows,) for v in hist.values())


def test_telemetry_buffer_overflow_keeps_final_state(problem):
    """A buffer smaller than the iteration count overwrites its last row:
    no NaNs, and the last row holds the *final* scalar state."""
    res = solve(problem, method="jacobi",
                options=SolverOptions(tol=1e-12, maxiter=50, telemetry=True,
                                      telemetry_buffer=4))
    tele = np.asarray(res.telemetry)
    assert tele.shape[0] == 4 and int(res.iters) > 4
    assert not np.isnan(tele).any()
    assert float(np.sqrt(tele[-1, 0])) == pytest.approx(float(res.res_norm))
    assert effective_rows(res) == 4


def test_curve_record_is_json_able(problem):
    res = solve(problem, method="cg", options=_tele_opts(maxiter=400))
    rec = curve_record(res, "cg", scalars=True)
    json.dumps(rec)                       # must round-trip
    assert rec["iters"] == int(res.iters)
    assert len(rec["residuals"]) == int(res.iters) + 1
    assert rec["telemetry_rows"] == int(res.iters) + 1
    assert rec["residuals"][-1] == pytest.approx(float(res.res_norm))
    np.testing.assert_allclose(rec["scalars"]["rr"],
                               np.asarray(res.history)[:int(res.iters) + 1]
                               ** 2, rtol=1e-12)
    # the residual curve helper agrees with the record
    np.testing.assert_allclose(residual_curve(res), rec["residuals"])


# -----------------------------------------------------------------------------
# shard_map backend: telemetry for every method (slow, 8-device subprocess)
# -----------------------------------------------------------------------------

_SHARD_TELE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro.api import SolverOptions, solve
from repro.core.methods import get_method
from repro.core.problems import make_problem
from repro.core.solvers import SOLVERS
from repro.launch.mesh import make_solver_mesh

prob = make_problem((12, 12, 16), "27pt")
mesh = make_solver_mesh(8)
out = {}
for m in sorted(SOLVERS):
    off = solve(prob, method=m, mesh=mesh,
                options=SolverOptions(tol=1e-6, maxiter=600))
    on = solve(prob, method=m, mesh=mesh,
               options=SolverOptions(tol=1e-6, maxiter=600, telemetry=True,
                                     telemetry_buffer=601))
    rows = min(int(on.iters) + 1, np.asarray(on.telemetry).shape[-2])
    mdef = get_method(m)
    tele_res = np.sqrt(np.asarray(on.telemetry)[
        :rows, mdef.scalars.index(mdef.res_scalar)])
    out[m] = dict(
        off_none=off.telemetry is None,
        bitwise=bool(np.array_equal(np.asarray(off.x), np.asarray(on.x))),
        shape=list(np.asarray(on.telemetry).shape),
        n_scalars=len(mdef.scalars),
        curve_ok=bool(np.allclose(tele_res,
                                  np.asarray(on.history)[:rows])),
    )
print(json.dumps(out))
"""


@pytest.mark.slow
def test_telemetry_all_methods_shardmap():
    out = run_multidevice(_SHARD_TELE_SCRIPT)
    assert sorted(out) == sorted(SOLVERS)
    for m, r in out.items():
        assert r["off_none"], m
        assert r["bitwise"], m         # telemetry never perturbs the solve
        assert r["shape"] == [601, r["n_scalars"]], (m, r)
        assert r["curve_ok"], m


# -----------------------------------------------------------------------------
# the span stream: schema, nesting, CLI summarizer round-trip
# -----------------------------------------------------------------------------

def test_span_stream_roundtrip(problem, tracer_path, capsys):
    sess = SolverSession(problem, method="cg",
                         options=SolverOptions(tol=1e-8, maxiter=300))
    sess.solve()
    sess.solve()                       # second call: compile-cache hit
    obs.disable()

    assert obs.validate_stream(tracer_path) == []
    records = obs.read_trace(tracer_path)
    by_name = {}
    for r in records:
        by_name.setdefault(r["name"], []).append(r)
    # lifecycle spans: resolve -> precond.setup -> compile -> solve/execute
    for name in ("resolve", "precond.setup", "compile", "solve", "execute"):
        assert name in by_name, name
    assert len(by_name["solve"]) == 2
    assert len(by_name["compile"]) == 1      # second solve reused the cache
    # nesting: execute's parent is its solve span
    solve_ids = {r["span_id"] for r in by_name["solve"]}
    assert all(r["parent_id"] in solve_ids for r in by_name["execute"])

    from repro.obs.__main__ import main as obs_main
    assert obs_main(["summarize", tracer_path, "--check", "--json"]) == 0
    summary = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert summary["schema_errors"] == 0
    assert summary["spans"]["solve"]["count"] == 2
    assert summary["spans"]["execute"]["p50_s"] is not None


def test_summarize_check_fails_on_bad_record(tmp_path):
    path = tmp_path / "bad.jsonl"
    good = obs.make_event("ok")
    bad = {"schema": obs.SCHEMA, "kind": "span", "name": "x"}  # missing keys
    path.write_text(json.dumps(good) + "\n" + json.dumps(bad) + "\n")
    from repro.obs.__main__ import main as obs_main
    assert obs_main(["summarize", str(path), "--check"]) == 1
    assert obs_main(["summarize", str(path)]) == 0   # report-only mode


def test_tracer_disabled_is_noop(tmp_path):
    obs.disable()
    with obs.span("nothing") as sid:
        assert sid is None
    assert obs.event("nothing") is not None      # record built, not written
    assert not obs.active()


# -----------------------------------------------------------------------------
# serve metrics as views over the event stream (+ the unification bugfix)
# -----------------------------------------------------------------------------

def test_serve_metrics_views_and_schema():
    from repro.serve import ServeMetrics
    m = ServeMetrics()
    t0 = time.monotonic()
    m.record_submit(t0, bucket="b0", rid=1)
    m.record_submit(t0 + 0.1, bucket="b1", rid=2)
    m.record_queue_depth(2)
    m.record_completion("b0", 0.5, t0 + 0.5)
    m.record_completion("b1", 1.5, t0 + 1.6)
    m.record_preemption(3)
    m.rejected += 1

    assert m.completed == 2 and m.preemptions == 1 and m.requeued == 3
    for rec in m.events():
        assert obs.validate_record(rec) == [], rec
    snap = m.snapshot(queue_depth=0)
    assert snap["schema"] == obs.SCHEMA
    # the pre-PR-8 key set the bench/CI gate parses, still intact
    for k in ("completed", "preemptions", "requeued", "rejected", "qps",
              "queue_depth_max", "p50_s", "p95_s", "p99_s", "per_bucket"):
        assert k in snap, k
    assert snap["completed"] == 2 and snap["rejected"] == 1
    assert snap["p50_s"] == pytest.approx(1.0)
    assert snap["per_bucket"]["b0"]["served"] == 1
    assert snap["qps"] == pytest.approx(2 / 1.6, rel=1e-6)


def test_serve_metrics_forward_to_tracer(tracer_path):
    from repro.serve import ServeMetrics
    m = ServeMetrics()
    m.record_submit(time.monotonic(), bucket="b0", rid=7)
    m.record_completion("b0", 0.2, time.monotonic())
    obs.disable()
    recs = obs.read_trace(tracer_path)
    assert [r["name"] for r in recs] == ["serve.admit", "serve.complete"]
    assert recs[1]["attrs"]["latency_s"] == pytest.approx(0.2)


def test_heartbeat_reader_accepts_both_schemas(tmp_path):
    from repro.runtime.monitor import scan_hosts, write_host_heartbeat
    d = str(tmp_path)
    # new writer: a repro.obs/v1 metric record
    write_host_heartbeat(d, 0, step=12, step_time=0.5)
    # pre-PR-8 flat shape, as an old monitor directory would hold
    with open(os.path.join(d, "host_1.json"), "w") as f:
        json.dump({"host": 1, "step": 9, "t": time.time(),
                   "step_time": 0.4}, f)
    out = scan_hosts(d)
    assert out["alive"] == [0, 1]
    assert out["min_step"] == 9 and out["max_step"] == 12
    with open(os.path.join(d, "host_0.json")) as f:
        assert obs.validate_record(json.load(f)) == []


def test_scan_metrics_accepts_pre_schema_records(tmp_path):
    from repro.serve import ServeMetrics, scan_metrics
    from repro.serve.metrics import load_record
    d = str(tmp_path)
    ServeMetrics().write(d, name="new")
    old = {"t": 123.0, "completed": 4, "qps": 2.0}    # pre-PR-8, untagged
    with open(os.path.join(d, "metrics_old.json"), "w") as f:
        json.dump(old, f)
    out = scan_metrics(d)
    assert out["new"]["schema"] == obs.SCHEMA
    assert out["old"]["schema"] == f"{obs.SCHEMA}+legacy"
    assert out["old"]["t_wall"] == 123.0 and out["old"]["completed"] == 4
    assert load_record(out["new"]) == out["new"]      # tagged: pass-through


def test_committed_bench_serve_record_still_parses():
    """Regression gate for the record unification: the PR-6-era
    BENCH_serve.json committed at the repo root must still satisfy its own
    check (old snapshot key set intact under the new metrics store)."""
    from benchmarks.bench_serve import check_record
    rec = check_record(os.path.join(REPO_ROOT, "BENCH_serve.json"))
    assert rec["dropped"] == 0


# -----------------------------------------------------------------------------
# benchmark trajectories
# -----------------------------------------------------------------------------

def test_trajectory_rows_append(tmp_path):
    from benchmarks.common import trajectory_append, trajectory_row
    path = str(tmp_path / "hist.jsonl")
    row = trajectory_row("kernels", value=1.0)
    for k in ("bench", "t_wall", "git_sha", "device", "backend", "dtype"):
        assert k in row, k
    trajectory_append(path, row)
    trajectory_append(path, trajectory_row("kernels", value=2.0))
    lines = [json.loads(ln) for ln in open(path)]
    assert len(lines) == 2                 # appended, not overwritten
    assert [ln["value"] for ln in lines] == [1.0, 2.0]


# -----------------------------------------------------------------------------
# attribution: phases sum to t_iter; rows flow through the trace (slow)
# -----------------------------------------------------------------------------

_ATTRIB_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["REPRO_TRACE"] = os.environ["ATTRIB_TRACE"]
import json
import jax
jax.config.update("jax_enable_x64", True)
from repro.core.problems import make_problem
from repro.launch.mesh import make_solver_mesh
from repro.obs.attribution import format_table, measure_phase_split

prob = make_problem((16, 16, 16), "27pt")
mesh = make_solver_mesh(8)
rows = [measure_phase_split(prob, m, mesh, inner=2, repeats=2)
        for m in ("cg", "cg_merged")]
table = format_table(rows)
print(json.dumps({"rows": rows, "table_lines": len(table.splitlines())}))
"""


@pytest.mark.slow
def test_attribution_sums_and_traces(tmp_path):
    trace_path = str(tmp_path / "attrib.jsonl")
    out = run_multidevice(_ATTRIB_SCRIPT,
                          env={"ATTRIB_TRACE": trace_path})
    assert out["table_lines"] == 2 + len(out["rows"])
    for row in out["rows"]:
        m = row["measured"]
        # t_compute is the raw remainder: the split sums exactly
        assert m["t_iter"] == pytest.approx(
            m["t_halo"] + m["t_reduce"] + m["t_compute"], abs=1e-12)
        assert m["t_iter"] > 0 and m["t_halo"] > 0 and m["t_reduce"] > 0
        for k in ("t_mem", "t_halo", "t_precond", "t_reduce", "total"):
            assert k in row["predicted"], k
        assert row["mesh"]["devices"] == 8
    # cg_merged declares half cg's allreduces — attribution must price that
    by = {r["method"]: r for r in out["rows"]}
    assert (by["cg_merged"]["counts"]["allreduces"]
            < by["cg"]["counts"]["allreduces"])
    # every emitted record validates; the rows round-trip from the trace
    assert obs.validate_stream(trace_path) == []
    from repro.obs.attribution import rows_from_trace
    rt = rows_from_trace(obs.read_trace(trace_path))
    assert [r["method"] for r in rt] == ["cg", "cg_merged"]


def test_iteration_breakdown_is_iteration_time():
    from benchmarks.scaling_model import iteration_breakdown, iteration_time
    bd = iteration_breakdown("cg", 27, (16, 16, 64), 8)
    assert bd["total"] == pytest.approx(
        bd["t_mem"] + bd["t_halo"] + bd["t_precond"] + bd["t_reduce"])
    assert iteration_time("cg", 27, (16, 16, 64), 8) == bd["total"]
