"""Data pipeline: determinism, resume, sharding, prefetch."""

import numpy as np

from repro.data.pipeline import (
    MemmapSource,
    PipelineConfig,
    Prefetcher,
    SyntheticSource,
    batches,
    make_batch,
)


def _cfg(**kw):
    base = dict(batch_size=4, seq_len=16, n_shards=2, shard=0, seed=7)
    base.update(kw)
    return PipelineConfig(**base)


def test_determinism_same_step_same_batch():
    src = SyntheticSource(1000, seed=7)
    b1 = make_batch(src, _cfg(), 3)
    b2 = make_batch(SyntheticSource(1000, seed=7), _cfg(), 3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["targets"], b2["targets"])


def test_steps_differ():
    src = SyntheticSource(1000, seed=7)
    assert not np.array_equal(make_batch(src, _cfg(), 0)["tokens"],
                              make_batch(src, _cfg(), 1)["tokens"])


def test_shards_differ():
    src = SyntheticSource(1000, seed=7)
    a = make_batch(src, _cfg(shard=0), 5)
    b = make_batch(src, _cfg(shard=1), 5)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_targets_are_shifted_tokens():
    src = SyntheticSource(1000, seed=7)
    b = make_batch(src, _cfg(), 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_resume_continuity():
    """batches(start_step=k) reproduces the tail of batches(start_step=0)."""
    src = SyntheticSource(1000, seed=7)
    full = [b["targets"] for _, b in zip(range(6), batches(src, _cfg()))]
    tail = [b["targets"] for _, b in zip(range(3), batches(src, _cfg(), 3))]
    for a, b in zip(full[3:], tail):
        np.testing.assert_array_equal(a, b)


def test_memmap_source(tmp_path):
    path = tmp_path / "toks.bin"
    arr = (np.arange(10_000) % 251).astype(np.uint16)
    arr.tofile(path)
    src = MemmapSource(str(path), vocab_size=251)
    t = src.tokens(0, 0, 64)
    assert t.shape == (64,) and t.dtype == np.int32
    assert t.max() < 251
    np.testing.assert_array_equal(src.tokens(3, 1, 64), src.tokens(3, 1, 64))


def test_frontends():
    src = SyntheticSource(1000, seed=0)
    b = make_batch(src, _cfg(frontend="vision", d_model=32, mrope=True), 0)
    assert "embeds" in b and b["embeds"].shape == (4, 16, 32)
    assert b["positions"].shape == (3, 4, 16)
    b = make_batch(src, _cfg(enc_dec=True, d_model=32), 0)
    assert b["src_embeds"].shape == (4, 4, 32)
    assert "tokens" in b


def test_prefetcher_yields_in_order():
    src = SyntheticSource(1000, seed=7)
    pre = Prefetcher(batches(src, _cfg()), depth=2)
    direct = batches(src, _cfg())
    for _ in range(4):
        np.testing.assert_array_equal(next(pre)["targets"],
                                      next(direct)["targets"])
    pre.close()
