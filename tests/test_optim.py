"""Optimizer + schedules."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import adamw, global_norm
from repro.optim.schedules import constant, cosine, for_arch, wsd


def test_adamw_minimises_quadratic():
    opt = adamw(constant(0.05), weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    assert float(loss(params)) < 1e-3


def test_grad_clipping():
    opt = adamw(constant(1e-3), clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    g = {"w": jnp.full(4, 1e6)}
    p2, state, m = opt.update(g, state, params)
    assert float(m["grad_norm"]) > 1e6
    assert np.all(np.isfinite(np.asarray(p2["w"])))


def test_moments_dtype_f32_params_preserved():
    opt = adamw(constant(1e-3))
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.float32
    g = {"w": jnp.ones(4, jnp.bfloat16)}
    p2, _, _ = opt.update(g, state, params)
    assert p2["w"].dtype == jnp.bfloat16


def test_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6


def test_wsd_shape():
    f = wsd(1.0, warmup=10, stable=80, decay=10, final_frac=0.1)
    lrs = [float(f(jnp.asarray(s))) for s in range(0, 110, 1)]
    assert lrs[5] < 1.0                      # warming up
    assert abs(lrs[50] - 1.0) < 1e-6         # stable plateau
    assert lrs[-1] <= 0.1 + 1e-6             # decayed
    assert max(lrs) <= 1.0 + 1e-6


def test_cosine_monotone_decay_after_warmup():
    f = cosine(1.0, warmup=5, total=100)
    lrs = [float(f(jnp.asarray(s))) for s in range(5, 100, 5)]
    assert all(a >= b - 1e-9 for a, b in zip(lrs, lrs[1:]))


def test_minicpm_gets_wsd():
    f = for_arch("minicpm-2b", 1.0, 1000)
    mid = float(f(jnp.asarray(500)))
    assert abs(mid - 1.0) < 1e-6  # WSD plateau (cosine would have decayed)
