"""End-to-end behaviour: the drivers run, solve, train, serve, and the
reproduction's headline claims hold on the paper's own problem."""

import numpy as np


def test_solve_driver_end_to_end():
    from repro.launch import solve as solve_mod
    out = solve_mod.main(["--method", "cg_nb", "--stencil", "27pt",
                          "--grid", "24", "24", "24"])
    assert out["res_norm"] < 1e-6
    assert out["err"] < 1e-6


def test_solver_variants_agree_on_hpcg():
    """Classical and nonblocking variants solve the same system to the same
    answer (the paper's arithmetical-equivalence claim, §3.1)."""
    from repro.launch import solve as solve_mod
    xs = {}
    for m in ("cg", "cg_nb", "bicgstab", "bicgstab_b1"):
        out = solve_mod.main(["--method", m, "--stencil", "7pt",
                              "--grid", "16", "16", "16"])
        xs[m] = out
    assert abs(xs["cg"]["iters"] - xs["cg_nb"]["iters"]) <= 1
    for m, o in xs.items():
        assert o["err"] < 1e-6, m


def test_train_driver_loss_decreases():
    from repro.launch import train as train_mod
    out = train_mod.main(["--arch", "minicpm-2b", "--reduced",
                          "--steps", "8", "--batch", "4", "--seq", "64",
                          "--lr", "3e-3"])
    losses = out["losses"]
    assert len(losses) == 8
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_train_driver_with_compression_runs():
    from repro.launch import train as train_mod
    out = train_mod.main(["--arch", "internlm2-1.8b", "--reduced",
                          "--steps", "4", "--batch", "2", "--seq", "32",
                          "--compress"])
    assert all(np.isfinite(l) for l in out["losses"])


def test_serve_driver_generates():
    from repro.launch import serve as serve_mod
    out = serve_mod.main(["--arch", "internlm2-1.8b", "--reduced",
                          "--batch", "2", "--prompt-len", "16", "--gen", "4"])
    toks = np.asarray(out["tokens"])
    assert toks.shape == (2, 5)  # first sampled + 4 generated
    assert toks.min() >= 0


def test_paper_iteration_counts_small_grid():
    """Scaled-down §4.1 table: same criterion (absolute 1e-6), 32^3 grid.

    The full 128^3 validation lives in benchmarks/table_iterations.py; here
    we assert the structural properties that make that table reproduce:
    strong diagonal dominance at 7pt -> fast convergence; near-marginal at
    27pt -> slow.
    """
    from repro.core.problems import make_problem
    from repro.core.solvers import SOLVERS, LocalOp
    iters = {}
    for stencil in ("7pt", "27pt"):
        prob = make_problem((32, 32, 32), stencil)
        A = LocalOp(prob.stencil)
        for m in ("cg", "jacobi"):
            res = SOLVERS[m](A, prob.b(), prob.x0(), tol=1e-6, maxiter=2000,
                             norm_ref=1.0)
            iters[(stencil, m)] = int(res.iters)
    assert iters[("7pt", "jacobi")] < 30       # paper: 18 at 128^3
    assert iters[("7pt", "cg")] < 20           # paper: 12
    assert iters[("27pt", "jacobi")] > 150     # paper: 515
    assert iters[("27pt", "cg")] > 30          # paper: 72
