"""Solver correctness: convergence, variant equivalence, restart, criteria."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.operators import build_dense_from_stencil
from repro.core.problems import make_problem
from repro.core.solvers import SOLVERS, LocalOp, bicgstab_b1, cg, cg_nb

pytestmark = pytest.mark.usefixtures("f64")

SHAPE = (10, 10, 10)


@pytest.fixture(scope="module", params=["7pt", "27pt"])
def problem(request):
    prob = make_problem(SHAPE, request.param)
    A = LocalOp(prob.stencil)
    Ad = build_dense_from_stencil(prob.stencil, SHAPE)
    xref = np.linalg.solve(Ad, np.asarray(prob.b(), np.float64).reshape(-1))
    return prob, A, xref.reshape(SHAPE)


@pytest.mark.parametrize("method", sorted(SOLVERS))
def test_converges_to_reference(problem, method):
    prob, A, xref = problem
    res = SOLVERS[method](A, prob.b(), prob.x0(), tol=1e-8, maxiter=3000,
                          norm_ref=1.0)
    assert int(res.iters) < 3000
    assert float(res.res_norm) < 1e-8
    np.testing.assert_allclose(np.asarray(res.x), xref, atol=1e-7)


def test_cg_nb_equivalent_to_cg(problem):
    """Alg. 1 is arithmetically equivalent to classical CG: identical
    residual histories up to rounding (paper §3.1)."""
    prob, A, _ = problem
    r1 = cg(A, prob.b(), prob.x0(), tol=1e-8, maxiter=200, norm_ref=1.0)
    r2 = cg_nb(A, prob.b(), prob.x0(), tol=1e-8, maxiter=200, norm_ref=1.0)
    n = int(r1.iters)
    assert abs(int(r2.iters) - n) <= 1
    h1 = np.asarray(r1.history)[: n - 1]
    h2 = np.asarray(r2.history)[: n - 1]
    np.testing.assert_allclose(h1, h2, rtol=1e-6)


def test_bicgstab_b1_matches_classical_solution(problem):
    prob, A, xref = problem
    r = bicgstab_b1(A, prob.b(), prob.x0(), tol=1e-8, maxiter=500, norm_ref=1.0)
    np.testing.assert_allclose(np.asarray(r.x), xref, atol=1e-6)


def test_residual_norm_is_true_residual(problem):
    """The solver's internal residual estimate must match ||b - A x||."""
    prob, A, _ = problem
    for method in ("cg", "cg_nb", "bicgstab", "jacobi"):
        res = SOLVERS[method](A, prob.b(), prob.x0(), tol=1e-8, maxiter=500,
                              norm_ref=1.0)
        true_r = float(jnp.linalg.norm(
            (prob.b() - A.matvec(res.x)).reshape(-1)))
        assert abs(true_r - float(res.res_norm)) <= 1e-6 * max(true_r, 1.0)


def test_iteration_ordering_matches_paper():
    """Paper §4.1 orders: BiCGStab < CG < symGS < Jacobi (iterations)."""
    prob = make_problem((24, 24, 24), "27pt")
    A = LocalOp(prob.stencil)
    iters = {}
    for m in ("bicgstab", "cg", "gauss_seidel", "jacobi"):
        res = SOLVERS[m](A, prob.b(), prob.x0(), tol=1e-6, maxiter=2500,
                         norm_ref=1.0)
        iters[m] = int(res.iters)
    assert iters["bicgstab"] < iters["cg"] < iters["gauss_seidel"] < iters["jacobi"]


def test_maxiter_respected():
    prob = make_problem((8, 8, 8), "27pt")
    A = LocalOp(prob.stencil)
    res = SOLVERS["jacobi"](A, prob.b(), prob.x0(), tol=1e-30, maxiter=7,
                            norm_ref=1.0)
    assert int(res.iters) == 7


def test_relative_vs_absolute_criteria():
    prob = make_problem((8, 8, 8), "7pt")
    A = LocalOp(prob.stencil)
    res_rel = SOLVERS["cg"](A, prob.b(), prob.x0(), tol=1e-6)  # rel to ||b||
    res_abs = SOLVERS["cg"](A, prob.b(), prob.x0(), tol=1e-6, norm_ref=1.0)
    assert int(res_abs.iters) >= int(res_rel.iters)


def test_history_monotone_for_cg():
    prob = make_problem((8, 8, 8), "27pt")
    A = LocalOp(prob.stencil)
    res = SOLVERS["cg"](A, prob.b(), prob.x0(), tol=1e-8, maxiter=300,
                        norm_ref=1.0)
    h = np.asarray(res.history)
    h = h[~np.isnan(h)]
    # CG residuals oscillate but must decay overall
    assert h[-1] < h[0] * 1e-6
