"""repro.resilience: breakdown-aware solves, recovery policies, the chaos
harness, and the self-healing serve layer.

Three invariants anchor this file:

* the typed ``SolveResult.status`` is ALWAYS filled — a solve that
  produced a NaN iterate can never report success (the historical
  bicgstab silent-wrong bug, regression-tested below with a crafted
  singular-direction RHS);
* arming the guards changes nothing on a healthy solve — bit-for-bit
  the same iterates, and (audited separately in test_audit) zero extra
  collectives;
* every injected fault becomes a TYPED outcome — a raised
  ``SolveBreakdown``, a non-zero status, or a ``ServeReject`` with a
  machine-readable reason.  Nothing is silently dropped or silently
  wrong.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from conftest import run_multidevice

from repro.api import SolverOptions, SolverSession, fallback_chain, solve
from repro.core.problems import make_problem
from repro.obs import trace as obs
from repro.resilience import (
    STATUS_BREAKDOWN,
    STATUS_CONVERGED,
    STATUS_MAXITER,
    ChaosInjector,
    ChaosPlan,
    SolveBreakdown,
)
from repro.serve import Request, ServeConfig, SolverService

pytestmark = pytest.mark.usefixtures("f64")

GRID = (8, 8, 8)


@pytest.fixture(scope="module")
def problem():
    return make_problem(GRID, "27pt")


def _true_res(problem, x) -> float:
    """||b - Ax|| computed OFFLINE with the plain stencil — independent of
    whatever recurrence the solver carried."""
    r = np.asarray(problem.b()) - np.asarray(problem.stencil.matvec(x))
    return float(np.linalg.norm(r))


def _nan_rhs(problem):
    bad = np.asarray(problem.b()).copy()
    bad[0, 0, 0] = np.nan
    return jnp.asarray(bad)


def _attempt_spans(path):
    return [r for r in obs.read_trace(path)
            if r["kind"] == "span" and r["name"] == "resilience.attempt"]


# -----------------------------------------------------------------------------
# options & registry plumbing
# -----------------------------------------------------------------------------

def test_options_validation():
    with pytest.raises(ValueError, match="on_breakdown"):
        SolverOptions(on_breakdown="retry")
    with pytest.raises(ValueError, match="residual_replacement"):
        SolverOptions(residual_replacement=-1)
    with pytest.raises(ValueError, match="divergence_factor"):
        SolverOptions(guards=True, divergence_factor=0.5)
    with pytest.raises(ValueError, match="max_restarts"):
        SolverOptions(max_restarts=-1)


def test_guards_armed_semantics():
    assert not SolverOptions().guards_armed()
    assert SolverOptions().guard_spec() is None          # zero-sync fast path
    assert SolverOptions(guards=True).guards_armed()
    # a recovery policy arms the guards implicitly — it needs the status
    assert SolverOptions(on_breakdown="restart").guards_armed()
    assert SolverOptions(on_breakdown="fallback").guard_spec() is not None
    assert not SolverOptions(on_breakdown="raise").guards_armed()


def test_residual_replacement_requires_refresh_hook(problem):
    # classic cg computes its residual directly — no refresh hook, and
    # silently accepting the option would misrepresent what ran
    with pytest.raises(ValueError, match="residual_replacement"):
        SolverSession(problem, method="cg",
                      options=SolverOptions(residual_replacement=8))


def test_fallback_chain_walks_variant_ancestry():
    assert fallback_chain("cg") == ["cg"]
    assert fallback_chain("cg_merged") == ["cg_merged", "cg"]
    assert fallback_chain("pbicgstab_merged") == [
        "pbicgstab_merged", "pbicgstab", "bicgstab"]
    with pytest.raises(KeyError):
        fallback_chain("not_a_method")


# -----------------------------------------------------------------------------
# typed status: always on, and free when healthy
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["cg", "cg_merged", "bicgstab", "pcg"])
def test_status_converged_always_filled(problem, method):
    res = solve(problem, method=method, tol=1e-8, maxiter=500)
    assert res.status is not None
    assert int(res.status) == STATUS_CONVERGED


def test_status_maxiter(problem):
    res = solve(problem, method="cg", tol=1e-12, maxiter=2)
    assert int(res.status) == STATUS_MAXITER


def test_nan_rhs_is_breakdown_even_without_guards(problem):
    # the always-on post-loop check: no guards, no recovery policy — a
    # NaN-poisoned operand still must not report success
    res = SolverSession(problem, method="cg",
                        options=SolverOptions(tol=1e-8, maxiter=50)
                        ).solve(_nan_rhs(problem))
    assert int(res.status) == STATUS_BREAKDOWN


def test_guarded_solve_is_bitwise_free_when_healthy(problem):
    plain = SolverSession(problem, method="cg_merged",
                          options=SolverOptions(tol=1e-8, maxiter=200)
                          ).solve()
    guarded = SolverSession(problem, method="cg_merged",
                            options=SolverOptions(tol=1e-8, maxiter=200,
                                                  guards=True,
                                                  on_breakdown="none")
                            ).solve()
    assert int(plain.iters) == int(guarded.iters)
    assert float(plain.res_norm) == float(guarded.res_norm)
    np.testing.assert_array_equal(np.asarray(plain.x), np.asarray(guarded.x))
    assert int(guarded.status) == STATUS_CONVERGED


# -----------------------------------------------------------------------------
# the bicgstab silent-wrong regression (crafted singular direction)
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["bicgstab", "bicgstab_merged"])
@pytest.mark.parametrize("guards", [False, True])
def test_bicgstab_singular_direction_never_silent(problem, method, guards):
    """A' = P·A with P zeroing the z=0 plane is singular; an RHS living
    entirely in that plane makes (r̂, A'p) = 0 at k=0, so α = ρ/0 poisons
    the iterate.  Historically the recurrence res_norm kept reporting the
    stale pre-breakdown value — a NaN x shipped as 'converged'.  Now the
    exit is typed breakdown, guards or not."""
    st = problem.stencil

    def masked_mv(xp):
        return st.matvec_padded(xp).at[:, :, 0].set(0.0)

    b = np.zeros(GRID)
    b[:, :, 0] = 1.0
    res = SolverSession(
        problem, method=method,
        options=SolverOptions(tol=1e-8, maxiter=50, matvec_padded=masked_mv,
                              guards=guards, on_breakdown="none"),
    ).solve(jnp.asarray(b))
    assert int(res.status) == STATUS_BREAKDOWN
    assert int(res.status) != STATUS_CONVERGED


@pytest.mark.parametrize("method", ["cg_nb", "cg_merged"])
def test_negative_curvature_guard_exits_early(problem, method):
    """Shift A past the RHS's Rayleigh quotient: pᵀA'p < 0 at k=0.  The
    guarded loop must exit immediately (last finite iterate, typed
    breakdown); the unguarded loop grinds to maxiter but still must not
    claim convergence."""
    st = problem.stencil
    rng = np.random.default_rng(0)
    b = rng.standard_normal(GRID)
    bj = jnp.asarray(b)
    Ab = np.asarray(st.matvec(bj))
    shift = float((b * Ab).sum() / (b * b).sum()) + 5.0

    def indef_mv(xp):
        return st.matvec_padded(xp) - shift * xp[1:-1, 1:-1, 1:-1]

    def run(guards):
        return SolverSession(
            problem, method=method,
            options=SolverOptions(tol=1e-8, maxiter=60,
                                  matvec_padded=indef_mv, guards=guards,
                                  on_breakdown="none")).solve(bj)

    guarded = run(True)
    assert int(guarded.status) == STATUS_BREAKDOWN
    assert int(guarded.iters) == 0                 # fired on the init state
    assert bool(np.isfinite(np.asarray(guarded.x)).all())
    plain = run(False)
    assert int(plain.status) != STATUS_CONVERGED


# -----------------------------------------------------------------------------
# recovery policies
# -----------------------------------------------------------------------------

def test_raise_policy(problem):
    sess = SolverSession(problem, method="cg",
                         options=SolverOptions(tol=1e-8, maxiter=50,
                                               guards=True))
    with pytest.raises(SolveBreakdown) as exc:
        sess.solve(_nan_rhs(problem))
    assert int(exc.value.result.status) == STATUS_BREAKDOWN
    assert "cg" in str(exc.value)


def test_restart_recovers_from_transient_breakdown(problem, tmp_path):
    """Fail the first attempt (finite garbage iterate, typed breakdown);
    the restart policy must re-enter from that iterate and converge."""
    sess = SolverSession(problem, method="cg",
                         options=SolverOptions(tol=1e-8, maxiter=300,
                                               on_breakdown="restart",
                                               max_restarts=3))
    real, calls = sess._solve_once, []

    def flaky(b=None, x0=None):
        calls.append(None if x0 is None else np.asarray(x0).ravel()[0])
        res = real(b, x0)
        if len(calls) == 1:
            return res._replace(x=jnp.full_like(res.x, 0.5),
                                status=jnp.asarray(STATUS_BREAKDOWN,
                                                   jnp.int32))
        return res

    sess._solve_once = flaky
    path = str(tmp_path / "restart.jsonl")
    obs.enable(path)
    try:
        res = sess.solve()
    finally:
        obs.disable()
    assert int(res.status) == STATUS_CONVERGED
    assert len(calls) == 2
    assert calls[1] == 0.5          # restarted FROM the last finite iterate
    spans = _attempt_spans(path)
    assert len(spans) == 1
    assert spans[0]["attrs"]["policy"] == "restart"
    assert spans[0]["attrs"]["from_status"] == "breakdown"


def test_restart_exhausts_budget_with_typed_status(problem, tmp_path):
    sess = SolverSession(problem, method="cg",
                         options=SolverOptions(tol=1e-8, maxiter=50,
                                               on_breakdown="restart",
                                               max_restarts=2))
    path = str(tmp_path / "exhaust.jsonl")
    obs.enable(path)
    try:
        res = sess.solve(_nan_rhs(problem))     # unfixable: b itself is NaN
    finally:
        obs.disable()
    assert int(res.status) == STATUS_BREAKDOWN  # typed, never an exception
    assert len(_attempt_spans(path)) == 2       # the whole budget was spent


def test_fallback_ladder_reaches_classic(problem, tmp_path):
    """Stamp every primary attempt as breakdown; the ladder must run the
    classical ancestor for real and return its (bitwise classic) answer."""
    sess = SolverSession(problem, method="cg_merged",
                         options=SolverOptions(tol=1e-8, maxiter=300,
                                               on_breakdown="fallback"))
    real = sess._solve_once
    sess._solve_once = lambda b=None, x0=None: real(b, x0)._replace(
        status=jnp.asarray(STATUS_BREAKDOWN, jnp.int32))
    path = str(tmp_path / "fallback.jsonl")
    obs.enable(path)
    try:
        res = sess.solve()
    finally:
        obs.disable()
    assert int(res.status) == STATUS_CONVERGED
    spans = _attempt_spans(path)
    assert [s["attrs"]["method"] for s in spans] == ["cg"]
    assert spans[0]["attrs"]["policy"] == "fallback"
    ref = solve(problem, method="cg", tol=1e-8, maxiter=300)
    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(ref.x))


def test_fallback_unfixable_returns_typed_status(problem):
    res = SolverSession(problem, method="cg_merged",
                        options=SolverOptions(tol=1e-8, maxiter=50,
                                              on_breakdown="fallback")
                        ).solve(_nan_rhs(problem))
    assert int(res.status) == STATUS_BREAKDOWN


def test_fallback_ladder_retreats_overrides_first(problem):
    """With a custom SpMV the suspect is the override, not the method: the
    first rung re-runs the SAME method on defaults, then walks ancestry.
    Ladder rungs never recurse (on_breakdown='none') and drop the options
    their method can't honour (residual_replacement without a refresh
    hook)."""
    sess = SolverSession(
        problem, method="cg_merged",
        options=SolverOptions(tol=1e-8, maxiter=50, on_breakdown="fallback",
                              matvec_padded=problem.stencil.matvec_padded,
                              residual_replacement=8))
    ladder = sess._fallback_ladder()
    assert [name for name, _ in ladder] == ["cg_merged", "cg"]
    for _, rung in ladder:
        assert rung.options.on_breakdown == "none"
        assert rung.options.guards
        assert rung.options.matvec_padded is None
    assert ladder[0][1].options.residual_replacement == 8   # has refresh
    assert ladder[1][1].options.residual_replacement == 0   # cg has none


# -----------------------------------------------------------------------------
# residual replacement: convergence preserved, drift bounded, cost priced
# -----------------------------------------------------------------------------

def test_residual_replacement_converges(problem):
    res = SolverSession(problem, method="cg_merged",
                        options=SolverOptions(tol=1e-8, maxiter=300,
                                              residual_replacement=8)
                        ).solve()
    assert int(res.status) == STATUS_CONVERGED
    assert _true_res(problem, res.x) < 1e-6


@pytest.fixture(scope="module")
def drift64():
    prob = make_problem((64, 64, 64), "27pt")
    ref = solve(prob, method="cg", tol=1e-9, maxiter=400)
    assert int(ref.status) == STATUS_CONVERGED
    return prob, _true_res(prob, ref.x)


@pytest.mark.parametrize("variant", ["cg_merged", "cg_pipe"])
def test_drift_regression_64cube(drift64, variant):
    """The acceptance bar: at 64³ the replaced merged/pipelined variants'
    TRUE residual (recomputed offline, not the carried recurrence scalar)
    lands within 10x of the classical CG floor."""
    prob, floor = drift64
    res = SolverSession(prob, method=variant,
                        options=SolverOptions(tol=1e-9, maxiter=400,
                                              residual_replacement=16)
                        ).solve()
    assert int(res.status) == STATUS_CONVERGED
    assert _true_res(prob, res.x) <= 10 * floor


def test_scaling_model_prices_residual_replacement():
    from benchmarks.scaling_model import iteration_breakdown
    kw = dict(nbar=27, local_grid=(64, 64, 64), chips=64)
    base = iteration_breakdown("cg_merged", **kw)
    rr = iteration_breakdown("cg_merged", refresh_every=10, **kw)
    assert base["t_rr"] == 0.0
    assert rr["t_rr"] > 0.0
    assert rr["total"] == pytest.approx(base["total"] + rr["t_rr"])
    # a method with no refresh hook prices as zero regardless
    assert iteration_breakdown("cg", refresh_every=10, **kw)["t_rr"] == 0.0


# -----------------------------------------------------------------------------
# serve: chaos matrix
# -----------------------------------------------------------------------------

def _submit(svc, rng, n, method="cg", **kw):
    return [svc.submit(Request(b=rng.standard_normal(GRID), method=method,
                               maxiter=200, **kw)) for _ in range(n)]


@pytest.mark.parametrize("async_compile", [True, False])
def test_compile_failure_becomes_typed_rejects(async_compile):
    """The satellite regression: a bucket whose compile fails must turn
    its queued requests into per-request typed rejects — on BOTH the
    async compile-then-admit path (which used to strand them silently)
    and the sync path — while other buckets keep completing."""
    rng = np.random.default_rng(0)
    inj = ChaosInjector(ChaosPlan(seed=0, fail_compile_buckets=("bicgstab",)))
    svc = SolverService(ServeConfig(max_batch=4, guards=True,
                                    async_compile=async_compile),
                        injector=inj)
    ids_ok = _submit(svc, rng, 3, method="cg")
    ids_cf = _submit(svc, rng, 2, method="bicgstab")
    svc.run_until_drained()
    svc.close()
    res, rej = svc.results(), svc.rejects()
    assert all(i in res and res[i].status == "converged" for i in ids_ok)
    assert all(i in rej and rej[i].reason == "compile_failed"
               for i in ids_cf)
    assert len(res) + len(rej) == len(ids_ok) + len(ids_cf)  # zero stranded
    snap = svc.snapshot()
    assert snap["rejects_by_reason"] == {"compile_failed": 2}


def test_poison_quarantine_spares_clean_lanes(problem):
    rng = np.random.default_rng(1)
    svc = SolverService(ServeConfig(max_batch=4, guards=True))
    bs = {}
    ids_ok = []
    for _ in range(3):
        b = rng.standard_normal(GRID)
        rid = svc.submit(Request(b=b, method="cg", maxiter=200))
        ids_ok.append(rid)
        bs[rid] = b
    poisoned = rng.standard_normal(GRID)
    poisoned[0, 0, 0] = np.nan
    id_poison = svc.submit(Request(b=poisoned, method="cg", maxiter=200))
    svc.run_until_drained()
    svc.close()
    res, rej = svc.results(), svc.rejects()
    # the poisoned lane rode the SAME padded batch as the clean ones —
    # it is quarantined, they converge
    assert id_poison in rej and rej[id_poison].reason == "poisoned"
    assert all(i in res and res[i].status == "converged" for i in ids_ok)
    # no silent wrong answers: cross-check the shipped x against the TRUE
    # residual, recomputed offline with the plain stencil
    for rid in ids_ok:
        r = bs[rid] - np.asarray(problem.stencil.matvec(jnp.asarray(res[rid].x)))
        assert float(np.linalg.norm(r)) < 1e-5


def test_halo_delay_slows_but_never_hangs():
    rng = np.random.default_rng(6)
    inj = ChaosInjector(ChaosPlan(seed=0, halo_delay_s=0.02))
    svc = SolverService(ServeConfig(max_batch=2, guards=True), injector=inj)
    ids = _submit(svc, rng, 3, method="cg")
    svc.run_until_drained()
    svc.close()
    res = svc.results()
    assert all(i in res and res[i].status == "converged" for i in ids)


def test_poison_needs_guards_off_means_status_only():
    # guards off: no quarantine — but the typed status still ships, so the
    # caller can see the lane is poisoned (nothing is silently wrong)
    rng = np.random.default_rng(2)
    svc = SolverService(ServeConfig(max_batch=2, guards=False))
    poisoned = rng.standard_normal(GRID)
    poisoned[0, 0, 0] = np.nan
    rid = svc.submit(Request(b=poisoned, method="cg", maxiter=100))
    svc.run_until_drained()
    svc.close()
    res = svc.results()
    assert rid in res and res[rid].status == "breakdown"


def test_deadline_rejects_expired_request():
    rng = np.random.default_rng(3)
    svc = SolverService(ServeConfig(max_batch=2, guards=True))
    id_dead = svc.submit(Request(b=rng.standard_normal(GRID), method="cg",
                                 maxiter=200, deadline_s=0.0))
    id_ok = svc.submit(Request(b=rng.standard_normal(GRID), method="cg",
                               maxiter=200))
    svc.run_until_drained()
    svc.close()
    assert svc.rejects()[id_dead].reason == "deadline"
    assert svc.results()[id_ok].status == "converged"


def test_retry_absorbs_preemption_in_place():
    rng = np.random.default_rng(4)
    inj = ChaosInjector(ChaosPlan(seed=0, preempt_at=(0,)))
    svc = SolverService(ServeConfig(max_batch=4, guards=True, max_retries=2,
                                    retry_backoff_s=0.01, retry_seed=0),
                        injector=inj)
    ids = _submit(svc, rng, 3, method="cg")
    svc.run_until_drained()
    svc.close()
    snap = svc.snapshot()
    assert all(i in svc.results() for i in ids)
    assert snap["retries"] >= 1
    assert snap["preemptions"] == 0     # absorbed, never hit the requeue


def test_retry_budget_exhausted_falls_back_to_requeue():
    rng = np.random.default_rng(5)
    inj = ChaosInjector(ChaosPlan(seed=0, preempt_at=(0,)))
    svc = SolverService(ServeConfig(max_batch=4, guards=True, max_retries=0),
                        injector=inj)
    ids = _submit(svc, rng, 3, method="cg")
    svc.run_until_drained()
    svc.close()
    snap = svc.snapshot()
    assert all(i in svc.results() for i in ids)   # requeued, then completed
    assert snap["preemptions"] == 1
    assert snap["retries"] == 0


def test_chaos_smoke_suite(tmp_path):
    """The ``make chaos-smoke`` entry point end-to-end: every fault class,
    one seeded run, a validating trace artifact."""
    from repro.resilience.__main__ import run_smoke
    summary = run_smoke(str(tmp_path / "TRACE_chaos.jsonl"), seed=0)
    assert summary["ok"], summary["checks"]


# -----------------------------------------------------------------------------
# multi-device: guards under shard_map, device loss, elastic shrink
# -----------------------------------------------------------------------------

SCRIPT_GUARDED_SHARDMAP = r"""
import json
import numpy as np
import jax.numpy as jnp
from repro.core.problems import enable_f64, make_problem
from repro.api import SolverOptions, SolverSession
from repro.core.compat import make_mesh
enable_f64()
prob = make_problem((8, 8, 8), "27pt")
mesh = make_mesh((8,), ("cells",))
opts = SolverOptions(tol=1e-8, maxiter=200, guards=True, on_breakdown="none",
                     residual_replacement=8)
dist = SolverSession(prob, method="cg_merged", options=opts, mesh=mesh).solve()
loc = SolverSession(prob, method="cg_merged", options=opts).solve()
bad = np.asarray(prob.b()).copy(); bad[0, 0, 0] = np.nan
nres = SolverSession(prob, method="cg_merged", options=opts,
                     mesh=mesh).solve(jnp.asarray(bad))
print(json.dumps({
    "status_dist": int(dist.status), "status_local": int(loc.status),
    "iters_equal": int(dist.iters) == int(loc.iters),
    "x_equal": bool((np.asarray(dist.x) == np.asarray(loc.x)).all()),
    "nan_status": int(nres.status)}))
"""


def test_guards_and_refresh_under_shardmap():
    """Guards + residual replacement on an 8-device mesh: the guarded
    distributed solve matches the guarded local one bitwise, and a
    poisoned operand exits typed breakdown on every shard (the guard
    scalars are post-psum replicated — no shard divergence)."""
    out = run_multidevice(SCRIPT_GUARDED_SHARDMAP)
    assert out["status_dist"] == 0 and out["status_local"] == 0
    assert out["iters_equal"] and out["x_equal"]
    assert out["nan_status"] == 2


SCRIPT_DEVICE_LOSS = r"""
import json
import numpy as np
from repro.core.problems import enable_f64
from repro.core.compat import make_mesh
from repro.resilience import ChaosInjector, ChaosPlan
from repro.runtime.elastic import shrink_mesh
from repro.serve import Request, ServeConfig, SolverService
enable_f64()
out = {}

# -- shrink_mesh unit behaviour -----------------------------------------------
mesh = make_mesh((8,), ("cells",))
ids = [d.id for d in mesh.devices.flat]
m2 = shrink_mesh(mesh, lost=ids[6:], divides=8)   # 6 survive -> trim to 4
out["shrunk_to"] = int(np.prod(m2.devices.shape))
out["axis_kept"] = list(m2.axis_names) == ["cells"]
try:
    shrink_mesh(make_mesh((4, 2), ("data", "model")), lost=())
    out["multiaxis_raises"] = False
except ValueError:
    out["multiaxis_raises"] = True

# -- device loss mid-stream: shrink, recompile, finish the work ---------------
rng = np.random.default_rng(0)
inj = ChaosInjector(ChaosPlan(seed=0, device_loss_at=(0,),
                              lose_devices=(6, 7)))
svc = SolverService(ServeConfig(max_batch=2, guards=True, mesh=mesh),
                    injector=inj)
rids = [svc.submit(Request(b=rng.standard_normal((8, 8, 8)), method="cg",
                           maxiter=200)) for _ in range(4)]
svc.run_until_drained()
svc.close()
res, snap = svc.results(), svc.snapshot()
out["all_converged"] = all(
    i in res and res[i].status == "converged" for i in rids)
out["device_losses"] = snap["device_losses"]
out["rejected"] = snap["service_rejects"]
out["mesh_after"] = int(np.prod(svc._mesh.devices.shape))
print(json.dumps(out))
"""


def test_device_loss_shrinks_mesh_and_resumes():
    """Losing 2 of 8 devices mid-dispatch: the service shrinks the mesh to
    the largest extent-dividing survivor count (4), drops every cached
    executable for the dead topology, requeues the in-flight batch, and
    completes all work on the shrunken mesh — zero rejects, zero drops."""
    out = run_multidevice(SCRIPT_DEVICE_LOSS)
    assert out["shrunk_to"] == 4 and out["axis_kept"]
    assert out["multiaxis_raises"]
    assert out["all_converged"]
    assert out["device_losses"] == 1
    assert out["rejected"] == 0
    assert out["mesh_after"] == 4
