"""Model stack: per-arch smoke (reduced configs), attention/cache/SSD
semantics, M-RoPE, softcap, decode-vs-forward consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import all_configs, get_config
from repro.models import steps as steps_mod
from repro.models.attention import _chunked_sdpa, _sdpa
from repro.models.common import apply_mrope, apply_rope, softcap
from repro.models.decode import caches_from_prefill, init_caches
from repro.models.transformer import ModelCtx, forward, init_params
from repro.optim.adamw import adamw
from repro.optim.schedules import for_arch

ARCHS = sorted(all_configs())


@pytest.fixture(scope="module")
def mesh1():
    from repro.core.compat import make_mesh
    return make_mesh((1, 1), ("data", "model"))


def _ctx(cfg, mesh):
    return ModelCtx(cfg=cfg, mesh=mesh, dp_axes=("data",), tp_axis="model",
                    dtype=jnp.float32, remat=False)


# -----------------------------------------------------------------------------
# Per-arch smoke: one train step + one decode step, reduced config (deliverable f)
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_and_decode(arch, mesh1):
    cfg = get_config(arch).reduced()
    ctx = _ctx(cfg, mesh1)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batch = steps_mod.synthetic_batch(cfg, "train_4k", override=(32, 2),
                                      dtype=jnp.float32)
    opt = adamw(for_arch(arch, 1e-3, 100))
    state = opt.init(params)
    step = steps_mod.make_train_step(ctx, opt)
    p2, s2, _, metrics = jax.jit(step)(params, state, None, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    # params actually changed
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params))
                if a.dtype == jnp.float32)
    assert delta > 0

    dbatch = steps_mod.synthetic_batch(cfg, "decode_32k", override=(64, 2),
                                       dtype=jnp.float32)
    dstep = steps_mod.make_decode_step(ctx)
    args = (params, dbatch["tokens"], dbatch["cur_pos"], dbatch["caches"])
    if cfg.enc_dec:
        args += (dbatch["cross_kvs"],)
    logits, new_caches = jax.jit(dstep)(*args)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_full_config_exact(arch):
    """The FULL configs carry the assigned numbers exactly."""
    cfg = get_config(arch)
    table = {
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    }
    L, d, H, KV, ff, V = table[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == (L, d, H, KV, ff, V)
    if arch == "qwen3-moe-235b-a22b":
        assert (cfg.n_experts, cfg.top_k) == (128, 8)
    if arch == "llama4-maverick-400b-a17b":
        assert (cfg.n_experts, cfg.top_k) == (128, 1)
    if arch == "mamba2-780m":
        assert cfg.ssm_state == 128
    if arch == "hymba-1.5b":
        assert cfg.ssm_state == 16


# -----------------------------------------------------------------------------
# Attention semantics
# -----------------------------------------------------------------------------

def test_decode_matches_forward_next_token(mesh1):
    """Prefill caches + one decode step == full forward at position S."""
    cfg = get_config("internlm2-1.8b").reduced()
    ctx = _ctx(cfg, mesh1)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size, jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(S + 1), (B, S + 1))
    # reference: full forward over S+1 tokens, logits at last position
    full_logits, _ = forward(ctx, params, {"tokens": toks, "positions": pos})
    # prefill S tokens -> cache -> decode token S
    _, extras = forward(ctx, params,
                        {"tokens": toks[:, :S], "positions": pos[:, :S]},
                        collect_kv=True)
    caches = caches_from_prefill(ctx, extras["kvs"], cache_len=S + 8)
    dstep = steps_mod.make_decode_step(ctx)
    logits, _ = dstep(params, toks[:, S:S + 1], jnp.array(S, jnp.int32), caches)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_ssm_decode_matches_forward(mesh1):
    """SSD chunked prefill == stepwise decode (streaming equivalence)."""
    cfg = get_config("mamba2-780m").reduced()
    ctx = _ctx(cfg, mesh1)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size, jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    full_logits, _ = forward(ctx, params, {"tokens": toks, "positions": pos})
    caches = init_caches(ctx, B, S)
    dstep = jax.jit(steps_mod.make_decode_step(ctx))
    for i in range(S):
        logits, caches = dstep(params, toks[:, i:i + 1],
                               jnp.array(i, jnp.int32), caches)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_chunked_attention_matches_full():
    cfg = get_config("internlm2-1.8b").reduced()
    B, S, H, KV, hd = 2, 128, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    for window in (0, 32):
        qp = jnp.arange(S)[:, None]
        kp = jnp.arange(S)[None, :]
        mask = kp <= qp
        if window:
            mask &= kp > (qp - window)
        full = _sdpa(cfg, q, k, v, mask[None, None])
        ch = _chunked_sdpa(cfg, q, k, v, window=window, n_q_chunks=4,
                           kv_chunk=32)
        np.testing.assert_allclose(np.asarray(full), np.asarray(ch),
                                   rtol=2e-5, atol=2e-5)


def test_sliding_window_ignores_distant_tokens(mesh1):
    """Perturbing a token outside every window must not change the logits."""
    cfg = dataclasses.replace(get_config("hymba-1.5b").reduced(),
                              sliding_window=8)
    # isolate the attention path: drop SSM influence by zeroing its out_proj
    ctx = _ctx(cfg, mesh1)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    params["layers"]["ssm"]["out_proj"] = jnp.zeros_like(
        params["layers"]["ssm"]["out_proj"])
    B, S = 1, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size, jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    base, _ = forward(ctx, params, {"tokens": toks, "positions": pos})
    toks2 = toks.at[:, 5].set((toks[:, 5] + 1) % cfg.vocab_size)
    pert, _ = forward(ctx, params, {"tokens": toks2, "positions": pos})
    # last position attends only to the final window (and SSM is silenced):
    # single-layer influence cannot reach position 63 from position 5
    if cfg.n_layers * cfg.sliding_window < S:
        np.testing.assert_allclose(np.asarray(base[:, -1]),
                                   np.asarray(pert[:, -1]), atol=1e-5)


def test_mrope_reduces_to_rope_for_text():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 16), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16), (2, 16))
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 16))
    r1 = apply_rope(x, pos, 1e4)
    r2 = apply_mrope(x, pos3, 1e4, (2, 3, 3))
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2),
                               rtol=1e-6, atol=1e-6)


def test_softcap_bounds():
    x = jnp.linspace(-1000, 1000, 101)
    y = softcap(x, 50.0)
    assert float(jnp.max(jnp.abs(y))) <= 50.0
    np.testing.assert_allclose(np.asarray(softcap(x, 0.0)), np.asarray(x))


def test_gemma2_local_global_alternation():
    from repro.models.transformer import _window_flags
    cfg = get_config("gemma2-2b")
    flags = _window_flags(cfg)
    assert flags[0] == 4096 and flags[1] == 0 and len(flags) == 26
    assert all(f == 4096 for f in flags[::2])
    assert all(f == 0 for f in flags[1::2])


def test_grad_accumulation_matches_full_batch(mesh1):
    """accum=2 microbatching == full-batch gradients (token-mean CE)."""
    from repro.optim.adamw import adamw
    from repro.optim.schedules import constant
    cfg = get_config("internlm2-1.8b").reduced()
    ctx = _ctx(cfg, mesh1)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batch = steps_mod.synthetic_batch(cfg, "train_4k", override=(32, 4),
                                      dtype=jnp.float32)
    opt = adamw(constant(1e-3))
    state = opt.init(params)
    p1, _, _, m1 = jax.jit(steps_mod.make_train_step(ctx, opt))(
        params, state, None, batch)
    p2, _, _, m2 = jax.jit(steps_mod.make_train_step(ctx, opt, accum=2))(
        params, state, None, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
