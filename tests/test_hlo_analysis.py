"""HLO analysis layer: shape parsing, collective counting, overlap slack —
plus the PR-4 acceptance claim: merged/pipelined Krylov iteration bodies
compile to exactly ONE all-reduce on a real multi-device mesh, where the
classics emit 2–3."""

import jax
import jax.numpy as jnp
import pytest
from conftest import run_multidevice

from repro.analysis.hlo import (
    collective_bytes,
    count_collectives,
    overlap_slack,
    parse_computations,
    shape_bytes,
)


def test_shape_bytes():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("bf16[4096]") == 8192
    assert shape_bytes("pred[]") == 1
    assert shape_bytes("(f32[8], s32[4])") == 32 + 16


def test_parse_simple_program():
    txt = jax.jit(lambda a, b: a @ b + 1.0).lower(
        jnp.zeros((8, 8)), jnp.zeros((8, 8))).compile().as_text()
    comps = parse_computations(txt)
    assert comps
    ops = {i.opcode for c in comps for i in c.instructions}
    assert any("dot" in o or "fusion" in o or "custom-call" in o for o in ops)


def test_no_collectives_single_device():
    txt = jax.jit(lambda a: a * 2).lower(jnp.zeros((8,))).compile().as_text()
    assert count_collectives(txt) == {}
    assert collective_bytes(txt) == 0


def test_trip_count_scaling():
    hlo = """
HloModule m
%body.1 (p: f32[8]) -> f32[8] {
  %p = f32[8] parameter(0)
  ROOT %ar = f32[8] all-reduce(%p), to_apply=%add
}
ENTRY %main (x: f32[8]) -> f32[8] {
  %x = f32[8] parameter(0)
  ROOT %ar2 = f32[8] all-reduce(%x), to_apply=%add
}
"""
    base = collective_bytes(hlo)
    scaled = collective_bytes(hlo, trip_counts={"body": 10})
    assert scaled == base + 9 * 32  # body's 32B counted 10x


def test_overlap_slack_structure():
    hlo = """
ENTRY %main (x: f32[64], y: f32[64]) -> f32[64] {
  %x = f32[64] parameter(0)
  %y = f32[64] parameter(1)
  %ar = f32[64] all-reduce(%x), to_apply=%add
  %big = f32[64] multiply(%y, %y)
  ROOT %out = f32[64] add(%ar, %big)
}
"""
    rep = overlap_slack(hlo)
    assert len(rep) == 1
    # %big is independent of the all-reduce -> hideable work exists
    assert rep[0]["slack_bytes"] >= 256


# -----------------------------------------------------------------------------
# Reduction counts of the compiled shard_map iteration bodies (PR 4).
# One step of each method is lowered on an 8-host-device 1-D mesh in a
# subprocess (the main pytest process must keep seeing 1 device) and its
# all-reduces counted: the merged/pipelined variants' entire scalar traffic
# must ride ONE stacked psum, the classics keep one per (paired) dot.
# -----------------------------------------------------------------------------

_COUNT_SCRIPT = r"""
import json
import jax
jax.config.update("jax_enable_x64", True)
from repro.core.compat import make_mesh
from repro.core.problems import make_problem
from repro.core.distributed import solve_step_shardmap, step_state_layout
from repro.analysis.hlo import count_collectives
from jax.sharding import NamedSharding

mesh = make_mesh((8,), ("cells",))
prob = make_problem((8, 8, 16), "27pt")
out = {}
for m in ("cg", "bicgstab", "pcg",
          "cg_merged", "cg_pipe", "pcg_merged", "pcg_pipe",
          "bicgstab_merged", "pbicgstab_merged"):
    fn, layout = solve_step_shardmap(prob, m, mesh)
    sh = NamedSharding(mesh, layout.spec())
    vecs, scals = step_state_layout(m)
    arr = jax.ShapeDtypeStruct(prob.shape, prob.dtype, sharding=sh)
    scal = jax.ShapeDtypeStruct((), prob.dtype)
    args = [arr] * (1 + len(vecs)) + [scal] * len(scals)
    txt = jax.jit(fn).lower(*args).compile().as_text()
    out[m] = count_collectives(txt).get("all-reduce", 0)
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def allreduce_counts():
    return run_multidevice(_COUNT_SCRIPT)


def test_classics_emit_multiple_allreduces(allreduce_counts):
    assert allreduce_counts["cg"] == 2
    assert allreduce_counts["bicgstab"] == 3
    assert allreduce_counts["pcg"] == 2      # p·Ap + the fused (r·z, r·r) pair


def test_merged_and_pipelined_emit_exactly_one_allreduce(allreduce_counts):
    """The tentpole claim, verified on compiled HLO: every reduction-hiding
    variant's iteration body contains exactly ONE all-reduce."""
    for m in ("cg_merged", "cg_pipe", "pcg_merged", "pcg_pipe",
              "bicgstab_merged", "pbicgstab_merged"):
        assert allreduce_counts[m] == 1, (m, allreduce_counts)
