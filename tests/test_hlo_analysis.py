"""HLO analysis layer: shape parsing, collective counting, overlap slack."""

import jax
import jax.numpy as jnp

from repro.analysis.hlo import (
    collective_bytes,
    count_collectives,
    overlap_slack,
    parse_computations,
    shape_bytes,
)


def test_shape_bytes():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("bf16[4096]") == 8192
    assert shape_bytes("pred[]") == 1
    assert shape_bytes("(f32[8], s32[4])") == 32 + 16


def test_parse_simple_program():
    txt = jax.jit(lambda a, b: a @ b + 1.0).lower(
        jnp.zeros((8, 8)), jnp.zeros((8, 8))).compile().as_text()
    comps = parse_computations(txt)
    assert comps
    ops = {i.opcode for c in comps for i in c.instructions}
    assert any("dot" in o or "fusion" in o or "custom-call" in o for o in ops)


def test_no_collectives_single_device():
    txt = jax.jit(lambda a: a * 2).lower(jnp.zeros((8,))).compile().as_text()
    assert count_collectives(txt) == {}
    assert collective_bytes(txt) == 0


def test_trip_count_scaling():
    hlo = """
HloModule m
%body.1 (p: f32[8]) -> f32[8] {
  %p = f32[8] parameter(0)
  ROOT %ar = f32[8] all-reduce(%p), to_apply=%add
}
ENTRY %main (x: f32[8]) -> f32[8] {
  %x = f32[8] parameter(0)
  ROOT %ar2 = f32[8] all-reduce(%x), to_apply=%add
}
"""
    base = collective_bytes(hlo)
    scaled = collective_bytes(hlo, trip_counts={"body": 10})
    assert scaled == base + 9 * 32  # body's 32B counted 10x


def test_overlap_slack_structure():
    hlo = """
ENTRY %main (x: f32[64], y: f32[64]) -> f32[64] {
  %x = f32[64] parameter(0)
  %y = f32[64] parameter(1)
  %ar = f32[64] all-reduce(%x), to_apply=%add
  %big = f32[64] multiply(%y, %y)
  ROOT %out = f32[64] add(%ar, %big)
}
"""
    rep = overlap_slack(hlo)
    assert len(rep) == 1
    # %big is independent of the all-reduce -> hideable work exists
    assert rep[0]["slack_bytes"] >= 256
