"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.operators import STENCIL_7PT, STENCIL_27PT
from repro.core.problems import make_problem
from repro.core.solvers import SOLVERS, LocalOp
from repro.kernels import ops, ref

STENCILS = [STENCIL_7PT, STENCIL_27PT]
SHAPES = [(8, 8, 8), (12, 10, 16), (16, 16, 24)]
DTYPES = [jnp.float32, jnp.float64]


def tols(dt):
    return dict(rtol=1e-4, atol=1e-5) if dt == jnp.float32 else dict(rtol=1e-12, atol=1e-12)


@pytest.fixture(scope="module", autouse=True)
def _f64():
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", prev)   # don't clobber session state


@pytest.mark.parametrize("stencil", STENCILS, ids=lambda s: s.name)
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dt", DTYPES, ids=str)
def test_stencil_spmv(stencil, shape, dt):
    x = jax.random.normal(jax.random.PRNGKey(0), shape, dt)
    xp = jnp.pad(x, 1)
    y = ops.spmv(xp, stencil)
    yr = ref.stencil_spmv_ref(xp, stencil=stencil)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), **tols(dt))


@pytest.mark.parametrize("stencil", STENCILS, ids=lambda s: s.name)
@pytest.mark.parametrize("dt", DTYPES, ids=str)
def test_stencil_spmv_fused_dot(stencil, dt):
    x = jax.random.normal(jax.random.PRNGKey(1), (12, 12, 16), dt)
    xp = jnp.pad(x, 1)
    y, d = ops.spmv_dot(xp, stencil)
    yr, dr = ref.stencil_spmv_dot_ref(xp, stencil=stencil)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), **tols(dt))
    np.testing.assert_allclose(float(d), float(dr),
                               rtol=1e-3 if dt == jnp.float32 else 1e-12)


@pytest.mark.parametrize("n", [1000, 4096, 5000])
@pytest.mark.parametrize("dt", DTYPES, ids=str)
def test_fused_axpby(n, dt):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x, y, z, w = (jax.random.normal(k, (n,), dt) for k in ks)
    a, b, c = (jnp.asarray(v, dt) for v in (0.3, -1.2, 2.0))
    o = ops.axpbypcz(a, x, b, y, c, z)
    np.testing.assert_allclose(np.asarray(o),
                               np.asarray(ref.fused_axpby_ref(a, x, b, y, c, z)),
                               **tols(dt))
    o2, d = ops.axpbypcz_dot(a, x, b, y, c, z, w)
    _, dr = ref.fused_axpby_dot_ref(a, x, b, y, c, z, w)
    np.testing.assert_allclose(float(d), float(dr),
                               rtol=1e-3 if dt == jnp.float32 else 1e-12)


@pytest.mark.parametrize("stencil", STENCILS, ids=lambda s: s.name)
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dt", DTYPES, ids=str)
def test_stencil_spmv_dots(stencil, shape, dt):
    """The merged-CG kernel: SpMV + BOTH dot partials in one pass."""
    x = jax.random.normal(jax.random.PRNGKey(8), shape, dt)
    xp = jnp.pad(x, 1)
    y, d_yx, d_xx = ops.spmv_dots(xp, stencil)
    yr, dr_yx, dr_xx = ref.stencil_spmv_dots_ref(xp, stencil=stencil)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), **tols(dt))
    rt = 1e-3 if dt == jnp.float32 else 1e-12
    np.testing.assert_allclose(float(d_yx), float(dr_yx), rtol=rt)
    np.testing.assert_allclose(float(d_xx), float(dr_xx), rtol=rt)


@pytest.mark.parametrize("n", [1000, 4096, 5000])
@pytest.mark.parametrize("dt", DTYPES, ids=str)
def test_fused_cg_body(n, dt):
    """The merged-CG vector-update kernel: 4 axpys in one pass, and the
    Chronopoulos–Gear ordering (x/r consume the UPDATED p/s)."""
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    x, r, p, s, w = (jax.random.normal(k, (n,), dt) for k in ks)
    alpha, beta = jnp.asarray(0.37, dt), jnp.asarray(-1.4, dt)
    outs = ops.cg_body(alpha, beta, x, r, p, s, w)
    refs = ref.fused_cg_body_ref(alpha, beta, x, r, p, s, w)
    for o, orf in zip(outs, refs):
        np.testing.assert_allclose(np.asarray(o), np.asarray(orf), **tols(dt))
    # the ordering really matters: x' uses p' (not the old p)
    x_new = np.asarray(outs[0])
    assert not np.allclose(x_new, np.asarray(x + alpha * p), atol=1e-6)


@pytest.mark.parametrize("stencil", STENCILS, ids=lambda s: s.name)
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dt", DTYPES, ids=str)
def test_spmv_dots3(stencil, shape, dt):
    """The PCG/pipelined reduction triple: SpMV + 3 dot partials, one pass."""
    ks = jax.random.split(jax.random.PRNGKey(12), 2)
    x, r = (jax.random.normal(k, shape, dt) for k in ks)
    xp = jnp.pad(x, 1)
    y, yx, rx, rr = ops.spmv_dots3(xp, r, stencil)
    yr, yxr, rxr, rrr = ref.stencil_spmv_dots3_ref(xp, r, stencil=stencil)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), **tols(dt))
    rt = 1e-3 if dt == jnp.float32 else 1e-12
    for d, dr in ((yx, yxr), (rx, rxr), (rr, rrr)):
        np.testing.assert_allclose(float(d), float(dr), rtol=rt)


@pytest.mark.parametrize("n", [1000, 4096, 5000])
@pytest.mark.parametrize("dt", DTYPES, ids=str)
def test_fused_dots(n, dt):
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    a, b, c = (jax.random.normal(k, (n,), dt) for k in ks)
    outs = ops.fused_dots(a, b, c)
    refs = ref.fused_dots_ref(a, b, c)
    rt = 1e-3 if dt == jnp.float32 else 1e-12
    for o, orf in zip(outs, refs):
        np.testing.assert_allclose(float(o), float(orf), rtol=rt)


@pytest.mark.parametrize("n", [1000, 4096, 5000])
@pytest.mark.parametrize("dt", DTYPES, ids=str)
def test_fused_pipe_body(n, dt):
    """Pipelined CG's six recurrences in one read pass, in the
    Ghysels–Vanroose ordering (x/r/w consume the UPDATED p/s/z)."""
    ks = jax.random.split(jax.random.PRNGKey(14), 7)
    x, r, w, p, s, z, nn = (jax.random.normal(k, (n,), dt) for k in ks)
    alpha, beta = jnp.asarray(0.41, dt), jnp.asarray(-0.9, dt)
    outs = ops.pipe_body(alpha, beta, x, r, w, p, s, z, nn)
    refs = ref.fused_pipe_body_ref(alpha, beta, x, r, w, p, s, z, nn)
    for o, orf in zip(outs, refs):
        np.testing.assert_allclose(np.asarray(o), np.asarray(orf), **tols(dt))


@pytest.mark.parametrize("n", [1000, 4096, 5000])
@pytest.mark.parametrize("dt", DTYPES, ids=str)
def test_fused_pcg_body(n, dt):
    ks = jax.random.split(jax.random.PRNGKey(15), 6)
    x, r, u, p, s, w = (jax.random.normal(k, (n,), dt) for k in ks)
    alpha, beta = jnp.asarray(0.29, dt), jnp.asarray(1.7, dt)
    outs = ops.pcg_body(alpha, beta, x, r, u, p, s, w)
    refs = ref.fused_pcg_body_ref(alpha, beta, x, r, u, p, s, w)
    for o, orf in zip(outs, refs):
        np.testing.assert_allclose(np.asarray(o), np.asarray(orf), **tols(dt))


@pytest.mark.parametrize("n", [1000, 4096, 5000])
@pytest.mark.parametrize("dt", DTYPES, ids=str)
def test_fused_ppipe_body(n, dt):
    ks = jax.random.split(jax.random.PRNGKey(16), 10)
    x, r, u, w, p, s, q, z, m, nn = (
        jax.random.normal(k, (n,), dt) for k in ks)
    alpha, beta = jnp.asarray(0.53, dt), jnp.asarray(-0.6, dt)
    outs = ops.ppipe_body(alpha, beta, x, r, u, w, p, s, q, z, m, nn)
    refs = ref.fused_ppipe_body_ref(alpha, beta, x, r, u, w, p, s, q, z,
                                    m, nn)
    for o, orf in zip(outs, refs):
        np.testing.assert_allclose(np.asarray(o), np.asarray(orf), **tols(dt))


@pytest.mark.parametrize("n", [1000, 4096, 5000])
@pytest.mark.parametrize("dt", DTYPES, ids=str)
def test_bicgstab_update1(n, dt):
    ks = jax.random.split(jax.random.PRNGKey(17), 6)
    y, p, q, yv, t, v = (jax.random.normal(k, (n,), dt) for k in ks)
    alpha, omega = jnp.asarray(0.73, dt), jnp.asarray(0.31, dt)
    outs = ops.bicgstab_update1(alpha, omega, y, p, q, yv, t, v)
    refs = ref.bicgstab_update1_ref(alpha, omega, y, p, q, yv, t, v)
    for o, orf in zip(outs, refs):
        np.testing.assert_allclose(np.asarray(o), np.asarray(orf), **tols(dt))


@pytest.mark.parametrize("stencil", STENCILS, ids=lambda s: s.name)
@pytest.mark.parametrize("dt", DTYPES, ids=str)
def test_bicgstab_spmv_dots(stencil, dt):
    """BiCGStab sweep 1: SpMV + q/y recurrences + 9 stacked dot partials."""
    shape = (12, 10, 16)
    ks = jax.random.split(jax.random.PRNGKey(18), 7)
    zi, z, r, w, s, rhat, t = (jax.random.normal(k, shape, dt) for k in ks)
    alpha = jnp.asarray(0.47, dt)
    zp = jnp.pad(zi, 1)
    v, q, y, parts = ops.bicgstab_spmv_dots(zp, z, r, w, s, rhat, t,
                                            alpha, stencil)
    vr, qr, yr, partsr = ref.bicgstab_spmv_dots_ref(zp, z, r, w, s, rhat, t,
                                                    alpha, stencil=stencil)
    for o, orf in ((v, vr), (q, qr), (y, yr)):
        np.testing.assert_allclose(np.asarray(o), np.asarray(orf), **tols(dt))
    rt = 1e-3 if dt == jnp.float32 else 1e-12
    for d, dr in zip(parts, partsr):
        np.testing.assert_allclose(float(d), float(dr), rtol=rt)


@pytest.mark.parametrize("stencil", STENCILS, ids=lambda s: s.name)
@pytest.mark.parametrize("dt", DTYPES, ids=str)
def test_bicgstab_spmv_update(stencil, dt):
    """BiCGStab sweep 2: SpMV + the β/ω direction recurrences."""
    shape = (12, 10, 16)
    ks = jax.random.split(jax.random.PRNGKey(19), 7)
    wi, w, r, p, s, z, v = (jax.random.normal(k, shape, dt) for k in ks)
    omega, beta = jnp.asarray(0.21, dt), jnp.asarray(-1.1, dt)
    wp = jnp.pad(wi, 1)
    outs = ops.bicgstab_spmv_update(wp, w, r, p, s, z, v, omega, beta,
                                    stencil)
    refs = ref.bicgstab_spmv_update_ref(wp, w, r, p, s, z, v, omega, beta,
                                        stencil=stencil)
    for o, orf in zip(outs, refs):
        np.testing.assert_allclose(np.asarray(o), np.asarray(orf), **tols(dt))


@pytest.mark.parametrize("dt", DTYPES, ids=str)
def test_cg_fused_update(dt):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    r, ar, p, ap = (jax.random.normal(k, (3000,), dt) for k in ks)
    beta = jnp.asarray(0.7, dt)
    pn, apn, pd = ops.cg_update(beta, r, ar, p, ap)
    pnr, apnr, pdr = ref.cg_fused_update_ref(beta, r, ar, p, ap)
    np.testing.assert_allclose(np.asarray(pn), np.asarray(pnr), **tols(dt))
    np.testing.assert_allclose(np.asarray(apn), np.asarray(apnr), **tols(dt))
    np.testing.assert_allclose(float(pd), float(pdr),
                               rtol=1e-3 if dt == jnp.float32 else 1e-12)


@pytest.mark.parametrize("stencil", STENCILS, ids=lambda s: s.name)
@pytest.mark.parametrize("colour", [0, 1])
def test_rb_gs_half_sweep(stencil, colour):
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 8, 8), jnp.float64)
    b = jax.random.normal(jax.random.PRNGKey(5), (8, 8, 8), jnp.float64)
    xp = jnp.pad(x, 1)
    o = ops.gs_half_sweep(xp, b, stencil, colour)
    orf = ref.rb_gs_half_sweep_ref(xp, b, stencil=stencil, colour=colour)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("stencil", STENCILS, ids=lambda s: s.name)
@pytest.mark.parametrize("shape", SHAPES)
def test_cheb_fused_step(stencil, shape):
    """Fused Chebyshev step: matvec + both axpby recurrences in one pass."""
    ks = jax.random.split(jax.random.PRNGKey(10), 3)
    z, r, d = (jax.random.normal(k, shape, jnp.float64) for k in ks)
    zp = jnp.pad(z, 1)
    zn, dn = ops.cheb_step(zp, r, d, stencil, a=0.4, c=1.3)
    znr, dnr = ref.cheb_fused_step_ref(zp, r, d, stencil=stencil, a=0.4, c=1.3)
    np.testing.assert_allclose(np.asarray(zn), np.asarray(znr),
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(dn), np.asarray(dnr),
                               rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("stencil", STENCILS, ids=lambda s: s.name)
@pytest.mark.parametrize("omega", [1.0, 0.8])
def test_block_jacobi_sweep(stencil, omega):
    ks = jax.random.split(jax.random.PRNGKey(11), 2)
    z, r = (jax.random.normal(k, (8, 8, 8), jnp.float64) for k in ks)
    zp = jnp.pad(z, 1)
    o = ops.jacobi_sweep(zp, r, stencil, omega=omega)
    orf = ref.block_jacobi_sweep_ref(zp, r, stencil=stencil, omega=omega)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("window", [0, 32])
@pytest.mark.parametrize("dt", [jnp.float32], ids=str)
def test_flash_attention(window, dt):
    B, S, H, hd = 2, 128, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, hd), dt) for kk in ks)
    out = ops.flash_attention(q, k, v, bq=32, bkv=32, window=window)
    refo = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(refo),
                               rtol=1e-4, atol=1e-5)


def test_flash_attention_shape_sweep():
    for (B, S, H, hd, bq, bkv) in [(1, 64, 2, 8, 16, 32), (2, 96, 1, 16, 32, 16),
                                   (1, 256, 2, 32, 64, 64)]:
        ks = jax.random.split(jax.random.PRNGKey(S), 3)
        q, k, v = (jax.random.normal(kk, (B, S, H, hd), jnp.float32)
                   for kk in ks)
        out = ops.flash_attention(q, k, v, bq=bq, bkv=bkv)
        refo = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(refo),
                                   rtol=1e-4, atol=1e-5)


def test_pallas_backed_cg_matches_jnp_backed():
    """The kernels are drop-in for the solver's matvec hook."""
    prob = make_problem((16, 16, 16), "27pt")
    A1 = LocalOp(prob.stencil)
    A2 = LocalOp(prob.stencil,
                 matvec_padded=ops.make_matvec_padded(prob.stencil))
    r1 = SOLVERS["cg"](A1, prob.b(), prob.x0(), tol=1e-6, maxiter=200,
                       norm_ref=1.0)
    r2 = SOLVERS["cg"](A2, prob.b(), prob.x0(), tol=1e-6, maxiter=200,
                       norm_ref=1.0)
    assert int(r1.iters) == int(r2.iters)
    np.testing.assert_allclose(np.asarray(r1.x), np.asarray(r2.x),
                               rtol=1e-10, atol=1e-12)
