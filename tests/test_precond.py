"""The repro.precond subsystem.

Covers the ISSUE-3 acceptance criteria:
  * pcg with EACH of the four preconditioners converges on the 64^3 7pt
    problem in strictly fewer iterations than plain cg at the same
    tolerance (and the paper's absolute criterion);
  * every SPD-preserving preconditioner keeps pcg convergent on the
    7pt/27pt problems, to the dense-solve reference;
  * precond="jacobi" parity between the local and shard_map backends (the
    subprocess asserts the repo's established local-vs-distributed standard
    — identical iteration counts, 1e-9 solutions: even the RAW SpMV is not
    bitwise across worlds, the compiler contracts per shape — plus strict
    bit-for-bit identity where it is well-defined, facade-vs-direct within
    the shard_map world; halo modes agree to last-digit rounding, and the
    batched path matches single solves);
  * the Pallas Chebyshev/block-Jacobi kernels match their kernels/ref.py
    oracles to machine precision, and the use_pallas apply path matches the
    jnp path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import run_multidevice

from repro.api import REGISTRY, SolverOptions, SolverSession, solve
from repro.core.operators import STENCIL_7PT, STENCIL_27PT, build_dense_from_stencil
from repro.core.problems import make_problem
from repro.core.solvers import LocalOp, cg, pcg, bicgstab, pbicgstab
from repro.kernels import ops, ref
from repro.precond import (
    PRECONDITIONERS,
    BlockJacobi,
    Chebyshev,
    PointJacobi,
    SSOR,
    gershgorin_bounds,
    make_precond,
    precond_names,
)

pytestmark = pytest.mark.usefixtures("f64")

PRECONDS = ("jacobi", "block_jacobi", "ssor", "chebyshev")
SHAPE = (10, 10, 12)


@pytest.fixture(scope="module", params=["7pt", "27pt"])
def problem(request):
    prob = make_problem(SHAPE, request.param)
    A = LocalOp(prob.stencil)
    Ad = build_dense_from_stencil(prob.stencil, SHAPE)
    xref = np.linalg.solve(Ad, np.asarray(prob.b(), np.float64).reshape(-1))
    return prob, A, xref.reshape(SHAPE)


# -----------------------------------------------------------------------------
# protocol / registry / metadata
# -----------------------------------------------------------------------------

def test_registry_and_factory():
    assert set(PRECONDS) == set(PRECONDITIONERS)
    assert precond_names() == ("none", *sorted(PRECONDS))
    assert make_precond("none") is None
    assert make_precond(None) is None
    with pytest.raises(KeyError, match="unknown preconditioner"):
        make_precond("ilu")
    with pytest.raises(ValueError, match="params"):
        make_precond("none", sweeps=2)
    for name in PRECONDS:
        inst = make_precond(name)
        assert inst.name == name
        # the subsystem's design constraint: no new barriers, ever
        assert inst.extra_reductions_per_apply == 0, name
        assert inst.spd_preserving, name
        assert inst.touched_elements_per_apply(27) > 0, name
    # block-Jacobi is communication-free by construction
    assert make_precond("block_jacobi").halo_matvecs_per_apply == 0
    assert make_precond("jacobi", sweeps=3).halo_matvecs_per_apply == 2
    assert make_precond("ssor").halo_hide == "none"
    assert make_precond("chebyshev", degree=5).matvecs_per_apply == 4


def test_param_validation():
    with pytest.raises(ValueError, match="sweeps"):
        PointJacobi(sweeps=0)
    with pytest.raises(ValueError, match="omega"):
        BlockJacobi(omega=1.5)
    with pytest.raises(ValueError, match="omega"):
        SSOR(omega=2.0)
    with pytest.raises(ValueError, match="degree"):
        Chebyshev(degree=0)
    with pytest.raises(ValueError, match="bounds"):
        Chebyshev(bounds=(-1.0, 2.0)).setup(LocalOp(STENCIL_7PT))


def test_gershgorin_bounds():
    assert gershgorin_bounds(STENCIL_7PT) == (21.0, 33.0)
    assert gershgorin_bounds(STENCIL_27PT) == (1.0, 53.0)


def test_solver_registry_hooks():
    for m in ("pcg", "pbicgstab"):
        assert REGISTRY[m].accepts_precond
    assert REGISTRY["pcg"].precond_applies_per_iter == 1
    assert REGISTRY["pbicgstab"].precond_applies_per_iter == 2
    assert REGISTRY["pcg"].variant_of == "cg"
    assert REGISTRY["pbicgstab"].variant_of == "bicgstab"
    for m in ("cg", "cg_nb", "bicgstab", "bicgstab_b1", "jacobi"):
        assert not REGISTRY[m].accepts_precond


# -----------------------------------------------------------------------------
# convergence property: every SPD-preserving preconditioner keeps pcg
# convergent (to the dense reference) on both stencils
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("name", PRECONDS)
def test_pcg_converges_with_every_spd_preconditioner(problem, name):
    prob, A, xref = problem
    M = make_precond(name).bind(A)
    res = pcg(A, prob.b(), prob.x0(), tol=1e-8, maxiter=800, norm_ref=1.0,
              M=M)
    assert int(res.iters) < 800, name
    assert float(res.res_norm) < 1e-8, name
    # the reported residual is the TRUE residual (same contract as cg)
    true_r = float(jnp.linalg.norm((prob.b() - A.matvec(res.x)).reshape(-1)))
    assert abs(true_r - float(res.res_norm)) <= 1e-6 * max(true_r, 1.0)
    np.testing.assert_allclose(np.asarray(res.x), xref, atol=1e-7,
                               err_msg=name)


@pytest.mark.parametrize("name", PRECONDS)
def test_pbicgstab_converges_with_every_preconditioner(problem, name):
    prob, A, xref = problem
    M = make_precond(name).bind(A)
    res = pbicgstab(A, prob.b(), prob.x0(), tol=1e-8, maxiter=800,
                    norm_ref=1.0, M=M)
    assert int(res.iters) < 800, name
    assert float(res.res_norm) < 1e-8, name
    np.testing.assert_allclose(np.asarray(res.x), xref, atol=1e-6,
                               err_msg=name)


def test_pcg_identity_matches_cg_bitwise(problem):
    """With M=None the preconditioned forms ARE the classical methods."""
    prob, A, _ = problem
    r1 = cg(A, prob.b(), prob.x0(), tol=1e-8, maxiter=500, norm_ref=1.0)
    r2 = pcg(A, prob.b(), prob.x0(), tol=1e-8, maxiter=500, norm_ref=1.0)
    assert int(r1.iters) == int(r2.iters)
    np.testing.assert_array_equal(np.asarray(r1.x), np.asarray(r2.x))
    b1 = bicgstab(A, prob.b(), prob.x0(), tol=1e-8, maxiter=500, norm_ref=1.0)
    b2 = pbicgstab(A, prob.b(), prob.x0(), tol=1e-8, maxiter=500,
                   norm_ref=1.0)
    assert int(b1.iters) == int(b2.iters)
    np.testing.assert_array_equal(np.asarray(b1.x), np.asarray(b2.x))


# -----------------------------------------------------------------------------
# the acceptance criterion: strictly fewer iterations than cg at 64^3 / 7pt
# -----------------------------------------------------------------------------

def test_pcg_strictly_beats_cg_on_64cubed_7pt():
    prob = make_problem((64, 64, 64), "7pt")
    A = LocalOp(prob.stencil)
    b, x0 = prob.b(), prob.x0()
    base = cg(A, b, x0, tol=1e-6, maxiter=700, norm_ref=1.0)
    assert int(base.iters) < 700
    for name in PRECONDS:
        res = pcg(A, b, x0, tol=1e-6, maxiter=700, norm_ref=1.0,
                  M=make_precond(name).bind(A))
        assert float(res.res_norm) < 1e-6, name
        assert int(res.iters) < int(base.iters), (
            name, int(res.iters), int(base.iters))


# -----------------------------------------------------------------------------
# facade plumbing
# -----------------------------------------------------------------------------

def test_facade_precond_options(problem):
    prob, A, _ = problem
    base = solve(prob, method="cg", tol=1e-8, maxiter=800)
    res = solve(prob, method="pcg", precond="chebyshev", tol=1e-8,
                maxiter=800)
    assert int(res.iters) < int(base.iters)
    # facade == direct (jitted) solver call, bit for bit — the zero-cost
    # contract; the facade jits the solve, so the reference must too (the
    # Chebyshev axpby chain fuses differently op-by-op)
    direct = jax.jit(
        lambda b, x0: pcg(A, b, x0, tol=1e-8, maxiter=800, norm_ref=1.0,
                          M=make_precond("chebyshev").bind(A))
    )(prob.b(), prob.x0())
    assert int(res.iters) == int(direct.iters)
    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(direct.x))
    # precond_params reach the constructor
    r6 = solve(prob, method="pcg", precond="chebyshev",
               precond_params={"degree": 6}, tol=1e-8, maxiter=800)
    assert int(r6.iters) <= int(res.iters)


def test_facade_precond_validation(problem):
    prob, _, _ = problem
    with pytest.raises(ValueError, match="precond"):
        SolverOptions(precond="ilu")
    with pytest.raises(ValueError, match="precond_params"):
        SolverOptions(precond_params={"sweeps": 2})
    with pytest.raises(ValueError, match="takes no preconditioner"):
        SolverSession(prob, method="cg",
                      options=SolverOptions(precond="jacobi"))
    sess = SolverSession(prob, method="pcg",
                         options=SolverOptions(precond="ssor"))
    assert "precond=ssor" in sess.describe()


def test_pcg_rejects_non_spd_preserving_precond(problem, monkeypatch):
    """spd_preserving gates pcg (CG's short recurrence silently breaks on a
    non-symmetric M); pbicgstab has no such requirement."""
    prob, _, _ = problem
    monkeypatch.setattr(PointJacobi, "spd_preserving", False)
    with pytest.raises(ValueError, match="SPD-preserving"):
        SolverSession(prob, method="pcg",
                      options=SolverOptions(precond="jacobi"))
    SolverSession(prob, method="pbicgstab",
                  options=SolverOptions(precond="jacobi"))


def test_batched_precond_matches_single(problem):
    prob, _, _ = problem
    sess = SolverSession(prob, method="pcg", options=SolverOptions(
        tol=1e-8, maxiter=400, norm_ref=None, precond="jacobi"))
    rng = np.random.default_rng(0)
    bs = jnp.asarray(rng.standard_normal((4, *SHAPE)))
    bres = sess.solve_batched(bs)
    for i in (0, 3):
        single = sess.solve(b=bs[i])
        assert int(bres.iters[i]) == int(single.iters), i
        np.testing.assert_allclose(np.asarray(bres.x[i]),
                                   np.asarray(single.x), atol=1e-12)


# -----------------------------------------------------------------------------
# Pallas kernels vs refs (machine precision) and the use_pallas apply path
# -----------------------------------------------------------------------------

KTOLS = {jnp.float32: dict(rtol=1e-4, atol=1e-5),
         jnp.float64: dict(rtol=1e-12, atol=1e-12)}


@pytest.mark.parametrize("stencil", [STENCIL_7PT, STENCIL_27PT],
                         ids=lambda s: s.name)
@pytest.mark.parametrize("dt", [jnp.float32, jnp.float64], ids=str)
def test_cheb_fused_step_kernel_matches_ref(stencil, dt):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    z = jax.random.normal(k1, (12, 10, 16), dt)
    r = jax.random.normal(k2, (12, 10, 16), dt)
    d = jax.random.normal(k3, (12, 10, 16), dt)
    zp = jnp.pad(z, 1)
    zn, dn = ops.cheb_step(zp, r, d, stencil, a=0.37, c=1.21)
    znr, dnr = ref.cheb_fused_step_ref(zp, r, d, stencil=stencil,
                                       a=0.37, c=1.21)
    np.testing.assert_allclose(np.asarray(zn), np.asarray(znr), **KTOLS[dt])
    np.testing.assert_allclose(np.asarray(dn), np.asarray(dnr), **KTOLS[dt])


@pytest.mark.parametrize("stencil", [STENCIL_7PT, STENCIL_27PT],
                         ids=lambda s: s.name)
@pytest.mark.parametrize("dt", [jnp.float32, jnp.float64], ids=str)
def test_block_jacobi_sweep_kernel_matches_ref(stencil, dt):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1), 2)
    z = jax.random.normal(k1, (12, 10, 16), dt)
    r = jax.random.normal(k2, (12, 10, 16), dt)
    zp = jnp.pad(z, 1)
    zs = ops.jacobi_sweep(zp, r, stencil, omega=0.9)
    zsr = ref.block_jacobi_sweep_ref(zp, r, stencil=stencil, omega=0.9)
    np.testing.assert_allclose(np.asarray(zs), np.asarray(zsr), **KTOLS[dt])


@pytest.mark.parametrize("cls", [Chebyshev, BlockJacobi],
                         ids=lambda c: c.name)
def test_use_pallas_apply_matches_jnp(cls):
    prob = make_problem((12, 12, 16), "27pt")
    A = LocalOp(prob.stencil)
    r = jax.random.normal(jax.random.PRNGKey(2), prob.shape, jnp.float64)
    z_jnp = cls().bind(A)(r)
    z_pal = cls(use_pallas=True).bind(A)(r)
    np.testing.assert_allclose(np.asarray(z_jnp), np.asarray(z_pal),
                               rtol=1e-12, atol=1e-12)


def test_pallas_flag_flows_into_precond(problem):
    prob, _, _ = problem
    sess = SolverSession(prob, method="pcg", options=SolverOptions(
        precond="chebyshev", pallas=True))
    assert sess.precond.use_pallas
    sess2 = SolverSession(prob, method="pcg", options=SolverOptions(
        precond="chebyshev", pallas=True,
        precond_params={"use_pallas": False}))
    assert not sess2.precond.use_pallas
    sess3 = SolverSession(prob, method="pcg", options=SolverOptions(
        precond="jacobi", pallas=True))     # no pallas kernel: flag ignored
    assert sess3.precond is not None


# -----------------------------------------------------------------------------
# local vs shard_map parity (subprocess: main process must keep 1 device)
# -----------------------------------------------------------------------------

_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "src")
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.api import SolverOptions, SolverSession
from repro.core.compat import shard_map
from repro.core.distributed import DistributedOp, make_layout, solve_shardmap
from repro.core.problems import make_problem
from repro.core.solvers import LocalOp
from repro.launch.mesh import make_solver_mesh
from repro.precond import make_precond

prob = make_problem((12, 12, 16), "7pt")
mesh = make_solver_mesh(8)
layout = make_layout(mesh)
sh = NamedSharding(mesh, layout.spec())
out = {}

# 1) solve-level parity, local vs shard_map, pcg + jacobi (and chebyshev)
for pname in ("jacobi", "chebyshev"):
    opts = SolverOptions(tol=1e-6, maxiter=700, precond=pname)
    loc = SolverSession(prob, method="pcg",
                        options=opts.replace(layout="local")).solve()
    dist_sess = SolverSession(prob, method="pcg", options=opts, mesh=mesh)
    dist = dist_sess.solve()
    # facade vs direct shard_map build: bit for bit (zero-cost contract)
    fn, _ = solve_shardmap(prob, "pcg", mesh, tol=1e-6, maxiter=700,
                           halo_mode="overlap",
                           precond=make_precond(pname))
    direct = jax.jit(fn)(jax.device_put(prob.b(), sh),
                         jax.device_put(prob.x0(), sh))
    out[pname] = dict(
        loc_iters=int(loc.iters), dist_iters=int(dist.iters),
        max_dx=float(jnp.abs(loc.x - dist.x).max()),
        hist_close=bool(np.allclose(np.asarray(loc.history),
                                    np.asarray(dist.history),
                                    rtol=1e-9, equal_nan=True)),
        facade_bitwise=bool(np.array_equal(np.asarray(dist.x),
                                           np.asarray(direct.x))),
    )

# 2) halo-mode parity for the preconditioned solve: identical iteration
# counts and ulp-level solutions (the M apply's elementwise chain around
# the matvec fuses differently per mode — unlike plain cg, whose body
# stays bitwise — so strict bit equality is not well-defined here)
ref_x, iters = None, set()
mode_maxdiff = 0.0
for mode in ("concat", "scatter", "overlap"):
    fn, _ = solve_shardmap(prob, "pcg", mesh, tol=1e-6, maxiter=700,
                           halo_mode=mode, precond=make_precond("jacobi"))
    res = jax.jit(fn)(jax.device_put(prob.b(), sh),
                      jax.device_put(prob.x0(), sh))
    x = np.asarray(res.x)
    iters.add(int(res.iters))
    if ref_x is None:
        ref_x = x
    mode_maxdiff = max(mode_maxdiff, float(np.abs(ref_x - x).max()))
out["halo_modes_iters_agree"] = len(iters) == 1
out["halo_modes_maxdiff"] = mode_maxdiff

# 3) batched preconditioned solves on the mesh match single solves
sess = SolverSession(prob, method="pcg", mesh=mesh,
                     options=SolverOptions(tol=1e-6, maxiter=700,
                                           precond="jacobi"))
rng = np.random.default_rng(1)
bs = jnp.asarray(rng.standard_normal((4, 12, 12, 16)))
bres = sess.solve_batched(bs)
out["batched_max_dx"] = max(
    float(jnp.abs(bres.x[i] - sess.solve(b=bs[i]).x).max()) for i in (0, 3))
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def parity_results():
    return run_multidevice(_PARITY_SCRIPT)


def test_local_vs_shardmap_parity(parity_results):
    """The repo's local-vs-distributed standard: identical iteration counts,
    1e-9-identical solutions and residual histories (the raw SpMV already
    differs in the last bits across worlds — per-shape compiler
    contraction), for the preconditioned solves too."""
    for pname in ("jacobi", "chebyshev"):
        r = parity_results[pname]
        assert r["loc_iters"] == r["dist_iters"], (pname, r)
        assert r["max_dx"] < 1e-9, (pname, r)
        assert r["hist_close"], pname
        assert r["facade_bitwise"], pname


def test_preconditioned_halo_modes_parity(parity_results):
    """All three halo modes agree on pcg+jacobi: same iteration counts,
    solutions equal to a couple of ulp (the preconditioner's elementwise
    chain fuses differently per mode, so — unlike plain cg — strict bit
    equality does not survive; 1e-13 pins last-digit rounding only)."""
    assert parity_results["halo_modes_iters_agree"]
    assert parity_results["halo_modes_maxdiff"] < 1e-13


def test_preconditioned_batched_parity(parity_results):
    assert parity_results["batched_max_dx"] < 1e-10
