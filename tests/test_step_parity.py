"""Step/solver parity: one ``solve_step_shardmap`` iteration must compute the
SAME numbers as one ``lax.while_loop`` body of the corresponding solver, for
every method in ``repro.api.REGISTRY``.

The step functions are what the dry-run/roofline lowers for exact
cost/overlap analysis — if a step drifts from its solver (as the
gauss_seidel backward sweep once did, silently dropping the forward sweep),
every per-iteration number derived from it is wrong.  Runs on the trivial
1-device mesh so the comparison is against the plain local solver.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import REGISTRY
from repro.core.distributed import init_step_state, solve_step_shardmap
from repro.core.problems import make_problem
from repro.core.solvers import SOLVERS, LocalOp

pytestmark = pytest.mark.usefixtures("f64")

SHAPE = (8, 8, 10)


#: which output slot carries the squared residual (the BiCGStab steps keep
#: rho/alpha_n in slot 4, pcg keeps rz there; ||r||^2 rides in slot 5;
#: the reduction-hiding variants carry method-specific state — see
#: core.distributed.STEP_STATE for the layouts)
_RES_SLOT = {"bicgstab": 5, "bicgstab_b1": 5, "pcg": 5, "pbicgstab": 5,
             "cg_merged": 5, "pcg_merged": 8, "cg_pipe": 8, "pcg_pipe": 10,
             "bicgstab_merged": 10, "pbicgstab_merged": 10}


@pytest.mark.parametrize("method", sorted(REGISTRY))
def test_one_step_matches_one_solver_iteration(mesh1, method):
    prob = make_problem(SHAPE, "27pt")
    A = LocalOp(prob.stencil)
    b, x0 = prob.b(), prob.x0()

    fn, layout = solve_step_shardmap(prob, method, mesh1)
    out = jax.jit(fn)(*init_step_state(method, A, b, x0))
    x_step = out[0]
    res_step = jnp.sqrt(out[_RES_SLOT.get(method, 4)])

    ref = SOLVERS[method](A, b, x0, tol=1e-30, maxiter=1, norm_ref=1.0)
    assert int(ref.iters) == 1

    if method == "cg_nb":
        # the solver's x lags one iteration; apply its exit correction to the
        # step state (same arithmetic as the post-loop line in cg_nb)
        _, _, p_new, _, an_new, ad_new = out
        x_step = x_step + (an_new / ad_new) * p_new
    if method == "pbicgstab_merged":
        # the step iterates in the preconditioned ŷ space; the solver's
        # exit line recovers x = x0 + M⁻¹ ŷ (M = identity here)
        x_step = x0 + x_step

    # ULP-tight: the two programs fuse differently (pad vs concat halos,
    # paired vs separate dots), so allow last-digit rounding only — the
    # gauss_seidel regression this pins was off by ~1e0, not 1e-13
    np.testing.assert_allclose(np.asarray(x_step), np.asarray(ref.x),
                               rtol=1e-13, atol=1e-13, err_msg=method)
    np.testing.assert_allclose(float(res_step), float(ref.res_norm),
                               rtol=1e-12, err_msg=method)


def test_gauss_seidel_step_applies_both_sweeps(mesh1):
    """Regression: the backward sweep must consume the forward-sweep result.
    Feeding it ``x0`` again makes one step equal a *backward-only* sweep of
    x0 (plus a wasted forward sweep) — strictly worse residual."""
    from repro.core.solvers import _plane_sweep
    prob = make_problem(SHAPE, "27pt")
    A = LocalOp(prob.stencil)
    b, x0 = prob.b(), prob.x0()
    fn, _ = solve_step_shardmap(prob, "gauss_seidel", mesh1)
    out = jax.jit(fn)(*init_step_state("gauss_seidel", A, b, x0))

    x_fwd = _plane_sweep(A, b, x0, forward=True)
    x_sym = _plane_sweep(A, b, x_fwd, forward=False)
    x_back_only = _plane_sweep(A, b, x0, forward=False)

    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(x_sym))
    assert not np.array_equal(np.asarray(out[0]), np.asarray(x_back_only))
