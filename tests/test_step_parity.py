"""Step/solver parity: one ``solve_step_shardmap`` iteration must compute the
SAME numbers as one ``lax.while_loop`` body of the corresponding solver, for
every method in ``repro.api.REGISTRY``.

Since PR 5 both programs are literally the same ``MethodDef.step`` executed
by different drivers, so parity is structural — but this test still pins it
end-to-end (the step fn runs inside shard_map over a ``DistributedOp`` with
concat halos while the solver runs the plain ``LocalOp``, so a drifted
operator protocol or state-layout derivation would surface here exactly as
the old hand-written ladder's drift did).  The residual slot, the exit
correction and the state signature are all derived from the ``MethodDef``
— no per-method tables.

Also covers the PR-5 additions: the fused Pallas body of ``cg_merged``
running INSIDE shard_map (one step == one local fused iteration), and the
unregistered-method regression (``solve_step_shardmap`` used to fall
through silently until trace time).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import REGISTRY
from repro.core.distributed import (init_step_state, solve_step_shardmap,
                                    step_state_layout)
from repro.core.methods import Ops, get_method
from repro.core.problems import make_problem
from repro.core.solvers import SOLVERS, LocalOp

pytestmark = pytest.mark.usefixtures("f64")

SHAPE = (8, 8, 10)


@pytest.mark.parametrize("method", sorted(REGISTRY))
def test_one_step_matches_one_solver_iteration(mesh1, method):
    prob = make_problem(SHAPE, "27pt")
    A = LocalOp(prob.stencil)
    b, x0 = prob.b(), prob.x0()
    mdef = get_method(method)

    fn, layout = solve_step_shardmap(prob, method, mesh1)
    out = jax.jit(fn)(*init_step_state(method, A, b, x0))
    res_step = jnp.sqrt(out[mdef.res_index])
    # the method's own exit correction (cg_nb's lagged x update,
    # pbicgstab_merged's x = x0 + M^-1 y recovery) — from the definition,
    # not a per-method special case in the test
    ops = Ops(A, b, norm_ref=1.0)
    x_step = mdef.finalize(ops, x0, out) if mdef.finalize else out[0]

    ref = SOLVERS[method](A, b, x0, tol=1e-30, maxiter=1, norm_ref=1.0)
    assert int(ref.iters) == 1

    # ULP-tight: the two programs fuse differently (pad vs concat halos,
    # paired vs separate dots), so allow last-digit rounding only — the
    # gauss_seidel regression this pins was off by ~1e0, not 1e-13
    np.testing.assert_allclose(np.asarray(x_step), np.asarray(ref.x),
                               rtol=1e-13, atol=1e-13, err_msg=method)
    np.testing.assert_allclose(float(res_step), float(ref.res_norm),
                               rtol=1e-12, err_msg=method)


def test_step_state_derived_from_method_def():
    """The step-state signature is the MethodDef's declared layout — the
    hand-written STEP_STATE table is gone; pin the documented layouts of
    the reduction-hiding variants so a definition edit that silently
    reshapes the analysis surface fails loudly."""
    assert step_state_layout("cg") == (("x", "r", "p"), ("rr",))
    assert step_state_layout("cg_merged") == (
        ("x", "r", "p", "s", "w"),
        ("gamma", "delta", "gamma_prev", "alpha_prev"))
    assert step_state_layout("pcg_pipe") == (
        ("x", "r", "u", "w", "p", "s", "q", "z"),
        ("gamma_prev", "alpha_prev", "rr"))
    assert step_state_layout("bicgstab_merged") == (
        ("x", "r", "w", "t", "p", "s", "z", "rhat"),
        ("rho", "alpha", "rr"))
    for method, spec in REGISTRY.items():
        vecs, scals = step_state_layout(method)
        assert (vecs, scals) == (spec.method_def.vectors,
                                 spec.method_def.scalars)
        assert vecs[0] == "x"
        assert spec.method_def.res_scalar in scals


def test_unregistered_method_raises_with_known_list(mesh1):
    """Regression: an unknown method name must raise immediately (it used
    to fall through to a trace-time error deep in the ladder) and the
    message must list the registered methods."""
    prob = make_problem(SHAPE, "27pt")
    with pytest.raises(ValueError, match="unknown method 'sor'"):
        solve_step_shardmap(prob, "sor", mesh1)
    with pytest.raises(ValueError, match="cg_merged"):
        solve_step_shardmap(prob, "sor", mesh1)


def test_fused_step_matches_local_fused_iteration(mesh1):
    """cg_merged + pallas now runs INSIDE shard_map: one fused step on the
    (trivial) mesh must equal one local fused iteration — same kernels,
    halos from the DistributedOp, partials through the stacked psum."""
    from repro.kernels.pallas_op import PallasOp
    prob = make_problem(SHAPE, "27pt")
    A = LocalOp(prob.stencil)
    b, x0 = prob.b(), prob.x0()
    mdef = get_method("cg_merged")

    fn, _ = solve_step_shardmap(prob, "cg_merged", mesh1, pallas_fused=True)
    ops = Ops(PallasOp(A), b, norm_ref=1.0)
    state0 = tuple(mdef.fused_init(ops, x0))
    out = jax.jit(fn)(b, *state0)
    ref = mdef.fused_step(ops, state0)
    for slot, (got, want) in enumerate(zip(out, ref)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-13, atol=1e-13,
                                   err_msg=f"slot {slot}")


def test_fused_step_rejects_methods_without_fused_body(mesh1):
    prob = make_problem(SHAPE, "27pt")
    with pytest.raises(ValueError, match="declares no fused kernels"):
        solve_step_shardmap(prob, "cg", mesh1, pallas_fused=True)


def test_gauss_seidel_step_applies_both_sweeps(mesh1):
    """Regression: the backward sweep must consume the forward-sweep result.
    Feeding it ``x0`` again makes one step equal a *backward-only* sweep of
    x0 (plus a wasted forward sweep) — strictly worse residual."""
    from repro.core.solvers import _plane_sweep
    prob = make_problem(SHAPE, "27pt")
    A = LocalOp(prob.stencil)
    b, x0 = prob.b(), prob.x0()
    fn, _ = solve_step_shardmap(prob, "gauss_seidel", mesh1)
    out = jax.jit(fn)(*init_step_state("gauss_seidel", A, b, x0))

    x_fwd = _plane_sweep(A, b, x0, forward=True)
    x_sym = _plane_sweep(A, b, x_fwd, forward=False)
    x_back_only = _plane_sweep(A, b, x0, forward=False)

    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(x_sym))
    assert not np.array_equal(np.asarray(out[0]), np.asarray(x_back_only))
