"""The registry-wide convergence property (PR 5): EVERY registered method —
with and without each compatible preconditioner — must converge on the
paper's 7pt and 27pt operators at 32³ to the requested tolerance.

This is the guard rail behind the single-source ``MethodDef`` refactor: a
new or edited definition that silently breaks a method (or a
method × preconditioner composition) fails here by construction, because
the parametrisation is *generated from the registry* — nothing to remember
to extend.  The residual contract is checked on the TRUE residual, not just
the method's own estimate, so recurrence-drift regressions surface too.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import REGISTRY, SolverOptions, solve
from repro.core.problems import make_problem
from repro.core.solvers import LocalOp

pytestmark = pytest.mark.usefixtures("f64")

GRID = (32, 32, 32)
TOL = 1e-6
#: generous caps — convergence is the property under test, not speed
MAXITER = {True: 6000, False: 2000}          # stationary vs Krylov

#: every (method, precond) cell: all 15 methods plain, plus each
#: accepts_precond method with each of the four built-in preconditioners
CELLS = [(m, "none") for m in sorted(REGISTRY)] + [
    (m, p)
    for m in sorted(REGISTRY) if REGISTRY[m].accepts_precond
    for p in ("jacobi", "block_jacobi", "ssor", "chebyshev")
]


@pytest.mark.parametrize("stencil", ["7pt", "27pt"])
@pytest.mark.parametrize("method,precond", CELLS,
                         ids=[f"{m}+{p}" for m, p in CELLS])
def test_every_registry_method_converges(method, precond, stencil):
    spec = REGISTRY[method]
    maxiter = MAXITER[spec.stationary]
    prob = make_problem(GRID, stencil)
    opts = SolverOptions(tol=TOL, maxiter=maxiter, precond=precond)
    res = solve(prob, method=method, options=opts)

    assert int(res.iters) < maxiter, (
        f"{method}+{precond}/{stencil}: no convergence in {maxiter} "
        f"iterations (res_norm={float(res.res_norm):.3e})")
    # the method's own estimate met the criterion (norm_ref=1.0: absolute)
    assert float(res.res_norm) < TOL
    # ...and so does the TRUE residual, within the documented recurrence
    # drift allowance (docs/API.md §Reduction-hiding variants)
    A = LocalOp(prob.stencil)
    true_r = float(jnp.linalg.norm((prob.b() - A.matvec(res.x)).reshape(-1)))
    assert true_r < 10 * TOL, (method, precond, stencil, true_r)
    # the residual history is finite and ends where the solve says it does
    hist = np.asarray(res.history)
    assert np.isfinite(hist[: int(res.iters) + 1]).all()
