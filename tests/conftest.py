# NOTE: deliberately NO xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device (the dry-run sets 512 inside its own
# process).  Multi-device distributed tests run via subprocess (see
# tests/test_distributed_solvers.py).
import jax
import pytest


@pytest.fixture(scope="session")
def f64():
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", prev)


@pytest.fixture(scope="session")
def mesh1():
    """Trivial 1-device mesh with production axis names."""
    from repro.core.compat import make_mesh
    return make_mesh((1, 1), ("data", "model"))
