# NOTE: deliberately NO xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device (the dry-run sets 512 inside its own
# process).  Multi-device distributed tests run via subprocess: see
# run_multidevice() below.
import json
import os
import subprocess
import sys

import jax
import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_multidevice(script: str, *, devices: int | None = 8,
                    env: dict | None = None, timeout: int = 560) -> dict:
    """Run ``script`` in a fresh interpreter with ``devices`` host devices
    and parse its LAST stdout line as JSON.

    The shared harness for every multi-device test: host-device count is
    fixed at jax import, so the main pytest process must keep seeing one
    device and anything needing a mesh runs out-of-process.  The subprocess
    gets ``XLA_FLAGS=--xla_force_host_platform_device_count=<devices>``
    (skipped when ``devices`` is None), ``PYTHONPATH=src`` and the repo root
    as cwd; extra ``env`` entries are merged on top.  Asserts a zero exit
    status (stderr tail in the failure message) — import it from conftest:
    ``from conftest import run_multidevice``.
    """
    full = dict(os.environ)
    if devices is not None:
        full["XLA_FLAGS"] = (full.get("XLA_FLAGS", "") +
                             f" --xla_force_host_platform_device_count="
                             f"{devices}").strip()
    full["PYTHONPATH"] = (os.path.join(REPO_ROOT, "src") + os.pathsep +
                          full.get("PYTHONPATH", ""))
    if env:
        full.update(env)
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=timeout, env=full,
        cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.fixture(scope="session")
def f64():
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", prev)


@pytest.fixture(scope="session")
def mesh1():
    """Trivial 1-device mesh with production axis names."""
    from repro.core.compat import make_mesh
    return make_mesh((1, 1), ("data", "model"))
