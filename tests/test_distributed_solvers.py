"""Distributed solvers on an 8-device host mesh (subprocess: the main pytest
process must keep seeing 1 device).

Asserts (i) distributed == single-device solutions/iterations for every
method, (ii) one all-reduce per fused reduction (the single-collective claim),
(iii) the paper's barrier structure: CG-NB removes the zero-slack reduction
classical CG has; BiCGStab-B1 keeps exactly one.

The barrier-structure part needs the ALGORITHM-level (unfused) HLO; this
jaxlib cannot disable passes per-compile (repeated proto field), so the
fixture runs the script twice — the "algo" run with the fusion passes
disabled via XLA_FLAGS — and merges the two JSON payloads.
"""

import os

import pytest
from conftest import run_multidevice

# multi-minute 8-device subprocess sweep; tier-1 (plain pytest) still runs it
pytestmark = pytest.mark.slow

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8").strip()
import sys, json
sys.path.insert(0, "src")
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.core.compat import make_mesh
from repro.core.problems import make_problem
from repro.core.solvers import SOLVERS, LocalOp
from repro.core.distributed import (solve_shardmap, solve_step_shardmap,
                                    step_state_layout)
from repro.analysis.hlo import overlap_slack, count_collectives

view = os.environ.get("TRACE_VIEW", "main")
mesh = make_mesh((2, 4), ("data", "model"))
prob = make_problem((16, 16, 16), "27pt")
b, x0 = prob.b(), prob.x0()
out = {}

if view == "main":
    A = LocalOp(prob.stencil)
    for m in sorted(SOLVERS):
        ref = SOLVERS[m](A, b, x0, tol=1e-6, maxiter=700, norm_ref=1.0)
        fn, layout = solve_shardmap(prob, m, mesh, tol=1e-6, maxiter=700)
        sh = NamedSharding(mesh, layout.spec())
        res = jax.jit(fn)(jax.device_put(b, sh), jax.device_put(x0, sh))
        out[m] = dict(
            ref_iters=int(ref.iters), dist_iters=int(res.iters),
            max_dx=float(jnp.abs(res.x - ref.x).max()),
            res=float(res.res_norm),
        )

vec_bytes = b.size // 8 * 8
for m in ("cg", "cg_nb", "bicgstab", "bicgstab_b1"):
    # paper-faithful implementation: the trace asserts the ALGORITHM's
    # dependence structure (fusion moves work before the collective issues,
    # which hides it from the slack accounting; the TPU latency-hiding
    # scheduler works on the unfused graph)
    fn, layout = solve_step_shardmap(prob, m, mesh, halo_mode="scatter",
                                     matvec_padded=prob.stencil.matvec_padded)
    sh = NamedSharding(mesh, layout.spec())
    vecs, scals = step_state_layout(m)   # derived from the MethodDef
    args = ([jax.device_put(b, sh)] * (1 + len(vecs))
            + [jnp.array(1.0)] * len(scals))
    txt = jax.jit(fn).lower(*args).compile().as_text()
    if view == "main":
        out[m + "_step"] = dict(counts=count_collectives(txt))
    else:  # algo view: fusion disabled via XLA_FLAGS by the parent
        rep = overlap_slack(txt)
        ar = [r for r in rep if r["op"].startswith("all-reduce")]
        out[m + "_step"] = dict(
            n_allreduce=len(ar),
            hard_barriers=sum(1 for r in ar
                              if r["slack_bytes"] < vec_bytes / 8),
            max_slack=max(r["slack_bytes"] for r in ar),
        )
print(json.dumps(out))
"""


def _run(view: str) -> dict:
    env = {"TRACE_VIEW": view}
    if view == "algo":
        env["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                            " --xla_disable_hlo_passes="
                            "fusion,cpu-instruction-fusion").strip()
    return run_multidevice(_SCRIPT, env=env)


@pytest.fixture(scope="module")
def results():
    out = _run("main")
    for key, val in _run("algo").items():
        out.setdefault(key, {}).update(val)
    return out


def test_distributed_matches_single_device(results):
    for m in ("cg", "cg_nb", "bicgstab", "bicgstab_b1", "jacobi",
              "gauss_seidel_rb"):
        r = results[m]
        assert r["dist_iters"] == r["ref_iters"], (m, r)
        assert r["max_dx"] < 1e-9, (m, r)


def test_relaxed_gs_converges_distributed(results):
    """Relaxed GS convergence may differ across blocks (stale halos — the
    paper's data-race semantics) but must still solve the system."""
    r = results["gauss_seidel"]
    assert r["res"] < 1e-6
    assert r["max_dx"] < 1e-6
    assert abs(r["dist_iters"] - r["ref_iters"]) <= 0.2 * r["ref_iters"] + 2


def test_collective_counts_per_iteration(results):
    """One all-reduce per (fused) reduction: CG 2, CG-NB 2, BiCGStab 3, B1 3."""
    assert results["cg_step"]["n_allreduce"] == 2
    assert results["cg_nb_step"]["n_allreduce"] == 2
    assert results["bicgstab_step"]["n_allreduce"] == 3
    assert results["bicgstab_b1_step"]["n_allreduce"] == 3


def test_barrier_elimination_matches_paper(results):
    """Hard (zero-slack) barriers in the algorithm-level dependence graph:
    classical CG keeps one, CG-NB eliminates it; B1's alpha_d stays hard
    (the paper's "one blocking" name); CG-NB's r·r reduction gets a
    SpMV-sized overlap window — the Fig. 1(b) structure.

    (Dataflow execution already hides the paper's OTHER MPI barriers for the
    classical methods — see EXPERIMENTS.md fig2 discussion.)
    """
    vec = 16 ** 3 * 8 // 8  # one local vector (f64, 8 shards)
    assert results["cg_step"]["hard_barriers"] == 1
    assert results["cg_nb_step"]["hard_barriers"] == 0
    assert results["cg_nb_step"]["max_slack"] > 10 * vec   # SpMV-sized window
    assert results["bicgstab_step"]["hard_barriers"] >= 1
    assert results["bicgstab_b1_step"]["hard_barriers"] == 1
