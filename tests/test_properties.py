"""Property-based tests (hypothesis) on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.operators import STENCILS
from repro.core.problems import make_problem
from repro.core.solvers import SOLVERS, LocalOp
from repro.distributed.compression import dequantize_int8, quantize_int8
from repro.models.common import apply_rope, rms_norm, softcap

SETTINGS = dict(max_examples=15, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])


@given(
    nx=st.integers(3, 8), ny=st.integers(3, 8), nz=st.integers(3, 8),
    stencil=st.sampled_from(["7pt", "27pt"]),
    method=st.sampled_from(["cg", "cg_nb", "bicgstab", "bicgstab_b1",
                            "jacobi"]),
)
@settings(**SETTINGS)
def test_solver_residual_contract(nx, ny, nz, stencil, method):
    """For any grid/stencil/method: if the solver reports convergence, the
    REPORTED residual matches the TRUE residual and meets the tolerance."""
    prob = make_problem((nx, ny, nz), stencil, dtype=jnp.float32)
    A = LocalOp(prob.stencil)
    tol = 1e-4
    res = SOLVERS[method](A, prob.b(), prob.x0(), tol=tol, maxiter=800,
                          norm_ref=1.0)
    if int(res.iters) < 800:
        true_r = float(jnp.linalg.norm(
            (prob.b() - A.matvec(res.x)).reshape(-1)))
        assert float(res.res_norm) < tol
        assert true_r <= 20 * tol  # rounding slack (f32)


@given(
    n=st.integers(1, 2048),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_int8_quantisation_error_bound(n, scale, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32) * scale
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6 * scale


@given(
    b=st.integers(1, 3), s=st.integers(1, 32), h=st.integers(1, 4),
    hd=st.sampled_from([4, 8, 16]), theta=st.floats(100.0, 1e6),
    seed=st.integers(0, 1000),
)
@settings(**SETTINGS)
def test_rope_preserves_norm(b, s, h, hd, theta, seed):
    """RoPE is a rotation: per-(token, head) L2 norms are invariant."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, s, h, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    y = apply_rope(x, pos, theta)
    nx = jnp.linalg.norm(x, axis=-1)
    ny_ = jnp.linalg.norm(y, axis=-1)
    np.testing.assert_allclose(np.asarray(nx), np.asarray(ny_),
                               rtol=1e-4, atol=1e-4)


@given(cap=st.floats(1.0, 100.0), lo=st.floats(-1e4, 0.0),
       hi=st.floats(0.0, 1e4))
@settings(**SETTINGS)
def test_softcap_bounded_and_monotone(cap, lo, hi):
    x = jnp.linspace(lo, hi, 64)
    y = softcap(x, cap)
    assert float(jnp.max(jnp.abs(y))) <= cap * (1 + 1e-6)
    d = jnp.diff(y)
    assert bool(jnp.all(d >= -1e-6))


@given(
    d=st.sampled_from([8, 32, 128]), b=st.integers(1, 4),
    seed=st.integers(0, 1000), mag=st.floats(0.5, 1e3),
)
@settings(**SETTINGS)
def test_rms_norm_scale_invariance(d, b, seed, mag):
    """rms_norm(c·x) == rms_norm(x) for c where the eps floor is negligible
    (eps=1e-6 deliberately breaks invariance for ||x|| -> 0)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, d), jnp.float32)
    scale = jnp.ones((d,), jnp.float32)
    y1 = rms_norm(x, scale)
    y2 = rms_norm(x * mag, scale)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=5e-3, atol=5e-3)


@given(
    nx=st.integers(2, 6), ny=st.integers(2, 6), nz=st.integers(2, 6),
    stencil=st.sampled_from(["7pt", "27pt"]), seed=st.integers(0, 1000),
)
@settings(**SETTINGS)
def test_stencil_linearity(nx, ny, nz, stencil, seed):
    A = STENCILS[stencil]
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (nx, ny, nz), jnp.float32)
    y = jax.random.normal(k2, (nx, ny, nz), jnp.float32)
    lhs = A.matvec(2.0 * x - 3.0 * y)
    rhs = 2.0 * A.matvec(x) - 3.0 * A.matvec(y)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-4, atol=1e-4)


@given(seed=st.integers(0, 10_000), step=st.integers(0, 100),
       shard=st.integers(0, 7))
@settings(**SETTINGS)
def test_pipeline_pure_function_of_seed_step_shard(seed, step, shard):
    from repro.data.pipeline import SyntheticSource
    a = SyntheticSource(50_000, seed).tokens(step, shard, 128)
    b = SyntheticSource(50_000, seed).tokens(step, shard, 128)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < 50_000
