"""The persistent kernel autotuner (PR 10): cache round-trip, default-table
fallback, the small-grid XLA-fallback rule, and the session/PallasOp reads."""

import json

import jax.numpy as jnp
import pytest

from repro.kernels import autotune
from repro.kernels.autotune import (
    DEFAULT_BZ,
    MIN_PALLAS_VOLUME,
    TuneDecision,
    default_decision,
    resolve,
    save_cache,
    tune_key,
)


@pytest.fixture()
def cache(tmp_path, monkeypatch):
    """An isolated cache file: no test reads/writes ~/.cache."""
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune._CACHE = None
    yield path
    autotune._CACHE = None


# -----------------------------------------------------------------------------
# default table
# -----------------------------------------------------------------------------

def test_default_table_small_grid_falls_back_to_xla():
    """16³ < 24³: the measured case where the fork-join Pallas path ran
    3.5× behind the jitted loop — the default routes it to XLA."""
    dec = default_decision((16, 16, 16), backend="tpu")
    assert dec == TuneDecision(use_pallas=False)
    assert (16 ** 3) < MIN_PALLAS_VOLUME <= (32 ** 3)


def test_default_table_large_grid_uses_pallas_on_tpu_only():
    assert default_decision((64, 64, 64), backend="tpu").use_pallas
    assert not default_decision((64, 64, 64), backend="cpu").use_pallas
    assert default_decision((64, 64, 64), backend="tpu").bz == DEFAULT_BZ


def test_resolve_without_cache_is_the_default_table(cache):
    dec = resolve("7pt", (64, 64, 64), jnp.float64)
    assert dec.source == "default"
    assert dec == default_decision((64, 64, 64))


# -----------------------------------------------------------------------------
# cache round-trip
# -----------------------------------------------------------------------------

def test_cache_round_trip(cache):
    """A persisted entry wins over the default table, with identical
    choices after a write -> resolve cycle."""
    key = tune_key("7pt", (16, 16, 16), jnp.float32)
    save_cache({key: {"use_pallas": True, "bz": 16, "br": 64}})
    dec = resolve("7pt", (16, 16, 16), jnp.float32)
    assert dec == TuneDecision(use_pallas=True, bz=16, br=64, source="cache")
    # resolve again: memoized read, same decision
    assert resolve("7pt", (16, 16, 16), jnp.float32) == dec
    # the file itself round-trips the entry verbatim
    assert json.loads(cache.read_text())[key]["bz"] == 16


def test_cache_key_pins_all_four_coordinates(cache):
    key = tune_key("7pt", (16, 16, 16), jnp.float32)
    save_cache({key: {"use_pallas": True, "bz": 4, "br": None}})
    hit = resolve("7pt", (16, 16, 16), jnp.float32)
    assert (hit.source, hit.br) == ("cache", None)
    # a different stencil / grid / dtype misses back to the default table
    assert resolve("27pt", (16, 16, 16), jnp.float32).source == "default"
    assert resolve("7pt", (16, 16, 32), jnp.float32).source == "default"
    assert resolve("7pt", (16, 16, 16), jnp.float64).source == "default"


def test_corrupt_cache_degrades_to_default(cache):
    cache.write_text("{not json")
    dec = resolve("7pt", (16, 16, 16), jnp.float32)
    assert dec.source == "default"


def test_tune_is_idempotent_and_retune_remeasures(cache):
    """``tune`` sweeps once, then serves the cache; ``--retune`` forces a
    re-measure.  4³ keeps the sweep sub-second."""
    d1 = autotune.tune((4, 4, 4), "7pt", jnp.float32, repeats=1)
    assert d1.source == "cache"
    mtime = cache.stat().st_mtime_ns
    d2 = autotune.tune((4, 4, 4), "7pt", jnp.float32, repeats=1)
    assert d2 == d1
    assert cache.stat().st_mtime_ns == mtime        # no re-sweep
    autotune.tune((4, 4, 4), "7pt", jnp.float32, repeats=1, retune=True)
    assert cache.stat().st_mtime_ns >= mtime        # rewritten


# -----------------------------------------------------------------------------
# the consumers: options.pallas=None and PallasOp tile resolution
# -----------------------------------------------------------------------------

def test_session_resolves_pallas_auto_from_cache(cache):
    from repro.api import SolverOptions, SolverSession
    from repro.core.problems import make_problem

    prob = make_problem((8, 8, 8), "7pt")
    key = tune_key("7pt", (8, 8, 8), prob.b().dtype)
    # off-TPU the default table would say False; the cache says True
    save_cache({key: {"use_pallas": True, "bz": 8, "br": None}})
    # the problem's dtype follows the process-global x64 flag (suite-order
    # dependent); the options must agree with it
    opts = SolverOptions(maxiter=5, pallas=None,
                         f64=prob.b().dtype == jnp.float64)
    sess = SolverSession(prob, method="cg", options=opts)
    assert sess.options.pallas is True
    # and without the entry, auto resolves via the default table
    save_cache({})
    sess = SolverSession(prob, method="cg", options=opts)
    assert sess.options.pallas is False


def test_pallas_op_reads_tuned_tiles(cache):
    import numpy as np

    from repro.core.solvers import LocalOp
    from repro.kernels.pallas_op import PallasOp

    key = tune_key("7pt", (8, 8, 8), jnp.float32)
    save_cache({key: {"use_pallas": True, "bz": 4, "br": 64}})
    op = PallasOp(LocalOp(__import__(
        "repro.core.operators", fromlist=["STENCILS"]).STENCILS["7pt"]))
    x = jnp.ones((8, 8, 8), jnp.float32)
    assert op._tiles(x) == (4, 64)
    # a pinned bz wins over the cache (fused_cg pins its own tiling)
    pinned = PallasOp(LocalOp(op.stencil), bz=8)
    assert pinned._tiles(x) == (8, None)
    # and the tuned tiling produces the same matvec as the untuned one
    y_tuned = op.matvec(x)
    y_pinned = pinned.matvec(x)
    np.testing.assert_allclose(np.asarray(y_tuned), np.asarray(y_pinned),
                               rtol=1e-6, atol=1e-6)


def test_cli_smoke_writes_both_configs(cache, capsys):
    autotune.main(["--grid", "4", "4", "4", "--repeats", "1"])
    table = json.loads(cache.read_text())
    assert tune_key("7pt", (4, 4, 4), jnp.float32) in table
    entry = table[tune_key("7pt", (4, 4, 4), jnp.float32)]
    assert set(entry) >= {"use_pallas", "bz", "br", "backend", "timings"}
    out = capsys.readouterr().out
    assert "use_pallas=" in out and str(cache) in out
