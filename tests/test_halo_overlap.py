"""Overlapped halo-exchange SpMV: parity + schedule-slack evidence.

Three claims, per the paper's task-based communication/computation overlap
(§3.1, applied to the point-to-point halo traffic instead of the global
reductions):

  1. ``halo_mode="overlap"`` produces bit-for-bit the same SOLVER results as
     the monolithic ``"concat"``/``"scatter"`` exchanges, on 7pt and 27pt
     stencils, 1-D (paper-faithful) and 3-D decompositions.
  2. Under ``"overlap"`` every halo ``collective-permute`` in a lowered CG
     iteration has more hideable independent work than a whole local vector
     (the interior apply); under ``"concat"`` it has less (only the
     opposite-direction slab escapes the dependence cone).
  3. The scaling model consumes the hide window: overlap strictly reduces
     modelled iteration time for halo-hiding methods and leaves the
     Gauss-Seidel sweeps (halos consumed at the first plane) unchanged.

Multi-device parts run in a subprocess (main pytest process keeps 1 device),
with the fusion passes disabled for the slack view — the dependence-graph
measurement, like tests/test_distributed_solvers.py's barrier traces.
"""

import os

import pytest
from conftest import run_multidevice

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8").strip()
import sys, json
sys.path.insert(0, "src")
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.core.compat import make_mesh
from repro.core.problems import make_problem
from repro.core.distributed import (solve_shardmap, solve_step_shardmap,
                                    step_state_layout)
from repro.analysis.hlo import overlap_slack
from repro.core.overlap import blocking_halos, halo_slack

view = os.environ.get("TRACE_VIEW", "main")
MESHES = {
    "1d": make_mesh((8,), ("cells",)),
    "3d": make_mesh((2, 2, 2), ("pod", "data", "model")),
}
out = {}

if view == "main":
    for mtag, mesh in MESHES.items():
        for st in ("7pt", "27pt"):
            prob = make_problem((8, 8, 16), st)
            b, x0 = prob.b(), prob.x0()
            runs = {}
            for hm in ("scatter", "concat", "overlap"):
                fn, layout = solve_shardmap(prob, "cg", mesh, tol=1e-6,
                                            maxiter=300, halo_mode=hm)
                sh = NamedSharding(mesh, layout.spec())
                res = jax.jit(fn)(jax.device_put(b, sh),
                                  jax.device_put(x0, sh))
                runs[hm] = (np.asarray(res.x), int(res.iters))
            out[f"{mtag}_{st}"] = dict(
                iters={k: v[1] for k, v in runs.items()},
                bitwise_concat_overlap=bool(
                    np.array_equal(runs["concat"][0], runs["overlap"][0])),
                bitwise_concat_scatter=bool(
                    np.array_equal(runs["concat"][0], runs["scatter"][0])),
            )
else:  # slack view: fusion disabled by the parent via XLA_FLAGS
    mesh = MESHES["1d"]
    prob = make_problem((16, 16, 32), "27pt")
    b = prob.b()
    vec_bytes = 16 * 16 * (32 // 8) * 8        # one local f64 vector
    for hm in ("concat", "overlap"):
        vecs, scals = step_state_layout("cg")   # derived from the MethodDef
        fn, layout = solve_step_shardmap(prob, "cg", mesh, halo_mode=hm)
        sh = NamedSharding(mesh, layout.spec())
        args = ([jax.device_put(b, sh)] * (1 + len(vecs))
                + [jnp.array(1.0)] * len(scals))
        txt = jax.jit(fn).lower(*args).compile().as_text()
        rep = halo_slack(overlap_slack(txt, ops=("collective-permute",)))
        out[f"slack_{hm}"] = dict(
            n_ppermute=len(rep),
            slack_bytes=[round(r["slack_bytes"]) for r in rep],
            blocking=blocking_halos(rep, vec_bytes),
        )
    out["vec_bytes"] = vec_bytes
print(json.dumps(out))
"""


def _run(view: str) -> dict:
    env = {"TRACE_VIEW": view}
    if view == "slack":
        env["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                            " --xla_disable_hlo_passes="
                            "fusion,cpu-instruction-fusion").strip()
    return run_multidevice(_SCRIPT, env=env)


@pytest.fixture(scope="module")
def results():
    out = _run("main")
    out.update(_run("slack"))
    return out


@pytest.mark.slow   # 8-device subprocess sweep; tier-1 (plain pytest) runs it
@pytest.mark.parametrize("layout", ["1d", "3d"])
@pytest.mark.parametrize("stencil", ["7pt", "27pt"])
def test_halo_modes_bitwise_identical(results, layout, stencil):
    r = results[f"{layout}_{stencil}"]
    assert len(set(r["iters"].values())) == 1, r
    assert r["bitwise_concat_overlap"], r
    assert r["bitwise_concat_scatter"], r


@pytest.mark.slow
def test_overlap_exposes_hideable_halo_work(results):
    """The acceptance criterion: >0 (a vector's worth of) hideable bytes per
    collective-permute under overlap; ~0 (sub-vector) under concat."""
    vec = results["vec_bytes"]
    con, ovl = results["slack_concat"], results["slack_overlap"]
    assert con["n_ppermute"] == ovl["n_ppermute"] == 2   # 1-D: lo + hi
    assert all(s > vec for s in ovl["slack_bytes"]), (ovl, vec)
    assert all(s < vec for s in con["slack_bytes"]), (con, vec)
    assert ovl["blocking"] == 0
    assert con["blocking"] == con["n_ppermute"]
    assert min(ovl["slack_bytes"]) > 4 * max(con["slack_bytes"])


def test_scaling_model_consumes_halo_hide_window():
    from benchmarks.scaling_model import iteration_time
    kw = dict(nbar=27, local_grid=(128, 128, 128), chips=512)
    for method in ("cg", "cg_nb", "bicgstab", "jacobi"):
        t_concat = iteration_time(method, halo_mode="concat", **kw)
        t_overlap = iteration_time(method, halo_mode="overlap", **kw)
        assert t_overlap < t_concat, method
        # under the MPI regime the exchange blocks regardless
        t_mpi = iteration_time(method, halo_mode="overlap",
                               execution="mpi", **kw)
        t_mpi_c = iteration_time(method, halo_mode="concat",
                                 execution="mpi", **kw)
        assert t_mpi == t_mpi_c, method
    # GS sweeps consume halos at the first plane/colour: no hide window
    for method in ("gauss_seidel", "gauss_seidel_rb"):
        assert iteration_time(method, halo_mode="overlap", **kw) == \
            iteration_time(method, halo_mode="concat", **kw), method


def test_registry_halo_metadata():
    from repro.api import REGISTRY
    for name, spec in REGISTRY.items():
        assert len(spec.halo_hides) == spec.spmvs_per_iter, name
    assert REGISTRY["cg"].hidden_halos == 1
    assert REGISTRY["bicgstab_b1"].hidden_halos == 2
    assert REGISTRY["gauss_seidel"].hidden_halos == 0
    assert REGISTRY["gauss_seidel_rb"].hidden_halos == 0
