"""Distributed LM correctness: the sharded (TP×FSDP×SP, expert-parallel)
train/decode steps must compute the SAME numbers as the single-device path.

Runs in a subprocess with 8 host devices (main pytest process keeps 1).
Covers: dense GQA (internlm2, SP + hints), MoE via shard_map expert
parallelism (qwen3 reduced: 8 experts over tp=4), MHA sharding (minicpm),
and the cache-sequence-parallel decode path.
"""

import pytest
from conftest import run_multidevice

# the multi-arch sweep costs minutes; stays in tier-1 (plain pytest) but is
# deselectable for quick loops via -m "not slow"
pytestmark = pytest.mark.slow

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "src")
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.configs.base import get_config
from repro.models.transformer import ModelCtx, init_params
from repro.models import steps as steps_mod
from repro.distributed.sharding import (batch_shardings, param_shardings,
                                        param_specs, opt_state_specs)
from repro.optim.adamw import adamw
from repro.optim.schedules import constant

from repro.core.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
mesh1 = make_mesh((1, 1), ("data", "model"))
out = {}
for arch in ("internlm2-1.8b", "qwen3-moe-235b-a22b", "minicpm-2b"):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batch = steps_mod.synthetic_batch(cfg, "train_4k", override=(32, 4),
                                      dtype=jnp.float32)
    opt = adamw(constant(1e-3))
    state = opt.init(params)

    losses = {}
    for tag, m, dp in (("single", mesh1, ("data",)),
                       ("dist", mesh, ("data",))):
        ctx = ModelCtx(cfg=cfg, mesh=m, dp_axes=dp, tp_axis="model",
                       dtype=jnp.float32, remat=True)
        step = steps_mod.make_train_step(ctx, opt)
        p_sh = param_shardings(params, m, cfg)
        b_sh = batch_shardings(batch, m, dp)
        p = jax.tree.map(jax.device_put, params, p_sh)
        b = jax.tree.map(jax.device_put, batch, b_sh)
        s = jax.tree.map(lambda x: jax.device_put(x), state)
        p2, s2, _, metrics = jax.jit(step)(p, s, None, b)
        losses[tag] = dict(loss=float(metrics["loss"]),
                           gnorm=float(metrics["grad_norm"]))
    out[arch] = losses

# decode equivalence on the distributed mesh (cache-seq-parallel path)
cfg = get_config("internlm2-1.8b").reduced()
params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
db = steps_mod.synthetic_batch(cfg, "decode_32k", override=(64, 4),
                               dtype=jnp.float32)
res = {}
for tag, m, dp in (("single", mesh1, ("data",)), ("dist", mesh, ("data",))):
    ctx = ModelCtx(cfg=cfg, mesh=m, dp_axes=dp, dtype=jnp.float32, remat=False)
    dstep = steps_mod.make_decode_step(ctx)
    p_sh = param_shardings(params, m, cfg)
    b_sh = batch_shardings(db, m, dp)
    p = jax.tree.map(jax.device_put, params, p_sh)
    b = jax.tree.map(lambda x, s: jax.device_put(x, s), db, b_sh)
    logits, _ = jax.jit(dstep)(p, b["tokens"], b["cur_pos"], b["caches"])
    res[tag] = jax.device_get(logits)           # host arrays: meshes differ
import numpy as np
out["decode_max_dlogit"] = float(np.abs(res["dist"] - res["single"]).max())
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    return run_multidevice(_SCRIPT)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "qwen3-moe-235b-a22b",
                                  "minicpm-2b"])
def test_train_step_matches_single_device(results, arch):
    r = results[arch]
    assert abs(r["dist"]["loss"] - r["single"]["loss"]) < 5e-3, r
    assert abs(r["dist"]["gnorm"] - r["single"]["gnorm"]) < 5e-2 * (
        1 + r["single"]["gnorm"]), r


def test_decode_matches_single_device(results):
    assert results["decode_max_dlogit"] < 5e-3, results["decode_max_dlogit"]
