"""Reduction-hiding Krylov variants (PR 4): convergence parity, registry
metadata, preconditioner composition, the fused-kernel facade path, buffer
donation, and the scaling model's t_reduce term.

The HLO-level one-all-reduce claim lives in tests/test_hlo_analysis.py; the
kernel-vs-oracle precision checks in tests/test_kernels.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import REGISTRY, SolverOptions, SolverSession, solve
from repro.core.problems import make_problem

pytestmark = pytest.mark.usefixtures("f64")

#: variant -> the classic whose iteration counts it must track (+10%)
VARIANTS = {
    "cg_merged": "cg",
    "cg_pipe": "cg",
    "pcg_merged": "pcg",
    "pcg_pipe": "pcg",
    "bicgstab_merged": "bicgstab",
    "pbicgstab_merged": "pbicgstab",
}

GRIDS = [(32, 32, 32), (64, 64, 64)]
TOL = 1e-6

_classic_cache: dict = {}


def _solve(method, grid, stencil, **kw):
    return solve(method=method, grid=grid, stencil=stencil,
                 options=SolverOptions(tol=TOL, maxiter=1500, **kw))


def _classic(method, grid, stencil):
    key = (method, grid, stencil)
    if key not in _classic_cache:
        _classic_cache[key] = _solve(method, grid, stencil)
    return _classic_cache[key]


# -----------------------------------------------------------------------------
# Convergence parity: same tolerance, ≤ +10% iterations, on 7pt/27pt × 32³/64³
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("stencil", ["7pt", "27pt"])
@pytest.mark.parametrize("grid", GRIDS, ids=lambda g: f"{g[0]}^3")
@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_variant_matches_classic_iterations(grid, stencil, variant):
    ref = _classic(VARIANTS[variant], grid, stencil)
    res = _solve(variant, grid, stencil)
    assert float(res.res_norm) < TOL, (variant, float(res.res_norm))
    # +10% (+1 for the pipelined variants' one-iteration-stale check)
    budget = int(np.ceil(1.1 * int(ref.iters))) + 1
    assert int(res.iters) <= budget, (variant, int(res.iters), int(ref.iters))
    # same solution, not just same count
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(ref.x),
                               atol=1e-5, err_msg=variant)


def test_true_residual_matches_estimate_at_convergence():
    """The recurrence-based ‖r‖ estimates must not drift from the truth by
    convergence time (the docs' numerical-stability caveat, quantified)."""
    prob = make_problem((32, 32, 32), "27pt")
    from repro.core.solvers import SOLVERS, LocalOp
    A = LocalOp(prob.stencil)
    for m in sorted(VARIANTS):
        kw = {"M": None} if REGISTRY[m].accepts_precond else {}
        res = SOLVERS[m](A, prob.b(), prob.x0(), tol=TOL, maxiter=1500,
                         norm_ref=1.0, **kw)
        true_r = float(jnp.linalg.norm(
            (prob.b() - A.matvec(res.x)).reshape(-1)))
        # the estimate declared convergence; the TRUE residual must agree
        # to within an order of magnitude of the tolerance
        assert true_r < 10 * TOL, (m, true_r, float(res.res_norm))


# -----------------------------------------------------------------------------
# Preconditioner composition: all four PR-3 preconditioners
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("precond", ["jacobi", "block_jacobi", "ssor",
                                     "chebyshev"])
@pytest.mark.parametrize("method", ["pcg_merged", "pcg_pipe",
                                    "pbicgstab_merged"])
def test_composes_with_preconditioners(method, precond):
    grid, stencil = (24, 24, 24), "27pt"
    classic = VARIANTS[method]
    ref = _solve(classic, grid, stencil, precond=precond)
    res = _solve(method, grid, stencil, precond=precond)
    assert float(res.res_norm) < TOL, (method, precond)
    budget = int(np.ceil(1.1 * int(ref.iters))) + 1
    assert int(res.iters) <= budget, (method, precond,
                                      int(res.iters), int(ref.iters))
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(ref.x),
                               atol=1e-5, err_msg=f"{method}+{precond}")


def test_preconditioned_merged_beats_plain_iterations():
    """The whole point of composing: fewer iterations AND one reduction."""
    grid, stencil = (48, 48, 48), "7pt"
    plain = _solve("cg_merged", grid, stencil)
    pre = _solve("pcg_merged", grid, stencil, precond="chebyshev")
    assert int(pre.iters) < int(plain.iters)


# -----------------------------------------------------------------------------
# Batched serving path (vmap inside the facade)
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["cg_merged", "bicgstab_merged"])
def test_batched_matches_single_solves(method):
    prob = make_problem((10, 10, 12), "27pt")
    sess = SolverSession(prob, method=method,
                         options=SolverOptions(tol=1e-8, maxiter=400,
                                               norm_ref=None))
    rng = np.random.default_rng(3)
    bs = jnp.asarray(rng.standard_normal((4, 10, 10, 12)))
    bres = sess.solve_batched(bs)
    for i in (0, 3):
        single = sess.solve(b=bs[i])
        assert int(bres.iters[i]) == int(single.iters), (method, i)
        np.testing.assert_allclose(np.asarray(bres.x[i]),
                                   np.asarray(single.x), atol=1e-11)


# -----------------------------------------------------------------------------
# The fused Pallas iteration path (pallas=True: the whole reduction-hiding
# family since PR 10, local AND shard_map)
# -----------------------------------------------------------------------------

#: every method with a MethodDef fused body (PR 10 grew this from cg_merged
#: to the full family) — each gets the same facade-parity gate cg_merged had
FUSED_METHODS = sorted(VARIANTS)


@pytest.mark.parametrize("method", FUSED_METHODS)
def test_fused_facade_path_matches_unfused(method):
    """pallas=True routes to the fused Pallas body; same iteration count
    and machine-precision agreement with the unfused facade solve."""
    kw = dict(method=method, grid=(16, 16, 16), stencil="27pt")
    plain = solve(**kw, options=SolverOptions(tol=1e-8, maxiter=300))
    fused = solve(**kw, options=SolverOptions(tol=1e-8, maxiter=300,
                                              pallas=True))
    assert int(fused.iters) == int(plain.iters), method
    np.testing.assert_allclose(np.asarray(fused.x), np.asarray(plain.x),
                               rtol=1e-12, atol=1e-12, err_msg=method)


@pytest.mark.parametrize("method", ["cg_merged", "cg_pipe",
                                    "bicgstab_merged"])
def test_fused_runs_under_shard_map(method, mesh1):
    """PR 5: the fused Pallas body is no longer a local-only special case —
    on a mesh backend the facade routes ``pallas=True`` through
    ``solve_shardmap(pallas_fused=True)`` (PallasOp inside the shard_map
    body).  On the trivial 1-device mesh the result must match the local
    fused solve."""
    prob = make_problem((16, 16, 16), "27pt")
    opts = SolverOptions(tol=1e-8, maxiter=300, pallas=True)
    local = solve(prob, method=method, options=opts)
    dist = solve(prob, method=method, options=opts, mesh=mesh1)
    assert int(dist.iters) == int(local.iters), method
    np.testing.assert_allclose(np.asarray(dist.x), np.asarray(local.x),
                               rtol=1e-12, atol=1e-12, err_msg=method)


@pytest.mark.parametrize("precond", ["chebyshev", "block_jacobi"])
def test_fused_pcg_merged_composes_preconditioner(precond):
    """The tentpole composition: ``pcg_merged`` + a fused-kernel
    preconditioner runs END-TO-END on the fused path (the preconditioner's
    own Pallas kernels inside the fused Krylov body) with bitwise-equal
    iteration counts and machine-precision agreement vs the unfused
    facade."""
    kw = dict(method="pcg_merged", grid=(16, 16, 16), stencil="27pt")
    plain = solve(**kw, options=SolverOptions(tol=1e-8, maxiter=300,
                                              precond=precond))
    fused = solve(**kw, options=SolverOptions(tol=1e-8, maxiter=300,
                                              precond=precond, pallas=True))
    assert int(fused.iters) == int(plain.iters), precond
    np.testing.assert_allclose(np.asarray(fused.x), np.asarray(plain.x),
                               rtol=1e-12, atol=1e-12, err_msg=precond)


def test_fused_pcg_merged_chebyshev_under_shard_map(mesh1):
    """The composed fused path (pcg_merged + chebyshev) under shard_map:
    PallasOp wraps the DistributedOp, the preconditioner binds against it,
    and the result matches the local composed fused solve."""
    prob = make_problem((16, 16, 16), "27pt")
    opts = SolverOptions(tol=1e-8, maxiter=300, precond="chebyshev",
                         pallas=True)
    local = solve(prob, method="pcg_merged", options=opts)
    dist = solve(prob, method="pcg_merged", options=opts, mesh=mesh1)
    assert int(dist.iters) == int(local.iters)
    np.testing.assert_allclose(np.asarray(dist.x), np.asarray(local.x),
                               rtol=1e-12, atol=1e-12)


def test_fused_routing_is_capability_based():
    """The facade's Pallas routing queries the registry capability (any
    method whose MethodDef declares a fused body), not a hard-coded name."""
    from repro.api.registry import fused_solver_names
    assert fused_solver_names() == FUSED_METHODS
    prob = make_problem((8, 8, 8), "27pt")
    fused = SolverSession(prob, method="cg_merged",
                          options=SolverOptions(pallas=True))
    assert fused._use_fused_body()
    assert fused.spec.has_fused_body
    # pallas=True on a non-fused method still swaps the SpMV kernel only
    plain = SolverSession(prob, method="cg",
                          options=SolverOptions(pallas=True))
    assert not plain._use_fused_body()
    assert not plain.spec.has_fused_body


def test_fused_solve_matches_solver_loop():
    from repro.core.solvers import LocalOp, cg_merged
    from repro.kernels.fused_cg import cg_merged_fused
    prob = make_problem((12, 12, 16), "27pt")
    A = LocalOp(prob.stencil)
    ref = cg_merged(A, prob.b(), prob.x0(), tol=1e-8, maxiter=300,
                    norm_ref=1.0)
    res = jax.jit(lambda b, x0: cg_merged_fused(
        prob.stencil, b, x0, tol=1e-8, maxiter=300, norm_ref=1.0))(
            prob.b(), prob.x0())
    assert int(res.iters) == int(ref.iters)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(ref.x),
                               rtol=1e-12, atol=1e-12)


# -----------------------------------------------------------------------------
# Buffer donation on the solver hot loops
# -----------------------------------------------------------------------------

def test_solve_donates_x0_buffer():
    """options.donate=True must register the x0 -> output aliasing in the
    lowered HLO (input_output_alias) — and it is live on CPU too (see
    test_donated_x0_is_invalidated)."""
    prob = make_problem((8, 8, 8), "27pt")
    sess = SolverSession(prob, method="cg_merged",
                         options=SolverOptions(tol=1e-6, maxiter=50))
    txt = sess._build_fn().lower(prob.b(), prob.x0()).as_text()
    assert "tf.aliasing_output" in txt
    off = sess._build_fn(donate=False).lower(prob.b(), prob.x0()).as_text()
    assert "tf.aliasing_output" not in off
    # b is NOT donated (stationary methods re-read it; callers keep it):
    # exactly one of the two array args carries the aliasing attribute
    assert txt.count("tf.aliasing_output") == 1


def test_batched_solve_donates_and_still_matches():
    prob = make_problem((8, 8, 8), "27pt")
    sess = SolverSession(prob, method="cg",
                         options=SolverOptions(tol=1e-8, maxiter=200))
    bs = jnp.stack([prob.b()] * 2)
    txt = sess._build_batched_fn().lower(bs, jnp.zeros_like(bs)).as_text()
    assert "tf.aliasing_output" in txt
    res = sess.solve_batched(bs)           # donated path end-to-end
    ref = sess.solve()
    np.testing.assert_array_equal(np.asarray(res.x[0]), np.asarray(ref.x))


def test_donated_x0_is_invalidated():
    """The documented donation semantics: reusing a caller-supplied x0
    after a donating solve raises; donate=False keeps it alive."""
    prob = make_problem((8, 8, 8), "27pt")
    sess = SolverSession(prob, method="cg",
                         options=SolverOptions(tol=1e-6, maxiter=20))
    x0 = prob.x0()
    sess.solve(x0=x0)
    with pytest.raises(Exception, match="deleted or donated"):
        sess.solve(x0=x0)
    keep = SolverSession(prob, method="cg",
                         options=SolverOptions(tol=1e-6, maxiter=20,
                                               donate=False))
    x0 = prob.x0()
    keep.solve(x0=x0)
    keep.solve(x0=x0)                       # still alive


def test_repeated_session_solves_with_donation():
    """problem.b()/x0() hand out fresh buffers, so back-to-back solves on a
    donating session must keep working (the serving loop)."""
    sess = SolverSession(method="bicgstab_merged", grid=(8, 8, 8),
                         options=SolverOptions(tol=1e-8, maxiter=200))
    r1, r2 = sess.solve(), sess.solve()
    assert int(r1.iters) == int(r2.iters)
    np.testing.assert_array_equal(np.asarray(r1.x), np.asarray(r2.x))


# -----------------------------------------------------------------------------
# Registry metadata + the scaling model's t_reduce term
# -----------------------------------------------------------------------------

def test_registry_reduction_hiding_metadata():
    for variant, base in VARIANTS.items():
        spec = REGISTRY[variant]
        assert spec.variant_of == base, variant
        assert spec.reductions_per_iter == 1, variant
        assert spec.reduce_hide in ("merged", "pipelined"), variant
        assert spec.spmvs_per_iter == REGISTRY[base].spmvs_per_iter, variant
    assert REGISTRY["cg_merged"].blocking_reductions == 1
    assert REGISTRY["cg_pipe"].blocking_reductions == 0
    assert REGISTRY["cg_pipe"].reduce_hide == "pipelined"
    assert REGISTRY["bicgstab_merged"].reduce_hide == "merged"
    for classic in ("cg", "cg_nb", "bicgstab", "bicgstab_b1"):
        assert REGISTRY[classic].reduce_hide == "none"


def test_registry_rejects_inconsistent_reduce_hide():
    import dataclasses
    spec = REGISTRY["cg_merged"]
    with pytest.raises(ValueError, match="ONE stacked reduction"):
        dataclasses.replace(spec, name="bad",
                            reduction_hides=("none", "none"))
    with pytest.raises(ValueError, match="pipe"):
        dataclasses.replace(spec, name="bad", reduce_hide="pipelined")


def test_scaling_model_t_reduce_term():
    """Merged pays Λ once (vs 2–3×); pipelined hides that one payment behind
    the SpMV — the fig3/fig56 pipelined-overlap curves' driving term."""
    from benchmarks.scaling_model import iteration_time, reduction_latency
    kw = dict(nbar=27, local_grid=(128, 128, 128), chips=4096,
              noise="noisy", halo_mode="overlap")
    t_cg = iteration_time("cg", **kw)
    t_merged = iteration_time("cg_merged", **kw)
    t_pipe = iteration_time("cg_pipe", **kw)
    assert t_pipe < t_merged < t_cg
    assert iteration_time("bicgstab_merged", **kw) < iteration_time(
        "bicgstab", **kw)
    # the pipelined win IS the hidden reduction: under the MPI regime
    # (no overlap) the pipe variant loses its edge over merged
    kw_mpi = dict(kw, execution="mpi")
    assert iteration_time("cg_pipe", **kw_mpi) >= iteration_time(
        "cg_merged", **kw_mpi)
    assert reduction_latency(1) == 0.0
    assert reduction_latency(4096, noise="noisy") > reduction_latency(
        4096, noise="tpu")


def test_step_state_layouts_consistent():
    from repro.core.distributed import init_step_state, step_state_layout
    from repro.core.solvers import LocalOp
    prob = make_problem((6, 6, 8), "7pt")
    A = LocalOp(prob.stencil)
    for m in REGISTRY:
        vecs, scals = step_state_layout(m)
        state = init_step_state(m, A, prob.b(), prob.x0())
        assert len(state) == 1 + len(vecs) + len(scals), m
        for v in state[1:1 + len(vecs)]:
            assert v.shape == prob.shape, m
        for sc in state[1 + len(vecs):]:
            assert jnp.shape(sc) == (), m
