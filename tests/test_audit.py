"""The contract auditor turned on itself: each pass must flag a deliberately
injected violation — and ONLY the pass that owns the invariant — while the
real registry/kernel table runs clean.

Three injections, one per pass:

* a ``SolverSpec`` whose ``allreduces_per_iter`` understates what the HLO
  contains → the comms comparator flags the ``all-reduce`` count;
* a ``MethodDef`` whose step branches (Python ``if``) on a traced scalar →
  the AST lint flags ``traced_branch``;
* a ``KernelSpec`` whose block footprint exceeds the VMEM budget → the
  kernel lint flags ``vmem_bytes``.

The comparator tests run on synthetic measurement records (compare() is
pure), so they are fast; one slow test drives the real subprocess worker
over a one-method subset end to end.
"""

import dataclasses

import pytest

from repro.analysis.audit import compare, compare_baseline, expected_comms
from repro.analysis.lint_kernels import (
    KERNEL_TABLE,
    VMEM_BUDGET_BYTES,
    KernelSpec,
    check_kernels,
)
from repro.analysis.lint_methods import check_method, check_methods
from repro.api.registry import (
    REGISTRY,
    RegistryConsistencyError,
    SolverSpec,
    method_field_diff,
    register_solver,
)
from repro.core.methods import METHODS, MethodDef


def _fake_measured(registry, mesh="1d", halo="concat"):
    """Measurement records exactly matching the registry's expectations."""
    comms = {}
    for name, spec in registry.items():
        want = expected_comms(spec, mesh)
        comms[f"{name}|{mesh}|{halo}|xla|none"] = {
            "counts": {op: n for op, n in want.items() if n},
            "bytes": 1000,
        }
    return {"comms": comms}


# -----------------------------------------------------------------------------
# injection 1: wrong registry comms metadata -> the comms pass flags it
# -----------------------------------------------------------------------------

def test_clean_registry_compares_clean():
    measured = _fake_measured(REGISTRY)
    assert compare(measured) == []


def test_wrong_allreduce_count_flagged():
    # cg really compiles to 2 all-reduces; doctor the spec to claim 1
    bad_cg = dataclasses.replace(REGISTRY["cg"], allreduces_per_iter=1)
    registry = dict(REGISTRY, cg=bad_cg)
    measured = _fake_measured({"cg": REGISTRY["cg"]})
    found = compare(measured, registry=registry)
    assert len(found) == 1
    v = found[0]
    assert (v.pass_name, v.field) == ("comms", "all-reduce")
    assert v.expected == 1 and v.actual == 2


def test_unexpected_collective_flagged():
    measured = _fake_measured({"cg": REGISTRY["cg"]})
    key = next(iter(measured["comms"]))
    measured["comms"][key]["counts"]["all-gather"] = 1   # lost sharding symptom
    found = compare(measured)
    assert [v.field for v in found] == ["all-gather"]
    assert found[0].expected == 0 and found[0].actual == 1


def test_donation_and_alias_violations_flagged():
    measured = {
        "donate_mesh": {"cg|1d": {"on": 0, "off": 0}},
        "local": {"cg": {"markers_on": 1, "markers_off": 1,
                         "collectives": {"all-reduce": 2},
                         "aliased_params": []}},
        "mesh_aliases": {"cg|1d": []},
    }
    found = compare(measured)
    fields = sorted((v.pass_name, v.field) for v in found)
    assert ("donation", "markers_on") in fields          # mesh: not annotated
    assert ("donation", "markers_off") in fields         # local: leaks donation
    assert ("comms", "collectives") in fields            # local: not collective-free
    assert ("donation", "input_output_alias") in fields  # alias not granted
    assert len(found) == 5                               # + mesh alias record


def test_baseline_drift_flagged():
    key = "cg|1d|concat|xla|none"
    rec = {"counts": {"all-reduce": 2}, "bytes": 1616}
    drifted = {"counts": {"all-reduce": 2}, "bytes": 3232}
    base = {"measured": {"comms": {key: rec}}}
    assert compare_baseline({"comms": {key: rec}}, base) == []
    found = compare_baseline({"comms": {key: drifted}}, base)
    assert [v.field for v in found] == ["drift"]
    missing = compare_baseline({"comms": {}}, base)
    new = compare_baseline(
        {"comms": {key: rec, "cg|3d|auto|xla|none": rec}}, base)
    assert [v.field for v in missing] == ["coverage"]
    assert [v.field for v in new] == ["coverage"]


def test_precond_configs_add_expected_traffic():
    plain = expected_comms(REGISTRY["pcg"], "2d")
    withp = expected_comms(REGISTRY["pcg"], "2d", precond="jacobi")
    assert withp["all-reduce"] == plain["all-reduce"]    # Jacobi: no extra psum
    assert withp["collective-permute"] > plain["collective-permute"]


# -----------------------------------------------------------------------------
# injection 2: MethodDef branching on a traced scalar -> the AST lint flags it
# -----------------------------------------------------------------------------

def _branchy_init(ops, x0):
    r = ops.b - ops.matvec(x0)
    return (x0, ops.dot(r, r))


def _branchy_step(ops, state):
    x, res = state
    if res > 1e-3:          # Python branch on a traced value: the injection
        x = x + 1.0
    return (x, res * 0.5)


_BRANCHY = MethodDef(
    name="_audit_branchy", vectors=("x",), scalars=("res2",),
    res_scalar="res2", init=_branchy_init, step=_branchy_step)


def test_traced_branch_flagged():
    found = check_method(_BRANCHY, layout=False)
    assert any(v.field == "traced_branch" and "res" in str(v.actual)
               for v in found)
    assert all(v.pass_name == "lint_methods" for v in found)


def test_traced_branch_also_breaks_layout_trace():
    # with the layout pass on, the same injection ALSO fails to trace under
    # eval_shape — both findings point at the same root cause
    fields = {v.field for v in check_method(_BRANCHY)}
    assert "traced_branch" in fields and "state_layout" in fields


def test_real_methods_lint_clean():
    assert check_methods(layout=False) == []


def test_real_methods_layout_clean():
    assert check_methods() == []


# -----------------------------------------------------------------------------
# injection 3: oversized kernel block -> the kernel lint flags it
# -----------------------------------------------------------------------------

def test_oversized_kernel_block_flagged():
    bad = KernelSpec("spmv", "stencil_spmv", "stencil_spmv_ref",
                     vmem_bytes=4 * VMEM_BUDGET_BYTES)
    found = check_kernels(table=(bad,))
    assert [v.field for v in found] == ["vmem_bytes"]
    assert found[0].pass_name == "lint_kernels"


def test_non_dividing_block_flagged():
    bad = KernelSpec("spmv", "stencil_spmv", "stencil_spmv_ref",
                     vmem_bytes=1024, block_z=7)
    found = check_kernels(table=(bad,))
    assert [v.field for v in found] == ["block_divisibility"]


def test_missing_oracle_flagged():
    bad = KernelSpec("spmv", "stencil_spmv", "no_such_ref_fn",
                     vmem_bytes=1024)
    found = check_kernels(table=(bad,))
    assert [v.field for v in found] == ["oracle"]


def test_real_kernel_table_clean():
    assert check_kernels() == []
    # and the real table stays under budget with honest margins
    for spec in KERNEL_TABLE:
        assert spec.vmem_bytes <= VMEM_BUDGET_BYTES, spec.name


# -----------------------------------------------------------------------------
# RegistryConsistencyError renders an expected-vs-actual field diff
# -----------------------------------------------------------------------------

def test_registry_consistency_error_prints_field_diff():
    toy = MethodDef(
        name="_audit_toy", vectors=("x",), scalars=("res2",),
        res_scalar="res2", init=_branchy_init,
        step=lambda ops, state: (state[0], state[1] * 0.5))
    METHODS[toy.name] = toy
    try:
        with pytest.raises(RegistryConsistencyError) as exc:
            register_solver(SolverSpec(
                name=toy.name, fn=lambda *a, **k: None,
                reduction_hides=("none",), spmvs_per_iter=1,
                stationary=True, accepts_precond=True))   # mdef says False/False
        msg = str(exc.value)
        assert "drifted from its MethodDef" in msg
        # the aligned table: header row + one row per mismatched field
        assert "registry" in msg and "derived" in msg
        assert "stationary" in msg and "accepts_precond" in msg
        assert "True" in msg and "False" in msg
        assert toy.name not in REGISTRY          # rejected, not registered
    finally:
        METHODS.pop(toy.name, None)
        REGISTRY.pop(toy.name, None)


def test_method_field_diff_rows():
    spec = REGISTRY["cg"]
    assert method_field_diff(spec, METHODS["cg"]) == []
    diffs = method_field_diff(spec, METHODS["cg_merged"])
    assert any(d.field == "reduce_hide" for d in diffs)
    d = next(d for d in diffs if d.field == "reduce_hide")
    assert "registry declares" in str(d) and "derived says" in str(d)


# -----------------------------------------------------------------------------
# the real thing, end to end (subprocess, 8 host devices)
# -----------------------------------------------------------------------------

@pytest.mark.slow
def test_audit_subset_end_to_end():
    from repro.analysis.audit import run_measurements
    measured = run_measurements(["cg_merged"])
    assert measured["comms"]                      # incl. the pallas configs
    assert any("|pallas|" in k for k in measured["comms"])
    assert compare(measured) == []
