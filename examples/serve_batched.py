"""Batched solver serving: many right-hand sides, one compiled call.

The serving workload for the paper's solvers: a traffic burst of independent
systems sharing one operator (same stencil, same grid — e.g. one PDE, many
boundary conditions/timesteps).  ``repro.api.solve_batched`` vmaps the solver
over the batch — locally on one device, *inside* shard_map on a mesh — so the
whole burst is a single XLA program: one compile, one dispatch, and each
iteration's reduction stays one collective for the entire batch.  JAX masks
finished lanes, so every RHS converges exactly as it would alone.

(The LM serving demo formerly here lives at ``python -m repro.launch.serve``;
the *streaming* version of this workload — heterogeneous requests over a
compiled-executable cache — is ``repro.serve``, demoed by
``python -m repro.launch.serve --mode solver``.)

PYTHONPATH=src python examples/serve_batched.py [--batch 8] [--json]
"""

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.api import SolverOptions, SolverSession
from repro.core.problems import enable_f64


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--grid", type=int, nargs=3, default=[32, 32, 32])
    ap.add_argument("--method", default="bicgstab_b1")
    ap.add_argument("--json", action="store_true",
                    help="also print the result record as one JSON line")
    args = ap.parse_args(argv)

    enable_f64()      # paper precision; the facade no longer flips x64 itself
    batch, grid = args.batch, tuple(args.grid)

    sess = SolverSession(method=args.method, grid=grid, stencil="27pt",
                         options=SolverOptions(tol=1e-6, maxiter=400,
                                               norm_ref=None))
    print(f"serving session: {sess.describe()}  batch={batch}")

    rng = np.random.default_rng(0)
    bs = jnp.asarray(rng.standard_normal((batch, *grid)),
                     dtype=sess.problem.b().dtype)

    res, stats = sess.timed_solve_batched(bs, repeats=3)   # warm-up compiles
    iters = np.asarray(res.iters)
    norms = np.asarray(res.res_norm)
    print(f"one compiled call: {batch} solves in {stats['median']*1e3:.1f} ms "
          f"(median of 3)")
    for i in range(batch):
        print(f"  rhs[{i}]: iters={int(iters[i]):3d}  ||r||={norms[i]:.2e}")

    # the naive serving loop, for contrast: one dispatch per request
    # (warmed + blocked, so this measures execution, not compile/async dispatch)
    jax.block_until_ready(sess.solve(b=bs[0]))
    t0 = time.perf_counter()
    for i in range(batch):
        jax.block_until_ready(sess.solve(b=bs[i]))
    loop_s = time.perf_counter() - t0
    print(f"sequential loop: {loop_s*1e3:.1f} ms for {batch} requests "
          f"(batched/loop = {stats['median']/loop_s:.2f})")
    print("(on CPU the batched lanes pad to the slowest RHS; the batched win "
          "comes on accelerators, where one dispatch and one collective per "
          "iteration serve the whole batch)")
    out = {"method": args.method, "grid": list(grid), "batch": batch,
           "batched_median_s": stats["median"], "loop_s": loop_s,
           "iters": iters.tolist(), "res_norm": norms.tolist()}
    if args.json:
        print(json.dumps(out))
    return out


if __name__ == "__main__":
    main()
