"""Batched serving example: prefill + autoregressive decode with KV caches.

Demonstrates the serve path for a dense GQA arch and the SSM decode path
(constant-state) for mamba2 — the mechanism behind the long_500k cells.

PYTHONPATH=src python examples/serve_batched.py
"""

from repro.launch import serve as serve_mod

for arch in ("internlm2-1.8b", "mamba2-780m"):
    print(f"=== {arch} (reduced) ===")
    serve_mod.main(["--arch", arch, "--reduced", "--batch", "4",
                    "--prompt-len", "32", "--gen", "16"])
