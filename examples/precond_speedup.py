"""Preconditioning in 60 seconds: fewer iterations, zero extra reductions.

Solves the HPCG system with plain cg/bicgstab and with pcg/pbicgstab under
each repro.precond implementation, printing the iteration counts side by
side with the preconditioner's per-apply cost metadata — the two axes of
the trade-off the scaling model prices (extra local sweeps and halo traffic
per iteration vs fewer iterations, i.e. fewer all-reduces total).

PYTHONPATH=src python examples/precond_speedup.py
"""

from repro.api import REGISTRY, SolverOptions, make_precond, solve
from repro.core.problems import enable_f64
from repro.precond import PRECONDITIONERS

enable_f64()      # paper precision; the facade never flips x64 itself

GRID = (48, 48, 48)
PRECONDS = tuple(sorted(PRECONDITIONERS))
opts = SolverOptions(tol=1e-6, maxiter=700)

for stencil in ("7pt", "27pt"):
    print(f"\n=== {stencil} stencil, grid={GRID} ===")
    print("method                      iters  residual   extra apply cost")
    for method, pmethod in (("cg", "pcg"), ("bicgstab", "pbicgstab")):
        base = solve(method=method, grid=GRID, stencil=stencil, options=opts)
        print(f"{method:27s} {int(base.iters):5d}  "
              f"{float(base.res_norm):9.2e}  -")
        nbar = 7 if stencil == "7pt" else 27
        applies = REGISTRY[pmethod].precond_applies_per_iter
        for name in PRECONDS:
            res = solve(method=pmethod, grid=GRID, stencil=stencil,
                        options=opts.replace(precond=name))
            inst = make_precond(name)
            cost = applies * inst.touched_elements_per_apply(nbar)
            halos = applies * inst.halo_matvecs_per_apply
            assert int(res.iters) <= int(base.iters), (pmethod, name)
            print(f"{pmethod + '+' + name:27s} {int(res.iters):5d}  "
                  f"{float(res.res_norm):9.2e}  "
                  f"+{cost} elems/row/iter, +{halos} halo exch, +0 reductions")

print("\nEvery preconditioner is reduction-free: the iteration savings come "
      "at zero additional synchronisation,\nso the win grows with the "
      "all-reduce latency (see benchmarks/fig3_weak_ksm.py breakeven "
      "curves).")
