"""Distributed solver demo: the paper's weak-scaling experiment in miniature.

Spawns a subprocess with 8 host devices, decomposes the grid like HPCCG
(1-D over z), runs CG-NB under shard_map, and verifies it matches the
single-device solve; then prints the TPU-projected weak-scaling table from
the roofline model.

PYTHONPATH=src python examples/solver_scaling.py
"""

import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.core import make_problem, solve_shardmap, LocalOp, SOLVERS
from repro.launch.mesh import make_solver_mesh

mesh = make_solver_mesh(8)                      # paper-faithful 1-D layout
prob = make_problem((32, 32, 64), "27pt")
fn, layout = solve_shardmap(prob, "cg_nb", mesh, tol=1e-6, maxiter=300)
sh = NamedSharding(mesh, layout.spec())
res = jax.jit(fn)(jax.device_put(prob.b(), sh), jax.device_put(prob.x0(), sh))
ref = SOLVERS["cg_nb"](LocalOp(prob.stencil), prob.b(), prob.x0(),
                       tol=1e-6, maxiter=300, norm_ref=1.0)
print(f"distributed: iters={int(res.iters)} res={float(res.res_norm):.2e}  "
      f"(single-device: iters={int(ref.iters)}) "
      f"max|dx|={float(jnp.abs(res.x-ref.x).max()):.2e}")
"""

if __name__ == "__main__":
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    subprocess.run([sys.executable, "-c", _SCRIPT], cwd=root, check=True)

    sys.path.insert(0, os.path.join(root))
    sys.path.insert(0, os.path.join(root, "src"))
    from benchmarks.scaling_model import weak_efficiency

    print("\nTPU-projected weak-scaling efficiency (27pt, 128^3/chip):")
    print("chips :  " + "  ".join(f"{n:>6d}" for n in (8, 64, 512, 4096)))
    for m in ("cg", "cg_nb"):
        effs = [weak_efficiency(m, 27, n) for n in (8, 64, 512, 4096)]
        print(f"{m:6s}:  " + "  ".join(f"{e:6.3f}" for e in effs))
