"""Distributed solver demo: the paper's weak-scaling experiment in miniature.

Spawns a subprocess with 8 host devices and runs CG-NB twice through the SAME
``repro.api.solve`` call — once forced local, once on the paper-faithful 1-D
z decomposition (``layout="1d"`` resolves to shard_map over all 8 devices) —
and verifies the two backends agree; then prints the TPU-projected
weak-scaling table from the roofline model.

PYTHONPATH=src python examples/solver_scaling.py
"""

import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax.numpy as jnp
from repro.api import SolverOptions, solve
from repro.core.problems import enable_f64

enable_f64()      # paper precision; the facade no longer flips x64 itself

opts = SolverOptions(tol=1e-6, maxiter=300)
kw = dict(method="cg_nb", grid=(32, 32, 64), stencil="27pt", options=opts)
res = solve(layout="1d", **kw)       # shard_map over 8 devices (HPCCG layout)
ref = solve(layout="local", **kw)    # single-device reference
print(f"distributed: iters={int(res.iters)} res={float(res.res_norm):.2e}  "
      f"(single-device: iters={int(ref.iters)}) "
      f"max|dx|={float(jnp.abs(res.x-ref.x).max()):.2e}")
"""

if __name__ == "__main__":
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    subprocess.run([sys.executable, "-c", _SCRIPT], cwd=root, check=True)

    sys.path.insert(0, os.path.join(root))
    sys.path.insert(0, os.path.join(root, "src"))
    from benchmarks.scaling_model import weak_efficiency

    print("\nTPU-projected weak-scaling efficiency (27pt, 128^3/chip, "
          "noisy-fabric regime):")
    print("chips     :  " + "  ".join(f"{n:>6d}" for n in (8, 64, 512, 4096)))
    # cg_merged pays the all-reduce latency ONCE per iteration, cg_pipe
    # additionally hides it behind the SpMV (PR 4, docs/API.md
    # §Reduction-hiding variants)
    for m in ("cg", "cg_nb", "cg_merged", "cg_pipe"):
        effs = [weak_efficiency(m, 27, n, noise="noisy")
                for n in (8, 64, 512, 4096)]
        print(f"{m:10s}:  " + "  ".join(f"{e:6.3f}" for e in effs))
