"""Quickstart: the paper in 60 seconds, through the unified facade.

Solves the HPCG system with every registered method via ``repro.api.solve``
(one entry point — the same call runs local, sharded, or Pallas-backed),
shows CG and the paper's nonblocking CG-NB are arithmetically equivalent,
and prints the per-iteration barrier structure straight from the solver
registry's metadata — the paper's whole point.

PYTHONPATH=src python examples/quickstart.py
"""

from repro.api import REGISTRY, SolverOptions, solve, solver_names
from repro.core.operators import touched_elements_per_iter
from repro.core.problems import enable_f64

enable_f64()      # paper precision; the facade no longer flips x64 itself

opts = SolverOptions(tol=1e-6, maxiter=700)

print("method           iters  residual   ||x-1||_inf  extra traffic")
for method in ("cg", "cg_nb", "bicgstab", "bicgstab_b1", "gauss_seidel",
               "gauss_seidel_rb", "jacobi"):
    assert method in solver_names()
    # the paper's system: 27-pt stencil on a hexahedral grid, b s.t. x* = 1
    res = solve(method=method, grid=(48, 48, 48), stencil="27pt",
                options=opts)
    err = float(abs(res.x - 1.0).max())
    t = touched_elements_per_iter(method, 27)
    print(f"{method:16s} {int(res.iters):5d}  {float(res.res_norm):9.2e}"
          f"  {err:11.2e}  ({t} elems/row/iter)")

print("\nBarrier structure per iteration (from repro.api.REGISTRY):")
for name in ("cg", "cg_nb", "bicgstab", "bicgstab_b1"):
    spec = REGISTRY[name]
    hides = ", ".join(spec.reduction_hides)
    variant = f"  (variant of {spec.variant_of})" if spec.variant_of else ""
    print(f"  {name:12s}: {spec.reductions_per_iter} reductions "
          f"({hides}) -> {spec.blocking_reductions} hard barrier(s){variant}")
print("Run `python -m benchmarks.run --only fig2_variants` for the "
      "measured traces.")
