"""Quickstart: the paper in 60 seconds.

Solves the HPCG system with classical CG and the paper's nonblocking CG-NB,
shows they are arithmetically equivalent, and prints the per-iteration
barrier structure that is the paper's whole point.

PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.core import LocalOp, SOLVERS, make_problem, enable_f64
from repro.core.operators import touched_elements_per_iter

enable_f64()

# the paper's system: 27-pt stencil on a hexahedral grid, b s.t. x* = 1
prob = make_problem((48, 48, 48), "27pt")
A = LocalOp(prob.stencil)
b, x0 = prob.b(), prob.x0()

print("method        iters  residual   ||x-1||_inf  extra traffic")
for method in ("cg", "cg_nb", "bicgstab", "bicgstab_b1", "gauss_seidel",
               "jacobi"):
    res = jax.jit(lambda b, x0, m=method: SOLVERS[m](
        A, b, x0, tol=1e-6, maxiter=700, norm_ref=1.0))(b, x0)
    err = float(abs(res.x - 1.0).max())
    t = touched_elements_per_iter(
        method if "gauss" not in method and method != "jacobi" else method, 27)
    print(f"{method:13s} {int(res.iters):5d}  {float(res.res_norm):9.2e}"
          f"  {err:11.2e}  ({t} elems/row/iter)")

print("""
Barrier structure per iteration (the paper's contribution):
  cg          : 2 reductions, 1 is a hard barrier (zero overlap slack)
  cg_nb       : 2 reductions, 0 hard barriers — r·r rides behind the SpMV,
                Ap·p behind the lagged x update          (Alg. 1)
  bicgstab    : 3 reductions, 2 hard barriers
  bicgstab_b1 : 3 reductions, 1 hard barrier (alpha_d)   (Alg. 2)
Run `python -m benchmarks.run --only fig2_variants` for the measured traces.
""")
