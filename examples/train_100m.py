"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the public config/training API with a custom ~100M dense config (the
assigned architectures' reduced versions are smaller; this one is sized for
the deliverable).  On CPU expect ~2-4 s/step at the default sizes; pass
--steps 300 for the full run or keep the default quick demo.

PYTHONPATH=src python examples/train_100m.py --steps 300
"""

import argparse

from repro.configs.base import ArchConfig, register
from repro.launch import train as train_mod

CFG_100M = register(ArchConfig(
    name="demo-100m",
    family="dense",
    n_layers=10,
    d_model=640,
    n_heads=10,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=50_000,
    tie_embeddings=True,
))

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()
    print(f"demo-100m params: {CFG_100M.param_count()/1e6:.0f}M")
    train_mod.main([
        "--arch", "demo-100m",
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--seq", str(args.seq),
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "25",
        "--lr", "3e-4",
    ])
