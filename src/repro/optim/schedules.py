"""LR schedules: WSD (minicpm's warmup-stable-decay), cosine, linear."""

from __future__ import annotations

import jax.numpy as jnp


def wsd(peak_lr: float, warmup: int, stable: int, decay: int,
        final_frac: float = 0.1):
    """MiniCPM's Warmup-Stable-Decay: linear warmup, flat plateau, then a
    short exponential-ish (here: linear) decay to ``final_frac``·peak."""

    def f(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        dec_t = (s - warmup - stable) / max(decay, 1)
        dec = peak_lr * (1.0 - (1.0 - final_frac) * jnp.clip(dec_t, 0.0, 1.0))
        return jnp.where(s < warmup, warm, jnp.where(s < warmup + stable,
                                                     peak_lr, dec))

    return f


def cosine(peak_lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup, warm, peak_lr * cos)

    return f


def constant(lr: float):
    def f(step):
        return jnp.full((), lr, jnp.float32)

    return f


def for_arch(arch_name: str, peak_lr: float = 3e-4, total: int = 10_000):
    """MiniCPM trains with WSD (its headline schedule); others use cosine."""
    if arch_name.startswith("minicpm"):
        return wsd(peak_lr, warmup=total // 100 + 1, stable=int(total * 0.8),
                   decay=int(total * 0.19) + 1)
    return cosine(peak_lr, warmup=total // 100 + 1, total=total)
