"""AdamW with f32 moments over bf16 params + global-norm clipping.

Moments are stored f32 and sharded exactly like the params (the update is
elementwise, so GSPMD keeps it fully local); the master-copy is elided —
updates are computed in f32 and cast back, which at these scales costs <1 bit
of effective precision per step and saves 4 bytes/param of HBM (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


class AdamW(NamedTuple):
    init: Callable
    update: Callable


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw(
    schedule: Callable[[jax.Array], jax.Array],
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
) -> AdamW:
    def init(params):
        zeros = lambda: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(), v=zeros())

    def update(grads, state: AdamWState, params):
        step = state.step + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
        lr = schedule(step).astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mhat = m2 / (1 - b1 ** step.astype(jnp.float32))
            vhat = v2 / (1 - b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            p2 = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return p2, m2, v2

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step=step, m=new_m, v=new_v), {
            "grad_norm": gnorm, "lr": lr}

    return AdamW(init=init, update=update)
