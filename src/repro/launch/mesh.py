"""Production mesh construction (deliverable e).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets the 512-device
host platform before calling it.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_for_devices(n: int | None = None, *, model: int | None = None) -> Mesh:
    """Best-effort mesh on the actually-available devices (train/serve/smoke).

    Picks the largest model axis that divides the device count (capped at 16,
    the production TP width).
    """
    n = n or len(jax.devices())
    model = model or next(m for m in (16, 8, 4, 2, 1) if n % m == 0)
    return make_mesh((n // model, model), ("data", "model"))


def make_solver_mesh(n: int | None = None) -> Mesh:
    """1-D mesh for the paper-faithful HPCCG layout (z-only decomposition)."""
    n = n or len(jax.devices())
    return make_mesh((n,), ("cells",))
