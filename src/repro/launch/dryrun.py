import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (deliverable e): lower + compile EVERY
(architecture × input shape × mesh) cell on 512 placeholder host devices,
print ``memory_analysis()`` (proves it fits) and ``cost_analysis()``
(feeds §Roofline), and dump a JSON record per cell under experiments/dryrun/.

Per-cell record:
  * bytes per device (argument/output/temp/peak) from memory_analysis,
  * HLO flops / bytes, raw and trip-count-corrected (scan bodies appear once
    in HLO; a single-layer compile supplies the per-layer cost, DESIGN.md §7),
  * collective operand bytes by op kind (all-gather/all-reduce/
    reduce-scatter/all-to-all/collective-permute), trip-scaled,
  * the analytic MODEL_FLOPS (6·N·D train / 2·N·D decode) for the
    useful-compute ratio.

Usage:
  python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # every LM cell, both meshes
  python -m repro.launch.dryrun --solvers        # the paper's HPCG cells
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import collective_bytes, count_collectives
from repro.core.compat import cost_analysis
from repro.configs.base import SHAPES, all_configs, get_config
from repro.distributed.sharding import (
    batch_shardings,
    dp_axes_of,
    param_shardings,
)
from repro.launch.mesh import make_production_mesh
from repro.models import steps as steps_mod
from repro.models.transformer import ModelCtx, init_params
from repro.optim.adamw import adamw
from repro.optim.schedules import for_arch

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _mesh(kind: str):
    return make_production_mesh(multi_pod=(kind == "multi"))


def _n_chips(mesh) -> int:
    return mesh.devices.size


def _ctx(cfg, mesh, profile: str = "tp") -> ModelCtx:
    """profile: "tp" (baseline TP+SP) | "fsdp" (batch over both axes; the
    recommended layout for small-d archs — EXPERIMENTS.md §Perf-2b)."""
    if profile == "fsdp":
        dp = tuple(a for a in ("pod", "data", "model")
                   if a in mesh.axis_names)
    else:
        dp = dp_axes_of(mesh)
    return ModelCtx(cfg=cfg, mesh=mesh, dp_axes=dp,
                    tp_axis="model", dtype=jnp.bfloat16, remat=True)


def _trips(cfg) -> int:
    if cfg.family == "moe" and cfg.moe_every == 2:
        return cfg.n_layers // 2
    if cfg.local_global:
        return cfg.n_layers // 2
    return cfg.n_layers


# -----------------------------------------------------------------------------
# Single-layer cost probes (trip-count correction)
# -----------------------------------------------------------------------------

def _layer_cost(ctx, params_shape, batch, kind: str):
    """cost_analysis of ONE scanned-group body (fwd, and fwd+bwd for train)."""
    from repro.models.transformer import _layer_forward, layer_kind
    cfg = ctx.cfg
    mesh = ctx.mesh
    dp = dp_axes_of(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]

    layer_shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
        params_shape["layers"])

    ref = batch.get("tokens", batch.get("embeds"))
    B, S = ref.shape[:2]
    h_shape = jax.ShapeDtypeStruct((B, S, cfg.d_model), ctx.dtype)
    tp = mesh.shape["model"]
    sp_ok = S % tp == 0
    pos = batch["positions"]

    def group_fwd(lp, h, positions):
        if cfg.family == "moe" and cfg.moe_every == 2:
            h, _ = _layer_forward(ctx, lp["dense"], h, positions, window=0,
                                  kind="dense")
            h, _ = _layer_forward(ctx, lp["moe"], h, positions, window=0,
                                  kind="moe")
            return h
        if cfg.local_global:
            p0 = jax.tree.map(lambda x: x, lp)
            h, _ = _layer_forward(ctx, p0, h, positions,
                                  window=cfg.sliding_window, kind="dense")
            h, _ = _layer_forward(ctx, p0, h, positions, window=0, kind="dense")
            return h
        h, _ = _layer_forward(ctx, lp, h, positions, window=cfg.sliding_window,
                              kind=layer_kind(cfg))
        return h

    # reuse the global param rules minus the leading layer axis
    from repro.distributed.sharding import param_specs
    full_specs = param_specs(params_shape, ctx.mesh)["layers"]
    lp_shard = jax.tree.map(
        lambda spec: NamedSharding(ctx.mesh, P(*spec[1:])), full_specs)
    # match the scan steady state: the residual stream is sequence-parallel
    h_shard = NamedSharding(ctx.mesh, P(dp_spec, "model" if sp_ok else None,
                                        None))
    pos_shard = batch_shardings({"positions": pos}, ctx.mesh)["positions"]

    if kind == "train":
        def fwd_loss(lp, h, positions):
            return jnp.sum(group_fwd(lp, h, positions).astype(jnp.float32))

        fn = jax.jit(jax.grad(fwd_loss, argnums=(0, 1)),
                     in_shardings=(lp_shard, h_shard, pos_shard))
    else:
        fn = jax.jit(group_fwd, in_shardings=(lp_shard, h_shard, pos_shard))
    compiled = fn.lower(layer_shapes, h_shape, pos).compile()
    ca = cost_analysis(compiled)
    cb = collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "collective_bytes": float(cb)}


def _decode_layer_cost(ctx, params_shape, batch):
    """One decode-group body cost (caches included)."""
    from repro.models.decode import _decode_layer
    from repro.models.transformer import layer_kind
    cfg = ctx.cfg
    mesh = ctx.mesh
    dp = dp_axes_of(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]
    B = batch["tokens"].shape[0]

    layer_shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
        params_shape["layers"])
    cache_shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
        batch["caches"])
    h_shape = jax.ShapeDtypeStruct((B, 1, cfg.d_model), ctx.dtype)
    pos_shape = jax.ShapeDtypeStruct((), jnp.int32)

    def group(lp, h, cur_pos, cache):
        if cfg.family == "moe" and cfg.moe_every == 2:
            h, cd = _decode_layer(ctx, lp["dense"], h, cur_pos,
                                  {"attn": cache["dense"]}, window=0, kind="dense")
            h, cm = _decode_layer(ctx, lp["moe"], h, cur_pos,
                                  {"attn": cache["moe"]}, window=0, kind="moe")
            return h, {"dense": cd["attn"], "moe": cm["attn"]}
        if cfg.local_global:
            h, ce = _decode_layer(ctx, lp, h, cur_pos, {"attn": cache["even"]},
                                  window=cfg.sliding_window, kind="dense")
            h, co = _decode_layer(ctx, lp, h, cur_pos, {"attn": cache["odd"]},
                                  window=0, kind="dense")
            return h, {"even": ce["attn"], "odd": co["attn"]}
        h, nc = _decode_layer(ctx, lp, h, cur_pos, cache,
                              window=cfg.sliding_window, kind=layer_kind(cfg))
        return h, nc

    # shard the probe's inputs like the real step (a replicated cache would
    # inflate the probe's per-device bytes by the full cache size)
    from repro.distributed.sharding import param_specs
    full_specs = param_specs(params_shape, ctx.mesh, cfg)["layers"]
    lp_shard = jax.tree.map(
        lambda spec: NamedSharding(ctx.mesh, P(*spec[1:])), full_specs)

    def cache_spec(path, x):
        name = str(getattr(path[-1], "key", path[-1]))
        if name in ("k", "v") or name == "state":   # (B,C,KV,hd)/(B,nH,P,N)
            return NamedSharding(ctx.mesh, P(dp_spec, "model", None, None))
        if name == "conv":                          # (B,K-1,ch)
            return NamedSharding(ctx.mesh, P(dp_spec, None, None))
        return NamedSharding(ctx.mesh, P())         # pos

    cache_shard = jax.tree_util.tree_map_with_path(cache_spec, cache_shapes)
    h_shard = NamedSharding(ctx.mesh, P(dp_spec, None, None))
    fn = jax.jit(group, in_shardings=(lp_shard, h_shard, None, cache_shard))
    compiled = fn.lower(layer_shapes, h_shape, pos_shape, cache_shapes).compile()
    ca = cost_analysis(compiled)
    cb = collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "collective_bytes": float(cb)}


# -----------------------------------------------------------------------------
# LM cells
# -----------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             profile: str = "tp", verbose: bool = True) -> dict:
    cfg = get_config(arch)
    mesh = _mesh(mesh_kind)
    ctx = _ctx(cfg, mesh, profile)
    S, B, kind = SHAPES[shape_name]
    t0 = time.time()

    params_shape = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), jnp.bfloat16))
    p_shard = param_shardings(params_shape, mesh, cfg)
    batch = steps_mod.input_specs(cfg, shape_name)
    b_shard = batch_shardings(batch, mesh, ctx.dp_axes)

    if kind == "train":
        opt = adamw(for_arch(arch, 3e-4, 10_000))
        opt_shape = jax.eval_shape(opt.init, params_shape)
        from repro.distributed.sharding import opt_state_specs, param_specs
        o_specs = opt_state_specs(opt_shape, param_specs(params_shape, mesh))
        o_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs)
        step = steps_mod.make_train_step(ctx, opt)

        def train_nometrics(params, opt_state, batch):
            p2, o2, _, m = step(params, opt_state, None, batch)
            return p2, o2, m

        fn = jax.jit(
            train_nometrics,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        lowered = fn.lower(params_shape, opt_shape, batch)
    elif kind == "prefill":
        fwd = steps_mod.make_prefill(ctx)
        fn = jax.jit(fwd, in_shardings=(p_shard, b_shard))
        lowered = fn.lower(params_shape, batch)
    else:  # decode
        dstep = steps_mod.make_decode_step(ctx)
        cache_shard = b_shard["caches"]
        if cfg.enc_dec:
            fn = jax.jit(dstep, in_shardings=(
                p_shard, b_shard["tokens"], b_shard["cur_pos"], cache_shard,
                b_shard["cross_kvs"]),
                out_shardings=(None, cache_shard),
                donate_argnums=(3,))
            lowered = fn.lower(params_shape, batch["tokens"], batch["cur_pos"],
                               batch["caches"], batch["cross_kvs"])
        else:
            fn = jax.jit(dstep, in_shardings=(
                p_shard, b_shard["tokens"], b_shard["cur_pos"], cache_shard),
                out_shardings=(None, cache_shard),
                donate_argnums=(3,))
            lowered = fn.lower(params_shape, batch["tokens"], batch["cur_pos"],
                               batch["caches"])

    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    ca = cost_analysis(compiled)
    hlo = compiled.as_text()
    n_coll = count_collectives(hlo)
    cb_raw = collective_bytes(hlo)
    trips = _trips(cfg)

    # trip-count correction via single-group probes
    try:
        if kind == "decode":
            layer = _decode_layer_cost(ctx, params_shape, batch)
        else:
            layer = _layer_cost(ctx, params_shape, batch, kind)
    except Exception as e:  # noqa: BLE001 — correction is best-effort
        layer = {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                 "error": f"{type(e).__name__}: {e}"}

    enc_trips = cfg.n_enc_layers if cfg.enc_dec and kind != "decode" else 0
    mult = trips - 1 + enc_trips  # encoder bodies approximated by the decoder probe
    flops = float(ca.get("flops", 0.0)) + mult * layer["flops"]
    bytes_ = float(ca.get("bytes accessed", 0.0)) + mult * layer["bytes"]
    coll = cb_raw + mult * layer["collective_bytes"]

    n_tok = S * B
    N = cfg.active_param_count()
    if kind == "train":
        model_flops = 6 * N * n_tok
    elif kind == "prefill":
        model_flops = 2 * N * n_tok
    else:
        model_flops = 2 * N * B  # one token per sequence

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": _n_chips(mesh), "kind": kind,
        "seq_len": S, "batch": B,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "hlo_flops_raw": float(ca.get("flops", 0.0)),
        "hlo_bytes_raw": float(ca.get("bytes accessed", 0.0)),
        "layer_probe": layer,
        "trips": trips,
        "hlo_flops": flops,
        "hlo_bytes": bytes_,
        "collective_bytes": coll,
        "collective_counts": n_coll,
        "model_flops": float(model_flops),
        "compile_s": round(time.time() - t0, 1),
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {mesh_kind}: "
              f"compile {rec['compile_s']}s, "
              f"flops {flops:.3e}, coll {coll:.3e} B, "
              f"collectives {n_coll}")
        print(f"  memory_analysis: {rec['memory']}")
    return rec


# -----------------------------------------------------------------------------
# Solver cells (the paper's workload on the production mesh)
# -----------------------------------------------------------------------------

def run_solver_cell(method: str, stencil: str, mesh_kind: str, *,
                    local_grid=(128, 128, 128), verbose=True) -> dict:
    from repro.api import SolverOptions, SolverSession, resolve_backend
    from repro.core.problems import make_problem

    mesh = _mesh(mesh_kind)
    opts = SolverOptions(f64=False)
    backend = resolve_backend(opts, mesh=mesh)
    gshape = tuple(local_grid[d] * backend.layout.axis_size(d)
                   for d in range(3))
    prob = make_problem(gshape, stencil, dtype=jnp.float32)
    t0 = time.time()
    sess = SolverSession(prob, method=method, options=opts, backend=backend)
    fn, layout = sess.step_fn()
    spec = layout.spec()
    sh = NamedSharding(mesh, spec)
    arr = jax.ShapeDtypeStruct(gshape, jnp.float32, sharding=sh)
    scal = jax.ShapeDtypeStruct((), jnp.float32)
    # the step state is method-dependent (the reduction-hiding variants
    # carry more recurrence vectors than the classic 4-slot layout)
    from repro.core.distributed import step_state_layout
    vec_names, scal_names = step_state_layout(method)
    args = [arr] * (1 + len(vec_names)) + [scal] * len(scal_names)
    lowered = jax.jit(fn).lower(*args)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    ca = cost_analysis(compiled)
    hlo = compiled.as_text()
    rec = {
        "method": method, "stencil": stencil, "mesh": mesh_kind,
        "chips": _n_chips(mesh), "global_grid": gshape,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        },
        "hlo_flops": float(ca.get("flops", 0.0)),
        "hlo_bytes": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes": float(collective_bytes(hlo)),
        "collective_counts": count_collectives(hlo),
        "compile_s": round(time.time() - t0, 1),
    }
    if verbose:
        print(f"[dryrun] hpcg-{method}-{stencil} × {mesh_kind}: "
              f"compile {rec['compile_s']}s, collectives "
              f"{rec['collective_counts']}, coll bytes {rec['collective_bytes']:.3e}")
    return rec


# -----------------------------------------------------------------------------
# CLI
# -----------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--solvers", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--profile", default="tp", choices=["tp", "fsdp"])
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    cells: list[tuple[str, str]] = []
    if args.all:
        for name, cfg in sorted(all_configs().items()):
            for shape in cfg.shapes():
                cells.append((name, shape))
    elif args.arch:
        cfg = get_config(args.arch)
        shapes = [args.shape] if args.shape else list(cfg.shapes())
        cells = [(args.arch, s) for s in shapes]

    for arch, shape in cells:
        for mk in meshes:
            tag = f"{arch}_{shape}_{mk}"
            if args.profile != "tp":
                tag += f"_{args.profile}"
            try:
                rec = run_cell(arch, shape, mk, profile=args.profile)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
            except Exception:
                failures.append(tag)
                traceback.print_exc()

    if args.solvers:
        # every registered method — the registry is the single source; new
        # MethodDefs show up here (and in the benchmarks) automatically
        from repro.api.registry import solver_names
        for method in solver_names():
            for stencil in ("7pt", "27pt"):
                for mk in meshes:
                    tag = f"hpcg-{method}-{stencil}_{mk}"
                    try:
                        rec = run_solver_cell(method, stencil, mk)
                        with open(os.path.join(args.out, tag + ".json"), "w") as f:
                            json.dump(rec, f, indent=1)
                    except Exception:
                        failures.append(tag)
                        traceback.print_exc()

    if failures:
        print(f"[dryrun] FAILURES ({len(failures)}): {failures}")
        raise SystemExit(1)
    print("[dryrun] all cells compiled OK")


if __name__ == "__main__":
    main()
