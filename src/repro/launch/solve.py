"""Distributed solver driver — the paper's workload end-to-end.

A thin client of ``repro.api``: backend resolution (local / 1-D paper-faithful
/ 2-D / 3-D shard_map), kernel choice (XLA vs Pallas), preconditioning and
timing all live in the facade; this module only parses flags.

PYTHONPATH=src python -m repro.launch.solve --method cg_nb --stencil 27pt \
    --grid 64 64 64

# preconditioned: pcg/pbicgstab take --precond (repro.precond registry);
# compare the iters/res_norm fields of the JSON result against the plain run
PYTHONPATH=src python -m repro.launch.solve --method pcg --precond chebyshev \
    --stencil 27pt --grid 64 64 64 --json
"""

from __future__ import annotations

import argparse
import json

import jax.numpy as jnp

from repro.api import (LAYOUTS, SolverOptions, SolverSession, precond_names,
                       solver_names)
from repro.configs.hpcg import SOLVER_CONFIGS
from repro.core.problems import enable_f64


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None, choices=sorted(SOLVER_CONFIGS),
                    help="named HPCG cell supplying method/stencil/tol/"
                         "maxiter defaults (explicit flags win)")
    ap.add_argument("--method", default=None, choices=solver_names())
    ap.add_argument("--stencil", default=None, choices=["7pt", "27pt"])
    ap.add_argument("--grid", type=int, nargs=3, default=[64, 64, 64])
    ap.add_argument("--tol", type=float, default=None)
    ap.add_argument("--maxiter", type=int, default=None)
    ap.add_argument("--layout", default="auto", choices=list(LAYOUTS),
                    help="auto = local on 1 device, else the paper-faithful "
                         "1-D z decomposition")
    ap.add_argument("--f64", action=argparse.BooleanOptionalAction,
                    default=True, help="double precision (--no-f64 for f32)")
    ap.add_argument("--pallas", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="use the Pallas stencil kernel for the local SpMV")
    ap.add_argument("--precond", default=None, choices=list(precond_names()),
                    help="preconditioner for pcg/pbicgstab (repro.precond): "
                         "jacobi | block_jacobi | ssor | chebyshev; "
                         "cuts iterations at the cost of extra local sweeps "
                         "but zero extra reductions")
    ap.add_argument("--json", action="store_true",
                    help="also print the result record as one JSON line")
    ap.add_argument("--batch", type=int, default=0,
                    help="also solve N random right-hand sides in one "
                         "compiled call (the serving path)")
    ap.add_argument("--repeats", type=int, default=1,
                    help="timed repetitions after the warm-up/compile call")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="append repro.obs/v1 trace records (solve lifecycle "
                         "spans) to PATH; equivalent to REPRO_TRACE=PATH")
    ap.add_argument("--telemetry", action="store_true",
                    help="carry the per-iteration scalar history through the "
                         "solve (SolverOptions.telemetry) and report the "
                         "convergence curve; off = bitwise-identical solve")
    ap.add_argument("--telemetry-buffer", type=int, default=None,
                    help="telemetry row cap (default "
                         "SolverOptions.telemetry_buffer)")
    args = ap.parse_args(argv)

    if args.trace:
        from repro.obs import trace as obs
        obs.enable(args.trace)

    cfg = SOLVER_CONFIGS[args.config] if args.config else None
    method = args.method or (cfg.method if cfg else "cg_nb")
    stencil = args.stencil or (cfg.stencil if cfg else "27pt")
    if args.f64:
        # process-global x64 is owned HERE, at the CLI entry point — the
        # facade refuses to flip it implicitly (see SolverOptions.f64)
        enable_f64()
    overrides = dict(f64=args.f64, layout=args.layout, pallas=args.pallas)
    if args.telemetry:
        overrides["telemetry"] = True
    if args.telemetry_buffer is not None:
        overrides["telemetry_buffer"] = args.telemetry_buffer
    if args.precond is not None:
        overrides["precond"] = args.precond
    if args.tol is not None:
        overrides["tol"] = args.tol
    if args.maxiter is not None:
        overrides["maxiter"] = args.maxiter
    opts = (cfg.to_options(**overrides) if cfg
            else SolverOptions(**overrides))
    sess = SolverSession(method=method, grid=tuple(args.grid),
                         stencil=stencil, options=opts)
    res, stats = sess.timed_solve(repeats=args.repeats, warmup=1)
    dt = stats["median"]

    err = float(jnp.max(jnp.abs(res.x - sess.problem.x_true())))
    print(f"[solve] {sess.describe()} "
          f"iters={int(res.iters)} res={float(res.res_norm):.3e} "
          f"err_inf={err:.3e} wall={dt:.2f}s")
    # iters + achieved residual ride along with the timing for EVERY method,
    # so preconditioned and plain runs are directly comparable from the JSON
    out = {"method": method, "stencil": stencil,
           "precond": sess.options.precond,
           "iters": int(res.iters), "res_norm": float(res.res_norm),
           "err": err, "wall_s": dt, "backend": sess.backend.describe()}
    if args.telemetry:
        from repro.obs.convergence import curve_record
        out["convergence"] = curve_record(res, method, scalars=True)
        print(f"[solve] telemetry: {out['convergence']['telemetry_rows']} "
              f"rows, scalars={sorted(out['convergence']['scalars'])}")

    if args.batch:
        import numpy as np
        rng = np.random.default_rng(0)
        bs = jnp.asarray(rng.standard_normal((args.batch, *args.grid)),
                         dtype=res.x.dtype)
        bres, bstats = sess.timed_solve_batched(bs, repeats=args.repeats)
        print(f"[solve] batched x{args.batch}: iters="
              f"{np.asarray(bres.iters).tolist()} wall={bstats['median']:.2f}s")
        out["batch_wall_s"] = bstats["median"]
        out["batch_iters"] = np.asarray(bres.iters).tolist()
        out["batch_res_norm"] = np.asarray(bres.res_norm).tolist()
    if args.json:
        print(json.dumps(out))
    return out


if __name__ == "__main__":
    main()
