"""Distributed solver driver — the paper's workload end-to-end.

Runs any of the seven methods on the HPCG system, decomposed over whatever
devices exist (paper-faithful 1-D z decomposition on a 1-D mesh, or the
2-D/3-D production layout), with optional Pallas kernels for the local
stencil.

PYTHONPATH=src python -m repro.launch.solve --method cg_nb --stencil 27pt \
    --grid 64 64 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.hpcg import SOLVER_CONFIGS
from repro.core.distributed import make_layout, solve_shardmap
from repro.core.problems import enable_f64, make_problem
from repro.core.solvers import SOLVERS, LocalOp
from repro.launch.mesh import make_mesh_for_devices, make_solver_mesh


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="cg_nb", choices=sorted(SOLVERS))
    ap.add_argument("--stencil", default="27pt", choices=["7pt", "27pt"])
    ap.add_argument("--grid", type=int, nargs=3, default=[64, 64, 64])
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--maxiter", type=int, default=600)
    ap.add_argument("--layout", default="1d", choices=["1d", "2d"],
                    help="1d = paper-faithful z-only decomposition")
    ap.add_argument("--f64", action="store_true", default=True)
    ap.add_argument("--pallas", action="store_true",
                    help="use the Pallas stencil kernel for the local SpMV")
    args = ap.parse_args(argv)

    if args.f64:
        enable_f64()
    prob = make_problem(tuple(args.grid), args.stencil)
    matvec_padded = None
    if args.pallas:
        from repro.kernels import ops
        matvec_padded = ops.make_matvec_padded(prob.stencil)

    n = len(jax.devices())
    if n == 1:
        A = LocalOp(prob.stencil, matvec_padded=matvec_padded)
        t0 = time.time()
        res = jax.jit(
            lambda b, x0: SOLVERS[args.method](
                A, b, x0, tol=args.tol, maxiter=args.maxiter, norm_ref=1.0)
        )(prob.b(), prob.x0())
        dt = time.time() - t0
    else:
        mesh = make_solver_mesh(n) if args.layout == "1d" else make_mesh_for_devices(n)
        fn, layout = solve_shardmap(
            prob, args.method, mesh, tol=args.tol, maxiter=args.maxiter,
            matvec_padded=matvec_padded)
        sh = NamedSharding(mesh, layout.spec())
        b = jax.device_put(prob.b(), sh)
        x0 = jax.device_put(prob.x0(), sh)
        t0 = time.time()
        res = jax.jit(fn)(b, x0)
        dt = time.time() - t0

    err = float(jnp.max(jnp.abs(res.x - prob.x_true())))
    print(f"[solve] {args.method}/{args.stencil} grid={tuple(args.grid)} "
          f"iters={int(res.iters)} res={float(res.res_norm):.3e} "
          f"err_inf={err:.3e} wall={dt:.2f}s devices={n}")
    return {"iters": int(res.iters), "res_norm": float(res.res_norm),
            "err": err, "wall_s": dt}


if __name__ == "__main__":
    main()
