"""Serving drivers behind one CLI: ``--mode solver`` (default ``lm``).

``lm``     — batched LM decode demo: prefill once, then autoregressive
             decode (CPU-scale demo of the decode_32k/long_500k dry-run
             cells).
``solver`` — the production solver service (``repro.serve``): replay a
             mixed-workload trace through continuous batching over the
             compiled-executable cache, print SLO metrics, optionally
             inject a preemption to exercise the WAL recovery path.

PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --reduced \
    --batch 4 --prompt-len 64 --gen 32

PYTHONPATH=src python -m repro.launch.serve --mode solver --scale 2 \
    --fail-at 3 --json
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

# enc-dec serving reuses the decoder path with precomputed cross-kv; the
# frontend stub provides source embeddings.


def _lm_main(args) -> dict:
    from repro.configs.base import get_config
    from repro.distributed.sharding import dp_axes_of
    from repro.launch.mesh import make_mesh_for_devices
    from repro.models import steps as steps_mod
    from repro.models.decode import caches_from_prefill, init_caches
    from repro.models.transformer import ModelCtx, init_params

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dtype = jnp.dtype(args.dtype)
    mesh = make_mesh_for_devices()
    ctx = ModelCtx(cfg=cfg, mesh=mesh, dp_axes=dp_axes_of(mesh),
                   dtype=dtype, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype)
    B, P = args.batch, args.prompt_len
    cache_len = P + args.gen

    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (B, P), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": prompt,
             "positions": jnp.broadcast_to(jnp.arange(P), (B, P))}
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(batch["positions"][None],
                                              (3, B, P))
    if cfg.enc_dec:
        T = max(P // steps_mod.SRC_FRACTION, 1)
        batch["src_embeds"] = jax.random.normal(key, (B, T, cfg.d_model), dtype)
        batch["src_positions"] = jnp.broadcast_to(jnp.arange(T), (B, T))

    # --- prefill ------------------------------------------------------------
    from repro.models.transformer import forward_hidden, logits_from_h
    t0 = time.time()
    h, extras = jax.jit(
        lambda p, b: forward_hidden(ctx, p, b, collect_kv=True)
    )(params, batch)
    logits = logits_from_h(ctx, params, h[:, -1:])
    if cfg.family in ("ssm", "hybrid"):
        # SSD state is rebuilt by replay for the demo (prefill-state plumbing
        # for hybrid archs is decode-from-scratch; see DESIGN.md §4)
        caches = init_caches(ctx, B, cache_len)
        cross = None
        tok = prompt[:, :1]
        dstep = jax.jit(steps_mod.make_decode_step(ctx))
        for i in range(P):
            logits, caches = dstep(params, prompt[:, i:i + 1],
                                   jnp.array(i, jnp.int32), caches)
    elif cfg.enc_dec:
        caches_built, cross = caches_from_prefill(ctx, extras["kvs"], cache_len)
        caches = caches_built
        # cross kv stacked per layer: (k, v) each (L, B, T, KV, hd)
        dstep = jax.jit(steps_mod.make_decode_step(ctx))
    else:
        caches = caches_from_prefill(ctx, extras["kvs"], cache_len)
        cross = None
        dstep = jax.jit(steps_mod.make_decode_step(ctx))
    t_prefill = time.time() - t0

    # --- decode loop ----------------------------------------------------------
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen):
        pos = jnp.array(P + i, jnp.int32)
        if cfg.enc_dec:
            logits, caches = dstep(params, tok, pos, caches, cross)
        else:
            logits, caches = dstep(params, tok, pos, caches)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    tps = B * args.gen / max(t_decode, 1e-9)
    print(f"[serve] arch={cfg.name} batch={B} prompt={P} gen={args.gen} "
          f"prefill {t_prefill:.2f}s decode {t_decode:.2f}s "
          f"({tps:.1f} tok/s)")
    print(f"[serve] sample continuation ids: {gen[0, :16].tolist()}")
    return {"tokens": gen, "tokens_per_s": tps}


def _solver_main(args) -> dict:
    from repro.core.problems import enable_f64
    from repro.runtime.monitor import FailureInjector
    from repro.serve import (MIXED_BUCKETS, SMOKE_BUCKETS, ServeConfig,
                             SolverService, generate_trace, replay)

    if args.trace:
        from repro.obs import trace as obs
        obs.enable(args.trace)   # equivalent: REPRO_TRACE=PATH
    enable_f64()   # the reference trace solves in the paper's f64
    cfg = ServeConfig(max_batch=args.max_batch,
                      cache_capacity=args.cache_capacity,
                      async_compile=not args.sync_compile,
                      recovery_dir=args.recovery_dir)
    injector = (FailureInjector(args.fail_at)
                if args.fail_at is not None else None)
    service = SolverService(cfg, injector=injector)
    recovered = service.recover()
    if recovered:
        print(f"[serve] recovered {len(recovered)} orphaned request(s) "
              f"from {cfg.recovery_dir}")
    buckets = SMOKE_BUCKETS if args.buckets == "smoke" else MIXED_BUCKETS
    trace = generate_trace(buckets, seed=args.seed, scale=args.scale)
    results = replay(service, trace)
    service.close()
    snap = service.snapshot()

    n_buckets = len({r.key() for r in trace})
    print(f"[serve] mode=solver: {len(results)}/{len(trace)} requests over "
          f"{n_buckets} buckets  max_batch={cfg.max_batch} "
          f"cache_capacity={cfg.cache_capacity}")
    print(f"[serve] qps={snap['qps']:.2f}  p50={snap['p50_s']*1e3:.0f}ms  "
          f"p95={snap['p95_s']*1e3:.0f}ms  p99={snap['p99_s']*1e3:.0f}ms  "
          f"preemptions={snap['preemptions']} requeued={snap['requeued']}")
    c = snap["cache"]
    print(f"[serve] cache: hits={c['hits']} misses={c['misses']} "
          f"evictions={c['evictions']} entries={c['entries']}")
    for b, st in c["per_bucket"].items():
        print(f"    {b}: compiles={st['misses']} "
              f"compile_s={st['compile_s']:.2f} batches={st['hits']}")
    out = {"mode": "solver", "requests": len(trace),
           "completed": len(results), "dropped": len(trace) - len(results),
           **{k: v for k, v in snap.items() if k != "t"}}
    if args.json:
        print(json.dumps(out))
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "solver"), default="lm",
                    help="lm = batched decode demo; solver = the repro.serve "
                         "solver service replaying a mixed trace")
    # -- lm mode ---------------------------------------------------------------
    ap.add_argument("--arch", default=None, help="(lm) model config name")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--dtype", default="float32")
    # -- solver mode -----------------------------------------------------------
    ap.add_argument("--scale", type=int, default=1,
                    help="(solver) trace size multiplier per bucket")
    ap.add_argument("--buckets", choices=("mixed", "smoke"), default="mixed",
                    help="(solver) reference mix to replay: mixed = the "
                         "acceptance trace, smoke = the tiny CI workload")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="(solver) append repro.obs/v1 records (serve "
                         "lifecycle spans + SLO events) to PATH; equivalent "
                         "to REPRO_TRACE=PATH")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="(solver) padded in-flight batch size per bucket")
    ap.add_argument("--cache-capacity", type=int, default=8,
                    help="(solver) LRU bound on resident executables")
    ap.add_argument("--sync-compile", action="store_true",
                    help="(solver) compile inline instead of a background "
                         "thread")
    ap.add_argument("--recovery-dir", default=None,
                    help="(solver) write-ahead journal dir (enables "
                         "preemption recovery)")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="(solver) inject a preemption at dispatch N "
                         "(exercises the recovery path)")
    ap.add_argument("--json", action="store_true",
                    help="(solver) also print the metrics record as JSON")
    args = ap.parse_args(argv)

    if args.mode == "solver":
        return _solver_main(args)
    if args.arch is None:
        ap.error("--arch is required for --mode lm")
    return _lm_main(args)


if __name__ == "__main__":
    main()
