"""Batched serving driver: prefill once, then autoregressive decode.

CPU-scale demo of the serve path the decode_32k/long_500k dry-run cells
lower at production scale.

PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --reduced \
    --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.distributed.sharding import dp_axes_of
from repro.launch.mesh import make_mesh_for_devices
from repro.models import steps as steps_mod
from repro.models.decode import caches_from_prefill, init_caches
from repro.models.transformer import ModelCtx, init_params

# enc-dec serving reuses the decoder path with precomputed cross-kv; the
# frontend stub provides source embeddings.


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dtype = jnp.dtype(args.dtype)
    mesh = make_mesh_for_devices()
    ctx = ModelCtx(cfg=cfg, mesh=mesh, dp_axes=dp_axes_of(mesh),
                   dtype=dtype, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype)
    B, P = args.batch, args.prompt_len
    cache_len = P + args.gen

    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (B, P), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": prompt,
             "positions": jnp.broadcast_to(jnp.arange(P), (B, P))}
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(batch["positions"][None],
                                              (3, B, P))
    if cfg.enc_dec:
        T = max(P // steps_mod.SRC_FRACTION, 1)
        batch["src_embeds"] = jax.random.normal(key, (B, T, cfg.d_model), dtype)
        batch["src_positions"] = jnp.broadcast_to(jnp.arange(T), (B, T))

    # --- prefill ------------------------------------------------------------
    from repro.models.transformer import forward_hidden, logits_from_h
    t0 = time.time()
    h, extras = jax.jit(
        lambda p, b: forward_hidden(ctx, p, b, collect_kv=True)
    )(params, batch)
    logits = logits_from_h(ctx, params, h[:, -1:])
    if cfg.family in ("ssm", "hybrid"):
        # SSD state is rebuilt by replay for the demo (prefill-state plumbing
        # for hybrid archs is decode-from-scratch; see DESIGN.md §4)
        caches = init_caches(ctx, B, cache_len)
        cross = None
        tok = prompt[:, :1]
        dstep = jax.jit(steps_mod.make_decode_step(ctx))
        for i in range(P):
            logits, caches = dstep(params, prompt[:, i:i + 1],
                                   jnp.array(i, jnp.int32), caches)
    elif cfg.enc_dec:
        caches_built, cross = caches_from_prefill(ctx, extras["kvs"], cache_len)
        caches = caches_built
        # cross kv stacked per layer: (k, v) each (L, B, T, KV, hd)
        dstep = jax.jit(steps_mod.make_decode_step(ctx))
    else:
        caches = caches_from_prefill(ctx, extras["kvs"], cache_len)
        cross = None
        dstep = jax.jit(steps_mod.make_decode_step(ctx))
    t_prefill = time.time() - t0

    # --- decode loop ----------------------------------------------------------
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen):
        pos = jnp.array(P + i, jnp.int32)
        if cfg.enc_dec:
            logits, caches = dstep(params, tok, pos, caches, cross)
        else:
            logits, caches = dstep(params, tok, pos, caches)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    tps = B * args.gen / max(t_decode, 1e-9)
    print(f"[serve] arch={cfg.name} batch={B} prompt={P} gen={args.gen} "
          f"prefill {t_prefill:.2f}s decode {t_decode:.2f}s "
          f"({tps:.1f} tok/s)")
    print(f"[serve] sample continuation ids: {gen[0, :16].tolist()}")
    return {"tokens": gen, "tokens_per_s": tps}


if __name__ == "__main__":
    main()
