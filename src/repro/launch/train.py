"""Fault-tolerant training driver.

Wires every substrate layer together: sharded init -> deterministic data
pipeline -> jit'd train step (optionally with int8+error-feedback gradient
compression) -> heartbeat/straggler monitor -> async checkpointing ->
restart/resume (incl. elastic restore onto a different mesh).

Examples
--------
# tiny CPU run of the reduced internlm2 config with checkpointing:
PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b --reduced \
    --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

# simulate a preemption at step 10, then resume to completion:
... --fail-at 10; rerun the same command to resume from the checkpoint.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.pipeline import PipelineConfig, Prefetcher, SyntheticSource, batches
from repro.distributed.compression import init_error_feedback, make_ef_int8_transform
from repro.distributed.sharding import (
    batch_shardings,
    dp_axes_of,
    param_shardings,
)
from repro.launch.mesh import make_mesh_for_devices
from repro.models import steps as steps_mod
from repro.models.transformer import ModelCtx, init_params
from repro.optim.adamw import adamw
from repro.optim.schedules import for_arch
from repro.runtime import checkpoint as ckpt
from repro.runtime.monitor import FailureInjector, Heartbeat, SimulatedFailure


def build_state(cfg, ctx, mesh, opt, dtype):
    params = init_params(cfg, jax.random.PRNGKey(0), dtype)
    p_shard = param_shardings(params, mesh, cfg)
    params = jax.tree.map(jax.device_put, params, p_shard)
    opt_state = opt.init(params)
    return params, opt_state, p_shard


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--compress", action="store_true",
                    help="int8 + error-feedback gradient compression")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dtype = jnp.dtype(args.dtype)
    mesh = make_mesh_for_devices()
    ctx = ModelCtx(cfg=cfg, mesh=mesh, dp_axes=dp_axes_of(mesh),
                   tp_axis="model", dtype=dtype, remat=True)
    opt = adamw(for_arch(cfg.name, args.lr, args.steps))
    grad_transform = make_ef_int8_transform() if args.compress else None
    step_fn = jax.jit(steps_mod.make_train_step(ctx, opt, grad_transform,
                                                accum=args.accum))

    params, opt_state, p_shard = build_state(cfg, ctx, mesh, opt, dtype)
    extra = init_error_feedback(params) if args.compress else None
    start_step = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start_step = ckpt.restore(
            (params, opt_state), args.ckpt_dir,
            shardings=(p_shard, None))
        print(f"[train] resumed from step {start_step}")

    n_dp = int(np.prod([mesh.shape[a] for a in dp_axes_of(mesh)]))
    pipe_cfg = PipelineConfig(
        batch_size=args.batch, seq_len=args.seq, n_shards=n_dp, shard=0,
        seed=args.seed, mrope=cfg.mrope, frontend=cfg.frontend,
        d_model=cfg.d_model, enc_dec=cfg.enc_dec,
        src_fraction=steps_mod.SRC_FRACTION)
    source = SyntheticSource(cfg.vocab_size, args.seed)
    data = Prefetcher(batches(source, pipe_cfg, start_step))

    hb = Heartbeat()
    injector = FailureInjector(args.fail_at)
    b_shard = None
    losses = []
    t0 = time.time()
    try:
        for step in range(start_step, args.steps):
            host_batch = next(data)
            if cfg.mrope and "positions" in host_batch:
                pass
            if b_shard is None:
                b_shard = batch_shardings(
                    jax.tree.map(jnp.asarray, host_batch), mesh)
            batch = jax.tree.map(
                lambda x, s: jax.device_put(jnp.asarray(x), s),
                host_batch, b_shard)
            params, opt_state, extra, metrics = step_fn(
                params, opt_state, extra, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            report = hb.tick()
            if report.get("straggler"):
                print(f"[monitor] step {step}: straggler suspected "
                      f"({report['step_time']:.2f}s vs median "
                      f"{report['median']:.2f}s)")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                ckpt.save((params, opt_state), args.ckpt_dir, step + 1,
                          background=False)
            injector.maybe_fail(step)
            print(f"[train] step {step} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
    except SimulatedFailure as e:
        print(f"[train] {e} — state is checkpointed; rerun to resume")
        data.close()
        return {"failed_at": args.fail_at, "losses": losses}
    data.close()
    dt = time.time() - t0
    print(f"[train] done: {len(losses)} steps in {dt:.1f}s, "
          f"final loss {losses[-1]:.4f}")
    return {"losses": losses, "final_params": params}


if __name__ == "__main__":
    main()
