"""Gated MLP (SwiGLU / GeGLU)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def _act(name: str):
    return jax.nn.silu if name == "silu" else jax.nn.gelu


def init_mlp_params(key, d: int, ff: int, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w_in": (jax.random.normal(k1, (d, 2 * ff), jnp.float32) * d ** -0.5).astype(dtype),
        "w_out": (jax.random.normal(k2, (ff, d), jnp.float32) * ff ** -0.5).astype(dtype),
    }


def mlp_forward(p, cfg: ArchConfig, x, hint=lambda x, *t: x):
    ff = p["w_out"].shape[-2]
    h = hint(x @ p["w_in"], "model")
    gate, up = h[..., :ff], h[..., ff:]
    return (_act(cfg.act)(gate) * up) @ p["w_out"]
