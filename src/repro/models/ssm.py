"""Mamba-2 SSD (state-space duality) block — chunked, MXU-friendly.

The chunked algorithm turns the linear recurrence into per-chunk matmuls
(the "duality"): within a chunk an attention-like (Q×Q) product, across
chunks a small state carry — structurally the same blocking the solver layer
uses for plane sweeps (fresh within a block, carried state across blocks).

Tensor-parallel layout: the z/x projections and all head-indexed tensors are
sharded over the model axis (d_inner and n_heads are head-aligned: e.g.
mamba2-780m has 48 SSD heads over 16 shards = 3 heads/shard); the small
B/C/dt projections are replicated.  The projections are kept as SEPARATE
weights (not mamba's fused in_proj) precisely so each piece shards cleanly —
a fused (d, 2·d_inner+2N+nH) output cannot be split 16 ways on head
boundaries (DESIGN.md §5).

All SSD internals run in f32 (exp/cumsum of negative decays is
well-conditioned: every exponent is <= 0 by construction).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.common import rms_norm


def _no_hint(x, *tail):
    return x


def init_ssm_params(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    di, N, nH, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.conv_kernel
    ks = jax.random.split(key, 8)
    dt = jnp.exp(jax.random.uniform(ks[6], (nH,), jnp.float32) *
                 (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    s = d ** -0.5
    return {
        "w_z": (jax.random.normal(ks[0], (d, di), jnp.float32) * s).astype(dtype),
        "w_x": (jax.random.normal(ks[1], (d, di), jnp.float32) * s).astype(dtype),
        "w_B": (jax.random.normal(ks[2], (d, N), jnp.float32) * s).astype(dtype),
        "w_C": (jax.random.normal(ks[3], (d, N), jnp.float32) * s).astype(dtype),
        "w_dt": (jax.random.normal(ks[4], (d, nH), jnp.float32) * s).astype(dtype),
        "conv_x": (jax.random.normal(ks[5], (K, di), jnp.float32)
                   * K ** -0.5).astype(dtype),
        "conv_B": (jax.random.normal(ks[5], (K, N), jnp.float32)
                   * K ** -0.5).astype(dtype),
        "conv_C": (jax.random.normal(ks[5], (K, N), jnp.float32)
                   * K ** -0.5).astype(dtype),
        "conv_bx": jnp.zeros((di,), dtype),
        "conv_bB": jnp.zeros((N,), dtype),
        "conv_bC": jnp.zeros((N,), dtype),
        "A_log": jnp.log(jnp.arange(1, nH + 1, dtype=jnp.float32)),
        "D": jnp.ones((nH,), jnp.float32),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": (jax.random.normal(ks[7], (di, d), jnp.float32)
                     * di ** -0.5).astype(dtype),
    }


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype) -> dict:
    di, N, nH, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.conv_kernel
    P_ = cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, K - 1, di + 2 * N), dtype),
        "state": jnp.zeros((batch, nH, P_, N), jnp.float32),
    }


def _causal_conv(u, w, b):
    """Depthwise causal conv, kernel K (unrolled shifted adds — K is tiny)."""
    K = w.shape[0]
    S = u.shape[1]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(pad[:, k: k + S] * w[k] for k in range(K))
    return jax.nn.silu(y + b)


def _projections(p, cfg, x, hint):
    """Returns z, x_conv, B_conv, C_conv, dt (pre-softplus) + raw convs."""
    z = hint(x @ p["w_z"], "model")
    xr = hint(x @ p["w_x"], "model")
    Br = hint(x @ p["w_B"])
    Cr = hint(x @ p["w_C"])
    dt = hint(x @ p["w_dt"], "model")
    return z, xr, Br, Cr, dt


def ssm_forward(p, cfg: ArchConfig, x, *, return_cache: bool = False,
                hint=_no_hint):
    """Chunked SSD over the full sequence. x: (B, S, d)."""
    B, S, _ = x.shape
    di, N, nH = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P_, Q = cfg.ssm_head_dim, cfg.ssm_chunk
    if S % Q:
        Q = next(q for q in range(min(Q, S), 0, -1) if S % q == 0)

    z, xr, Br, Cr, dt = _projections(p, cfg, x, hint)
    xc = _causal_conv(xr, p["conv_x"], p["conv_bx"])
    Bc_ = _causal_conv(Br, p["conv_B"], p["conv_bB"])
    Cc_ = _causal_conv(Cr, p["conv_C"], p["conv_bC"])
    xs = xc.reshape(B, S, nH, P_).astype(jnp.float32)
    Bm = Bc_.astype(jnp.float32)                              # (B,S,N) G=1
    Cm = Cc_.astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,nH)
    A = -jnp.exp(p["A_log"])                                  # (nH,)

    nc = S // Q
    xs_c = xs.reshape(B, nc, Q, nH, P_)
    B_c = Bm.reshape(B, nc, Q, N)
    C_c = Cm.reshape(B, nc, Q, N)
    dt_c = dt.reshape(B, nc, Q, nH)

    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_step(h, inp):
        xck, Bck, Cck, dtc = inp                  # (B,Q,...)
        a = dtc * A                               # (B,Q,nH) <= 0
        cum = jnp.cumsum(a, axis=1)
        CB = jnp.einsum("bin,bjn->bij", Cck, Bck)  # (B,Q,Q)
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,Q,Q,nH)
        M = jnp.where(tri[None, :, :, None], CB[..., None] * decay
                      * dtc[:, None, :, :], 0.0)
        y = jnp.einsum("bijh,bjhp->bihp", M, xck)
        y = y + jnp.einsum("bin,bhpn->bihp", Cck, h) * jnp.exp(cum)[..., None]
        a_sum = cum[:, -1]                        # (B,nH)
        carry_decay = jnp.exp(a_sum[:, None, :] - cum)            # (B,Q,nH)
        h_new = (jnp.exp(a_sum)[:, :, None, None] * h
                 + jnp.einsum("bjh,bjn,bjhp->bhpn",
                              carry_decay * dtc, Bck, xck))
        return h_new, y

    h0 = jnp.zeros((B, nH, P_, N), jnp.float32)
    h_fin, ys = lax.scan(
        chunk_step, h0,
        (xs_c.swapaxes(0, 1), B_c.swapaxes(0, 1),
         C_c.swapaxes(0, 1), dt_c.swapaxes(0, 1)),
    )
    y = ys.swapaxes(0, 1).reshape(B, S, nH, P_)
    y = y + p["D"][:, None] * xs
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_cache:
        K = cfg.conv_kernel
        raw = jnp.concatenate([xr, Br, Cr], axis=-1)
        pad = jnp.pad(raw, ((0, 0), (K - 1, 0), (0, 0)))
        conv_cache = pad[:, S: S + K - 1]
        return out, {"conv": conv_cache.astype(x.dtype), "state": h_fin}
    return out


def ssm_decode(p, cfg: ArchConfig, x, cache, hint=_no_hint):
    """One-token SSD step. x: (B, 1, d)."""
    B = x.shape[0]
    di, N, nH = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P_, K = cfg.ssm_head_dim, cfg.conv_kernel
    z, xr, Br, Cr, dt = _projections(p, cfg, x, hint)
    raw = jnp.concatenate([xr[:, 0], Br[:, 0], Cr[:, 0]], axis=-1)  # (B, ch)
    full = jnp.concatenate([cache["conv"], raw[:, None, :]], axis=1)  # (B,K,ch)

    def conv1(w, b, lo, hi):
        seg = full[:, :, lo:hi].astype(jnp.float32)
        return jax.nn.silu(jnp.einsum("bkc,kc->bc", seg,
                                      w.astype(jnp.float32)) + b)

    xc = conv1(p["conv_x"], p["conv_bx"].astype(jnp.float32), 0, di)
    Bm = conv1(p["conv_B"], p["conv_bB"].astype(jnp.float32), di, di + N)
    Cm = conv1(p["conv_C"], p["conv_bC"].astype(jnp.float32), di + N, di + 2 * N)
    xs = xc.reshape(B, nH, P_)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,nH)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dtv * A)                                        # (B,nH)
    h = (decay[:, :, None, None] * cache["state"]
         + jnp.einsum("bh,bn,bhp->bhpn", dtv, Bm, xs))
    y = jnp.einsum("bn,bhpn->bhp", Cm, h) + p["D"][:, None] * xs
    y = y.reshape(B, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z[:, 0]), p["norm"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    new_cache = {"conv": full[:, 1:].astype(cache["conv"].dtype), "state": h}
    return out, new_cache
