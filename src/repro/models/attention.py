"""GQA attention: RoPE/M-RoPE, QKV bias, QK-norm, softcap, sliding window,
unified full/rolling KV cache for prefill+decode.

Cache layout (per layer): ``k``/``v``: (B, C, KV, hd), ``pos``: (C,) int32 —
the absolute position held in each slot (-1 = empty).  ``C`` equals the max
sequence length for full attention or the sliding window for local layers;
decode writes slot ``pos % C``, which makes the same code path serve both.
RoPE is applied *before* caching, so rolling slots stay correct.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import NEG_INF, apply_mrope, apply_rope, rms_norm, softcap


def init_attn_params(key, cfg: ArchConfig, dtype) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, H * hd), jnp.float32) * scale).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, KV * hd), jnp.float32) * scale).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, KV * hd), jnp.float32) * scale).astype(dtype),
        "wo": (jax.random.normal(ks[3], (H * hd, d), jnp.float32) * (H * hd) ** -0.5).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def init_attn_cache(cfg: ArchConfig, batch: int, cache_len: int, window: int,
                    dtype) -> dict:
    C = min(cache_len, window) if window else cache_len
    KV, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, C, KV, hd), dtype),
        "v": jnp.zeros((batch, C, KV, hd), dtype),
        "pos": jnp.full((C,), -1, jnp.int32),
    }


def _no_hint(x, *tail):
    return x


def _project_qkv(p, cfg: ArchConfig, x, positions, hint=_no_hint,
                 q_heads_sharded: bool = True):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    # pin batch sharding (see distributed.sharding.make_hint): q head-sharded
    # over the tensor axis, k/v replicated over it (GQA repeat form).  Decode
    # passes q_heads_sharded=False: there the KV *cache* is sharded over the
    # tensor axis (cache-sequence-parallel attention) and a head-sharded q
    # would force GSPMD to all-gather the cache (~0.5 GiB/layer measured) —
    # replicating the (B,1,H,hd) q costs ~1 MiB instead.
    q = q.reshape(B, S, H, hd)
    q = hint(q, "model", None) if q_heads_sharded else hint(q)
    # MHA (KV == H): shard K/V on heads like Q — replicating them over the
    # tensor axis costs tp-times-redundant projections (measured: minicpm's
    # useful-compute 0.30 vs 0.6+ for GQA archs).  GQA keeps K/V replicated
    # (the repeat form, see _sdpa).
    kv_tail = ("model", None) if (KV == H and q_heads_sharded) else ()
    k = hint(k.reshape(B, S, KV, hd), *kv_tail)
    v = hint(v.reshape(B, S, KV, hd), *kv_tail)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(cfg: ArchConfig, q, k, v, mask, hint=_no_hint,
          kv_seq_sharded: bool = False):
    """q: (B,S,H,hd), k/v: (B,T,KV,hd), mask: broadcastable to (B,H,S,T).

    GQA is computed by repeating K/V to the full head count rather than
    reshaping Q into (KV, G, hd): the latter splits the tensor-sharded head
    dim fractionally (e.g. KV=8 over tp=16) and forces GSPMD to replicate the
    (S×T) logits — measured at ~34 GiB/device on the train_4k cells.  With
    the repeat form, Q stays head-sharded, K/V stay replicated over the
    tensor axis (their projections are small), and the logits shard by head.

    ``kv_seq_sharded`` is the decode path: the KV *cache*'s sequence dim is
    tensor-sharded and must STAY sharded through the repeat/einsum (left
    unconstrained, GSPMD re-shards the cache onto heads — a full 8 GiB
    gather per layer, measured) — the softmax then runs distributed over T
    (psum'd max/denominator, a few KB) and the output psums once.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    if kv_seq_sharded:
        k = hint(k, "model", None, None)
        v = hint(v, "model", None, None)
    logits = jnp.einsum("bshn,bthn->bhst", q, k).astype(jnp.float32)
    if kv_seq_sharded:
        logits = hint(logits, None, "model")
    logits *= hd ** -0.5
    if cfg.attn_softcap:
        logits = softcap(logits, cfg.attn_softcap)
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    if kv_seq_sharded:
        w = hint(w, None, "model")
    out = jnp.einsum("bhst,bthn->bshn", w, v)
    if kv_seq_sharded:
        out = hint(out)
    return out.reshape(B, S, H * hd)


#: sequences longer than this use the chunked online-softmax path
CHUNKED_ATTN_THRESHOLD = 2048


def _chunked_sdpa(cfg: ArchConfig, q, k, v, *, window: int,
                  n_q_chunks: int = 8, kv_chunk: int = 1024):
    """Flash-style causal attention: online softmax over KV blocks.

    Never materialises the (S, S) logits (measured 12.9 GiB/device at the
    qwen2.5-32b train_4k cell).  The query dim is split into a static Python
    loop (so each q-chunk's KV scan has a *static* causal upper bound — no
    wasted upper-triangle block compute) and KV blocks stream through a
    ``lax.scan`` with running (max, denom, acc) in f32.  Sliding windows also
    bound the scan from below (gemma2 local layers touch only w/kv_chunk
    blocks).  On real TPU this is the splash-kernel slot; the pure-JAX form
    keeps the same blocking so the roofline accounting carries over.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    q_chunk = S // n_q_chunks
    while S % q_chunk:
        q_chunk -= 1
    kv_chunk = min(kv_chunk, S)
    while S % kv_chunk:
        kv_chunk -= 1
    scale = hd ** -0.5
    outs = []
    for qi in range(S // q_chunk):
        q0 = qi * q_chunk
        q_i = q[:, q0: q0 + q_chunk]                       # (B,bq,H,hd)
        hi = (q0 + q_chunk - 1) // kv_chunk                # last causal block
        lo = 0 if not window else max(0, (q0 - window + 1) // kv_chunk)
        nblk = hi + 1 - lo
        k_s = k[:, lo * kv_chunk: (hi + 1) * kv_chunk].reshape(
            B, nblk, kv_chunk, H, hd).swapaxes(0, 1)
        v_s = v[:, lo * kv_chunk: (hi + 1) * kv_chunk].reshape(
            B, nblk, kv_chunk, H, hd).swapaxes(0, 1)
        blk_ids = jnp.arange(lo, hi + 1)
        q_idx = q0 + jnp.arange(q_chunk)

        def body(carry, inp):
            m, l, acc = carry
            kj, k_b, v_b = inp
            s = jnp.einsum("bqhn,bkhn->bhqk", q_i, k_b).astype(jnp.float32)
            s = s * scale
            if cfg.attn_softcap:
                s = softcap(s, cfg.attn_softcap)
            k_idx = kj * kv_chunk + jnp.arange(kv_chunk)
            msk = k_idx[None, :] <= q_idx[:, None]
            if window:
                msk &= k_idx[None, :] > (q_idx[:, None] - window)
            s = jnp.where(msk[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p_ = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p_, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhn->bhqn", p_.astype(v_b.dtype), v_b).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (blk_ids, k_s, v_s))
        o = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        outs.append(o.swapaxes(1, 2))                      # (B,bq,H,hd)
    out = jnp.concatenate(outs, axis=1)
    return out.reshape(B, S, H * hd)


def attn_forward(p, cfg: ArchConfig, x, positions, *, window: int = 0,
                 hint=_no_hint):
    """Full-sequence causal attention (train / prefill)."""
    q, k, v = _project_qkv(p, cfg, x, positions, hint)
    S = x.shape[1]
    if S > CHUNKED_ATTN_THRESHOLD:
        out = hint(_chunked_sdpa(cfg, q, k, v, window=window), "model")
    else:
        q_pos = jnp.arange(S)[:, None]
        k_pos = jnp.arange(S)[None, :]
        mask = k_pos <= q_pos
        if window:
            mask &= k_pos > (q_pos - window)
        out = hint(_sdpa(cfg, q, k, v, mask[None, None]), "model")
    return out @ p["wo"], (k, v)


def prefill_cache(cfg: ArchConfig, k, v, *, cache_len: int, window: int, dtype):
    """Build the decode cache from prefill k/v (take the last C positions)."""
    B, S = k.shape[0], k.shape[1]
    C = min(cache_len, window) if window else cache_len
    take = min(S, C)
    ks = k[:, S - take:].astype(dtype)
    vs = v[:, S - take:].astype(dtype)
    pos_abs = jnp.arange(S - take, S, dtype=jnp.int32)
    cache = init_attn_cache(cfg, B, cache_len, window, dtype)
    slots = pos_abs % C
    cache["k"] = cache["k"].at[:, slots].set(ks)
    cache["v"] = cache["v"].at[:, slots].set(vs)
    cache["pos"] = cache["pos"].at[slots].set(pos_abs)
    return cache


def attn_decode(p, cfg: ArchConfig, x, cur_pos, cache, *, window: int = 0,
                hint=_no_hint):
    """One-token decode. x: (B, 1, d); cur_pos: () int32 absolute position."""
    B = x.shape[0]
    positions = jnp.full((B, 1), cur_pos, jnp.int32)
    if cfg.mrope:
        positions = jnp.broadcast_to(positions[None], (3, B, 1))
    q, k, v = _project_qkv(p, cfg, x, positions, hint, q_heads_sharded=False)
    C = cache["k"].shape[1]
    slot = cur_pos % C
    # where-mask update instead of dynamic_update_slice: a dynamic scatter
    # into the tensor-sharded cache dim makes GSPMD gather the whole cache
    # (measured ~6 GB/layer/device at decode_32k); the masked select is
    # fully local on every shard.
    sel = jnp.arange(C, dtype=jnp.int32) == slot.astype(jnp.int32)
    k_cache = jnp.where(sel[None, :, None, None],
                        k.astype(cache["k"].dtype), cache["k"])
    v_cache = jnp.where(sel[None, :, None, None],
                        v.astype(cache["v"].dtype), cache["v"])
    pos_arr = jnp.where(sel, cur_pos.astype(jnp.int32), cache["pos"])
    valid = (pos_arr >= 0) & (pos_arr <= cur_pos)
    if window:
        valid &= pos_arr > (cur_pos - window)
    mask = valid[None, None, None, :]  # (1,1,1,C) -> broadcast (B,H,1,C)
    out = _sdpa(cfg, q, k_cache, v_cache, mask, hint, kv_seq_sharded=True)
    new_cache = {"k": hint(k_cache, "model", None, None),
                 "v": hint(v_cache, "model", None, None),
                 "pos": pos_arr}
    return out @ p["wo"], new_cache


# --- cross attention (enc-dec) -----------------------------------------------

def init_cross_params(key, cfg: ArchConfig, dtype) -> dict:
    return init_attn_params(key, cfg, dtype)


def cross_forward(p, cfg: ArchConfig, x, enc_kv):
    """x: (B,S,d); enc_kv: (k, v) each (B,T,KV,hd) precomputed from encoder."""
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, S, H, hd)
    k, v = enc_kv
    out = _sdpa(cfg, q, k, v, jnp.ones((1, 1, 1, 1), bool))
    return out @ p["wo"]


def cross_kv(p, cfg: ArchConfig, enc_out):
    B, T, _ = enc_out.shape
    KV, hd = cfg.n_kv_heads, cfg.hd
    k = enc_out @ p["wk"]
    v = enc_out @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return k.reshape(B, T, KV, hd), v.reshape(B, T, KV, hd)
