"""Step factories (train / prefill / decode) + per-shape input specs.

``input_specs(cfg, shape_name)`` is the dry-run contract: it returns
ShapeDtypeStruct stand-ins for every input of the step function that the
shape cell lowers — weak-type-correct, shardable, no device allocation.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ArchConfig
from repro.models import decode as decode_mod
from repro.models.transformer import (
    ModelCtx,
    forward,
    forward_hidden,
    logits_from_h,
)
from repro.optim.adamw import AdamW

AUX_WEIGHT = 0.01          # MoE load-balance loss weight
SRC_FRACTION = 4           # enc-dec: source frames = seq_len / 4 (audio stub)


# =============================================================================
# Loss
# =============================================================================

def _ce_chunk_size(S: int, target: int = 512) -> int:
    c = min(target, S)
    while S % c:
        c -= 1
    return c


def lm_loss(ctx: ModelCtx, params, batch):
    """Vocab-parallel, sequence-chunked cross entropy.

    Two measured memory cliffs avoided here (EXPERIMENTS.md §Perf):
      1. ``take_along_axis`` + ``logsumexp`` make GSPMD all-gather the (T, V)
         f32 logits per device (~21 GiB at the train_4k cells).  Instead the
         label logit is a bool-mask select over the sharded vocab dim and
         logsumexp is explicit max/sum reductions (local partials + psum).
      2. Even sharded, the f32 logits pipeline is ~12 GiB live.  The head is
         therefore re-applied per sequence chunk under ``jax.checkpoint``
         inside a scan: live logits are (B, 512, V/tp) and the backward
         recomputes them chunk by chunk.
    """
    h, extras = forward_hidden(ctx, params, batch)
    from repro.distributed.sharding import make_hint
    h = make_hint(ctx.mesh, ctx.dp_axes)(h)   # gather S before chunk reshape
    tgt = batch["targets"]
    mask = batch.get("loss_mask", jnp.ones_like(tgt, jnp.float32))
    B, S, d = h.shape
    C = _ce_chunk_size(S)
    nc = S // C
    h_c = h.reshape(B, nc, C, d).swapaxes(0, 1)          # (nc, B, C, d)
    tgt_c = tgt.reshape(B, nc, C).swapaxes(0, 1)
    mask_c = mask.reshape(B, nc, C).swapaxes(0, 1)

    def chunk(carry, inp):
        h_i, tgt_i, mask_i = inp
        logits = logits_from_h(ctx, params, h_i)
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
        lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
        iota_v = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        ll = jnp.sum(jnp.where(iota_v == tgt_i[..., None], logits, 0.0), axis=-1)
        return carry + jnp.sum((lse - ll) * mask_i), None

    total, _ = jax.lax.scan(jax.checkpoint(chunk), jnp.zeros((), jnp.float32),
                            (h_c, tgt_c, mask_c))
    ce = total / jnp.maximum(jnp.sum(mask), 1.0)
    aux = extras["aux"]
    loss = ce + AUX_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


def _split_microbatches(batch, accum: int):
    """Reshape every batch leaf to (accum, B/accum, ...) on its batch dim
    (dim 1 for M-RoPE positions (3, B, S), dim 0 otherwise)."""

    def split(path, x):
        name = ".".join(str(getattr(p, "key", p)) for p in path)
        axis = 1 if ("positions" in name and x.ndim == 3) else 0
        b = x.shape[axis]
        assert b % accum == 0, (name, b, accum)
        new = (x.shape[:axis] + (accum, b // accum) + x.shape[axis + 1:])
        x = x.reshape(new)
        return jnp.moveaxis(x, axis, 0)

    return jax.tree_util.tree_map_with_path(split, batch)


def make_train_step(ctx: ModelCtx, opt: AdamW, grad_transform=None,
                    accum: int = 1):
    """Returns ``step(params, opt_state, extra_state, batch) -> (...)``.

    ``grad_transform`` is the hook used by gradient compression (error
    feedback state rides in ``extra_state``).  ``accum > 1`` splits the batch
    into gradient-accumulation microbatches (f32 accumulator, one optimizer
    update) — the memory remedy for the ~400B MoE train cells
    (EXPERIMENTS.md §Dry-run): peak activation memory scales with B/accum.
    """

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: lm_loss(ctx, p, batch), has_aux=True)(params)

    def step(params, opt_state, extra_state, batch):
        if accum == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            micro = _split_microbatches(batch, accum)

            def body(carry, mb):
                gsum, lsum = carry
                (l, _), g = grads_of(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (gsum, lsum), _ = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)),
                                           micro)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}
        if grad_transform is not None:
            grads, extra_state = grad_transform(grads, extra_state)
        params, opt_state, om = opt.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, extra_state, metrics

    return step


def make_forward(ctx: ModelCtx):
    def fwd(params, batch):
        logits, _ = forward(ctx, params, batch)
        return logits

    return fwd


def make_prefill(ctx: ModelCtx):
    """Prefill: last-position logits + the per-layer k/v needed for decode.

    Full-sequence logits would cost (B, S, V) f32 for one useful row —
    prefill serves sampling, so only position S-1 reaches the head.
    """

    def fwd(params, batch):
        h, extras = forward_hidden(ctx, params, batch, collect_kv=True)
        logits = logits_from_h(ctx, params, h[:, -1:])
        return logits, extras["kvs"]

    return fwd


def make_decode_step(ctx: ModelCtx):
    cfg = ctx.cfg

    if cfg.enc_dec:
        def step(params, tokens, cur_pos, caches, cross_kvs):
            return decode_mod.decode_step(ctx, params, tokens, cur_pos, caches,
                                          cross_kvs=cross_kvs)
        return step

    def step(params, tokens, cur_pos, caches):
        return decode_mod.decode_step(ctx, params, tokens, cur_pos, caches)

    return step


# =============================================================================
# Input specs (dry-run contract) and synthetic batches (smoke/examples)
# =============================================================================

def _batch_shapes(cfg: ArchConfig, shape_name: str,
                  override: tuple[int, int] | None = None,
                  dtype=jnp.bfloat16) -> dict[str, Any]:
    """Abstract input shapes for the *step function* of this shape cell.

    ``override=(S, B)`` shrinks the cell for CPU smoke tests.
    """
    S, B, kind = SHAPES[shape_name]
    if override is not None:
        S, B = override
    i32, bf16 = jnp.int32, dtype
    d = cfg.d_model

    def tok(shape):
        return jax.ShapeDtypeStruct(shape, i32)

    if kind in ("train", "prefill"):
        batch: dict[str, Any] = {}
        if cfg.frontend == "none":
            batch["tokens"] = tok((B, S))
        else:
            batch["embeds"] = jax.ShapeDtypeStruct((B, S, d), bf16)
        if cfg.mrope:
            batch["positions"] = tok((3, B, S))
        else:
            batch["positions"] = tok((B, S))
        if cfg.enc_dec:
            T = S // SRC_FRACTION
            batch = {
                "tokens": tok((B, S)),
                "positions": tok((B, S)),
                "src_embeds": jax.ShapeDtypeStruct((B, T, d), bf16),
                "src_positions": tok((B, T)),
            }
        if kind == "train":
            batch["targets"] = tok((B, S))
        return batch

    # decode: one new token against a cache of S positions
    batch = {"tokens": tok((B, 1)), "cur_pos": jax.ShapeDtypeStruct((), i32)}
    ctx = ModelCtx(cfg=cfg, dtype=dtype)
    caches = jax.eval_shape(
        lambda: decode_mod.init_caches(ctx, B, S))
    batch["caches"] = caches
    if cfg.enc_dec:
        T = S // SRC_FRACTION
        KV, hd = cfg.n_kv_heads, cfg.hd
        kv = jax.ShapeDtypeStruct((cfg.n_layers, B, T, KV, hd), bf16)
        batch["cross_kvs"] = (kv, kv)
    return batch


def input_specs(cfg: ArchConfig, shape_name: str,
                override: tuple[int, int] | None = None,
                dtype=jnp.bfloat16) -> dict[str, Any]:
    return _batch_shapes(cfg, shape_name, override, dtype)


def synthetic_batch(cfg: ArchConfig, shape_name: str, key=None,
                    override: tuple[int, int] | None = None,
                    dtype=jnp.bfloat16) -> dict[str, Any]:
    """Concrete random inputs with the spec's structure (smoke tests).

    Intended for REDUCED configs — full configs go through the dry-run only.
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    spec = _batch_shapes(cfg, shape_name, override, dtype)
    S = override[0] if override else SHAPES[shape_name][0]

    def realise(path, s):
        name = ".".join(str(getattr(p, "key", p)) for p in path)
        if name.endswith(".pos"):          # cache slot positions: full cache
            C = s.shape[-1]
            return jnp.broadcast_to(jnp.arange(C, dtype=s.dtype), s.shape)
        if "positions" in name:
            pos = jnp.arange(s.shape[-1], dtype=s.dtype)
            return jnp.broadcast_to(pos, s.shape)
        if "cur_pos" in name:
            return jnp.array(S, s.dtype)   # next position after the cache
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jax.random.randint(key, s.shape, 0, cfg.vocab_size, s.dtype)
        return (jax.random.normal(key, s.shape, jnp.float32) * 0.02).astype(s.dtype)

    return jax.tree_util.tree_map_with_path(realise, spec)
