"""Mixture-of-Experts with explicit expert parallelism via shard_map.

TPU adaptation of the paper's over-decomposition idea applied to MoE: the
classic GShard einsum dispatch materialises a (tokens × experts × capacity)
tensor — at this repo's shapes that is >100 GB per device, a non-starter.
Instead each model-axis shard owns ``E/tp`` experts and dispatches locally:

  1. route on the (model-replicated) token block: top-k over E experts,
  2. sort token-expert assignments, rank within expert (capacity C drop),
  3. gather into (E_local, C, d), two grouped einsums, weighted scatter-add,
  4. ONE psum over the model axis combines expert partials + the shared
     expert's tensor-parallel partial — the same single-collective structure
     as the paper's fused MPI_Allreduce of scalar pairs, at tensor scale.

The router compute (step 1-2) is independent of the expert weights and sits
*before* the psum in the dependence graph — the overlap property CG-NB gives
its reductions (DESIGN.md §4).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.compat import pvary, shard_map
from repro.models.mlp import _act


def init_moe_params(key, cfg: ArchConfig, dtype) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * d ** -0.5
                   ).astype(jnp.float32),
        "w_in": (jax.random.normal(ks[1], (E, d, 2 * f), jnp.float32) * d ** -0.5
                 ).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (E, f, d), jnp.float32) * f ** -0.5
                  ).astype(dtype),
    }
    if cfg.shared_expert:
        p["shared_in"] = (jax.random.normal(ks[3], (d, 2 * f), jnp.float32)
                          * d ** -0.5).astype(dtype)
        p["shared_out"] = (jax.random.normal(ks[4], (f, d), jnp.float32)
                           * f ** -0.5).astype(dtype)
    return p


def capacity(T: int, cfg: ArchConfig) -> int:
    c = int(math.ceil(T * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # >=8, rounded up to a multiple of 8


def moe_forward(p, cfg: ArchConfig, x, mesh: Mesh, dp_axes: tuple[str, ...],
                tp_axis: str):
    """x: (B, S, d) global (batch sharded over dp, replicated over tp)."""
    E, k_top, d, f = cfg.n_experts, cfg.top_k, cfg.d_model, cfg.d_ff
    tp = mesh.shape[tp_axis]
    assert E % tp == 0, (E, tp)
    E_local = E // tp

    #: at/below this many token-expert assignments the gather path wins:
    #: dense dispatch reads EVERY resident expert's weights regardless of
    #: routing (measured: llama4 decode_32k reads ~2 GB/layer/device for 8
    #: tokens), while gathering the routed experts' weights costs
    #: assignments × one expert slice.
    GATHER_MAX_ASSIGNMENTS = 64

    def gather_fn(router_w, w_in, w_out, shared, x_loc):
        B, S, _ = x_loc.shape
        T = B * S
        xt = x_loc.reshape(T, d)
        logits = (xt.astype(jnp.float32) @ router_w)
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = lax.top_k(probs, k_top)                    # (T, k)
        w = w / jnp.sum(w, axis=-1, keepdims=True)
        E_local = w_in.shape[0]
        shard = lax.axis_index(tp_axis)
        flat_e = idx.reshape(-1)
        flat_t = jnp.arange(T * k_top) // k_top
        flat_w = w.reshape(-1)
        le = flat_e - shard * E_local
        mine = (le >= 0) & (le < E_local)

        def body(y, inp):
            t, e_loc, ok, wgt = inp
            wi = w_in[jnp.clip(e_loc, 0, E_local - 1)]       # (d, 2f)
            h = xt[t] @ wi
            h = _act(cfg.act)(h[:f]) * h[f:]
            o = (h @ w_out[jnp.clip(e_loc, 0, E_local - 1)]) * wgt.astype(
                xt.dtype)
            return y.at[t].add(jnp.where(ok, o, 0)), None

        y0 = jnp.zeros((T, d), xt.dtype)
        # match the scan carry's varying-manual-axes to the body output
        y0 = pvary(y0, tuple(dp_axes) + (tp_axis,))
        out, _ = lax.scan(body, y0, (flat_t, le, mine, flat_w))
        if cfg.shared_expert:
            sh_in, sh_out = shared
            f_loc = sh_out.shape[0]
            hs = xt @ sh_in
            out = out + (_act(cfg.act)(hs[:, :f_loc]) * hs[:, f_loc:]) @ sh_out
        out = lax.psum(out, tp_axis)
        me = lax.pmean(jnp.mean(probs, axis=0), dp_axes)
        ce = lax.pmean(
            jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * k_top),
            dp_axes)
        aux = E * jnp.sum(me * ce)
        return out.reshape(B, S, d).astype(x_loc.dtype), aux

    dp_total = 1
    for a in dp_axes:
        dp_total *= mesh.shape[a]

    def local_fn(router_w, w_in, w_out, shared, x_loc):
        B, S, _ = x_loc.shape
        T = B * S
        # gather wins ONLY when a shard sees fewer assignments than it owns
        # experts (measured: at decode_32k's B_loc·k ≈ E/tp the two paths
        # read the same weight bytes — EXPERIMENTS.md §Perf-3c, refuted).
        # Decided on the GLOBAL count: the paths differ in drop semantics
        # (gather never drops), so dp layouts must not flip the choice.
        if T * dp_total * k_top <= min(GATHER_MAX_ASSIGNMENTS, E // tp - 1):
            return gather_fn(router_w, w_in, w_out, shared, x_loc)
        # capacity and drop decisions must be dp-invariant: C from the GLOBAL
        # token count, ranks offset by earlier dp shards' per-expert loads —
        # otherwise distributed and single-device runs drop DIFFERENT
        # token-expert assignments and the losses diverge (the old per-shard
        # capacity(T_local) was off by the dp rounding AND re-ranked each
        # shard's tokens from zero).
        C = capacity(T * dp_total, cfg)
        xt = x_loc.reshape(T, d)
        # --- routing (replicated over tp; independent of expert weights) ----
        logits = (xt.astype(jnp.float32) @ router_w)
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = lax.top_k(probs, k_top)                    # (T, k)
        w = w / jnp.sum(w, axis=-1, keepdims=True)
        flat_e = idx.reshape(-1)                            # (T*k,)
        order = jnp.argsort(flat_e)
        se = flat_e[order]
        st = (jnp.arange(T * k_top) // k_top)[order]
        sw = w.reshape(-1)[order]
        starts = jnp.searchsorted(se, jnp.arange(E))
        rank = jnp.arange(T * k_top) - starts[se]           # local stable rank
        rank_g = rank
        if dp_total > 1:
            # global rank = local rank + assignments to the same expert on
            # dp shards owning EARLIER tokens (batch is laid out row-major
            # over dp_axes, matching all_gather's tuple order)
            counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
            all_counts = lax.all_gather(counts, dp_axes, axis=0)  # (dp, E)
            lin = jnp.int32(0)
            for a in dp_axes:
                lin = lin * mesh.shape[a] + lax.axis_index(a)
            before = jnp.arange(dp_total, dtype=jnp.int32) < lin
            offset = jnp.sum(jnp.where(before[:, None], all_counts, 0), axis=0)
            rank_g = rank + offset[se]
        # --- my experts ------------------------------------------------------
        shard = lax.axis_index(tp_axis)
        le = se - shard * E_local
        # drop on the GLOBAL rank (same set as a single-device run); slots
        # index by the LOCAL rank, so the dispatch buffers stay sized by
        # what this shard can actually fill (rank < min(C, T·k) always,
        # since rank <= rank_g < C and a shard has T·k assignments) — NOT
        # by the dp-independent global capacity
        Cs = min(C, T * k_top)
        valid = (le >= 0) & (le < E_local) & (rank_g < C)
        slot = jnp.where(valid, le * Cs + rank, E_local * Cs)  # OOB -> dropped
        table = jnp.full((E_local * Cs,), T, jnp.int32).at[slot].set(
            st.astype(jnp.int32), mode="drop")
        wtab = jnp.zeros((E_local * Cs,), jnp.float32).at[slot].set(
            sw, mode="drop")
        x_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)])
        xg = x_pad[table].reshape(E_local, Cs, d)
        h = jnp.einsum("ecd,edf->ecf", xg, w_in)
        gate, up = h[..., :f], h[..., f:]
        h = _act(cfg.act)(gate) * up
        y = jnp.einsum("ecf,efd->ecd", h, w_out)
        y = y * wtab.reshape(E_local, Cs, 1).astype(y.dtype)
        y_flat = jnp.zeros((T + 1, d), y.dtype).at[table].add(
            y.reshape(E_local * Cs, d))
        out = y_flat[:T]
        # --- shared expert: plain tensor-parallel MLP partial ----------------
        if cfg.shared_expert:
            sh_in, sh_out = shared
            f_loc = sh_out.shape[0]
            hs = xt @ sh_in
            out = out + (_act(cfg.act)(hs[:, :f_loc]) * hs[:, f_loc:]) @ sh_out
        out = lax.psum(out, tp_axis)                         # ONE collective
        # --- load-balance aux (Switch-style), replicated ---------------------
        # pmean the per-expert vectors BEFORE the bilinear product: the aux
        # is E·Σ_e me_e·ce_e over the GLOBAL batch; averaging per-shard
        # products instead is a different (dp-dependent) number
        me = lax.pmean(jnp.mean(probs, axis=0), dp_axes)     # (E,)
        ce = lax.pmean(
            jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * k_top),
            dp_axes)
        aux = E * jnp.sum(me * ce)
        return out.reshape(B, S, d).astype(x_loc.dtype), aux

    dp = P(dp_axes if len(dp_axes) > 1 else dp_axes[0])
    x_spec = P(*dp, None, None) if len(dp_axes) else P(None, None, None)
    shared_specs = (P(None, None), P(None, None))
    if cfg.shared_expert:
        shared_specs = (P(None, tp_axis), P(tp_axis, None))
        shared = (p["shared_in"], p["shared_out"])
    else:
        shared = (jnp.zeros((1, 2), x.dtype), jnp.zeros((1, 1), x.dtype))
    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(None, None),                    # router replicated
            P(tp_axis, None, None),           # experts sharded over tp
            P(tp_axis, None, None),
            shared_specs,
            x_spec,
        ),
        out_specs=(x_spec, P()),
    )
    return fn(p["router"], p["w_in"], p["w_out"], shared, x)
