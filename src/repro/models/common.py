"""Shared model components: norms, RoPE/M-RoPE, initialisers, masks."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
             offset: float = 0.0) -> jax.Array:
    """RMSNorm: variance in f32, application in the input dtype.

    Upcasting the whole tensor to f32 makes XLA hoist a full-precision copy
    of every saved residual out of the backward scan (measured 12 GiB/device,
    EXPERIMENTS.md §Perf) — only the reduction needs f32.
    """
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * r * (offset + scale.astype(jnp.float32)).astype(x.dtype)


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return logits
    return cap * jnp.tanh(logits / cap)


# -----------------------------------------------------------------------------
# Rotary embeddings
# -----------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, dtype=jnp.float32) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=dtype) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float, sections: tuple[int, ...]
) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    ``positions``: (3, B, S) — temporal/height/width position ids; the rotary
    half-dim is split into ``sections`` (e.g. (16, 24, 24) for head_dim 128),
    each section rotated by its own position stream.  For pure text the three
    streams are identical and M-RoPE reduces to RoPE.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    # per-section position selection
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=half
    )  # (half,) static
    pos = jnp.take(positions, sec_id, axis=0)          # (half, B, S)
    pos = jnp.moveaxis(pos, 0, -1)                     # (B, S, half)
    ang = pos.astype(jnp.float32) * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -----------------------------------------------------------------------------
# Masks
# -----------------------------------------------------------------------------

NEG_INF = -2.3819763e38  # as used by flax/maxtext for bf16-safe masking


def causal_mask(q_len: int, kv_len: int, *, q_offset=0,
                window: int = 0) -> jax.Array:
    """(q_len, kv_len) boolean mask; True = attend.

    ``q_offset`` is the absolute position of query 0 (decode: cache length).
    ``window`` > 0 restricts to a sliding window of that many positions.
    """
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    m = k_pos <= q_pos
    if window:
        m &= k_pos > (q_pos - window)
    return m


# -----------------------------------------------------------------------------
# Initialisation
# -----------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size, dtype) -> jax.Array:
    scale = 1.0 / jnp.sqrt(in_axis_size)
    return (jax.random.uniform(key, shape, jnp.float32, -1.0, 1.0) * scale).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
