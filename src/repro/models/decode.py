"""Autoregressive decode: per-layer KV/SSM caches, one-token steps.

Cache pytrees are stacked along the layer axis and scanned together with the
stacked params; heterogeneous layer schedules (gemma2 local/global, llama4
dense/moe super-layers) use grouped stacking so every scan leaf is uniform.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import make_hint
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import rms_norm
from repro.models.transformer import (
    ModelCtx,
    _maybe_post,
    embed_tokens,
    layer_kind,
    logits_from_h,
)


# =============================================================================
# Cache init
# =============================================================================

def _stack_attn_caches(cfg, n, batch, cache_len, window, dtype):
    one = attn_mod.init_attn_cache(cfg, batch, cache_len, window, dtype)
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n, *x.shape)), one)


def _stack_ssm_caches(cfg, n, batch, dtype):
    one = ssm_mod.init_ssm_cache(cfg, batch, dtype)
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n, *x.shape)), one)


def init_caches(ctx: ModelCtx, batch: int, cache_len: int) -> dict:
    cfg = ctx.cfg
    L, dt = cfg.n_layers, ctx.dtype
    kind = layer_kind(cfg)
    if kind == "ssm":
        return {"ssm": _stack_ssm_caches(cfg, L, batch, dt)}
    if cfg.family == "moe" and cfg.moe_every == 2:
        n = L // 2
        return {
            "dense": _stack_attn_caches(cfg, n, batch, cache_len, 0, dt),
            "moe": _stack_attn_caches(cfg, n, batch, cache_len, 0, dt),
        }
    if cfg.local_global:
        n = L // 2
        return {
            "even": _stack_attn_caches(cfg, n, batch, cache_len,
                                       cfg.sliding_window, dt),
            "odd": _stack_attn_caches(cfg, n, batch, cache_len, 0, dt),
        }
    caches = {"attn": _stack_attn_caches(cfg, L, batch, cache_len,
                                         cfg.sliding_window, dt)}
    if kind == "hybrid":
        caches["ssm"] = _stack_ssm_caches(cfg, L, batch, dt)
    return caches


# =============================================================================
# One-token layer step
# =============================================================================

def _decode_layer(ctx: ModelCtx, p, h, cur_pos, cache, *, window: int,
                  kind: str, cross_kv=None):
    cfg = ctx.cfg
    hint = make_hint(ctx.mesh, ctx.dp_axes)
    new_cache = {}
    if kind == "ssm":
        x = rms_norm(h, p["norm_attn"], cfg.norm_eps)
        y, new_ssm = ssm_mod.ssm_decode(p["ssm"], cfg, x, cache["ssm"], hint)
        return h + cfg.residual_scale * y, {"ssm": new_ssm}
    x = rms_norm(h, p["norm_attn"], cfg.norm_eps)
    a, new_attn = attn_mod.attn_decode(p["attn"], cfg, x, cur_pos,
                                       cache["attn"], window=window, hint=hint)
    new_cache["attn"] = new_attn
    if kind == "hybrid":
        s, new_ssm = ssm_mod.ssm_decode(p["ssm"], cfg, x, cache["ssm"], hint)
        a = 0.5 * (rms_norm(a, p["fuse_norm_attn"], cfg.norm_eps)
                   + rms_norm(s, p["fuse_norm_ssm"], cfg.norm_eps))
        new_cache["ssm"] = new_ssm
    h = h + cfg.residual_scale * _maybe_post(cfg, p, "post_norm_attn", a)
    if cross_kv is not None:
        c = attn_mod.cross_forward(
            p["cross"], cfg, rms_norm(h, p["norm_cross"], cfg.norm_eps), cross_kv)
        h = h + cfg.residual_scale * hint(c)
    x = rms_norm(h, p["norm_mlp"], cfg.norm_eps)
    if kind == "moe":
        m, _ = moe_mod.moe_forward(p["moe"], cfg, x, ctx.mesh, ctx.dp_axes,
                                   ctx.tp_axis)
    else:
        m = mlp_mod.mlp_forward(p["mlp"], cfg, x, hint)
    h = h + cfg.residual_scale * _maybe_post(cfg, p, "post_norm_mlp", m)
    return h, new_cache


# =============================================================================
# Full decode step
# =============================================================================

def decode_step(ctx: ModelCtx, params, tokens, cur_pos, caches,
                cross_kvs=None):
    """tokens: (B, 1); cur_pos: () int32. Returns (logits, new_caches)."""
    cfg = ctx.cfg
    h = embed_tokens(ctx, params, tokens)
    kind = layer_kind(cfg)

    if cfg.family == "moe" and cfg.moe_every == 2:
        def f(h, xs):
            p2, c2 = xs
            h, cd = _decode_layer(ctx, p2["dense"], h, cur_pos,
                                  {"attn": c2["dense"]}, window=0, kind="dense")
            h, cm = _decode_layer(ctx, p2["moe"], h, cur_pos,
                                  {"attn": c2["moe"]}, window=0, kind="moe")
            return h, {"dense": cd["attn"], "moe": cm["attn"]}
        h, new_caches = lax.scan(f, h, (params["layers"], caches))
    elif cfg.local_global:
        L = cfg.n_layers
        tree = jax.tree.map(
            lambda x: x.reshape(2, L // 2, *x.shape[1:]).swapaxes(0, 1),
            params["layers"])

        def f(h, xs):
            p2, c2 = xs
            p_even = jax.tree.map(lambda x: x[0], p2)
            p_odd = jax.tree.map(lambda x: x[1], p2)
            h, ce = _decode_layer(ctx, p_even, h, cur_pos,
                                  {"attn": c2["even"]},
                                  window=cfg.sliding_window, kind="dense")
            h, co = _decode_layer(ctx, p_odd, h, cur_pos,
                                  {"attn": c2["odd"]}, window=0, kind="dense")
            return h, {"even": ce["attn"], "odd": co["attn"]}
        h, new_caches = lax.scan(f, h, (tree, caches))
    elif cfg.enc_dec:
        def f(h, xs):
            p, c2, ckv = xs
            h, nc = _decode_layer(ctx, p, h, cur_pos, {"attn": c2["attn"]},
                                  window=0, kind="dense", cross_kv=ckv)
            return h, {"attn": nc["attn"]}
        h, new_caches = lax.scan(f, h, (params["layers"], caches, cross_kvs))
    else:
        window = cfg.sliding_window

        def f(h, xs):
            p, c = xs
            h, nc = _decode_layer(ctx, p, h, cur_pos, c, window=window,
                                  kind=kind)
            return h, nc
        h, new_caches = lax.scan(f, h, (params["layers"], caches))

    logits = logits_from_h(ctx, params, h)
    return logits, new_caches


# =============================================================================
# Prefill -> caches
# =============================================================================

def caches_from_prefill(ctx: ModelCtx, kvs, cache_len: int) -> dict:
    """Transform forward(collect_kv=True) stacked (k, v) into decode caches."""
    cfg = ctx.cfg
    dt = ctx.dtype

    def build(kv, window):
        k, v = kv  # (n, B, S, KV, hd)
        return jax.vmap(
            lambda kk, vv: attn_mod.prefill_cache(
                cfg, kk, vv, cache_len=cache_len, window=window, dtype=dt)
        )(k, v)

    if cfg.family == "moe" and cfg.moe_every == 2:
        kv0, kv1 = kvs
        return {"dense": build(kv0, 0), "moe": build(kv1, 0)}
    if cfg.local_global:
        kv0, kv1 = kvs
        return {"even": build(kv0, cfg.sliding_window), "odd": build(kv1, 0)}
    if cfg.enc_dec:
        kv_self, kv_cross = kvs
        return {"attn": build(kv_self, 0)}, kv_cross
    caches = {"attn": build(kvs, cfg.sliding_window)}
    return caches
