"""Model assembly for all 10 assigned architectures.

One parameterised stack covers: dense GQA transformers (minicpm, internlm2,
gemma2, qwen2.5), MoE (qwen3-moe every-layer, llama4 interleaved+shared),
VLM backbone (qwen2-vl, M-RoPE + embedding inputs), SSD (mamba2), hybrid
attn||SSM (hymba), and encoder-decoder (seamless-m4t, audio frontend stub).

Layers are scanned (stacked params) so the HLO stays one-layer-sized — the
dry-run multiplies per-layer cost by trip count explicitly (DESIGN.md §7).
Per-layer heterogeneity is handled by scanned flag arrays (gemma2
local/global) or super-layer grouping (llama4 dense+moe pairs).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.distributed.sharding import make_hint
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import embed_init, rms_norm, softcap


@dataclasses.dataclass(frozen=True)
class ModelCtx:
    """Everything the pure functions need besides params."""
    cfg: ArchConfig
    mesh: Mesh | None = None
    dp_axes: tuple[str, ...] = ()
    tp_axis: str = "model"
    dtype: Any = jnp.bfloat16
    remat: bool = True


# =============================================================================
# Init
# =============================================================================

def _init_layer(key, cfg: ArchConfig, dtype, *, kind: str) -> dict:
    """kind: dense | moe | ssm | hybrid | encoder | decoder_x (with cross)."""
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {"norm_attn": jnp.ones((cfg.d_model,), dtype)}
    if cfg.post_norm:
        p["post_norm_attn"] = jnp.ones((cfg.d_model,), dtype)
    if kind == "ssm":
        p["ssm"] = ssm_mod.init_ssm_params(ks[0], cfg, dtype)
        return p
    p["attn"] = attn_mod.init_attn_params(ks[0], cfg, dtype)
    if kind == "hybrid":
        p["ssm"] = ssm_mod.init_ssm_params(ks[1], cfg, dtype)
        p["fuse_norm_attn"] = jnp.ones((cfg.d_model,), dtype)
        p["fuse_norm_ssm"] = jnp.ones((cfg.d_model,), dtype)
    p["norm_mlp"] = jnp.ones((cfg.d_model,), dtype)
    if cfg.post_norm:
        p["post_norm_mlp"] = jnp.ones((cfg.d_model,), dtype)
    if kind == "moe":
        p["moe"] = moe_mod.init_moe_params(ks[2], cfg, dtype)
    else:
        ff = cfg.dense_ff or cfg.d_ff
        p["mlp"] = mlp_mod.init_mlp_params(ks[2], cfg.d_model, ff, dtype)
    if kind == "decoder_x":
        p["cross"] = attn_mod.init_cross_params(ks[3], cfg, dtype)
        p["norm_cross"] = jnp.ones((cfg.d_model,), dtype)
    return p


def layer_kind(cfg: ArchConfig) -> str:
    return {"ssm": "ssm", "hybrid": "hybrid", "moe": "moe"}.get(cfg.family, "dense")


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> dict:
    keys = jax.random.split(key, 6)
    params: dict[str, Any] = {
        "embed": embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(keys[1], (cfg.vocab_size, cfg.d_model), dtype)

    kind = layer_kind(cfg)
    if cfg.family == "moe" and cfg.moe_every == 2:
        n_super = cfg.n_layers // 2
        dk = jax.random.split(keys[2], n_super)
        mk = jax.random.split(keys[3], n_super)
        params["layers"] = {
            "dense": jax.vmap(lambda k: _init_layer(k, cfg, dtype, kind="dense"))(dk),
            "moe": jax.vmap(lambda k: _init_layer(k, cfg, dtype, kind="moe"))(mk),
        }
    elif cfg.enc_dec:
        ek = jax.random.split(keys[2], cfg.n_enc_layers)
        dk = jax.random.split(keys[3], cfg.n_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _init_layer(k, cfg, dtype, kind="dense"))(ek),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
        params["layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, dtype, kind="decoder_x"))(dk)
    else:
        lk = jax.random.split(keys[2], cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, dtype, kind=kind))(lk)
    return params


# =============================================================================
# Layer bodies (full-sequence mode)
# =============================================================================

def _maybe_post(cfg, p, name, y):
    return rms_norm(y, p[name], cfg.norm_eps) if cfg.post_norm else y


def _sp_hint(ctx: ModelCtx, S: int):
    """Sequence-parallel residual-stream constraint (Megatron-SP).

    Between layers the residual stream is sharded (dp, model, None) over
    (B, S, d): the per-layer all-reduce of the wo/w_out partials becomes an
    equal-traffic reduce-scatter + all-gather pair, and — the point — every
    saved activation of the backward scan shrinks by the tensor width
    (measured 16x on the stacked (L, B, S, d) saves; EXPERIMENTS.md §Perf).
    """
    hint = make_hint(ctx.mesh, ctx.dp_axes)
    if (ctx.mesh is None or ctx.mesh.devices.size == 1
            or ctx.tp_axis in ctx.dp_axes          # pure-FSDP profile: no SP
            or S % ctx.mesh.shape[ctx.tp_axis]):
        return hint, lambda t: t
    return hint, lambda t: hint(t, ctx.tp_axis, None)


def _layer_forward(ctx: ModelCtx, p, h, positions, *, window: int,
                   kind: str, enc_kv=None, causal=True):
    """One layer, full sequence. Returns new h (and optional (k, v))."""
    cfg = ctx.cfg
    hint, sp = _sp_hint(ctx, h.shape[1])
    kv = None
    if kind == "ssm":
        y = ssm_mod.ssm_forward(p["ssm"], cfg,
                                hint(rms_norm(h, p["norm_attn"], cfg.norm_eps)),
                                hint=hint)
        return sp(h + cfg.residual_scale * sp(y)), (None, 0.0)
    x = hint(rms_norm(h, p["norm_attn"], cfg.norm_eps))
    if causal:
        a, kv = attn_mod.attn_forward(p["attn"], cfg, x, positions,
                                      window=window, hint=hint)
    else:  # bidirectional encoder
        q, k, v = attn_mod._project_qkv(p["attn"], cfg, x, positions, hint)
        a = attn_mod._sdpa(cfg, q, k, v, jnp.ones((1, 1, 1, 1), bool))
        a = a @ p["attn"]["wo"]
    if kind == "hybrid":
        s = ssm_mod.ssm_forward(p["ssm"], cfg, x, hint=hint)
        a = 0.5 * (rms_norm(sp(a), p["fuse_norm_attn"], cfg.norm_eps)
                   + rms_norm(sp(s), p["fuse_norm_ssm"], cfg.norm_eps))
    h = sp(h + cfg.residual_scale * _maybe_post(cfg, p, "post_norm_attn", sp(a)))
    if enc_kv is not None:
        c = attn_mod.cross_forward(
            p["cross"], cfg, hint(rms_norm(h, p["norm_cross"], cfg.norm_eps)),
            enc_kv)
        h = sp(h + cfg.residual_scale * sp(c))
    x = hint(rms_norm(h, p["norm_mlp"], cfg.norm_eps))
    if kind == "moe":
        m, aux = moe_mod.moe_forward(p["moe"], cfg, x, ctx.mesh, ctx.dp_axes,
                                     ctx.tp_axis)
    else:
        m, aux = mlp_mod.mlp_forward(p["mlp"], cfg, x, hint), 0.0
    h = sp(h + cfg.residual_scale * _maybe_post(cfg, p, "post_norm_mlp", sp(m)))
    return h, (kv, aux)


def _window_flags(cfg: ArchConfig) -> list[int]:
    """Static per-layer sliding windows (gemma2 alternation, hymba all-SW)."""
    if cfg.local_global:
        return [cfg.sliding_window if (i % 2 == 0) else 0
                for i in range(cfg.n_layers)]
    return [cfg.sliding_window] * cfg.n_layers


def _scan_layers(ctx: ModelCtx, stacked, h, positions, *, kind, enc_kv=None,
                 collect_kv: bool = False):
    """Scan h through stacked layers; windows vary per layer -> grouped scans."""
    cfg = ctx.cfg
    windows = _window_flags(cfg) if kind not in ("ssm",) else [0] * cfg.n_layers
    aux_total = 0.0
    kv_all = []

    def body(window, collect):
        def f(h, p):
            h2, (kv, aux) = _layer_forward(ctx, p, h, positions, window=window,
                                           kind=kind, enc_kv=enc_kv)
            out = (kv, aux) if collect else (None, aux)
            return h2, out
        return jax.checkpoint(f) if ctx.remat else f

    if cfg.local_global:
        # alternate local/global: scan pairs (same param shapes, different masks)
        L = cfg.n_layers
        tree = jax.tree.map(lambda x: x.reshape(2, L // 2, *x.shape[1:]).swapaxes(0, 1),
                            stacked)

        def pair(h, p2):
            p_even = jax.tree.map(lambda x: x[0], p2)
            p_odd = jax.tree.map(lambda x: x[1], p2)
            h, (kv0, a0) = body(cfg.sliding_window, collect_kv)(h, p_even)
            h, (kv1, a1) = body(0, collect_kv)(h, p_odd)
            return h, ((kv0, kv1), a0 + a1)

        h, (kvs, auxs) = lax.scan(pair, h, tree)
        if collect_kv:
            kv_all = kvs
        aux_total = jnp.sum(auxs) if kind == "moe" else 0.0
        return h, kv_all, aux_total

    window = windows[0]
    h, (kvs, auxs) = lax.scan(body(window, collect_kv), h, stacked)
    if collect_kv:
        kv_all = kvs
    aux_total = jnp.sum(auxs) if kind == "moe" else 0.0
    return h, kv_all, aux_total


# =============================================================================
# Full-model forward (train / prefill)
# =============================================================================

def embed_tokens(ctx: ModelCtx, params, tokens):
    h = jnp.take(params["embed"], tokens, axis=0)
    return (h * ctx.cfg.embed_scale).astype(ctx.dtype)


def logits_from_h(ctx: ModelCtx, params, h):
    cfg = ctx.cfg
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", h, table) * cfg.logit_scale
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    if ctx.mesh is not None and ctx.mesh.devices.size > 1:
        from repro.distributed.sharding import make_hint
        tp = None if ctx.tp_axis in ctx.dp_axes else ctx.tp_axis
        logits = make_hint(ctx.mesh, ctx.dp_axes)(logits, tp)
    return logits


def forward_hidden(ctx: ModelCtx, params, batch, *, collect_kv: bool = False):
    """Full-sequence forward up to the final hidden states (pre-norm).

    batch: tokens (B,S) and/or embeds (B,S,d); positions; enc-dec adds
    src_embeds (B,T,d).  Returns (h, extras).
    """
    cfg = ctx.cfg
    if "embeds" in batch:
        h = (batch["embeds"] * cfg.embed_scale).astype(ctx.dtype)
    else:
        h = embed_tokens(ctx, params, batch["tokens"])
    _, sp = _sp_hint(ctx, h.shape[1])
    h = sp(h)
    positions = batch["positions"]

    if cfg.enc_dec:
        src = (batch["src_embeds"] * cfg.embed_scale).astype(ctx.dtype)
        src_pos = batch["src_positions"]
        enc_h, _, _ = _scan_layers_enc(ctx, params["encoder"]["layers"], src, src_pos)
        enc_out = rms_norm(enc_h, params["encoder"]["final_norm"], cfg.norm_eps)
        batch = dict(batch, enc_out=enc_out)

    if cfg.family == "moe" and cfg.moe_every == 2:
        h, kvs, aux = _scan_superlayers(ctx, params["layers"], h, positions,
                                        collect_kv=collect_kv)
    elif cfg.enc_dec:
        h, kvs, aux = _scan_decoder_x(ctx, params["layers"], h, positions,
                                      batch["enc_out"], collect_kv=collect_kv)
    else:
        h, kvs, aux = _scan_layers(ctx, params["layers"], h, positions,
                                   kind=layer_kind(cfg), collect_kv=collect_kv)
    extras = {"kvs": kvs, "aux": aux}
    if cfg.enc_dec:
        extras["enc_out"] = batch["enc_out"]
    return h, extras


def forward(ctx: ModelCtx, params, batch, *, collect_kv: bool = False):
    """Full-sequence forward to logits (prefill / eval path)."""
    h, extras = forward_hidden(ctx, params, batch, collect_kv=collect_kv)
    return logits_from_h(ctx, params, h), extras


def _scan_layers_enc(ctx: ModelCtx, stacked, h, positions):
    def f(h, p):
        h2, _ = _layer_forward(ctx, p, h, positions, window=0, kind="dense",
                               causal=False)
        return h2, None
    f = jax.checkpoint(f) if ctx.remat else f
    h, _ = lax.scan(f, h, stacked)
    return h, None, 0.0


def _scan_superlayers(ctx: ModelCtx, stacked, h, positions, *, collect_kv):
    def f(h, p2):
        h, (kv0, _) = _layer_forward(ctx, p2["dense"], h, positions, window=0,
                                     kind="dense")
        h, (kv1, aux) = _layer_forward(ctx, p2["moe"], h, positions, window=0,
                                       kind="moe")
        return h, ((kv0, kv1) if collect_kv else None, aux)

    f = jax.checkpoint(f) if ctx.remat else f
    h, (kvs, auxs) = lax.scan(f, h, stacked)
    return h, (kvs if collect_kv else []), jnp.sum(auxs)


def _scan_decoder_x(ctx: ModelCtx, stacked, h, positions, enc_out, *, collect_kv):
    cfg = ctx.cfg

    def f(carry, p):
        h = carry
        enc_kv = attn_mod.cross_kv(p["cross"], cfg, enc_out)
        h2, (kv, aux) = _layer_forward(ctx, p, h, positions, window=0,
                                       kind="dense", enc_kv=enc_kv)
        return h2, ((kv, enc_kv) if collect_kv else None, aux)

    f = jax.checkpoint(f) if ctx.remat else f
    h, (kvs, _) = lax.scan(f, h, stacked)
    return h, (kvs if collect_kv else []), 0.0
