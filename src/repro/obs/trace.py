"""Zero-dependency structured tracing: spans, events and metric records.

One schema (``repro.obs/v1``) for every record the repo emits — facade
solve spans, serve lifecycle events, host heartbeats, attribution
measurements — persisted as JSON lines so a trace is greppable, appendable
across processes, and machine-checkable (``validate_stream``).  The
aggregations the serving layer reports (p50/p95/p99, QPS) are *views* over
this stream (:func:`summarize`), not a second bespoke format.

Record kinds
------------
``span``   — a timed region: ``name``, monotonic ``t_start``/``t_end``/
             ``dur_s`` (``time.perf_counter``), wall-clock ``t_wall`` (for
             cross-process alignment), ``span_id`` + ``parent_id`` links,
             ``pid``/``tid``/``host``, free-form ``attrs``.
``event``  — a point-in-time fact: ``name``, ``t`` (monotonic), ``t_wall``,
             the enclosing ``span_id`` (or None), ids, ``attrs``.
``metric`` — a counter/gauge snapshot (heartbeats, serve snapshots):
             ``name``, ``t_wall``, ``host``, ``attrs``.

Activation
----------
Disabled by default at near-zero cost (one module-level check per span).
Enable programmatically (``enable(path)`` / ``disable()``) or via the
``REPRO_TRACE=PATH`` environment variable (checked lazily on first use;
``launch/solve.py --trace`` and ``launch/serve.py --trace`` are the CLI
spellings).  Files are opened in append mode: several commands can share
one trace.  Span parents are tracked per-thread (``contextvars``), so a
compile running on the serve pool's worker thread starts its own span
root rather than corrupting the dispatcher's stack.
"""

from __future__ import annotations

import contextlib
import contextvars
import itertools
import json
import os
import platform
import threading
import time

#: the schema tag every record carries; bump on incompatible changes
SCHEMA = "repro.obs/v1"

#: required keys per record kind — the contract ``validate_record`` checks
#: and docs/API.md §Observability documents
REQUIRED_KEYS = {
    "span": ("schema", "kind", "name", "span_id", "parent_id",
             "t_start", "t_end", "dur_s", "t_wall", "pid", "tid", "host",
             "attrs"),
    "event": ("schema", "kind", "name", "t", "t_wall", "span_id",
              "pid", "tid", "host", "attrs"),
    "metric": ("schema", "kind", "name", "t_wall", "host", "attrs"),
}


class Tracer:
    """A thread-safe JSON-lines sink.  Construct via :func:`enable`."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        self._f = open(path, "a")
        self._lock = threading.Lock()
        self._ids = itertools.count(1)

    def next_id(self) -> str:
        return f"{os.getpid():x}-{next(self._ids):x}"

    def emit(self, rec: dict) -> None:
        line = json.dumps(rec)
        with self._lock:
            self._f.write(line + "\n")
            self._f.flush()

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.close()


_tracer: Tracer | None = None
_env_checked = False
_span_stack: contextvars.ContextVar[str | None] = contextvars.ContextVar(
    "repro_obs_span", default=None)


def enable(path: str) -> Tracer:
    """Start emitting records to ``path`` (append mode)."""
    global _tracer, _env_checked
    disable()
    _env_checked = True      # an explicit enable/disable wins over REPRO_TRACE
    _tracer = Tracer(path)
    return _tracer


def disable() -> None:
    global _tracer, _env_checked
    _env_checked = True
    if _tracer is not None:
        _tracer.close()
        _tracer = None


def current() -> Tracer | None:
    """The active tracer, resolving ``REPRO_TRACE`` lazily on first use."""
    global _env_checked, _tracer
    if _tracer is None and not _env_checked:
        _env_checked = True
        path = os.environ.get("REPRO_TRACE")
        if path:
            _tracer = Tracer(path)
    return _tracer


def active() -> bool:
    return current() is not None


def _ids() -> dict:
    return {"pid": os.getpid(), "tid": threading.get_ident(),
            "host": platform.node()}


@contextlib.contextmanager
def span(name: str, **attrs):
    """Time a region: ``with span("solve", method="cg"): ...``.

    Yields the span id (``None`` when tracing is disabled — the only cost
    then is this one check).  The record is emitted on exit, carrying the
    parent span id of the enclosing ``span`` on this thread.
    """
    tr = current()
    if tr is None:
        yield None
        return
    sid = tr.next_id()
    parent = _span_stack.get()
    token = _span_stack.set(sid)
    t_wall = time.time()
    t0 = time.perf_counter()
    try:
        yield sid
    finally:
        t1 = time.perf_counter()
        _span_stack.reset(token)
        tr.emit({"schema": SCHEMA, "kind": "span", "name": name,
                 "span_id": sid, "parent_id": parent,
                 "t_start": t0, "t_end": t1, "dur_s": t1 - t0,
                 "t_wall": t_wall, **_ids(), "attrs": attrs})


def event(name: str, **attrs) -> dict | None:
    """Emit (and return) a point-in-time event record; None when disabled."""
    rec = make_event(name, **attrs)
    tr = current()
    if tr is not None:
        tr.emit(rec)
    return rec


def make_event(name: str, **attrs) -> dict:
    """Build an event record without requiring an active tracer (the serve
    metrics store these in memory and forward them when tracing is on)."""
    return {"schema": SCHEMA, "kind": "event", "name": name,
            "t": time.perf_counter(), "t_wall": time.time(),
            "span_id": _span_stack.get(), **_ids(), "attrs": attrs}


def make_metric(name: str, *, host=None, **attrs) -> dict:
    """Build a metric record (heartbeats, snapshots — the unified
    replacement for the bespoke per-host JSON shapes)."""
    return {"schema": SCHEMA, "kind": "metric", "name": name,
            "t_wall": time.time(),
            "host": platform.node() if host is None else host,
            "attrs": attrs}


def emit(rec: dict) -> None:
    """Forward a pre-built record to the active tracer (no-op when off)."""
    tr = current()
    if tr is not None:
        tr.emit(rec)


# -- reading / validation / views ---------------------------------------------

def read_trace(path: str) -> list[dict]:
    """Parse a JSONL trace; malformed lines raise (use ``validate_stream``
    for a non-throwing report)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def validate_record(rec: dict) -> list[str]:
    """Schema errors for one record ([] == valid)."""
    errs = []
    if not isinstance(rec, dict):
        return [f"record is {type(rec).__name__}, not an object"]
    kind = rec.get("kind")
    if kind not in REQUIRED_KEYS:
        return [f"unknown kind {kind!r}"]
    if rec.get("schema") != SCHEMA:
        errs.append(f"schema {rec.get('schema')!r} != {SCHEMA!r}")
    missing = [k for k in REQUIRED_KEYS[kind] if k not in rec]
    if missing:
        errs.append(f"{kind} record missing keys {missing}")
    if not isinstance(rec.get("attrs", {}), dict):
        errs.append("attrs is not an object")
    if kind == "span" and "dur_s" in rec and "t_start" in rec \
            and "t_end" in rec:
        if abs((rec["t_end"] - rec["t_start"]) - rec["dur_s"]) > 1e-6:
            errs.append("dur_s != t_end - t_start")
    return errs


def validate_stream(path: str) -> list[str]:
    """Every schema violation in a trace file, prefixed by line number."""
    errs: list[str] = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                errs.append(f"line {i}: not JSON ({e})")
                continue
            errs.extend(f"line {i}: {e}" for e in validate_record(rec))
    return errs


def _pcts(vals: list[float]) -> dict:
    import numpy as np
    if not vals:
        return {"p50_s": None, "p95_s": None, "p99_s": None}
    arr = np.asarray(vals)
    return {f"p{p}_s": float(np.percentile(arr, p)) for p in (50, 95, 99)}


def summarize(records: list[dict]) -> dict:
    """Aggregation view over a record stream: per-span-name count/total and
    latency percentiles, per-event-name counts, metric record counts.
    ``ServeMetrics`` computes its SLO numbers through the same helpers —
    the percentiles printed by the serve CLI and the ones this summary
    reports for ``serve.complete`` events come from one code path."""
    spans: dict[str, list[float]] = {}
    events: dict[str, int] = {}
    metrics: dict[str, int] = {}
    for rec in records:
        # tolerate malformed records: summarize runs on streams --check has
        # not gated yet, so a missing key must not crash the report
        kind = rec.get("kind")
        name = rec.get("name", "<unnamed>")
        if kind == "span" and isinstance(rec.get("dur_s"), (int, float)):
            spans.setdefault(name, []).append(rec["dur_s"])
        elif kind == "event":
            events[name] = events.get(name, 0) + 1
        elif kind == "metric":
            metrics[name] = metrics.get(name, 0) + 1
    return {
        "records": len(records),
        "spans": {
            name: {"count": len(ds), "total_s": float(sum(ds)),
                   "max_s": float(max(ds)), **_pcts(ds)}
            for name, ds in sorted(spans.items())
        },
        "events": dict(sorted(events.items())),
        "metrics": dict(sorted(metrics.items())),
    }
