"""Per-iteration convergence telemetry — the host-side half.

The device-side half lives in the MethodDef driver
(``repro.core.methods.run_method(..., telemetry=N)``): an opt-in, bounded
``(rows, n_scalars)`` buffer of every declared loop-carry scalar, threaded
through the ``lax.while_loop`` carry so it works identically on the local,
shard_map and fused-Pallas backends.  This module turns that raw buffer
(and the always-present residual ``history``) into things a human or a
JSON consumer can use: named per-scalar curves, trimmed residual curves,
and the offline true-residual recompute the tests gate the telemetry
against.

Enabled via ``SolverOptions(telemetry=True[, telemetry_buffer=N])``;
``launch/solve.py --telemetry`` surfaces the curves in its ``--json``
record.
"""

from __future__ import annotations

import numpy as np


def _get_method(method: str):
    from repro.core.methods import get_method
    return get_method(method)


def effective_rows(result) -> int:
    """Rows of ``result.telemetry`` actually written: ``iters + 1`` clamped
    to the buffer bound (iterations past the buffer overwrote its last
    row)."""
    if result.telemetry is None:
        raise ValueError("result carries no telemetry "
                         "(SolverOptions.telemetry was off)")
    cap = int(np.asarray(result.telemetry).shape[-2])
    return min(int(result.iters) + 1, cap)


def scalar_history(result, method: str) -> dict[str, np.ndarray]:
    """``{scalar name: per-iteration values}`` from a telemetry-carrying
    ``SolveResult``, keyed by the method's declared scalar slots and
    trimmed to the rows actually written (row 0 = the initial state)."""
    mdef = _get_method(method)
    rows = effective_rows(result)
    tele = np.asarray(result.telemetry)[..., :rows, :]
    return {name: tele[..., i] for i, name in enumerate(mdef.scalars)}


def residual_curve(result) -> np.ndarray:
    """The per-iteration residual-norm curve, trimmed to ``iters + 1``
    entries (the NaN padding past convergence dropped).  Reads the
    driver's ``history`` — present on every solve, telemetry or not."""
    hist = np.asarray(result.history)
    return hist[..., : int(np.asarray(result.iters).max()) + 1]


def telemetry_residuals(result, method: str) -> np.ndarray:
    """The residual curve as recorded in the telemetry buffer: sqrt of the
    method's declared ``res_scalar`` column.  Equals
    :func:`residual_curve` over the buffered rows — asserted by
    tests/test_obs.py for every registry method on both backends."""
    mdef = _get_method(method)
    rows = effective_rows(result)
    tele = np.asarray(result.telemetry)
    return np.sqrt(tele[..., :rows, mdef.res_index - len(mdef.vectors)])


def true_residual_norm(A, b, x) -> float:
    """``||b - A x||_2`` recomputed offline against the operator itself —
    the ground truth the recurrence-carried curves are validated against
    (they drift from it by O(eps * kappa) per iteration; see the
    repro.core.methods module docstring)."""
    import jax.numpy as jnp
    r = jnp.asarray(b) - A.matvec(jnp.asarray(x))
    return float(jnp.sqrt(jnp.vdot(r, r)))


def curve_record(result, method: str, *, scalars: bool = False) -> dict:
    """A JSON-able telemetry record for one solve — what
    ``launch/solve.py --telemetry --json`` embeds.

    Always: ``iters`` and the trimmed ``residuals`` curve.  When the
    result carries a telemetry buffer: ``telemetry_rows`` (buffer rows
    written) and, with ``scalars=True``, every named scalar curve.
    """
    out = {
        "iters": int(np.asarray(result.iters).max()),
        "residuals": [float(v) for v in np.atleast_1d(
            np.asarray(residual_curve(result)).squeeze())],
    }
    if result.telemetry is not None:
        out["telemetry_rows"] = effective_rows(result)
        if scalars:
            out["scalars"] = {
                name: [float(v) for v in np.atleast_1d(vals.squeeze())]
                for name, vals in scalar_history(result, method).items()
            }
    return out
