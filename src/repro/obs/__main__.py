"""``python -m repro.obs`` — trace tooling + cost attribution.

Modes::

    python -m repro.obs summarize TRACE.jsonl [--check] [--json]
    python -m repro.obs attribute --devices 8 --grid 16 16 16 \
        --methods cg cg_merged cg_pipe [--halo-mode overlap] [--json]
    python -m repro.obs attribute TRACE.jsonl        # re-render from records

``summarize`` validates every record against the ``repro.obs/v1`` schema
and prints the aggregation view (per-span percentiles, event counts);
``--check`` exits non-zero on any schema violation — the ``make
obs-smoke`` CI gate.  ``attribute`` measures the per-phase iteration split
on a multi-device mesh and prints it against the scaling model's
prediction (see ``repro.obs.attribution``); given a trace file instead,
it re-renders the table from the ``obs.attribution`` records a prior run
emitted.  ``--devices N`` forces N host devices — it must be parsed
before jax is imported, which is why the heavy imports here are lazy.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _summarize(args) -> int:
    from repro.obs import trace

    errs = trace.validate_stream(args.trace)
    records = []
    with open(args.trace) as f:
        for line in f:
            line = line.strip()
            if line:
                try:
                    records.append(json.loads(line))
                except json.JSONDecodeError:
                    pass
    summary = trace.summarize(records)
    summary["schema_errors"] = len(errs)
    if args.json:
        print(json.dumps(summary))
    else:
        print(f"[obs] {args.trace}: {summary['records']} records, "
              f"{len(errs)} schema error(s)")
        for name, st in summary["spans"].items():
            p = (f"p50={st['p50_s'] * 1e3:.1f}ms "
                 f"p99={st['p99_s'] * 1e3:.1f}ms" if st["p50_s"] is not None
                 else "")
            print(f"  span   {name:<24} x{st['count']:<5} "
                  f"total={st['total_s']:.3f}s {p}")
        for name, n in summary["events"].items():
            print(f"  event  {name:<24} x{n}")
        for name, n in summary["metrics"].items():
            print(f"  metric {name:<24} x{n}")
    for e in errs[:20]:
        print(f"[obs] schema: {e}", file=sys.stderr)
    if args.check and errs:
        print(f"[obs] FAIL: {len(errs)} schema violation(s) in {args.trace}",
              file=sys.stderr)
        return 1
    return 0


def _attribute(args) -> int:
    from repro.obs import trace
    from repro.obs.attribution import (attribution_report, format_table,
                                       rows_from_trace)

    if args.trace:
        rows = rows_from_trace(trace.read_trace(args.trace))
        if not rows:
            print(f"[obs] {args.trace}: no obs.attribution records",
                  file=sys.stderr)
            return 1
    else:
        import jax

        from repro.core.problems import enable_f64
        from repro.launch.mesh import make_solver_mesh

        enable_f64()
        mesh = make_solver_mesh(min(args.devices, len(jax.devices())))
        rows = attribution_report(
            args.methods, tuple(args.grid), mesh, halo_mode=args.halo_mode,
            inner=args.inner, repeats=args.repeats,
            profile_dir=args.profile_dir)
    print(format_table(rows))
    if args.json:
        print(json.dumps({"rows": rows}))
    return 0


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    # --devices pins the host-device count and must precede the jax import;
    # peek at it before any subcommand work
    if "attribute" in argv[:1] and "--devices" in argv:
        n = int(argv[argv.index("--devices") + 1])
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={n}".strip())

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="repro.obs trace tooling: schema-checked summaries and "
                    "predicted-vs-measured cost attribution")
    sub = ap.add_subparsers(dest="cmd", required=True)

    s = sub.add_parser("summarize", help="validate + aggregate a trace")
    s.add_argument("trace", help="JSONL trace (repro.obs/v1 records)")
    s.add_argument("--check", action="store_true",
                   help="exit non-zero on schema violations (the CI gate)")
    s.add_argument("--json", action="store_true")

    a = sub.add_parser("attribute",
                       help="measure the per-phase iteration split vs the "
                            "scaling model")
    a.add_argument("trace", nargs="?", default=None,
                   help="re-render from a trace's obs.attribution records "
                        "instead of measuring")
    a.add_argument("--methods", nargs="+",
                   default=["cg", "cg_merged", "cg_pipe"])
    a.add_argument("--grid", type=int, nargs=3, default=[16, 16, 16])
    a.add_argument("--devices", type=int, default=8,
                   help="host devices to force (sets XLA_FLAGS; must not "
                        "already be pinned)")
    a.add_argument("--halo-mode", default="concat",
                   choices=["concat", "scatter", "overlap"])
    a.add_argument("--inner", type=int, default=8,
                   help="phase trips per timed call (amortises dispatch)")
    a.add_argument("--repeats", type=int, default=5)
    a.add_argument("--profile-dir", default=None,
                   help="also write a jax.profiler trace here")
    a.add_argument("--json", action="store_true")

    args = ap.parse_args(argv)
    return _summarize(args) if args.cmd == "summarize" else _attribute(args)


if __name__ == "__main__":
    sys.exit(main())
