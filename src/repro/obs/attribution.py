"""Predicted-vs-measured cost attribution per (method, mesh, halo_mode).

The scaling model (``benchmarks/scaling_model.py``) *predicts* where an
iteration's time goes — memory-bound compute, halo exchange, global
reductions.  This module *measures* the same split with the existing step
machinery and reports both side by side, so model drift is a first-class,
inspectable number instead of a vibe:

  * ``t_iter``    — one full solver iteration, the method's
                    ``MethodDef.step`` lowered standalone by
                    ``solve_step_shardmap`` (trip-count-exact, the same
                    machinery the dry-run costs);
  * ``t_halo``    — the halo-assembly phase: ``DistributedOp.pad_exchange``
                    (ppermutes + concat/scatter assembly) in isolation,
                    times the registry's ``halo_exchanges_per_iter``;
  * ``t_reduce``  — the reduction phase: one global ``psum`` dot in
                    isolation, times ``allreduces_per_iter``;
  * ``t_compute`` — the remainder ``t_iter - t_halo - t_reduce`` (interior
                    compute; can dip negative on a noisy host — it is
                    reported raw so the three phases always sum to
                    ``t_iter`` exactly).

Each micro-phase runs ``inner`` trips inside one compiled ``fori_loop``
behind ``lax.optimization_barrier`` (no loop-invariant hoisting), timed as
a min over repeats — kernels, not container noise (the bench_kernels
convention).  ``jax.profiler`` trace hooks are available via
``profile_dir`` for a device-level timeline next to the numbers.

Caveat: the model prices TPU v5e (``benchmarks/common.py`` constants); on
the CPU containers that run CI the drift ratios are dominated by the
hardware mismatch and only the *relative* split is meaningful.  On the
target hardware the drift column is the tuning signal.

CLI: ``python -m repro.obs attribute --devices 8 --methods cg cg_merged
cg_pipe`` (runs the measurement; also emits ``obs.attribution`` metric
records to the active trace) or ``python -m repro.obs attribute
TRACE.jsonl`` (re-render a table from a trace that carries such records).
"""

from __future__ import annotations

import contextlib
import time

from repro.obs import trace as _trace


def _time_min(fn, args, *, repeats: int) -> float:
    """Min-over-repeats wall time of ``fn(*args)``, compile outside."""
    import jax
    jax.block_until_ready(fn(*args))           # warm-up / compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _phase_fns(problem, method: str, mesh, *, halo_mode: str, inner: int):
    """(step_chain, halo_chain, reduce_chain, layout) — each a jitted fn
    over global arrays running ``inner`` trips of one phase."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro.core.compat import shard_map
    from repro.core.distributed import (DistributedOp, init_step_state,
                                        make_layout, solve_step_shardmap)
    from repro.core.solvers import LocalOp

    layout = make_layout(mesh, None)
    stencil = problem.stencil
    spec = layout.spec()

    step_fn, _ = solve_step_shardmap(problem, method, mesh,
                                     halo_mode=halo_mode)

    @jax.jit
    def step_chain(b, *state):
        for _ in range(inner):
            state = step_fn(b, *state)
        return state

    def local_halo(x_loc):
        op = DistributedOp(stencil, layout, halo_mode=halo_mode)

        def body(_, x):
            xp = op.pad_exchange(lax.optimization_barrier(x))
            return xp[1:-1, 1:-1, 1:-1]

        return lax.fori_loop(0, inner, body, x_loc)

    halo_chain = jax.jit(shard_map(local_halo, mesh=mesh, in_specs=(spec,),
                                   out_specs=spec))

    def local_reduce(x_loc):
        op = DistributedOp(stencil, layout, halo_mode=halo_mode)

        def body(_, c):
            x, acc = c
            xb = lax.optimization_barrier(x)
            return (x, acc + op.dot(xb, xb))

        return lax.fori_loop(0, inner, body,
                             (x_loc, jnp.zeros((), x_loc.dtype)))[1]

    reduce_chain = jax.jit(shard_map(local_reduce, mesh=mesh,
                                     in_specs=(spec,), out_specs=P()))

    state0 = init_step_state(method, LocalOp(stencil), problem.b(),
                             problem.x0())
    return step_chain, halo_chain, reduce_chain, layout, state0


def predicted_split(method: str, problem, mesh, layout, *,
                    halo_mode: str) -> dict:
    """The scaling model's per-phase prediction for this (method, mesh,
    halo_mode) — ``benchmarks.scaling_model.iteration_breakdown`` with the
    mesh translated to its chips/local-grid/decomposition terms."""
    from benchmarks.scaling_model import iteration_breakdown

    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    local = tuple(
        g // (axes[a] if a is not None else 1)
        for g, a in zip(problem.shape, layout.dim_axes))
    ndim = sum(a is not None for a in layout.dim_axes)
    chips = int(mesh.devices.size)
    return iteration_breakdown(
        method, problem.stencil.npoint, local, chips,
        decomposition="1d" if ndim <= 1 else "3d",
        halo_mode="overlap" if halo_mode == "overlap" else "concat")


def measure_phase_split(problem, method: str, mesh, *,
                        halo_mode: str = "concat", inner: int = 8,
                        repeats: int = 5, profile_dir: str | None = None
                        ) -> dict:
    """One attribution row: measured t_iter/t_halo/t_reduce/t_compute next
    to the model's prediction.  Emits the row as an ``obs.attribution``
    metric record to the active trace (if any)."""
    import jax

    from repro.api.registry import get_solver

    spec = get_solver(method)
    with _trace.span("attribute.measure", method=method,
                     halo_mode=halo_mode):
        step_chain, halo_chain, reduce_chain, layout, state0 = _phase_fns(
            problem, method, mesh, halo_mode=halo_mode, inner=inner)
        prof = (jax.profiler.trace(profile_dir) if profile_dir
                else contextlib.nullcontext())
        with prof:
            t_iter = _time_min(step_chain, state0, repeats=repeats) / inner
            x = problem.b()
            t_halo1 = _time_min(halo_chain, (x,), repeats=repeats) / inner
            t_red1 = _time_min(reduce_chain, (x,), repeats=repeats) / inner
    n_halo = spec.halo_exchanges_per_iter
    n_red = spec.allreduces_per_iter
    t_halo = n_halo * t_halo1
    t_red = n_red * t_red1
    pred = predicted_split(method, problem, mesh, layout,
                           halo_mode=halo_mode)
    row = {
        "method": method,
        "halo_mode": halo_mode,
        "grid": list(problem.shape),
        "mesh": {"axes": list(mesh.axis_names),
                 "shape": list(mesh.devices.shape),
                 "devices": int(mesh.devices.size)},
        "counts": {"halo_exchanges": n_halo, "allreduces": n_red},
        "measured": {
            "t_iter": t_iter,
            "t_halo": t_halo,
            "t_reduce": t_red,
            # raw remainder: the three phases sum to t_iter EXACTLY
            "t_compute": t_iter - t_halo - t_red,
        },
        "predicted": pred,
        "drift": {
            "total": t_iter / pred["total"] if pred["total"] else None,
            "halo": t_halo / pred["t_halo"] if pred["t_halo"] else None,
            "reduce": t_red / pred["t_reduce"] if pred["t_reduce"] else None,
        },
    }
    _trace.emit(_trace.make_metric("obs.attribution", **row))
    return row


def attribution_report(methods, grid, mesh, *, halo_mode: str = "concat",
                       inner: int = 8, repeats: int = 5,
                       profile_dir: str | None = None) -> list[dict]:
    """Attribution rows for several methods on one mesh."""
    import jax
    import jax.numpy as jnp

    from repro.core.problems import make_problem

    dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    problem = make_problem(tuple(grid), "27pt", dtype=dtype)
    return [measure_phase_split(problem, m, mesh, halo_mode=halo_mode,
                                inner=inner, repeats=repeats,
                                profile_dir=profile_dir)
            for m in methods]


def _us(v) -> str:
    return "      -" if v is None else f"{v * 1e6:10.1f}"


def format_table(rows: list[dict]) -> str:
    """The predicted-vs-measured table (times in microseconds/iteration).
    ``meas``/``pred`` column pairs per phase; ``drift`` = measured/predicted
    total."""
    head = (f"{'method':<18} {'halo':<8} "
            f"{'iter_us':>10} {'comp_us':>10} "
            f"{'halo_us':>10} {'halo_pred':>10} "
            f"{'red_us':>10} {'red_pred':>10} "
            f"{'pred_us':>10} {'drift':>8}")
    lines = [head, "-" * len(head)]
    for r in rows:
        m, p, d = r["measured"], r["predicted"], r["drift"]
        drift = f"{d['total']:8.1f}x" if d["total"] else "       -"
        lines.append(
            f"{r['method']:<18} {r['halo_mode']:<8} "
            f"{_us(m['t_iter'])} {_us(m['t_compute'])} "
            f"{_us(m['t_halo'])} {_us(p['t_halo'])} "
            f"{_us(m['t_reduce'])} {_us(p['t_reduce'])} "
            f"{_us(p['total'])} {drift}")
    return "\n".join(lines)


def rows_from_trace(records: list[dict]) -> list[dict]:
    """Recover attribution rows from a trace's ``obs.attribution`` metric
    records (the ``attribute TRACE.jsonl`` re-render path)."""
    return [r["attrs"] for r in records
            if r.get("kind") == "metric" and r.get("name") == "obs.attribution"]
