# Unified observability (docs/API.md §Observability): structured spans/
# events on one JSONL schema, per-iteration convergence telemetry, and
# predicted-vs-measured cost attribution.  Only the zero-dependency trace
# surface is imported eagerly (span() must stay near-free when disabled);
# the telemetry/attribution helpers import jax and live in
# ``repro.obs.convergence`` / ``repro.obs.attribution``.
from repro.obs.trace import (SCHEMA, Tracer, active, current, disable,
                             emit, enable, event, make_event, make_metric,
                             read_trace, span, summarize, validate_record,
                             validate_stream)

__all__ = [
    "SCHEMA",
    "Tracer",
    "active",
    "current",
    "disable",
    "emit",
    "enable",
    "event",
    "make_event",
    "make_metric",
    "read_trace",
    "span",
    "summarize",
    "validate_record",
    "validate_stream",
]
