"""Heartbeat / straggler monitoring + failure injection.

At 1000+ nodes the dominant availability risks are (i) silent stragglers
(one slow host gates every collective — the same effect the paper measures
as MPI_Allreduce latencies inflating 100x under system noise, §4.2) and
(ii) hard failures.  This module provides the host-side machinery:

  * ``Heartbeat`` — per-step wall-time records with robust outlier detection
    (median + MAD); in a multi-host deployment each host reports its step
    time into the shared store (here: a directory of per-host files, the
    JAX-native analogue of a coordination service).
  * ``FailureInjector`` — deterministic fault scheduling for tests: raises a
    simulated preemption at a chosen step so the checkpoint/restore path is
    exercised end-to-end (tests/test_runtime.py).
"""

from __future__ import annotations

import json
import os
import time
from collections import deque
from dataclasses import dataclass, field


@dataclass
class Heartbeat:
    window: int = 50
    straggler_factor: float = 3.0
    times: deque = field(default_factory=lambda: deque(maxlen=256))
    _last: float | None = None

    def tick(self) -> dict:
        now = time.monotonic()
        if self._last is None:
            # cold start: no interval exists yet — return a well-formed
            # record (callers index into it) instead of {}
            self._last = now
            return {"step_time": None, "straggler": False, "warmup": True}
        dt = now - self._last
        self.times.append(dt)
        self._last = now
        return self.check(dt)

    def check(self, dt: float) -> dict:
        if len(self.times) < 8:
            return {"step_time": dt, "straggler": False}
        xs = sorted(self.times)
        med = xs[len(xs) // 2]
        mad = sorted(abs(x - med) for x in xs)[len(xs) // 2]
        # a window of identical samples has mad == 0 (and med may be 0 for
        # sub-resolution steps): floor the spread term so the threshold
        # never degenerates to med itself and flags dt == med as straggling
        spread = max(mad, 0.05 * med, 1e-9)
        threshold = med + self.straggler_factor * spread
        return {
            "step_time": dt,
            "median": med,
            "mad": mad,
            "straggler": dt > threshold,
        }


def write_host_heartbeat(directory: str, host_id: int, step: int,
                         step_time: float) -> None:
    """One per-host heartbeat record.  Written as a ``repro.obs/v1``
    *metric* record (``name="heartbeat"``, step/step_time in ``attrs``) —
    the same schema the serve metrics and every trace span use, replacing
    the bespoke ``{host, step, t, step_time}`` shape that was incompatible
    with ``serve/metrics.py``'s records.  Also forwarded to the active
    trace when one is enabled."""
    from repro.obs import trace as obs

    os.makedirs(directory, exist_ok=True)
    rec = obs.make_metric("heartbeat", host=host_id, step=step,
                          step_time=step_time)
    obs.emit(rec)
    path = os.path.join(directory, f"host_{host_id}.json")
    with open(path, "w") as f:
        json.dump(rec, f)


def _read_heartbeat(path: str) -> dict:
    """``{host, step, t, step_time}`` from a heartbeat file — new schema
    or the pre-PR-8 flat shape (the back-compat reader the unification
    keeps old monitor directories scannable with)."""
    with open(path) as f:
        rec = json.load(f)
    if rec.get("schema", "").startswith("repro.obs/"):
        a = rec["attrs"]
        return {"host": rec["host"], "step": a["step"], "t": rec["t_wall"],
                "step_time": a.get("step_time")}
    return rec


def scan_hosts(directory: str, timeout_s: float = 60.0) -> dict:
    """Coordinator-side: which hosts are alive / behind / straggling."""
    now = time.time()
    alive, dead, steps = [], [], {}
    if not os.path.isdir(directory):
        return {"alive": [], "dead": [], "min_step": None}
    for fn in os.listdir(directory):
        if not fn.startswith("host_"):
            continue
        rec = _read_heartbeat(os.path.join(directory, fn))
        (alive if now - rec["t"] < timeout_s else dead).append(rec["host"])
        steps[rec["host"]] = rec["step"]
    return {
        "alive": sorted(alive),
        "dead": sorted(dead),
        "min_step": min(steps.values()) if steps else None,
        "max_step": max(steps.values()) if steps else None,
    }


class FailureInjector:
    """Raises ``SimulatedFailure`` at the configured step (tests/examples).

    This is also the duck-typed surface the serve layer's chaos hooks use
    (``repro.resilience.inject.ChaosInjector``): ``maybe_fail(step)`` at
    dispatch points and ``maybe_fail_compile(key)`` at compile points —
    the base injector never fails compiles, so existing callers are
    unaffected."""

    def __init__(self, fail_at_step: int | None):
        self.fail_at_step = fail_at_step
        self.fired = False

    def maybe_fail(self, step: int) -> None:
        if (self.fail_at_step is not None and step == self.fail_at_step
                and not self.fired):
            self.fired = True
            raise SimulatedFailure(f"injected failure at step {step}")

    def maybe_fail_compile(self, key) -> None:
        """Hook point before a compile keyed by ``key`` (a serve bucket,
        a solve shape, ...).  No-op here; chaos injectors override it."""


class SimulatedFailure(RuntimeError):
    pass


class DeviceLost(RuntimeError):
    """A device dropped out of the mesh mid-run (real XLA surfaces this as
    a backend error; the chaos harness raises it deterministically).  The
    serve layer reacts by shrinking the mesh (``runtime.elastic``) and
    replaying in-flight work from the WAL."""
