"""Elastic rescaling: move a training state between mesh topologies.

Because checkpoints are global host arrays (runtime/checkpoint.py) and data
order is a pure function of (seed, step, shard) (data/pipeline.py), scaling
from N to M devices is: restore -> re-shard with the new mesh's specs ->
re-partition the data stream.  No state surgery required; validated in
tests/test_runtime.py by training on mesh A, rescaling to mesh B, and
asserting bitwise-identical forward losses.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import param_shardings
from repro.optim.adamw import AdamWState


def reshard_array(x, mesh: Mesh, spec) -> jax.Array:
    """Place one (host or otherwise-sharded) array onto ``mesh``/``spec``.

    The serve recovery path's elastic restore: a preempted batch's WAL
    entry is global host arrays (runtime/checkpoint.py), so the service
    that recovers it may run a DIFFERENT topology than the one that was
    preempted — restore is just placement onto the current mesh.
    """
    return jax.device_put(x, NamedSharding(mesh, spec))


def reshard_state(params: Any, opt_state: AdamWState | None,
                  mesh: Mesh) -> tuple[Any, AdamWState | None]:
    """Place an (unsharded or otherwise-sharded) state onto ``mesh``."""
    p_shard = param_shardings(params, mesh)
    params = jax.tree.map(jax.device_put, params, p_shard)
    if opt_state is None:
        return params, None
    m = jax.tree.map(jax.device_put, opt_state.m, p_shard)
    v = jax.tree.map(jax.device_put, opt_state.v, p_shard)
    step = jax.device_put(opt_state.step,
                          NamedSharding(mesh, jax.sharding.PartitionSpec()))
    return params, AdamWState(step=step, m=m, v=v)


def rescale_pipeline(cfg, old_shards: int, new_shards: int, global_batch: int):
    """New per-shard batch size after a topology change (data re-partition)."""
    assert global_batch % new_shards == 0, (global_batch, new_shards)
    return global_batch // new_shards
