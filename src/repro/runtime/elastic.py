"""Elastic rescaling: move a training state between mesh topologies.

Because checkpoints are global host arrays (runtime/checkpoint.py) and data
order is a pure function of (seed, step, shard) (data/pipeline.py), scaling
from N to M devices is: restore -> re-shard with the new mesh's specs ->
re-partition the data stream.  No state surgery required; validated in
tests/test_runtime.py by training on mesh A, rescaling to mesh B, and
asserting bitwise-identical forward losses.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import param_shardings
from repro.optim.adamw import AdamWState


def shrink_mesh(mesh: Mesh, lost: Sequence[int] = (), *,
                divides: int | None = None) -> Mesh:
    """The same-named 1-axis mesh over the devices surviving a loss — the
    serve layer's device-loss resume (repro.resilience): drop the ``lost``
    device ids, optionally trim to the largest count that divides
    ``divides`` (the decomposed grid extent — shard_map needs an even
    split), and rebuild.  Callers then recompile their sessions on the
    shrunk mesh and replay in-flight work from the WAL.  Multi-axis
    topologies raise: shrinking a pod×data×model mesh is a layout decision,
    not a mechanical one — rebuild it explicitly."""
    if len(mesh.axis_names) != 1:
        raise ValueError(
            f"shrink_mesh handles 1-axis meshes (the paper's 1-D z "
            f"decomposition); got axes {mesh.axis_names} — rebuild the "
            f"topology explicitly")
    lost_ids = set(lost)
    devs = [d for d in mesh.devices.flat if d.id not in lost_ids]
    if not devs:
        raise ValueError(f"no devices survive losing {sorted(lost_ids)}")
    if divides is not None:
        n = len(devs)
        while n > 1 and divides % n:
            n -= 1
        devs = devs[:n]
    return Mesh(np.asarray(devs), mesh.axis_names)


def reshard_array(x, mesh: Mesh, spec) -> jax.Array:
    """Place one (host or otherwise-sharded) array onto ``mesh``/``spec``.

    The serve recovery path's elastic restore: a preempted batch's WAL
    entry is global host arrays (runtime/checkpoint.py), so the service
    that recovers it may run a DIFFERENT topology than the one that was
    preempted — restore is just placement onto the current mesh.
    """
    return jax.device_put(x, NamedSharding(mesh, spec))


def reshard_state(params: Any, opt_state: AdamWState | None,
                  mesh: Mesh) -> tuple[Any, AdamWState | None]:
    """Place an (unsharded or otherwise-sharded) state onto ``mesh``."""
    p_shard = param_shardings(params, mesh)
    params = jax.tree.map(jax.device_put, params, p_shard)
    if opt_state is None:
        return params, None
    m = jax.tree.map(jax.device_put, opt_state.m, p_shard)
    v = jax.tree.map(jax.device_put, opt_state.v, p_shard)
    step = jax.device_put(opt_state.step,
                          NamedSharding(mesh, jax.sharding.PartitionSpec()))
    return params, AdamWState(step=step, m=m, v=v)


def rescale_pipeline(cfg, old_shards: int, new_shards: int, global_batch: int):
    """New per-shard batch size after a topology change (data re-partition)."""
    assert global_batch % new_shards == 0, (global_batch, new_shards)
    return global_batch // new_shards
