"""Fault-tolerant checkpointing: async writes, retention, elastic restore.

Checkpoints are *global* host arrays (one ``.npy`` per leaf + a JSON
manifest), so a restore can target ANY mesh shape — the elastic-rescale path
(runtime/elastic.py) is just ``device_put`` onto new shardings.  Writes are
atomic (tmp dir + rename) and can run on a background thread so the train
loop never blocks on storage.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import ml_dtypes
import numpy as np

_SEP = "__"

# numpy can't serialise ml_dtypes (bfloat16 etc.) natively — store a
# same-width integer view and reconstruct on load.
_EXOTIC = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _to_safe(arr: np.ndarray) -> np.ndarray:
    name = str(arr.dtype)
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name][1])
    return arr


def _from_safe(arr: np.ndarray, target_dtype) -> np.ndarray:
    name = str(target_dtype)
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name][0])
    return arr.astype(target_dtype)


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = _to_safe(np.asarray(leaf))
    return flat


def save(state: Any, directory: str, step: int, *, keep: int = 3,
         background: bool = False) -> threading.Thread | None:
    """Write ``state`` (pytree) to ``directory/step_<n>``; prune old steps."""
    treedef = jax.tree_util.tree_structure(state)
    flat = _flatten(state)

    def write():
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        for k, v in flat.items():
            np.save(os.path.join(tmp, k + ".npy"), v)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "keys": sorted(flat.keys()),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _prune(directory, keep)

    if background:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _prune(directory: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(template: Any, directory: str, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``template``.

    ``shardings`` (optional pytree of NamedSharding) places each leaf
    directly onto devices — pass the CURRENT mesh's shardings to perform an
    elastic restore onto a different topology than the one that saved.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    if shardings is None:
        shard_leaves = [None] * len(leaves_p)
    else:
        # shardings may be a PREFIX tree (e.g. (param_shardings, None))
        shard_leaves = []

        def _add(pfx_leaf, subtree):
            n = len(jax.tree_util.tree_leaves(subtree))
            shard_leaves.extend([pfx_leaf] * n)

        jax.tree_util.tree_map(
            _add, shardings, template,
            is_leaf=lambda x: x is None or isinstance(x, jax.sharding.Sharding))
    out = []
    for (path, leaf), sh in zip(leaves_p, shard_leaves):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.load(os.path.join(d, key + ".npy"))
        if hasattr(leaf, "dtype"):
            arr = _from_safe(arr, leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, out), step
