"""hymba-1.5b [hybrid] — parallel attention + mamba heads. [arXiv:2411.13676]

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Attention and SSD heads read the same input in parallel; their normalised
outputs are mean-fused before the output projection.  Sliding-window
attention (2048) keeps decode state bounded -> runs long_500k.  (Hymba's
handful of global-attention layers and meta tokens are simplified to
all-SW + no meta tokens; noted in DESIGN.md §4.)
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    sliding_window=2048,
    subquadratic=True,
))
