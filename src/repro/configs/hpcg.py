"""The paper's own workload: HPCG sparse systems + solver selection.

Not an LM architecture — these named cells drive launch/solve.py, the
solver benchmarks and the dry-run.  Weak-scaling sizes follow §4.1: 128^3
per device (the paper uses 128x128x128 per MPI rank and 128x128x3072 per
hybrid socket); strong scaling uses the fixed 128x128x6144 grid.

A ``SolverConfig`` is declarative; ``to_options()`` / ``session()`` turn a
cell into the typed ``repro.api`` objects that actually run it.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    name: str
    method: str                  # repro.api registry key
    stencil: str                 # "7pt" | "27pt"
    local_grid: tuple[int, int, int] = (128, 128, 128)
    tol: float = 1e-6
    maxiter: int = 600
    weak_scaling: bool = True    # grid grows with devices (along mapped dims)
    precond: str = "none"        # repro.precond registry key (pcg/pbicgstab)

    def to_options(self, **overrides):
        """The cell's ``repro.api.SolverOptions`` (facade kwargs win)."""
        from repro.api import SolverOptions
        kw = dict(tol=self.tol, maxiter=self.maxiter, precond=self.precond)
        kw.update(overrides)
        return SolverOptions(**kw)

    def session(self, *, mesh=None, grid=None, **overrides):
        """A ready ``SolverSession`` for this cell (defaults to one device's
        weak-scaling block)."""
        from repro.api import SolverSession
        return SolverSession(method=self.method,
                             grid=tuple(grid or self.local_grid),
                             stencil=self.stencil, mesh=mesh,
                             options=self.to_options(**overrides))


SOLVER_CONFIGS = {
    f"hpcg-{m}-{s}": SolverConfig(name=f"hpcg-{m}-{s}", method=m, stencil=s)
    for m in ("jacobi", "gauss_seidel", "gauss_seidel_rb", "cg", "cg_nb",
              "bicgstab", "bicgstab_b1", "pcg", "pbicgstab")
    for s in ("7pt", "27pt")
}

# preconditioned PCG cells (the production workload: same system, a fraction
# of the iterations, zero extra reductions per iteration)
SOLVER_CONFIGS.update({
    f"hpcg-pcg-{p}-{s}": SolverConfig(
        name=f"hpcg-pcg-{p}-{s}", method="pcg", stencil=s, precond=p)
    for p in ("jacobi", "block_jacobi", "ssor", "chebyshev")
    for s in ("7pt", "27pt")
})
