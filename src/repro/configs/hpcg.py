"""The paper's own workload: HPCG sparse systems + solver selection.

Not an LM architecture — this config drives launch/solve.py and the solver
benchmarks.  Weak-scaling sizes follow §4.1: 128^3 per device (the paper uses
128x128x128 per MPI rank and 128x128x3072 per hybrid socket); strong scaling
uses the fixed 128x128x6144 grid.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    name: str
    method: str                  # repro.core.solvers.SOLVERS key
    stencil: str                 # "7pt" | "27pt"
    local_grid: tuple[int, int, int] = (128, 128, 128)
    tol: float = 1e-6
    maxiter: int = 600
    weak_scaling: bool = True    # grid grows with devices (along mapped dims)


SOLVER_CONFIGS = {
    f"hpcg-{m}-{s}": SolverConfig(name=f"hpcg-{m}-{s}", method=m, stencil=s)
    for m in ("jacobi", "gauss_seidel", "gauss_seidel_rb", "cg", "cg_nb",
              "bicgstab", "bicgstab_b1")
    for s in ("7pt", "27pt")
}
