"""qwen3-moe-235b-a22b [moe] — 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B family]

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,                  # per-expert FFN dim
    vocab_size=151936,
    n_experts=128,
    top_k=8,
    qk_norm=True,               # qwen3 applies RMSNorm to q/k heads
    rope_theta=1e6,
    notes="expert-parallel MoE over the model axis; router+dispatch follow "
          "the paper's overlap principle (DESIGN.md §4)",
))
