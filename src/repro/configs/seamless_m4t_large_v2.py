"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal.
[arXiv:2308.11596]

24L (enc) + 24L (dec) d_model=1024 16H (MHA kv=16) d_ff=8192 vocab=256206.
The audio frontend (w2v-BERT conformer) is a STUB: input_specs() provides
precomputed frame embeddings to the text-decoder-facing encoder.  Decode
shapes run the autoregressive decoder with self+cross KV caches.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    enc_dec=True,
    n_enc_layers=24,
    act="gelu",
    frontend="audio",
))
