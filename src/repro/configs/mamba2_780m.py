"""mamba2-780m [ssm] — SSD (state-space duality). [arXiv:2405.21060]

48L d_model=1536, attention-free, vocab=50280, ssm_state=128.  Sub-quadratic:
runs the long_500k shape (constant-size SSM state at decode).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                     # attn-free, no MLP: interleaved mamba blocks only
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,            # d_inner=3072 -> 48 SSD heads
    ssm_chunk=128,
    tie_embeddings=True,        # GPT-NeoX tokenizer family convention
    subquadratic=True,
    notes="paper-technique inapplicable (no linear solve); SSD chunked scan "
          "reuses the solver's plane-carry blocking pattern (DESIGN.md §4)",
))
