"""Architecture config schema + registry (deliverable f).

Every assigned architecture is a frozen ``ArchConfig`` in its own module; the
exact figures come from the assignment table (sources noted per file).  The
``reduced()`` view is what CPU smoke tests instantiate (same family/topology,
tiny widths); the FULL config is only ever touched through the dry-run's
``ShapeDtypeStruct``s.
"""

from __future__ import annotations

import dataclasses

SHAPES = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    moe_every: int = 1             # llama4: MoE every 2nd layer
    dense_ff: int = 0              # FFN dim of the non-MoE layers when moe_every>1
    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    conv_kernel: int = 4
    # --- attention details ---
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    sliding_window: int = 0        # 0 = full attention
    local_global: bool = False     # gemma2: even layers local(window), odd global
    rope_theta: float = 1e4
    mrope: bool = False
    mrope_sections: tuple[int, ...] = ()
    # --- structure ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    act: str = "silu"              # silu (swiglu) | gelu (geglu)
    norm_eps: float = 1e-6
    post_norm: bool = False        # gemma2 post-layer norms
    tie_embeddings: bool = False
    # --- scaling (minicpm µP-style) ---
    embed_scale: float = 1.0
    residual_scale: float = 1.0
    logit_scale: float = 1.0
    # --- modality frontend (stubbed: inputs are precomputed embeddings) ---
    frontend: str = "none"         # none | vision | audio
    # --- applicability flags ---
    subquadratic: bool = False     # may run long_500k
    notes: str = ""

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def has_attn(self) -> bool:
        return self.family != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def shapes(self) -> dict[str, tuple[int, int, str]]:
        out = dict(train_4k=SHAPES["train_4k"], prefill_32k=SHAPES["prefill_32k"],
                   decode_32k=SHAPES["decode_32k"])
        if self.subquadratic:
            out["long_500k"] = SHAPES["long_500k"]
        return out

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2,
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16,
            ssm_chunk=16,
            sliding_window=min(self.sliding_window, 8) if self.sliding_window else 0,
            mrope_sections=(2, 3, 3) if self.mrope else (),
        )

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline cross-checks)."""
        d, L = self.d_model, self.n_layers
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        n = self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                 # lm head
        glu = 2 if self.act in ("silu", "gelu") else 1

        def attn_params():
            p = d * H * hd + 2 * d * KV * hd + H * hd * d
            if self.qkv_bias:
                p += (H + 2 * KV) * hd
            return p

        def mlp_params(ff):
            return d * ff * glu + ff * d

        def ssm_params():
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            proj_in = d * (2 * di + 2 * ns + nh)
            conv = (di + 2 * ns) * self.conv_kernel
            return proj_in + conv + 3 * nh + di * d + di

        per_layer = 2 * d  # norms
        if self.family == "ssm":
            per_layer += ssm_params()
        elif self.family == "hybrid":
            per_layer += attn_params() + ssm_params() + mlp_params(self.d_ff)
        elif self.family == "moe":
            n_moe = L // self.moe_every
            n_dense = L - n_moe
            moe_layer = (attn_params() + 2 * d + d * self.n_experts
                         + self.n_experts * mlp_params(self.d_ff)
                         + (mlp_params(self.d_ff) if self.shared_expert else 0))
            dense_layer = (attn_params() + 2 * d
                           + mlp_params(self.dense_ff or self.d_ff))
            return n + n_moe * moe_layer + n_dense * dense_layer
        else:
            per_layer += attn_params() + mlp_params(self.d_ff)
        n += L * per_layer
        if self.enc_dec:
            enc_layer = attn_params() + mlp_params(self.d_ff) + 2 * d
            cross = attn_params() + d
            n += self.n_enc_layers * enc_layer + self.n_layers * cross
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared expert only)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        glu = 2
        expert = d * self.d_ff * glu + self.d_ff * d
        total = self.param_count()
        inactive = (L // self.moe_every) * (self.n_experts - self.top_k) * expert
        return total - inactive


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> dict[str, ArchConfig]:
    if not _REGISTRY:
        _load_all()
    return dict(_REGISTRY)


def _load_all() -> None:
    from repro.configs import (  # noqa: F401
        gemma2_2b,
        hymba_1_5b,
        internlm2_1_8b,
        llama4_maverick_400b_a17b,
        mamba2_780m,
        minicpm_2b,
        qwen2_5_32b,
        qwen2_vl_7b,
        qwen3_moe_235b_a22b,
        seamless_m4t_large_v2,
    )
