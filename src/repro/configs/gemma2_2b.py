"""gemma2-2b [dense] — local/global alternating attention, logit softcaps.
[arXiv:2408.00118]

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.  Even layers use a
4096-token sliding window, odd layers are global; attention logits capped at
50, final logits at 30; GeGLU MLP; pre+post layer norms; head_dim 256.
long_500k is SKIPPED: the global layers are quadratic (DESIGN.md §4).
"""
import math
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    act="gelu",
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    local_global=True,
    post_norm=True,
    tie_embeddings=True,
    embed_scale=math.sqrt(2304.0),
))
