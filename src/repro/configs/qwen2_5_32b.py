"""qwen2.5-32b [dense] — GQA, QKV bias. [hf:Qwen/Qwen2.5 family]

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
))
