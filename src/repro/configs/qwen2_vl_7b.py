"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution. [arXiv:2409.12191]

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.  Vision frontend is a
STUB: input_specs() provides precomputed patch embeddings + (3, B, S) M-RoPE
position ids (temporal/height/width).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),   # halves of head_dim 128
    rope_theta=1e6,
    frontend="vision",
))
