"""minicpm-2b [dense] — WSD schedule, llama-like arch. [arXiv:2404.06395]

40L d_model=2304 36H (MHA kv=36) d_ff=5760 vocab=122753.  µP-style scaling:
embeddings ×12, residual branches ×(1.4/sqrt(40)), logits ×(256/2304).
The WSD (warmup-stable-decay) schedule lives in repro/optim/schedules.py and
is this arch's default training schedule.
"""
import math
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    tie_embeddings=True,
    embed_scale=12.0,
    residual_scale=1.4 / math.sqrt(40),
    logit_scale=256.0 / 2304.0,
))
