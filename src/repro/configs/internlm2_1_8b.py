"""internlm2-1.8b [dense] — GQA. [arXiv:2403.17297]

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92544,
    rope_theta=1e6,
))
