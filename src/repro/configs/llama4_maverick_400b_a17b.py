"""llama4-maverick-400b-a17b [moe] — MoE top-1 + shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E family]

48L d_model=5120 40H (GQA kv=8) expert d_ff=8192 vocab=202048, 128e top-1.
Early-fusion multimodality is a frontend concern and is stubbed (text backbone
exercised; the assignment specifies the transformer backbone only).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    n_experts=128,
    top_k=1,
    shared_expert=True,         # llama4 routes top-1 + always-on shared expert
    moe_every=2,                # MoE every 2nd layer (interleave_moe_layer_step)
    dense_ff=16384,             # the non-MoE layers' FFN dim
    rope_theta=5e5,
    notes="40 heads over 16-way tensor axis is non-divisible; GSPMD pads "
          "(wasted-compute ratio recorded in EXPERIMENTS.md)",
))
