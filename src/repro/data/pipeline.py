"""Deterministic sharded token pipeline with host prefetch.

Two sources:
  * ``SyntheticSource`` — seeded zipf-ish token stream (self-contained runs),
  * ``MemmapSource``    — flat binary token file (uint16/uint32), the
    standard "tokenized corpus on disk" format.

Determinism contract (needed for fault-tolerant resume): batch ``t`` for data
shard ``s`` depends only on ``(seed, t, s)`` — restarting from a checkpoint
at step ``t`` reproduces the exact stream, and *elastic* restarts (different
shard count) only re-partition future batches.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np


class SyntheticSource:
    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab_size = vocab_size
        self.seed = seed

    def tokens(self, step: int, shard: int, n: int) -> np.ndarray:
        rs = np.random.RandomState(
            (self.seed * 1_000_003 + step * 997 + shard) % (2 ** 31))
        # zipf-ish distribution clipped to vocab
        z = rs.zipf(1.3, size=n).astype(np.int64)
        return (z % self.vocab_size).astype(np.int32)


class MemmapSource:
    def __init__(self, path: str, vocab_size: int, dtype=np.uint16):
        self.arr = np.memmap(path, dtype=dtype, mode="r")
        self.vocab_size = vocab_size

    def tokens(self, step: int, shard: int, n: int) -> np.ndarray:
        total = self.arr.shape[0]
        start = (step * 31_337 + shard * 7_919) * n % max(total - n, 1)
        return np.asarray(self.arr[start: start + n], dtype=np.int32) % self.vocab_size


@dataclass
class PipelineConfig:
    batch_size: int            # per-shard batch
    seq_len: int
    n_shards: int = 1
    shard: int = 0
    seed: int = 0
    mrope: bool = False
    frontend: str = "none"     # none | vision | audio
    d_model: int = 0
    enc_dec: bool = False
    src_fraction: int = 4


def make_batch(source, cfg: PipelineConfig, step: int) -> dict:
    B, S = cfg.batch_size, cfg.seq_len
    toks = source.tokens(step, cfg.shard, B * (S + 1)).reshape(B, S + 1)
    batch = {
        "tokens": toks[:, :-1].copy(),
        "targets": toks[:, 1:].copy(),
    }
    pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S))
    if cfg.mrope:
        batch["positions"] = np.broadcast_to(pos, (3, B, S)).copy()
    else:
        batch["positions"] = pos.copy()
    if cfg.frontend != "none":
        rs = np.random.RandomState((cfg.seed + step) % (2 ** 31))
        batch["embeds"] = (rs.randn(B, S, cfg.d_model) * 0.02).astype(np.float32)
        batch.pop("tokens")
    if cfg.enc_dec:
        T = S // cfg.src_fraction
        rs = np.random.RandomState((cfg.seed + step + 1) % (2 ** 31))
        batch["src_embeds"] = (rs.randn(B, T, cfg.d_model) * 0.02).astype(np.float32)
        batch["src_positions"] = np.broadcast_to(
            np.arange(T, dtype=np.int32), (B, T)).copy()
        batch["tokens"] = batch.get("tokens", toks[:, :-1].copy())
        batch.pop("embeds", None)
    return batch


def batches(source, cfg: PipelineConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield make_batch(source, cfg, step)
        step += 1


class Prefetcher:
    """Background-thread prefetch of host batches (depth-bounded)."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def run():
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(item)

        self.t = threading.Thread(target=run, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
