"""Seeded fault injection — the chaos harness behind ``make chaos-smoke``.

A :class:`ChaosPlan` declares *which* faults fire and *where* (all of it
deterministic under ``seed``); a :class:`ChaosInjector` executes the plan
through the same hook surface ``repro.serve`` already calls for its
preemption tests (``runtime.monitor.FailureInjector``), plus an operand
poisoner the trace replayers apply at submission time.  Fault classes:

  * **NaN-poisoned operands** — :meth:`ChaosInjector.poison_b` NaNs a
    seeded fraction of submitted right-hand sides.  Downstream, the
    breakdown guards (``repro.core.methods``) must exit the while-loop
    with ``status="breakdown"`` instead of burning ``maxiter`` iterations
    on NaN arithmetic, and the serve layer must quarantine the lane.
  * **Collective delay** — ``halo_delay_s`` sleeps on the dispatch path,
    the harness analogue of the paper's §4.2 observation that one noisy
    host inflates every ``MPI_Allreduce``; exercises deadline rejection.
  * **Compile failure** — :meth:`maybe_fail_compile` raises
    :class:`CompileFailure` for matching buckets, every time (a bucket
    that cannot compile stays broken).  The service must convert that
    bucket's queued requests into typed rejects, not strand them.
  * **Preemption / device loss** — :meth:`maybe_fail` raises
    ``SimulatedFailure`` (recoverable: WAL replay) or ``DeviceLost``
    (topology change: mesh shrink + recompile) at planned dispatch
    sequence numbers, once each.

The injector is intentionally host-side only: faults land between
compiled calls, never inside them, so every test remains deterministic
and the compiled artifacts stay byte-identical to production ones.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.runtime.monitor import DeviceLost, FailureInjector, SimulatedFailure


class CompileFailure(RuntimeError):
    """An injected (or real) executable-build failure for one bucket."""


@dataclasses.dataclass(frozen=True)
class ChaosPlan:
    """What the injector fires, fully determined by the fields + ``seed``.

    ``nan_rate``/``nan_count``: probability a submitted RHS is poisoned
    and how many entries get NaN'd.  ``fail_compile_buckets``: substrings
    matched against the bucket's ``short()`` name; matching compiles
    raise.  ``preempt_at``/``device_loss_at``: dispatch sequence numbers
    (the service's ``seq``) at which to raise, once each.
    ``lose_devices``: device ids reported lost with ``DeviceLost``.
    ``halo_delay_s``: straggler sleep before every dispatch.
    """

    seed: int = 0
    nan_rate: float = 0.0
    nan_count: int = 1
    fail_compile_buckets: tuple[str, ...] = ()
    preempt_at: tuple[int, ...] = ()
    device_loss_at: tuple[int, ...] = ()
    lose_devices: tuple[int, ...] = ()
    halo_delay_s: float = 0.0

    def __post_init__(self):
        if not 0.0 <= self.nan_rate <= 1.0:
            raise ValueError(f"nan_rate must be in [0, 1], got {self.nan_rate}")
        if self.nan_count < 1:
            raise ValueError(f"nan_count must be >= 1, got {self.nan_count}")
        if self.halo_delay_s < 0:
            raise ValueError(
                f"halo_delay_s must be >= 0, got {self.halo_delay_s}")


class ChaosInjector(FailureInjector):
    """Executes a :class:`ChaosPlan` through the ``FailureInjector`` hook
    surface (drop-in wherever ``repro.serve`` takes ``injector=``)."""

    def __init__(self, plan: ChaosPlan):
        super().__init__(fail_at_step=None)
        self.plan = plan
        self._rng = np.random.default_rng(plan.seed)
        self._fired_preempt: set[int] = set()
        self._fired_loss: set[int] = set()
        self.poisoned = 0          # RHSs NaN'd so far (test bookkeeping)
        self.compile_failures = 0

    # -- operand poisoning (applied by the submitter, not the service) --------
    def poison_b(self, b: np.ndarray) -> tuple[np.ndarray, bool]:
        """Maybe NaN-poison one RHS (seeded draw against ``nan_rate``);
        returns ``(rhs, poisoned)`` — the original array is never mutated."""
        if self.plan.nan_rate == 0.0 or self._rng.random() >= self.plan.nan_rate:
            return b, False
        out = np.array(b, copy=True)
        flat = out.reshape(-1)
        idx = self._rng.integers(0, flat.size, size=self.plan.nan_count)
        flat[idx] = np.nan
        self.poisoned += 1
        return out, True

    # -- the FailureInjector hook surface -------------------------------------
    def maybe_fail(self, step: int) -> None:
        if self.plan.halo_delay_s:
            # the straggler: one slow host gates the collective (§4.2)
            time.sleep(self.plan.halo_delay_s)
        if step in self.plan.device_loss_at and step not in self._fired_loss:
            self._fired_loss.add(step)
            exc = DeviceLost(
                f"chaos: device(s) {list(self.plan.lose_devices)} lost at "
                f"dispatch {step}")
            exc.lost = tuple(self.plan.lose_devices)
            raise exc
        if step in self.plan.preempt_at and step not in self._fired_preempt:
            self._fired_preempt.add(step)
            self.fired = True
            raise SimulatedFailure(f"chaos: injected preemption at "
                                   f"dispatch {step}")

    def maybe_fail_compile(self, key) -> None:
        name = key.short() if hasattr(key, "short") else str(key)
        if any(pat in name for pat in self.plan.fail_compile_buckets):
            self.compile_failures += 1
            raise CompileFailure(f"chaos: injected compile failure for "
                                 f"bucket {name!r}")
