"""``python -m repro.resilience --smoke`` — the seeded chaos suite.

One process runs every injectable fault class end-to-end against real
solves and a real service, records the whole run as a ``repro.obs/v1``
trace (``TRACE_chaos.jsonl`` — the CI artifact), and prints a JSON
summary whose ``ok`` field gates ``make chaos-smoke``:

  * NaN-poisoned operand  -> guarded solve exits ``status="breakdown"``
    (and the ``raise`` policy raises ``SolveBreakdown``);
  * fallback recovery     -> a merged variant's ladder reaches the
    classical method and converges;
  * compile failure       -> the broken bucket's requests become typed
    ``compile_failed`` rejects, other buckets complete;
  * injected preemption   -> in-place retry (backoff + seeded jitter)
    completes the dispatch with zero dropped requests;
  * poison quarantine     -> the poisoned lane is rejected, clean lanes
    in the same batch converge;
  * deadline              -> an expired request is rejected at dispatch.

Everything is seeded: two runs produce the same injections, the same
rejects, the same trace record names.
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def run_smoke(out: str, seed: int = 0) -> dict:
    from repro.api import SolverOptions, SolverSession
    from repro.core.methods import SolveBreakdown
    from repro.core.problems import enable_f64
    from repro.obs import trace as obs
    from repro.resilience import ChaosInjector, ChaosPlan
    from repro.serve import Request, ServeConfig, SolverService

    enable_f64()
    rng = np.random.default_rng(seed)
    checks: dict[str, bool] = {}
    obs.enable(out)
    try:
        with obs.span("chaos.smoke", seed=seed):
            grid = (8, 8, 8)

            # -- solver: NaN operand -> typed breakdown, raise policy -----
            sess = SolverSession(grid=grid, method="cg",
                                 options=SolverOptions(tol=1e-8, maxiter=200,
                                                       guards=True))
            bad = np.asarray(sess.problem.b()).copy()
            bad[0, 0, 0] = np.nan
            try:
                sess.solve(bad)
                checks["nan_raises"] = False
            except SolveBreakdown as e:
                checks["nan_raises"] = (
                    e.result.status is not None
                    and int(e.result.status) == 2)

            # -- solver: fallback ladder converges on a clean system ------
            sess_fb = SolverSession(
                grid=grid, method="cg_merged",
                options=SolverOptions(tol=1e-8, maxiter=200,
                                      on_breakdown="fallback"))
            r = sess_fb.solve()
            checks["fallback_clean_converges"] = int(r.status) == 0

            # -- serve: compile failure + preempt-retry + quarantine +
            #    deadline, one service ------------------------------------
            inj = ChaosInjector(ChaosPlan(
                seed=seed, fail_compile_buckets=("bicgstab",),
                preempt_at=(0,)))
            svc = SolverService(
                ServeConfig(max_batch=4, guards=True, max_retries=2,
                            retry_backoff_s=0.01, retry_seed=seed),
                injector=inj)
            ids_ok = [svc.submit(Request(b=rng.standard_normal(grid),
                                         method="cg", maxiter=200))
                      for _ in range(3)]
            ids_cf = [svc.submit(Request(b=rng.standard_normal(grid),
                                         method="bicgstab", maxiter=200))
                      for _ in range(2)]
            poisoned = rng.standard_normal(grid)
            poisoned[0, 0, 0] = np.nan
            id_poison = svc.submit(Request(b=poisoned, method="cg",
                                           maxiter=200))
            id_dead = svc.submit(Request(b=rng.standard_normal(grid),
                                         method="cg", maxiter=200,
                                         deadline_s=0.0))
            svc.run_until_drained()
            svc.close()
            res, rej = svc.results(), svc.rejects()
            snap = svc.snapshot()
            checks["compile_fail_rejects"] = all(
                rej.get(i) is not None
                and rej[i].reason == "compile_failed" for i in ids_cf)
            checks["clean_complete"] = all(
                i in res and res[i].status == "converged" for i in ids_ok)
            checks["poison_quarantined"] = (
                id_poison in rej and rej[id_poison].reason == "poisoned")
            checks["deadline_rejected"] = (
                id_dead in rej and rej[id_dead].reason == "deadline")
            checks["retry_not_requeue"] = (snap["retries"] >= 1
                                           and snap["preemptions"] == 0)
            checks["nothing_stranded"] = (
                len(res) + len(rej) == snap["completed"]
                + snap["service_rejects"] == len(ids_ok) + len(ids_cf) + 2)
    finally:
        obs.disable()
    problems = obs.validate_stream(out)
    checks["trace_validates"] = not problems
    return {"ok": all(checks.values()), "seed": seed, "checks": checks,
            "trace": out, "trace_problems": problems[:5]}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.resilience")
    ap.add_argument("--smoke", action="store_true",
                    help="run the seeded chaos suite (the CI gate)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="TRACE_chaos.jsonl",
                    help="trace artifact path")
    args = ap.parse_args(argv)
    if not args.smoke:
        ap.error("nothing to do: pass --smoke")
    summary = run_smoke(args.out, seed=args.seed)
    print(json.dumps(summary, indent=2, sort_keys=True))
    return 0 if summary["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
