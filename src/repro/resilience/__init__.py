# Breakdown-aware solving + fault injection (docs/API.md §Robustness).
#
# The solver side lives in repro.core.methods (typed SolveResult.status,
# GuardSpec, the per-method guard/refresh hooks) and repro.api (the
# on_breakdown recovery policies); this package re-exports that surface
# and adds the chaos harness (inject.py) the tests, `make chaos-smoke`
# and the serve layer's self-healing paths are exercised with.
from repro.core.methods import (GuardSpec, STATUS_BREAKDOWN,
                                STATUS_CONVERGED, STATUS_DIVERGED,
                                STATUS_MAXITER, STATUS_NAMES,
                                STATUS_STAGNATED, SolveBreakdown,
                                status_name)
from repro.resilience.inject import ChaosInjector, ChaosPlan, CompileFailure
from repro.runtime.monitor import DeviceLost, SimulatedFailure

__all__ = [
    "ChaosInjector",
    "ChaosPlan",
    "CompileFailure",
    "DeviceLost",
    "GuardSpec",
    "STATUS_BREAKDOWN",
    "STATUS_CONVERGED",
    "STATUS_DIVERGED",
    "STATUS_MAXITER",
    "STATUS_NAMES",
    "STATUS_STAGNATED",
    "SimulatedFailure",
    "SolveBreakdown",
    "status_name",
]
