# Production solver service (docs/API.md §Serving): continuous batching
# of a heterogeneous request stream over an LRU cache of pre-compiled
# executables, with SLO metrics and WAL-based preemption recovery.
from repro.serve.cache import CacheEntry, ExecutableCache, session_for
from repro.serve.metrics import PERCENTILES, ServeMetrics, scan_metrics
from repro.serve.queue import (BucketKey, DTYPES, QueueFull, Request,
                               RequestQueue)
from repro.serve.service import (ServeConfig, ServeReject, ServeResult,
                                 SolverService)
from repro.serve.trace import (MIXED_BUCKETS, SMOKE_BUCKETS, TraceBucket,
                               generate_trace, replay)

__all__ = [
    "BucketKey",
    "CacheEntry",
    "DTYPES",
    "ExecutableCache",
    "MIXED_BUCKETS",
    "SMOKE_BUCKETS",
    "PERCENTILES",
    "QueueFull",
    "Request",
    "RequestQueue",
    "ServeConfig",
    "ServeMetrics",
    "ServeReject",
    "ServeResult",
    "SolverService",
    "TraceBucket",
    "generate_trace",
    "replay",
    "scan_metrics",
    "session_for",
]
