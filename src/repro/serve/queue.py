"""Request admission + bucketing for the solver service.

A request is one right-hand side plus everything that determines which
compiled executable can serve it.  Admission validates against the solver
registry *before* the request costs anything (unknown method, a
preconditioner on a method with no ``M=`` hook, a wrong-shaped RHS and a
bad dtype are all rejected at the door), then files the request into a
FIFO bucket keyed by

    ``(grid, stencil, method, precond, dtype)``  + solve params

— exactly the tuple that pins one compiled executable.  ``tol`` /
``maxiter`` / ``norm_ref`` / ``precond_params`` are burned into the
compiled while-loop as constants, so they ride along in
``BucketKey.solve_params``: requests that differ there *cannot* share an
executable and honestly fork their own bucket.

The queue is pure bookkeeping — no JAX, no threads.  The service drains
it bucket-at-a-time (``next_batch``) and pushes preempted work back at
the *front* (``requeue_front``) so recovery preserves FIFO order.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import NamedTuple

import numpy as np

from repro.api import get_solver, precond_names, solver_names

#: accepted request dtypes (f64 additionally requires the process to have
#: run ``repro.core.problems.enable_f64()`` — SolverSession enforces it)
DTYPES = ("f32", "f64")


class BucketKey(NamedTuple):
    """Everything that pins ONE compiled executable (== one cache entry)."""

    grid: tuple[int, int, int]
    stencil: str
    method: str
    precond: str
    dtype: str
    #: (tol, maxiter, norm_ref, frozen precond_params) — compiled-in
    #: constants; requests differing here fork their own bucket.
    solve_params: tuple

    def short(self) -> str:
        g = "x".join(map(str, self.grid))
        pre = f"+{self.precond}" if self.precond != "none" else ""
        return f"{self.method}{pre}/{self.stencil}/{g}/{self.dtype}"


@dataclasses.dataclass
class Request:
    """One solve request.  ``b`` is the RHS (host array, ``grid``-shaped);
    the rest selects the executable.  Runtime fields (``id``, timestamps,
    ``requeues``) are filled in by the queue/service."""

    b: np.ndarray
    method: str = "cg"
    stencil: str = "27pt"
    precond: str = "none"
    precond_params: dict | None = None
    dtype: str = "f64"
    tol: float = 1e-8
    maxiter: int = 500
    norm_ref: float | None = 1.0
    #: end-to-end deadline (seconds after admission); a request still
    #: queued past it is rejected at dispatch time instead of solved
    #: (``ServeConfig.default_deadline_s`` applies when None).  Not part
    #: of the bucket key — deadlines don't pin an executable — and not
    #: persisted in the WAL (a recovered batch re-solves regardless: the
    #: work is already journalled and paid for).
    deadline_s: float | None = None

    id: int | None = None
    t_submit: float | None = None
    requeues: int = 0

    def key(self) -> BucketKey:
        pp = (tuple(sorted(self.precond_params.items()))
              if self.precond_params else ())
        return BucketKey(grid=tuple(self.b.shape), stencil=self.stencil,
                         method=self.method, precond=self.precond,
                         dtype=self.dtype,
                         solve_params=(self.tol, self.maxiter,
                                       self.norm_ref, pp))


class QueueFull(RuntimeError):
    """Admission control: the queue is at ``max_depth``."""


class RequestQueue:
    """Per-bucket FIFO queues with validated admission."""

    def __init__(self, max_depth: int | None = None):
        self.max_depth = max_depth
        self._buckets: OrderedDict[BucketKey, deque[Request]] = OrderedDict()
        self._next_id = 0
        self.admitted = 0
        self.rejected = 0

    # -- admission ------------------------------------------------------------
    def _validate(self, req: Request) -> None:
        if req.dtype not in DTYPES:
            raise ValueError(f"unknown dtype {req.dtype!r}; options: {DTYPES}")
        if req.method not in solver_names():
            raise ValueError(f"unknown method {req.method!r}; "
                             f"options: {solver_names()}")
        if req.precond not in precond_names():
            raise ValueError(f"unknown precond {req.precond!r}; "
                             f"options: {precond_names()}")
        if req.precond != "none" and not get_solver(req.method).accepts_precond:
            raise ValueError(
                f"method {req.method!r} takes no preconditioner "
                f"(requested {req.precond!r})")
        b = np.asarray(req.b)
        if b.ndim != 3:
            raise ValueError(f"request RHS must be (nx, ny, nz), "
                             f"got shape {b.shape}")

    def admit(self, req: Request, *, now: float) -> int:
        """Validate + enqueue; returns the assigned request id.  Raises
        ``ValueError`` (malformed) or ``QueueFull`` (at ``max_depth``) —
        the request costs nothing past this point."""
        try:
            self._validate(req)
        except ValueError:
            self.rejected += 1
            raise
        if self.max_depth is not None and self.depth() >= self.max_depth:
            self.rejected += 1
            raise QueueFull(f"queue at max_depth={self.max_depth}")
        req.id = self._next_id
        self._next_id += 1
        req.t_submit = now
        self._buckets.setdefault(req.key(), deque()).append(req)
        self.admitted += 1
        return req.id

    # -- draining -------------------------------------------------------------
    def buckets(self) -> list[BucketKey]:
        """Bucket keys with pending work, oldest head-request first (the
        service's fairness order)."""
        live = [(k, q[0].t_submit) for k, q in self._buckets.items() if q]
        return [k for k, _ in sorted(live, key=lambda kv: kv[1])]

    def pending(self, key: BucketKey) -> int:
        return len(self._buckets.get(key, ()))

    def depth(self) -> int:
        return sum(len(q) for q in self._buckets.values())

    def next_batch(self, key: BucketKey, n: int) -> list[Request]:
        """Pop up to ``n`` requests from ``key``'s FIFO."""
        q = self._buckets.get(key)
        out = []
        while q and len(out) < n:
            out.append(q.popleft())
        return out

    def requeue_front(self, key: BucketKey, reqs: list[Request]) -> None:
        """Push preempted requests back at the FRONT, preserving order."""
        q = self._buckets.setdefault(key, deque())
        for r in reversed(reqs):
            r.requeues += 1
            q.appendleft(r)
