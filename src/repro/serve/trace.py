"""Mixed-workload trace generation + replay.

A trace is a deterministic, interleaved stream of heterogeneous requests
— the workload the service exists for (one homogeneous burst would just
be ``solve_batched``).  ``MIXED_BUCKETS`` is the fixed reference mix the
bench and the CI smoke gate replay: two grids x two methods, one of them
preconditioned, so the stream exercises bucketing, padding, warm-cache
reuse and compile-then-admit in one pass.

Replay interleaves submission with scheduling steps (a request stream,
not an offline batch): ``chunk`` requests are admitted, then one
``step()`` runs, until the trace is exhausted; the service then drains.
Everything is seeded — the same trace replayed twice produces bitwise-
identical results.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve.queue import Request
from repro.serve.service import ServeResult, SolverService


@dataclasses.dataclass(frozen=True)
class TraceBucket:
    """``count`` requests for one bucket, payloads drawn from the trace's
    seeded RNG."""

    grid: tuple[int, int, int]
    method: str
    stencil: str = "27pt"
    precond: str = "none"
    precond_params: tuple = ()      # frozen dict items, hashable
    dtype: str = "f64"
    count: int = 8
    tol: float = 1e-8
    maxiter: int = 500
    norm_ref: float | None = 1.0


#: the reference heterogeneous mix (>= 4 distinct buckets: two grids x
#: two methods, one preconditioned) — the acceptance trace
MIXED_BUCKETS = (
    TraceBucket(grid=(12, 12, 12), method="cg", stencil="27pt"),
    TraceBucket(grid=(16, 16, 16), method="cg", stencil="7pt"),
    TraceBucket(grid=(12, 12, 12), method="bicgstab_b1", stencil="27pt"),
    TraceBucket(grid=(16, 16, 16), method="pcg", stencil="27pt",
                precond="jacobi", precond_params=(("sweeps", 2),)),
)

#: the same mix shrunk for CI: tiny grids, modest counts — shared by
#: ``benchmarks/bench_serve.py --smoke``, ``launch/serve.py --buckets
#: smoke`` and ``make obs-smoke`` so every gate replays one workload
SMOKE_BUCKETS = (
    TraceBucket(grid=(8, 8, 8), method="cg", stencil="27pt", count=6,
                maxiter=200),
    TraceBucket(grid=(12, 12, 12), method="cg", stencil="7pt", count=6,
                maxiter=200),
    TraceBucket(grid=(8, 8, 8), method="bicgstab_b1", stencil="27pt",
                count=6, maxiter=200),
    TraceBucket(grid=(12, 12, 12), method="pcg", stencil="27pt",
                precond="jacobi", precond_params=(("sweeps", 2),),
                count=6, maxiter=200),
)


def generate_trace(buckets=MIXED_BUCKETS, *, seed: int = 0,
                   scale: int = 1) -> list[Request]:
    """Build the request stream: ``scale * bucket.count`` requests per
    bucket, round-robin interleaved (a heterogeneous arrival order, the
    worst case for a batcher that wants runs of identical work)."""
    rng = np.random.default_rng(seed)
    per_bucket = []
    for tb in buckets:
        dt = np.float64 if tb.dtype == "f64" else np.float32
        reqs = [Request(b=rng.standard_normal(tb.grid).astype(dt),
                        method=tb.method, stencil=tb.stencil,
                        precond=tb.precond,
                        precond_params=(dict(tb.precond_params)
                                        if tb.precond_params else None),
                        dtype=tb.dtype, tol=tb.tol, maxiter=tb.maxiter,
                        norm_ref=tb.norm_ref)
                for _ in range(tb.count * scale)]
        per_bucket.append(reqs)
    trace = []
    for i in range(max(len(rs) for rs in per_bucket)):
        for rs in per_bucket:
            if i < len(rs):
                trace.append(rs[i])
    return trace


def replay(service: SolverService, trace: list[Request], *,
           chunk: int = 4) -> dict[int, ServeResult]:
    """Feed ``trace`` through ``service`` as a stream (``chunk`` submits
    per scheduling step), then drain.  Returns ``{request id: result}``."""
    for i, req in enumerate(trace):
        service.submit(req)
        if (i + 1) % chunk == 0:
            service.step()
    return service.run_until_drained()
