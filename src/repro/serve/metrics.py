"""SLO metrics for the solver service: latency percentiles, sustained
QPS, queue depth, per-bucket compile time.

Latency is end-to-end (admission -> result committed), which is what a
client experiences: queue wait + any compile the request was unlucky
enough to trigger + solve time.  Sustained QPS is completions over the
span from first admission to last completion — the number a capacity
plan can use, not a burst peak.

Since PR 8 the store is not bespoke: every ``record_*`` call builds one
``repro.obs/v1`` *event* record (``serve.admit`` / ``serve.complete`` /
``serve.queue_depth`` / ``serve.preempt``), keeps it in memory, and
forwards it to the active trace (``repro.obs``) when one is enabled —
so a ``--trace`` run of the service and the numbers it prints come from
the same stream.  The counters and percentiles below are *views* over
those events (``repro.obs.trace.summarize`` uses the same percentile
helper); ``snapshot()`` keeps its pre-PR-8 key set (bench_serve and the
CI gate parse it) plus a ``schema`` tag.

``write()`` persists a snapshot as ``metrics_<name>.json`` — the same
host-side record style as ``runtime/monitor.py``'s per-host heartbeats —
and ``scan_metrics`` is the coordinator-side reader.  Both readers accept
the pre-PR-8 untagged records (``load_record`` is the back-compat shim);
``benchmarks/bench_serve.py`` embeds the same snapshot into
``BENCH_serve.json`` for the CI gate, and PR-6-era files still parse
(tests/test_obs.py regression-tests the committed one).
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.obs import trace as obs

#: the SLO percentiles every snapshot reports
PERCENTILES = (50, 95, 99)


def load_record(rec: dict) -> dict:
    """Back-compat reader: normalise a metrics/heartbeat record written
    before the unified schema (no ``schema`` key) into the tagged shape.
    Already-tagged records pass through unchanged."""
    if "schema" in rec:
        return rec
    out = dict(rec)
    out["schema"] = f"{obs.SCHEMA}+legacy"
    if "t_wall" not in out and "t" in out:
        out["t_wall"] = out["t"]
    return out


class ServeMetrics:
    def __init__(self):
        self._events: list[dict] = []     # repro.obs/v1 event records
        self.rejected = 0
        self._t_first_submit: float | None = None
        self._t_last_done: float | None = None

    # -- recording (each call = one schema event, forwarded to the trace) -----
    def _record(self, name: str, **attrs) -> None:
        rec = obs.make_event(name, **attrs)
        self._events.append(rec)
        obs.emit(rec)

    def record_submit(self, now: float, *, bucket: str | None = None,
                      rid: int | None = None) -> None:
        if self._t_first_submit is None:
            self._t_first_submit = now
        self._record("serve.admit", bucket=bucket, id=rid)

    def record_completion(self, bucket: str, latency_s: float,
                          now: float) -> None:
        self._t_last_done = now
        self._record("serve.complete", bucket=bucket, latency_s=latency_s)

    def record_queue_depth(self, depth: int) -> None:
        self._record("serve.queue_depth", depth=depth)

    def record_preemption(self, n_requeued: int) -> None:
        self._record("serve.preempt", requeued=n_requeued)

    def record_reject(self, bucket: str, reason: str, *,
                      rid: int | None = None) -> None:
        """A service-level reject (compile_failed / deadline / poisoned) —
        distinct from ``rejected``, which counts admission-time refusals."""
        self._record("serve.reject", bucket=bucket, reason=reason, id=rid)

    def record_retry(self, bucket: str, attempt: int,
                     backoff_s: float) -> None:
        self._record("serve.retry", bucket=bucket, attempt=attempt,
                     backoff_s=backoff_s)

    def record_device_loss(self, n_lost: int, survivors: int | None) -> None:
        self._record("serve.device_loss", lost=n_lost, survivors=survivors)

    # -- views over the event stream ------------------------------------------
    def events(self) -> list[dict]:
        """The raw schema-tagged event records (what a trace would hold)."""
        return list(self._events)

    def _named(self, name: str) -> list[dict]:
        return [e for e in self._events if e["name"] == name]

    @property
    def completed(self) -> int:
        return len(self._named("serve.complete"))

    @property
    def preemptions(self) -> int:
        return len(self._named("serve.preempt"))

    @property
    def service_rejects(self) -> int:
        return len(self._named("serve.reject"))

    @property
    def retries(self) -> int:
        return len(self._named("serve.retry"))

    @property
    def device_losses(self) -> int:
        return len(self._named("serve.device_loss"))

    @property
    def requeued(self) -> int:
        return sum(e["attrs"]["requeued"] for e in self._named("serve.preempt"))

    @staticmethod
    def _pcts(lats: list[float]) -> dict[str, float]:
        if not lats:
            return {f"p{p}_s": None for p in PERCENTILES}
        arr = np.asarray(lats)
        return {f"p{p}_s": float(np.percentile(arr, p)) for p in PERCENTILES}

    def qps(self) -> float | None:
        """Sustained throughput: completions / (first submit -> last done)."""
        if self.completed == 0 or self._t_first_submit is None:
            return None
        span = self._t_last_done - self._t_first_submit
        return self.completed / max(span, 1e-9)

    def snapshot(self, *, cache_stats: dict | None = None,
                 queue_depth: int | None = None) -> dict:
        lats = [e["attrs"]["latency_s"] for e in self._named("serve.complete")]
        by_bucket: dict[str, list[float]] = {}
        for e in self._named("serve.complete"):
            by_bucket.setdefault(e["attrs"]["bucket"], []).append(
                e["attrs"]["latency_s"])
        depths = [e["attrs"]["depth"] for e in self._named("serve.queue_depth")]
        rec = {
            "schema": obs.SCHEMA,
            "t": time.time(),
            "completed": self.completed,
            "preemptions": self.preemptions,
            "requeued": self.requeued,
            "rejected": self.rejected,
            "service_rejects": self.service_rejects,
            "rejects_by_reason": {
                r: sum(1 for e in self._named("serve.reject")
                       if e["attrs"]["reason"] == r)
                for r in sorted({e["attrs"]["reason"]
                                 for e in self._named("serve.reject")})},
            "retries": self.retries,
            "device_losses": self.device_losses,
            "qps": self.qps(),
            "queue_depth": queue_depth,
            "queue_depth_max": max(depths) if depths else 0,
            **self._pcts(lats),
            "per_bucket": {
                b: {"served": len(ls), **self._pcts(ls)}
                for b, ls in sorted(by_bucket.items())
            },
        }
        if cache_stats is not None:
            rec["cache"] = cache_stats
        return rec

    def write(self, directory: str, *, name: str = "serve",
              **snapshot_kw) -> str:
        """Persist a snapshot as ``directory/metrics_<name>.json`` (the
        monitor.py per-host-record idiom)."""
        os.makedirs(directory, exist_ok=True)
        path = os.path.join(directory, f"metrics_{name}.json")
        with open(path, "w") as f:
            json.dump(self.snapshot(**snapshot_kw), f, indent=2)
        return path


def scan_metrics(directory: str) -> dict[str, dict]:
    """Coordinator-side reader for ``ServeMetrics.write`` records; accepts
    pre-schema (untagged) files via :func:`load_record`."""
    out = {}
    if not os.path.isdir(directory):
        return out
    for fn in sorted(os.listdir(directory)):
        if fn.startswith("metrics_") and fn.endswith(".json"):
            with open(os.path.join(directory, fn)) as f:
                out[fn[len("metrics_"):-len(".json")]] = load_record(
                    json.load(f))
    return out
