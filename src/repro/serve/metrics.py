"""SLO metrics for the solver service: latency percentiles, sustained
QPS, queue depth, per-bucket compile time.

Latency is end-to-end (admission -> result committed), which is what a
client experiences: queue wait + any compile the request was unlucky
enough to trigger + solve time.  Sustained QPS is completions over the
span from first admission to last completion — the number a capacity
plan can use, not a burst peak.

``snapshot()`` returns one plain-dict record and ``write()`` persists it
as a JSON file, the same host-side record style as
``runtime/monitor.py``'s per-host heartbeats (a directory of small JSON
files a coordinator can scan) — ``scan_metrics`` is the coordinator-side
reader.  ``benchmarks/bench_serve.py`` embeds the same record into
``BENCH_serve.json`` for the CI gate.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

#: the SLO percentiles every snapshot reports
PERCENTILES = (50, 95, 99)


class ServeMetrics:
    def __init__(self):
        self._latencies: list[float] = []
        self._by_bucket: dict[str, list[float]] = {}
        self._depth_samples: list[int] = []
        self.completed = 0
        self.preemptions = 0
        self.requeued = 0
        self.rejected = 0
        self._t_first_submit: float | None = None
        self._t_last_done: float | None = None

    # -- recording ------------------------------------------------------------
    def record_submit(self, now: float) -> None:
        if self._t_first_submit is None:
            self._t_first_submit = now

    def record_completion(self, bucket: str, latency_s: float,
                          now: float) -> None:
        self._latencies.append(latency_s)
        self._by_bucket.setdefault(bucket, []).append(latency_s)
        self.completed += 1
        self._t_last_done = now

    def record_queue_depth(self, depth: int) -> None:
        self._depth_samples.append(depth)

    def record_preemption(self, n_requeued: int) -> None:
        self.preemptions += 1
        self.requeued += n_requeued

    # -- reading --------------------------------------------------------------
    @staticmethod
    def _pcts(lats: list[float]) -> dict[str, float]:
        if not lats:
            return {f"p{p}_s": None for p in PERCENTILES}
        arr = np.asarray(lats)
        return {f"p{p}_s": float(np.percentile(arr, p)) for p in PERCENTILES}

    def qps(self) -> float | None:
        """Sustained throughput: completions / (first submit -> last done)."""
        if self.completed == 0 or self._t_first_submit is None:
            return None
        span = self._t_last_done - self._t_first_submit
        return self.completed / max(span, 1e-9)

    def snapshot(self, *, cache_stats: dict | None = None,
                 queue_depth: int | None = None) -> dict:
        rec = {
            "t": time.time(),
            "completed": self.completed,
            "preemptions": self.preemptions,
            "requeued": self.requeued,
            "rejected": self.rejected,
            "qps": self.qps(),
            "queue_depth": queue_depth,
            "queue_depth_max": (max(self._depth_samples)
                                if self._depth_samples else 0),
            **self._pcts(self._latencies),
            "per_bucket": {
                b: {"served": len(ls), **self._pcts(ls)}
                for b, ls in sorted(self._by_bucket.items())
            },
        }
        if cache_stats is not None:
            rec["cache"] = cache_stats
        return rec

    def write(self, directory: str, *, name: str = "serve",
              **snapshot_kw) -> str:
        """Persist a snapshot as ``directory/metrics_<name>.json`` (the
        monitor.py per-host-record idiom)."""
        os.makedirs(directory, exist_ok=True)
        path = os.path.join(directory, f"metrics_{name}.json")
        with open(path, "w") as f:
            json.dump(self.snapshot(**snapshot_kw), f, indent=2)
        return path


def scan_metrics(directory: str) -> dict[str, dict]:
    """Coordinator-side reader for ``ServeMetrics.write`` records."""
    out = {}
    if not os.path.isdir(directory):
        return out
    for fn in sorted(os.listdir(directory)):
        if fn.startswith("metrics_") and fn.endswith(".json"):
            with open(os.path.join(directory, fn)) as f:
                out[fn[len("metrics_"):-len(".json")]] = json.load(f)
    return out
