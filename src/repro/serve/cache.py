"""LRU cache of pre-compiled solver executables, one entry per bucket.

An entry owns a ``SolverSession`` whose batched executable has been
AOT-compiled at the service's fixed padded batch size
(``session.compile_batched``), so every dispatch is a warm call — the
"compiled-resource reuse" the PETSc hybrid study identifies as the
efficiency lever at moderate resources.  The cache is bounded: inserting
past ``capacity`` evicts the least-recently-*dispatched* bucket, dropping
its session (and with it the compiled executables) on the floor.

Counter semantics (exported through ``stats()`` and asserted by the serve
tests + CI gate):

  * ``miss``  — a bucket needed an executable that wasn't resident; each
    miss corresponds to exactly one compile (triggered by the service's
    compile-then-admit path).
  * ``hit``   — one dispatched batch served from a resident entry.
  * ``eviction`` — one entry dropped to respect ``capacity``.

Per-bucket compile seconds come from the session's own
``cache_stats()`` (the satellite observability this layer is built on).
"""

from __future__ import annotations

from collections import OrderedDict

from repro.api import SolverOptions, SolverSession
from repro.serve.queue import BucketKey


def session_for(key: BucketKey, *, pallas: bool = False, mesh=None,
                guards: bool = False) -> SolverSession:
    """Build the ``SolverSession`` a bucket's executable lives in.

    ``guards`` arms the breakdown guards so every batched dispatch returns
    honest per-lane ``status`` for the poison quarantine; the recovery
    policy stays ``"none"`` — the service, not the session, decides what a
    poisoned lane costs (batched solves never restart the whole batch for
    one bad lane).  ``mesh`` pins the executable's topology (the elastic
    device-loss path rebuilds entries on a shrunk mesh)."""
    tol, maxiter, norm_ref, pp = key.solve_params
    opts = SolverOptions(tol=tol, maxiter=maxiter, norm_ref=norm_ref,
                         f64=(key.dtype == "f64"), pallas=pallas,
                         precond=key.precond,
                         precond_params=dict(pp) if pp else None,
                         guards=guards,
                         on_breakdown="none" if guards else "raise")
    return SolverSession(method=key.method, grid=key.grid,
                         stencil=key.stencil, options=opts, mesh=mesh)


class CacheEntry:
    """One resident bucket: its session + the padded batch size it was
    compiled at."""

    def __init__(self, key: BucketKey, session: SolverSession, batch: int):
        self.key = key
        self.session = session
        self.batch = batch
        self.batches_served = 0

    def compile_seconds(self) -> float:
        return sum(st["compile_s"]
                   for st in self.session.cache_stats().values())


class ExecutableCache:
    """Bounded LRU of ``CacheEntry``, with hit/miss/eviction counters."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._entries: OrderedDict[BucketKey, CacheEntry] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._per_key: dict[BucketKey, dict] = {}

    def _counters(self, key: BucketKey) -> dict:
        return self._per_key.setdefault(
            key, {"hits": 0, "misses": 0, "evictions": 0, "compile_s": 0.0})

    def contains(self, key: BucketKey) -> bool:
        """Residency check WITHOUT touching LRU order or counters (the
        scheduler peeks constantly; only dispatches should count)."""
        return key in self._entries

    def lookup(self, key: BucketKey) -> CacheEntry | None:
        """Dispatch-path lookup: counts a hit and refreshes LRU order."""
        ent = self._entries.get(key)
        if ent is None:
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        self._counters(key)["hits"] += 1
        ent.batches_served += 1
        return ent

    def record_miss(self, key: BucketKey) -> None:
        """A bucket needed a non-resident executable; the service pairs
        every miss with exactly one compile-then-admit."""
        self.misses += 1
        self._counters(key)["misses"] += 1

    def insert(self, entry: CacheEntry) -> list[BucketKey]:
        """Admit a compiled entry; returns the evicted keys (if any)."""
        self._entries[entry.key] = entry
        self._entries.move_to_end(entry.key)
        self._counters(entry.key)["compile_s"] += entry.compile_seconds()
        evicted = []
        while len(self._entries) > self.capacity:
            k, dropped = self._entries.popitem(last=False)
            del dropped
            self.evictions += 1
            self._counters(k)["evictions"] += 1
            evicted.append(k)
        return evicted

    def clear(self) -> list[BucketKey]:
        """Drop every resident entry WITHOUT counting evictions — the
        device-loss path: the executables were compiled against a dead
        topology, so dropping them is a correctness act, not an LRU
        capacity decision.  Counters survive (the recompiles that follow
        are honest misses).  Returns the dropped keys."""
        keys = list(self._entries)
        self._entries.clear()
        return keys

    def stats(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "entries": len(self._entries),
            "capacity": self.capacity,
            "per_bucket": {k.short(): dict(v)
                           for k, v in self._per_key.items()},
        }
