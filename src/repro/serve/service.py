"""The serving loop: continuous batching over the executable cache.

The scheduling analogue of the paper's task-based-over-fork-join thesis:
instead of one homogeneous ``solve_batched`` call (fork-join over a fixed
batch), a *stream* of heterogeneous requests keeps the machine busy —
warm buckets dispatch while cold buckets compile off to the side, and a
preemption costs a re-enqueue, not the queue.

One ``step()`` is one scheduling action:

  1. admit any finished compiles into the LRU cache;
  2. for every pending bucket with no resident executable, record a cache
     miss and start its compile (a background thread by default, so a
     cold bucket never stalls a warm one);
  3. dispatch one padded batch from the warmest pending bucket (the one
     whose head request has waited longest), or — if nothing is warm —
     block on the oldest in-flight compile.

Dispatches pad to the bucket's fixed ``max_batch`` with zero lanes (a
zero RHS converges at iteration 0 and is masked out by the batched
while-loop), so each bucket compiles **exactly once** — verified against
``SolverSession.cache_stats()`` by the tests and the CI gate.

Preemption recovery: with ``recovery_dir`` set, every dispatch first
journals its in-flight batch (request ids + RHS payloads) as a
``runtime/checkpoint.py`` write-ahead entry.  An injected preemption
(``runtime.monitor.FailureInjector``) mid-solve restores the batch *from
disk* and re-enqueues it at the front of its bucket — zero dropped
requests, bitwise-identical results (solves are deterministic, so a
re-run is indistinguishable from an uninterrupted one).  A service that
starts over a dead process's WAL re-admits the orphaned batches via
:meth:`SolverService.recover`; the WAL holds global host arrays, so the
recovering service may run a different topology than the one that died
(``runtime/elastic.py::reshard_array`` places them onto the current
mesh).

Self-healing (repro.resilience, all default-off): a bucket whose compile
FAILS is marked broken and its requests — queued and future — become
typed :class:`ServeReject`\\ s instead of stranding forever behind a
compile that will never land; requests carry deadlines and are rejected
at dispatch once expired; transient dispatch failures retry in place
with exponential backoff + seeded jitter before falling back to the WAL
requeue; with ``guards`` on, lanes whose solve exits breakdown/diverged/
stagnated are quarantined as rejects (one poisoned RHS must not ship a
NaN ``x`` nor take the batch down); ``DeviceLost`` shrinks the mesh
(``runtime/elastic.py::shrink_mesh``), drops every resident executable
(compiled against the dead topology) and replays the in-flight batch
from the WAL onto the surviving devices.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
from concurrent.futures import ThreadPoolExecutor, wait as futures_wait

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.methods import status_name
from repro.obs import trace as obs
from repro.runtime import checkpoint as ckpt
from repro.runtime.elastic import reshard_array, shrink_mesh
from repro.runtime.monitor import (DeviceLost, FailureInjector,
                                   SimulatedFailure)
from repro.serve.cache import CacheEntry, ExecutableCache, session_for
from repro.serve.metrics import ServeMetrics
from repro.serve.queue import BucketKey, Request, RequestQueue

#: per-lane statuses the poison quarantine rejects (guards on)
_POISON_STATUSES = ("breakdown", "diverged", "stagnated")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Service knobs.  ``max_batch`` is the padded in-flight batch size
    every bucket compiles at (one executable per bucket); ``async_compile``
    runs compiles on a background thread (compile-then-admit);
    ``recovery_dir`` enables the write-ahead journal.

    Resilience knobs (all default-off — the default service is bitwise
    the pre-resilience one): ``guards`` arms per-lane breakdown guards
    and the poison quarantine; ``default_deadline_s`` applies to requests
    that declare none; ``max_retries``/``retry_backoff_s``/``retry_jitter``
    bound the in-place dispatch retry (exponential backoff, jitter drawn
    from a ``retry_seed``-seeded RNG — chaos runs are reproducible);
    ``mesh`` pins the bucket executables' topology and enables the
    device-loss shrink-and-resume path."""

    max_batch: int = 4
    cache_capacity: int = 8
    max_queue_depth: int | None = None
    async_compile: bool = True
    recovery_dir: str | None = None
    pallas: bool = False
    guards: bool = False
    default_deadline_s: float | None = None
    max_retries: int = 0
    retry_backoff_s: float = 0.05
    retry_jitter: float = 0.5
    retry_seed: int = 0
    mesh: object | None = None


@dataclasses.dataclass
class ServeResult:
    """One completed request, as the client sees it."""

    id: int
    bucket: str
    x: np.ndarray
    iters: int
    res_norm: float
    latency_s: float
    requeues: int
    #: the solve's typed exit (``repro.core.methods.STATUS_NAMES``);
    #: with ``ServeConfig.guards`` on, poisoned lanes never get here —
    #: they become :class:`ServeReject` instead
    status: str = "converged"


@dataclasses.dataclass
class ServeReject:
    """One request the service refused to (or could not) serve, with a
    machine-readable reason: ``"compile_failed"`` (its bucket's
    executable never built), ``"deadline"`` (expired in queue) or
    ``"poisoned"`` (its solve lane exited breakdown/diverged/stagnated
    under ``ServeConfig.guards``)."""

    id: int
    bucket: str
    reason: str
    detail: str
    latency_s: float


class SolverService:
    def __init__(self, config: ServeConfig | None = None, *,
                 injector: FailureInjector | None = None):
        self.config = config or ServeConfig()
        self.queue = RequestQueue(max_depth=self.config.max_queue_depth)
        self.cache = ExecutableCache(self.config.cache_capacity)
        self.metrics = ServeMetrics()
        self.injector = injector
        self._results: dict[int, ServeResult] = {}
        self._rejects: dict[int, ServeReject] = {}
        self._failed: dict[BucketKey, str] = {}   # broken buckets -> detail
        self._mesh = self.config.mesh             # shrinks on device loss
        self._retry_rng = np.random.default_rng(self.config.retry_seed)
        self._compiling: dict[BucketKey, object] = {}   # key -> Future
        self._pool = (ThreadPoolExecutor(max_workers=1,
                                         thread_name_prefix="serve-compile")
                      if self.config.async_compile else None)
        self._seq = 0

    # -- client surface -------------------------------------------------------
    def submit(self, req: Request) -> int:
        now = time.monotonic()
        try:
            rid = self.queue.admit(req, now=now)
        except Exception:
            self.metrics.rejected += 1
            raise
        # serve lifecycle events (repro.obs): admit -> queue_wait ->
        # compile -> dispatch -> complete, all on the one schema
        self.metrics.record_submit(now, bucket=req.key().short(), rid=rid)
        self.metrics.record_queue_depth(self.queue.depth())
        return rid

    def results(self) -> dict[int, ServeResult]:
        return self._results

    def rejects(self) -> dict[int, ServeReject]:
        """Requests the service refused with a typed reason — the client-
        visible complement of :meth:`results` (every admitted id ends up
        in exactly one of the two once the queue drains)."""
        return self._rejects

    def run_until_drained(self) -> dict[int, ServeResult]:
        while self.step():
            pass
        return self._results

    def snapshot(self) -> dict:
        return self.metrics.snapshot(cache_stats=self.cache.stats(),
                                     queue_depth=self.queue.depth())

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)

    # -- the scheduling step --------------------------------------------------
    def step(self) -> bool:
        """One scheduling action; returns False when fully drained."""
        self._admit_ready_compiles(block=False)
        # a broken bucket (compile failed) rejects everything queued for
        # it, including requests submitted after the failure — they would
        # otherwise strand behind a compile that will never land
        for k in [k for k in self.queue.buckets() if k in self._failed]:
            self._drain_failed(k)
        keys = self.queue.buckets()
        if not keys:
            if self._compiling:
                self._admit_ready_compiles(block=True)
                return True
            return False
        warm = [k for k in keys if self.cache.contains(k)]
        for k in keys:
            if not self.cache.contains(k) and k not in self._compiling:
                # the miss event: this bucket's traffic needs a compile
                self.cache.record_miss(k)
                self._start_compile(k)
        if warm:
            self._dispatch(warm[0])
            return True
        self._admit_ready_compiles(block=True)
        return True

    # -- compile-then-admit ---------------------------------------------------
    def _build_entry(self, key: BucketKey) -> CacheEntry:
        # runs on the compile pool's thread: the span starts its own root
        # there (per-thread parent tracking), labelled by bucket
        with obs.span("serve.compile", bucket=key.short(),
                      batch=self.config.max_batch):
            if self.injector is not None:
                self.injector.maybe_fail_compile(key)
            session = session_for(key, pallas=self.config.pallas,
                                  mesh=self._mesh,
                                  guards=self.config.guards)
            session.compile_batched(self.config.max_batch)
        return CacheEntry(key, session, self.config.max_batch)

    def _start_compile(self, key: BucketKey) -> None:
        if self._pool is None:
            try:
                entry = self._build_entry(key)
            except Exception as e:
                self._fail_bucket(key, e)
                return
            self.cache.insert(entry)
            return
        self._compiling[key] = self._pool.submit(self._build_entry, key)

    def _admit_ready_compiles(self, *, block: bool) -> None:
        if not self._compiling:
            return
        done = [k for k, f in self._compiling.items() if f.done()]
        if block and not done:
            # wait without .result(): a failed compile must become a
            # per-bucket reject below, not an exception on the scheduler
            oldest = next(iter(self._compiling))
            futures_wait([self._compiling[oldest]])
            done = [k for k, f in self._compiling.items() if f.done()]
        for k in done:
            fut = self._compiling.pop(k)
            try:
                entry = fut.result()
            except Exception as e:
                self._fail_bucket(k, e)
                continue
            self.cache.insert(entry)

    def _fail_bucket(self, key: BucketKey, exc: Exception) -> None:
        """A bucket's executable will never build: mark it broken and
        convert its queued requests into typed rejects (the pre-resilience
        behaviour stranded them forever behind the dead compile)."""
        detail = f"{type(exc).__name__}: {exc}"
        self._failed[key] = detail
        obs.event("serve.compile_failed", bucket=key.short(), detail=detail)
        self._drain_failed(key)

    def _drain_failed(self, key: BucketKey) -> None:
        detail = self._failed[key]
        now = time.monotonic()
        while True:
            reqs = self.queue.next_batch(key, self.config.max_batch)
            if not reqs:
                break
            for r in reqs:
                self._reject(r, key, "compile_failed", detail, now)
        self.metrics.record_queue_depth(self.queue.depth())

    # -- dispatch + recovery --------------------------------------------------
    def _reject(self, r: Request, key: BucketKey, reason: str, detail: str,
                now: float) -> None:
        self._rejects[r.id] = ServeReject(
            id=r.id, bucket=key.short(), reason=reason, detail=detail,
            latency_s=now - r.t_submit if r.t_submit is not None else 0.0)
        self.metrics.record_reject(key.short(), reason, rid=r.id)

    def _expire_deadlines(self, key: BucketKey, reqs: list[Request],
                          now: float) -> list[Request]:
        live = []
        for r in reqs:
            dl = (r.deadline_s if r.deadline_s is not None
                  else self.config.default_deadline_s)
            if dl is not None and now - r.t_submit > dl:
                self._reject(r, key, "deadline",
                             f"queued {now - r.t_submit:.3f}s > "
                             f"deadline {dl}s", now)
            else:
                live.append(r)
        return live

    def _dispatch(self, key: BucketKey) -> None:
        entry = self.cache.lookup(key)
        assert entry is not None, key
        with obs.span("serve.dispatch", bucket=key.short(),
                      batch=entry.batch):
            reqs = self.queue.next_batch(key, entry.batch)
            t_disp = time.monotonic()
            for r in reqs:
                obs.event("serve.queue_wait", id=r.id, bucket=key.short(),
                          wait_s=t_disp - r.t_submit)
            reqs = self._expire_deadlines(key, reqs, t_disp)
            self.metrics.record_queue_depth(self.queue.depth())
            if not reqs:
                return
            session = entry.session
            dtype = np.dtype(session.problem.dtype)
            bs = np.zeros((entry.batch, *key.grid), dtype)
            for i, r in enumerate(reqs):
                bs[i] = np.asarray(r.b, dtype)
            seq = self._seq
            self._seq += 1
            self._wal_write(seq, key, reqs, bs)
            attempt = 0
            while True:
                try:
                    res = session.solve_batched(jnp.asarray(bs))
                    # "mid-solve": the dispatch is in flight (JAX dispatch
                    # is async); a preemption here loses the computed
                    # results
                    if self.injector is not None:
                        self.injector.maybe_fail(seq)
                    res = jax.block_until_ready(res)
                    break
                except DeviceLost as e:
                    # the executable's topology is gone: shrink, drop every
                    # resident entry, replay this batch from the WAL — the
                    # recompile on the surviving devices happens on the
                    # normal compile-then-admit path
                    self._on_device_loss(e, key)
                    self._recover_inflight(seq, key, reqs)
                    return
                except SimulatedFailure:
                    if attempt >= self.config.max_retries:
                        self._recover_inflight(seq, key, reqs)
                        self.metrics.record_preemption(len(reqs))
                        return
                    attempt += 1
                    backoff = (self.config.retry_backoff_s
                               * (2.0 ** (attempt - 1))
                               * (1.0 + self.config.retry_jitter
                                  * float(self._retry_rng.random())))
                    self.metrics.record_retry(key.short(), attempt, backoff)
                    time.sleep(backoff)
            now = time.monotonic()
            for i, r in enumerate(reqs):
                st = (status_name(res.status[i])
                      if res.status is not None else "converged")
                if self.config.guards and st in _POISON_STATUSES:
                    # quarantine: one poisoned lane must not ship a NaN x
                    self._reject(r, key, "poisoned",
                                 f"lane exited with status={st!r} "
                                 f"(res_norm={float(res.res_norm[i]):.3e})",
                                 now)
                    continue
                self._results[r.id] = ServeResult(
                    id=r.id, bucket=key.short(), x=np.asarray(res.x[i]),
                    iters=int(res.iters[i]), res_norm=float(res.res_norm[i]),
                    latency_s=now - r.t_submit, requeues=r.requeues,
                    status=st)
                self.metrics.record_completion(key.short(), now - r.t_submit,
                                               now)
            self._wal_clear(seq)

    def _on_device_loss(self, exc: DeviceLost, key: BucketKey) -> None:
        lost = tuple(getattr(exc, "lost", ()) or ())
        if self._mesh is not None:
            self._mesh = shrink_mesh(self._mesh, lost,
                                     divides=key.grid[-1])
            survivors = int(np.prod(self._mesh.devices.shape))
        else:
            survivors = None
        self.metrics.record_device_loss(len(lost), survivors)
        obs.event("serve.device_loss", bucket=key.short(),
                  lost=list(lost), survivors=survivors)
        # in-flight compiles also target the dead topology: let them
        # finish (the pool thread holds references) and discard them
        if self._compiling:
            futures_wait(list(self._compiling.values()))
            self._compiling.clear()
        self.cache.clear()

    # -- the write-ahead journal ----------------------------------------------
    def _wal_meta_path(self, seq: int) -> str:
        return os.path.join(self.config.recovery_dir, f"wal_{seq:08d}.json")

    @staticmethod
    def _wal_template(key: BucketKey, batch: int, dtype: str):
        np_dtype = np.float64 if dtype == "f64" else np.float32
        return {"ids": np.zeros(batch, np.int64),
                "t_submit": np.zeros(batch, np.float64),
                "requeues": np.zeros(batch, np.int64),
                "bs": np.zeros((batch, *key.grid), np_dtype)}

    def _wal_write(self, seq: int, key: BucketKey, reqs: list[Request],
                   bs: np.ndarray) -> None:
        if self.config.recovery_dir is None:
            return
        state = self._wal_template(key, bs.shape[0], key.dtype)
        state["bs"] = bs
        state["ids"][:] = -1
        for i, r in enumerate(reqs):
            state["ids"][i] = r.id
            state["t_submit"][i] = r.t_submit
            state["requeues"][i] = r.requeues
        os.makedirs(self.config.recovery_dir, exist_ok=True)
        with open(self._wal_meta_path(seq), "w") as f:
            json.dump({"seq": seq, "batch": bs.shape[0], "n": len(reqs),
                       "key": {"grid": list(key.grid),
                               "stencil": key.stencil, "method": key.method,
                               "precond": key.precond, "dtype": key.dtype,
                               "solve_params": [key.solve_params[0],
                                                key.solve_params[1],
                                                key.solve_params[2],
                                                [list(kv) for kv in
                                                 key.solve_params[3]]]}}, f)
        ckpt.save(state, self.config.recovery_dir, step=seq, keep=10 ** 9)

    def _wal_clear(self, seq: int) -> None:
        if self.config.recovery_dir is None:
            return
        shutil.rmtree(os.path.join(self.config.recovery_dir,
                                   f"step_{seq:08d}"), ignore_errors=True)
        try:
            os.remove(self._wal_meta_path(seq))
        except FileNotFoundError:
            pass

    @staticmethod
    def _key_from_meta(meta: dict) -> BucketKey:
        k = meta["key"]
        tol, maxiter, norm_ref, pp = k["solve_params"]
        return BucketKey(grid=tuple(k["grid"]), stencil=k["stencil"],
                         method=k["method"], precond=k["precond"],
                         dtype=k["dtype"],
                         solve_params=(tol, maxiter, norm_ref,
                                       tuple(tuple(kv) for kv in pp)))

    def _requests_from_wal(self, seq: int, key: BucketKey,
                           meta: dict) -> list[Request]:
        """Rebuild the in-flight requests from the journal (the on-disk
        copy is authoritative — the preempted dispatch's memory is gone)."""
        template = self._wal_template(key, meta["batch"], key.dtype)
        state, _ = ckpt.restore(template, self.config.recovery_dir, step=seq)
        tol, maxiter, norm_ref, pp = key.solve_params
        out = []
        for i in range(meta["n"]):
            b = state["bs"][i]
            entry = (self.cache._entries.get(key)
                     if self.cache.contains(key) else None)
            if entry is not None and entry.session.backend.mesh is not None:
                # elastic placement: the WAL is host-global; put the RHS
                # onto whatever mesh THIS service runs (which may differ
                # from the topology that was preempted)
                b = np.asarray(reshard_array(
                    state["bs"][i], entry.session.backend.mesh,
                    entry.session.backend.sharding().spec))
            out.append(Request(
                b=b, method=key.method, stencil=key.stencil,
                precond=key.precond,
                precond_params=dict(pp) if pp else None, dtype=key.dtype,
                tol=tol, maxiter=maxiter, norm_ref=norm_ref,
                id=int(state["ids"][i]),
                t_submit=float(state["t_submit"][i]),
                requeues=int(state["requeues"][i])))
        return out

    def _recover_inflight(self, seq: int, key: BucketKey,
                          reqs: list[Request]) -> None:
        """A dispatch was preempted: put its requests back at the front of
        their bucket.  With the WAL enabled the batch is rebuilt from disk
        (exercising the real restore path); without it, from memory."""
        if self.config.recovery_dir is not None:
            with open(self._wal_meta_path(seq)) as f:
                meta = json.load(f)
            reqs = self._requests_from_wal(seq, key, meta)
            self._wal_clear(seq)
        self.queue.requeue_front(key, reqs)

    def recover(self) -> dict[int, int]:
        """Cold-start recovery: scan ``recovery_dir`` for journal entries a
        dead process left behind and re-admit their requests (front of
        queue, fresh ids, ``t_submit`` reset to now — queue-wait before the
        death is not double-counted).  Returns ``{old_id: new_id}``."""
        remap: dict[int, int] = {}
        d = self.config.recovery_dir
        if d is None or not os.path.isdir(d):
            return remap
        metas = sorted(fn for fn in os.listdir(d)
                       if fn.startswith("wal_") and fn.endswith(".json"))
        for fn in metas:
            with open(os.path.join(d, fn)) as f:
                meta = json.load(f)
            key = self._key_from_meta(meta)
            seq = meta["seq"]
            for r in self._requests_from_wal(seq, key, meta):
                old = r.id
                r.id = None
                r.requeues += 1
                new = self.queue.admit(r, now=time.monotonic())
                remap[old] = new
            self._wal_clear(seq)
        return remap
