"""Gradient compression with error feedback (cross-pod DP traffic).

Multi-pod data parallelism pays one gradient all-reduce over the (slow) DCI
per step.  The classic mitigation stack, implemented here:

  * int8 quantisation with per-leaf scale (8x traffic reduction),
  * error feedback (EF-SGD): the quantisation residual is carried into the
    next step, preserving convergence to first order,
  * (wired in train.py as the ``grad_transform`` hook of the train step; the
    within-pod reduction stays f32 — only the pod-axis traffic is compressed,
    mirroring hierarchical MPI_Allreduce implementations).

The quantise/dequantise pair is exact enough that tests assert (i) EF makes
the *accumulated* applied gradient track the true sum, and (ii) turning it
off reproduces plain AdamW trajectories.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return q.astype(dtype) * scale


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_ef_int8_transform():
    """A ``grad_transform`` for steps.make_train_step.

    grads' = dequant(quant(grads + ef)); ef' = (grads + ef) - grads'.
    """

    def transform(grads, ef_state):
        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            q, s = quantize_int8(g32)
            gq = dequantize_int8(q, s)
            return gq.astype(g.dtype), g32 - gq

        out = jax.tree.map(one, grads, ef_state)
        new_grads = jax.tree.map(lambda t: t[0], out,
                                 is_leaf=lambda t: isinstance(t, tuple))
        new_ef = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        return new_grads, new_ef

    return transform
