"""Sharding rules: params (tensor×FSDP), activations, caches, optimizer state.

Scheme (DESIGN.md §5): tensor parallelism over ``model`` (heads / ffn / expert
dim), FSDP over ``data`` (the other weight dim), pure data parallelism over
``pod`` (params replicated across pods — gradients cross the DCI once per
step, which the overlapped-psum trick hides; see distributed/compression.py
for the int8 cross-pod path).

Rules are keyed on the *leaf name* (rightmost dict key), specified from the
rightmost dims; leading stacked-layer dims are padded with None.  GSPMD pads
non-divisible dims (e.g. llama4's 40 heads over 16 shards) — the padding
waste is accounted in EXPERIMENTS.md.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# leaf name -> spec of the LAST len(spec) dims
_RULES: dict[str, tuple] = {
    # embeddings / head
    "embed": ("model", "data"),
    "lm_head": ("model", "data"),
    # attention: K/V projections replicated over model (small; keeps GQA
    # logits head-sharded — see models/attention._sdpa)
    "wq": ("data", "model"),
    "wk": ("data", None),
    "wv": ("data", None),
    "wo": ("model", "data"),
    "bq": ("model",),
    "bk": (None,),
    "bv": (None,),
    "q_norm": (None,),
    "k_norm": (None,),
    # mlp
    "w_in": ("data", "model"),
    "w_out": ("model", "data"),
    # moe (leading E dim sharded over model = expert parallelism)
    "router": ("data", None),
    "moe_w_in": ("model", "data", None),
    "moe_w_out": ("model", None, "data"),
    "shared_in": ("data", "model"),
    "shared_out": ("model", "data"),
    # ssm (split projections; z/x head-sharded over model, B/C/dt replicated)
    "w_z": ("data", "model"),
    "w_x": ("data", "model"),
    "w_B": ("data", None),
    "w_C": ("data", None),
    "w_dt": ("data", None),
    "out_proj": ("model", "data"),
    "conv_x": (None, "model"),
    "conv_B": (None, None),
    "conv_C": (None, None),
    "conv_bx": ("model",),
    "conv_bB": (None,),
    "conv_bC": (None,),
    "A_log": (None,),
    "D": (None,),
    "dt_bias": (None,),
}

_REPLICATED_SUFFIXES = ("norm", "scale", "bias_norm")


def _leaf_rule(path) -> tuple | None:
    keys = [str(getattr(p, "key", p)) for p in path]
    name = keys[-1]
    # moe expert weights share names with mlp weights; disambiguate via parent
    if len(keys) >= 2 and keys[-2] == "moe" and name in ("w_in", "w_out"):
        return _RULES["moe_" + name]
    if name in _RULES:
        return _RULES[name]
    if "norm" in name:
        return ()
    return None


def _divisible(spec_tuple, shape, mesh: Mesh):
    """Drop (replicate) any axis that does not divide its dim — pjit
    in_shardings require exact divisibility (e.g. hymba's vocab 32001)."""
    out = []
    for dim, a in zip(shape, spec_tuple):
        if a is None:
            out.append(None)
            continue
        axes = a if isinstance(a, tuple) else (a,)
        size = 1
        for ax in axes:
            size *= mesh.shape[ax]
        out.append(a if dim % size == 0 else None)
    return tuple(out)


def param_specs(params: Any, mesh: Mesh, cfg=None) -> Any:
    """PartitionSpec pytree for a params/grads/moments tree.

    ``cfg`` enables arch-dependent rules: MHA (n_kv_heads == n_heads) shards
    the K/V projections over the tensor axis like Q (see
    models/attention._project_qkv); GQA keeps them replicated.
    """
    mha = cfg is not None and getattr(cfg, "n_kv_heads", 0) == getattr(
        cfg, "n_heads", -1)

    def spec(path, leaf):
        rule = _leaf_rule(path)
        name = str(getattr(path[-1], "key", path[-1]))
        if mha and name in ("wk", "wv"):
            rule = ("data", "model")
        if mha and name in ("bk", "bv"):
            rule = ("model",)
        rank = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
        if rule is None or rule == ():
            return P()
        pad = rank - len(rule)
        full = ((None,) * pad) + tuple(rule)
        return P(*_divisible(full, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec, params)


def param_shardings(params: Any, mesh: Mesh, cfg=None) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh, cfg))


def dp_axes_of(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def recommended_dp_axes(cfg, mesh: Mesh) -> tuple[str, ...]:
    """Per-arch parallelism profile (EXPERIMENTS.md §Perf-2b).

    Small-d dense/ssm/hybrid archs waste the tensor axis: 16-way TP+SP moves
    ~4·B·S·d of activations per layer while their per-layer weights are tiny —
    measured 5-6x more collective bytes than a pure-FSDP layout that shards
    the batch over BOTH axes and all-gathers the (small) weights instead.
    MoE archs keep the tensor axis (expert parallelism needs it), as do
    large-d dense models where weight traffic dominates.
    """
    if cfg.family == "moe" or cfg.d_model > 2304:
        return dp_axes_of(mesh)
    return tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)


def _longest_divisible(axes: tuple[str, ...], dim: int, mesh: Mesh):
    """Longest prefix of ``axes`` whose product divides ``dim``."""
    out = []
    size = 1
    for a in axes:
        if dim % (size * mesh.shape[a]) == 0:
            out.append(a)
            size *= mesh.shape[a]
        else:
            break
    if not out:
        return None
    return tuple(out) if len(out) > 1 else out[0]


def batch_specs(batch: Any, mesh: Mesh,
                dp_axes: tuple[str, ...] | None = None) -> Any:
    """Input sharding: batch dims over the dp axes; caches split heads/cache
    over model where profitable."""
    dp = dp_axes if dp_axes is not None else dp_axes_of(mesh)
    tp_in_dp = "model" in dp

    def spec(path, leaf):
        keys = [str(getattr(p, "key", p)) for p in path]
        name = keys[-1]
        shape = leaf.shape
        if name == "cur_pos" or len(shape) == 0:
            return P()
        if name == "pos":                     # (L, C) slot positions
            return P()
        # batch dims take the longest divisible prefix of the dp axes
        bdim = shape[1] if len(shape) >= 4 or name == "cross_kvs" else shape[0]
        if name == "positions" and len(shape) == 3:
            bdim = shape[1]
        bspec = _longest_divisible(dp, bdim, mesh) if dp else None
        cache_tp = None if tp_in_dp else "model"
        raw = None
        if "caches" in keys or name in ("k", "v", "state", "conv"):
            if name in ("k", "v"):            # (L, B, C, KV, hd)
                raw = (None, bspec, cache_tp, None, None)
            elif name == "state":             # (L, B, nH, P, N)
                raw = (None, bspec, cache_tp, None, None)
            elif name == "conv":              # (L, B, K-1, ch) mixed channels
                raw = (None, bspec, None, None)
        if raw is None and (name == "cross_kvs" or len(shape) == 5):
            raw = (None, bspec, cache_tp, None, None)    # (L,B,T,KV,hd)
        if raw is None and name == "positions" and len(shape) == 3:
            raw = (None, bspec, None)                    # mrope (3, B, S)
        if raw is None and len(shape) == 3:
            raw = (bspec, None, None)                    # embeds (B, S, d)
        if raw is None and len(shape) == 2:
            raw = (bspec, None)                          # tokens/targets
        if raw is None:
            return P()
        return P(*_divisible(raw, shape, mesh))

    return jax.tree_util.tree_map_with_path(spec, batch)


def batch_shardings(batch: Any, mesh: Mesh,
                    dp_axes: tuple[str, ...] | None = None) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        batch_specs(batch, mesh, dp_axes))


def make_hint(mesh: Mesh | None, dp_axes: tuple[str, ...]):
    """Activation sharding-constraint helper.

    FSDP shards each weight's contraction dim over ``data`` — the same axis
    that shards the batch.  Without explicit activation constraints GSPMD may
    resolve the conflict by un-sharding the *activations* (measured: 4 GiB
    replicated rope buffers per device, EXPERIMENTS.md §Perf).  ``hint(x,
    *tail)`` pins ``x`` to P(dp, None, *tail) so the (small) weights get
    gathered instead.
    """
    if mesh is None or mesh.devices.size == 1:
        return lambda x, *tail: x
    dp = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)

    def hint(x, *tail):
        # in the pure-FSDP profile the tensor axis belongs to dp — drop it
        # from feature-dim tails (an axis cannot appear twice in a spec)
        tail = tuple(None if (t is not None and t in dp_axes) else t
                     for t in tail)
        bdim = x.shape[0]
        d = dp
        if isinstance(dp, tuple):
            size = 1
            keep = []
            for a in dp:
                if bdim % (size * mesh.shape[a]) == 0:
                    keep.append(a)
                    size *= mesh.shape[a]
                else:
                    break
            d = tuple(keep) if len(keep) > 1 else (keep[0] if keep else None)
        elif dp is not None and bdim % mesh.shape[dp]:
            d = None
        spec = P(d, *((None,) * (x.ndim - 1 - len(tail))), *tail)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return hint


def opt_state_specs(opt_state, params_spec) -> Any:
    """AdamWState(step, m, v): moments shard like params."""
    from repro.optim.adamw import AdamWState
    return AdamWState(step=P(), m=params_spec, v=params_spec)
