"""AST lint over every registered :class:`MethodDef` body.

PR 5 made one definition per method drive four backends; the flip side is
that one *bad idiom* in a definition now breaks four backends — usually not
at registration but deep inside a shard_map trace, as an opaque tracer
error, or (worst) silently on just one backend.  This pass rejects the four
idioms with exactly that failure mode, at lint time:

* **Python branching on traced state** (``if rr < tol:`` inside ``step``):
  works under eager numpy-like debugging, raises a ``TracerBoolConversionError``
  under ``jit``, and would change the compiled collective schedule per
  branch if it traced.  Control flow on traced values belongs in
  ``lax.cond``/``lax.while_loop`` (the generic driver owns the loop).

* **Closures over mutable globals**: a list/dict/set captured by a method
  body is invisible re-entrant state — two sessions compiling the same
  method could observe each other's mutations.  All tuning knobs go through
  ``ops.params`` (declared in ``MethodDef.params``).

* **Calls outside the operator protocol**: the body may touch only the
  declared ``Ops``/operator surface (``OPS_PROTOCOL``/``OPERATOR_PROTOCOL``
  in ``repro.core.methods``).  Anything else — say ``ops.A.layout.mesh`` —
  couples the definition to one backend and breaks the
  write-once/parallelise-underneath contract.

* **State-layout mismatches**: the declared ``vectors``/``scalars`` must be
  exactly what ``init`` produces and ``step`` preserves (shape AND dtype —
  ``lax.while_loop`` requires a stable carry).  Verified abstractly via
  ``jax.eval_shape`` on a tiny local problem; no kernels execute.

Scope note: the lint sees the registered functions' own ASTs (including
factory-made closures), not helpers they call — helpers are shared across
methods and covered by the backends' parity tests.
"""

from __future__ import annotations

import ast
import inspect
import textwrap

from repro.analysis.violation import Violation
from repro.core.methods import (
    METHODS,
    OPERATOR_PROTOCOL,
    OPS_PROTOCOL,
    MethodDef,
    Ops,
)

#: dict-protocol attrs allowed on ``ops.params`` (a plain dict of knobs)
_PARAMS_ATTRS = frozenset({"get", "items", "keys", "values"})

_MUTABLE_TYPES = (list, dict, set, bytearray)

_FN_KINDS = ("init", "step", "finalize", "fused_init", "fused_step",
             "guard", "refresh")


def _method_functions(mdef: MethodDef):
    for kind in _FN_KINDS:
        fn = getattr(mdef, kind)
        if fn is not None:
            yield kind, fn


def _function_node(fn) -> ast.FunctionDef:
    src = textwrap.dedent(inspect.getsource(fn))
    tree = ast.parse(src)
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            return node
    raise ValueError(f"no function definition found in source of {fn!r}")


def _names(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _attr_chain(node: ast.Attribute) -> list[str] | None:
    """``ops.A.base.matvec`` -> ["ops", "A", "base", "matvec"]; None when the
    chain is not rooted at a plain name (e.g. a subscript)."""
    parts: list[str] = []
    cur: ast.AST = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return parts[::-1]
    return None


def _where(mdef_name: str, kind: str, fn, node: ast.AST) -> str:
    line = fn.__code__.co_firstlineno + getattr(node, "lineno", 1) - 1
    return f"{fn.__code__.co_filename}:{line} ({mdef_name}.{kind})"


def _assign_targets(stmt: ast.AST) -> list[ast.AST]:
    if isinstance(stmt, ast.Assign):
        return list(stmt.targets)
    if isinstance(stmt, (ast.AugAssign, ast.AnnAssign, ast.For)):
        return [stmt.target]
    return []


def _rhs(stmt: ast.AST) -> ast.AST | None:
    if isinstance(stmt, (ast.Assign, ast.AugAssign)):
        return stmt.value
    if isinstance(stmt, ast.AnnAssign):
        return stmt.value
    if isinstance(stmt, ast.For):
        return stmt.iter
    return None


def _ops_rooted_value(node: ast.AST, ops_name: str) -> bool:
    """Does the expression read the ops context (anything but ``ops.params``)?

    Every such read — ``ops.b``, ``ops.matvec(p)``, ``ops.dotn(...)`` —
    yields a traced value inside jit, so it taints its targets.  Only the
    static knob dict ``ops.params`` is exempt.
    """
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute):
            chain = _attr_chain(sub)
            if chain and chain[0] == ops_name and chain[1:2] != ["params"]:
                return True
    return False


def _tainted_names(fdef: ast.FunctionDef, ops_name: str) -> set[str]:
    """Names carrying traced values: the non-ops parameters (state/x0) plus
    everything transitively assigned from them or from ops reads."""
    tainted = {a.arg for a in fdef.args.args if a.arg != ops_name}
    changed = True
    while changed:
        changed = False
        for stmt in ast.walk(fdef):
            rhs = _rhs(stmt)
            if rhs is None:
                continue
            if _names(rhs) & tainted or _ops_rooted_value(rhs, ops_name):
                for tgt in _assign_targets(stmt):
                    new = _names(tgt) - tainted
                    if new:
                        tainted |= new
                        changed = True
    return tainted


def _branch_violations(mdef_name, kind, fn, fdef, ops_name) -> list[Violation]:
    tainted = _tainted_names(fdef, ops_name)
    out = []
    for node in ast.walk(fdef):
        if not isinstance(node, (ast.If, ast.While, ast.IfExp, ast.Assert)):
            continue
        test = node.test
        hot = (_names(test) & tainted) or _ops_rooted_value(test, ops_name)
        if hot:
            out.append(Violation(
                pass_name="lint_methods",
                subject=f"method:{mdef_name}",
                field="traced_branch",
                expected="lax.cond/lax.while_loop for traced control flow",
                actual=f"Python {type(node).__name__} on traced value(s) "
                       f"{sorted(_names(test) & tainted)}",
                detail=_where(mdef_name, kind, fn, node)))
    return out


def _closure_violations(mdef_name, kind, fn) -> list[Violation]:
    out = []
    if fn.__closure__:
        for var, cell in zip(fn.__code__.co_freevars, fn.__closure__):
            try:
                val = cell.cell_contents
            except ValueError:      # unfilled cell
                continue
            if isinstance(val, _MUTABLE_TYPES):
                out.append(Violation(
                    pass_name="lint_methods",
                    subject=f"method:{mdef_name}",
                    field="mutable_closure",
                    expected="immutable captures (pass knobs via ops.params)",
                    actual=f"closure over {type(val).__name__} {var!r}",
                    detail=f"{fn.__code__.co_filename} ({mdef_name}.{kind})"))
    g = fn.__globals__
    for name in fn.__code__.co_names:
        if name in g and isinstance(g[name], _MUTABLE_TYPES):
            out.append(Violation(
                pass_name="lint_methods",
                subject=f"method:{mdef_name}",
                field="mutable_global",
                expected="immutable globals (pass knobs via ops.params)",
                actual=f"reads mutable global {name!r} "
                       f"({type(g[name]).__name__})",
                detail=f"{fn.__code__.co_filename} ({mdef_name}.{kind})"))
    return out


def _protocol_violations(mdef_name, kind, fn, fdef, ops_name) -> list[Violation]:
    out = []
    seen: set[tuple] = set()
    for node in ast.walk(fdef):
        if not isinstance(node, ast.Attribute):
            continue
        chain = _attr_chain(node)
        if not chain or chain[0] != ops_name or len(chain) < 2:
            continue
        attr, allowed, depth = None, None, None
        if len(chain) == 2:
            attr, allowed, depth = chain[1], OPS_PROTOCOL, "ops"
        elif chain[1] == "A" and len(chain) >= 3:
            # ops.A.x and ops.A.base.x are both operator-protocol surface
            i = 3 if chain[2] == "base" and len(chain) >= 4 else 2
            attr, allowed, depth = chain[i], OPERATOR_PROTOCOL, "ops.A"
        elif chain[1] == "params" and len(chain) >= 3:
            attr, allowed, depth = chain[2], _PARAMS_ATTRS, "ops.params"
        if attr is not None and attr not in allowed and (depth, attr) not in seen:
            seen.add((depth, attr))
            out.append(Violation(
                pass_name="lint_methods",
                subject=f"method:{mdef_name}",
                field="protocol_escape",
                expected=f"{depth}.<attr> with attr in the declared protocol",
                actual=f"{depth}.{attr}",
                detail=_where(mdef_name, kind, fn, node)))
    return out


# --- state layout ------------------------------------------------------------

_LAYOUT_GRID = (4, 4, 4)


def _layout_ops(fused: bool):
    from repro.core.problems import make_problem
    from repro.core.solvers import LocalOp

    import jax.numpy as jnp

    prob = make_problem(_LAYOUT_GRID, "7pt")
    A = LocalOp(prob.stencil)
    if fused:
        from repro.kernels.pallas_op import PallasOp
        A = PallasOp(A)
    b = jnp.ones(prob.shape, prob.dtype)
    return Ops(A, b, norm_ref=1.0), prob


def _layout_violations(mdef: MethodDef) -> list[Violation]:
    """Declared ``vectors``/``scalars`` vs what init/step abstractly produce."""
    import jax

    out = []
    for fused in (False, True):
        if fused and not mdef.has_fused_body:
            continue
        init = mdef.fused_init if fused else mdef.init
        step = mdef.fused_step if fused else mdef.step
        tag = f"method:{mdef.name}" + ("|pallas" if fused else "")
        ops, prob = _layout_ops(fused)
        x0 = jax.ShapeDtypeStruct(prob.shape, prob.dtype)
        try:
            state = jax.eval_shape(lambda x: tuple(init(ops, x)), x0)
        except Exception as e:  # noqa: BLE001 — any trace error IS the finding
            out.append(Violation(
                "lint_methods", tag, "state_layout",
                expected="init traces under eval_shape",
                actual=f"{type(e).__name__}: {e}"))
            continue
        nv, ns = len(mdef.vectors), len(mdef.scalars)
        if len(state) != nv + ns:
            out.append(Violation(
                "lint_methods", tag, "state_layout",
                expected=f"{nv} vectors + {ns} scalars "
                         f"({mdef.vectors} + {mdef.scalars})",
                actual=f"init produced {len(state)} slots"))
            continue
        for i, sds in enumerate(state):
            want = prob.shape if i < nv else ()
            slot = (mdef.vectors + mdef.scalars)[i]
            if tuple(sds.shape) != tuple(want):
                out.append(Violation(
                    "lint_methods", tag, "state_layout",
                    expected=f"slot {slot!r} shape {want}",
                    actual=f"shape {tuple(sds.shape)}"))
        try:
            stepped = jax.eval_shape(lambda s: tuple(step(ops, s)), state)
        except Exception as e:  # noqa: BLE001
            out.append(Violation(
                "lint_methods", tag, "state_layout",
                expected="step traces under eval_shape",
                actual=f"{type(e).__name__}: {e}"))
            continue
        if [(tuple(s.shape), str(s.dtype)) for s in stepped] != \
           [(tuple(s.shape), str(s.dtype)) for s in state]:
            out.append(Violation(
                "lint_methods", tag, "state_layout",
                expected="step preserves the init state layout "
                         "(lax.while_loop carry stability)",
                actual=f"init {[tuple(s.shape) for s in state]} vs "
                       f"step {[tuple(s.shape) for s in stepped]}"))
    return out


def check_method(mdef: MethodDef, *, layout: bool = True) -> list[Violation]:
    """All lint findings for one MethodDef."""
    out: list[Violation] = []
    for kind, fn in _method_functions(mdef):
        try:
            fdef = _function_node(fn)
        except (OSError, TypeError, ValueError) as e:
            out.append(Violation(
                "lint_methods", f"method:{mdef.name}", "source",
                expected="inspectable Python source for every body",
                actual=f"{type(e).__name__}: {e}", detail=kind))
            continue
        ops_name = fdef.args.args[0].arg if fdef.args.args else "ops"
        out += _branch_violations(mdef.name, kind, fn, fdef, ops_name)
        out += _closure_violations(mdef.name, kind, fn)
        out += _protocol_violations(mdef.name, kind, fn, fdef, ops_name)
    if layout:
        out += _layout_violations(mdef)
    return out


def check_methods(methods: dict[str, MethodDef] | None = None, *,
                  layout: bool = True) -> list[Violation]:
    """Lint every registered MethodDef (or an injected table, for tests)."""
    methods = METHODS if methods is None else methods
    out: list[Violation] = []
    for name in sorted(methods):
        out += check_method(methods[name], layout=layout)
    return out
