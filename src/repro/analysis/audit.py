"""Comms/donation audit: the registry's communication metadata vs compiled HLO.

For every registry method the audit lowers the shard_map *iteration body*
(``solve_step_shardmap`` — one step == one while-loop body, guaranteed by
tests/test_step_parity.py) on three mesh shapes (1-D/2-D/3-D over 8 host
devices), both blocking and overlapped halo modes, with and without the
Pallas fused body where the method declares one, and with a bound Jacobi
preconditioner where it accepts one — then asserts on the compiled HLO:

* ``all-reduce`` count == ``SolverSpec.allreduces_per_iter`` (+ the
  preconditioner's ``extra_reductions_per_apply`` × applies);
* ``collective-permute`` count == halo exchanges × 2 × split dims, where
  halo exchanges = ``halo_exchanges_per_iter`` (+ the preconditioner's
  ``halo_matvecs_per_apply`` × applies);
* **no other collective at all** — an accidental ``all-gather`` (the
  classic symptom of a lost sharding annotation) or an unfused psum pair
  fails the audit by construction;
* collective *bytes* equal to the committed AUDIT.json baseline — counts
  catch structural drift, bytes catch payload drift (a state-layout change
  that keeps the collective count but moves the traffic).

Donation: the whole-solve function is lowered with ``donate_argnums=(1,)``
(exactly what ``SolverSession`` passes when ``SolverOptions.donate`` is
set) and the audit asserts ONE donation annotation with donation on —
``tf.aliasing_output`` on the local path, ``jax.buffer_donor`` once
shardings are attached — and ZERO with it off, for every method on the
local path and both mesh shapes; for representative methods it further
compiles the mesh solve and asserts XLA *granted* the alias
(``input_output_alias`` names parameter 1, i.e. x0's buffer is reused).

Measurements run in a fresh subprocess (`worker_main`) because host-device
count is fixed at jax import; the parent process builds expectations from
the registry and compares.  ``python -m repro.analysis`` drives this.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from repro.analysis.violation import Violation
from repro.api.registry import REGISTRY, SolverSpec

#: audit meshes: name -> (device grid, axis names, # grid dims actually split)
MESHES: dict[str, tuple[tuple[int, ...], tuple[str, ...], int]] = {
    "1d": ((8,), ("cells",), 1),
    "2d": ((2, 4), ("data", "model"), 2),
    "3d": ((2, 2, 2), ("pod", "data", "model"), 3),
}
N_DEVICES = 8
GRID = (8, 8, 16)            # divisible by every audit mesh layout
STENCIL = "27pt"
#: mesh shapes the donation lowering runs on (the ">= 2 mesh shapes" gate)
DONATION_MESHES = ("1d", "2d")
#: methods whose mesh solve is fully compiled to check the granted alias
ALIAS_METHODS = ("cg", "cg_merged", "bicgstab")
#: methods whose whole mesh solve is compiled plain AND guarded to assert
#: the breakdown guards ride existing carry scalars — identical collective
#: counts with guards on (repro.resilience's zero-extra-collectives claim)
GUARD_METHODS = ("cg", "cg_merged", "bicgstab_merged")
#: the preconditioner bound for the precond-accepting methods' extra configs
AUDIT_PRECOND_SWEEPS = 2


def _precond_meta() -> dict[str, int]:
    """Cost metadata of the audit's Jacobi preconditioner instance."""
    from repro.precond import PointJacobi
    p = PointJacobi(sweeps=AUDIT_PRECOND_SWEEPS)
    return {
        "extra_reductions_per_apply": p.extra_reductions_per_apply,
        "halo_matvecs_per_apply": p.halo_matvecs_per_apply,
    }


def comms_jobs(registry: dict[str, SolverSpec] | None = None) -> list[dict]:
    """The comms audit matrix.  Key: ``method|mesh|halo|kernel|precond``."""
    registry = REGISTRY if registry is None else registry
    jobs = []

    def add(method, mesh, halo, kern="xla", prec="none"):
        jobs.append(dict(key=f"{method}|{mesh}|{halo}|{kern}|{prec}",
                         method=method, mesh=mesh, halo=halo,
                         pallas=(kern == "pallas"), precond=prec))

    for name in sorted(registry):
        spec = registry[name]
        add(name, "1d", "concat")
        add(name, "1d", "overlap")
        add(name, "2d", "auto")
        add(name, "3d", "auto")
        if spec.accepts_precond:
            add(name, "1d", "auto", prec="jacobi")
            add(name, "2d", "auto", prec="jacobi")
        if spec.has_fused_body:
            add(name, "1d", "auto", kern="pallas")
            add(name, "2d", "auto", kern="pallas")
    return jobs


def expected_comms(spec: SolverSpec, mesh: str, *,
                   precond: str = "none",
                   precond_meta: dict[str, int] | None = None) -> dict[str, int]:
    """Collective counts the registry metadata predicts for one config."""
    n_split = MESHES[mesh][2]
    allreduce = spec.allreduces_per_iter
    halos = spec.halo_exchanges_per_iter
    if precond != "none":
        meta = precond_meta or _precond_meta()
        allreduce += (spec.precond_applies_per_iter
                      * meta["extra_reductions_per_apply"])
        halos += (spec.precond_applies_per_iter
                  * meta["halo_matvecs_per_apply"])
    return {
        "all-reduce": allreduce,
        "collective-permute": halos * 2 * n_split,
        "all-gather": 0,
        "reduce-scatter": 0,
        "all-to-all": 0,
    }


# =============================================================================
# Measurement worker (runs in a subprocess with 8 host devices)
# =============================================================================

def worker_main() -> None:
    """Measure every job; print one JSON record on the last stdout line.

    Reads an optional JSON filter from stdin: ``{"methods": [...]}``
    restricts the matrix (used by the fast subset test).  Must run in a
    fresh process: host-device count is fixed at jax import.
    """
    import jax
    jax.config.update("jax_enable_x64", True)
    from jax.sharding import NamedSharding

    from repro.analysis.hlo import (
        collective_stats,
        count_collectives,
        donation_markers,
        input_output_aliases,
    )
    from repro.core.compat import make_mesh
    from repro.core.distributed import (
        solve_shardmap,
        solve_step_shardmap,
        step_state_layout,
    )
    from repro.core.methods import Ops, get_method, run_method
    from repro.core.problems import make_problem
    from repro.core.solvers import LocalOp
    from repro.precond import PointJacobi

    raw = sys.stdin.read().strip()
    filt = json.loads(raw) if raw else {}
    methods = filt.get("methods")

    assert jax.device_count() == N_DEVICES, (
        f"worker needs {N_DEVICES} host devices, got {jax.device_count()} — "
        f"run via run_measurements() / the CLI, not directly")

    prob = make_problem(GRID, STENCIL)
    meshes = {name: make_mesh(devs, axes)
              for name, (devs, axes, _) in MESHES.items()}

    def precond_of(name):
        return PointJacobi(sweeps=AUDIT_PRECOND_SWEEPS) if name == "jacobi" \
            else None

    # --- comms: compiled iteration bodies -----------------------------------
    comms = {}
    for job in comms_jobs():
        if methods is not None and job["method"] not in methods:
            continue
        mesh = meshes[job["mesh"]]
        fn, layout = solve_step_shardmap(
            prob, job["method"], mesh, halo_mode=job["halo"],
            precond=precond_of(job["precond"]), pallas_fused=job["pallas"])
        sh = NamedSharding(mesh, layout.spec())
        vecs, scals = step_state_layout(job["method"])
        arr = jax.ShapeDtypeStruct(prob.shape, prob.dtype, sharding=sh)
        scal = jax.ShapeDtypeStruct((), prob.dtype)
        args = [arr] * (1 + len(vecs)) + [scal] * len(scals)
        txt = jax.jit(fn).lower(*args).compile().as_text()
        stats = collective_stats(txt)
        comms[job["key"]] = {
            "counts": {op: s["count"] for op, s in sorted(stats.items())},
            "bytes": sum(s["bytes"] for s in stats.values()),
        }

    # --- donation on the mesh paths (lowered markers) -----------------------
    donate_mesh = {}
    for name in sorted(REGISTRY):
        if methods is not None and name not in methods:
            continue
        for mesh_name in DONATION_MESHES:
            mesh = meshes[mesh_name]
            fn, layout = solve_shardmap(prob, name, mesh, maxiter=5)
            sh = NamedSharding(mesh, layout.spec())
            sds = jax.ShapeDtypeStruct(prob.shape, prob.dtype, sharding=sh)
            rec = {}
            for mode, jit_kw in (("on", dict(donate_argnums=(1,))),
                                 ("off", {})):
                txt = jax.jit(fn, **jit_kw).lower(sds, sds).as_text()
                rec[mode] = donation_markers(txt)
            donate_mesh[f"{name}|{mesh_name}"] = rec

    # --- local path: donation markers + zero collectives + granted alias ----
    local = {}
    for name in sorted(REGISTRY):
        if methods is not None and name not in methods:
            continue
        mdef = get_method(name)
        A = LocalOp(prob.stencil)

        def fn(b, x0, _mdef=mdef, _A=A):
            ops = Ops(_A, b, norm_ref=1.0)
            return run_method(_mdef, ops, x0, tol=1e-6, maxiter=5)

        sds = jax.ShapeDtypeStruct(prob.shape, prob.dtype)
        lowered_on = jax.jit(fn, donate_argnums=(1,)).lower(sds, sds)
        compiled = lowered_on.compile()
        ctext = compiled.as_text()
        local[name] = {
            "markers_on": donation_markers(lowered_on.as_text()),
            "markers_off": donation_markers(jax.jit(fn).lower(sds, sds)
                                            .as_text()),
            "collectives": count_collectives(ctext),
            "aliased_params": input_output_aliases(ctext),
        }

    # --- granted alias on a compiled mesh solve (representative set) --------
    mesh_aliases = {}
    for name in ALIAS_METHODS:
        if methods is not None and name not in methods:
            continue
        mesh = meshes["1d"]
        fn, layout = solve_shardmap(prob, name, mesh, maxiter=5)
        sh = NamedSharding(mesh, layout.spec())
        sds = jax.ShapeDtypeStruct(prob.shape, prob.dtype, sharding=sh)
        ctext = jax.jit(fn, donate_argnums=(1,)).lower(sds, sds).compile() \
                   .as_text()
        mesh_aliases[f"{name}|1d"] = input_output_aliases(ctext)

    # --- guard invariance: arming the guards adds zero collectives ----------
    # The breakdown guards (repro.resilience) must ride scalars the loop
    # already carries post-psum; compile the WHOLE mesh solve plain and
    # guarded (telemetry off, no residual replacement — the raise-policy
    # configuration) and record each one's collective counts.
    from repro.core.methods import GuardSpec
    guard_invariance = {}
    for name in GUARD_METHODS:
        if methods is not None and name not in methods:
            continue
        mesh = meshes["1d"]
        rec = {}
        for mode, gs in (("plain", None), ("guarded", GuardSpec())):
            fn, layout = solve_shardmap(prob, name, mesh, maxiter=5,
                                        guard_spec=gs)
            sh = NamedSharding(mesh, layout.spec())
            sds = jax.ShapeDtypeStruct(prob.shape, prob.dtype, sharding=sh)
            ctext = jax.jit(fn).lower(sds, sds).compile().as_text()
            rec[mode] = count_collectives(ctext)
        guard_invariance[f"{name}|1d"] = rec

    print(json.dumps({"comms": comms, "donate_mesh": donate_mesh,
                      "local": local, "mesh_aliases": mesh_aliases,
                      "guard_invariance": guard_invariance}))


def run_measurements(methods: list[str] | None = None, *,
                     timeout: int = 1200) -> dict:
    """Run :func:`worker_main` in a subprocess with 8 host devices."""
    import repro
    # repro is a namespace package (no __init__.py): locate it via __path__
    pkg_dir = os.path.abspath(list(repro.__path__)[0])      # .../src/repro
    src = os.path.dirname(os.path.dirname(pkg_dir))         # repo root
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{N_DEVICES}").strip()
    env["PYTHONPATH"] = (os.path.join(src, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-c",
         "from repro.analysis.audit import worker_main; worker_main()"],
        input=json.dumps({"methods": methods} if methods else {}),
        capture_output=True, text=True, timeout=timeout, env=env, cwd=src)
    if proc.returncode != 0:
        raise RuntimeError(
            f"audit worker failed (rc={proc.returncode}):\n"
            f"{proc.stderr[-3000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


# =============================================================================
# Comparison: measured vs registry expectations vs committed baseline
# =============================================================================

def compare(measured: dict,
            registry: dict[str, SolverSpec] | None = None,
            baseline: dict | None = None) -> list[Violation]:
    """Every contract breach between measurement, registry and baseline."""
    registry = REGISTRY if registry is None else registry
    meta = _precond_meta()
    out: list[Violation] = []

    # --- comms vs registry ---------------------------------------------------
    for key, rec in sorted(measured.get("comms", {}).items()):
        method, mesh, _halo, _kern, prec = key.split("|")
        spec = registry.get(method)
        if spec is None:
            out.append(Violation("comms", key, "method",
                                 expected="a registered method",
                                 actual="unknown"))
            continue
        want = expected_comms(spec, mesh, precond=prec, precond_meta=meta)
        counts = rec["counts"]
        for op, n in want.items():
            got = counts.get(op, 0)
            if got != n:
                out.append(Violation(
                    "comms", key, op, expected=n, actual=got,
                    detail="registry metadata vs compiled iteration body"))
        for op, got in counts.items():
            if op not in want and got:
                out.append(Violation(
                    "comms", key, op, expected=0, actual=got,
                    detail="unexpected collective opcode in the body"))

    # --- donation ------------------------------------------------------------
    for key, rec in sorted(measured.get("donate_mesh", {}).items()):
        if rec.get("on") != 1:
            out.append(Violation(
                "donation", key, "markers_on", expected=1,
                actual=rec.get("on"),
                detail="donate=True must annotate exactly x0 for donation"))
        if rec.get("off") != 0:
            out.append(Violation(
                "donation", key, "markers_off", expected=0,
                actual=rec.get("off"),
                detail="donate=False must not annotate any argument"))
    for name, rec in sorted(measured.get("local", {}).items()):
        if rec.get("markers_on") != 1:
            out.append(Violation(
                "donation", f"{name}|local", "markers_on", expected=1,
                actual=rec.get("markers_on")))
        if rec.get("markers_off") != 0:
            out.append(Violation(
                "donation", f"{name}|local", "markers_off", expected=0,
                actual=rec.get("markers_off")))
        if rec.get("collectives"):
            out.append(Violation(
                "comms", f"{name}|local", "collectives", expected={},
                actual=rec["collectives"],
                detail="single-device solve must compile collective-free"))
        if rec.get("aliased_params") != [1]:
            out.append(Violation(
                "donation", f"{name}|local", "input_output_alias",
                expected=[1], actual=rec.get("aliased_params"),
                detail="XLA must grant the x0 (param 1) buffer reuse"))
    for key, aliased in sorted(measured.get("mesh_aliases", {}).items()):
        if aliased != [1]:
            out.append(Violation(
                "donation", key, "input_output_alias",
                expected=[1], actual=aliased,
                detail="compiled mesh solve must reuse x0's buffer"))

    # --- guard invariance ----------------------------------------------------
    for key, rec in sorted(measured.get("guard_invariance", {}).items()):
        if rec.get("guarded") != rec.get("plain"):
            out.append(Violation(
                "guard_invariance", key, "collectives",
                expected=rec.get("plain"), actual=rec.get("guarded"),
                detail="arming the breakdown guards must add zero "
                       "collectives (guards ride carried post-psum "
                       "scalars)"))

    # --- drift vs the committed baseline ------------------------------------
    if baseline is not None:
        out += compare_baseline(measured, baseline)
    return out


def compare_baseline(measured: dict, baseline: dict) -> list[Violation]:
    """Exact equality against AUDIT.json (counts AND bytes)."""
    out: list[Violation] = []
    base = baseline.get("measured", baseline)
    for section in ("comms", "donate_mesh", "local", "mesh_aliases"):
        got, want = measured.get(section, {}), base.get(section, {})
        for key in sorted(set(got) | set(want)):
            if key not in want:
                out.append(Violation(
                    "baseline", f"{section}:{key}", "coverage",
                    expected="present in AUDIT.json", actual="new config",
                    detail="rewrite the baseline: make audit-write"))
            elif key not in got:
                out.append(Violation(
                    "baseline", f"{section}:{key}", "coverage",
                    expected=want[key], actual="config no longer measured"))
            elif got[key] != want[key]:
                out.append(Violation(
                    "baseline", f"{section}:{key}", "drift",
                    expected=want[key], actual=got[key],
                    detail="measured HLO drifted from the committed "
                           "baseline"))
    return out
