"""Structural analysis of lowered/compiled HLO.

This is the "profiler" of the dry-run methodology (no real TPU): we parse the
HLO text to (i) count collectives, (ii) sum collective operand bytes for the
roofline's collective term, and (iii) measure *overlap slack* — how much
independent compute the schedule could run concurrently with each collective.

Overlap slack is the TPU-side evidence for the paper's Fig. 1: in classical
CG both all-reduces have ~zero independent work available (blocking barriers),
while in CG-NB each reduction has a full SpMV / vector-update's worth of
independent ops — the dependence-graph property that lets XLA's latency-hiding
scheduler overlap them.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

import numpy as np

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

# e.g. ``f32[128,256]{1,0}`` or ``bf16[4096]`` or ``pred[]``
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")
# instruction line: ``  %name = <shape or tuple> opcode(...operands...)``,
# optionally prefixed with ROOT.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*([\w\-]+)\((.*)$"
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes appearing in ``shape_str``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    result_bytes: int
    operand_names: list[str]
    operand_bytes: int
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: list[Instruction]

    def by_name(self) -> dict[str, int]:
        return {ins.name: i for i, ins in enumerate(self.instructions)}


def parse_computations(hlo_text: str) -> list[Computation]:
    comps: list[Computation] = []
    cur: Computation | None = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ("(" in stripped or stripped.startswith("ENTRY")):
            header = stripped.split("(")[0].strip().lstrip("%")
            cur = Computation(name=header or "entry", instructions=[])
            continue
        if stripped == "}":
            if cur is not None:
                comps.append(cur)
                cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_str, opcode, rest = m.groups()
        # operand region: up to the matching close paren — approximate by
        # cutting at ``), `` attribute separators; operands are %refs anyway.
        operand_names = _OPERAND_RE.findall(rest.split("),")[0])
        cur.instructions.append(
            Instruction(
                name=name,
                opcode=opcode,
                result_bytes=shape_bytes(shape_str),
                operand_names=operand_names,
                operand_bytes=0,  # filled below
                raw=stripped,
            )
        )
    # resolve operand bytes from producer result sizes
    for comp in comps:
        idx = comp.by_name()
        for ins in comp.instructions:
            b = 0
            for on in ins.operand_names:
                j = idx.get(on)
                if j is not None:
                    b += comp.instructions[j].result_bytes
            ins.operand_bytes = b
    return comps


def is_collective(opcode: str) -> bool:
    base = opcode.replace("-start", "").replace("-done", "")
    return base in COLLECTIVE_OPS


def count_collectives(hlo_text: str) -> dict[str, int]:
    counts: dict[str, int] = defaultdict(int)
    for comp in parse_computations(hlo_text):
        for ins in comp.instructions:
            if is_collective(ins.opcode) and not ins.opcode.endswith("-done"):
                base = ins.opcode.replace("-start", "")
                counts[base] += 1
    return dict(counts)


def collective_stats(hlo_text: str) -> dict[str, dict[str, int]]:
    """Per-opcode ``{"count": n, "bytes": b}`` over every collective.

    The audit's drift detector: counts are checked against the registry's
    communication metadata, bytes against the committed AUDIT.json baseline
    (a byte change with stable counts means the *payload* structure moved —
    e.g. a psum pair silently unfusing into two half-size reductions would
    keep total bytes but change counts, while a state-layout change keeps
    counts but moves bytes).  ``-start``/``-done`` pairs count once, like
    :func:`count_collectives`.
    """
    stats: dict[str, dict[str, int]] = {}
    for comp in parse_computations(hlo_text):
        for ins in comp.instructions:
            if is_collective(ins.opcode) and not ins.opcode.endswith("-done"):
                base = ins.opcode.replace("-start", "")
                rec = stats.setdefault(base, {"count": 0, "bytes": 0})
                rec["count"] += 1
                rec["bytes"] += ins.operand_bytes or ins.result_bytes
    return stats


#: the donation annotations jax leaves in lowered text: ``tf.aliasing_output``
#: on unsharded lowerings, ``jax.buffer_donor`` once shardings are attached.
_DONATION_MARKERS = ("tf.aliasing_output", "jax.buffer_donor")


def donation_markers(lowered_text: str) -> int:
    """Number of donated arguments visible in *lowered* (StableHLO) text.

    Counts both spellings: a lowering with concrete/unsharded arguments
    annotates ``tf.aliasing_output = N``, one with shardings attached emits
    ``jax.buffer_donor = true`` — either way, one marker per donated
    argument.  ``SolverOptions.donate`` donates exactly x0, so the audit
    expects 1 with donation on and 0 with it off.
    """
    return sum(lowered_text.count(m) for m in _DONATION_MARKERS)


_ALIAS_ENTRY_RE = re.compile(r"\{[\d,\s]*\}:\s*\((\d+)\s*,")


def input_output_aliases(compiled_text: str) -> list[int]:
    """Parameter numbers aliased to outputs in a *compiled* HloModule.

    Parses the ``input_output_alias={ {0}: (1, {}, may-alias) }`` header
    attribute — the form XLA actually acts on (the lowered markers above are
    requests; this is the grant).  Returns one entry per aliased output,
    e.g. ``[1]`` when output 0 reuses parameter 1's buffer.
    """
    out: list[int] = []
    for line in compiled_text.splitlines():
        if "input_output_alias={" not in line:
            continue
        body = line.split("input_output_alias={", 1)[1]
        depth = 1
        end = 0
        for i, ch in enumerate(body):
            depth += (ch == "{") - (ch == "}")
            if depth == 0:
                end = i
                break
        out.extend(int(p) for p in _ALIAS_ENTRY_RE.findall(body[:end]))
    return out


def collective_bytes(hlo_text: str, trip_counts: dict[str, int] | None = None) -> int:
    """Sum of operand bytes over every collective op.

    ``trip_counts`` maps computation-name substrings to a multiplier (used to
    scale while-loop bodies by their trip count, since a loop body appears
    once in the HLO but executes many times).
    """
    total = 0
    for comp in parse_computations(hlo_text):
        mult = 1
        if trip_counts:
            for frag, m in trip_counts.items():
                if frag in comp.name:
                    mult = m
                    break
        for ins in comp.instructions:
            if is_collective(ins.opcode) and not ins.opcode.endswith("-done"):
                # operand bytes == per-device send volume (all-gather sends the
                # shard, all-reduce ~the buffer (ring ~2x, ignored), ppermute
                # the slab).  Result bytes would overcount gathers n-fold.
                total += mult * (ins.operand_bytes or ins.result_bytes)
    return total


def _reachable(adj: list[list[int]], start: int) -> set[int]:
    seen = {start}
    stack = [start]
    while stack:
        u = stack.pop()
        for v in adj[u]:
            if v not in seen:
                seen.add(v)
                stack.append(v)
    return seen


# Opcodes that represent no real work (layout plumbing / constants); excluded
# from the overlap-slack work accounting.
_TRIVIAL_OPS = {
    "parameter", "constant", "iota", "broadcast", "copy", "bitcast",
    "bitcast-convert", "tuple", "get-tuple-element", "reshape", "convert",
    "transpose", "copy-start", "copy-done", "after-all", "partition-id",
}


def overlap_slack(hlo_text: str, computation_filter: str | None = None,
                  ops: tuple[str, ...] | None = None):
    """For each collective: how much work is *hideable behind it* — ops that
    are neither ancestors (already done when the collective issues) nor
    descendants (waiting on it) in the dependence graph.

    ``ops`` restricts the report to the named collective base opcodes (e.g.
    ``("collective-permute",)`` for the halo traffic, ``("all-reduce",)`` for
    the global reductions); default is every collective.

    Work proxy: result bytes of non-trivial ops (solver bodies are
    elementwise/stencil-dominated so byte traffic tracks FLOPs).  Reported
    both as absolute ``slack_bytes`` and as a fraction of the computation's
    total work.  A reduction is a *blocking barrier* in the paper's sense when
    its slack is below ~one vector's worth of traffic — see
    ``repro.core.overlap.blocking_reductions``.
    """
    out = []
    for comp in parse_computations(hlo_text):
        if computation_filter and computation_filter not in comp.name:
            continue
        n = len(comp.instructions)
        idx = comp.by_name()
        fwd: list[list[int]] = [[] for _ in range(n)]   # producer -> consumer
        bwd: list[list[int]] = [[] for _ in range(n)]
        for i, ins in enumerate(comp.instructions):
            for on in ins.operand_names:
                j = idx.get(on)
                if j is not None and j != i:
                    fwd[j].append(i)
                    bwd[i].append(j)
        weights = np.array(
            [
                0.0 if ins.opcode in _TRIVIAL_OPS else float(ins.result_bytes)
                for ins in comp.instructions
            ]
        )
        total_w = weights.sum() or 1.0
        for i, ins in enumerate(comp.instructions):
            if not is_collective(ins.opcode) or ins.opcode.endswith("-done"):
                continue
            if ops is not None and ins.opcode.replace("-start", "") not in ops:
                continue
            dependent = _reachable(fwd, i) | _reachable(bwd, i)
            indep_w = total_w - weights[list(dependent)].sum()
            out.append(
                dict(
                    computation=comp.name,
                    op=ins.opcode,
                    name=ins.name,
                    bytes=max(ins.operand_bytes, ins.result_bytes),
                    slack_bytes=float(indep_w),
                    slack_fraction=float(indep_w / total_w),
                )
            )
    return out


def while_loop_bodies(hlo_text: str) -> list[str]:
    """Names of computations that look like while-loop bodies."""
    return [
        c.name
        for c in parse_computations(hlo_text)
        if "body" in c.name or "while" in c.name
    ]
