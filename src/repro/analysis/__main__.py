"""``python -m repro.analysis`` — run the contract auditor.

Modes::

    python -m repro.analysis --lint-only          # AST + kernel passes only
    python -m repro.analysis --write AUDIT.json   # measure, (re)write baseline
    python -m repro.analysis --check AUDIT.json   # full audit, fail on drift

``--check`` is the CI gate: all three passes plus the registry-vs-MethodDef
field sweep, comparing the measured HLO against both the registry metadata
and the committed byte-level baseline.  Exit status 1 on any violation.
"""

from __future__ import annotations

import argparse
import json
import sys


def _registry_violations():
    """Re-assert registry ↔ MethodDef consistency as auditable findings.

    Registration already hard-fails on drift (``RegistryConsistencyError``),
    so this sweep is clean by construction — it exists so the audit report
    states the invariant was checked, and so a spec constructed outside
    ``register_solver`` (tests, tools) is still caught.
    """
    from repro.analysis.violation import Violation
    from repro.api.registry import REGISTRY, method_field_diff
    from repro.core.methods import METHODS

    out = []
    for name in sorted(REGISTRY):
        if name not in METHODS:
            out.append(Violation("registry", name, "method_def",
                                 expected="a registered MethodDef",
                                 actual="missing"))
            continue
        for d in method_field_diff(REGISTRY[name], METHODS[name]):
            out.append(Violation("registry", name, d.field,
                                 expected=d.derived_value,
                                 actual=d.registry_value,
                                 detail="SolverSpec drifted from MethodDef"))
    for name in sorted(set(METHODS) - set(REGISTRY)):
        out.append(Violation("registry", name, "solver_spec",
                             expected="a registry entry per MethodDef",
                             actual="missing"))
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static contract auditor: comms/donation HLO audit, "
                    "MethodDef AST lint, Pallas kernel checks")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--check", metavar="AUDIT.json",
                      help="full audit against this committed baseline")
    mode.add_argument("--write", metavar="AUDIT.json",
                      help="measure and (re)write the baseline, then verify")
    mode.add_argument("--lint-only", action="store_true",
                      help="skip the (slow) HLO measurement passes")
    ap.add_argument("--methods", default=None,
                    help="comma-separated subset (debugging; baseline "
                         "comparison is skipped for subsets)")
    args = ap.parse_args(argv)

    from repro.analysis.lint_kernels import check_kernels
    from repro.analysis.lint_methods import check_methods
    from repro.analysis.violation import format_violations

    violations = []

    print("[1/4] registry <-> MethodDef field sweep", flush=True)
    violations += _registry_violations()
    print("[2/4] MethodDef AST + state-layout lint", flush=True)
    violations += check_methods()
    print("[3/4] Pallas kernel static checks", flush=True)
    violations += check_kernels()

    if args.lint_only:
        print("[4/4] HLO comms/donation audit: skipped (--lint-only)")
    else:
        from repro.analysis.audit import compare, run_measurements
        methods = args.methods.split(",") if args.methods else None
        print("[4/4] HLO comms/donation audit "
              "(compiling every method x mesh in a subprocess)", flush=True)
        measured = run_measurements(methods)
        n_cfg = sum(len(measured.get(k, {})) for k in
                    ("comms", "donate_mesh", "local", "mesh_aliases"))
        print(f"      measured {n_cfg} configurations", flush=True)
        baseline = None
        if args.check and methods is None:
            try:
                with open(args.check) as f:
                    baseline = json.load(f)
            except OSError as e:
                print(f"cannot read baseline {args.check!r}: {e}",
                      file=sys.stderr)
                return 1
        violations += compare(measured, baseline=baseline)
        if args.write and methods is None and not violations:
            from repro.analysis.audit import GRID, MESHES, STENCIL
            doc = {"grid": list(GRID), "stencil": STENCIL,
                   "meshes": {k: {"devices": list(v[0]), "axes": list(v[1])}
                              for k, v in MESHES.items()},
                   "measured": measured}
            with open(args.write, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
                f.write("\n")
            print(f"wrote baseline: {args.write} ({n_cfg} configurations)")

    if violations:
        print(format_violations(violations), file=sys.stderr)
        print(f"FAILED: {len(violations)} violation(s)", file=sys.stderr)
        return 1
    print("OK: all contracts hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
