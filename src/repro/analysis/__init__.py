# Static-verification subsystem: the HLO parsing layer (hlo.py) plus three
# analysis passes gated in CI via `python -m repro.analysis --check AUDIT.json`
# (see docs/API.md §"Static analysis"):
#
#   audit        — lower every registry method × mesh shape to compiled HLO
#                  and assert collective counts/bytes + donation aliasing
#                  match the registry's communication metadata exactly
#   lint_methods — AST lint over every MethodDef body (no Python branching
#                  on traced state, no mutable-global closures, operator-
#                  protocol calls only, declared state layout == produced)
#   lint_kernels — Pallas kernel static checks (VMEM footprint vs budget,
#                  block divisibility, oracle + test-row completeness)
from repro.analysis.violation import Violation, format_violations  # noqa: F401
