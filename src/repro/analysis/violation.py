"""The one result type every analysis pass emits.

A :class:`Violation` is a machine-checkable contract breach: which pass
found it, what it looked at, which field drifted, and the expected-vs-actual
pair.  The CLI (``python -m repro.analysis``) renders them and fails CI on
any; tests assert on (pass_name, subject, field) triples.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Violation:
    pass_name: str      # "comms" | "donation" | "lint_methods" | "lint_kernels" | "registry" | "baseline"
    subject: str        # e.g. "cg|1d|concat|xla|none", "method:cg", "kernel:spmv"
    field: str          # e.g. "all-reduce", "vmem_bytes", "traced_branch"
    expected: object
    actual: object
    detail: str = ""

    def __str__(self) -> str:
        s = (f"[{self.pass_name}] {self.subject} :: {self.field}: "
             f"expected {self.expected!r}, got {self.actual!r}")
        return f"{s} — {self.detail}" if self.detail else s


def format_violations(violations: list[Violation]) -> str:
    if not violations:
        return "no violations"
    by_pass: dict[str, list[Violation]] = {}
    for v in violations:
        by_pass.setdefault(v.pass_name, []).append(v)
    lines = []
    for pass_name in sorted(by_pass):
        vs = by_pass[pass_name]
        lines.append(f"{pass_name}: {len(vs)} violation(s)")
        lines.extend(f"  {v}" for v in vs)
    return "\n".join(lines)
