"""Static checks over the Pallas kernel layer (no kernel executes).

Three families of checks, mirroring how TPU kernels actually fail:

* **VMEM footprint**: each kernel streams blocks through ~16 MiB of VMEM;
  a block-shape change that fits interpret-mode CPU tests can still OOM on
  hardware.  We estimate the per-grid-step footprint from the block shapes
  at the *production* operating point (f64, 128×128 planes, the default
  ``bz``; double-buffered) and fail when it exceeds the budget.

* **Block divisibility**: the z-block must tile the production grid depths
  and the test grids — ``_pick_bz`` silently shrinks a non-dividing block
  (a perf cliff, not an error), so the lint makes the drift loud.

* **Completeness**: every module under ``repro.kernels`` containing a
  ``pallas_call`` must be covered by a table row; every row's wrapper must
  exist in ``kernels.ops``, its oracle in ``kernels.ref``, and a test row
  referencing ``ops.<name>`` in ``tests/test_kernels.py`` — the invariant
  ROADMAP.md states ("every kernel gets a ref.py oracle and a bench row").

The table is declarative so tests can inject a deliberately bad row
(oversized block) and assert this pass — and only this pass — flags it.
"""

from __future__ import annotations

import dataclasses
import inspect
import pathlib

from repro.analysis.violation import Violation

#: per-core VMEM, the budget the estimates are checked against
VMEM_BUDGET_BYTES = 16 * 2 ** 20
_DOUBLE_BUFFER = 2          # pallas pipelines block N+1's copy-in behind N
_ITEMSIZE = 4               # f32: what the kernels run on real TPUs (x64 is
                            # a CPU/interpret-mode concern), matching the
                            # VMEM accounting in stencil_spmv.py's docstring

#: production operating point for the stencil kernels (128² z-slabs) and the
#: grid depths a default block must divide
PROD_PLANE = (128, 128)
PROD_NZ = (32, 64, 128)
#: flattened-row counts of the production grids for the (br, 1024)-tiled
#: vector kernels: 128³/1024 and 128·128·64/1024
PROD_ROWS = (1024, 2048)
#: grids used by tests/test_kernels.py (completeness cross-checks the file)
TEST_GRIDS = ((8, 8, 8), (12, 10, 16), (16, 16, 24))


def _slab_bytes(*, bz: int = 8, windows: int = 1, plains: int = 0,
                outs: int = 1, accs: int = 0,
                plane: tuple[int, int] = PROD_PLANE) -> int:
    """Footprint of one grid step of a z-slab stencil kernel: ``windows``
    halo-padded (nx+2, ny+2, bz+2) inputs, ``plains`` unpadded (nx, ny, bz)
    inputs, ``outs`` (nx, ny, bz) outputs, ``accs`` scalar accumulators."""
    nx, ny = plane
    win = (nx + 2) * (ny + 2) * (bz + 2)
    blk = nx * ny * bz
    one_step = windows * win + (plains + outs) * blk
    return _DOUBLE_BUFFER * _ITEMSIZE * one_step + accs * _ITEMSIZE


def _row_bytes(n_bufs: int, *, br: int = 256, row: int = 1024,
               accs: int = 0) -> int:
    """Footprint of one grid step of a flattened (br, ROW)-tiled vector
    kernel (fused_axpby/cg_fused_update family): ``n_bufs`` live in/out
    blocks plus scalar accumulators."""
    return _DOUBLE_BUFFER * _ITEMSIZE * n_bufs * br * row + accs * _ITEMSIZE


def _flash_bytes(*, bq: int = 256, bkv: int = 256, hd: int = 128) -> int:
    """One (bq × bkv) attention tile: q block, k/v blocks, logits/weights,
    online-softmax running stats + output accumulator."""
    tile = bq * hd + 2 * bkv * hd + bq * bkv + bq * hd + 2 * bq
    return _DOUBLE_BUFFER * 4 * tile    # attention runs in f32/bf16


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One audited kernel: wrapper name, where it lives, its oracle, and the
    static facts the checks run on."""

    name: str                      # public wrapper in repro.kernels.ops
    module: str                    # repro.kernels.<module> with the pallas_call
    ref: str                       # oracle fn in repro.kernels.ref
    vmem_bytes: int                # footprint estimate at production shape
    block_z: int | None = 8        # z-block that must divide the grids below
    divides: tuple[int, ...] = PROD_NZ


KERNEL_TABLE: tuple[KernelSpec, ...] = (
    KernelSpec("spmv", "stencil_spmv", "stencil_spmv_ref",
               _slab_bytes(windows=1, outs=1)),
    KernelSpec("spmv_dot", "stencil_spmv", "stencil_spmv_dot_ref",
               _slab_bytes(windows=1, outs=1, accs=1)),
    KernelSpec("spmv_dots", "spmv_dot", "stencil_spmv_dots_ref",
               _slab_bytes(windows=1, outs=1, accs=2)),
    KernelSpec("cg_update", "cg_fused_update", "cg_fused_update_ref",
               _row_bytes(6, accs=1), block_z=256, divides=PROD_ROWS),
    KernelSpec("cg_body", "cg_fused_update", "fused_cg_body_ref",
               _row_bytes(9, br=128), block_z=128, divides=PROD_ROWS),
    KernelSpec("axpbypcz", "fused_axpby", "fused_axpby_ref",
               _row_bytes(4), block_z=256, divides=PROD_ROWS),
    KernelSpec("axpbypcz_dot", "fused_axpby", "fused_axpby_dot_ref",
               _row_bytes(5, accs=1), block_z=256, divides=PROD_ROWS),
    KernelSpec("gs_half_sweep", "rb_gs", "rb_gs_half_sweep_ref",
               _slab_bytes(windows=1, plains=1, outs=1)),
    KernelSpec("cheb_step", "precond", "cheb_fused_step_ref",
               _slab_bytes(windows=1, plains=2, outs=2)),
    KernelSpec("jacobi_sweep", "precond", "block_jacobi_sweep_ref",
               _slab_bytes(windows=1, plains=1, outs=1)),
    KernelSpec("flash_attention", "flash_attention", "flash_attention_ref",
               _flash_bytes(), block_z=256,
               divides=(1024, 2048, 4096)),
    # --- PR 10: the full fused-body family ----------------------------------
    KernelSpec("spmv_dots3", "spmv_dot", "stencil_spmv_dots3_ref",
               _slab_bytes(windows=1, plains=1, outs=1, accs=3)),
    KernelSpec("fused_dots", "fused_bodies", "fused_dots_ref",
               _row_bytes(3, accs=3), block_z=256, divides=PROD_ROWS),
    KernelSpec("pipe_body", "fused_bodies", "fused_pipe_body_ref",
               _row_bytes(13, br=64), block_z=64, divides=PROD_ROWS),
    KernelSpec("pcg_body", "fused_bodies", "fused_pcg_body_ref",
               _row_bytes(10, br=128), block_z=128, divides=PROD_ROWS),
    KernelSpec("ppipe_body", "fused_bodies", "fused_ppipe_body_ref",
               _row_bytes(18, br=64), block_z=64, divides=PROD_ROWS),
    KernelSpec("bicgstab_update1", "fused_bodies", "bicgstab_update1_ref",
               _row_bytes(9, br=128), block_z=128, divides=PROD_ROWS),
    KernelSpec("bicgstab_spmv_dots", "bicgstab_fused",
               "bicgstab_spmv_dots_ref",
               _slab_bytes(windows=1, plains=6, outs=3, accs=9)),
    KernelSpec("bicgstab_spmv_update", "bicgstab_fused",
               "bicgstab_spmv_update_ref",
               _slab_bytes(windows=1, plains=6, outs=4)),
)

#: public names in kernels.ops that deliberately have no table row
_EXEMPT_WRAPPERS = {
    # thin factory closing over `spmv` (audited above) — no kernel of its own
    "make_matvec_padded",
}


def _kernels_dir() -> pathlib.Path:
    import repro.kernels
    return pathlib.Path(repro.kernels.__file__).resolve().parent


def _tests_file() -> pathlib.Path:
    # src/repro/kernels -> repo root / tests/test_kernels.py
    return _kernels_dir().parents[2] / "tests" / "test_kernels.py"


def check_kernels(table: tuple[KernelSpec, ...] | None = None, *,
                  budget: int = VMEM_BUDGET_BYTES) -> list[Violation]:
    """Run every kernel static check; returns the (possibly empty) findings."""
    table = KERNEL_TABLE if table is None else table
    out: list[Violation] = []

    from repro.kernels import ops as ops_mod, ref as ref_mod

    # --- VMEM budget + divisibility per row ---------------------------------
    for spec in table:
        subj = f"kernel:{spec.name}"
        if spec.vmem_bytes > budget:
            out.append(Violation(
                "lint_kernels", subj, "vmem_bytes",
                expected=f"<= {budget} (VMEM budget)",
                actual=spec.vmem_bytes,
                detail="block shape streams more than VMEM per grid step"))
        if spec.block_z:
            bad = [n for n in spec.divides if n % spec.block_z]
            if bad:
                out.append(Violation(
                    "lint_kernels", subj, "block_divisibility",
                    expected=f"block {spec.block_z} divides grid depths "
                             f"{spec.divides}",
                    actual=f"non-dividing depths {bad}",
                    detail="_pick_bz would silently shrink the block "
                           "(perf cliff)"))

    # --- completeness: wrapper, oracle, test row ----------------------------
    try:
        tests_src = _tests_file().read_text()
    except OSError:
        tests_src = None
        out.append(Violation(
            "lint_kernels", "kernel:*", "test_row",
            expected=f"readable {_tests_file()}",
            actual="missing", detail="cannot verify per-kernel test rows"))
    for spec in table:
        subj = f"kernel:{spec.name}"
        if not callable(getattr(ops_mod, spec.name, None)):
            out.append(Violation(
                "lint_kernels", subj, "wrapper",
                expected=f"repro.kernels.ops.{spec.name}", actual="missing"))
        if not callable(getattr(ref_mod, spec.ref, None)):
            out.append(Violation(
                "lint_kernels", subj, "oracle",
                expected=f"repro.kernels.ref.{spec.ref}", actual="missing",
                detail="every kernel needs a pure-jnp allclose reference"))
        if tests_src is not None and f"ops.{spec.name}" not in tests_src:
            out.append(Violation(
                "lint_kernels", subj, "test_row",
                expected=f"'ops.{spec.name}' referenced in "
                         f"tests/test_kernels.py",
                actual="no reference",
                detail="kernel has no interpret-mode row against its oracle"))

    # --- completeness: every pallas_call module covered, every public
    # wrapper tabled (only for the default table — an injected test table is
    # deliberately partial) --------------------------------------------------
    if table is KERNEL_TABLE:
        covered = {spec.module for spec in table}
        for py in sorted(_kernels_dir().glob("*.py")):
            if py.name == "__init__.py":
                continue
            if "pallas_call" in py.read_text() and py.stem not in covered:
                out.append(Violation(
                    "lint_kernels", f"kernel:{py.stem}", "table_row",
                    expected="a KERNEL_TABLE row per pallas_call module",
                    actual="module not covered",
                    detail=str(py)))
        tabled = {spec.name for spec in table} | _EXEMPT_WRAPPERS
        for name, fn in inspect.getmembers(ops_mod, inspect.isfunction):
            if name.startswith("_") or fn.__module__ != ops_mod.__name__:
                continue
            if name not in tabled:
                out.append(Violation(
                    "lint_kernels", f"kernel:{name}", "table_row",
                    expected="a KERNEL_TABLE row per public kernel wrapper",
                    actual="wrapper not covered"))
        # every fused hook a method declares must itself be a tabled kernel —
        # an untabled (hence oracle-less, VMEM-unchecked) kernel reached via
        # the fused path would dodge all the checks above
        from repro.core.methods import METHODS
        for mname, mdef in sorted(METHODS.items()):
            for hook in mdef.fused_kernels:
                if hook not in tabled:
                    out.append(Violation(
                        "lint_kernels", f"kernel:{hook}", "fused_coverage",
                        expected=f"a KERNEL_TABLE row for fused hook "
                                 f"{hook!r} (declared by {mname!r})",
                        actual="hook not tabled",
                        detail="fused-path kernels take the same "
                               "VMEM/oracle/test checks as classic ones"))
    return out
