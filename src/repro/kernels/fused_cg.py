"""Merged-reduction CG driven entirely by the fused Pallas kernels.

``core.solvers.cg_merged`` restructures CG so each iteration is (a) four
vector updates and (b) one SpMV + two dots; this module backs BOTH halves
with single-pass kernels:

    x, r, p, s = fused_cg_body(α, β, x, r, p, s, w)        # 1 HBM pass
    w, δ, γ    = stencil_spmv_dots(pad(r))                 # 1 HBM pass

Two passes per iteration versus the classic CG's five-to-six separate
kernel sweeps (SpMV, p·Ap, x-update, r-update, r·r, p-update) — the
kernel-switch fork-join barriers the paper's §3.3 task merging removes,
eliminated here as HBM round trips.  ``benchmarks/bench_kernels.py``
measures exactly this pairing; ``repro.api`` routes
``method="cg_merged", pallas=True`` single-device solves here.

Numerics: identical recurrence to ``cg_merged``; the fused dot partials
accumulate per z-slab instead of in jnp's reduction order, so iterates
agree to machine precision but not bit-for-bit
(tests/test_reduction_hiding.py pins the tolerance).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.operators import Stencil
from repro.core.solvers import SolveResult, _cg_merged_scalars, _hist_init
from repro.kernels import ops


def cg_merged_fused(stencil: Stencil, b: jax.Array, x0: jax.Array, *,
                    tol: float = 1e-6, maxiter: int = 500,
                    norm_ref: float | None = None,
                    bz: int = 8) -> SolveResult:
    """Single-device merged CG, two fused HBM passes per iteration.

    Same signature semantics as the ``core.solvers`` methods (``norm_ref``
    ``None`` = relative to ``‖b‖``); jit-safe.
    """
    if norm_ref is None:
        norm_ref = jnp.sqrt(jnp.vdot(b, b))
    thresh2 = (tol * norm_ref) ** 2
    r = b - stencil.matvec(x0)
    w, delta, gamma = ops.spmv_dots(jnp.pad(r, 1), stencil, bz=bz)
    hist = _hist_init(maxiter, jnp.sqrt(gamma), b.dtype)
    zero = jnp.zeros_like(b)
    inf = jnp.asarray(jnp.inf, gamma.dtype)
    one = jnp.asarray(1.0, gamma.dtype)

    def cond(c):
        gamma, k = c[5], c[9]
        return (gamma >= thresh2) & (k < maxiter)

    def body(c):
        x, r, p, s, w, gamma, delta, gamma_prev, alpha_prev, k, hist = c
        alpha, beta = _cg_merged_scalars(gamma, delta, gamma_prev, alpha_prev)
        x, r, p, s = ops.cg_body(alpha, beta, x, r, p, s, w)   # pass 1
        w, delta_new, gamma_new = ops.spmv_dots(                # pass 2
            jnp.pad(r, 1), stencil, bz=bz)
        hist = hist.at[k + 1].set(jnp.sqrt(gamma_new).astype(hist.dtype))
        return (x, r, p, s, w, gamma_new, delta_new, gamma, alpha, k + 1,
                hist)

    x, r, p, s, w, gamma, delta, _, _, k, hist = lax.while_loop(
        cond, body, (x0, r, zero, zero, w, gamma, delta, inf, one, 0, hist))
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(gamma), history=hist)
