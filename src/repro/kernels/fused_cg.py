"""Merged-reduction CG driven entirely by the fused Pallas kernels.

The fused iteration is no longer a hand-written loop: it is the
``cg_merged`` ``MethodDef``'s *fused body* (``repro.core.methods``),
executed by the same generic ``run_method`` driver as every other
backend, over a :class:`repro.kernels.pallas_op.PallasOp`:

    x, r, p, s = A.cg_body(α, β, x, r, p, s, w)        # 1 HBM pass
    w, δ, γ    = A.spmv_dots(r)                        # 1 HBM pass

Two passes per iteration versus the classic CG's five-to-six separate
kernel sweeps (SpMV, p·Ap, x-update, r-update, r·r, p-update) — the
kernel-switch fork-join barriers the paper's §3.3 task merging removes,
eliminated here as HBM round trips.  ``benchmarks/bench_kernels.py``
measures exactly this pairing; ``repro.api`` routes ``pallas=True`` solves
of any fused-capable method here (and to the shard_map equivalent on a
mesh — see ``core.distributed.solve_shardmap(pallas_fused=True)``).

Numerics: identical recurrence to ``cg_merged``; the fused dot partials
accumulate per z-slab instead of in jnp's reduction order, so iterates
agree to machine precision but not bit-for-bit
(tests/test_reduction_hiding.py pins the tolerance).
"""

from __future__ import annotations

import jax

from repro.core.methods import Ops, SolveResult, get_method, run_method
from repro.core.operators import Stencil
from repro.core.solvers import LocalOp
from repro.kernels.pallas_op import PallasOp


def cg_merged_fused(stencil: Stencil, b: jax.Array, x0: jax.Array, *,
                    tol: float = 1e-6, maxiter: int = 500,
                    norm_ref: float | None = None,
                    bz: int = 8) -> SolveResult:
    """Single-device merged CG, two fused HBM passes per iteration.

    Same signature semantics as the ``core.solvers`` methods (``norm_ref``
    ``None`` = relative to ``‖b‖``); jit-safe.
    """
    A = PallasOp(LocalOp(stencil), bz=bz)
    ops = Ops(A, b, norm_ref=norm_ref)
    return run_method(get_method("cg_merged"), ops, x0, tol=tol,
                      maxiter=maxiter, fused=True)
