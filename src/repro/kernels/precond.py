"""Pallas TPU kernels for the preconditioner hot paths.

Two fused kernels, both on the ``stencil_spmv`` overlapping-window z-slab
tiling ((nx+2, ny+2, bz+2) VMEM windows, HBM traffic (bz+2)/bz):

  * ``cheb_fused_step`` — one Chebyshev recurrence step in ONE VMEM pass:
    the stencil apply ``A z`` plus the whole axpby chain
    ``d' = a·d + c·(r - A z); z' = z + d'``.  Unfused this is a matvec
    kernel plus two vector sweeps (the ``fused_axpby`` pattern); fusion
    removes both extra HBM round trips.  The coefficients ``a, c`` come
    from the *static* Chebyshev scalar schedule (precomputed from the
    Gershgorin bounds — see precond/chebyshev.py), so they are baked into
    the kernel as compile-time constants: the whole apply is a chain of
    ``degree-1`` such calls with no scalar traffic at all.

  * ``block_jacobi_sweep`` — one damped local Jacobi sweep
    ``z' = z + ω·(r - A z)/diag`` in one pass, the inner iteration of the
    block-Jacobi (two-stage multisplitting) preconditioner.  The caller
    zero-pads ``z`` (decomposed faces are physical boundary for the block
    operator), so the kernel is communication-free by construction.

Pure-jnp oracles live in kernels/ref.py; dispatch wrappers in
kernels/ops.py (interpret mode off-TPU, like every kernel here).
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

from repro.core.operators import Stencil
from repro.kernels.stencil_spmv import _pick_bz, _window_spec, apply_stencil_slab


def _cheb_kernel(stencil: Stencil, nx: int, ny: int, bz: int,
                 a: float, c: float):
    def body(zin, rin, din, zout, dout):
        z_slab = zin[...]
        az = apply_stencil_slab(stencil, z_slab, nx, ny, bz)
        d_new = a * din[...] + c * (rin[...] - az)
        dout[...] = d_new
        zout[...] = z_slab[1:-1, 1:-1, 1:-1] + d_new

    return body


@functools.partial(
    jax.jit, static_argnames=("stencil", "a", "c", "bz", "interpret")
)
def cheb_fused_step(
    zp: jax.Array,
    r: jax.Array,
    d: jax.Array,
    *,
    stencil: Stencil,
    a: float,
    c: float,
    bz: int = 8,
    interpret: bool = True,
):
    """One fused Chebyshev step from the halo-padded ``zp``.

    Returns ``(z_new, d_new)`` with ``d_new = a·d + c·(r - A z)`` and
    ``z_new = z + d_new``; shapes (nx, ny, nz) from ``zp``'s interior.
    """
    nx, ny, nz = r.shape
    bzz = _pick_bz(nz, bz)
    slab = pl.BlockSpec((nx, ny, bzz), lambda i: (0, 0, i))
    z_new, d_new = pl.pallas_call(
        _cheb_kernel(stencil, nx, ny, bzz, a, c),
        grid=(nz // bzz,),
        in_specs=[_window_spec(nx, ny, bzz), slab, slab],
        out_specs=[slab, slab],
        out_shape=[
            jax.ShapeDtypeStruct((nx, ny, nz), r.dtype),
            jax.ShapeDtypeStruct((nx, ny, nz), r.dtype),
        ],
        interpret=interpret,
    )(zp, r, d)
    return z_new, d_new


def _bj_kernel(stencil: Stencil, nx: int, ny: int, bz: int, omega: float):
    def body(zin, rin, out):
        z_slab = zin[...]
        az = apply_stencil_slab(stencil, z_slab, nx, ny, bz)
        out[...] = z_slab[1:-1, 1:-1, 1:-1] + omega * (rin[...] - az) / stencil.diag

    return body


@functools.partial(
    jax.jit, static_argnames=("stencil", "omega", "bz", "interpret")
)
def block_jacobi_sweep(
    zp: jax.Array,
    r: jax.Array,
    *,
    stencil: Stencil,
    omega: float = 1.0,
    bz: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """``z + ω·(r - A z)/diag`` from the zero-padded local ``zp``, one pass."""
    nx, ny, nz = r.shape
    bzz = _pick_bz(nz, bz)
    return pl.pallas_call(
        _bj_kernel(stencil, nx, ny, bzz, omega),
        grid=(nz // bzz,),
        in_specs=[
            _window_spec(nx, ny, bzz),
            pl.BlockSpec((nx, ny, bzz), lambda i: (0, 0, i)),
        ],
        out_specs=pl.BlockSpec((nx, ny, bzz), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((nx, ny, nz), r.dtype),
        interpret=interpret,
    )(zp, r)
