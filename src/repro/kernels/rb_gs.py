"""Pallas TPU kernel: red-black Gauss-Seidel half-sweep (paper §3.4).

One colour's update, fused with the stencil application:

    x[i,j,k] <- (b[i,j,k] - Σ_off c·x[neigh]) / diag     where (i+j+k)%2 == colour
    x[i,j,k] <- x[i,j,k]                                  otherwise

Same z-slab overlapping-window tiling as ``stencil_spmv``; the parity mask is
built from iotas plus the grid step's global z offset.  The colour is a
Python static (two specialisations), mirroring the paper's two-colour scheme.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.operators import Stencil
from repro.kernels.stencil_spmv import _window_spec


def _kernel(stencil: Stencil, nx: int, ny: int, bz: int, colour: int):
    off_groups: dict[int, list[tuple[int, int]]] = {-1: [], 0: [], 1: []}
    for dx, dy, dz in stencil.offsets:
        off_groups[dz].append((dx, dy))

    def body(xin, bin_, out):
        x_slab = xin[...]
        centre = x_slab[1:-1, 1:-1, 1:-1]
        off = jnp.zeros((nx, ny, bz), x_slab.dtype)
        for dz, xy in off_groups.items():
            zsl = x_slab[:, :, 1 + dz : 1 + dz + bz]
            for dx, dy in xy:
                off = off + stencil.off_coeff * zsl[
                    1 + dx : 1 + dx + nx, 1 + dy : 1 + dy + ny, :
                ]
        gs = (bin_[...] - off) / stencil.diag
        i = pl.program_id(0)
        ii = jax.lax.broadcasted_iota(jnp.int32, (nx, ny, bz), 0)
        jj = jax.lax.broadcasted_iota(jnp.int32, (nx, ny, bz), 1)
        kk = jax.lax.broadcasted_iota(jnp.int32, (nx, ny, bz), 2) + i * bz
        mask = ((ii + jj + kk) % 2) == colour
        out[...] = jnp.where(mask, gs, centre)

    return body


@functools.partial(jax.jit, static_argnames=("stencil", "colour", "bz", "interpret"))
def rb_gs_half_sweep(
    xp: jax.Array,
    b: jax.Array,
    *,
    stencil: Stencil,
    colour: int,
    bz: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """One coloured half-sweep from padded ``xp``; returns the updated grid."""
    nx, ny, nz = b.shape
    bzz = min(bz, nz)
    while nz % bzz:
        bzz -= 1
    return pl.pallas_call(
        _kernel(stencil, nx, ny, bzz, colour),
        grid=(nz // bzz,),
        in_specs=[
            _window_spec(nx, ny, bzz),
            pl.BlockSpec((nx, ny, bzz), lambda i: (0, 0, i)),
        ],
        out_specs=pl.BlockSpec((nx, ny, bzz), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((nx, ny, nz), b.dtype),
        interpret=interpret,
    )(xp, b)
