"""Jit'd dispatch wrappers for the Pallas kernels.

On a TPU backend the kernels compile natively; everywhere else they run in
``interpret=True`` mode (the kernel body executed op-by-op on CPU), which is
how this repo validates them.  ``use_pallas=False`` falls back to the jnp
oracle — the solvers take a ``matvec_padded`` hook, so the whole solver suite
can run on either implementation (tests assert they agree).
"""

from __future__ import annotations

import jax

from repro.core.operators import Stencil
from repro.kernels.cg_fused_update import (
    cg_fused_update as _cg_fused_update,
    fused_cg_body as _fused_cg_body,
)
from repro.kernels.spmv_dot import (
    stencil_spmv_dots as _stencil_spmv_dots,
    stencil_spmv_dots3 as _stencil_spmv_dots3,
)
from repro.kernels.fused_axpby import (
    fused_axpby as _fused_axpby,
    fused_axpby_dot as _fused_axpby_dot,
)
from repro.kernels.fused_bodies import (
    bicgstab_fused_update1 as _bicgstab_fused_update1,
    fused_dots as _fused_dots,
    fused_pcg_body as _fused_pcg_body,
    fused_pipe_body as _fused_pipe_body,
    fused_ppipe_body as _fused_ppipe_body,
)
from repro.kernels.bicgstab_fused import (
    bicgstab_fused_spmv_dots as _bicgstab_fused_spmv_dots,
    bicgstab_fused_spmv_update as _bicgstab_fused_spmv_update,
)
from repro.kernels.flash_attention import flash_attention as _flash_attention
from repro.kernels.precond import (
    block_jacobi_sweep as _block_jacobi_sweep,
    cheb_fused_step as _cheb_fused_step,
)
from repro.kernels.rb_gs import rb_gs_half_sweep as _rb_gs_half_sweep
from repro.kernels.stencil_spmv import stencil_spmv as _stencil_spmv


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def spmv(xp: jax.Array, stencil: Stencil, *, bz: int = 8) -> jax.Array:
    return _stencil_spmv(xp, stencil=stencil, bz=bz, interpret=_interpret())


def spmv_dot(xp: jax.Array, stencil: Stencil, *, bz: int = 8):
    return _stencil_spmv(
        xp, stencil=stencil, bz=bz, fuse_dot=True, interpret=_interpret()
    )


def axpbypcz(a, x, b, y, c, z):
    return _fused_axpby(a, x, b, y, c, z, interpret=_interpret())


def axpbypcz_dot(a, x, b, y, c, z, w):
    return _fused_axpby_dot(a, x, b, y, c, z, w, interpret=_interpret())


def spmv_dots(xp: jax.Array, stencil: Stencil, *, bz: int = 8):
    """``(A·x, (A·x)·x, x·x)`` in one VMEM pass (merged CG's reduction pair)."""
    return _stencil_spmv_dots(xp, stencil=stencil, bz=bz,
                              interpret=_interpret())


def spmv_dots3(xp: jax.Array, r: jax.Array, stencil: Stencil, *, bz: int = 8):
    """``(A·x, (A·x)·x, r·x, r·r)`` in one pass (PCG/pipe reduction triple)."""
    return _stencil_spmv_dots3(xp, r, stencil=stencil, bz=bz,
                               interpret=_interpret())


def fused_dots(a, b, c, *, br: int = 256):
    """Stacked partial dots ``(a·b, c·b, a·a)`` in one read pass."""
    return _fused_dots(a, b, c, br=br, interpret=_interpret())


def pipe_body(alpha, beta, x, r, w, p, s, z, n, *, br: int = 64):
    """Pipelined CG's six recurrences -> (x', r', w', p', s', z')."""
    return _fused_pipe_body(alpha, beta, x, r, w, p, s, z, n, br=br,
                            interpret=_interpret())


def pcg_body(alpha, beta, x, r, u, p, s, w, *, br: int = 128):
    """Merged PCG's four vector updates -> (x', r', p', s')."""
    return _fused_pcg_body(alpha, beta, x, r, u, p, s, w, br=br,
                           interpret=_interpret())


def ppipe_body(alpha, beta, x, r, u, w, p, s, q, z, m, n, *, br: int = 64):
    """Pipelined PCG's eight recurrences -> (x', r', u', w', p', s', q', z')."""
    return _fused_ppipe_body(alpha, beta, x, r, u, w, p, s, q, z, m, n,
                             br=br, interpret=_interpret())


def bicgstab_update1(alpha, omega, y, p, q, yv, t, v, *, br: int = 128):
    """BiCGStab's ω-half x/r/w updates -> (y', r', w')."""
    return _bicgstab_fused_update1(alpha, omega, y, p, q, yv, t, v, br=br,
                                   interpret=_interpret())


def bicgstab_spmv_dots(zp, z, r, w, s, rhat, t, alpha, stencil: Stencil, *,
                       bz: int = 8):
    """BiCGStab sweep 1: ``v = A·z̃`` + ``q``/``y`` + 9 dot partials."""
    return _bicgstab_fused_spmv_dots(
        zp, z, r, w, s, rhat, t, alpha, stencil=stencil, bz=bz,
        interpret=_interpret()
    )


def bicgstab_spmv_update(wp, w, r, p, s, z, v, omega, beta, stencil: Stencil,
                         *, bz: int = 8):
    """BiCGStab sweep 2: ``t' = A·w̃`` + direction recurrences."""
    return _bicgstab_fused_spmv_update(
        wp, w, r, p, s, z, v, omega, beta, stencil=stencil, bz=bz,
        interpret=_interpret()
    )


def cg_update(beta, r, ar, p, ap):
    return _cg_fused_update(beta, r, ar, p, ap, interpret=_interpret())


def cg_body(alpha, beta, x, r, p, s, w, *, br: int = 128):
    """Merged-CG's four vector updates in one VMEM pass -> (x', r', p', s')."""
    return _fused_cg_body(alpha, beta, x, r, p, s, w, br=br,
                          interpret=_interpret())


def gs_half_sweep(xp, b, stencil: Stencil, colour: int, *, bz: int = 8):
    return _rb_gs_half_sweep(
        xp, b, stencil=stencil, colour=colour, bz=bz, interpret=_interpret()
    )


def cheb_step(zp, r, d, stencil: Stencil, *, a: float, c: float, bz: int = 8):
    return _cheb_fused_step(
        zp, r, d, stencil=stencil, a=a, c=c, bz=bz, interpret=_interpret()
    )


def jacobi_sweep(zp, r, stencil: Stencil, *, omega: float = 1.0, bz: int = 8):
    return _block_jacobi_sweep(
        zp, r, stencil=stencil, omega=omega, bz=bz, interpret=_interpret()
    )


def flash_attention(q, k, v, *, bq: int = 256, bkv: int = 256,
                    window: int = 0):
    return _flash_attention(q, k, v, bq=bq, bkv=bkv, window=window,
                            interpret=_interpret())


def make_matvec_padded(stencil: Stencil, *, bz: int = 8):
    """A ``matvec_padded`` hook (for LocalOp/DistributedOp) backed by Pallas."""

    def mv(xp: jax.Array) -> jax.Array:
        return spmv(xp, stencil, bz=bz)

    return mv
