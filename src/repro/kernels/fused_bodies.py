"""Pallas TPU kernels: the vector-update halves of every fused Krylov body.

PR 4 gave ``cg_merged`` a single-pass vector-update kernel
(``cg_fused_update.fused_cg_body``); this module (PR 10) extends the family
to the rest of the reduction-hiding variants, so each of their
``MethodDef.fused_step`` bodies runs as two-to-three VMEM-resident HBM
passes instead of the 5–9 separate axpy/dot dispatches of the fork-join
form:

  * ``fused_pipe_body``  — pipelined CG's SIX recurrences (z, s, p, x, r, w)
    in one pass (``cg_pipe``).
  * ``fused_pcg_body``   — merged PCG's four updates; identical to
    ``fused_cg_body`` except ``p' = u + β p`` reads the *preconditioned*
    residual (``pcg_merged``).
  * ``fused_ppipe_body`` — pipelined PCG's EIGHT recurrences (``pcg_pipe``).
  * ``fused_dots``       — the stacked partial-dot triple ``(a·b, c·b, a·a)``
    with no SpMV attached: pipelined PCG needs its reduction on carried
    state *before* the preconditioner apply, so the dots get their own
    single read pass.
  * ``bicgstab_fused_update1`` — single-reduction BiCGStab's mid-iteration
    x/r/w updates (the ω half), between the two SpMV passes of
    ``bicgstab_fused.py``.

All use the flattened (br, 1024) row tiling of ``fused_axpby``; scalars ride
a (1, k) coefficient block.  Block sizes are VMEM-budgeted in
``repro.analysis.lint_kernels`` (n_live_blocks × br × 1024, double-buffered)
and tunable via ``kernels.autotune``.  Oracles: ``kernels/ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_axpby import ROW, _to_2d


def _tile(v):
    v2, n = _to_2d(v)
    return v2, n


def _row_grid(rows: int, br: int) -> int:
    brr = min(br, rows)
    while rows % brr:
        brr -= 1
    return brr


def _dots_kernel(*refs):
    a, b, c, acc = refs
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc[...] = jnp.zeros((1, 3), acc.dtype)

    av, bv, cv = a[...], b[...], c[...]
    acc[0, 0] += jnp.sum(av * bv).astype(acc.dtype)
    acc[0, 1] += jnp.sum(cv * bv).astype(acc.dtype)
    acc[0, 2] += jnp.sum(av * av).astype(acc.dtype)


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def fused_dots(
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    br: int = 256,
    interpret: bool = True,
):
    """Stacked partial dots ``(a·b, c·b, a·a)`` in ONE read pass.

    Pipelined PCG's reduction triple on carried state: with
    ``(a, b, c) = (r, u, w)`` this is ``(γ = r·u, δ = w·u, ‖r‖²)``.
    """
    a2, _ = _tile(a)
    b2, _ = _tile(b)
    c2, _ = _tile(c)
    rows = a2.shape[0]
    brr = _row_grid(rows, br)
    acc_dtype = jnp.float32 if a.dtype == jnp.bfloat16 else a.dtype
    blk = lambda: pl.BlockSpec((brr, ROW), lambda i: (i, 0))
    acc = pl.pallas_call(
        _dots_kernel,
        grid=(rows // brr,),
        in_specs=[blk(), blk(), blk()],
        out_specs=[pl.BlockSpec((1, 3), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, 3), acc_dtype)],
        interpret=interpret,
    )(a2, b2, c2)[0]
    return acc[0, 0], acc[0, 1], acc[0, 2]


def _pipe_kernel(*refs):
    coef, x, r, w, p, s, z, n, x_o, r_o, w_o, p_o, s_o, z_o = refs
    alpha = coef[0, 0]
    beta = coef[0, 1]
    z_new = n[...] + beta * z[...]
    s_new = w[...] + beta * s[...]
    p_new = r[...] + beta * p[...]
    z_o[...] = z_new
    s_o[...] = s_new
    p_o[...] = p_new
    x_o[...] = x[...] + alpha * p_new
    r_o[...] = r[...] - alpha * s_new
    w_o[...] = w[...] - alpha * z_new


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def fused_pipe_body(
    alpha: jax.Array,
    beta: jax.Array,
    x: jax.Array,
    r: jax.Array,
    w: jax.Array,
    p: jax.Array,
    s: jax.Array,
    z: jax.Array,
    n: jax.Array,
    *,
    br: int = 64,   # 13 live blocks (7 in + 6 out): see lint_kernels budget
    interpret: bool = True,
):
    """Pipelined CG's six vector recurrences in one VMEM pass.

    ``z' = n + βz``, ``s' = w + βs``, ``p' = r + βp``, ``x' = x + αp'``,
    ``r' = r − αs'``, ``w' = w − αz'`` (Ghysels–Vanroose ordering).
    Returns ``(x', r', w', p', s', z')``.
    """
    shape = x.shape
    tiles = [_tile(v)[0] for v in (x, r, w, p, s, z, n)]
    nflat = x.size
    rows = tiles[0].shape[0]
    brr = _row_grid(rows, br)
    coef = jnp.stack([alpha, beta]).astype(x.dtype).reshape(1, 2)
    blk = lambda: pl.BlockSpec((brr, ROW), lambda i: (i, 0))
    outs = pl.pallas_call(
        _pipe_kernel,
        grid=(rows // brr,),
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0))] + [blk()] * 7,
        out_specs=[blk()] * 6,
        out_shape=[jax.ShapeDtypeStruct(tiles[0].shape, x.dtype)] * 6,
        interpret=interpret,
    )(coef, *tiles)
    return tuple(o.reshape(-1)[:nflat].reshape(shape) for o in outs)


def _pcg_kernel(*refs):
    coef, x, r, u, p, s, w, x_o, r_o, p_o, s_o = refs
    alpha = coef[0, 0]
    beta = coef[0, 1]
    p_new = u[...] + beta * p[...]
    s_new = w[...] + beta * s[...]
    p_o[...] = p_new
    s_o[...] = s_new
    x_o[...] = x[...] + alpha * p_new
    r_o[...] = r[...] - alpha * s_new


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def fused_pcg_body(
    alpha: jax.Array,
    beta: jax.Array,
    x: jax.Array,
    r: jax.Array,
    u: jax.Array,
    p: jax.Array,
    s: jax.Array,
    w: jax.Array,
    *,
    br: int = 128,   # 10 live blocks (6 in + 4 out)
    interpret: bool = True,
):
    """Merged PCG's four vector updates in one VMEM pass.

    ``p' = u + βp`` (the preconditioned residual drives the search
    direction), ``s' = w + βs``, ``x' = x + αp'``, ``r' = r − αs'``.
    Returns ``(x', r', p', s')``.
    """
    shape = x.shape
    tiles = [_tile(v)[0] for v in (x, r, u, p, s, w)]
    nflat = x.size
    rows = tiles[0].shape[0]
    brr = _row_grid(rows, br)
    coef = jnp.stack([alpha, beta]).astype(x.dtype).reshape(1, 2)
    blk = lambda: pl.BlockSpec((brr, ROW), lambda i: (i, 0))
    outs = pl.pallas_call(
        _pcg_kernel,
        grid=(rows // brr,),
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0))] + [blk()] * 6,
        out_specs=[blk()] * 4,
        out_shape=[jax.ShapeDtypeStruct(tiles[0].shape, x.dtype)] * 4,
        interpret=interpret,
    )(coef, *tiles)
    return tuple(o.reshape(-1)[:nflat].reshape(shape) for o in outs)


def _ppipe_kernel(*refs):
    (coef, x, r, u, w, p, s, q, z, m, n,
     x_o, r_o, u_o, w_o, p_o, s_o, q_o, z_o) = refs
    alpha = coef[0, 0]
    beta = coef[0, 1]
    z_new = n[...] + beta * z[...]
    q_new = m[...] + beta * q[...]
    s_new = w[...] + beta * s[...]
    p_new = u[...] + beta * p[...]
    z_o[...] = z_new
    q_o[...] = q_new
    s_o[...] = s_new
    p_o[...] = p_new
    x_o[...] = x[...] + alpha * p_new
    r_o[...] = r[...] - alpha * s_new
    u_o[...] = u[...] - alpha * q_new
    w_o[...] = w[...] - alpha * z_new


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def fused_ppipe_body(
    alpha: jax.Array,
    beta: jax.Array,
    x: jax.Array,
    r: jax.Array,
    u: jax.Array,
    w: jax.Array,
    p: jax.Array,
    s: jax.Array,
    q: jax.Array,
    z: jax.Array,
    m: jax.Array,
    n: jax.Array,
    *,
    br: int = 64,   # 18 live blocks (10 in + 8 out)
    interpret: bool = True,
):
    """Pipelined PCG's eight vector recurrences in one VMEM pass.

    ``z' = n + βz``, ``q' = m + βq``, ``s' = w + βs``, ``p' = u + βp``,
    ``x' = x + αp'``, ``r' = r − αs'``, ``u' = u − αq'``, ``w' = w − αz'``.
    Returns ``(x', r', u', w', p', s', q', z')``.
    """
    shape = x.shape
    tiles = [_tile(v)[0] for v in (x, r, u, w, p, s, q, z, m, n)]
    nflat = x.size
    rows = tiles[0].shape[0]
    brr = _row_grid(rows, br)
    coef = jnp.stack([alpha, beta]).astype(x.dtype).reshape(1, 2)
    blk = lambda: pl.BlockSpec((brr, ROW), lambda i: (i, 0))
    outs = pl.pallas_call(
        _ppipe_kernel,
        grid=(rows // brr,),
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0))] + [blk()] * 10,
        out_specs=[blk()] * 8,
        out_shape=[jax.ShapeDtypeStruct(tiles[0].shape, x.dtype)] * 8,
        interpret=interpret,
    )(coef, *tiles)
    return tuple(o.reshape(-1)[:nflat].reshape(shape) for o in outs)


def _bicgstab_u1_kernel(*refs):
    coef, y, p, q, yv, t, v, y_o, r_o, w_o = refs
    alpha = coef[0, 0]
    omega = coef[0, 1]
    y_o[...] = y[...] + alpha * p[...] + omega * q[...]
    r_o[...] = q[...] - omega * yv[...]
    w_o[...] = yv[...] - omega * (t[...] - alpha * v[...])


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def bicgstab_fused_update1(
    alpha: jax.Array,
    omega: jax.Array,
    y: jax.Array,
    p: jax.Array,
    q: jax.Array,
    yv: jax.Array,
    t: jax.Array,
    v: jax.Array,
    *,
    br: int = 128,   # 9 live blocks (6 in + 3 out)
    interpret: bool = True,
):
    """Single-reduction BiCGStab's ω-half updates in one VMEM pass.

    ``y' = y + αp + ωq``, ``r' = q − ω·yv``, ``w' = yv − ω(t − αv)``
    (Cools–Vanroose recurrences between the iteration's two SpMVs).
    Returns ``(y', r', w')``.
    """
    shape = y.shape
    tiles = [_tile(v_)[0] for v_ in (y, p, q, yv, t, v)]
    nflat = y.size
    rows = tiles[0].shape[0]
    brr = _row_grid(rows, br)
    coef = jnp.stack([alpha, omega]).astype(y.dtype).reshape(1, 2)
    blk = lambda: pl.BlockSpec((brr, ROW), lambda i: (i, 0))
    outs = pl.pallas_call(
        _bicgstab_u1_kernel,
        grid=(rows // brr,),
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0))] + [blk()] * 6,
        out_specs=[blk()] * 3,
        out_shape=[jax.ShapeDtypeStruct(tiles[0].shape, y.dtype)] * 3,
        interpret=interpret,
    )(coef, *tiles)
    return tuple(o.reshape(-1)[:nflat].reshape(shape) for o in outs)
