"""``PallasOp``: the LocalOp-protocol operator backed by the fused kernels.

Before PR 5 the fused Pallas iteration (``fused_cg_body`` + ``spmv_dots``)
was a local-only special case hard-wired to ``cg_merged`` in the facade.
``PallasOp`` turns it into a *backend*: it wraps any operator satisfying the
``LocalOp`` protocol (``LocalOp`` itself, or a ``DistributedOp`` inside a
``shard_map`` body) and supplies

  * the protocol surface (``matvec``/``matvec_local``/``pad_exchange``/
    ``diag``/``dot``/``dotn``) with the stencil apply running on the Pallas
    SpMV kernel, and
  * the fused-iteration hooks the ``MethodDef.fused_step`` bodies are
    written against — ``cg_body`` (all four merged-CG vector updates, one
    VMEM pass) and ``spmv_dots`` (SpMV + both dot partials, one VMEM pass).

Halo exchange comes from the wrapped operator (``jnp.pad`` locally,
ppermutes on a mesh) and the fused kernels' locally-accumulated dot
partials are made global through the wrapped operator's ``sum_partials``
(identity locally, ONE stacked psum on a mesh) — so the same fused method
body executes single-device and inside shard_map, which is how
``cg_merged`` + ``pallas=True`` now runs distributed.

The preconditioner fused kernels (``cheb_fused_step``, ``block_jacobi_sweep``)
ride the same wrapper: ``repro.precond`` binds against the PallasOp like any
other operator, so ``use_pallas`` preconditioners compose with the fused
solvers inside shard_map too.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops


class PallasOp:
    """Pallas-kernel execution of a wrapped LocalOp-protocol operator."""

    def __init__(self, base, *, bz: int = 8):
        self.base = base
        self.stencil = base.stencil
        self.bz = bz

    @property
    def diag(self) -> float:
        return self.base.diag

    # --- protocol surface (halos/reductions delegate to the wrapped op) ------
    def pad_exchange(self, x: jax.Array) -> jax.Array:
        return self.base.pad_exchange(x)

    def matvec(self, x: jax.Array) -> jax.Array:
        return ops.spmv(self.pad_exchange(x), self.stencil, bz=self.bz)

    def matvec_local(self, x: jax.Array) -> jax.Array:
        return ops.spmv(jnp.pad(x, 1), self.stencil, bz=self.bz)

    @property
    def dot(self):
        d = getattr(self.base, "dot", None)
        return d if d is not None else jnp.vdot

    def dotn(self, *pairs) -> tuple:
        return self.base.dotn(*pairs)

    def sum_partials(self, *vals) -> tuple:
        return self.base.sum_partials(*vals)

    # --- fused-iteration hooks (what MethodDef.fused_step is written against)
    def spmv_dots(self, x: jax.Array) -> tuple:
        """``(A·x, (A·x)·x, x·x)`` in one VMEM pass; the two dot partials are
        accumulated per local block inside the kernel and reduced globally
        through the wrapped operator (one stacked psum on a mesh)."""
        w, delta, gamma = ops.spmv_dots(self.pad_exchange(x), self.stencil,
                                        bz=self.bz)
        delta, gamma = self.sum_partials(delta, gamma)
        return w, delta, gamma

    def cg_body(self, alpha, beta, x, r, p, s, w) -> tuple:
        """Merged-CG's four vector updates in one VMEM pass (shard-local —
        no communication, so it needs no wrapping)."""
        return ops.cg_body(alpha, beta, x, r, p, s, w)
