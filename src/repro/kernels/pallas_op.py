"""``PallasOp``: the LocalOp-protocol operator backed by the fused kernels.

Before PR 5 the fused Pallas iteration (``fused_cg_body`` + ``spmv_dots``)
was a local-only special case hard-wired to ``cg_merged`` in the facade.
``PallasOp`` turns it into a *backend*: it wraps any operator satisfying the
``LocalOp`` protocol (``LocalOp`` itself, or a ``DistributedOp`` inside a
``shard_map`` body) and supplies

  * the protocol surface (``matvec``/``matvec_local``/``pad_exchange``/
    ``diag``/``dot``/``dotn``) with the stencil apply running on the Pallas
    SpMV kernel, and
  * the fused-iteration hooks the ``MethodDef.fused_step`` bodies are
    written against.  PR 10 grew these from the lone merged-CG pair
    (``cg_body`` + ``spmv_dots``) to the full reduction-hiding family:
    ``spmv_dots3``/``pcg_body`` (merged PCG), ``pipe_body`` (pipelined CG),
    ``fused_dots``/``ppipe_body`` (pipelined PCG) and the three-kernel
    BiCGStab set (``bicgstab_spmv_dots``/``bicgstab_update1``/
    ``bicgstab_spmv_update``).

Tile sizes come from ``kernels.autotune`` unless pinned: with the default
``bz=None`` each call resolves the persisted ``(stencil, grid, dtype,
device_kind)`` cache entry (falling back to the documented default table)
at trace time, so a tuning run changes the compiled tilings without any
call-site change.

Halo exchange comes from the wrapped operator (``jnp.pad`` locally,
ppermutes on a mesh) and the fused kernels' locally-accumulated dot
partials are made global through the wrapped operator's ``sum_partials``
(identity locally, ONE stacked psum on a mesh) — so the same fused method
body executes single-device and inside shard_map, which is how
``cg_merged`` + ``pallas=True`` now runs distributed.

The preconditioner fused kernels (``cheb_fused_step``, ``block_jacobi_sweep``)
ride the same wrapper: ``repro.precond`` binds against the PallasOp like any
other operator, so ``use_pallas`` preconditioners compose with the fused
solvers inside shard_map too.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import autotune, ops


class PallasOp:
    """Pallas-kernel execution of a wrapped LocalOp-protocol operator.

    ``bz=None`` (the default) consults the autotune cache per call; an
    explicit ``bz`` pins the slab depth and skips tuning entirely.
    """

    def __init__(self, base, *, bz: int | None = None):
        self.base = base
        self.stencil = base.stencil
        self.bz = bz

    def _tiles(self, x: jax.Array) -> tuple[int, int | None]:
        """(bz, br) for the local interior shape ``x`` — pinned or tuned.

        Runs at trace time (shapes/dtypes are static), so the cache lookup
        costs nothing per iteration; ``br=None`` keeps each row-tiled
        kernel's own VMEM-budgeted default.
        """
        if self.bz is not None:
            return self.bz, None
        dec = autotune.resolve(self.stencil.name, x.shape, x.dtype)
        return dec.bz, dec.br

    @property
    def diag(self) -> float:
        return self.base.diag

    # --- protocol surface (halos/reductions delegate to the wrapped op) ------
    def pad_exchange(self, x: jax.Array) -> jax.Array:
        return self.base.pad_exchange(x)

    def matvec(self, x: jax.Array) -> jax.Array:
        return ops.spmv(self.pad_exchange(x), self.stencil, bz=self._tiles(x)[0])

    def matvec_local(self, x: jax.Array) -> jax.Array:
        return ops.spmv(jnp.pad(x, 1), self.stencil, bz=self._tiles(x)[0])

    @property
    def dot(self):
        d = getattr(self.base, "dot", None)
        return d if d is not None else jnp.vdot

    def dotn(self, *pairs) -> tuple:
        return self.base.dotn(*pairs)

    def sum_partials(self, *vals) -> tuple:
        return self.base.sum_partials(*vals)

    # --- fused-iteration hooks (what MethodDef.fused_step is written against)
    def spmv_dots(self, x: jax.Array) -> tuple:
        """``(A·x, (A·x)·x, x·x)`` in one VMEM pass; the two dot partials are
        accumulated per local block inside the kernel and reduced globally
        through the wrapped operator (one stacked psum on a mesh)."""
        w, delta, gamma = ops.spmv_dots(self.pad_exchange(x), self.stencil,
                                        bz=self._tiles(x)[0])
        delta, gamma = self.sum_partials(delta, gamma)
        return w, delta, gamma

    def spmv_dots3(self, x: jax.Array, r: jax.Array) -> tuple:
        """``(A·x, (A·x)·x, r·x, r·r)`` in one VMEM pass — merged PCG's
        reduction triple (``x = u``) and pipelined CG's (``x = w``, first
        slot unused).  One stacked psum on a mesh."""
        y, yx, rx, rr = ops.spmv_dots3(self.pad_exchange(x), r, self.stencil,
                                       bz=self._tiles(x)[0])
        yx, rx, rr = self.sum_partials(yx, rx, rr)
        return y, yx, rx, rr

    def fused_dots(self, r, u, w) -> tuple:
        """``(r·u, w·u, r·r)`` in one read pass (pipelined PCG's triple on
        carried state); one stacked psum on a mesh."""
        return self.sum_partials(*ops.fused_dots(r, u, w))

    def cg_body(self, alpha, beta, x, r, p, s, w) -> tuple:
        """Merged-CG's four vector updates in one VMEM pass (shard-local —
        no communication, so it needs no wrapping)."""
        br = self._tiles(x)[1]
        if br is not None:
            return ops.cg_body(alpha, beta, x, r, p, s, w, br=br)
        return ops.cg_body(alpha, beta, x, r, p, s, w)

    def pcg_body(self, alpha, beta, x, r, u, p, s, w) -> tuple:
        """Merged PCG's four vector updates (shard-local)."""
        br = self._tiles(x)[1]
        if br is not None:
            return ops.pcg_body(alpha, beta, x, r, u, p, s, w, br=br)
        return ops.pcg_body(alpha, beta, x, r, u, p, s, w)

    def pipe_body(self, alpha, beta, x, r, w, p, s, z, n) -> tuple:
        """Pipelined CG's six vector recurrences (shard-local)."""
        return ops.pipe_body(alpha, beta, x, r, w, p, s, z, n)

    def ppipe_body(self, alpha, beta, x, r, u, w, p, s, q, z, m, n) -> tuple:
        """Pipelined PCG's eight vector recurrences (shard-local)."""
        return ops.ppipe_body(alpha, beta, x, r, u, w, p, s, q, z, m, n)

    def bicgstab_spmv_dots(self, zi, z, r, w, s, rhat, t, alpha) -> tuple:
        """BiCGStab sweep 1: ``v = A·z̃`` + ``q``/``y`` + all 9 partials;
        the partials ride ONE stacked psum on a mesh."""
        v, q, y, parts = ops.bicgstab_spmv_dots(
            self.pad_exchange(zi), z, r, w, s, rhat, t, alpha, self.stencil,
            bz=self._tiles(z)[0])
        return v, q, y, self.sum_partials(*parts)

    def bicgstab_update1(self, alpha, omega, y, p, q, yv, t, v) -> tuple:
        """BiCGStab's ω-half x/r/w updates (shard-local)."""
        return ops.bicgstab_update1(alpha, omega, y, p, q, yv, t, v)

    def bicgstab_spmv_update(self, wi, w, r, p, s, z, v, omega, beta) -> tuple:
        """BiCGStab sweep 2: ``t' = A·w̃`` + the direction recurrences."""
        return ops.bicgstab_spmv_update(
            self.pad_exchange(wi), w, r, p, s, z, v, omega, beta,
            self.stencil, bz=self._tiles(w)[0])
