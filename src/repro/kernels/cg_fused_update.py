"""Pallas TPU kernels: fused CG vector-update passes.

``cg_fused_update`` — CG-NB's fused Tk1&2 (+Tk2's reduction partial).
Alg. 1 lines 6-8 share all their operands, so the paper assigns them to
adjacent tasks; the TPU analogue is a single VMEM pass computing

    Ap_new = Ar + β·Ap
    p_new  = r  + β·p
    α_d    = Σ Ap_new · p_new        (partial, reduced outside)

One read of {r, Ar, p, Ap} + one write of {p_new, Ap_new} instead of three
separate kernels (two axpbys + a dot) costing 6 reads + 2 writes.

``fused_cg_body`` (PR 4) — the ENTIRE vector-update half of a merged-CG
iteration (``core.solvers.cg_merged``) in one VMEM pass:

    p' = r + β·p,   s' = w + β·s,   x' = x + α·p',   r' = r − α·s'

5 reads + 4 writes instead of the four separate axpys' 8 reads + 4 writes
(and three kernel-switch HBM round trips).  Together with
``spmv_dot.stencil_spmv_dots`` this collapses a merged-CG iteration to two
HBM passes — the "single-pass fused iteration" benchmarked by
benchmarks/bench_kernels.py.  Oracle: ``ref.fused_cg_body_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_axpby import ROW, _to_2d


def _kernel(*refs):
    coef, r, ar, p, ap, p_out, ap_out, acc = refs
    beta = coef[0, 0]
    p_new = r[...] + beta * p[...]
    ap_new = ar[...] + beta * ap[...]
    p_out[...] = p_new
    ap_out[...] = ap_new
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc[0, 0] = jnp.zeros((), acc.dtype)

    acc[0, 0] += jnp.sum(ap_new * p_new).astype(acc.dtype)


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def cg_fused_update(
    beta: jax.Array,
    r: jax.Array,
    ar: jax.Array,
    p: jax.Array,
    ap: jax.Array,
    *,
    br: int = 256,
    interpret: bool = True,
):
    """Returns ``(p_new, Ap_new, partial_dot)``."""
    shape = r.shape
    r2, n = _to_2d(r)
    ar2, _ = _to_2d(ar)
    p2, _ = _to_2d(p)
    ap2, _ = _to_2d(ap)
    rows = r2.shape[0]
    brr = min(br, rows)
    while rows % brr:
        brr -= 1
    acc_dtype = jnp.float32 if r.dtype == jnp.bfloat16 else r.dtype
    coef = beta.astype(r.dtype).reshape(1, 1)
    blk = lambda: pl.BlockSpec((brr, ROW), lambda i: (i, 0))
    p_new, ap_new, acc = pl.pallas_call(
        _kernel,
        grid=(rows // brr,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)), blk(), blk(), blk(), blk()],
        out_specs=[blk(), blk(), pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[
            jax.ShapeDtypeStruct(r2.shape, r.dtype),
            jax.ShapeDtypeStruct(r2.shape, r.dtype),
            jax.ShapeDtypeStruct((1, 1), acc_dtype),
        ],
        interpret=interpret,
    )(coef, r2, ar2, p2, ap2)
    return (
        p_new.reshape(-1)[:n].reshape(shape),
        ap_new.reshape(-1)[:n].reshape(shape),
        acc[0, 0],
    )


def _body_kernel(*refs):
    coef, x, r, p, s, w, x_out, r_out, p_out, s_out = refs
    alpha = coef[0, 0]
    beta = coef[0, 1]
    p_new = r[...] + beta * p[...]
    s_new = w[...] + beta * s[...]
    p_out[...] = p_new
    s_out[...] = s_new
    x_out[...] = x[...] + alpha * p_new
    r_out[...] = r[...] - alpha * s_new


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def fused_cg_body(
    alpha: jax.Array,
    beta: jax.Array,
    x: jax.Array,
    r: jax.Array,
    p: jax.Array,
    s: jax.Array,
    w: jax.Array,
    *,
    br: int = 128,   # 9 live blocks (5 in + 4 out): br=256 would double-buffer
    interpret: bool = True,  # past 16 MiB VMEM (repro.analysis.lint_kernels)
):
    """One merged-CG iteration's four vector updates in one VMEM pass.

    Returns ``(x', r', p', s')`` with ``p' = r + β p``, ``s' = w + β s``,
    ``x' = x + α p'``, ``r' = r − α s'`` (the Chronopoulos–Gear ordering:
    x/r consume the UPDATED p/s).
    """
    shape = x.shape
    x2, n = _to_2d(x)
    r2, _ = _to_2d(r)
    p2, _ = _to_2d(p)
    s2, _ = _to_2d(s)
    w2, _ = _to_2d(w)
    rows = x2.shape[0]
    brr = min(br, rows)
    while rows % brr:
        brr -= 1
    coef = jnp.stack([alpha, beta]).astype(x.dtype).reshape(1, 2)
    blk = lambda: pl.BlockSpec((brr, ROW), lambda i: (i, 0))
    outs = pl.pallas_call(
        _body_kernel,
        grid=(rows // brr,),
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0)),
                  blk(), blk(), blk(), blk(), blk()],
        out_specs=[blk(), blk(), blk(), blk()],
        out_shape=[jax.ShapeDtypeStruct(x2.shape, x.dtype)] * 4,
        interpret=interpret,
    )(coef, x2, r2, p2, s2, w2)
    return tuple(o.reshape(-1)[:n].reshape(shape) for o in outs)
