"""Pallas TPU kernel: CG-NB's fused Tk1&2 (+Tk2's reduction partial).

Alg. 1 lines 6-8 share all their operands, so the paper assigns them to
adjacent tasks; the TPU analogue is a single VMEM pass computing

    Ap_new = Ar + β·Ap
    p_new  = r  + β·p
    α_d    = Σ Ap_new · p_new        (partial, reduced outside)

One read of {r, Ar, p, Ap} + one write of {p_new, Ap_new} instead of three
separate kernels (two axpbys + a dot) costing 6 reads + 2 writes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_axpby import ROW, _to_2d


def _kernel(*refs):
    coef, r, ar, p, ap, p_out, ap_out, acc = refs
    beta = coef[0, 0]
    p_new = r[...] + beta * p[...]
    ap_new = ar[...] + beta * ap[...]
    p_out[...] = p_new
    ap_out[...] = ap_new
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc[0, 0] = jnp.zeros((), acc.dtype)

    acc[0, 0] += jnp.sum(ap_new * p_new).astype(acc.dtype)


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def cg_fused_update(
    beta: jax.Array,
    r: jax.Array,
    ar: jax.Array,
    p: jax.Array,
    ap: jax.Array,
    *,
    br: int = 256,
    interpret: bool = True,
):
    """Returns ``(p_new, Ap_new, partial_dot)``."""
    shape = r.shape
    r2, n = _to_2d(r)
    ar2, _ = _to_2d(ar)
    p2, _ = _to_2d(p)
    ap2, _ = _to_2d(ap)
    rows = r2.shape[0]
    brr = min(br, rows)
    while rows % brr:
        brr -= 1
    acc_dtype = jnp.float32 if r.dtype == jnp.bfloat16 else r.dtype
    coef = beta.astype(r.dtype).reshape(1, 1)
    blk = lambda: pl.BlockSpec((brr, ROW), lambda i: (i, 0))
    p_new, ap_new, acc = pl.pallas_call(
        _kernel,
        grid=(rows // brr,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)), blk(), blk(), blk(), blk()],
        out_specs=[blk(), blk(), pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[
            jax.ShapeDtypeStruct(r2.shape, r.dtype),
            jax.ShapeDtypeStruct(r2.shape, r.dtype),
            jax.ShapeDtypeStruct((1, 1), acc_dtype),
        ],
        interpret=interpret,
    )(coef, r2, ar2, p2, ap2)
    return (
        p_new.reshape(-1)[:n].reshape(shape),
        ap_new.reshape(-1)[:n].reshape(shape),
        acc[0, 0],
    )
