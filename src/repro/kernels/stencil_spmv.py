"""Pallas TPU kernel: 7/27-point stencil SpMV with z-plane VMEM tiling.

The paper's hot kernel is the CSR SpMV (Code 1/3).  On TPU we exploit the
structure (DESIGN.md §2): the operator is a constant-coefficient stencil, so
each grid step streams a slab of ``bz`` z-planes (plus one halo plane on each
side — expressed with an *overlapping-window* ``pl.Element`` BlockSpec, HBM
traffic (bz+2)/bz instead of re-reading neighbours) into VMEM and applies the
stencil as shifted 2-D adds on the VPU.

Fusion (the task-merging analogue, §3.3): ``fuse_dot=True`` additionally
accumulates the partial ``(A·x)·x`` reduction in the same VMEM pass — this is
what lets CG compute ``α_d = (A·p)·p`` without a second memory sweep.  The
accumulator output revisits the same (1,1) block every grid step; TPU grid
iterations are sequential, so the accumulation is well-defined.

VMEM budget per grid step (f32): (bz+2 + bz) · (nx+2)(ny+2) · 4 B; with the
default bz=8 and 128² planes that is ~1.2 MiB — comfortably double-bufferable
in 16 MiB VMEM, with MXU-free VPU work at 8×128-aligned shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.operators import Stencil


def _window_spec(nx: int, ny: int, bz: int) -> pl.BlockSpec:
    """The overlapping (nx+2, ny+2, bz+2) input window, z-indexed by element
    offset ``i*bz``.  Newer pallas spells the mixed mode per-dim with
    ``pl.Element``; older pallas only has whole-spec ``Unblocked`` indexing,
    which is equivalent here because the x/y offsets are always 0."""
    if hasattr(pl, "Element"):
        return pl.BlockSpec(
            (nx + 2, ny + 2, pl.Element(bz + 2)), lambda i: (0, 0, i * bz)
        )
    return pl.BlockSpec(
        (nx + 2, ny + 2, bz + 2), lambda i: (0, 0, i * bz),
        indexing_mode=pl.Unblocked(),
    )


def _pick_bz(nz: int, requested: int) -> int:
    bz = min(requested, nz)
    while nz % bz:
        bz -= 1
    return bz


def apply_stencil_slab(stencil: Stencil, x_slab, nx: int, ny: int, bz: int):
    """``A x`` on one (nx+2, ny+2, bz+2) window -> (nx, ny, bz) slab.

    The shared slab-apply of every stencil-consuming kernel (SpMV here,
    the fused preconditioner steps in kernels/precond.py): z-offsets are
    grouped so each of the three z-planes is sliced once.
    """
    off_groups: dict[int, list[tuple[int, int]]] = {-1: [], 0: [], 1: []}
    for dx, dy, dz in stencil.offsets:
        off_groups[dz].append((dx, dy))
    y = stencil.diag * x_slab[1:-1, 1:-1, 1:-1]
    for dz, xy in off_groups.items():
        zsl = x_slab[:, :, 1 + dz : 1 + dz + bz]
        for dx, dy in xy:
            y = y + stencil.off_coeff * zsl[
                1 + dx : 1 + dx + nx, 1 + dy : 1 + dy + ny, :
            ]
    return y


def _kernel(stencil: Stencil, nx: int, ny: int, bz: int, fuse_dot: bool):
    def body(*refs):
        if fuse_dot:
            xin, out, acc = refs
        else:
            xin, out = refs
        # xin: (nx+2, ny+2, bz+2) overlapping window; out: (nx, ny, bz)
        x_slab = xin[...]
        centre = x_slab[1:-1, 1:-1, 1:-1]
        y = apply_stencil_slab(stencil, x_slab, nx, ny, bz)
        out[...] = y
        if fuse_dot:
            i = pl.program_id(0)

            @pl.when(i == 0)
            def _init():
                acc[0, 0] = jnp.zeros((), acc.dtype)

            acc[0, 0] += jnp.sum(y * centre).astype(acc.dtype)

    return body


@functools.partial(
    jax.jit, static_argnames=("stencil", "bz", "fuse_dot", "interpret")
)
def stencil_spmv(
    xp: jax.Array,
    *,
    stencil: Stencil,
    bz: int = 8,
    fuse_dot: bool = False,
    interpret: bool = True,
):
    """``y = A·x`` (and optionally ``y·x``) from the halo-padded ``xp``.

    ``xp``: (nx+2, ny+2, nz+2).  Returns ``y`` of shape (nx, ny, nz), or
    ``(y, dot)`` when ``fuse_dot``.
    """
    nx, ny, nz = xp.shape[0] - 2, xp.shape[1] - 2, xp.shape[2] - 2
    bz = _pick_bz(nz, bz)
    acc_dtype = jnp.float32 if xp.dtype == jnp.bfloat16 else xp.dtype

    out_shape = [jax.ShapeDtypeStruct((nx, ny, nz), xp.dtype)]
    out_specs = [pl.BlockSpec((nx, ny, bz), lambda i: (0, 0, i))]
    if fuse_dot:
        out_shape.append(jax.ShapeDtypeStruct((1, 1), acc_dtype))
        out_specs.append(pl.BlockSpec((1, 1), lambda i: (0, 0)))

    res = pl.pallas_call(
        _kernel(stencil, nx, ny, bz, fuse_dot),
        grid=(nz // bz,),
        in_specs=[_window_spec(nx, ny, bz)],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(xp)
    if fuse_dot:
        return res[0], res[1][0, 0]
    return res[0]
