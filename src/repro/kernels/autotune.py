"""Persistent autotuner for the Pallas kernel tilings and the XLA crossover.

The fused kernels have two free tiling knobs — ``bz`` (slab depth of the
stencil kernels) and ``br`` (row-block of the flattened vector-update
kernels) — plus one *routing* decision: below a crossover volume the
per-kernel dispatch overhead makes the separately-launched Pallas path
slower than letting XLA fuse the whole jitted iteration (the measured 16³
case where ``cg_classic_kernels`` ran 3.5× behind ``cg_classic_jit``).

``sweep`` measures all three per ``(stencil, grid, dtype, device_kind)``
and ``tune`` persists the winner in a JSON cache (same key discipline as
the serve executable cache: exact shapes, no fuzzy matching).  ``resolve``
is the read side consulted by ``PallasOp`` (tile sizes) and
``SolverSession`` (``options.pallas = None`` → the routing bit); a cache
miss falls back to the static default table below, so nothing ever
*requires* a tuning run:

  default table
  -------------
  use_pallas :  backend == "tpu"  AND  nx·ny·nz >= MIN_PALLAS_VOLUME (24³)
  bz         :  8   (shrunk per-shape by ``_pick_bz`` as always)
  br         :  None (each kernel's own VMEM-budgeted default)

Cache file: ``$REPRO_AUTOTUNE_CACHE`` or ``~/.cache/repro/autotune.json``.
CLI: ``python -m repro.kernels.autotune --grid 32 32 32 [--retune]``;
``--smoke`` runs the two bounded CI configs (see ``make autotune-smoke``).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp

DEFAULT_BZ = 8
MIN_PALLAS_VOLUME = 24 ** 3   # below this, XLA whole-iteration fusion wins
BZ_CANDIDATES = (4, 8, 16)
BR_CANDIDATES = (64, 128, 256)

_DTYPES = {"float32": jnp.float32, "float64": jnp.float64,
           "bfloat16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class TuneDecision:
    """What the kernel layer should do at one (stencil, grid, dtype) point.

    ``br = None`` keeps each row-tiled kernel's own VMEM-budgeted default;
    a tuned value overrides only the merged-CG/PCG body family the sweep
    actually measures.  ``source`` is ``"default"`` (static table) or
    ``"cache"`` (a persisted tuning run) — surfaced in telemetry/bench so
    a silent fallback is visible.
    """

    use_pallas: bool
    bz: int = DEFAULT_BZ
    br: int | None = None
    source: str = "default"


def device_kind() -> str:
    return jax.devices()[0].device_kind


def tune_key(stencil: str, grid, dtype, kind: str | None = None) -> str:
    nx, ny, nz = grid
    kind = device_kind() if kind is None else kind
    return f"{stencil}|{nx}x{ny}x{nz}|{jnp.dtype(dtype).name}|{kind}"


def cache_path() -> Path:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "autotune.json"


_CACHE: tuple[Path, float, dict] | None = None


def load_cache(path: Path | None = None) -> dict:
    """The persisted tune table, memoized on (path, mtime)."""
    global _CACHE
    path = cache_path() if path is None else Path(path)
    try:
        mtime = path.stat().st_mtime
    except OSError:
        return {}
    if _CACHE is not None and _CACHE[0] == path and _CACHE[1] == mtime:
        return _CACHE[2]
    try:
        table = json.loads(path.read_text())
    except (OSError, ValueError):
        return {}
    _CACHE = (path, mtime, table)
    return table


def save_cache(table: dict, path: Path | None = None) -> Path:
    global _CACHE
    path = cache_path() if path is None else Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(table, indent=2, sort_keys=True) + "\n")
    _CACHE = None
    return path


def default_decision(grid, *, backend: str | None = None) -> TuneDecision:
    """The documented static fallback (no cache entry, no tuning run)."""
    backend = jax.default_backend() if backend is None else backend
    nx, ny, nz = grid
    on = backend == "tpu" and nx * ny * nz >= MIN_PALLAS_VOLUME
    return TuneDecision(use_pallas=on)


def resolve(stencil: str, grid, dtype, *,
            path: Path | None = None) -> TuneDecision:
    """Cache lookup with default-table fallback (the PallasOp/session read)."""
    entry = load_cache(path).get(tune_key(stencil, grid, dtype))
    if entry is None:
        return default_decision(grid)
    return TuneDecision(use_pallas=bool(entry["use_pallas"]),
                        bz=int(entry["bz"]),
                        br=None if entry.get("br") is None else int(entry["br"]),
                        source="cache")


# ---------------------------------------------------------------- measurement

def _timeit(fn, *args, repeats: int = 3) -> float:
    """min-of-repeats wall seconds for fn(*args) (compile excluded)."""
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def sweep(grid, stencil: str = "7pt", dtype=jnp.float32, *,
          repeats: int = 3) -> dict:
    """Measure bz/br winners and the Pallas-vs-XLA crossover at one point.

    Returns a JSON-ready cache entry.  Off-TPU the Pallas timings are the
    ``interpret=True`` path — honest for the routing bit (interpret mode
    *should* lose to XLA), meaningless as absolute kernel throughput; the
    entry records ``backend`` so a cache tuned on one device kind is never
    mistaken for another (the key already pins ``device_kind``).
    """
    from repro.core.problems import make_problem
    from repro.kernels import ops, ref

    prob = make_problem(tuple(grid), stencil)
    st = prob.stencil
    nx, ny, nz = grid
    key = jax.random.PRNGKey(0)
    r = jax.random.normal(key, (nx, ny, nz), dtype)
    xp = jnp.pad(r, 1)
    vecs = [jax.random.normal(jax.random.fold_in(key, i), (nx, ny, nz), dtype)
            for i in range(5)]
    alpha = jnp.asarray(0.5, dtype)
    beta = jnp.asarray(0.1, dtype)

    # -- bz: the slab SpMV+dots kernel, all candidates that divide nz
    bz_times = {}
    for bz in BZ_CANDIDATES:
        if nz % bz:
            continue
        bz_times[bz] = _timeit(
            lambda b=bz: ops.spmv_dots(xp, st, bz=b), repeats=repeats)
    best_bz = min(bz_times, key=bz_times.get) if bz_times else DEFAULT_BZ

    # -- br: the row-tiled merged-body kernel
    br_times = {}
    for br in BR_CANDIDATES:
        br_times[br] = _timeit(
            lambda b=br: ops.cg_body(alpha, beta, *vecs[:4], r, br=b),
            repeats=repeats)
    best_br = min(br_times, key=br_times.get)

    # -- crossover: separately-dispatched Pallas pass vs whole-jit XLA ref
    pallas_t = _timeit(lambda: ops.spmv_dots(xp, st, bz=best_bz),
                       repeats=repeats)
    xla = jax.jit(lambda a: ref.stencil_spmv_dots_ref(a, stencil=st))
    xla_t = _timeit(xla, xp, repeats=repeats)

    return {
        "use_pallas": bool(pallas_t <= xla_t),
        "bz": int(best_bz),
        "br": int(best_br),
        "backend": jax.default_backend(),
        "timings": {
            "bz": {str(k): v for k, v in bz_times.items()},
            "br": {str(k): v for k, v in br_times.items()},
            "pallas_s": pallas_t,
            "xla_s": xla_t,
        },
    }


def tune(grid, stencil: str = "7pt", dtype=jnp.float32, *,
         path: Path | None = None, retune: bool = False,
         repeats: int = 3) -> TuneDecision:
    """Sweep-and-persist (skipped if already cached, unless ``retune``)."""
    key = tune_key(stencil, grid, dtype)
    table = dict(load_cache(path))
    if key not in table or retune:
        table[key] = sweep(grid, stencil, dtype, repeats=repeats)
        save_cache(table, path)
    return resolve(stencil, grid, dtype, path=path)


SMOKE_CONFIGS = (((16, 16, 16), "7pt"), ((32, 32, 32), "7pt"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--grid", type=int, nargs=3, default=(32, 32, 32))
    ap.add_argument("--stencil", choices=("7pt", "27pt"), default="7pt")
    ap.add_argument("--dtype", choices=sorted(_DTYPES), default="float32")
    ap.add_argument("--cache", type=Path, default=None,
                    help="cache file (default: $REPRO_AUTOTUNE_CACHE)")
    ap.add_argument("--retune", action="store_true",
                    help="re-measure even if the key is already cached")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="bounded sweep over the two CI configs")
    args = ap.parse_args(argv)

    configs = (SMOKE_CONFIGS if args.smoke
               else (((tuple(args.grid)), args.stencil),))
    for grid, stencil in configs:
        dec = tune(grid, stencil, _DTYPES[args.dtype], path=args.cache,
                   retune=args.retune, repeats=args.repeats)
        print(f"{tune_key(stencil, grid, _DTYPES[args.dtype])}: "
              f"use_pallas={dec.use_pallas} bz={dec.bz} br={dec.br} "
              f"[{dec.source}]")
    print(f"cache: {args.cache or cache_path()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
