# Pallas TPU kernels for the compute hot-spots (pl.pallas_call + explicit
# BlockSpec VMEM tiling), each with a jit'd wrapper in ops.py and a pure-jnp
# oracle in ref.py (validated via interpret=True on CPU):
#
#   stencil_spmv     — 7/27-pt stencil SpMV, overlapping-window z-slabs,
#                      optional fused (A·x)·x partial (the paper's SpMV)
#   fused_axpby      — the paper's ad hoc z := a·x + b·y + c·z (+ fused dot)
#   cg_fused_update  — Alg.1 Tk1&2 in one VMEM pass (Ap, p updates + dot)
#   rb_gs            — red-black Gauss-Seidel half sweep (§3.4)
#   precond          — fused preconditioner steps: Chebyshev matvec+axpby
#                      chain and the block-Jacobi damped sweep, one VMEM pass
#   flash_attention  — causal online-softmax attention, (bq×bkv) VMEM tiles
#                      (the LM stack's chunked-attention endpoint)
from repro.kernels import ops, ref  # noqa: F401
