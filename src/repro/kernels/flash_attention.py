"""Pallas TPU kernel: causal flash attention (online softmax, VMEM-blocked).

The on-TPU endpoint of ``models/attention._chunked_sdpa``: same blocking
(q-chunks × kv-chunks, running max/denominator in f32), but as an explicit
``pl.pallas_call`` with VMEM BlockSpecs — one (bq × hd) accumulator and one
(bq × bkv) score tile resident per grid step, HBM traffic 1× q + nq-fold k/v
streaming, no (S, S) materialisation.

Grid: (B·H, nq, nkv), kv innermost — TPU executes grid steps sequentially per
core, so the f32 scratch accumulators carry across the kv dimension and are
re-initialised at kv block 0 (the same revisiting-output pattern as the
solver kernels' fused dots).  Causal blocks above the diagonal are predicated
off with ``pl.when``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38


def _kernel(bq: int, bkv: int, hd: int, scale: float, window: int):
    def body(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr):
        i = pl.program_id(1)          # q block
        j = pl.program_id(2)          # kv block
        nk = pl.num_programs(2)

        @pl.when(j == 0)
        def _init():
            m_scr[...] = jnp.full((bq,), -jnp.inf, jnp.float32)
            l_scr[...] = jnp.zeros((bq,), jnp.float32)
            acc_scr[...] = jnp.zeros((bq, hd), jnp.float32)

        @pl.when(j * bkv <= i * bq + bq - 1)   # causal: block reachable
        def _compute():
            q = q_ref[0]              # (bq, hd)
            k = k_ref[0]              # (bkv, hd)
            s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
            q_idx = i * bq + jnp.arange(bq)
            k_idx = j * bkv + jnp.arange(bkv)
            mask = k_idx[None, :] <= q_idx[:, None]
            if window:
                mask &= k_idx[None, :] > (q_idx[:, None] - window)
            s = jnp.where(mask, s, NEG_INF)
            m_prev = m_scr[...]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[:, None])
            alpha = jnp.exp(m_prev - m_new)
            l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
            acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
                p.astype(v_ref.dtype), v_ref[0]).astype(jnp.float32)
            m_scr[...] = m_new

        @pl.when(j == nk - 1)
        def _finish():
            o_ref[0] = (acc_scr[...] /
                        jnp.maximum(l_scr[...], 1e-30)[:, None]
                        ).astype(o_ref.dtype)

    return body


@functools.partial(jax.jit,
                   static_argnames=("bq", "bkv", "window", "interpret"))
def flash_attention(
    q: jax.Array,           # (B, S, H, hd)
    k: jax.Array,           # (B, S, H, hd)  (KV already repeated to H)
    v: jax.Array,
    *,
    bq: int = 256,
    bkv: int = 256,
    window: int = 0,
    interpret: bool = True,
) -> jax.Array:
    B, S, H, hd = q.shape
    while S % bq:
        bq -= 1
    while S % bkv:
        bkv -= 1
    scale = hd ** -0.5
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    out = pl.pallas_call(
        _kernel(bq, bkv, hd, scale, window),
        grid=(B * H, S // bq, S // bkv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            # (bq,) running max, (bq,) denominator, (bq, hd) accumulator —
            # persist across the sequential kv grid dim (VMEM on TPU)
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
