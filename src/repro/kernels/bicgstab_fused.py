"""Pallas TPU kernels: single-reduction BiCGStab's two fused SpMV sweeps.

The merged BiCGStab iteration (``core.methods.bicgstab_merged``) does two
SpMVs and NINE stacked dot partials per step.  Unfused that is ~11 HBM
sweeps; these two kernels plus ``fused_bodies.bicgstab_fused_update1``
collapse the iteration to three passes:

  1. ``bicgstab_fused_spmv_dots`` — the first SpMV ``v = A·z̃`` (z̃ = M(z)
     for the preconditioned variant) fused with the intermediate vectors
     ``q = r − αs``, ``y = w − αz`` AND all nine reduction partials
     ``(q·y, y·y, q·q, r̂·q, r̂·y, r̂·t, r̂·v, r̂·z, r̂·s)`` — one slab
     sweep feeds the iteration's single all-reduce.
  2. ``bicgstab_fused_spmv_update`` — the second SpMV ``t' = A·w̃`` fused
     with the three direction recurrences ``p' = r + β(p − ωs)``,
     ``s' = w + β(s − ωz)``, ``z' = t' + β(z − ωv)``.

Both reuse the overlapping-window slab BlockSpec of ``stencil_spmv``;
traced scalar coefficients ride a (1, k) block.  Partial accumulation
follows the sequential-TPU-grid idiom of ``spmv_dot.py`` (init at step 0,
``+=`` on the revisited accumulator block), so the slab-ordered sums are
deterministic for a fixed tiling.  Oracles:
``kernels/ref.py::bicgstab_spmv_dots_ref`` / ``bicgstab_spmv_update_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.operators import Stencil
from repro.kernels.stencil_spmv import _pick_bz, _window_spec, apply_stencil_slab


def _dots_kernel(stencil: Stencil, nx: int, ny: int, bz: int):
    def body(zin, coef, z, r, w, s, rhat, t, v_o, q_o, y_o, acc):
        # zin: (nx+2, ny+2, bz+2) window; coef: (1, 1) = [α]; the six plain
        # slabs and three outputs: (nx, ny, bz); acc: (1, 9) partials
        alpha = coef[0, 0]
        v = apply_stencil_slab(stencil, zin[...], nx, ny, bz)
        q = r[...] - alpha * s[...]
        y = w[...] - alpha * z[...]
        rh = rhat[...]
        v_o[...] = v
        q_o[...] = q
        y_o[...] = y
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            acc[...] = jnp.zeros((1, 9), acc.dtype)

        acc[0, 0] += jnp.sum(q * y).astype(acc.dtype)
        acc[0, 1] += jnp.sum(y * y).astype(acc.dtype)
        acc[0, 2] += jnp.sum(q * q).astype(acc.dtype)
        acc[0, 3] += jnp.sum(rh * q).astype(acc.dtype)
        acc[0, 4] += jnp.sum(rh * y).astype(acc.dtype)
        acc[0, 5] += jnp.sum(rh * t[...]).astype(acc.dtype)
        acc[0, 6] += jnp.sum(rh * v).astype(acc.dtype)
        acc[0, 7] += jnp.sum(rh * z[...]).astype(acc.dtype)
        acc[0, 8] += jnp.sum(rh * s[...]).astype(acc.dtype)

    return body


@functools.partial(jax.jit, static_argnames=("stencil", "bz", "interpret"))
def bicgstab_fused_spmv_dots(
    zp: jax.Array,
    z: jax.Array,
    r: jax.Array,
    w: jax.Array,
    s: jax.Array,
    rhat: jax.Array,
    t: jax.Array,
    alpha: jax.Array,
    *,
    stencil: Stencil,
    bz: int = 8,
    interpret: bool = True,
):
    """``v = A·z̃`` + intermediates ``q, y`` + all 9 partials, one sweep.

    ``zp``: (nx+2, ny+2, nz+2) halo-padded SpMV operand (``M(z)`` when
    preconditioned, else ``z``); the six interior-shaped vectors stream
    alongside.  Returns ``(v, q, y, parts)`` with ``parts`` the 9-tuple
    ``(q·y, y·y, q·q, r̂·q, r̂·y, r̂·t, r̂·v, r̂·z, r̂·s)``.
    """
    nx, ny, nz = zp.shape[0] - 2, zp.shape[1] - 2, zp.shape[2] - 2
    bz = _pick_bz(nz, bz)
    acc_dtype = jnp.float32 if zp.dtype == jnp.bfloat16 else zp.dtype
    coef = alpha.astype(zp.dtype).reshape(1, 1)
    slab = lambda: pl.BlockSpec((nx, ny, bz), lambda i: (0, 0, i))

    v, q, y, acc = pl.pallas_call(
        _dots_kernel(stencil, nx, ny, bz),
        grid=(nz // bz,),
        in_specs=[
            _window_spec(nx, ny, bz),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            slab(), slab(), slab(), slab(), slab(), slab(),
        ],
        out_specs=[
            slab(), slab(), slab(),
            pl.BlockSpec((1, 9), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nx, ny, nz), zp.dtype),
            jax.ShapeDtypeStruct((nx, ny, nz), zp.dtype),
            jax.ShapeDtypeStruct((nx, ny, nz), zp.dtype),
            jax.ShapeDtypeStruct((1, 9), acc_dtype),
        ],
        interpret=interpret,
    )(zp, coef, z, r, w, s, rhat, t)
    return v, q, y, tuple(acc[0, k] for k in range(9))


def _update_kernel(stencil: Stencil, nx: int, ny: int, bz: int):
    def body(win, coef, w, r, p, s, z, v, t_o, p_o, s_o, z_o):
        # win: (nx+2, ny+2, bz+2) window; coef: (1, 2) = [ω, β]
        omega = coef[0, 0]
        beta = coef[0, 1]
        t_new = apply_stencil_slab(stencil, win[...], nx, ny, bz)
        t_o[...] = t_new
        p_o[...] = r[...] + beta * (p[...] - omega * s[...])
        s_o[...] = w[...] + beta * (s[...] - omega * z[...])
        z_o[...] = t_new + beta * (z[...] - omega * v[...])

    return body


@functools.partial(jax.jit, static_argnames=("stencil", "bz", "interpret"))
def bicgstab_fused_spmv_update(
    wp: jax.Array,
    w: jax.Array,
    r: jax.Array,
    p: jax.Array,
    s: jax.Array,
    z: jax.Array,
    v: jax.Array,
    omega: jax.Array,
    beta: jax.Array,
    *,
    stencil: Stencil,
    bz: int = 8,
    interpret: bool = True,
):
    """``t' = A·w̃`` + the three direction recurrences, one sweep.

    ``wp``: (nx+2, ny+2, nz+2) halo-padded SpMV operand (``M(w')`` when
    preconditioned, else ``w'``).  Returns ``(t', p', s', z')`` with
    ``p' = r + β(p − ωs)``, ``s' = w + β(s − ωz)``, ``z' = t' + β(z − ωv)``.
    """
    nx, ny, nz = wp.shape[0] - 2, wp.shape[1] - 2, wp.shape[2] - 2
    bz = _pick_bz(nz, bz)
    coef = jnp.stack([omega, beta]).astype(wp.dtype).reshape(1, 2)
    slab = lambda: pl.BlockSpec((nx, ny, bz), lambda i: (0, 0, i))

    outs = pl.pallas_call(
        _update_kernel(stencil, nx, ny, bz),
        grid=(nz // bz,),
        in_specs=[
            _window_spec(nx, ny, bz),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
            slab(), slab(), slab(), slab(), slab(), slab(),
        ],
        out_specs=[slab(), slab(), slab(), slab()],
        out_shape=[jax.ShapeDtypeStruct((nx, ny, nz), wp.dtype)] * 4,
        interpret=interpret,
    )(wp, coef, w, r, p, s, z, v)
    return tuple(outs)
