"""Pallas TPU kernel: the paper's ad hoc vector-update kernel.

§3.1: CG-NB's extra vector update "can be optimised via the ad hoc kernel
``z := a·x + b·y + c·z`` that reuses memory".  This kernel does exactly that
in one VMEM pass, optionally fusing a dot-product partial (``out·w``) so the
following reduction needs no extra sweep — the fork-join "kernel switch
barrier" the paper's tasking removes corresponds here to an extra HBM round
trip, removed by fusion.

Data is processed as (rows, 128·k) tiles: the wrapper reshapes flat vectors
into lane-aligned 2-D blocks (TPU VPU registers are 8×128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: lane-aligned row width used by the flat-vector wrappers
ROW = 1024


def _kernel(fuse_dot: bool, br: int, cols: int):
    def body(*refs):
        if fuse_dot:
            coef, x, y, z, w, out, acc = refs
        else:
            coef, x, y, z, out = refs
        a = coef[0, 0]
        b = coef[0, 1]
        c = coef[0, 2]
        r = a * x[...] + b * y[...] + c * z[...]
        out[...] = r
        if fuse_dot:
            i = pl.program_id(0)

            @pl.when(i == 0)
            def _init():
                acc[0, 0] = jnp.zeros((), acc.dtype)

            acc[0, 0] += jnp.sum(r * w[...]).astype(acc.dtype)

    return body


def _to_2d(v: jax.Array) -> tuple[jax.Array, int]:
    n = v.size
    pad = (-n) % ROW
    if pad:
        v = jnp.concatenate([v.reshape(-1), jnp.zeros((pad,), v.dtype)])
    return v.reshape(-1, ROW), n


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def fused_axpby(
    a: jax.Array,
    x: jax.Array,
    b: jax.Array,
    y: jax.Array,
    c: jax.Array,
    z: jax.Array,
    *,
    br: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """``a·x + b·y + c·z`` elementwise, any (matching) shapes."""
    shape = x.shape
    x2, n = _to_2d(x)
    y2, _ = _to_2d(y)
    z2, _ = _to_2d(z)
    rows = x2.shape[0]
    brr = min(br, rows)
    while rows % brr:
        brr -= 1
    coef = jnp.stack([a, b, c]).astype(x.dtype).reshape(1, 3)
    out = pl.pallas_call(
        _kernel(False, brr, ROW),
        grid=(rows // brr,),
        in_specs=[
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
            pl.BlockSpec((brr, ROW), lambda i: (i, 0)),
            pl.BlockSpec((brr, ROW), lambda i: (i, 0)),
            pl.BlockSpec((brr, ROW), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((brr, ROW), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(coef, x2, y2, z2)
    return out.reshape(-1)[:n].reshape(shape)


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def fused_axpby_dot(
    a: jax.Array,
    x: jax.Array,
    b: jax.Array,
    y: jax.Array,
    c: jax.Array,
    z: jax.Array,
    w: jax.Array,
    *,
    br: int = 256,
    interpret: bool = True,
):
    """``out = a·x + b·y + c·z`` and the fused partial ``dot(out, w)``."""
    shape = x.shape
    x2, n = _to_2d(x)
    y2, _ = _to_2d(y)
    z2, _ = _to_2d(z)
    w2, _ = _to_2d(w)
    rows = x2.shape[0]
    brr = min(br, rows)
    while rows % brr:
        brr -= 1
    acc_dtype = jnp.float32 if x.dtype == jnp.bfloat16 else x.dtype
    coef = jnp.stack([a, b, c]).astype(x.dtype).reshape(1, 3)
    out, acc = pl.pallas_call(
        _kernel(True, brr, ROW),
        grid=(rows // brr,),
        in_specs=[
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
            pl.BlockSpec((brr, ROW), lambda i: (i, 0)),
            pl.BlockSpec((brr, ROW), lambda i: (i, 0)),
            pl.BlockSpec((brr, ROW), lambda i: (i, 0)),
            pl.BlockSpec((brr, ROW), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((brr, ROW), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x2.shape, x.dtype),
            jax.ShapeDtypeStruct((1, 1), acc_dtype),
        ],
        interpret=interpret,
    )(coef, x2, y2, z2, w2)
    return out.reshape(-1)[:n].reshape(shape), acc[0, 0]
