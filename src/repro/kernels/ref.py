"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.operators import Stencil


def stencil_spmv_ref(xp: jax.Array, *, stencil: Stencil) -> jax.Array:
    return stencil.matvec_padded(xp)


def stencil_spmv_dot_ref(xp: jax.Array, *, stencil: Stencil):
    y = stencil.matvec_padded(xp)
    x = xp[1:-1, 1:-1, 1:-1]
    acc_dtype = jnp.float32 if xp.dtype == jnp.bfloat16 else xp.dtype
    return y, jnp.sum(y.astype(acc_dtype) * x.astype(acc_dtype))


def stencil_spmv_dots_ref(xp: jax.Array, *, stencil: Stencil):
    """SpMV + BOTH merged-CG partials: ``(A x, (A x)·x, x·x)``."""
    y = stencil.matvec_padded(xp)
    x = xp[1:-1, 1:-1, 1:-1]
    acc_dtype = jnp.float32 if xp.dtype == jnp.bfloat16 else xp.dtype
    ya = y.astype(acc_dtype)
    xa = x.astype(acc_dtype)
    return y, jnp.sum(ya * xa), jnp.sum(xa * xa)


def stencil_spmv_dots3_ref(xp: jax.Array, r: jax.Array, *, stencil: Stencil):
    """SpMV + the reduction triple: ``(A x, (A x)·x, r·x, r·r)``."""
    y = stencil.matvec_padded(xp)
    x = xp[1:-1, 1:-1, 1:-1]
    acc_dtype = jnp.float32 if xp.dtype == jnp.bfloat16 else xp.dtype
    ya = y.astype(acc_dtype)
    xa = x.astype(acc_dtype)
    ra = r.astype(acc_dtype)
    return y, jnp.sum(ya * xa), jnp.sum(ra * xa), jnp.sum(ra * ra)


def fused_cg_body_ref(alpha, beta, x, r, p, s, w):
    """Merged-CG vector updates: p' = r+βp, s' = w+βs, x' = x+αp', r' = r−αs'."""
    p_new = r + beta * p
    s_new = w + beta * s
    return x + alpha * p_new, r - alpha * s_new, p_new, s_new


def fused_dots_ref(a, b, c):
    """Stacked partial dots ``(a·b, c·b, a·a)`` (pipelined PCG's triple)."""
    acc_dtype = jnp.float32 if a.dtype == jnp.bfloat16 else a.dtype
    aa = a.astype(acc_dtype)
    ba = b.astype(acc_dtype)
    ca = c.astype(acc_dtype)
    return jnp.sum(aa * ba), jnp.sum(ca * ba), jnp.sum(aa * aa)


def fused_pipe_body_ref(alpha, beta, x, r, w, p, s, z, n):
    """Pipelined CG's six recurrences (Ghysels–Vanroose ordering)."""
    z_new = n + beta * z
    s_new = w + beta * s
    p_new = r + beta * p
    return (x + alpha * p_new, r - alpha * s_new, w - alpha * z_new,
            p_new, s_new, z_new)


def fused_pcg_body_ref(alpha, beta, x, r, u, p, s, w):
    """Merged PCG's updates: p' = u+βp, s' = w+βs, x' = x+αp', r' = r−αs'."""
    p_new = u + beta * p
    s_new = w + beta * s
    return x + alpha * p_new, r - alpha * s_new, p_new, s_new


def fused_ppipe_body_ref(alpha, beta, x, r, u, w, p, s, q, z, m, n):
    """Pipelined PCG's eight recurrences."""
    z_new = n + beta * z
    q_new = m + beta * q
    s_new = w + beta * s
    p_new = u + beta * p
    return (x + alpha * p_new, r - alpha * s_new, u - alpha * q_new,
            w - alpha * z_new, p_new, s_new, q_new, z_new)


def bicgstab_spmv_dots_ref(zp, z, r, w, s, rhat, t, alpha, *, stencil: Stencil):
    """First BiCGStab sweep: ``v = A·z̃``, ``q``, ``y`` and all 9 partials."""
    v = stencil.matvec_padded(zp)
    q = r - alpha * s
    y = w - alpha * z
    acc_dtype = jnp.float32 if zp.dtype == jnp.bfloat16 else zp.dtype
    d = lambda a, b: jnp.sum(a.astype(acc_dtype) * b.astype(acc_dtype))
    parts = (d(q, y), d(y, y), d(q, q), d(rhat, q), d(rhat, y),
             d(rhat, t), d(rhat, v), d(rhat, z), d(rhat, s))
    return v, q, y, parts


def bicgstab_update1_ref(alpha, omega, y, p, q, yv, t, v):
    """BiCGStab ω-half: y' = y+αp+ωq, r' = q−ω·yv, w' = yv−ω(t−αv)."""
    return (y + alpha * p + omega * q,
            q - omega * yv,
            yv - omega * (t - alpha * v))


def bicgstab_spmv_update_ref(wp, w, r, p, s, z, v, omega, beta, *,
                             stencil: Stencil):
    """Second BiCGStab sweep: ``t' = A·w̃`` + the direction recurrences."""
    t_new = stencil.matvec_padded(wp)
    return (t_new,
            r + beta * (p - omega * s),
            w + beta * (s - omega * z),
            t_new + beta * (z - omega * v))


def fused_axpby_ref(a, x, b, y, c, z):
    return a * x + b * y + c * z


def fused_axpby_dot_ref(a, x, b, y, c, z, w):
    out = a * x + b * y + c * z
    acc_dtype = jnp.float32 if x.dtype == jnp.bfloat16 else x.dtype
    return out, jnp.vdot(out.astype(acc_dtype), w.astype(acc_dtype))


def cg_fused_update_ref(beta, r, ar, p, ap):
    p_new = r + beta * p
    ap_new = ar + beta * ap
    acc_dtype = jnp.float32 if r.dtype == jnp.bfloat16 else r.dtype
    return p_new, ap_new, jnp.vdot(ap_new.astype(acc_dtype), p_new.astype(acc_dtype))


def flash_attention_ref(q, k, v, *, window: int = 0):
    """Causal softmax attention, full-matrix form (q/k/v: (B,S,H,hd))."""
    B, S, H, hd = q.shape
    logits = jnp.einsum("bshn,bthn->bhst", q, k).astype(jnp.float32)
    logits *= hd ** -0.5
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = kp <= qp
    if window:
        mask &= kp > (qp - window)
    logits = jnp.where(mask[None, None], logits, -2.3819763e38)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthn->bshn", w, v)


def cheb_fused_step_ref(zp: jax.Array, r: jax.Array, d: jax.Array, *,
                        stencil: Stencil, a: float, c: float):
    az = stencil.matvec_padded(zp)
    d_new = a * d + c * (r - az)
    return zp[1:-1, 1:-1, 1:-1] + d_new, d_new


def block_jacobi_sweep_ref(zp: jax.Array, r: jax.Array, *, stencil: Stencil,
                           omega: float = 1.0):
    az = stencil.matvec_padded(zp)
    return zp[1:-1, 1:-1, 1:-1] + omega * (r - az) / stencil.diag


def rb_gs_half_sweep_ref(xp: jax.Array, b: jax.Array, *, stencil: Stencil, colour: int):
    x = xp[1:-1, 1:-1, 1:-1]
    off = stencil.offdiag_apply_padded(xp)
    gs = (b - off) / stencil.diag
    i = jax.lax.broadcasted_iota(jnp.int32, b.shape, 0)
    j = jax.lax.broadcasted_iota(jnp.int32, b.shape, 1)
    k = jax.lax.broadcasted_iota(jnp.int32, b.shape, 2)
    mask = ((i + j + k) % 2) == colour
    return jnp.where(mask, gs, x)
