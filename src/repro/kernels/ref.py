"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.operators import Stencil


def stencil_spmv_ref(xp: jax.Array, *, stencil: Stencil) -> jax.Array:
    return stencil.matvec_padded(xp)


def stencil_spmv_dot_ref(xp: jax.Array, *, stencil: Stencil):
    y = stencil.matvec_padded(xp)
    x = xp[1:-1, 1:-1, 1:-1]
    acc_dtype = jnp.float32 if xp.dtype == jnp.bfloat16 else xp.dtype
    return y, jnp.sum(y.astype(acc_dtype) * x.astype(acc_dtype))


def stencil_spmv_dots_ref(xp: jax.Array, *, stencil: Stencil):
    """SpMV + BOTH merged-CG partials: ``(A x, (A x)·x, x·x)``."""
    y = stencil.matvec_padded(xp)
    x = xp[1:-1, 1:-1, 1:-1]
    acc_dtype = jnp.float32 if xp.dtype == jnp.bfloat16 else xp.dtype
    ya = y.astype(acc_dtype)
    xa = x.astype(acc_dtype)
    return y, jnp.sum(ya * xa), jnp.sum(xa * xa)


def fused_cg_body_ref(alpha, beta, x, r, p, s, w):
    """Merged-CG vector updates: p' = r+βp, s' = w+βs, x' = x+αp', r' = r−αs'."""
    p_new = r + beta * p
    s_new = w + beta * s
    return x + alpha * p_new, r - alpha * s_new, p_new, s_new


def fused_axpby_ref(a, x, b, y, c, z):
    return a * x + b * y + c * z


def fused_axpby_dot_ref(a, x, b, y, c, z, w):
    out = a * x + b * y + c * z
    acc_dtype = jnp.float32 if x.dtype == jnp.bfloat16 else x.dtype
    return out, jnp.vdot(out.astype(acc_dtype), w.astype(acc_dtype))


def cg_fused_update_ref(beta, r, ar, p, ap):
    p_new = r + beta * p
    ap_new = ar + beta * ap
    acc_dtype = jnp.float32 if r.dtype == jnp.bfloat16 else r.dtype
    return p_new, ap_new, jnp.vdot(ap_new.astype(acc_dtype), p_new.astype(acc_dtype))


def flash_attention_ref(q, k, v, *, window: int = 0):
    """Causal softmax attention, full-matrix form (q/k/v: (B,S,H,hd))."""
    B, S, H, hd = q.shape
    logits = jnp.einsum("bshn,bthn->bhst", q, k).astype(jnp.float32)
    logits *= hd ** -0.5
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = kp <= qp
    if window:
        mask &= kp > (qp - window)
    logits = jnp.where(mask[None, None], logits, -2.3819763e38)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthn->bshn", w, v)


def cheb_fused_step_ref(zp: jax.Array, r: jax.Array, d: jax.Array, *,
                        stencil: Stencil, a: float, c: float):
    az = stencil.matvec_padded(zp)
    d_new = a * d + c * (r - az)
    return zp[1:-1, 1:-1, 1:-1] + d_new, d_new


def block_jacobi_sweep_ref(zp: jax.Array, r: jax.Array, *, stencil: Stencil,
                           omega: float = 1.0):
    az = stencil.matvec_padded(zp)
    return zp[1:-1, 1:-1, 1:-1] + omega * (r - az) / stencil.diag


def rb_gs_half_sweep_ref(xp: jax.Array, b: jax.Array, *, stencil: Stencil, colour: int):
    x = xp[1:-1, 1:-1, 1:-1]
    off = stencil.offdiag_apply_padded(xp)
    gs = (b - off) / stencil.diag
    i = jax.lax.broadcasted_iota(jnp.int32, b.shape, 0)
    j = jax.lax.broadcasted_iota(jnp.int32, b.shape, 1)
    k = jax.lax.broadcasted_iota(jnp.int32, b.shape, 2)
    mask = ((i + j + k) % 2) == colour
    return jnp.where(mask, gs, x)
