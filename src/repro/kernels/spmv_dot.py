"""Pallas TPU kernel: stencil SpMV + BOTH merged-CG dot partials, one pass.

The merged-reduction CG iteration (``core.solvers.cg_merged``) needs exactly
two scalars per iteration — ``γ = r·r`` and ``δ = (A r)·r`` — and the one
SpMV that produces ``w = A r``.  Streaming the slab once and accumulating
both partials alongside the stencil apply turns the classic
SpMV + dot + dot sequence (three HBM sweeps, two kernel-switch barriers)
into a single VMEM pass: the memory-side analogue of stacking the two
``MPI_Allreduce``s into one.

Extends ``kernels/stencil_spmv.py``'s ``fuse_dot`` (which emits only
``(A x)·x``) with the second accumulator; same overlapping-window BlockSpec,
same sequential-grid accumulation (TPU grid steps run in order, so the
revisited (1, 2) accumulator block is well-defined).  Oracle:
``kernels/ref.py::stencil_spmv_dots_ref``.

``stencil_spmv_dots3`` (PR 10) is the same pass with a second (unpadded)
streamed operand ``r`` and a (1, 3) accumulator — the reduction triple the
preconditioned/pipelined variants need: with ``x = u`` it yields merged
PCG's ``(A u, (A u)·u, r·u, r·r)``; with ``x = w`` pipelined CG reads the
``r·w``/``r·r`` slots and ignores the first.  Oracle:
``kernels/ref.py::stencil_spmv_dots3_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.operators import Stencil
from repro.kernels.stencil_spmv import _pick_bz, _window_spec, apply_stencil_slab


def _kernel(stencil: Stencil, nx: int, ny: int, bz: int):
    def body(xin, out, acc):
        # xin: (nx+2, ny+2, bz+2) overlapping window; out: (nx, ny, bz);
        # acc: (1, 2) = [Σ y·x, Σ x·x] partials, revisited every grid step
        x_slab = xin[...]
        centre = x_slab[1:-1, 1:-1, 1:-1]
        y = apply_stencil_slab(stencil, x_slab, nx, ny, bz)
        out[...] = y
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            acc[...] = jnp.zeros((1, 2), acc.dtype)

        acc[0, 0] += jnp.sum(y * centre).astype(acc.dtype)
        acc[0, 1] += jnp.sum(centre * centre).astype(acc.dtype)

    return body


@functools.partial(jax.jit, static_argnames=("stencil", "bz", "interpret"))
def stencil_spmv_dots(
    xp: jax.Array,
    *,
    stencil: Stencil,
    bz: int = 8,
    interpret: bool = True,
):
    """``y = A·x``, ``y·x`` and ``x·x`` from the halo-padded ``xp``.

    ``xp``: (nx+2, ny+2, nz+2).  Returns ``(y, y·x, x·x)`` — for merged CG,
    with ``x = r``: ``w = A r``, ``δ`` and ``γ`` in one HBM pass.
    """
    nx, ny, nz = xp.shape[0] - 2, xp.shape[1] - 2, xp.shape[2] - 2
    bz = _pick_bz(nz, bz)
    acc_dtype = jnp.float32 if xp.dtype == jnp.bfloat16 else xp.dtype

    y, acc = pl.pallas_call(
        _kernel(stencil, nx, ny, bz),
        grid=(nz // bz,),
        in_specs=[_window_spec(nx, ny, bz)],
        out_specs=[
            pl.BlockSpec((nx, ny, bz), lambda i: (0, 0, i)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nx, ny, nz), xp.dtype),
            jax.ShapeDtypeStruct((1, 2), acc_dtype),
        ],
        interpret=interpret,
    )(xp)
    return y, acc[0, 0], acc[0, 1]


def _kernel3(stencil: Stencil, nx: int, ny: int, bz: int):
    def body(xin, rin, out, acc):
        # xin: (nx+2, ny+2, bz+2) overlapping window; rin/out: (nx, ny, bz);
        # acc: (1, 3) = [Σ y·x, Σ r·x, Σ r·r] partials, revisited per step
        x_slab = xin[...]
        centre = x_slab[1:-1, 1:-1, 1:-1]
        r_slab = rin[...]
        y = apply_stencil_slab(stencil, x_slab, nx, ny, bz)
        out[...] = y
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            acc[...] = jnp.zeros((1, 3), acc.dtype)

        acc[0, 0] += jnp.sum(y * centre).astype(acc.dtype)
        acc[0, 1] += jnp.sum(r_slab * centre).astype(acc.dtype)
        acc[0, 2] += jnp.sum(r_slab * r_slab).astype(acc.dtype)

    return body


@functools.partial(jax.jit, static_argnames=("stencil", "bz", "interpret"))
def stencil_spmv_dots3(
    xp: jax.Array,
    r: jax.Array,
    *,
    stencil: Stencil,
    bz: int = 8,
    interpret: bool = True,
):
    """``y = A·x`` plus the THREE partials ``(y·x, r·x, r·r)``, one pass.

    ``xp``: (nx+2, ny+2, nz+2) halo-padded SpMV operand; ``r``: (nx, ny, nz)
    streamed alongside.  For merged PCG with ``x = u = M⁻¹r`` this is
    ``(w, δ, γ, ‖r‖²)``; pipelined CG calls it with ``x = w`` and reads the
    ``r·w``/``r·r`` slots.
    """
    nx, ny, nz = xp.shape[0] - 2, xp.shape[1] - 2, xp.shape[2] - 2
    bz = _pick_bz(nz, bz)
    acc_dtype = jnp.float32 if xp.dtype == jnp.bfloat16 else xp.dtype

    y, acc = pl.pallas_call(
        _kernel3(stencil, nx, ny, bz),
        grid=(nz // bz,),
        in_specs=[
            _window_spec(nx, ny, bz),
            pl.BlockSpec((nx, ny, bz), lambda i: (0, 0, i)),
        ],
        out_specs=[
            pl.BlockSpec((nx, ny, bz), lambda i: (0, 0, i)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nx, ny, nz), xp.dtype),
            jax.ShapeDtypeStruct((1, 3), acc_dtype),
        ],
        interpret=interpret,
    )(xp, r)
    return y, acc[0, 0], acc[0, 1], acc[0, 2]
