"""Backend resolution: options + device topology -> how the solve executes.

One place owns the decision every driver used to make inline:

  * ``local``     — a single device; ``LocalOp`` with zero-padded halos.
  * ``shard_map`` — a device mesh; ``DistributedOp`` with ppermute halos and
                    psum reductions inside one shard_mapped program.

Resolution rules (documented in docs/API.md):

  1. An explicit ``mesh`` argument always wins; ``options.dims_map`` then
     overrides the default grid-dim -> mesh-axis mapping.
  2. ``layout="local"`` forces the single-device path.
  3. ``layout="auto"`` picks local on one device, else the paper-faithful
     1-D z decomposition over all devices.
  4. ``layout="1d" | "2d" | "3d"`` build the corresponding mesh over all
     devices (1-D ``cells`` / data×model / pod×data×model).

The kernel choice is orthogonal: ``options.pallas`` swaps the local stencil
SpMV for the Pallas kernel in either world (``options.matvec_padded`` wins
over both).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
from jax.sharding import Mesh, NamedSharding

from repro.api.options import SolverOptions
from repro.core.compat import make_mesh
from repro.core.distributed import GridLayout, make_layout
from repro.core.operators import Stencil
from repro.launch.mesh import make_mesh_for_devices, make_solver_mesh


@dataclasses.dataclass(frozen=True)
class Backend:
    """Resolved execution target for a solve."""

    kind: str                     # "local" | "shard_map"
    mesh: Mesh | None = None
    layout: GridLayout | None = None

    @property
    def n_devices(self) -> int:
        return 1 if self.mesh is None else self.mesh.devices.size

    def sharding(self) -> NamedSharding | None:
        if self.kind == "local":
            return None
        return NamedSharding(self.mesh, self.layout.spec())

    def describe(self) -> str:
        if self.kind == "local":
            return "local(1 device)"
        axes = ",".join(f"{a}={self.mesh.shape[a]}"
                        for a in self.mesh.axis_names)
        return f"shard_map({axes})"


def _mesh_3d(n: int) -> Mesh:
    """pod×data×model mesh over ``n`` devices (beyond-paper 3-D blocks)."""
    if n < 8:
        raise ValueError(f"3d layout needs >= 8 devices, have {n}")
    for model in (16, 8, 4, 2):
        if n % model == 0 and (n // model) % 2 == 0:
            return make_mesh((2, n // model // 2, model),
                             ("pod", "data", "model"))
    raise ValueError(f"cannot factor {n} devices into pod*data*model")


def resolve_backend(options: SolverOptions, *, mesh: Mesh | None = None,
                    n_devices: int | None = None) -> Backend:
    """Apply the resolution rules above.  ``n_devices`` is a test hook."""
    if mesh is not None:
        return Backend(kind="shard_map", mesh=mesh,
                       layout=make_layout(mesh, options.dims_map))
    n = n_devices if n_devices is not None else len(jax.devices())
    layout = options.layout
    if layout == "local" or (layout == "auto" and n == 1):
        return Backend(kind="local")
    if layout in ("auto", "1d"):
        mesh = make_solver_mesh(n)
    elif layout == "2d":
        mesh = make_mesh_for_devices(n)
    else:  # "3d"
        mesh = _mesh_3d(n)
    return Backend(kind="shard_map", mesh=mesh,
                   layout=make_layout(mesh, options.dims_map))


def resolve_matvec(stencil: Stencil,
                   options: SolverOptions) -> Callable | None:
    """The padded-operand SpMV implementing ``options`` (None = jnp oracle)."""
    if options.matvec_padded is not None:
        return options.matvec_padded
    if options.pallas:
        from repro.kernels import ops
        return ops.make_matvec_padded(stencil)
    return None


def resolve_precond(options: SolverOptions):
    """Build the ``repro.precond.Preconditioner`` ``options`` asks for.

    ``None`` for ``precond="none"``.  ``options.pallas`` flows into the
    preconditioners that have fused Pallas kernels (``PALLAS_PRECONDS``)
    unless ``precond_params`` pins ``use_pallas`` explicitly — the same
    one-flag rule as the stencil SpMV.
    """
    if options.precond in (None, "none"):
        return None
    from repro.precond import PALLAS_PRECONDS, make_precond
    params = dict(options.precond_params or {})
    if options.pallas and options.precond in PALLAS_PRECONDS:
        params.setdefault("use_pallas", True)
    return make_precond(options.precond, **params)


def resolve_halo_mode(options: SolverOptions) -> str:
    """Resolve ``halo_mode="auto"`` for the distributed operator.

    ``"overlap"`` (interior/shell split, ppermutes hidden behind interior
    compute) is the default for the built-in stencil formulations — it is
    bit-for-bit identical to ``"concat"`` and strictly better on the
    schedule.  A user-supplied ``matvec_padded`` or the Pallas kernel may be
    tile-shape-specialised, so the slab-shaped shell applies fall back to
    the monolithic ``"concat"`` exchange there.
    """
    if options.halo_mode != "auto":
        return options.halo_mode
    if options.matvec_padded is not None or options.pallas:
        return "concat"
    return "overlap"
