"""Solver registry: methods + the metadata the paper reasons about.

Subsumes the bare ``SOLVERS`` / ``VARIANT_OF`` dicts in ``core.solvers``:
each entry carries the per-iteration communication structure (reductions,
how each one hides, SpMV count) that drives the scaling model and the
barrier-structure reporting, plus solver-selection facts (SPD requirement,
stationary vs Krylov, which classical method a variant descends from).

New methods register once here and every driver — launch, benchmarks,
examples, the dry-run — picks them up.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core import solvers as _solvers
from repro.core.methods import METHODS, MethodDef

#: how a reduction's latency is hidden (the scaling model's terms):
#: "none" = blocking barrier, "vec" = overlapped with one vector update,
#: "spmv" = overlapped with the SpMV, "pipe" = a pipelined stacked
#: reduction overlapped with the NEXT SpMV (+ preconditioner apply) —
#: the Ghysels–Vanroose window, priced by scaling_model's t_reduce term.
HideKind = str

#: accepted ``SolverSpec.reduce_hide`` values — the variant's reduction
#: *scheduling strategy* (orthogonal to the per-reduction hide kinds):
#: "none"      = one psum per dot product (the classics + the paper's
#:               nonblocking variants),
#: "merged"    = every dot of the iteration stacked into ONE psum
#:               (Chronopoulos–Gear CG, single-reduction BiCGStab),
#: "pipelined" = the ONE stacked psum additionally overlapped with the
#:               body's SpMV (Ghysels–Vanroose).
REDUCE_HIDES = ("none", "merged", "pipelined")

#: how a SpMV's halo exchange hides (one entry per SpMV per iteration):
#: "interior" = the ppermutes ride behind the interior stencil apply
#: (halo_mode="overlap"), "none" = the consumer needs the halos immediately
#: (the Gauss-Seidel sweeps: the very first plane/colour reads them).
HaloHideKind = str


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """A solver plus the metadata the drivers and models need."""

    name: str
    fn: Callable                      # (A, b, x0, *, tol, maxiter, dot, norm_ref)
    reduction_hides: tuple[HideKind, ...]
    spmvs_per_iter: int
    halo_hides: tuple[HaloHideKind, ...] = ()   # defaults to all-"interior"
    variant_of: str | None = None     # classical baseline this method refines
    spd_required: bool = False
    stationary: bool = False          # Jacobi/GS family (vs Krylov)
    accepts_precond: bool = False     # fn takes M= (repro.precond apply)
    precond_applies_per_iter: int = 0  # M^{-1} applications per iteration
    reduce_hide: str = "none"         # reduction scheduling (REDUCE_HIDES)
    fused_kernels: tuple[str, ...] = ()  # Pallas fused-body capability
    #: COMPILED all-reduces per iteration body — what `python -m
    #: repro.analysis` asserts on the HLO of every mesh shape.  Defaults to
    #: ``reductions_per_iter``; set explicitly where the implementation fuses
    #: logical reductions into one collective (pcg rides r·z and r·r on a
    #: single psum pair, so 3 logical reductions compile to 2 all-reduces).
    allreduces_per_iter: int | None = None
    #: halo exchanges (``pad_exchange`` calls) per iteration body; each one
    #: compiles to ``2 × n_split_dims`` collective-permutes.  Defaults to
    #: ``spmvs_per_iter``; the Gauss-Seidel sweeps exchange per plane/colour
    #: half-sweep plus once for the residual matvec, so they set it higher.
    halo_exchanges_per_iter: int | None = None
    description: str = ""
    #: the single-source algorithm definition (repro.core.methods); attached
    #: and cross-validated by register_solver — every metadata field that IS
    #: derivable from the definition must agree with it.
    method_def: MethodDef | None = None

    def __post_init__(self):
        if not self.halo_hides:
            object.__setattr__(
                self, "halo_hides", ("interior",) * self.spmvs_per_iter)
        if len(self.halo_hides) != self.spmvs_per_iter:
            raise ValueError(
                f"{self.name!r}: halo_hides needs one entry per SpMV "
                f"({len(self.halo_hides)} != {self.spmvs_per_iter})")
        if self.precond_applies_per_iter and not self.accepts_precond:
            raise ValueError(
                f"{self.name!r}: precond_applies_per_iter without "
                f"accepts_precond")
        if self.reduce_hide not in REDUCE_HIDES:
            raise ValueError(
                f"{self.name!r}: unknown reduce_hide {self.reduce_hide!r}; "
                f"options: {REDUCE_HIDES}")
        if self.reduce_hide != "none" and len(self.reduction_hides) != 1:
            raise ValueError(
                f"{self.name!r}: reduce_hide={self.reduce_hide!r} means ONE "
                f"stacked reduction per iteration, but reduction_hides has "
                f"{len(self.reduction_hides)} entries")
        if self.reduce_hide == "pipelined" and self.reduction_hides != ("pipe",):
            raise ValueError(
                f"{self.name!r}: a pipelined variant's single reduction "
                f"hides behind the next SpMV — reduction_hides must be "
                f"('pipe',)")
        if self.allreduces_per_iter is None:
            object.__setattr__(
                self, "allreduces_per_iter", self.reductions_per_iter)
        if self.halo_exchanges_per_iter is None:
            object.__setattr__(
                self, "halo_exchanges_per_iter", self.spmvs_per_iter)
        if self.reduce_hide != "none" and self.allreduces_per_iter != 1:
            raise ValueError(
                f"{self.name!r}: reduce_hide={self.reduce_hide!r} claims ONE "
                f"stacked reduction but allreduces_per_iter="
                f"{self.allreduces_per_iter}")
        if self.allreduces_per_iter > self.reductions_per_iter:
            raise ValueError(
                f"{self.name!r}: allreduces_per_iter "
                f"({self.allreduces_per_iter}) exceeds the declared logical "
                f"reductions ({self.reductions_per_iter}) — fusing can only "
                f"reduce the collective count")
        if self.halo_exchanges_per_iter < self.spmvs_per_iter:
            raise ValueError(
                f"{self.name!r}: halo_exchanges_per_iter "
                f"({self.halo_exchanges_per_iter}) below spmvs_per_iter "
                f"({self.spmvs_per_iter}) — every SpMV needs its halos")

    @property
    def reductions_per_iter(self) -> int:
        return len(self.reduction_hides)

    @property
    def blocking_reductions(self) -> int:
        """Reductions with no overlap window (the paper's hard barriers)."""
        return sum(1 for h in self.reduction_hides if h == "none")

    @property
    def hidden_halos(self) -> int:
        """SpMVs whose halo exchange overlaps interior compute."""
        return sum(1 for h in self.halo_hides if h == "interior")

    @property
    def has_fused_body(self) -> bool:
        """Whether the method declares a fused Pallas iteration body — the
        capability the facade's ``pallas=True`` routing queries."""
        return bool(self.fused_kernels)


REGISTRY: dict[str, SolverSpec] = {}


class RegistryConsistencyError(RuntimeError):
    """The registry drifted from what ``core.solvers``/``core.methods``
    export.  The message renders every mismatched field as an
    expected-vs-actual table (method, field, registry value, derived value)
    so a drifted registration reads as a diff, not a bare assertion."""


@dataclasses.dataclass(frozen=True)
class FieldDiff:
    """One registry-vs-derived mismatch (a row of the consistency report)."""

    method: str
    field: str
    registry_value: object
    derived_value: object

    def __str__(self) -> str:
        return (f"{self.method}.{self.field}: registry declares "
                f"{self.registry_value!r}, derived says {self.derived_value!r}")


def format_field_diffs(diffs: list[FieldDiff]) -> str:
    """Render mismatches as an aligned expected-vs-actual table."""
    rows = [("method", "field", "registry", "derived")]
    rows += [(d.method, d.field, repr(d.registry_value), repr(d.derived_value))
             for d in diffs]
    widths = [max(len(r[c]) for r in rows) for c in range(4)]
    lines = ["  ".join(cell.ljust(w) for cell, w in zip(row, widths)).rstrip()
             for row in rows]
    lines.insert(1, "  ".join("-" * w for w in widths))
    return "\n".join(lines)


def method_field_diff(spec: SolverSpec, mdef: MethodDef) -> list[FieldDiff]:
    """Registry fields that are derivable from the MethodDef but disagree
    with it — the definition is the single source of truth.  Empty list ==
    consistent.  Exported for ``repro.analysis`` (the audit re-runs this
    diff across the whole registry instead of trusting the import-time
    check ran under the same code)."""
    derived = {
        "stationary": mdef.stationary,
        "accepts_precond": mdef.accepts_precond,
        "reduce_hide": mdef.reduce_hide,
        "variant_of": mdef.variant_of,
        "fused_kernels": mdef.fused_kernels,
    }
    return [
        FieldDiff(spec.name, field, getattr(spec, field), want)
        for field, want in derived.items()
        if getattr(spec, field) != want
    ]


def fused_capability_diff(spec: SolverSpec, mdef: MethodDef) -> list[FieldDiff]:
    """The ``fused_kernels`` capability must be *executable*, not just
    declared: a non-empty tuple requires the MethodDef to carry a fused
    body (``fused_step``/``fused_init``), and every named kernel must be a
    real ``PallasOp`` hook.  Before this check a capability typo silently
    routed ``pallas=True`` to the unfused path (``has_fused_body`` was
    true, the hook lookup failed only at trace time — or never, if the
    name drifted from the hook it meant)."""
    if not spec.fused_kernels:
        return []
    from repro.kernels.pallas_op import PallasOp

    diffs = []
    if mdef.fused_step is None or mdef.fused_init is None:
        diffs.append(FieldDiff(
            spec.name, "fused_kernels", spec.fused_kernels,
            "() — MethodDef has no fused body (fused_step/fused_init)"))
    missing = tuple(k for k in spec.fused_kernels
                    if not callable(getattr(PallasOp, k, None)))
    if missing:
        diffs.append(FieldDiff(
            spec.name, "fused_kernels", spec.fused_kernels,
            f"PallasOp hooks — {missing} not found on PallasOp"))
    return diffs


def _validate_against_method(spec: SolverSpec, mdef: MethodDef) -> None:
    diffs = method_field_diff(spec, mdef) + fused_capability_diff(spec, mdef)
    if diffs:
        raise RegistryConsistencyError(
            f"{spec.name!r} drifted from its MethodDef:\n"
            + format_field_diffs(diffs))


def register_solver(spec: SolverSpec) -> SolverSpec:
    if spec.name in REGISTRY:
        raise ValueError(f"solver {spec.name!r} already registered")
    if spec.variant_of is not None and spec.variant_of not in REGISTRY:
        raise ValueError(
            f"{spec.name!r}: unknown baseline {spec.variant_of!r} "
            f"(register the classical method first)")
    if spec.name not in METHODS:
        raise RegistryConsistencyError(
            f"{spec.name!r}: no MethodDef in repro.core.methods — define the "
            f"algorithm first (docs/API.md §'Authoring a new method')")
    mdef = METHODS[spec.name]
    _validate_against_method(spec, mdef)
    object.__setattr__(spec, "method_def", mdef)
    REGISTRY[spec.name] = spec
    return spec


def get_solver(name: str) -> SolverSpec:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown method {name!r}; options: {sorted(REGISTRY)}") from None


def solver_names() -> list[str]:
    return sorted(REGISTRY)


def variant_pairs() -> list[tuple[str, str]]:
    """(classical, variant) pairs — the paper's side-by-side comparisons."""
    return sorted((s.variant_of, s.name) for s in REGISTRY.values()
                  if s.variant_of is not None)


def fallback_chain(name: str) -> list[str]:
    """``[name, its variant_of, ...]`` down to the classical method — the
    robustness ladder ``on_breakdown="fallback"`` walks (repro.resilience):
    each rung trades back a communication-hiding rearrangement for the
    numerically plainer recurrence it was derived from.  Cycle-safe (a
    malformed registry cannot loop) and always at least ``[name]``."""
    chain, seen = [name], {name}
    cur = get_solver(name)
    while cur.variant_of is not None and cur.variant_of not in seen:
        chain.append(cur.variant_of)
        seen.add(cur.variant_of)
        cur = get_solver(cur.variant_of)
    return chain


# --- the seven methods of the paper ------------------------------------------
# Reduction structure per §3.1/Fig. 1; SpMV counts per the touched-elements
# model.  Stationary methods report one residual-norm reduction per sweep.
# halo_hides: Krylov/Jacobi SpMVs split interior/shell (halo_mode="overlap"),
# the GS sweeps consume their halos at the first plane/colour -> "none".

register_solver(SolverSpec(
    name="jacobi", fn=_solvers.jacobi,
    reduction_hides=("none",), spmvs_per_iter=1, stationary=True,
    description="x += D^-1 r; 1 SpMV + 1 blocking residual reduction"))

register_solver(SolverSpec(
    name="gauss_seidel_rb", fn=_solvers.sym_gauss_seidel_rb,
    reduction_hides=("none",), spmvs_per_iter=2, stationary=True,
    halo_hides=("none", "none"),
    halo_exchanges_per_iter=5,   # 4 colour half-sweeps + the residual matvec
    description="red-black coloured symmetric Gauss-Seidel (§3.4)"))

register_solver(SolverSpec(
    name="gauss_seidel", fn=_solvers.sym_gauss_seidel_relaxed,
    reduction_hides=("none",), spmvs_per_iter=2, stationary=True,
    halo_hides=("none", "none"),
    halo_exchanges_per_iter=3,   # fwd + bwd plane sweeps + residual matvec
    variant_of="gauss_seidel_rb",
    description="relaxed tasked symmetric GS (§3.4 Code 4, TPU adaptation)"))

register_solver(SolverSpec(
    name="cg", fn=_solvers.cg,
    reduction_hides=("none", "vec"), spmvs_per_iter=1, spd_required=True,
    description="classical conjugate gradient (2 blocking reductions)"))

register_solver(SolverSpec(
    name="cg_nb", fn=_solvers.cg_nb,
    reduction_hides=("spmv", "vec"), spmvs_per_iter=1, spd_required=True,
    variant_of="cg",
    description="nonblocking CG (Alg. 1): both reductions off the critical path"))

register_solver(SolverSpec(
    name="pcg", fn=_solvers.pcg,
    reduction_hides=("none", "none", "vec"), spmvs_per_iter=1,
    spd_required=True, variant_of="cg",
    allreduces_per_iter=2,       # the (r·z, r·r) pair rides ONE psum (dot2)
    accepts_precond=True, precond_applies_per_iter=1,
    description="preconditioned CG (repro.precond): p·Ap and r·z block, "
                "r·r feeds only the check; +0 reductions from the "
                "built-in preconditioners"))

register_solver(SolverSpec(
    name="bicgstab", fn=_solvers.bicgstab,
    reduction_hides=("none", "none", "vec"), spmvs_per_iter=2,
    description="classical BiCGStab (3 blocking reductions)"))

register_solver(SolverSpec(
    name="bicgstab_b1", fn=_solvers.bicgstab_b1,
    reduction_hides=("none", "vec", "vec"), spmvs_per_iter=2,
    variant_of="bicgstab",
    description="BiCGStab one-blocking (Alg. 2) with restart"))


register_solver(SolverSpec(
    name="pbicgstab", fn=_solvers.pbicgstab,
    reduction_hides=("none", "none", "vec"), spmvs_per_iter=2,
    variant_of="bicgstab",
    accepts_precond=True, precond_applies_per_iter=2,
    description="right-preconditioned BiCGStab (true-residual stopping)"))


# --- PR 4: reduction-hiding variants (merged + pipelined) --------------------
# One stacked psum per iteration; "merged" pays it as a single blocking
# barrier, "pipelined" hides it behind the body's SpMV ("pipe" hide kind).
# tests/test_hlo_analysis.py asserts the one-all-reduce claim on compiled
# shard_map iteration bodies.

register_solver(SolverSpec(
    name="cg_merged", fn=_solvers.cg_merged,
    reduction_hides=("none",), spmvs_per_iter=1, spd_required=True,
    variant_of="cg", reduce_hide="merged",
    fused_kernels=("cg_body", "spmv_dots"),
    description="Chronopoulos–Gear CG: all dots in ONE stacked psum "
                "(Saad recurrence for p·Ap)"))

register_solver(SolverSpec(
    name="cg_pipe", fn=_solvers.cg_pipe,
    reduction_hides=("pipe",), spmvs_per_iter=1, spd_required=True,
    variant_of="cg", reduce_hide="pipelined",
    fused_kernels=("spmv_dots3", "pipe_body"),
    description="Ghysels–Vanroose pipelined CG: the ONE stacked psum "
                "overlaps the SpMV"))

register_solver(SolverSpec(
    name="pcg_merged", fn=_solvers.pcg_merged,
    reduction_hides=("none",), spmvs_per_iter=1, spd_required=True,
    variant_of="pcg", reduce_hide="merged",
    accepts_precond=True, precond_applies_per_iter=1,
    fused_kernels=("pcg_body", "spmv_dots3"),
    description="merged-reduction PCG (Chronopoulos–Gear with M)"))

register_solver(SolverSpec(
    name="pcg_pipe", fn=_solvers.pcg_pipe,
    reduction_hides=("pipe",), spmvs_per_iter=1, spd_required=True,
    variant_of="pcg", reduce_hide="pipelined",
    accepts_precond=True, precond_applies_per_iter=1,
    fused_kernels=("fused_dots", "ppipe_body"),
    description="pipelined PCG: the stacked psum overlaps M-apply + SpMV"))

register_solver(SolverSpec(
    name="bicgstab_merged", fn=_solvers.bicgstab_merged,
    reduction_hides=("none",), spmvs_per_iter=2,
    variant_of="bicgstab", reduce_hide="merged",
    fused_kernels=("bicgstab_spmv_dots", "bicgstab_update1",
                   "bicgstab_spmv_update"),
    description="single-reduction BiCGStab: nine dots, ONE stacked psum "
                "(Cools–Vanroose recurrences)"))

register_solver(SolverSpec(
    name="pbicgstab_merged", fn=_solvers.pbicgstab_merged,
    reduction_hides=("none",), spmvs_per_iter=2,
    variant_of="pbicgstab", reduce_hide="merged",
    accepts_precond=True, precond_applies_per_iter=2,
    fused_kernels=("bicgstab_spmv_dots", "bicgstab_update1",
                   "bicgstab_spmv_update"),
    description="right-preconditioned single-reduction BiCGStab "
                "(merged core on A∘M⁻¹, true-residual stopping)"))


def fused_solver_names() -> list[str]:
    """Methods whose MethodDef declares a fused Pallas iteration body — the
    capability query behind the facade's ``pallas=True`` routing."""
    return sorted(n for n, s in REGISTRY.items() if s.has_fused_body)


def check_consistent_with_core(registry=None, solvers=None,
                               variant_of=None) -> None:
    """The registry must cover exactly what core.solvers exports.

    Raises :class:`RegistryConsistencyError` — deliberately NOT ``assert``:
    this guard runs at import time and must survive ``python -O`` / ``-OO``,
    where asserts are compiled away (the bug this replaces: a drifted
    registry imported cleanly under optimised bytecode).  The keyword
    arguments exist so tests can feed deliberately inconsistent tables;
    production callers use the defaults.
    """
    registry = REGISTRY if registry is None else registry
    solvers = _solvers.SOLVERS if solvers is None else solvers
    variant_of = _solvers.VARIANT_OF if variant_of is None else variant_of
    if set(registry) != set(solvers):
        raise RegistryConsistencyError(
            f"method sets differ: registry-only="
            f"{sorted(set(registry) - set(solvers))}, "
            f"core-only={sorted(set(solvers) - set(registry))}")
    for name, spec in registry.items():
        if spec.fn is not solvers[name]:
            raise RegistryConsistencyError(
                f"{name!r}: registered fn is not core.solvers.SOLVERS[{name!r}]")
    for variant, base in variant_of.items():
        if variant not in registry or registry[variant].variant_of != base:
            raise RegistryConsistencyError(
                f"{variant!r}: registry variant_of="
                f"{registry[variant].variant_of if variant in registry else '<missing>'!r}"
                f" but core says {base!r}")


check_consistent_with_core()
