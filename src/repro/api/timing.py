"""Shared wall-clock helper for every driver and benchmark.

JAX dispatch is asynchronous and the first call compiles: ``time.time()``
around a bare ``jit`` call measures compile+dispatch, not execution.  This
helper does it right once — warm-up calls first (compile outside the timed
region), ``block_until_ready`` inside it — and reports the box-whisker stats
the paper uses (median/quartiles of repeated runs).
"""

from __future__ import annotations

import time
from typing import Any, Callable

import jax
import numpy as np


def timed(fn: Callable, *args, repeats: int = 10,
          warmup: int = 1) -> dict[str, float]:
    """Median/quartile seconds of ``repeats`` fully-blocked calls."""
    _, stats = timed_result(fn, *args, repeats=repeats, warmup=warmup)
    return stats


def timed_result(fn: Callable, *args, repeats: int = 10,
                 warmup: int = 1) -> tuple[Any, dict[str, float]]:
    """Like :func:`timed` but also returns the (last) result of ``fn``."""
    out = None
    for _ in range(warmup):
        out = jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts = np.array(ts)
    return out, {
        "median": float(np.median(ts)),
        "q1": float(np.quantile(ts, 0.25)),
        "q3": float(np.quantile(ts, 0.75)),
        "min": float(ts.min()),
        "mean": float(ts.mean()),
    }
