"""The solver facade: one entry point for every execution world.

``SolverSession`` binds (problem, method, options) to a resolved backend and
compiles the solve once; ``solve()`` / ``solve_batched()`` are the one-shot
conveniences.  The paper's "write the algorithm once, swap the parallelisation
underneath" now holds at the user surface too:

    from repro.api import solve, SolverOptions
    res = solve(method="cg_nb", grid=(64, 64, 64), stencil="27pt",
                options=SolverOptions(tol=1e-6, maxiter=600))

runs ``LocalOp`` on one device, the paper-faithful 1-D shard_map decomposition
on many, and the Pallas stencil kernel when ``options.pallas`` is set — with
identical ``SolveResult`` semantics everywhere.

``solve_batched`` is the serving path: many right-hand sides solved in ONE
compiled call.  Locally the solver is vmapped; on a mesh the vmap happens
*inside* shard_map, so the batch rides the same halo exchanges and each
reduction stays one ``psum`` per iteration for the whole batch.  JAX's
batching rule for ``while_loop`` masks finished lanes, so each RHS converges
exactly as it would alone (same iteration count, same iterates).
"""

from __future__ import annotations

import time
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.api.backend import (Backend, resolve_backend, resolve_halo_mode,
                               resolve_matvec, resolve_precond)
from repro.api.options import SolverOptions
from repro.api.registry import SolverSpec, fallback_chain, get_solver
from repro.api.timing import timed_result
from repro.core.compat import shard_map
from repro.core.distributed import DistributedOp, solve_shardmap, solve_step_shardmap
from repro.core.methods import (STATUS_BREAKDOWN, STATUS_DIVERGED,
                                STATUS_STAGNATED, SolveBreakdown, status_name)
from repro.core.problems import HPCGProblem, make_problem
from repro.core.solvers import LocalOp, SolveResult
from repro.obs import trace as obs

#: guarded exit statuses the recovery policies act on
_RECOVERABLE = (STATUS_BREAKDOWN, STATUS_DIVERGED, STATUS_STAGNATED)


class SolverSession:
    """A problem + method + options bound to a resolved backend.

    Reuse a session to amortise compilation across repeated solves (the
    serving loop); use the module-level :func:`solve` for one-offs.
    """

    def __init__(self, problem: HPCGProblem | None = None, *,
                 method: str = "cg_nb",
                 grid: tuple[int, int, int] | None = None,
                 stencil: str = "27pt",
                 options: SolverOptions | None = None,
                 mesh: Mesh | None = None,
                 backend: Backend | None = None):
        self.options = options or SolverOptions()
        if problem is None:
            if grid is None:
                raise ValueError("need either a problem or a grid")
            if self.options.f64 and not jax.config.jax_enable_x64:
                raise ValueError(
                    "SolverOptions.f64=True but jax x64 is disabled.  The "
                    "facade no longer flips the process-global "
                    "jax_enable_x64 flag implicitly: call "
                    "repro.core.problems.enable_f64() at process start "
                    "(x64 is not a per-computation switch in JAX) or pass "
                    "SolverOptions(f64=False).")
            dtype = jnp.float64 if self.options.f64 else jnp.float32
            problem = make_problem(tuple(grid), stencil, dtype=dtype)
        else:
            want = jnp.float64 if self.options.f64 else jnp.float32
            have = jnp.dtype(problem.dtype)
            if have != jnp.dtype(want):
                raise ValueError(
                    f"SolverOptions.f64={self.options.f64} conflicts with the "
                    f"pre-built problem's dtype {have.name}; pass "
                    f"f64={have == jnp.dtype(jnp.float64)} (the problem's "
                    f"dtype is authoritative) or rebuild the problem.")
        self.problem = problem
        if self.options.pallas is None:
            # pallas="auto": the kernels/autotune cache (or its documented
            # default table) decides the Pallas-vs-XLA routing for this
            # (stencil, grid, dtype, device_kind); downstream code only
            # ever sees a concrete bool.
            from repro.kernels import autotune
            dec = autotune.resolve(problem.stencil.name, problem.shape,
                                   problem.dtype)
            self.options = self.options.replace(pallas=dec.use_pallas)
        # solve-lifecycle spans (repro.obs): resolve -> precond.setup ->
        # compile (in _executable) -> execute (in solve/solve_batched)
        with obs.span("resolve", method=method, layout=self.options.layout,
                      grid=list(problem.shape)):
            self.spec: SolverSpec = get_solver(method)
            self.backend: Backend = backend or resolve_backend(self.options,
                                                               mesh=mesh)
            self._matvec = resolve_matvec(problem.stencil, self.options)
            self.halo_mode = resolve_halo_mode(self.options)
        with obs.span("precond.setup", precond=self.options.precond):
            self.precond = resolve_precond(self.options)
        if self.precond is not None and not self.spec.accepts_precond:
            from repro.api.registry import REGISTRY
            takers = sorted(n for n, s in REGISTRY.items()
                            if s.accepts_precond)
            raise ValueError(
                f"method {self.method!r} takes no preconditioner; use one "
                f"of {takers} with precond={self.options.precond!r}, or "
                f"precond='none'")
        if (self.precond is not None and self.spec.spd_required
                and not self.precond.spd_preserving):
            raise ValueError(
                f"method {self.method!r} requires an SPD-preserving "
                f"preconditioner, but {self.precond.describe()} declares "
                f"spd_preserving=False; use pbicgstab or an SPD-preserving "
                f"M (CG's short recurrence silently breaks down otherwise)")
        mdef = getattr(self.spec, "method_def", None)
        if (self.options.residual_replacement
                and not (mdef is not None and mdef.has_refresh)):
            raise ValueError(
                f"residual_replacement={self.options.residual_replacement} "
                f"but method {self.method!r} declares no refresh hook; "
                f"residual replacement targets the merged/pipelined variants "
                f"(MethodDef.refresh) — the classical recurrences already "
                f"compute the true residual")
        # kept for the fallback ladder (sessions rebuilt on the same mesh)
        self._mesh_arg = mesh if mesh is not None else getattr(
            self.backend, "mesh", None)
        self._fallbacks: list[tuple[str, "SolverSession"]] | None = None
        # AOT-compiled executables keyed by input shape: ``grid`` for the
        # single-RHS solve, ``(batch, *grid)`` for the batched one.  Each
        # entry is a ``jax.stages.Compiled`` (the ``.lower().compile()``
        # product of the same jitted builders the lazy path used), so the
        # session can report honest per-shape compile seconds and hit/miss
        # counts — the observability ``repro.serve``'s executable cache and
        # its CI gate are built on.
        self._executables: dict[tuple, Any] = {}
        self._compile_stats: dict[tuple, dict] = {}
        self._timed_fn = None         # undonated variants for timed_*
        self._timed_batched_fn = None  # (repeat calls reuse input buffers)

    # -- introspection --------------------------------------------------------
    @property
    def method(self) -> str:
        return self.spec.name

    @property
    def layout(self):
        return self.backend.layout

    def describe(self) -> str:
        pre = (f" precond={self.precond.describe()}"
               if self.precond is not None else "")
        return (f"{self.method}/{self.problem.stencil.name} "
                f"grid={self.problem.shape} on {self.backend.describe()}"
                f"{' [pallas]' if self.options.pallas else ''}{pre}")

    def _solver_kwargs(self, A) -> dict:
        """tol/maxiter/norm_ref plus the bound preconditioner apply (and
        the telemetry row bound when convergence telemetry is on — only
        passed when enabled, so a custom registry ``fn`` that predates the
        keyword keeps working)."""
        kw = self.options.solver_kwargs()
        if self.spec.accepts_precond:
            kw["M"] = None if self.precond is None else self.precond.bind(A)
        rows = self.options.telemetry_rows()
        if rows:
            kw["telemetry"] = rows
        gs = self.options.guard_spec()
        if gs is not None:
            kw["guard_spec"] = gs
        if self.options.residual_replacement:
            kw["refresh_every"] = self.options.residual_replacement
        return kw

    def _use_fused_body(self) -> bool:
        """Route ``pallas=True`` solves of any method whose ``MethodDef``
        declares a fused kernel body (the registry's ``has_fused_body``
        capability — not a hard-coded method name) to the fully fused
        iteration: e.g. merged CG's SpMV *and* its two dot partials in one
        VMEM pass, the four vector updates in another — instead of merely
        swapping the SpMV under the jnp solver.  Works on the local AND the
        shard_map backend (``PallasOp`` supplies halos/psums there).
        Single-RHS solves only: the batched path always runs the jnp body
        (with the Pallas SpMV under ``pallas=True``) — vmapping the fused
        kernels is not supported.  Preconditioned methods stay on the
        fused path too (PR 10): the bound preconditioner apply composes
        inside the fused body (its own Pallas kernels when
        ``use_pallas``), so ``pcg_merged + chebyshev`` runs end-to-end on
        the 2-HBM-pass path."""
        return (self.options.pallas and self.spec.has_fused_body
                and (self.precond is None or self.spec.accepts_precond)
                and self.options.matvec_padded is None
                and self.options.dot is None)

    # -- single-RHS path ------------------------------------------------------
    def _build_fn(self, *, donate: bool | None = None):
        opts = self.options
        donate = opts.donate if donate is None else donate
        # donating x0 lets XLA alias the x/r/p iterate chain onto the
        # caller's buffer (input_output_alias in the lowered HLO); b stays
        # un-donated — the stationary methods re-read it every iteration
        # and callers routinely keep it.
        jit_kw = dict(donate_argnums=(1,)) if donate else {}
        if self._use_fused_body():
            if self.backend.kind == "local":
                from repro.core.methods import Ops, run_method
                from repro.kernels.pallas_op import PallasOp
                A = PallasOp(LocalOp(self.problem.stencil))
                mdef = self.spec.method_def
                M = (None if self.precond is None
                     else self.precond.bind(A))

                def run_fused(b, x0):
                    ops = Ops(A, b, M=M, norm_ref=opts.norm_ref)
                    return run_method(mdef, ops, x0, tol=opts.tol,
                                      maxiter=opts.maxiter, fused=True,
                                      telemetry=opts.telemetry_rows(),
                                      guard_spec=opts.guard_spec(),
                                      refresh_every=opts.residual_replacement)

                return jax.jit(run_fused, **jit_kw)
            # fused kernels inside the shard_map body (PallasOp wraps the
            # DistributedOp for halos + the stacked partial-dot psum)
            fn, _ = solve_shardmap(
                self.problem, self.method, self.backend.mesh,
                dims_map=opts.dims_map, tol=opts.tol, maxiter=opts.maxiter,
                norm_ref=opts.norm_ref, halo_mode=self.halo_mode,
                pallas_fused=True, precond=self.precond,
                telemetry=opts.telemetry_rows(),
                guard_spec=opts.guard_spec(),
                refresh_every=opts.residual_replacement)
            return jax.jit(fn, **jit_kw)
        if self.backend.kind == "local":
            A = LocalOp(self.problem.stencil, matvec_padded=self._matvec)

            def run(b, x0):
                return self.spec.fn(A, b, x0, dot=opts.dot,
                                    **self._solver_kwargs(A))

            return jax.jit(run, **jit_kw)
        fn, _ = solve_shardmap(
            self.problem, self.method, self.backend.mesh,
            dims_map=opts.dims_map, tol=opts.tol, maxiter=opts.maxiter,
            norm_ref=opts.norm_ref, matvec_padded=self._matvec,
            halo_mode=self.halo_mode, precond=self.precond,
            telemetry=opts.telemetry_rows(),
            guard_spec=opts.guard_spec(),
            refresh_every=opts.residual_replacement)
        return jax.jit(fn, **jit_kw)

    def _place(self, x: jax.Array, *, batched: bool = False) -> jax.Array:
        sh = self.backend.sharding()
        if sh is None:
            return x
        if batched:
            sh = NamedSharding(self.backend.mesh,
                               P(None, *self.layout.dim_axes))
        return jax.device_put(x, sh)

    # -- compiled-executable cache (observability for the serving layer) ------
    def _executable(self, shape: tuple, builder, example_args: tuple):
        """Return the AOT-compiled executable for ``shape``, compiling (and
        recording honest wall-clock compile seconds) on first use."""
        ent = self._executables.get(shape)
        st = self._compile_stats.setdefault(
            (shape, self.method, self.options.precond),
            {"hits": 0, "misses": 0, "compile_s": 0.0})
        if ent is None:
            with obs.span("compile", method=self.method, shape=list(shape),
                          backend=self.backend.kind):
                t0 = time.perf_counter()
                ent = builder().lower(*example_args).compile()
                st["misses"] += 1
                st["compile_s"] += time.perf_counter() - t0
                self._executables[shape] = ent
        else:
            st["hits"] += 1
        return ent

    def cache_stats(self) -> dict[tuple, dict]:
        """Compile-cache observability: ``{(shape, method, precond):
        {"hits", "misses", "compile_s"}}``.  ``shape`` is the problem grid
        for single-RHS solves and ``(batch, *grid)`` for batched ones; a
        miss is one real XLA compile (``jit(...).lower().compile()``) and
        ``compile_s`` its measured wall-clock cost.  ``repro.serve``'s
        executable cache asserts "exactly one compile per bucket" against
        these counters."""
        return {k: dict(v) for k, v in self._compile_stats.items()}

    def _abstract(self, shape: tuple, *, batched: bool = False):
        dt = jnp.dtype(self.problem.dtype)
        sh = self.backend.sharding()
        if sh is not None and batched:
            sh = NamedSharding(self.backend.mesh,
                               P(None, *self.layout.dim_axes))
        if sh is None:
            return jax.ShapeDtypeStruct(shape, dt)
        return jax.ShapeDtypeStruct(shape, dt, sharding=sh)

    def compile_batched(self, batch: int) -> float:
        """Compile the ``batch``-RHS executable ahead of time (no solve
        executes) and return the compile seconds; a later
        :meth:`solve_batched` at this batch size is a cache hit.  This is
        the serve layer's compile-then-admit hook: a cold bucket compiles
        off the serving path and only then starts taking batches."""
        shape = (batch, *self.problem.shape)
        ab = self._abstract(shape, batched=True)
        t0 = time.perf_counter()
        self._executable(shape, self._build_batched_fn, (ab, ab))
        return time.perf_counter() - t0

    def _solve_once(self, b: jax.Array | None = None,
                    x0: jax.Array | None = None) -> SolveResult:
        """One compiled solve, no recovery policy (the :meth:`solve` body
        pre-resilience; restart/fallback attempts re-enter here)."""
        with obs.span("solve", method=self.method,
                      grid=list(self.problem.shape),
                      backend=self.backend.kind):
            b = self.problem.b() if b is None else b
            x0 = self.problem.x0() if x0 is None else x0
            fn = self._executable(
                tuple(self.problem.shape), self._build_fn,
                (self._abstract(tuple(self.problem.shape)),) * 2)
            with obs.span("execute") as sp:
                res = fn(self._place(b), self._place(x0))
                if sp is not None:
                    # only when tracing: block so the span times the solve,
                    # not the async dispatch (result semantics unchanged)
                    res = jax.block_until_ready(res)
        return res

    def solve(self, b: jax.Array | None = None,
              x0: jax.Array | None = None) -> SolveResult:
        """Solve one system, applying ``options.on_breakdown`` when the
        breakdown guards are armed and the solve exits with an abnormal
        typed status (breakdown / diverged / stagnated):

        * ``"raise"``    — raise :class:`SolveBreakdown` (result attached);
        * ``"none"``     — return the result, status untouched;
        * ``"restart"``  — re-solve from the last finite iterate (zeros if
          the iterate is poisoned), up to ``max_restarts`` attempts;
        * ``"fallback"`` — walk the robustness ladder: the same method on
          the plain XLA path first, then each ``variant_of`` ancestor down
          to the classical method.

        With guards disarmed (the default) this is exactly the compiled
        solve — no status inspection, no host sync.  Each recovery attempt
        is traced as a ``resilience.attempt`` span (repro.obs)."""
        res = self._solve_once(b, x0)
        opts = self.options
        if opts.guard_spec() is None or opts.on_breakdown == "none":
            return res
        if int(res.status) not in _RECOVERABLE:
            return res
        return self._recover(res, b)

    def _recover(self, res: SolveResult, b: jax.Array | None) -> SolveResult:
        """Apply the armed ``on_breakdown`` policy to an abnormal exit."""
        opts = self.options
        if opts.on_breakdown == "raise":
            raise SolveBreakdown(self.method, res)
        b = self.problem.b() if b is None else b
        if opts.on_breakdown == "restart":
            for attempt in range(1, opts.max_restarts + 1):
                x_start = res.x
                if not bool(jnp.all(jnp.isfinite(x_start))):
                    x_start = jnp.zeros_like(b)
                with obs.span("resilience.attempt", policy="restart",
                              method=self.method, attempt=attempt,
                              from_status=status_name(res.status)):
                    res = self._solve_once(b, x_start)
                if int(res.status) not in _RECOVERABLE:
                    break
            return res
        for attempt, (name, sess) in enumerate(self._fallback_ladder(), 1):
            if attempt > max(1, opts.max_restarts):
                break
            with obs.span("resilience.attempt", policy="fallback",
                          method=name, attempt=attempt,
                          from_status=status_name(res.status)):
                res = sess._solve_once(b, None)
            if int(res.status) not in _RECOVERABLE:
                break
        return res

    def _fallback_ladder(self) -> list[tuple[str, "SolverSession"]]:
        """Sessions the ``"fallback"`` policy walks, built lazily and cached
        for the session's lifetime: the same method with every kernel
        override retreated to the reference XLA operator (Pallas / custom
        ``matvec_padded`` / custom ``dot`` dropped) when one was active,
        then each ``variant_of`` ancestor down to the classical method —
        the preconditioner is dropped for rungs without an ``M=`` hook and
        residual replacement for rungs without a refresh hook.  Ladder
        sessions run with guards armed but ``on_breakdown="none"``: their
        typed status gates the walk without recursing into recovery."""
        if self._fallbacks is not None:
            return self._fallbacks
        opts = self.options
        base = opts.replace(on_breakdown="none", guards=True, pallas=False,
                            matvec_padded=None, dot=None)
        plan: list[tuple[str, SolverOptions]] = []
        if (opts.pallas or opts.matvec_padded is not None
                or opts.dot is not None):
            plan.append((self.method, base))
        for name in fallback_chain(self.method)[1:]:
            spec = get_solver(name)
            o = base
            if not spec.accepts_precond and o.precond != "none":
                o = o.replace(precond="none", precond_params=None)
            mdef = getattr(spec, "method_def", None)
            if o.residual_replacement and not (mdef is not None
                                               and mdef.has_refresh):
                o = o.replace(residual_replacement=0)
            plan.append((name, o))
        self._fallbacks = [
            (name, SolverSession(self.problem, method=name, options=o,
                                 mesh=self._mesh_arg))
            for name, o in plan]
        return self._fallbacks

    def timed_solve(self, b: jax.Array | None = None,
                    x0: jax.Array | None = None, *,
                    repeats: int = 10,
                    warmup: int = 1) -> tuple[SolveResult, dict[str, float]]:
        """Solve with honest wall-clock stats: warm-up (compile) happens
        outside the timed region and every call blocks until ready.  Uses
        an undonated compile (repeat calls reuse the same input buffers)."""
        with obs.span("solve", method=self.method,
                      grid=list(self.problem.shape),
                      backend=self.backend.kind, timed=True,
                      repeats=repeats):
            b = self._place(self.problem.b() if b is None else b)
            x0 = self._place(self.problem.x0() if x0 is None else x0)
            if self._timed_fn is None:
                # the jit is lazy, so AOT-lower here to give the compile its
                # own honest span (warm-up inside timed_result would
                # otherwise absorb it invisibly)
                with obs.span("compile", method=self.method,
                              shape=list(self.problem.shape),
                              backend=self.backend.kind):
                    self._timed_fn = (self._build_fn(donate=False)
                                      .lower(b, x0).compile())
            with obs.span("execute"):
                return timed_result(self._timed_fn, b, x0, repeats=repeats,
                                    warmup=warmup)

    # -- batched multi-RHS path (the serving workload) ------------------------
    def _build_batched_fn(self, *, donate: bool | None = None):
        opts = self.options
        donate = opts.donate if donate is None else donate
        jit_kw = dict(donate_argnums=(1,)) if donate else {}
        if self.backend.kind == "local":
            A = LocalOp(self.problem.stencil, matvec_padded=self._matvec)

            def run(b, x0):
                return self.spec.fn(A, b, x0, dot=opts.dot,
                                    **self._solver_kwargs(A))

            return jax.jit(jax.vmap(run), **jit_kw)

        layout = self.layout
        stencil = self.problem.stencil

        def local_solve(b_loc, x0_loc):
            op = DistributedOp(stencil, layout, matvec_padded=self._matvec,
                               halo_mode=self.halo_mode)
            return self.spec.fn(op, b_loc, x0_loc, dot=op.dot,
                                **self._solver_kwargs(op))

        bspec = P(None, *layout.dim_axes)
        fn = shard_map(
            jax.vmap(local_solve),
            mesh=self.backend.mesh,
            in_specs=(bspec, bspec),
            out_specs=SolveResult(
                x=bspec, iters=P(), res_norm=P(), history=P(),
                telemetry=P() if opts.telemetry_rows() else None,
                status=P()),
        )
        return jax.jit(fn, **jit_kw)

    def _prep_batched(self, bs, x0s):
        """Validate + place a batch; returns (bs, x0s)."""
        if bs.ndim != 4:
            raise ValueError(f"bs must be (batch, nx, ny, nz), got {bs.shape}")
        if bs.shape[1:] != self.problem.shape:
            raise ValueError(
                f"RHS grid {bs.shape[1:]} != problem grid {self.problem.shape}")
        if x0s is None:
            x0s = jnp.zeros_like(bs)
        return self._place(bs, batched=True), self._place(x0s, batched=True)

    def solve_batched(self, bs: jax.Array,
                      x0s: jax.Array | None = None) -> SolveResult:
        """Solve ``bs.shape[0]`` right-hand sides in one compiled call.

        ``bs``/``x0s``: (batch, nx, ny, nz); ``x0s`` defaults to zeros.
        Returns a ``SolveResult`` whose leaves carry a leading batch axis.
        ``on_breakdown`` recovery never applies here: one poisoned lane
        must not raise or re-solve the whole batch — callers (the serve
        layer's poison quarantine) read the per-lane ``status`` instead.
        """
        with obs.span("solve", method=self.method,
                      grid=list(self.problem.shape),
                      backend=self.backend.kind, batch=int(bs.shape[0])):
            bs, x0s = self._prep_batched(bs, x0s)
            shape = tuple(bs.shape)
            fn = self._executable(shape, self._build_batched_fn,
                                  (self._abstract(shape, batched=True),) * 2)
            with obs.span("execute") as sp:
                res = fn(bs, x0s)
                if sp is not None:
                    res = jax.block_until_ready(res)
        return res

    def timed_solve_batched(self, bs: jax.Array,
                            x0s: jax.Array | None = None, *,
                            repeats: int = 10, warmup: int = 1
                            ) -> tuple[SolveResult, dict[str, float]]:
        """:meth:`solve_batched` with honest wall-clock stats (undonated
        compile — repeat calls reuse the same input buffers)."""
        bs, x0s = self._prep_batched(bs, x0s)
        if self._timed_batched_fn is None:
            self._timed_batched_fn = self._build_batched_fn(donate=False)
        return timed_result(self._timed_batched_fn, bs, x0s, repeats=repeats,
                            warmup=warmup)

    # -- analysis path (dry-run / roofline / barrier traces) ------------------
    def step_fn(self):
        """One solver *iteration* as a shard_mapped fn (exact cost analysis;
        see ``core.distributed.solve_step_shardmap``).  Mesh backends only."""
        if self.backend.kind != "shard_map":
            raise ValueError("step_fn needs a mesh backend")
        return solve_step_shardmap(
            self.problem, self.method, self.backend.mesh,
            dims_map=self.options.dims_map, matvec_padded=self._matvec,
            halo_mode=self.halo_mode, precond=self.precond)


# -- one-shot facades ---------------------------------------------------------

def _session(problem, method, grid, stencil, options, mesh,
             overrides: dict[str, Any]) -> SolverSession:
    options = options or SolverOptions()
    if overrides:
        options = options.replace(**overrides)
    return SolverSession(problem, method=method, grid=grid, stencil=stencil,
                         options=options, mesh=mesh)


def solve(problem: HPCGProblem | None = None, *, method: str = "cg_nb",
          grid: tuple[int, int, int] | None = None, stencil: str = "27pt",
          options: SolverOptions | None = None, mesh: Mesh | None = None,
          b: jax.Array | None = None, x0: jax.Array | None = None,
          **overrides) -> SolveResult:
    """Solve one system.  ``**overrides`` are ``SolverOptions`` fields
    (``tol=``, ``maxiter=``, ``pallas=``, ...) applied on top of ``options``."""
    sess = _session(problem, method, grid, stencil, options, mesh, overrides)
    return sess.solve(b=b, x0=x0)


def solve_batched(bs: jax.Array, problem: HPCGProblem | None = None, *,
                  method: str = "cg_nb",
                  grid: tuple[int, int, int] | None = None,
                  stencil: str = "27pt",
                  options: SolverOptions | None = None,
                  mesh: Mesh | None = None,
                  x0s: jax.Array | None = None,
                  **overrides) -> SolveResult:
    """Solve a batch of right-hand sides in one compiled call."""
    if bs.ndim != 4:
        raise ValueError(f"bs must be (batch, nx, ny, nz), got {bs.shape}")
    if grid is None and problem is None:
        grid = tuple(bs.shape[1:])
    sess = _session(problem, method, grid, stencil, options, mesh, overrides)
    return sess.solve_batched(bs, x0s=x0s)
