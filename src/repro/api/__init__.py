# Unified solver facade (docs/API.md): one entry point for local, sharded
# and Pallas-backed solves, with batched multi-RHS support for serving.
from repro.api.backend import (Backend, resolve_backend, resolve_halo_mode,
                               resolve_matvec, resolve_precond)
from repro.api.options import HALO_MODES, LAYOUTS, SolverOptions
from repro.api.registry import (
    REGISTRY,
    RegistryConsistencyError,
    SolverSpec,
    check_consistent_with_core,
    fallback_chain,
    fused_solver_names,
    get_solver,
    register_solver,
    solver_names,
    variant_pairs,
)
from repro.precond import PRECONDITIONERS, Preconditioner, make_precond, precond_names
from repro.api.session import SolverSession, solve, solve_batched
from repro.api.timing import timed, timed_result

__all__ = [
    "Backend",
    "HALO_MODES",
    "LAYOUTS",
    "PRECONDITIONERS",
    "Preconditioner",
    "REGISTRY",
    "RegistryConsistencyError",
    "SolverOptions",
    "SolverSession",
    "SolverSpec",
    "check_consistent_with_core",
    "fallback_chain",
    "fused_solver_names",
    "get_solver",
    "make_precond",
    "precond_names",
    "register_solver",
    "resolve_backend",
    "resolve_halo_mode",
    "resolve_matvec",
    "resolve_precond",
    "solve",
    "solve_batched",
    "solver_names",
    "timed",
    "timed_result",
    "variant_pairs",
]
