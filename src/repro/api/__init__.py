# Unified solver facade (docs/API.md): one entry point for local, sharded
# and Pallas-backed solves, with batched multi-RHS support for serving.
from repro.api.backend import (Backend, resolve_backend, resolve_halo_mode,
                               resolve_matvec)
from repro.api.options import HALO_MODES, LAYOUTS, SolverOptions
from repro.api.registry import (
    REGISTRY,
    SolverSpec,
    get_solver,
    register_solver,
    solver_names,
    variant_pairs,
)
from repro.api.session import SolverSession, solve, solve_batched
from repro.api.timing import timed, timed_result

__all__ = [
    "Backend",
    "HALO_MODES",
    "LAYOUTS",
    "REGISTRY",
    "SolverOptions",
    "SolverSession",
    "SolverSpec",
    "get_solver",
    "register_solver",
    "resolve_backend",
    "resolve_halo_mode",
    "resolve_matvec",
    "solve",
    "solve_batched",
    "solver_names",
    "timed",
    "timed_result",
    "variant_pairs",
]
