"""Typed solver options — the one configuration object the facade accepts.

Replaces the ad-hoc kwargs previously threaded through ``launch/solve.py``,
``configs/hpcg.py`` and every benchmark driver.  Everything the seven solvers
and the two execution worlds (local / shard_map) understand is named here;
call sites stop inventing their own flag spellings.
"""

from __future__ import annotations

import dataclasses
from typing import Callable


#: accepted ``layout`` values and what they resolve to (see backend.py)
LAYOUTS = ("auto", "local", "1d", "2d", "3d")


@dataclasses.dataclass(frozen=True)
class SolverOptions:
    """Everything that parameterises a solve, minus the problem itself.

    Attributes
    ----------
    tol:          convergence tolerance (relative to ``norm_ref``).
    maxiter:      iteration cap.
    f64:          build facade-constructed problems in double precision (the
                  paper's setting).  Only consulted when the facade builds
                  the problem from ``grid``/``stencil``: it then calls
                  ``enable_f64()``, which flips the PROCESS-GLOBAL
                  ``jax_enable_x64`` flag (a JAX limitation — x64 is not a
                  per-computation switch).  A problem you pass in is
                  authoritative: its dtype is used as-is and no global
                  state is touched.
    layout:       device decomposition: ``"auto"`` (local on 1 device, else
                  the paper-faithful 1-D z split), ``"local"``, ``"1d"``,
                  ``"2d"`` (data×model mesh), ``"3d"`` (pod×data×model).
    pallas:       back the local stencil SpMV with the Pallas kernel.
    norm_ref:     residual normalisation; ``1.0`` = the paper's absolute
                  HPCCG criterion, ``None`` = relative to ``||b||``.
    dot:          override the reduction used by the solver (local path
                  only; the distributed path always uses the layout's psum).
    halo_mode:    halo-exchange strategy for the distributed operator
                  (``"auto"`` | ``"concat"`` | ``"scatter"``).
    matvec_padded: override the padded-operand SpMV (wins over ``pallas``).
    dims_map:     explicit grid-dim -> mesh-axis mapping (advanced; wins
                  over ``layout`` when a mesh is supplied).
    """

    tol: float = 1e-6
    maxiter: int = 600
    f64: bool = True
    layout: str = "auto"
    pallas: bool = False
    norm_ref: float | None = 1.0
    dot: Callable | None = None
    halo_mode: str = "auto"
    matvec_padded: Callable | None = None
    dims_map: dict[str, str | None] | None = None

    def __post_init__(self):
        if self.layout not in LAYOUTS:
            raise ValueError(
                f"unknown layout {self.layout!r}; options: {LAYOUTS}")
        if self.maxiter < 0:
            raise ValueError(f"maxiter must be >= 0, got {self.maxiter}")

    def replace(self, **kw) -> "SolverOptions":
        return dataclasses.replace(self, **kw)

    def solver_kwargs(self) -> dict:
        """The kwargs every solver in the registry accepts."""
        return dict(tol=self.tol, maxiter=self.maxiter,
                    norm_ref=self.norm_ref)
