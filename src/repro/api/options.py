"""Typed solver options — the one configuration object the facade accepts.

Replaces the ad-hoc kwargs previously threaded through ``launch/solve.py``,
``configs/hpcg.py`` and every benchmark driver.  Everything the seven solvers
and the two execution worlds (local / shard_map) understand is named here;
call sites stop inventing their own flag spellings.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

#: accepted ``halo_mode`` values, owned by the operator that implements them
#: (see backend.resolve_halo_mode for how "auto" resolves)
from repro.core.distributed import HALO_MODES

#: accepted ``precond`` values ("none" + the repro.precond registry)
from repro.precond import precond_names

from repro.core.methods import GuardSpec

#: accepted ``layout`` values and what they resolve to (see backend.py)
LAYOUTS = ("auto", "local", "1d", "2d", "3d")

#: accepted ``on_breakdown`` recovery policies (repro.resilience):
#: "raise"    — raise SolveBreakdown on an abnormal guarded exit
#: "none"     — return the typed SolveResult.status untouched
#: "restart"  — re-solve from the last finite iterate, up to max_restarts
#: "fallback" — retry down the robustness ladder: pallas→XLA first, then
#:              variant_of back to the classical method
ON_BREAKDOWN = ("raise", "none", "restart", "fallback")


@dataclasses.dataclass(frozen=True)
class SolverOptions:
    """Everything that parameterises a solve, minus the problem itself.

    Attributes
    ----------
    tol:          convergence tolerance (relative to ``norm_ref``).
    maxiter:      iteration cap.
    f64:          solve in double precision (the paper's setting).  The
                  facade never flips the process-global ``jax_enable_x64``
                  flag itself: building an f64 problem requires the caller
                  to have run ``repro.core.problems.enable_f64()`` at
                  process start (drivers do), and a pre-built ``problem``
                  whose dtype contradicts this flag raises instead of being
                  silently accepted.
    layout:       device decomposition: ``"auto"`` (local on 1 device, else
                  the paper-faithful 1-D z split), ``"local"``, ``"1d"``,
                  ``"2d"`` (data×model mesh), ``"3d"`` (pod×data×model).
    pallas:       back the local stencil SpMV with the Pallas kernel.
                  ``None`` = "auto": ``kernels.autotune`` decides per
                  (stencil, grid, dtype, device_kind) — the persisted tune
                  cache when one exists, else the default table (TPU and
                  grid volume >= 24³).  Resolved to a concrete bool at
                  session construction.
    norm_ref:     residual normalisation; ``1.0`` = the paper's absolute
                  HPCCG criterion, ``None`` = relative to ``||b||``.
    dot:          override the reduction used by the solver (local path
                  only; the distributed path always uses the layout's psum).
    halo_mode:    halo-exchange strategy for the distributed operator
                  (``"auto"`` | ``"concat"`` | ``"scatter"`` |
                  ``"overlap"``).  ``"overlap"`` splits the SpMV into an
                  interior part computed while the ppermutes are in flight
                  and a boundary shell finished from the received planes;
                  all modes produce bit-for-bit identical results.
                  ``"auto"`` resolves to ``"overlap"`` for the built-in
                  stencil formulations, ``"concat"`` under a custom
                  ``matvec_padded``/Pallas kernel (see
                  ``backend.resolve_halo_mode``).
    matvec_padded: override the padded-operand SpMV (wins over ``pallas``).
    dims_map:     explicit grid-dim -> mesh-axis mapping (advanced; wins
                  over ``layout`` when a mesh is supplied).
    precond:      preconditioner for the methods that take one (``pcg`` /
                  ``pbicgstab``): ``"none"`` | ``"jacobi"`` |
                  ``"block_jacobi"`` | ``"ssor"`` | ``"chebyshev"``
                  (the ``repro.precond`` registry).  Resolved by
                  ``backend.resolve_precond``; requesting one with a
                  method that has no ``M=`` hook raises.
    precond_params: constructor knobs for the chosen preconditioner
                  (``{"sweeps": 3}``, ``{"degree": 5}``,
                  ``{"omega": 1.2}``, ...); ``options.pallas`` flows into
                  the preconditioners that have fused Pallas kernels
                  unless ``use_pallas`` is pinned here.
    donate:       donate the ``x0`` buffer of ``solve``/``solve_batched``
                  to the compiled call (``jax.jit`` ``donate_argnums``), so
                  the x/r/p iterate buffers reuse it instead of allocating
                  a fresh output each solve — the serving hot path.
                  Caveat: donation is live on EVERY backend (CPU included):
                  a caller-supplied ``x0`` array is INVALIDATED by the call
                  (reusing it raises a deleted-buffer error); pass
                  ``donate=False`` to keep reusing your own ``x0`` buffer.
                  The ``timed_*`` paths always compile an undonated variant
                  (they re-call with the same buffers).
    telemetry:    opt-in per-iteration convergence telemetry (repro.obs):
                  thread a bounded scalar-history buffer through the
                  solver's while-loop carry and return it as
                  ``SolveResult.telemetry`` — a
                  ``(min(telemetry_buffer, maxiter+1), n_scalars)`` array
                  whose row k holds every declared loop-carry scalar after
                  iteration k (row 0 = the initial state; NaN-padded past
                  convergence; iterations beyond the buffer overwrite its
                  last row).  Works on every backend (the buffer is part of
                  the MethodDef driver's carry) and is donation-safe
                  (fixed-size, created inside the jitted solve).  Disabled
                  (the default) the solve is a bitwise no-op vs the
                  pre-telemetry facade: ``SolveResult.telemetry`` is
                  ``None`` — an empty pytree subtree — and the lowered HLO
                  is unchanged.  Enabled it adds one (cheap, fused)
                  buffer write per iteration to the compiled loop.
    telemetry_buffer: row bound of the telemetry buffer (clamped to
                  ``maxiter + 1``); only read when ``telemetry=True``.
    guards:       arm the per-iteration breakdown guards (repro.resilience):
                  NaN scalars, divergence, the method's ρ-underflow /
                  negative-curvature guard and optional stagnation
                  detection, all riding scalars the loop already carries
                  (zero extra collectives).  OFF by default — with guards
                  off and ``on_breakdown="raise"`` the compiled solve is
                  bitwise the pre-resilience one except for the always-on
                  typed ``SolveResult.status``.
    on_breakdown: what ``SolverSession.solve`` does when a GUARDED solve
                  exits with status breakdown/diverged/stagnated (see
                  ``ON_BREAKDOWN``).  Any value other than "raise"/"none"
                  implies ``guards``.  Applies to single-RHS ``solve``
                  only; ``solve_batched`` always returns per-lane statuses.
    max_restarts: attempt budget for the "restart"/"fallback" policies.
    residual_replacement: every N > 0 iterations, re-derive the TRUE
                  residual (and the recurrence images) from the iterate —
                  the drift mitigation for the merged/pipelined variants
                  (methods whose MethodDef declares a ``refresh`` hook).
                  Cost: ``refresh_spmvs`` SpMV-equivalents per refresh,
                  priced by the scaling model's ``t_rr`` term.  0 = off.
    breakdown_eps / divergence_factor / stagnation_window / stagnation_rtol:
                  GuardSpec thresholds (see ``core.methods.GuardSpec``);
                  read only when guards are armed.
    """

    tol: float = 1e-6
    maxiter: int = 600
    f64: bool = True
    layout: str = "auto"
    pallas: bool | None = False
    norm_ref: float | None = 1.0
    dot: Callable | None = None
    halo_mode: str = "auto"
    matvec_padded: Callable | None = None
    dims_map: dict[str, str | None] | None = None
    precond: str = "none"
    precond_params: dict | None = None
    donate: bool = True
    telemetry: bool = False
    telemetry_buffer: int = 256
    guards: bool = False
    on_breakdown: str = "raise"
    max_restarts: int = 2
    residual_replacement: int = 0
    breakdown_eps: float = 1e-12
    divergence_factor: float = 1e8
    stagnation_window: int = 0
    stagnation_rtol: float = 1.0

    def guards_armed(self) -> bool:
        """Whether the breakdown guards compile into the loop cond: armed
        explicitly (``guards=True``) or implied by an active recovery
        policy (restart/fallback need the typed early exit to act on)."""
        return self.guards or self.on_breakdown in ("restart", "fallback")

    def guard_spec(self) -> GuardSpec | None:
        """The GuardSpec the MethodDef driver takes; None when disarmed."""
        if not self.guards_armed():
            return None
        return GuardSpec(
            breakdown_eps=self.breakdown_eps,
            divergence_factor=self.divergence_factor,
            stagnation_window=self.stagnation_window,
            stagnation_rtol=self.stagnation_rtol)

    def telemetry_rows(self) -> int:
        """Effective telemetry buffer rows: 0 when disabled, else the
        declared bound clamped to ``maxiter + 1`` (the most rows a solve
        can produce).  This is the ``telemetry=`` integer the MethodDef
        driver and ``solve_shardmap`` take."""
        if not self.telemetry:
            return 0
        return min(self.telemetry_buffer, self.maxiter + 1)

    def __post_init__(self):
        if self.precond not in precond_names():
            raise ValueError(
                f"unknown precond {self.precond!r}; "
                f"options: {precond_names()}")
        if self.precond_params and self.precond == "none":
            raise ValueError("precond_params given but precond='none'")
        if self.layout not in LAYOUTS:
            raise ValueError(
                f"unknown layout {self.layout!r}; options: {LAYOUTS}")
        if self.halo_mode not in HALO_MODES:
            raise ValueError(
                f"unknown halo_mode {self.halo_mode!r}; options: {HALO_MODES}")
        if self.maxiter < 0:
            raise ValueError(f"maxiter must be >= 0, got {self.maxiter}")
        if self.telemetry_buffer < 1:
            raise ValueError(
                f"telemetry_buffer must be >= 1, got {self.telemetry_buffer}")
        if self.on_breakdown not in ON_BREAKDOWN:
            raise ValueError(
                f"unknown on_breakdown {self.on_breakdown!r}; "
                f"options: {ON_BREAKDOWN}")
        if self.max_restarts < 0:
            raise ValueError(
                f"max_restarts must be >= 0, got {self.max_restarts}")
        if self.residual_replacement < 0:
            raise ValueError(
                f"residual_replacement must be >= 0 (0 disables), got "
                f"{self.residual_replacement}")
        if self.guards_armed():
            self.guard_spec()   # validates the GuardSpec thresholds

    def replace(self, **kw) -> "SolverOptions":
        return dataclasses.replace(self, **kw)

    def solver_kwargs(self) -> dict:
        """The kwargs every solver in the registry accepts."""
        return dict(tol=self.tol, maxiter=self.maxiter,
                    norm_ref=self.norm_ref)
