# The preconditioning subsystem (docs/API.md §Preconditioning): a small
# Preconditioner protocol with four reduction-free implementations, consumed
# by the pcg/pbicgstab solvers through SolverOptions.precond.  Importing the
# implementation modules registers them.
from repro.precond.base import (
    PRECONDITIONERS,
    Preconditioner,
    make_precond,
    precond_names,
    register_preconditioner,
)
from repro.precond.chebyshev import Chebyshev, gershgorin_bounds
from repro.precond.jacobi import BlockJacobi, PointJacobi
from repro.precond.ssor import SSOR

#: preconditioners with a fused Pallas kernel behind ``use_pallas=True``
PALLAS_PRECONDS = ("block_jacobi", "chebyshev")

__all__ = [
    "PALLAS_PRECONDS",
    "PRECONDITIONERS",
    "BlockJacobi",
    "Chebyshev",
    "PointJacobi",
    "Preconditioner",
    "SSOR",
    "gershgorin_bounds",
    "make_precond",
    "precond_names",
    "register_preconditioner",
]
