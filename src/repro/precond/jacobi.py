"""Point-Jacobi and block-Jacobi preconditioners.

``PointJacobi`` is the classical diagonal preconditioner extended to ``m``
Jacobi sweeps on ``A z = r`` (the ``m``-term Neumann/Jacobi-smoothing
polynomial).  NOTE on the HPCG operator: the matrix has a *constant*
diagonal, so a single sweep (``sweeps=1``, pure ``z = D^{-1} r``) rescales
the Krylov space uniformly and is a convergence no-op; the default two
sweeps give the degree-1 polynomial ``M^{-1} = (2I - D^{-1}A) D^{-1}``,
which genuinely clusters the spectrum.  Each extra sweep costs one full
matvec (with halo exchange — the overlapped SpMV from PR 2 applies).

``BlockJacobi`` is the two-stage-multisplitting idea (Brown et al.): the
outer Krylov method sees a block-diagonal ``M`` whose blocks are each
shard's *local* operator with zero halos, solved *incompletely* by a fixed
number of damped Jacobi sweeps.  Zero communication: the sweeps use
``A.matvec_local`` (no ppermutes), so the preconditioner adds no halo
traffic and no reductions — it is free on the wire.  On one device the
local block is the whole domain and block-Jacobi degenerates to Jacobi
smoothing; distributed, the block structure (and hence the iterate) differs
per decomposition, which is the accepted multisplitting trade.

SPD: with the constant diagonal both are polynomials in (the local) SPD
operator; positivity holds whenever ``omega * lambda_max(A) < 2 * diag``
(true for both HPCG stencils at ``omega <= 1``) — odd sweep counts are
unconditionally safe.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.precond.base import Preconditioner, register_preconditioner


@register_preconditioner
class PointJacobi(Preconditioner):
    """``m``-sweep Jacobi: ``z_{k+1} = z_k + D^{-1}(r - A z_k)``, ``z_1 = D^{-1} r``."""

    name = "jacobi"
    spd_preserving = True

    def __init__(self, sweeps: int = 2):
        if sweeps < 1:
            raise ValueError(f"sweeps must be >= 1, got {sweeps}")
        self.sweeps = sweeps

    def apply(self, state, A, r: jax.Array) -> jax.Array:
        z = r / A.diag
        for _ in range(self.sweeps - 1):
            z = z + (r - A.matvec(z)) / A.diag
        return z

    @property
    def matvecs_per_apply(self) -> int:
        return self.sweeps - 1

    @property
    def halo_matvecs_per_apply(self) -> int:
        return self.sweeps - 1          # every sweep's matvec is global

    def touched_elements_per_apply(self, nbar: int) -> int:
        # first sweep: read r, write z (2); each further sweep: one stencil
        # apply (nbar+2) + read r,z / write z (3)
        return 2 + (self.sweeps - 1) * (nbar + 2 + 3)

    def describe(self) -> str:
        return f"jacobi(sweeps={self.sweeps})"


@register_preconditioner
class BlockJacobi(Preconditioner):
    """Per-shard incomplete solve: damped Jacobi sweeps with zero halos."""

    name = "block_jacobi"
    spd_preserving = True

    def __init__(self, sweeps: int = 3, omega: float = 1.0,
                 use_pallas: bool = False):
        if sweeps < 1:
            raise ValueError(f"sweeps must be >= 1, got {sweeps}")
        if not 0.0 < omega <= 1.0:
            raise ValueError(f"omega must be in (0, 1], got {omega}")
        self.sweeps = sweeps
        self.omega = omega
        self.use_pallas = use_pallas

    def apply(self, state, A, r: jax.Array) -> jax.Array:
        z = self.omega * r / A.diag
        for _ in range(self.sweeps - 1):
            if self.use_pallas:
                from repro.kernels import ops
                z = ops.jacobi_sweep(jnp.pad(z, 1), r, A.stencil,
                                     omega=self.omega)
            else:
                z = z + self.omega * (r - A.matvec_local(z)) / A.diag
        return z

    @property
    def matvecs_per_apply(self) -> int:
        return self.sweeps - 1

    @property
    def halo_matvecs_per_apply(self) -> int:
        return 0                        # shard-local by construction

    def touched_elements_per_apply(self, nbar: int) -> int:
        return 2 + (self.sweeps - 1) * (nbar + 2 + 3)

    def describe(self) -> str:
        return f"block_jacobi(sweeps={self.sweeps}, omega={self.omega})"
