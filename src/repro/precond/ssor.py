"""Symmetric SOR preconditioner via the red-black colouring (§3.4 machinery).

One apply performs, per sweep, the relaxed half-sweep sequence
red, black | black, red (forward SOR then backward SOR) on ``A z = r``
starting from ``z = 0`` — the procedural form of
``M = (D/ω + L) (ω/(2-ω)) D^{-1} (D/ω + U)``, which is SPD for SPD ``A``
and ``0 < ω < 2``, so ``pcg`` applies.  For the 7-pt stencil the colouring
is an exact Gauss-Seidel reordering (the graph is bipartite); for the 27-pt
stencil same-colour neighbours make each half-sweep a coloured relaxation —
the palindromic half-sweep sequence keeps ``M`` symmetric either way (each
half-sweep's iteration map is ``A``-self-adjoint for the constant diagonal).

Communication: each half-sweep consumes fresh halos at its first cell, so
its exchange cannot hide behind interior work (``halo_hide="none"``, like
the Gauss-Seidel *solvers* the registry already marks).  Reductions: zero.
The half-sweep reuses ``Stencil.offdiag_apply_padded`` + the operator's
``pad_exchange`` — the exact machinery of ``sym_gauss_seidel_rb`` /
``kernels/rb_gs.py`` — so local and shard_map applies are the same grid-wide
sweep (identical arithmetic; the distributed operator only swaps where the
halo planes come from).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.solvers import _colour_mask
from repro.precond.base import Preconditioner, register_preconditioner


@register_preconditioner
class SSOR(Preconditioner):
    """Red-black symmetric SOR: forward (red, black) + backward (black, red)."""

    name = "ssor"
    spd_preserving = True
    halo_hide = "none"                  # half-sweeps read halos immediately

    def __init__(self, omega: float = 1.0, sweeps: int = 1):
        if not 0.0 < omega < 2.0:
            raise ValueError(f"SSOR needs 0 < omega < 2, got {omega}")
        if sweeps < 1:
            raise ValueError(f"sweeps must be >= 1, got {sweeps}")
        self.omega = omega
        self.sweeps = sweeps

    def _half_sweep(self, A, r, z, mask) -> jax.Array:
        off = A.stencil.offdiag_apply_padded(A.pad_exchange(z))
        relaxed = (1.0 - self.omega) * z + self.omega * (r - off) / A.diag
        return jnp.where(mask, relaxed, z)

    def apply(self, state, A, r: jax.Array) -> jax.Array:
        red = _colour_mask(r.shape, 0)
        black = _colour_mask(r.shape, 1)
        # the very first half-sweep acts on z = 0, so its halo exchange and
        # off-diagonal apply are all-zeros work: fold it into the initial
        # guess directly (identical arithmetic, one exchange+apply fewer)
        z = jnp.where(red, self.omega * r / A.diag, jnp.zeros_like(r))
        masks = [red, black, black, red] * self.sweeps
        for mask in masks[1:]:
            z = self._half_sweep(A, r, z, mask)
        return z

    @property
    def matvecs_per_apply(self) -> int:
        # 4 half-sweeps per sweep, minus the folded-away first one
        return 4 * self.sweeps - 1

    @property
    def halo_matvecs_per_apply(self) -> int:
        return 4 * self.sweeps - 1

    def touched_elements_per_apply(self, nbar: int) -> int:
        # init (read r, write z) + per half-sweep: off-diagonal apply
        # (nbar+1) + read r,z / write z
        return 2 + (4 * self.sweeps - 1) * (nbar + 1 + 3)

    def describe(self) -> str:
        return f"ssor(omega={self.omega}, sweeps={self.sweeps})"
