"""Chebyshev polynomial preconditioner.

``z = p_{k-1}(A) r`` where ``p`` is the degree-``k-1`` Chebyshev polynomial
minimising the error over the eigenvalue interval ``[lmin, lmax]`` — the
classical reduction-free preconditioner: the apply is a pure matvec chain
(``k-1`` SpMVs, each reusing the PR-2 overlapped halo exchange), with NO
inner products.  That makes it the natural fit for this repo's thesis:
where CG-NB hides its two reductions per iteration, pcg+chebyshev *removes*
the preconditioner's reductions entirely and pays only hideable halo
traffic.

Eigenvalue bounds default to the Gershgorin interval of the constant-
coefficient stencil: ``diag ± Σ|off|`` — exact-tight for the 7-pt HPCG
operator ([21, 33]) and valid for the 27-pt one ([1, 53]).  The scalar
recurrence (theta/delta/rho) involves only these static bounds, so the
whole coefficient schedule is precomputed in Python at setup: the traced
apply is nothing but SpMVs and axpys with *constant* coefficients, which is
what lets the fused Pallas kernel (``kernels/precond.cheb_fused_step``)
bake them in and do matvec + d/z updates in one VMEM pass.

SPD: ``p`` is positive on ``[lmin, lmax] ⊃ spec(A)`` by construction
(``lmin > 0``), so ``M^{-1} = p(A)`` is SPD and ``pcg`` applies.
"""

from __future__ import annotations

import jax

from repro.precond.base import Preconditioner, register_preconditioner


def gershgorin_bounds(stencil) -> tuple[float, float]:
    """Spectral interval ``diag ± Σ|off_coeff|`` of the stencil operator."""
    s = sum(abs(stencil.off_coeff) for _ in stencil.offsets)
    return stencil.diag - s, stencil.diag + s


@register_preconditioner
class Chebyshev(Preconditioner):
    """Degree-``degree-1`` Chebyshev polynomial apply (``degree-1`` SpMVs)."""

    name = "chebyshev"
    spd_preserving = True

    def __init__(self, degree: int = 4,
                 bounds: tuple[float, float] | None = None,
                 use_pallas: bool = False):
        if degree < 1:
            raise ValueError(f"degree must be >= 1, got {degree}")
        self.degree = degree
        self.bounds = bounds
        self.use_pallas = use_pallas

    def setup(self, A) -> tuple:
        lmin, lmax = self.bounds or gershgorin_bounds(A.stencil)
        if not 0.0 < lmin < lmax:
            raise ValueError(
                f"Chebyshev needs 0 < lmin < lmax, got [{lmin}, {lmax}]; "
                f"pass explicit bounds= for indefinite/near-singular operators")
        theta = (lmax + lmin) / 2.0
        delta = (lmax - lmin) / 2.0
        sigma = theta / delta
        rho = 1.0 / sigma
        coefs = []                       # static Python floats, per step
        for _ in range(self.degree - 1):
            rho_new = 1.0 / (2.0 * sigma - rho)
            coefs.append((rho_new * rho, 2.0 * rho_new / delta))
            rho = rho_new
        return (theta, tuple(coefs))

    def apply(self, state, A, r: jax.Array) -> jax.Array:
        theta, coefs = state
        z = r / theta
        d = z
        for a, c in coefs:               # d = a*d + c*(r - A z); z += d
            if self.use_pallas:
                from repro.kernels import ops
                z, d = ops.cheb_step(A.pad_exchange(z), r, d, A.stencil,
                                     a=a, c=c)
            else:
                d = a * d + c * (r - A.matvec(z))
                z = z + d
        return z

    @property
    def matvecs_per_apply(self) -> int:
        return self.degree - 1

    @property
    def halo_matvecs_per_apply(self) -> int:
        return self.degree - 1

    def touched_elements_per_apply(self, nbar: int) -> int:
        # z_1 = r/theta (2) + per step: SpMV (nbar+2) + r,d,z reads/writes (5)
        return 2 + (self.degree - 1) * (nbar + 2 + 5)

    def describe(self) -> str:
        return f"chebyshev(degree={self.degree})"
