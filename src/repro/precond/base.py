"""The preconditioner protocol + registry (the `repro.precond` subsystem).

The paper's four methods are unpreconditioned; at production sizes their
iteration counts grow with the grid and dominate every communication win.
A preconditioner trades extra *local* work per iteration (and, for some,
extra halo exchanges — but never extra global reductions) for fewer
iterations, i.e. fewer all-reduces total.  That is exactly the axis the
scaling model reasons about, so every implementation carries the metadata
the model and the drivers need:

  * ``extra_reductions_per_apply`` — global reductions the apply performs
    (0 for all built-ins: being reduction-free is the design constraint,
    following the two-stage-multisplitting idea that inner work must not
    add barriers),
  * ``matvecs_per_apply`` / ``halo_matvecs_per_apply`` — stencil applies
    per ``M^{-1} r``, and how many of them need a halo exchange in the
    distributed world (block-Jacobi: zero — its sweeps are shard-local),
  * ``halo_hide`` — whether those exchanges can ride behind the interior
    apply (``"interior"``, the PR-2 overlapped SpMV) or block like the
    Gauss-Seidel sweeps (``"none"``),
  * ``spd_preserving`` — whether ``M^{-1}`` keeps the preconditioned
    operator SPD, i.e. whether ``pcg`` is applicable.

Protocol: ``setup(A) -> state`` (traced once per solve, inside jit),
``apply(state, A, r) -> z``; ``bind(A)`` packages both into the
``z = M^{-1} r`` callable the solvers take.  ``A`` is any operator
satisfying the ``LocalOp`` protocol (``matvec``, ``matvec_local``,
``pad_exchange``, ``diag``, ``stencil``), so one implementation runs
single-device and inside ``shard_map`` unchanged — the same
write-once/parallelise-underneath rule the solvers follow.
"""

from __future__ import annotations

from typing import Callable

import jax


class Preconditioner:
    """Base class; subclasses are registered in ``PRECONDITIONERS``."""

    name: str = "?"
    spd_preserving: bool = True
    #: global reductions per apply (all built-ins: 0 — no new barriers)
    extra_reductions_per_apply: int = 0
    #: halo-exchange hide kind for the exchanges the apply does perform:
    #: "interior" = rides behind the interior stencil apply (PR-2 overlap),
    #: "none" = consumed immediately (the SSOR half-sweeps).
    halo_hide: str = "interior"

    # -- the protocol ---------------------------------------------------------
    def setup(self, A) -> tuple:
        """Build the per-solve state (traced; must be cheap and pure)."""
        return ()

    def apply(self, state, A, r: jax.Array) -> jax.Array:
        """``z ~= A^{-1} r`` — one application of ``M^{-1}``."""
        raise NotImplementedError

    def bind(self, A) -> Callable[[jax.Array], jax.Array]:
        """The ``z = M^{-1} r`` callable the solvers accept as ``M=``."""
        state = self.setup(A)

        def apply_M(r: jax.Array) -> jax.Array:
            return self.apply(state, A, r)

        return apply_M

    # -- cost metadata (the scaling model's t_precond term) -------------------
    @property
    def matvecs_per_apply(self) -> int:
        """Stencil applications per ``M^{-1} r`` (HBM traffic)."""
        return 0

    @property
    def halo_matvecs_per_apply(self) -> int:
        """...of which need a halo exchange in the distributed world."""
        return 0

    def touched_elements_per_apply(self, nbar: int) -> int:
        """Per-row memory traffic of one apply, in the paper's §3.1 units
        (each stencil apply streams n̄+2 elements per row; vector updates
        add their operand count)."""
        raise NotImplementedError

    def describe(self) -> str:
        return self.name


#: name -> Preconditioner subclass; "none" is represented by Python None
PRECONDITIONERS: dict[str, type] = {}


def register_preconditioner(cls: type) -> type:
    """Class decorator: add a Preconditioner implementation to the registry."""
    if not issubclass(cls, Preconditioner):
        raise TypeError(f"{cls!r} is not a Preconditioner subclass")
    if cls.name in PRECONDITIONERS:
        raise ValueError(f"preconditioner {cls.name!r} already registered")
    PRECONDITIONERS[cls.name] = cls
    return cls


def precond_names() -> tuple[str, ...]:
    """Accepted ``SolverOptions.precond`` values ("none" + the registry)."""
    return ("none", *sorted(PRECONDITIONERS))


def make_precond(name: str | None, **params) -> Preconditioner | None:
    """Build a configured preconditioner; ``"none"``/``None`` -> ``None``.

    ``params`` are the implementation's constructor knobs (``sweeps=``,
    ``omega=``, ``degree=``, ``use_pallas=``, ...).
    """
    if name is None or name == "none":
        if params:
            raise ValueError(f"precond='none' takes no params, got {params}")
        return None
    try:
        cls = PRECONDITIONERS[name]
    except KeyError:
        raise KeyError(
            f"unknown preconditioner {name!r}; options: {precond_names()}"
        ) from None
    return cls(**params)
