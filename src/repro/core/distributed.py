"""Distributed solver layer: the paper's MPI decomposition on a TPU mesh.

The paper decomposes the 3-D grid explicitly across MPI ranks (HPCCG splits
only the last dimension) and exchanges boundary planes point-to-point
(``exchange_externals``, Code 2).  Here the decomposition is expressed as a
``GridLayout`` mapping grid dims -> mesh axes, halos travel over
``lax.ppermute`` (nearest-neighbour ICI traffic), and global reductions are
``lax.psum``.  Everything runs inside one ``jax.shard_map``-wrapped solver so
the entire iteration is a single compiled program — the analogue of the
paper's zero-sequential-parts requirement (HDOT).

Faithful mode: 1-D decomposition of z over one flattened axis (the paper's
HPCCG layout).  Beyond-paper mode: full 3-D decomposition (x->model, y->data,
z->pod on the production mesh), which reduces halo bytes per device from
``2·nx·ny`` to the block's surface — see EXPERIMENTS.md §Perf.

Dimension-ordered halo exchange: each dim's slabs span the *padded* extent of
the other dims, so later exchanges forward previously received halos and the
27-pt stencil's edge/corner neighbours arrive correctly with only 6 ppermutes.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map
from repro.core.operators import Stencil, interior_matvec, shell_assemble
from repro.core.problems import HPCGProblem
from repro.core.solvers import SOLVERS, SolveResult, _cg_merged_scalars

#: halo-exchange strategies of the distributed operator ("auto" resolves to
#: "concat" here; repro.api.backend upgrades it to "overlap" where safe)
HALO_MODES = ("auto", "scatter", "concat", "overlap")


@dataclasses.dataclass(frozen=True)
class GridLayout:
    """Maps grid dims (x, y, z) to mesh axis names (or None = not split)."""

    mesh: Mesh
    dim_axes: tuple[str | None, str | None, str | None]

    def __post_init__(self):
        for a in self.dim_axes:
            if a is not None and a not in self.mesh.axis_names:
                raise ValueError(f"axis {a!r} not in mesh {self.mesh.axis_names}")

    @property
    def reduce_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.dim_axes if a is not None)

    def spec(self) -> P:
        return P(*self.dim_axes)

    def axis_size(self, d: int) -> int:
        a = self.dim_axes[d]
        return 1 if a is None else self.mesh.shape[a]

    def local_shape(self, global_shape: tuple[int, int, int]) -> tuple[int, int, int]:
        out = []
        for d, g in enumerate(global_shape):
            n = self.axis_size(d)
            if g % n:
                raise ValueError(f"grid dim {d} ({g}) not divisible by mesh axis ({n})")
            out.append(g // n)
        return tuple(out)


class DistributedOp:
    """Stencil operator on a local block inside ``shard_map``.

    Protocol-compatible with ``solvers.LocalOp``: the solver code is identical
    in both worlds (the paper's write-once/parallelise-underneath goal).
    """

    def __init__(self, stencil: Stencil, layout: GridLayout,
                 matvec_padded: Callable | None = None,
                 halo_mode: str = "auto"):
        self.stencil = stencil
        self.layout = layout
        # measured per-stencil bests (EXPERIMENTS.md §Perf): the slice-add
        # stencil fuses well at 7pt; the conv formulation halves traffic at
        # 27pt; concat halos beat pad+scatter in both conv cases
        if matvec_padded is None:
            matvec_padded = (stencil.conv_matvec_padded()
                             if stencil.npoint >= 27 else stencil.matvec_padded)
        self._mv_padded = matvec_padded
        if halo_mode not in HALO_MODES:
            raise ValueError(
                f"unknown halo_mode {halo_mode!r}; options: {HALO_MODES}")
        if halo_mode == "auto":
            halo_mode = "concat"
        self.halo_mode = halo_mode

    @property
    def diag(self) -> float:
        return self.stencil.diag

    @property
    def split_dims(self) -> tuple[int, ...]:
        """Grid dims actually decomposed (mapped to a mesh axis of size > 1)."""
        return tuple(
            d for d, a in enumerate(self.layout.dim_axes)
            if a is not None and self.layout.mesh.shape[a] > 1)

    # --- halo exchange (the paper's exchange_externals) ----------------------
    def pad_exchange(self, x: jax.Array) -> jax.Array:
        if self.halo_mode == "scatter":
            return self._pad_exchange_scatter(x)
        return self._pad_exchange_concat(x)

    def _pad_exchange_scatter(self, x: jax.Array) -> jax.Array:
        """Baseline: zero-pad then scatter received planes into the halos.

        Costs a full-array pad copy plus per-dim ``.at[].set`` updates —
        measured at ~8r extra HBM traffic per matvec (EXPERIMENTS.md §Perf).
        """
        xp = jnp.pad(x, 1)
        for d, axis in enumerate(self.layout.dim_axes):
            if axis is None:
                continue
            n = self.layout.mesh.shape[axis]
            if n == 1:
                continue
            sl_lo = [slice(None)] * 3
            sl_hi = [slice(None)] * 3
            sl_lo[d] = slice(1, 2)        # my bottom interior plane
            sl_hi[d] = slice(-2, -1)      # my top interior plane
            up = lax.ppermute(                       # i -> i+1: fills my LOWER halo
                xp[tuple(sl_hi)], axis, [(i, i + 1) for i in range(n - 1)]
            )
            down = lax.ppermute(                     # i -> i-1: fills my UPPER halo
                xp[tuple(sl_lo)], axis, [(i + 1, i) for i in range(n - 1)]
            )
            halo_lo = [slice(None)] * 3
            halo_hi = [slice(None)] * 3
            halo_lo[d] = slice(0, 1)
            halo_hi[d] = slice(xp.shape[d] - 1, xp.shape[d])
            xp = xp.at[tuple(halo_lo)].set(up)
            xp = xp.at[tuple(halo_hi)].set(down)
        return xp

    def _pad_exchange_concat(self, x: jax.Array) -> jax.Array:
        """Optimised: build the padded array by per-dim concatenation.

        The received (or zero) halo planes are concatenated onto the block
        dim by dim — one materialisation per dim instead of pad + scatter
        pairs, and XLA folds the nested concats into a single copy.  Later
        dims' slabs span the already-extended extents, so 27-pt corner
        neighbours arrive exactly as in the scatter form.
        """
        xp = x
        for d in range(3):
            axis = self.layout.dim_axes[d]
            shape = list(xp.shape)
            shape[d] = 1
            zero = jnp.zeros(shape, xp.dtype)
            n = self.layout.mesh.shape[axis] if axis is not None else 1
            if axis is None or n == 1:
                lo = hi = zero
            else:
                sl_lo = [slice(None)] * 3
                sl_hi = [slice(None)] * 3
                sl_lo[d] = slice(0, 1)
                sl_hi[d] = slice(xp.shape[d] - 1, xp.shape[d])
                lo = lax.ppermute(xp[tuple(sl_hi)], axis,
                                  [(i, i + 1) for i in range(n - 1)])
                hi = lax.ppermute(xp[tuple(sl_lo)], axis,
                                  [(i + 1, i) for i in range(n - 1)])
            xp = jnp.concatenate([lo, xp, hi], axis=d)
        return xp

    def matvec(self, x: jax.Array) -> jax.Array:
        if self.halo_mode == "overlap":
            return self._matvec_overlap(x)
        return self._mv_padded(self.pad_exchange(x))

    def matvec_local(self, x: jax.Array) -> jax.Array:
        """Zero-halo apply on the local block ONLY — no ppermutes.

        The block-diagonal operator of the block-Jacobi preconditioner
        (two-stage multisplitting): decomposed faces are treated as
        physical boundary, so the apply is communication-free.
        """
        return self._mv_padded(jnp.pad(x, 1))

    def _matvec_overlap(self, x: jax.Array) -> jax.Array:
        """Overlapped halo-exchange SpMV (the paper's task-based split).

        The ppermutes are issued first; the interior — every output cell at
        distance >= 1 from a decomposed face, i.e. almost the whole block —
        depends only on ``x``, so the latency-hiding scheduler can run it
        while the collectives are in flight.  Only the one-cell boundary
        shell consumes the received planes.  The ``optimization_barrier``
        pins the interior as its own schedulable task (the same idiom that
        keeps bicgstab_b1's reduction overlap windows from fusing away).
        Solver results are bit-for-bit identical to the concat/scatter
        modes (tests/test_halo_overlap.py).
        """
        split = self.split_dims
        if not split or min(x.shape[d] for d in split) < 2:
            # nothing decomposed (or degenerate 1-plane blocks: no interior)
            return self._mv_padded(self._pad_exchange_concat(x))
        xp = self._pad_exchange_concat(x)
        y_int = lax.optimization_barrier(
            interior_matvec(self._mv_padded, x, split))
        return shell_assemble(self._mv_padded, xp, y_int, split)

    # --- global reductions (the paper's MPI_Allreduce) -----------------------
    def dot(self, a: jax.Array, b: jax.Array) -> jax.Array:
        # single psum over the tuple of axes == ONE all-reduce (one barrier),
        # exactly like one MPI_Allreduce over the world communicator.
        return lax.psum(jnp.vdot(a, b), self.layout.reduce_axes)

    def dotn(self, *pairs) -> tuple:
        """Any number of dot products in ONE collective: stack the local
        partials, single psum, unstack.  The merged/pipelined Krylov
        variants ride their entire per-iteration scalar traffic (2, 3 or 9
        dots) through this — one all-reduce per iteration, verified on the
        compiled HLO by tests/test_hlo_analysis.py."""
        stacked = lax.psum(
            jnp.stack([jnp.vdot(a, b) for a, b in pairs]),
            self.layout.reduce_axes)
        return tuple(stacked[i] for i in range(len(pairs)))

    def dot2(self, a, b, c, d):
        """Two dot products in ONE collective (the paper fuses scalar pairs
        into a single MPI_Allreduce)."""
        return self.dotn((a, b), (c, d))

def make_layout(mesh: Mesh, dims_map: dict[str, str | None] | None = None) -> GridLayout:
    """Default layouts per mesh:

    * ('data','model')        -> x: model, y: data, z: unsplit  (single pod)
    * ('pod','data','model')  -> x: model, y: data, z: pod      (multi pod)
    * 1-D mesh ('cells',)     -> z: cells (the paper-faithful HPCCG layout)
    """
    names = mesh.axis_names
    if dims_map is not None:
        da = (dims_map.get("x"), dims_map.get("y"), dims_map.get("z"))
        return GridLayout(mesh=mesh, dim_axes=da)
    if names == ("cells",):
        return GridLayout(mesh=mesh, dim_axes=(None, None, "cells"))
    if names == ("data", "model"):
        return GridLayout(mesh=mesh, dim_axes=("model", "data", None))
    if names == ("pod", "data", "model"):
        return GridLayout(mesh=mesh, dim_axes=("model", "data", "pod"))
    raise ValueError(f"no default layout for mesh axes {names}")


def solve_shardmap(
    problem: HPCGProblem,
    method: str,
    mesh: Mesh,
    *,
    dims_map: dict[str, str | None] | None = None,
    tol: float = 1e-6,
    maxiter: int = 600,
    norm_ref: float | None = 1.0,   # paper: absolute ||r|| < eps (HPCCG criterion)
    matvec_padded: Callable | None = None,
    halo_mode: str = "auto",
    precond=None,
):
    """Build the shard_map-wrapped distributed solver; returns (fn, in_specs).

    ``fn(b, x0) -> SolveResult`` with b/x0 GLOBAL arrays sharded per layout.
    ``precond`` is a ``repro.precond.Preconditioner`` (or None); it is bound
    to the DistributedOp *inside* shard_map, so its applies see the local
    block and the mesh's halo machinery — same write-once rule as the
    solvers.  Only methods taking an ``M=`` kwarg (pcg/pbicgstab) accept it.
    """
    layout = make_layout(mesh, dims_map)
    solver = SOLVERS[method]
    stencil = problem.stencil

    def local_solve(b_loc: jax.Array, x0_loc: jax.Array) -> SolveResult:
        op = DistributedOp(stencil, layout, matvec_padded=matvec_padded,
                           halo_mode=halo_mode)
        kw = {} if precond is None else {"M": precond.bind(op)}
        return solver(
            op, b_loc, x0_loc, tol=tol, maxiter=maxiter,
            dot=op.dot, norm_ref=norm_ref, **kw,
        )

    spec = layout.spec()
    fn = shard_map(
        local_solve,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=SolveResult(x=spec, iters=P(), res_norm=P(), history=P()),
    )
    return fn, layout


#: per-method step-state layout for ``solve_step_shardmap``: (vector slot
#: names, scalar slot names), EXCLUDING the leading ``b``.  The paper's
#: methods share the historical (x, r, p, Ap) × (an, ad) layout (slots are
#: reused — e.g. the BiCGStab steps carry r-hat in the Ap slot); the
#: reduction-hiding variants carry their full recurrence state, which no
#: longer fits four vectors.  Drivers that lower a step generically
#: (launch/dryrun, tests) build their argument lists from this table.
_LEGACY_STEP_STATE = (("x", "r", "p", "Ap"), ("an", "ad"))
STEP_STATE: dict[str, tuple[tuple[str, ...], tuple[str, ...]]] = {
    "cg_merged": (("x", "r", "p", "s", "w"),
                  ("gamma", "delta", "gamma_prev", "alpha_prev")),
    "pcg_merged": (("x", "r", "u", "p", "s", "w"),
                   ("gamma", "delta", "rr", "gamma_prev", "alpha_prev")),
    "cg_pipe": (("x", "r", "w", "p", "s", "z"),
                ("gamma_prev", "alpha_prev", "rr")),
    "pcg_pipe": (("x", "r", "u", "w", "p", "s", "q", "z"),
                 ("gamma_prev", "alpha_prev", "rr")),
    "bicgstab_merged": (("x", "r", "w", "t", "p", "s", "z", "rhat"),
                        ("rho", "alpha", "rr")),
    "pbicgstab_merged": (("x", "r", "w", "t", "p", "s", "z", "rhat"),
                         ("rho", "alpha", "rr")),
}


def step_state_layout(method: str) -> tuple[tuple[str, ...], tuple[str, ...]]:
    """(vector slot names, scalar slot names) of a method's step state."""
    return STEP_STATE.get(method, _LEGACY_STEP_STATE)


def init_step_state(method: str, A, b, x0, M=None) -> tuple:
    """The full argument tuple ``(b, *vectors, *scalars)`` feeding one
    ``solve_step_shardmap`` iteration, matching the solver's loop carry at
    iteration 0 (so one step == one ``lax.while_loop`` body —
    tests/test_step_parity.py).  ``A`` is any LocalOp-protocol operator;
    ``M`` the bound preconditioner apply for the methods that take one.
    """
    apply_M = M if M is not None else (lambda v: v)
    r = b - A.matvec(x0)
    rr = jnp.vdot(r, r)
    zero_v = jnp.zeros_like(b)
    zero = jnp.zeros((), b.dtype)
    inf = jnp.asarray(jnp.inf, b.dtype)
    one = jnp.asarray(1.0, b.dtype)
    if method == "cg_merged":
        w = A.matvec(r)
        return (b, x0, r, zero_v, zero_v, w,
                rr, jnp.vdot(w, r), inf, one)
    if method == "pcg_merged":
        u = apply_M(r)
        w = A.matvec(u)
        return (b, x0, r, u, zero_v, zero_v, w,
                jnp.vdot(r, u), jnp.vdot(w, u), rr, inf, one)
    if method == "cg_pipe":
        w = A.matvec(r)
        return (b, x0, r, w, zero_v, zero_v, zero_v, inf, one, rr)
    if method == "pcg_pipe":
        u = apply_M(r)
        w = A.matvec(u)
        return (b, x0, r, u, w, zero_v, zero_v, zero_v, zero_v,
                inf, one, rr)
    if method in ("bicgstab_merged", "pbicgstab_merged"):
        mv = (A.matvec if method == "bicgstab_merged"
              else (lambda v: A.matvec(apply_M(v))))
        y0 = x0 if method == "bicgstab_merged" else zero_v
        w = mv(r)
        t = mv(w)
        rho = jnp.vdot(r, r)               # r̂ = r0
        alpha = rho / jnp.vdot(r, w)
        return (b, y0, r, w, t, r, w, t, r, rho, alpha, rho)
    # --- legacy (x, r, p, Ap) × (an, ad) layout ------------------------------
    if method == "cg_nb":
        Ap = A.matvec(r)
        return (b, x0, r, r, Ap, rr, jnp.vdot(Ap, r))
    if method == "bicgstab_b1":
        rhat = r / jnp.sqrt(rr)
        return (b, x0, r, r, rhat, jnp.vdot(r, rhat), zero)
    # cg / pcg (p slot = z0; with M=None: z == r, rz == rr), the BiCGStab
    # pair (Ap slot = r-hat, an slot = rho) and the stationary methods all
    # start from the same (r, r, r, rr) filling.
    return (b, x0, r, r, r, rr, zero)


def solve_step_shardmap(
    problem: HPCGProblem,
    method: str,
    mesh: Mesh,
    *,
    dims_map: dict[str, str | None] | None = None,
    matvec_padded: Callable | None = None,
    halo_mode: str = "auto",
    precond=None,
):
    """One *iteration* of the solver as a standalone shard_mapped function.

    Used by the dry-run/roofline: lowering a single iteration makes
    ``cost_analysis`` exact (no while-loop trip-count ambiguity) and exposes
    the per-iteration collective schedule for the overlap analysis.  The
    state signature is ``(b, *vectors, *scalars)`` per
    :func:`step_state_layout` (method-dependent for the reduction-hiding
    variants); :func:`init_step_state` builds a matching initial tuple.
    """
    layout = make_layout(mesh, dims_map)
    stencil = problem.stencil
    vec_names, scal_names = step_state_layout(method)

    def local_step_generic(b_loc, *state):
        op = DistributedOp(stencil, layout, matvec_padded=matvec_padded,
                           halo_mode=halo_mode)
        M = precond.bind(op) if precond is not None else (lambda v: v)
        if method == "cg_merged":
            x, r, p, s, w, gamma, delta, gamma_prev, alpha_prev = state
            alpha, beta = _cg_merged_scalars(gamma, delta, gamma_prev,
                                             alpha_prev)
            p = r + beta * p
            s = w + beta * s
            x = x + alpha * p
            r = r - alpha * s
            w = op.matvec(r)
            gamma_new, delta_new = op.dotn((r, r), (w, r))  # ONE all-reduce
            return (x, r, p, s, w, gamma_new, delta_new, gamma, alpha)
        elif method == "pcg_merged":
            (x, r, u, p, s, w, gamma, delta, rr,
             gamma_prev, alpha_prev) = state
            alpha, beta = _cg_merged_scalars(gamma, delta, gamma_prev,
                                             alpha_prev)
            p = u + beta * p
            s = w + beta * s
            x = x + alpha * p
            r = r - alpha * s
            u = M(r)
            w = op.matvec(u)
            gamma_new, delta_new, rr_new = op.dotn((r, u), (w, u), (r, r))
            return (x, r, u, p, s, w, gamma_new, delta_new, rr_new,
                    gamma, alpha)
        elif method == "cg_pipe":
            x, r, w, p, s, z, gamma_prev, alpha_prev, rr = state
            gamma, delta = op.dotn((r, r), (w, r))        # issued...
            n = lax.optimization_barrier(op.matvec(w))    # ...hidden here
            alpha, beta = _cg_merged_scalars(gamma, delta, gamma_prev,
                                             alpha_prev)
            z = n + beta * z
            s = w + beta * s
            p = r + beta * p
            x = x + alpha * p
            r = r - alpha * s
            w = w - alpha * z
            return (x, r, w, p, s, z, gamma, alpha, gamma)
        elif method == "pcg_pipe":
            x, r, u, w, p, s, q, z, gamma_prev, alpha_prev, rr = state
            gamma, delta, rr_new = op.dotn((r, u), (w, u), (r, r))
            m = M(w)
            n = lax.optimization_barrier(op.matvec(m))
            alpha, beta = _cg_merged_scalars(gamma, delta, gamma_prev,
                                             alpha_prev)
            z = n + beta * z
            q = m + beta * q
            s = w + beta * s
            p = u + beta * p
            x = x + alpha * p
            r = r - alpha * s
            u = u - alpha * q
            w = w - alpha * z
            return (x, r, u, w, p, s, q, z, gamma, alpha, rr_new)
        elif method in ("bicgstab_merged", "pbicgstab_merged"):
            mv = (op.matvec if method == "bicgstab_merged"
                  else (lambda v: op.matvec(M(v))))
            y, r, w, t, p, s, z, rhat, rho, alpha, rr = state
            q = r - alpha * s
            yv = w - alpha * z
            v = lax.optimization_barrier(mv(z))
            (qy, yy, qq, rhq, rhy, rht, rhv, rhz, rhs) = op.dotn(
                (q, yv), (yv, yv), (q, q), (rhat, q), (rhat, yv),
                (rhat, t), (rhat, v), (rhat, z), (rhat, s))
            omega = qy / yy
            y = y + alpha * p + omega * q
            r = q - omega * yv
            rr_new = jnp.maximum(
                qq - 2.0 * omega * qy + omega * omega * yy, 0.0)
            rho_new = rhq - omega * rhy
            beta = (rho_new / rho) * (alpha / omega)
            w = yv - omega * (t - alpha * v)
            t = mv(w)
            rhw = rhy - omega * (rht - alpha * rhv)
            alpha_new = rho_new / (rhw + beta * (rhs - omega * rhz))
            p = r + beta * (p - omega * s)
            s = w + beta * (s - omega * z)
            z = t + beta * (z - omega * v)
            return (y, r, w, t, p, s, z, rhat, rho_new, alpha_new, rr_new)
        x_loc, r_loc, p_loc, Ap_loc, an, ad = state
        if method == "cg":
            Ap = op.matvec(p_loc)
            pAp = op.dot(p_loc, Ap)
            alpha = an / pAp
            x = x_loc + alpha * p_loc
            r = r_loc - alpha * Ap
            rr = op.dot(r, r)
            beta = rr / an
            p = r + beta * p_loc
            return x, r, p, Ap, rr, pAp
        elif method == "cg_nb":
            alpha = an / ad
            r = r_loc - alpha * Ap_loc
            an_new = op.dot(r, r)
            Ar = op.matvec(r)
            beta = an_new / an
            Ap = Ar + beta * Ap_loc
            p = r + beta * p_loc
            ad_new = op.dot(Ap, p)
            x = x_loc + alpha * p_loc
            return x, r, p, Ap, an_new, ad_new
        elif method == "jacobi":
            x = x_loc + r_loc / op.diag
            r = b_loc - op.matvec(x)
            rr = op.dot(r, r)
            return x, r, p_loc, Ap_loc, rr, ad
        elif method == "pcg":
            # p slot = p, Ap slot carries z; an slot = rz (with M=None the
            # state degenerates to cg's: z == r, rz == rr)
            Ap = op.matvec(p_loc)
            pAp = op.dot(p_loc, Ap)         # blocking
            alpha = an / pAp
            x = x_loc + alpha * p_loc
            r = r_loc - alpha * Ap
            z = M(r)
            rz, rr = op.dot2(r, z, r, r)
            beta = rz / an
            p = z + beta * p_loc
            return x, r, p, z, rz, rr
        elif method == "bicgstab":
            # one classical BiCGStab iteration (3 blocking reductions);
            # the Ap slot carries r-hat for the step driver.
            rhat = Ap_loc
            v = op.matvec(p_loc)
            rhat_v = op.dot(rhat, v)            # barrier 1
            alpha = an / rhat_v                 # an slot = rho
            s = r_loc - alpha * v
            t = op.matvec(s)
            ts, tt = op.dot2(t, s, t, t)        # barrier 2
            omega = ts / tt
            x = x_loc + alpha * p_loc + omega * s
            r = s - omega * t
            rho_new, rr = op.dot2(rhat, r, r, r)  # barrier 3
            beta = (rho_new / an) * (alpha / omega)
            p = r + beta * (p_loc - omega * v)
            return x, r, p, rhat, rho_new, rr
        elif method == "pbicgstab":
            # right-preconditioned BiCGStab; Ap slot carries r-hat
            rhat = Ap_loc
            phat = M(p_loc)
            v = op.matvec(phat)
            rhat_v = op.dot(rhat, v)            # barrier 1
            alpha = an / rhat_v                 # an slot = rho
            s = r_loc - alpha * v
            shat = M(s)
            t = op.matvec(shat)
            ts, tt = op.dot2(t, s, t, t)        # barrier 2
            omega = ts / tt
            x = x_loc + alpha * phat + omega * shat
            r = s - omega * t
            rho_new, rr = op.dot2(rhat, r, r, r)  # barrier 3
            beta = (rho_new / an) * (alpha / omega)
            p = r + beta * (p_loc - omega * v)
            return x, r, p, rhat, rho_new, rr
        elif method == "bicgstab_b1":
            rhat = Ap_loc  # slot reuse for the step driver
            Ap = op.matvec(p_loc)
            adj = op.dot(Ap, rhat)          # the ONE blocking reduction
            alpha = an / adj
            s = r_loc - alpha * Ap
            As = op.matvec(s)
            ts, tt = op.dot2(As, s, As, As)
            # keep the overlap payloads un-fused from their reduction
            # consumers (see solvers.bicgstab_b1)
            x_half = lax.optimization_barrier(x_loc + alpha * p_loc)
            omega = ts / tt
            x = x_half + omega * s
            r = s - omega * As
            an_new, brr = op.dot2(r, rhat, r, r)
            p_half = lax.optimization_barrier(p_loc - omega * Ap)
            p = r + (an_new / (adj * omega)) * p_half
            return x, r, p, Ap, an_new, brr
        elif method == "gauss_seidel":
            from repro.core.solvers import _plane_sweep
            x = _plane_sweep(op, b_loc, x_loc, forward=True)
            x = _plane_sweep(op, b_loc, x, forward=False)  # backward sweep
            r = b_loc - op.matvec(x)                       # of the FORWARD result
            rr = op.dot(r, r)
            return x, r, p_loc, Ap_loc, rr, ad
        elif method == "gauss_seidel_rb":
            from repro.core.solvers import _colour_mask, _rb_half_sweep
            red = _colour_mask(x_loc.shape, 0)
            black = _colour_mask(x_loc.shape, 1)
            x = _rb_half_sweep(op, b_loc, x_loc, red)
            x = _rb_half_sweep(op, b_loc, x, black)
            x = _rb_half_sweep(op, b_loc, x, black)
            x = _rb_half_sweep(op, b_loc, x, red)
            r = b_loc - op.matvec(x)
            rr = op.dot(r, r)
            return x, r, p_loc, Ap_loc, rr, ad
        raise ValueError(f"unknown method {method}")

    spec = layout.spec()
    fn = shard_map(
        local_step_generic,
        mesh=mesh,
        in_specs=(spec,) + (spec,) * len(vec_names) + (P(),) * len(scal_names),
        out_specs=(spec,) * len(vec_names) + (P(),) * len(scal_names),
    )
    return fn, layout
