"""Distributed solver layer: the paper's MPI decomposition on a TPU mesh.

The paper decomposes the 3-D grid explicitly across MPI ranks (HPCCG splits
only the last dimension) and exchanges boundary planes point-to-point
(``exchange_externals``, Code 2).  Here the decomposition is expressed as a
``GridLayout`` mapping grid dims -> mesh axes, halos travel over
``lax.ppermute`` (nearest-neighbour ICI traffic), and global reductions are
``lax.psum``.  Everything runs inside one ``jax.shard_map``-wrapped solver so
the entire iteration is a single compiled program — the analogue of the
paper's zero-sequential-parts requirement (HDOT).

Faithful mode: 1-D decomposition of z over one flattened axis (the paper's
HPCCG layout).  Beyond-paper mode: full 3-D decomposition (x->model, y->data,
z->pod on the production mesh), which reduces halo bytes per device from
``2·nx·ny`` to the block's surface — see EXPERIMENTS.md §Perf.

Dimension-ordered halo exchange: each dim's slabs span the *padded* extent of
the other dims, so later exchanges forward previously received halos and the
27-pt stencil's edge/corner neighbours arrive correctly with only 6 ppermutes.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map
from repro.core.methods import Ops, get_method, run_method
from repro.core.operators import Stencil, interior_matvec, shell_assemble
from repro.core.problems import HPCGProblem
from repro.core.solvers import SolveResult

#: halo-exchange strategies of the distributed operator ("auto" resolves to
#: "concat" here; repro.api.backend upgrades it to "overlap" where safe)
HALO_MODES = ("auto", "scatter", "concat", "overlap")


@dataclasses.dataclass(frozen=True)
class GridLayout:
    """Maps grid dims (x, y, z) to mesh axis names (or None = not split)."""

    mesh: Mesh
    dim_axes: tuple[str | None, str | None, str | None]

    def __post_init__(self):
        for a in self.dim_axes:
            if a is not None and a not in self.mesh.axis_names:
                raise ValueError(f"axis {a!r} not in mesh {self.mesh.axis_names}")

    @property
    def reduce_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.dim_axes if a is not None)

    def spec(self) -> P:
        return P(*self.dim_axes)

    def axis_size(self, d: int) -> int:
        a = self.dim_axes[d]
        return 1 if a is None else self.mesh.shape[a]

    def local_shape(self, global_shape: tuple[int, int, int]) -> tuple[int, int, int]:
        out = []
        for d, g in enumerate(global_shape):
            n = self.axis_size(d)
            if g % n:
                raise ValueError(f"grid dim {d} ({g}) not divisible by mesh axis ({n})")
            out.append(g // n)
        return tuple(out)


class DistributedOp:
    """Stencil operator on a local block inside ``shard_map``.

    Protocol-compatible with ``solvers.LocalOp``: the solver code is identical
    in both worlds (the paper's write-once/parallelise-underneath goal).
    """

    def __init__(self, stencil: Stencil, layout: GridLayout,
                 matvec_padded: Callable | None = None,
                 halo_mode: str = "auto"):
        self.stencil = stencil
        self.layout = layout
        # measured per-stencil bests (EXPERIMENTS.md §Perf): the slice-add
        # stencil fuses well at 7pt; the conv formulation halves traffic at
        # 27pt; concat halos beat pad+scatter in both conv cases
        if matvec_padded is None:
            matvec_padded = (stencil.conv_matvec_padded()
                             if stencil.npoint >= 27 else stencil.matvec_padded)
        self._mv_padded = matvec_padded
        if halo_mode not in HALO_MODES:
            raise ValueError(
                f"unknown halo_mode {halo_mode!r}; options: {HALO_MODES}")
        if halo_mode == "auto":
            halo_mode = "concat"
        self.halo_mode = halo_mode

    @property
    def diag(self) -> float:
        return self.stencil.diag

    @property
    def split_dims(self) -> tuple[int, ...]:
        """Grid dims actually decomposed (mapped to a mesh axis of size > 1)."""
        return tuple(
            d for d, a in enumerate(self.layout.dim_axes)
            if a is not None and self.layout.mesh.shape[a] > 1)

    # --- halo exchange (the paper's exchange_externals) ----------------------
    def pad_exchange(self, x: jax.Array) -> jax.Array:
        if self.halo_mode == "scatter":
            return self._pad_exchange_scatter(x)
        return self._pad_exchange_concat(x)

    def _pad_exchange_scatter(self, x: jax.Array) -> jax.Array:
        """Baseline: zero-pad then scatter received planes into the halos.

        Costs a full-array pad copy plus per-dim ``.at[].set`` updates —
        measured at ~8r extra HBM traffic per matvec (EXPERIMENTS.md §Perf).
        """
        xp = jnp.pad(x, 1)
        for d, axis in enumerate(self.layout.dim_axes):
            if axis is None:
                continue
            n = self.layout.mesh.shape[axis]
            if n == 1:
                continue
            sl_lo = [slice(None)] * 3
            sl_hi = [slice(None)] * 3
            sl_lo[d] = slice(1, 2)        # my bottom interior plane
            sl_hi[d] = slice(-2, -1)      # my top interior plane
            up = lax.ppermute(                       # i -> i+1: fills my LOWER halo
                xp[tuple(sl_hi)], axis, [(i, i + 1) for i in range(n - 1)]
            )
            down = lax.ppermute(                     # i -> i-1: fills my UPPER halo
                xp[tuple(sl_lo)], axis, [(i + 1, i) for i in range(n - 1)]
            )
            halo_lo = [slice(None)] * 3
            halo_hi = [slice(None)] * 3
            halo_lo[d] = slice(0, 1)
            halo_hi[d] = slice(xp.shape[d] - 1, xp.shape[d])
            xp = xp.at[tuple(halo_lo)].set(up)
            xp = xp.at[tuple(halo_hi)].set(down)
        return xp

    def _pad_exchange_concat(self, x: jax.Array) -> jax.Array:
        """Optimised: build the padded array by per-dim concatenation.

        The received (or zero) halo planes are concatenated onto the block
        dim by dim — one materialisation per dim instead of pad + scatter
        pairs, and XLA folds the nested concats into a single copy.  Later
        dims' slabs span the already-extended extents, so 27-pt corner
        neighbours arrive exactly as in the scatter form.
        """
        xp = x
        for d in range(3):
            axis = self.layout.dim_axes[d]
            shape = list(xp.shape)
            shape[d] = 1
            zero = jnp.zeros(shape, xp.dtype)
            n = self.layout.mesh.shape[axis] if axis is not None else 1
            if axis is None or n == 1:
                lo = hi = zero
            else:
                sl_lo = [slice(None)] * 3
                sl_hi = [slice(None)] * 3
                sl_lo[d] = slice(0, 1)
                sl_hi[d] = slice(xp.shape[d] - 1, xp.shape[d])
                lo = lax.ppermute(xp[tuple(sl_hi)], axis,
                                  [(i, i + 1) for i in range(n - 1)])
                hi = lax.ppermute(xp[tuple(sl_lo)], axis,
                                  [(i + 1, i) for i in range(n - 1)])
            xp = jnp.concatenate([lo, xp, hi], axis=d)
        return xp

    def matvec(self, x: jax.Array) -> jax.Array:
        if self.halo_mode == "overlap":
            return self._matvec_overlap(x)
        return self._mv_padded(self.pad_exchange(x))

    def matvec_local(self, x: jax.Array) -> jax.Array:
        """Zero-halo apply on the local block ONLY — no ppermutes.

        The block-diagonal operator of the block-Jacobi preconditioner
        (two-stage multisplitting): decomposed faces are treated as
        physical boundary, so the apply is communication-free.
        """
        return self._mv_padded(jnp.pad(x, 1))

    def _matvec_overlap(self, x: jax.Array) -> jax.Array:
        """Overlapped halo-exchange SpMV (the paper's task-based split).

        The ppermutes are issued first; the interior — every output cell at
        distance >= 1 from a decomposed face, i.e. almost the whole block —
        depends only on ``x``, so the latency-hiding scheduler can run it
        while the collectives are in flight.  Only the one-cell boundary
        shell consumes the received planes.  The ``optimization_barrier``
        pins the interior as its own schedulable task (the same idiom that
        keeps bicgstab_b1's reduction overlap windows from fusing away).
        Solver results are bit-for-bit identical to the concat/scatter
        modes (tests/test_halo_overlap.py).
        """
        split = self.split_dims
        if not split or min(x.shape[d] for d in split) < 2:
            # nothing decomposed (or degenerate 1-plane blocks: no interior)
            return self._mv_padded(self._pad_exchange_concat(x))
        xp = self._pad_exchange_concat(x)
        y_int = lax.optimization_barrier(
            interior_matvec(self._mv_padded, x, split))
        return shell_assemble(self._mv_padded, xp, y_int, split)

    # --- global reductions (the paper's MPI_Allreduce) -----------------------
    def dot(self, a: jax.Array, b: jax.Array) -> jax.Array:
        # single psum over the tuple of axes == ONE all-reduce (one barrier),
        # exactly like one MPI_Allreduce over the world communicator.
        return lax.psum(jnp.vdot(a, b), self.layout.reduce_axes)

    def dotn(self, *pairs) -> tuple:
        """Any number of dot products in ONE collective: stack the local
        partials, single psum, unstack.  The merged/pipelined Krylov
        variants ride their entire per-iteration scalar traffic (2, 3 or 9
        dots) through this — one all-reduce per iteration, verified on the
        compiled HLO by tests/test_hlo_analysis.py."""
        stacked = lax.psum(
            jnp.stack([jnp.vdot(a, b) for a, b in pairs]),
            self.layout.reduce_axes)
        return tuple(stacked[i] for i in range(len(pairs)))

    def dot2(self, a, b, c, d):
        """Two dot products in ONE collective (the paper fuses scalar pairs
        into a single MPI_Allreduce)."""
        return self.dotn((a, b), (c, d))

    def sum_partials(self, *vals) -> tuple:
        """Globally reduce already-computed local partial scalars in ONE
        collective — the fused Pallas kernels' dot partials (accumulated
        per block inside the kernel) ride this to become global dots."""
        stacked = lax.psum(jnp.stack(vals), self.layout.reduce_axes)
        return tuple(stacked[i] for i in range(len(vals)))

def make_layout(mesh: Mesh, dims_map: dict[str, str | None] | None = None) -> GridLayout:
    """Default layouts per mesh:

    * ('data','model')        -> x: model, y: data, z: unsplit  (single pod)
    * ('pod','data','model')  -> x: model, y: data, z: pod      (multi pod)
    * 1-D mesh ('cells',)     -> z: cells (the paper-faithful HPCCG layout)
    """
    names = mesh.axis_names
    if dims_map is not None:
        da = (dims_map.get("x"), dims_map.get("y"), dims_map.get("z"))
        return GridLayout(mesh=mesh, dim_axes=da)
    if names == ("cells",):
        return GridLayout(mesh=mesh, dim_axes=(None, None, "cells"))
    if names == ("data", "model"):
        return GridLayout(mesh=mesh, dim_axes=("model", "data", None))
    if names == ("pod", "data", "model"):
        return GridLayout(mesh=mesh, dim_axes=("model", "data", "pod"))
    raise ValueError(f"no default layout for mesh axes {names}")


def _local_ops(stencil, layout, b_loc, *, matvec_padded, halo_mode,
               precond, norm_ref, pallas_fused):
    """Build the DistributedOp (optionally Pallas-wrapped) + Ops context for
    one shard_map body — shared by solve_shardmap and solve_step_shardmap."""
    op = DistributedOp(stencil, layout, matvec_padded=matvec_padded,
                       halo_mode=halo_mode)
    if pallas_fused:
        from repro.kernels.pallas_op import PallasOp
        op = PallasOp(op)
    M = precond.bind(op) if precond is not None else None
    return Ops(op, b_loc, M=M, norm_ref=norm_ref)


def _check_method(method: str, precond, pallas_fused: bool,
                  matvec_padded=None):
    """Resolve + validate a method name for the distributed drivers.

    Raises a ``ValueError`` listing the known methods for an unregistered
    name (previously ``solve_step_shardmap`` fell through silently until
    trace time), and rejects precond/fused requests the definition does not
    support.
    """
    from repro.core.methods import METHODS
    mdef = get_method(method)          # ValueError w/ known-method list
    if precond is not None and not mdef.accepts_precond:
        raise ValueError(
            f"method {method!r} takes no preconditioner; use one of "
            f"{sorted(n for n, m in METHODS.items() if m.accepts_precond)}")
    if pallas_fused and not mdef.has_fused_body:
        raise ValueError(
            f"method {method!r} declares no fused kernels; fused methods: "
            f"{sorted(n for n, m in METHODS.items() if m.has_fused_body)}")
    if pallas_fused and matvec_padded is not None:
        # the fused body's SpMVs run the built-in Pallas stencil kernel —
        # a custom matvec_padded would apply only to the (unfused) initial
        # residual, i.e. a solve against two different operators
        raise ValueError(
            "pallas_fused=True is incompatible with a custom matvec_padded "
            "(the fused kernels implement the built-in stencil apply)")
    return mdef


def solve_shardmap(
    problem: HPCGProblem,
    method: str,
    mesh: Mesh,
    *,
    dims_map: dict[str, str | None] | None = None,
    tol: float = 1e-6,
    maxiter: int = 600,
    norm_ref: float | None = 1.0,   # paper: absolute ||r|| < eps (HPCCG criterion)
    matvec_padded: Callable | None = None,
    halo_mode: str = "auto",
    precond=None,
    pallas_fused: bool = False,
    telemetry: int = 0,
    guard_spec=None,
    refresh_every: int = 0,
):
    """Build the shard_map-wrapped distributed solver; returns (fn, in_specs).

    ``fn(b, x0) -> SolveResult`` with b/x0 GLOBAL arrays sharded per layout.
    The solve is the method's ``MethodDef`` run by the generic
    ``run_method`` driver over a ``DistributedOp`` — the identical
    definition the local path executes.  ``precond`` is a
    ``repro.precond.Preconditioner`` (or None); it is bound to the operator
    *inside* shard_map, so its applies see the local block and the mesh's
    halo machinery.  ``pallas_fused=True`` wraps the operator in a
    ``PallasOp`` and runs the method's fused-kernel body (methods that
    declare one, e.g. ``cg_merged``) — the fused kernels execute inside
    the shard_map body, halos and psums included.  ``telemetry=N``
    (repro.obs) threads the driver's bounded scalar-history buffer through
    the loop carry; the recorded scalars are post-psum (replicated), so the
    buffer rides an unsharded ``P()`` out_spec.  ``telemetry=0`` keeps the
    out-spec tree (and the lowered HLO) bit-for-bit the pre-telemetry one.

    Resilience (repro.resilience): ``guard_spec``/``refresh_every`` are
    forwarded to the driver.  Guards compare post-psum (replicated)
    scalars, so every shard exits the while-loop on the same iteration
    with no extra collectives; the residual-replacement ``lax.cond`` body
    re-runs the method's own halo exchange + stacked psum, so both
    branches stay replication-consistent under shard_map.  The typed
    ``status`` scalar is replicated and rides a ``P()`` out_spec.
    """
    mdef = _check_method(method, precond, pallas_fused, matvec_padded)
    layout = make_layout(mesh, dims_map)
    stencil = problem.stencil

    def local_solve(b_loc: jax.Array, x0_loc: jax.Array) -> SolveResult:
        ops = _local_ops(stencil, layout, b_loc, matvec_padded=matvec_padded,
                         halo_mode=halo_mode, precond=precond,
                         norm_ref=norm_ref, pallas_fused=pallas_fused)
        return run_method(mdef, ops, x0_loc, tol=tol, maxiter=maxiter,
                          fused=pallas_fused, telemetry=telemetry,
                          guard_spec=guard_spec, refresh_every=refresh_every)

    spec = layout.spec()
    fn = shard_map(
        local_solve,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=SolveResult(x=spec, iters=P(), res_norm=P(), history=P(),
                              telemetry=P() if telemetry else None,
                              status=P()),
    )
    return fn, layout


def step_state_layout(method: str) -> tuple[tuple[str, ...], tuple[str, ...]]:
    """(vector slot names, scalar slot names) of a method's step state —
    derived mechanically from its ``MethodDef`` (the hand-written
    ``STEP_STATE`` table this replaces is gone; tests assert the derived
    layouts match the documented ones)."""
    mdef = get_method(method)
    return mdef.vectors, mdef.scalars


def init_step_state(method: str, A, b, x0, M=None) -> tuple:
    """The full argument tuple ``(b, *vectors, *scalars)`` feeding one
    ``solve_step_shardmap`` iteration, matching the solver's loop carry at
    iteration 0 (so one step == one ``lax.while_loop`` body —
    tests/test_step_parity.py).  ``A`` is any LocalOp-protocol operator;
    ``M`` the bound preconditioner apply for the methods that take one.
    Derived mechanically from the method's ``MethodDef.init``.
    """
    mdef = get_method(method)
    ops = Ops(A, b, M=M, norm_ref=1.0)
    return (b, *mdef.init(ops, x0))


def solve_step_shardmap(
    problem: HPCGProblem,
    method: str,
    mesh: Mesh,
    *,
    dims_map: dict[str, str | None] | None = None,
    matvec_padded: Callable | None = None,
    halo_mode: str = "auto",
    precond=None,
    pallas_fused: bool = False,
):
    """One *iteration* of the solver as a standalone shard_mapped function.

    Used by the dry-run/roofline: lowering a single iteration makes
    ``cost_analysis`` exact (no while-loop trip-count ambiguity) and exposes
    the per-iteration collective schedule for the overlap analysis.  The
    body IS the method's ``MethodDef.step`` (no per-method dispatch here);
    the state signature is ``(b, *vectors, *scalars)`` per
    :func:`step_state_layout` and :func:`init_step_state` builds a matching
    initial tuple.  Unknown method names raise a ``ValueError`` listing the
    registry (they previously fell through to a trace-time error).
    ``pallas_fused=True`` lowers the fused-kernel body instead.
    """
    mdef = _check_method(method, precond, pallas_fused, matvec_padded)
    layout = make_layout(mesh, dims_map)
    stencil = problem.stencil
    step = mdef.fused_step if pallas_fused else mdef.step

    def local_step(b_loc, *state):
        ops = _local_ops(stencil, layout, b_loc, matvec_padded=matvec_padded,
                         halo_mode=halo_mode, precond=precond,
                         norm_ref=1.0, pallas_fused=pallas_fused)
        return tuple(step(ops, state))

    spec = layout.spec()
    nvec, nscal = len(mdef.vectors), len(mdef.scalars)
    fn = shard_map(
        local_step,
        mesh=mesh,
        in_specs=(spec,) * (1 + nvec) + (P(),) * nscal,
        out_specs=(spec,) * nvec + (P(),) * nscal,
    )
    return fn, layout
