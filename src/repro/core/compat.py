"""Version compatibility for the JAX surface this repo uses.

The code targets the modern spellings (``jax.shard_map``, ``jax.make_mesh``
with ``axis_types``); this shim lets the same source run on older jaxlibs
(0.4.x) where shard_map still lives in ``jax.experimental`` and meshes have
no axis types.  Everything mesh/shard_map-shaped goes through here.
"""

from __future__ import annotations

import inspect
from typing import Callable, Sequence

import jax

_HAS_AXIS_TYPES = "axis_types" in inspect.signature(jax.make_mesh).parameters


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where supported."""
    if _HAS_AXIS_TYPES:
        from jax.sharding import AxisType
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=(AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def pvary(x, axis_names):
    """``lax.pvary`` where it exists; identity on older jax (the varying-axes
    annotation only matters for shard_map's rep checking, which the old-jax
    shim disables via ``check_rep=False``)."""
    from jax import lax
    if hasattr(lax, "pvary"):
        return lax.pvary(x, axis_names)
    return x


def _ensure_optimization_barrier_batchable() -> None:
    """Older jax has no batching rule for ``lax.optimization_barrier``; the
    barrier is batch-transparent, so register the identity rule (needed to
    vmap bicgstab_b1 for the batched multi-RHS path).  Checked against the
    batcher registry directly — no traced probe, so importing this module
    never initialises the device backend."""
    try:
        from jax._src.lax.lax import optimization_barrier_p
        from jax.interpreters import batching
    except ImportError:      # newer jax: internals moved AND rule exists
        return
    if optimization_barrier_p not in batching.primitive_batchers:
        def _batcher(args, dims):
            return optimization_barrier_p.bind(*args), dims

        batching.primitive_batchers[optimization_barrier_p] = _batcher


_ensure_optimization_barrier_batchable()


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict (older jax returns a
    one-element list of per-program dicts)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


if hasattr(jax, "shard_map"):
    def shard_map(f: Callable, *, mesh, in_specs, out_specs) -> Callable:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
else:  # jax < 0.6: experimental module; check_rep chokes on psum-in-loop
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f: Callable, *, mesh, in_specs, out_specs) -> Callable:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
