"""Single-source method definitions: each iterative method defined ONCE.

This module is the paper's central design point made literal.  The paper's
claim is that the *same* numerical method can be re-expressed across parallel
execution models (MPI-only, fork-join, task-based) and compared fairly; the
repo's analogue is that ONE :class:`MethodDef` per algorithm drives

  * the local single-device ``solve()`` path          (``LocalOp``),
  * the whole-solve distributed path                  (``DistributedOp``
    inside ``shard_map`` — ``core.distributed.solve_shardmap``),
  * the one-iteration analysis hook                   (``solve_step_shardmap``,
    what the dry-run/roofline lowers for exact cost analysis), and
  * the fused Pallas execution of the methods that declare fused kernels
    (``kernels.pallas_op.PallasOp``) — single-device AND inside shard_map.

A ``MethodDef`` is three pure functions plus a declared state layout:

  ``init(ops, x0) -> state``      the loop carry at iteration 0
  ``step(ops, state) -> state``   ONE iteration (== one while_loop body)
  ``finalize(ops, x0, state)``    exit correction (optional; default state[0])

``state`` is a flat tuple: the declared ``vectors`` (local-grid arrays, the
iterate first) followed by the declared ``scalars``.  ``res_scalar`` names the
scalar slot carrying the method's squared-residual estimate — the generic
driver's convergence check, residual history and reported ``res_norm`` all
read exactly that slot, which is what keeps iteration counts comparable
across methods and backends.

``ops`` is an :class:`Ops` context: the operator ``A`` (anything satisfying
the ``LocalOp`` protocol — ``matvec``/``pad_exchange``/``diag``/``dotn``),
the right-hand side ``b``, the bound preconditioner apply ``M`` (identity
when absent), and the reduction hooks ``dot``/``dot2``/``dotn``.  On a
single device the reductions are plain ``jnp.vdot``; inside ``shard_map``
they are the layout's ``psum`` — the method definition cannot tell, which is
the whole point (the paper's write-once/parallelise-underneath rule).

Barrier structure reproduced from the paper (§3.1, Fig. 1):

  * ``cg``            — 2 blocking reductions / iteration.
  * ``cg_nb``         — Alg. 1: the SpMV is applied to ``r`` so ``A·p`` becomes a
                        vector update; both reductions leave the critical path
                        (the ``r·r`` reduction overlaps the SpMV, the ``Ap·p``
                        reduction overlaps the lagged ``x`` update).  NOTE:
                        Alg. 1 line 9 is implemented with the sign convention
                        that keeps ``x_j = x_{j-1} + α_{j-1} p_{j-1}`` (the
                        printed minus sign is a typo — with it the recursion
                        contradicts line 4).  Equivalence with classical CG is
                        asserted by tests/test_solvers.py.
  * ``bicgstab``      — 3 blocking reductions / iteration.
  * ``bicgstab_b1``   — Alg. 2: ω's reductions overlap the ``x_{j+1/2}`` update,
                        the ``α_n``/``β`` reductions overlap the ``p_{j+1/2}``
                        update; one blocking reduction (``α_d``) remains.
                        Includes the restart procedure (lines 13-15).
  * ``jacobi``        — 1 reduction (the residual norm).
  * ``gauss_seidel``  — the paper's *relaxed* tasked GS adapted to TPU:
                        GS-fresh across z-planes inside a block, stale across
                        blocks (the role the benign data races play in the
                        paper's Code 4).
  * ``gauss_seidel_rb`` — red-black coloured symmetric GS (§3.4).

Beyond the paper: the preconditioned forms (``pcg``/``pbicgstab`` + merged/
pipelined composites, PR 3) and the reduction-hiding restructurings
(``*_merged``/``*_pipe``, PR 4 — Chronopoulos–Gear, Cools–Vanroose,
Ghysels–Vanroose).  Numerical caveat: the merged/pipelined forms replace
``p·Ap`` (and, for BiCGStab, ‖r‖²) with recurrences; rounding makes them
drift from the classics by O(ε·κ) per iteration and puts an O(ε·κ·‖b‖)
floor on the attainable residual — solve in f64 (the paper's setting) for
tight absolute tolerances.  The reported ``res_norm`` is each method's own
estimate, like the classics'.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


#: typed loop-exit statuses (``SolveResult.status``, repro.resilience).
#: Always computed — classification is a handful of scalar ``where``s on
#: values the loop already carries, so it adds no collectives and no cost.
STATUS_CONVERGED = 0    # res_scalar dropped below (tol * norm_ref)^2
STATUS_MAXITER = 1      # iteration budget exhausted, residual still finite
STATUS_BREAKDOWN = 2    # NaN scalars or a method guard fired (rho/omega
#                         underflow, negative curvature on a non-SPD operator)
STATUS_DIVERGED = 3     # residual blew past divergence_factor^2 * ||r0||^2
STATUS_STAGNATED = 4    # no relative progress for stagnation_window iters

STATUS_NAMES = ("converged", "maxiter", "breakdown", "diverged", "stagnated")


def status_name(code) -> str:
    """Human name for a ``SolveResult.status`` code (host-side helper)."""
    return STATUS_NAMES[int(code)]


@dataclasses.dataclass(frozen=True)
class GuardSpec:
    """Breakdown-guard thresholds for the resilient driver (opt-in).

    Every check reads scalars the while-loop already carries (post-psum,
    hence replicated under shard_map) — enabling guards changes neither the
    collective count nor the reduction schedule, which is what the
    ``repro.analysis`` guard-invariance audit asserts.

    ``breakdown_eps``      ρ-underflow threshold for the BiCGStab family
                           (fires when ρ² < ε²·‖r₀‖²·‖r‖²).  Conservative
                           default: only a genuine orthogonality collapse
                           trips it.
    ``divergence_factor``  exit with ``diverged`` once the squared residual
                           exceeds ``factor² · max(‖r₀‖², thresh²)``.
    ``stagnation_window``  0 disables; N > 0 exits with ``stagnated`` after
                           N consecutive iterations without the squared
                           residual improving below ``stagnation_rtol`` ×
                           the best seen so far.
    """

    breakdown_eps: float = 1e-12
    divergence_factor: float = 1e8
    stagnation_window: int = 0
    stagnation_rtol: float = 1.0

    def __post_init__(self):
        if self.breakdown_eps < 0 or self.divergence_factor <= 1:
            raise ValueError(
                f"GuardSpec: breakdown_eps must be >= 0 and "
                f"divergence_factor > 1, got {self.breakdown_eps!r}/"
                f"{self.divergence_factor!r}")
        if self.stagnation_window < 0 or not 0 < self.stagnation_rtol <= 1:
            raise ValueError(
                f"GuardSpec: stagnation_window >= 0 and 0 < stagnation_rtol "
                f"<= 1 required, got {self.stagnation_window!r}/"
                f"{self.stagnation_rtol!r}")


class SolveBreakdown(RuntimeError):
    """A guarded solve exited abnormally under ``on_breakdown="raise"``.

    Carries the method name and the full :class:`SolveResult` (``.method``,
    ``.result``) so callers can inspect the typed status, the iterate and
    the residual history of the failed attempt.
    """

    def __init__(self, method: str, result: "SolveResult"):
        self.method = method
        self.result = result
        super().__init__(
            f"{method}: solve exited with status="
            f"{status_name(result.status)!r} after {int(result.iters)} "
            f"iterations (res_norm={float(result.res_norm):.3e})")


class SolveResult(NamedTuple):
    x: jax.Array
    iters: jax.Array          # number of completed iterations
    res_norm: jax.Array       # final ||r||_2 (method's own residual estimate)
    history: jax.Array        # (maxiter+1,) residual-norm history, NaN-padded
    #: opt-in per-iteration scalar-state telemetry (repro.obs): a bounded
    #: (buffer, len(mdef.scalars)) NaN-padded buffer of the method's declared
    #: loop-carry scalars, row k = the state after iteration k (row 0 = the
    #: initial state; overflow past the buffer overwrites the last row).
    #: ``None`` when disabled — an EMPTY pytree subtree, so the result tree,
    #: the lowered HLO and every shard_map out_spec are bit-for-bit the
    #: pre-telemetry ones.
    telemetry: jax.Array | None = None
    #: typed loop-exit status (int32, one of the ``STATUS_*`` codes above).
    #: ``run_method`` always fills it; the ``None`` default only keeps
    #: hand-built results (tests, out_spec templates) constructible.
    status: jax.Array | None = None


def _default_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.vdot(a, b)


def _identity(v: jax.Array) -> jax.Array:
    return v


def _stacked_dot(A, dot):
    """The fused-reduction hook of the merged/pipelined variants.

    Returns ``dotn(*pairs) -> tuple`` computing every pair in ONE global
    reduction.  When the caller passes the operator's own ``dot`` (or none),
    the operator's ``dotn`` is used — ``DistributedOp.dotn`` stacks the
    partials into a single ``psum``, which is the whole point of the merged
    variants.  A foreign ``dot`` override (``SolverOptions.dot``) falls back
    to per-pair calls, preserving its semantics at the cost of the fusion.
    """
    if dot is None or getattr(dot, "__self__", None) is A:
        dn = getattr(A, "dotn", None)
        if dn is not None:
            return dn
    d = dot or _default_dot

    def dotn(*pairs):
        return tuple(d(a, b) for a, b in pairs)

    return dotn


def _hist_init(maxiter: int, v0, dtype) -> jax.Array:
    h = jnp.full((maxiter + 1,), jnp.nan, dtype=dtype)
    return h.at[0].set(v0.astype(dtype))


#: the FULL surface a MethodDef body may touch on its ``ops`` context — the
#: write-once/parallelise-underneath contract, stated once so the AST lint
#: (``repro.analysis.lint_methods``) and the humans reading this file agree.
#: A method body calling anything else is coupling itself to one backend.
OPS_PROTOCOL = frozenset({
    "A", "b", "M", "dot", "dot2", "dotn", "matvec", "diag", "norm_ref",
    "params",
})

#: what a MethodDef may touch on the operator itself (``ops.A`` — the
#: LocalOp/DistributedOp/PallasOp protocol).  ``base`` unwraps a PallasOp to
#: its inner operator; everything from ``spmv_dots`` on is a fused-kernel
#: hook a ``fused_step`` body targets (``PallasOp`` supplies them — one per
#: single-pass Pallas kernel of the reduction-hiding family).
OPERATOR_PROTOCOL = frozenset({
    "matvec", "matvec_local", "pad_exchange", "diag", "stencil", "dot",
    "dot2", "dotn", "sum_partials", "split_dims", "base", "spmv_dots",
    "cg_body", "spmv_dots3", "fused_dots", "pipe_body", "pcg_body",
    "ppipe_body", "bicgstab_spmv_dots", "bicgstab_update1",
    "bicgstab_spmv_update",
})


class Ops:
    """The execution context a :class:`MethodDef` runs against.

    Bundles the operator, the right-hand side, the bound preconditioner
    apply and the reduction hooks.  ``dot`` defaults to the operator's own
    global reduction (``DistributedOp.dot`` = one psum) when it has one,
    else ``jnp.vdot``; ``dotn`` stacks any number of dot products into ONE
    collective where the operator supports it (see :func:`_stacked_dot`).
    ``norm_ref=None`` resolves to ``||b||`` via ``dot`` (the relative
    criterion); the paper's absolute HPCCG criterion is ``norm_ref=1.0``.
    """

    __slots__ = ("A", "b", "M", "dot", "dotn", "norm_ref", "params")

    def __init__(self, A, b, *, M=None, dot=None, norm_ref=None,
                 params: dict | None = None):
        self.A = A
        self.b = b
        self.M = M if M is not None else _identity
        own = getattr(A, "dot", None)
        self.dot = dot if dot is not None else (own or _default_dot)
        self.dotn = _stacked_dot(A, dot)
        self.params = params or {}
        if norm_ref is None:
            norm_ref = jnp.sqrt(self.dot(b, b))
        self.norm_ref = norm_ref

    def matvec(self, x: jax.Array) -> jax.Array:
        return self.A.matvec(x)

    def dot2(self, a, b, c, d) -> tuple:
        """Two dot products in ONE collective (the paper fuses scalar pairs
        into a single MPI_Allreduce)."""
        return self.dotn((a, b), (c, d))

    @property
    def diag(self):
        return self.A.diag


@dataclasses.dataclass(frozen=True)
class MethodDef:
    """One iterative method, defined once, executed by pluggable runtimes.

    ``vectors``/``scalars`` declare the loop-carry layout (the step-state
    signature of ``solve_step_shardmap`` and the dry-run is derived from
    them mechanically); ``res_scalar`` names the scalar slot the generic
    driver's convergence check and history read.  ``fused_init``/
    ``fused_step`` (present iff ``fused_kernels`` is non-empty) are the
    same iteration expressed against the fused-kernel hooks of
    ``kernels.pallas_op.PallasOp`` — the capability the registry and the
    facade's Pallas routing query.
    """

    name: str
    vectors: tuple[str, ...]          # loop-carried grid arrays; [0] = iterate
    scalars: tuple[str, ...]          # loop-carried scalars
    res_scalar: str                   # scalar slot holding ||r||^2 (estimate)
    init: Callable                    # (ops, x0) -> state
    step: Callable                    # (ops, state) -> state
    finalize: Callable | None = None  # (ops, x0, state) -> x
    variant_of: str | None = None     # classical baseline this method refines
    accepts_precond: bool = False     # init/step consult ops.M
    stationary: bool = False          # Jacobi/GS family (vs Krylov)
    reduce_hide: str = "none"         # "none" | "merged" | "pipelined"
    params: tuple[str, ...] = ()      # tuning knobs read from ops.params
    default_maxiter: int = 500
    fused_kernels: tuple[str, ...] = ()   # PallasOp hooks the fused body uses
    fused_init: Callable | None = None
    fused_step: Callable | None = None
    #: optional breakdown guard ``(ops, state, rr0, eps) -> bool``: True
    #: means the next step would amplify a numerical breakdown (ρ/ω
    #: underflow, negative curvature).  Evaluated on carried post-psum
    #: scalars only — it must add no reductions.  None = generic NaN/
    #: divergence guards only.
    guard: Callable | None = None
    #: optional residual replacement ``(ops, x0, state) -> state``:
    #: recompute the TRUE residual (and the recurrence images derived from
    #: it) from the current iterate, bounding the O(ε·κ) per-iteration
    #: recurrence drift of the merged/pipelined variants.  Applied every
    #: ``refresh_every`` iterations by the resilient driver.
    refresh: Callable | None = None
    #: SpMV-equivalents one refresh costs (scaling-model price of the
    #: residual-replacement cadence); required iff ``refresh`` is set.
    refresh_spmvs: int = 0

    def __post_init__(self):
        if self.res_scalar not in self.scalars:
            raise ValueError(
                f"{self.name!r}: res_scalar {self.res_scalar!r} not in "
                f"declared scalars {self.scalars}")
        if bool(self.fused_kernels) != (self.fused_step is not None):
            raise ValueError(
                f"{self.name!r}: fused_kernels and fused_step must be "
                f"declared together")
        if self.fused_step is not None and self.fused_init is None:
            raise ValueError(f"{self.name!r}: fused_step without fused_init")
        if (self.refresh is None) != (self.refresh_spmvs == 0):
            raise ValueError(
                f"{self.name!r}: refresh and refresh_spmvs must be declared "
                f"together (the scaling model prices every refresh hook)")

    @property
    def res_index(self) -> int:
        """Flat state index of the ``res_scalar`` slot."""
        return len(self.vectors) + self.scalars.index(self.res_scalar)

    @property
    def has_fused_body(self) -> bool:
        return self.fused_step is not None

    @property
    def has_refresh(self) -> bool:
        """Whether the method declares a residual-replacement hook — the
        capability ``SolverOptions.residual_replacement`` queries."""
        return self.refresh is not None


METHODS: dict[str, MethodDef] = {}


def register_method(mdef: MethodDef) -> MethodDef:
    if mdef.name in METHODS:
        raise ValueError(f"method {mdef.name!r} already defined")
    if mdef.variant_of is not None and mdef.variant_of not in METHODS:
        raise ValueError(
            f"{mdef.name!r}: unknown baseline {mdef.variant_of!r} "
            f"(define the classical method first)")
    METHODS[mdef.name] = mdef
    return mdef


def get_method(name: str) -> MethodDef:
    """Look up a MethodDef; unknown names raise a ValueError that lists the
    known methods (the silent-fallthrough regression fixed in PR 5)."""
    try:
        return METHODS[name]
    except KeyError:
        raise ValueError(
            f"unknown method {name!r}; known methods: "
            f"{sorted(METHODS)}") from None


def method_names() -> list[str]:
    return sorted(METHODS)


# =============================================================================
# The generic driver: MethodDef + Ops -> a whole solve
# =============================================================================

def _status_basic(res2, thresh2):
    """Loop-exit classification from the residual scalar alone.

    The plain driver's cond (``res2 >= thresh2``) already exits on NaN
    (every comparison with NaN is False) — this names WHY the loop exited
    instead of letting a NaN ``res_norm`` masquerade as convergence.
    """
    status = jnp.where(res2 < thresh2, STATUS_CONVERGED, STATUS_MAXITER)
    status = jnp.where(jnp.isinf(res2), STATUS_DIVERGED, status)
    status = jnp.where(jnp.isnan(res2), STATUS_BREAKDOWN, status)
    return status.astype(jnp.int32)


def run_method(mdef: MethodDef, ops: Ops, x0: jax.Array, *,
               tol: float = 1e-6, maxiter: int | None = None,
               fused: bool = False, telemetry: int = 0,
               guard_spec: GuardSpec | None = None,
               refresh_every: int = 0) -> SolveResult:
    """Run ``mdef`` to convergence: ``lax.while_loop`` around its ``step``.

    The convergence check, the residual history and the reported
    ``res_norm`` all read the method's declared ``res_scalar`` slot, so
    every backend (local, shard_map, fused Pallas) stops on identical
    criteria.  ``fused=True`` selects the fused-kernel body (``ops.A`` must
    then be a ``PallasOp``).

    ``telemetry=N`` (repro.obs) additionally threads a bounded
    ``(min(N, maxiter+1), len(mdef.scalars))`` scalar-history buffer
    through the while-loop carry: row k holds every declared loop-carry
    scalar after iteration k (row 0 = the initial state; iterations past
    the buffer overwrite its last row — fixed-size, so the carry stays
    donation-safe).  ``telemetry=0`` (the default) takes a code path
    byte-identical to the pre-telemetry driver and returns
    ``SolveResult.telemetry = None``.

    Resilience (repro.resilience):

    * ``SolveResult.status`` is ALWAYS filled with a typed exit code —
      with everything below disabled it is classified post-loop from the
      residual scalar alone (:func:`_status_basic`), so the loop, its
      carry and its collectives are untouched.
    * ``guard_spec=GuardSpec(...)`` arms per-iteration breakdown guards in
      the loop cond: NaN in any carried scalar, divergence past
      ``divergence_factor``, the method's own ``guard`` hook (ρ-underflow,
      negative curvature) and optional stagnation detection.  A fired
      guard exits BEFORE the poisoning step runs, preserving the last
      finite iterate.  Guards read carried post-psum scalars only — zero
      extra collectives (audited by ``repro.analysis``).
    * ``refresh_every=N`` applies the method's residual-replacement hook
      every N iterations (methods with ``refresh`` declared — the
      merged/pipelined variants), bounding recurrence drift at a priced
      cost of ``refresh_spmvs`` SpMV-equivalents per refresh.
    """
    if maxiter is None:
        maxiter = mdef.default_maxiter
    if fused and not mdef.has_fused_body:
        raise ValueError(f"{mdef.name!r} declares no fused kernels")
    if refresh_every < 0:
        raise ValueError(f"refresh_every must be >= 0, got {refresh_every}")
    if refresh_every and mdef.refresh is None:
        raise ValueError(
            f"{mdef.name!r} declares no residual-replacement hook; "
            f"refresh_every applies only to methods with one "
            f"(the merged/pipelined variants)")
    init = mdef.fused_init if fused else mdef.init
    step = mdef.fused_step if fused else mdef.step
    thresh2 = (tol * ops.norm_ref) ** 2
    ridx = mdef.res_index
    state = tuple(init(ops, x0))
    hist = _hist_init(maxiter, jnp.sqrt(state[ridx]), ops.b.dtype)

    if guard_spec is not None or refresh_every:
        return _run_resilient(mdef, ops, x0, step, state, hist,
                              thresh2=thresh2, maxiter=maxiter,
                              telemetry=telemetry, guard_spec=guard_spec,
                              refresh_every=refresh_every)

    if not telemetry:
        def cond(c):
            state, k, _ = c
            return (state[ridx] >= thresh2) & (k < maxiter)

        def body(c):
            state, k, hist = c
            state = tuple(step(ops, state))
            hist = hist.at[k + 1].set(jnp.sqrt(state[ridx]).astype(hist.dtype))
            return (state, k + 1, hist)

        state, k, hist = lax.while_loop(cond, body, (state, 0, hist))
        x = mdef.finalize(ops, x0, state) if mdef.finalize else state[0]
        return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(state[ridx]),
                           history=hist,
                           status=_status_basic(state[ridx], thresh2))

    cap = min(int(telemetry), maxiter + 1)
    nvec = len(mdef.vectors)
    dt = hist.dtype

    def _scal_row(state):
        return jnp.stack([jnp.asarray(s).astype(dt) for s in state[nvec:]])

    tele = jnp.full((cap, len(mdef.scalars)), jnp.nan, dt)
    tele = tele.at[0].set(_scal_row(state))

    def cond(c):
        state, k, _, _ = c
        return (state[ridx] >= thresh2) & (k < maxiter)

    def body(c):
        state, k, hist, tele = c
        state = tuple(step(ops, state))
        hist = hist.at[k + 1].set(jnp.sqrt(state[ridx]).astype(hist.dtype))
        tele = tele.at[jnp.minimum(k + 1, cap - 1)].set(_scal_row(state))
        return (state, k + 1, hist, tele)

    state, k, hist, tele = lax.while_loop(cond, body, (state, 0, hist, tele))
    x = mdef.finalize(ops, x0, state) if mdef.finalize else state[0]
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(state[ridx]),
                       history=hist, telemetry=tele,
                       status=_status_basic(state[ridx], thresh2))


def _run_resilient(mdef: MethodDef, ops: Ops, x0, step, state, hist, *,
                   thresh2, maxiter: int, telemetry: int,
                   guard_spec: GuardSpec | None,
                   refresh_every: int) -> SolveResult:
    """The guarded/refreshing driver loop (run_method's opt-in slow path).

    Carries a dict pytree so the optional extras (telemetry rows,
    stagnation counters) ride along only when requested.  All guard terms
    are elementwise ops on carried post-psum scalars — under shard_map they
    are replicated, so every shard takes the same branch and no collective
    is added (the invariant ``repro.analysis`` audits).
    """
    guards_on = guard_spec is not None
    gs = guard_spec if guards_on else GuardSpec()
    ridx = mdef.res_index
    nvec = len(mdef.vectors)
    dt = hist.dtype
    window = gs.stagnation_window if guards_on else 0
    rr0 = state[ridx]
    # divergence ceiling relative to the larger of ||r0||^2 and the stop
    # threshold, so near-converged starts don't trip it on noise
    div2 = (gs.divergence_factor ** 2) * jnp.maximum(
        rr0, jnp.asarray(thresh2, dtype=jnp.asarray(rr0).dtype))

    def _nan_scalars(state):
        bad = jnp.isnan(state[ridx])
        for s in state[nvec:]:
            bad = bad | jnp.isnan(s)
        return bad

    def _guard_fired(state):
        if mdef.guard is None:
            return jnp.asarray(False)
        return mdef.guard(ops, state, rr0, gs.breakdown_eps)

    def _scal_row(state):
        return jnp.stack([jnp.asarray(s).astype(dt) for s in state[nvec:]])

    carry = {"state": state, "k": 0, "hist": hist}
    if telemetry:
        cap = min(int(telemetry), maxiter + 1)
        tele = jnp.full((cap, len(mdef.scalars)), jnp.nan, dt)
        carry["tele"] = tele.at[0].set(_scal_row(state))
    if window:
        carry["best2"] = rr0
        carry["since"] = 0

    def cond(c):
        state, k = c["state"], c["k"]
        go = (state[ridx] >= thresh2) & (k < maxiter)
        if guards_on:
            # pre-step guards: a firing exits with the LAST FINITE iterate
            bad = _nan_scalars(state) | _guard_fired(state) \
                | (state[ridx] > div2)
            if window:
                bad = bad | (c["since"] >= window)
            go = go & ~bad
        return go

    def body(c):
        k = c["k"]
        state = tuple(step(ops, c["state"]))
        if refresh_every:
            state = lax.cond(
                (k + 1) % refresh_every == 0,
                lambda s: tuple(mdef.refresh(ops, x0, s)),
                lambda s: s, state)
        out = {"state": state, "k": k + 1,
               "hist": c["hist"].at[k + 1].set(
                   jnp.sqrt(state[ridx]).astype(dt))}
        if telemetry:
            cap = c["tele"].shape[0]
            out["tele"] = c["tele"].at[jnp.minimum(k + 1, cap - 1)].set(
                _scal_row(state))
        if window:
            res2 = state[ridx]
            improved = res2 < gs.stagnation_rtol * c["best2"]
            out["best2"] = jnp.minimum(res2, c["best2"])
            out["since"] = jnp.where(improved, 0, c["since"] + 1)
        return out

    fc = lax.while_loop(cond, body, carry)
    state, k, hist = fc["state"], fc["k"], fc["hist"]
    x = mdef.finalize(ops, x0, state) if mdef.finalize else state[0]
    res2 = state[ridx]
    nan_bad = _nan_scalars(state)
    i32 = jnp.int32
    status = jnp.asarray(STATUS_MAXITER, i32)
    if window:
        status = jnp.where(fc["since"] >= window,
                           jnp.asarray(STATUS_STAGNATED, i32), status)
    diverged = jnp.isinf(res2)
    if guards_on:
        diverged = diverged | (res2 > div2)
    status = jnp.where(diverged, jnp.asarray(STATUS_DIVERGED, i32), status)
    broke = nan_bad if not guards_on else (nan_bad | _guard_fired(state))
    status = jnp.where(broke, jnp.asarray(STATUS_BREAKDOWN, i32), status)
    status = jnp.where((res2 < thresh2) & ~nan_bad,
                       jnp.asarray(STATUS_CONVERGED, i32), status)
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(res2), history=hist,
                       telemetry=fc.get("tele"), status=status)


# =============================================================================
# Krylov methods — conjugate gradients
# =============================================================================

def _rho_underflow_guard(rho_idx: int, rr_idx: int):
    """BiCGStab-family breakdown guard: ρ = (r̂, r) collapsing relative to
    ‖r̂‖‖r‖ ≈ ‖r₀‖‖r‖ means the shadow residual has become numerically
    orthogonal — the next β/α division amplifies noise into the iterate.
    Reads only carried post-psum scalars (flat-state indices are pinned by
    the declared vectors/scalars layouts)."""
    def guard(ops, state, rr0, eps):
        rho, rr = state[rho_idx], state[rr_idx]
        return rho * rho < (eps * eps) * rr0 * rr
    return guard


def _nonpositive_guard(idx: int):
    """Negative-curvature/indefiniteness guard for the CG family: the
    carried inner product at ``idx`` (p·Ap, r·z, w·r, ...) must stay
    positive on an SPD operator — a non-positive value means A (or M) is
    not SPD and the α division is about to change sign or blow up."""
    def guard(ops, state, rr0, eps):
        return state[idx] <= 0.0
    return guard


def _cg_init(ops, x0):
    r = ops.b - ops.matvec(x0)
    rr = ops.dot(r, r)
    return (x0, r, r, rr)


def _cg_step(ops, state):
    """Classical CG (HPCCG reference): 2 blocking reductions."""
    x, r, p, rr = state
    Ap = ops.matvec(p)
    pAp = ops.dot(p, Ap)              # blocking: feeds alpha immediately
    alpha = rr / pAp
    x = x + alpha * p
    r = r - alpha * Ap
    rr_new = ops.dot(r, r)            # blocking: feeds beta before next SpMV
    beta = rr_new / rr
    p = r + beta * p
    return (x, r, p, rr_new)


register_method(MethodDef(
    name="cg", vectors=("x", "r", "p"), scalars=("rr",), res_scalar="rr",
    init=_cg_init, step=_cg_step))


def _cg_nb_init(ops, x0):
    r = ops.b - ops.matvec(x0)
    Ap = ops.matvec(r)                # p_0 = r_0
    an = ops.dot(r, r)
    ad = ops.dot(Ap, r)
    return (x0, r, r, Ap, an, ad)


def _cg_nb_step(ops, state):
    """Nonblocking CG (paper Alg. 1): the SpMV is applied to ``r_j``;
    ``A·p_j`` is reconstructed as a vector update (line 6).  Both reductions
    are off the critical path: the dataflow successor of ``α_n = r·r`` is
    line 6 which *follows* the SpMV, and the successor of ``α_d`` is the
    *next* iteration's ``α``, past the lagged ``x`` update (line 9)."""
    x, r, p, Ap, an, ad = state
    alpha = an / ad                       # α_{j-1}
    r_new = r - alpha * Ap                # Tk 0 (line 4)
    an_new = ops.dot(r_new, r_new)        # Tk 0 (line 5) — reduction in flight...
    Ar = ops.matvec(r_new)                # ...overlapped with this SpMV
    beta = an_new / an
    Ap_new = Ar + beta * Ap               # Tk 1 & 2 (line 6) — no SpMV on p!
    p_new = r_new + beta * p              # Tk 2 (line 7)
    ad_new = ops.dot(Ap_new, p_new)       # Tk 2 (line 8) — overlapped with...
    x = x + alpha * p                     # Tk 3 (line 9, sign-fixed; uses OLD p)
    return (x, r_new, p_new, Ap_new, an_new, ad_new)


def _cg_nb_finalize(ops, x0, state):
    # the x update lags one iteration; apply the final correction term
    x, r, p, Ap, an, ad = state
    return x + (an / ad) * p


register_method(MethodDef(
    name="cg_nb", vectors=("x", "r", "p", "Ap"), scalars=("an", "ad"),
    res_scalar="an", init=_cg_nb_init, step=_cg_nb_step,
    finalize=_cg_nb_finalize, variant_of="cg",
    guard=_nonpositive_guard(5)))       # ad = p·Ap: negative curvature


def _pcg_init(ops, x0):
    r = ops.b - ops.matvec(x0)
    z = ops.M(r)
    rz = ops.dot(r, z)
    rr = ops.dot(r, r)
    return (x0, r, z, rz, rr)


def _pcg_step(ops, state):
    """Preconditioned CG; ``M`` must be SPD-preserving.  ``p·Ap`` and
    ``r·z`` block (the latter pair-fused with the check-only ``r·r``);
    the convergence check stays on the TRUE residual ``||r||``, so
    iteration counts are comparable with ``cg`` at the same tolerance.
    With ``M = I`` this is arithmetically identical to ``cg``."""
    x, r, p, rz, rr = state
    Ap = ops.matvec(p)
    pAp = ops.dot(p, Ap)              # blocking: feeds alpha immediately
    alpha = rz / pAp
    x = x + alpha * p
    r = r - alpha * Ap
    z = ops.M(r)
    rz_new, rr_new = ops.dot2(r, z, r, r)   # blocking pair (r·r: check only)
    beta = rz_new / rz
    p = z + beta * p
    return (x, r, p, rz_new, rr_new)


register_method(MethodDef(
    name="pcg", vectors=("x", "r", "p"), scalars=("rz", "rr"),
    res_scalar="rr", init=_pcg_init, step=_pcg_step,
    variant_of="cg", accepts_precond=True,
    guard=_nonpositive_guard(3)))       # rz = r·M⁻¹r: M or A not SPD


def _cg_merged_scalars(gamma, delta, gamma_prev, alpha_prev):
    """β and the Saad-recurrence α of merged/pipelined CG.

    ``α = γ/(δ − βγ/α_prev)`` equals classical CG's ``γ/(p·Ap)`` in exact
    arithmetic; seeding ``γ_prev = inf, α_prev = 1`` makes the first pass
    degenerate to ``β = 0, α = γ/δ`` without a cond.
    """
    beta = gamma / gamma_prev
    alpha = gamma / (delta - beta * gamma / alpha_prev)
    return alpha, beta


def _merged_seed(ref):
    inf = jnp.asarray(jnp.inf, ref.dtype)
    one = jnp.asarray(1.0, ref.dtype)
    return inf, one


def _cg_merged_init(ops, x0):
    r = ops.b - ops.matvec(x0)
    w = ops.matvec(r)
    gamma, delta = ops.dotn((r, r), (w, r))
    zero = jnp.zeros_like(ops.b)
    inf, one = _merged_seed(gamma)
    return (x0, r, zero, zero, w, gamma, delta, inf, one)


def _cg_merged_step(ops, state):
    """Merged-reduction CG (Chronopoulos–Gear): the SpMV is applied to ``r``
    (``w = A r``) and both scalars the iteration needs — ``γ = r·r`` and
    ``δ = w·r`` — come out of a single stacked reduction; ``p·Ap`` is
    recovered by the Saad recurrence.  ONE psum per iteration; one extra
    vector recurrence (``s = A p``) of memory traffic."""
    x, r, p, s, w, gamma, delta, gamma_prev, alpha_prev = state
    alpha, beta = _cg_merged_scalars(gamma, delta, gamma_prev, alpha_prev)
    p = r + beta * p
    s = w + beta * s                  # s = A p by recurrence — no SpMV on p
    x = x + alpha * p
    r = r - alpha * s
    w = ops.matvec(r)
    gamma_new, delta_new = ops.dotn((r, r), (w, r))   # the ONE reduction
    return (x, r, p, s, w, gamma_new, delta_new, gamma, alpha)


def _cg_merged_fused_init(ops, x0):
    # the initial residual uses the wrapped operator's (jnp) matvec — the
    # fused kernels take over from the first spmv_dots pass onward
    r = ops.b - ops.A.base.matvec(x0)
    w, delta, gamma = ops.A.spmv_dots(r)
    zero = jnp.zeros_like(ops.b)
    inf, one = _merged_seed(gamma)
    return (x0, r, zero, zero, w, gamma, delta, inf, one)


def _cg_merged_fused_step(ops, state):
    """The merged-CG iteration as TWO fused HBM passes (``ops.A`` is a
    ``PallasOp``): all four vector updates in one VMEM pass
    (``fused_cg_body``), then the SpMV + BOTH dot partials in another
    (``spmv_dots``; the partials ride one stacked psum under shard_map).
    Identical recurrence to :func:`_cg_merged_step` — iterates agree to
    machine precision (slab-ordered dot accumulation), pinned by
    tests/test_reduction_hiding.py."""
    x, r, p, s, w, gamma, delta, gamma_prev, alpha_prev = state
    alpha, beta = _cg_merged_scalars(gamma, delta, gamma_prev, alpha_prev)
    x, r, p, s = ops.A.cg_body(alpha, beta, x, r, p, s, w)     # pass 1
    w, delta_new, gamma_new = ops.A.spmv_dots(r)               # pass 2
    return (x, r, p, s, w, gamma_new, delta_new, gamma, alpha)


def _cg_merged_refresh(ops, x0, state):
    """Residual replacement (van der Vorst–Ye / Cools): recompute the TRUE
    residual from the iterate and rebuild every recurrence image (``s = A
    p``, ``w = A r``) and scalar from it, discarding accumulated drift.
    One stacked reduction, same shape as the step's own."""
    x, r, p, s, w, gamma, delta, gamma_prev, alpha_prev = state
    r = ops.b - ops.matvec(x)
    s = ops.matvec(p)
    w = ops.matvec(r)
    gamma, delta = ops.dotn((r, r), (w, r))
    return (x, r, p, s, w, gamma, delta, gamma_prev, alpha_prev)


register_method(MethodDef(
    name="cg_merged", vectors=("x", "r", "p", "s", "w"),
    scalars=("gamma", "delta", "gamma_prev", "alpha_prev"),
    res_scalar="gamma", init=_cg_merged_init, step=_cg_merged_step,
    variant_of="cg", reduce_hide="merged",
    fused_kernels=("cg_body", "spmv_dots"),
    fused_init=_cg_merged_fused_init, fused_step=_cg_merged_fused_step,
    guard=_nonpositive_guard(6),        # delta = r·Ar: A not SPD
    refresh=_cg_merged_refresh, refresh_spmvs=3))


def _pcg_merged_init(ops, x0):
    r = ops.b - ops.matvec(x0)
    u = ops.M(r)
    w = ops.matvec(u)
    gamma, delta, rr = ops.dotn((r, u), (w, u), (r, r))
    zero = jnp.zeros_like(ops.b)
    inf, one = _merged_seed(gamma)
    return (x0, r, u, zero, zero, w, gamma, delta, rr, inf, one)


def _pcg_merged_step(ops, state):
    """Merged-reduction PCG (Chronopoulos–Gear with ``u = M⁻¹r``); the
    TRUE-residual ``r·r`` rides in the same stacked reduction (3 scalars,
    ONE psum), so stopping matches ``pcg``.  ``M`` must be SPD-preserving."""
    x, r, u, p, s, w, gamma, delta, rr, gamma_prev, alpha_prev = state
    alpha, beta = _cg_merged_scalars(gamma, delta, gamma_prev, alpha_prev)
    p = u + beta * p
    s = w + beta * s
    x = x + alpha * p
    r = r - alpha * s
    u = ops.M(r)
    w = ops.matvec(u)
    gamma_new, delta_new, rr_new = ops.dotn((r, u), (w, u), (r, r))
    return (x, r, u, p, s, w, gamma_new, delta_new, rr_new, gamma, alpha)


def _pcg_merged_guard(ops, state, rr0, eps):
    # gamma = r·u (the M-inner product) and delta = u·Au must both stay
    # positive when A and M are SPD
    return (state[6] <= 0.0) | (state[7] <= 0.0)


def _pcg_merged_refresh(ops, x0, state):
    """Residual replacement for merged PCG: true r, fresh ``u = M⁻¹r`` and
    recurrence images, all scalars from one stacked reduction."""
    x, r, u, p, s, w, gamma, delta, rr, gamma_prev, alpha_prev = state
    r = ops.b - ops.matvec(x)
    u = ops.M(r)
    w = ops.matvec(u)
    s = ops.matvec(p)
    gamma, delta, rr = ops.dotn((r, u), (w, u), (r, r))
    return (x, r, u, p, s, w, gamma, delta, rr, gamma_prev, alpha_prev)


def _pcg_merged_fused_step(ops, state):
    """Merged PCG as fused HBM passes: all four vector updates in one VMEM
    pass (``pcg_body``), the preconditioner apply on its own (Pallas)
    kernels via ``ops.M``, then SpMV + the full reduction triple
    (``γ = r·u``, ``δ = w·u``, true ``r·r``) in one more pass
    (``spmv_dots3``, partials on one stacked psum).  Same recurrence as
    :func:`_pcg_merged_step`."""
    x, r, u, p, s, w, gamma, delta, rr, gamma_prev, alpha_prev = state
    alpha, beta = _cg_merged_scalars(gamma, delta, gamma_prev, alpha_prev)
    x, r, p, s = ops.A.pcg_body(alpha, beta, x, r, u, p, s, w)   # pass 1
    u = ops.M(r)                                   # precond (own kernels)
    w, delta_new, gamma_new, rr_new = ops.A.spmv_dots3(u, r)     # pass 2
    return (x, r, u, p, s, w, gamma_new, delta_new, rr_new, gamma, alpha)


register_method(MethodDef(
    name="pcg_merged", vectors=("x", "r", "u", "p", "s", "w"),
    scalars=("gamma", "delta", "rr", "gamma_prev", "alpha_prev"),
    res_scalar="rr", init=_pcg_merged_init, step=_pcg_merged_step,
    variant_of="pcg", reduce_hide="merged", accepts_precond=True,
    fused_kernels=("pcg_body", "spmv_dots3"),
    fused_init=_pcg_merged_init, fused_step=_pcg_merged_fused_step,
    guard=_pcg_merged_guard,
    refresh=_pcg_merged_refresh, refresh_spmvs=3))


def _cg_pipe_init(ops, x0):
    r = ops.b - ops.matvec(x0)
    w = ops.matvec(r)
    (rr0,) = ops.dotn((r, r))
    zero = jnp.zeros_like(ops.b)
    inf, one = _merged_seed(rr0)
    return (x0, r, w, zero, zero, zero, inf, one, rr0)


def _cg_pipe_step(ops, state):
    """Pipelined CG (Ghysels–Vanroose): the ONE stacked reduction is issued
    at the top of the body and the body's SpMV (``n = A w``, on carried
    state) is dataflow-independent of it — the latency-hiding scheduler
    runs the SpMV while the psum is in flight.  The ``optimization_barrier``
    pins the SpMV as its own schedulable task (the ``bicgstab_b1`` idiom).
    The freshest residual norm available to the check is the previous
    body's, so the method typically reports one more iteration than ``cg``;
    two extra vector recurrences (``s = A p``, ``z = A s``) pay for the
    hiding."""
    x, r, w, p, s, z, gamma_prev, alpha_prev, rr = state
    gamma, delta = ops.dotn((r, r), (w, r))           # issued...
    n = lax.optimization_barrier(ops.matvec(w))       # ...hidden behind this
    alpha, beta = _cg_merged_scalars(gamma, delta, gamma_prev, alpha_prev)
    z = n + beta * z                  # z = A s by recurrence
    s = w + beta * s                  # s = A p by recurrence
    p = r + beta * p
    x = x + alpha * p
    r = r - alpha * s
    w = w - alpha * z                 # w = A r by recurrence
    return (x, r, w, p, s, z, gamma, alpha, gamma)


def _cg_pipe_refresh(ops, x0, state):
    """Residual replacement for pipelined CG: the three recurrence chains
    (``w = A r``, ``s = A p``, ``z = A s``) all restart from the true
    residual; one extra SpMV each plus the lagged ``rr`` recomputed."""
    x, r, w, p, s, z, gamma_prev, alpha_prev, rr = state
    r = ops.b - ops.matvec(x)
    w = ops.matvec(r)
    s = ops.matvec(p)
    z = ops.matvec(s)
    (rr,) = ops.dotn((r, r))
    return (x, r, w, p, s, z, gamma_prev, alpha_prev, rr)


def _cg_pipe_fused_step(ops, state):
    """Pipelined CG as TWO fused HBM passes: the body's SpMV (``n = A w``)
    and BOTH reduction partials come out of one slab sweep
    (``spmv_dots3`` with ``x = w`` — its first partial ``(A w)·w`` is
    unused), then all six vector recurrences in one VMEM pass
    (``pipe_body``).  The latency overlap the unfused form schedules
    explicitly happens *inside* the sweep: partials accumulate while the
    stencil streams, and the stacked psum rides the kernel boundary."""
    x, r, w, p, s, z, gamma_prev, alpha_prev, rr = state
    n, _nw, delta, gamma = ops.A.spmv_dots3(w, r)                # pass 1
    alpha, beta = _cg_merged_scalars(gamma, delta, gamma_prev, alpha_prev)
    x, r, w, p, s, z = ops.A.pipe_body(
        alpha, beta, x, r, w, p, s, z, n)                        # pass 2
    return (x, r, w, p, s, z, gamma, alpha, gamma)


register_method(MethodDef(
    name="cg_pipe", vectors=("x", "r", "w", "p", "s", "z"),
    scalars=("gamma_prev", "alpha_prev", "rr"), res_scalar="rr",
    init=_cg_pipe_init, step=_cg_pipe_step,
    variant_of="cg", reduce_hide="pipelined",
    fused_kernels=("spmv_dots3", "pipe_body"),
    fused_init=_cg_pipe_init, fused_step=_cg_pipe_fused_step,
    refresh=_cg_pipe_refresh, refresh_spmvs=4))


def _pcg_pipe_init(ops, x0):
    r = ops.b - ops.matvec(x0)
    u = ops.M(r)
    w = ops.matvec(u)
    (rr0,) = ops.dotn((r, r))
    zero = jnp.zeros_like(ops.b)
    inf, one = _merged_seed(rr0)
    return (x0, r, u, w, zero, zero, zero, zero, inf, one, rr0)


def _pcg_pipe_step(ops, state):
    """Pipelined PCG (Ghysels–Vanroose Alg. 3): the stacked reduction
    (``γ = r·u``, ``δ = w·u``, TRUE ``r·r`` — ONE psum) overlaps both the
    preconditioner apply ``m = M⁻¹w`` and the SpMV ``n = A m``.  Four extra
    recurrences (``s, q, z, u``); stopping lags one iteration like the
    unpreconditioned pipeline."""
    x, r, u, w, p, s, q, z, gamma_prev, alpha_prev, rr = state
    gamma, delta, rr_new = ops.dotn((r, u), (w, u), (r, r))   # issued...
    m = ops.M(w)                                  # ...hidden behind the
    n = lax.optimization_barrier(ops.matvec(m))   # apply and the SpMV
    alpha, beta = _cg_merged_scalars(gamma, delta, gamma_prev, alpha_prev)
    z = n + beta * z                  # z = A q by recurrence
    q = m + beta * q                  # q = M⁻¹ s by recurrence
    s = w + beta * s                  # s = A p by recurrence
    p = u + beta * p
    x = x + alpha * p
    r = r - alpha * s
    u = u - alpha * q                 # u = M⁻¹ r by recurrence
    w = w - alpha * z                 # w = A u by recurrence
    return (x, r, u, w, p, s, q, z, gamma, alpha, rr_new)


def _pcg_pipe_refresh(ops, x0, state):
    """Residual replacement for pipelined PCG: true r, fresh preconditioned
    images ``u = M⁻¹r``/``q = M⁻¹s`` and SpMV images rebuilt from them."""
    x, r, u, w, p, s, q, z, gamma_prev, alpha_prev, rr = state
    r = ops.b - ops.matvec(x)
    u = ops.M(r)
    w = ops.matvec(u)
    s = ops.matvec(p)
    q = ops.M(s)
    z = ops.matvec(q)
    (rr,) = ops.dotn((r, r))
    return (x, r, u, w, p, s, q, z, gamma_prev, alpha_prev, rr)


def _pcg_pipe_fused_step(ops, state):
    """Pipelined PCG as fused HBM passes: the reduction triple on carried
    state in one read pass (``fused_dots``), the preconditioner apply and
    SpMV on their own kernels, then all eight vector recurrences in one
    VMEM pass (``ppipe_body``).  Same recurrence as
    :func:`_pcg_pipe_step`."""
    x, r, u, w, p, s, q, z, gamma_prev, alpha_prev, rr = state
    gamma, delta, rr_new = ops.A.fused_dots(r, u, w)             # pass 1
    m = ops.M(w)                                   # precond (own kernels)
    n = ops.A.matvec(m)                                          # SpMV
    alpha, beta = _cg_merged_scalars(gamma, delta, gamma_prev, alpha_prev)
    x, r, u, w, p, s, q, z = ops.A.ppipe_body(
        alpha, beta, x, r, u, w, p, s, q, z, m, n)               # pass 2
    return (x, r, u, w, p, s, q, z, gamma, alpha, rr_new)


register_method(MethodDef(
    name="pcg_pipe", vectors=("x", "r", "u", "w", "p", "s", "q", "z"),
    scalars=("gamma_prev", "alpha_prev", "rr"), res_scalar="rr",
    init=_pcg_pipe_init, step=_pcg_pipe_step,
    variant_of="pcg", reduce_hide="pipelined", accepts_precond=True,
    fused_kernels=("fused_dots", "ppipe_body"),
    fused_init=_pcg_pipe_init, fused_step=_pcg_pipe_fused_step,
    refresh=_pcg_pipe_refresh, refresh_spmvs=4))


# =============================================================================
# Krylov methods — BiCGStab family
# =============================================================================

def _bicgstab_init(ops, x0):
    r = ops.b - ops.matvec(x0)
    rho = ops.dot(r, r)               # r̂ = r_0 ⇒ ρ_0 = (r̂,r_0) = ‖r_0‖²
    return (x0, r, r, r, rho, rho)


def _bicgstab_step(ops, state):
    """Classical BiCGStab: 3 blocking reduction points per iteration (the
    ω pair and the ρ/‖r‖² pair each fused into one collective)."""
    x, r, rhat, p, rho, rr = state
    v = ops.matvec(p)
    rhat_v = ops.dot(rhat, v)             # barrier 1
    alpha = rho / rhat_v
    s = r - alpha * v
    t = ops.matvec(s)
    ts, tt = ops.dot2(t, s, t, t)         # barrier 2 (fused pair of dots)
    omega = ts / tt
    x = x + alpha * p + omega * s
    r = s - omega * t
    rho_new, rr_new = ops.dot2(rhat, r, r, r)   # barrier 3 (fused pair)
    beta = (rho_new / rho) * (alpha / omega)
    p = r + beta * (p - omega * v)
    return (x, r, rhat, p, rho_new, rr_new)


register_method(MethodDef(
    name="bicgstab", vectors=("x", "r", "rhat", "p"),
    scalars=("rho", "rr"), res_scalar="rr",
    init=_bicgstab_init, step=_bicgstab_step,
    guard=_rho_underflow_guard(4, 5)))


def _pbicgstab_step(ops, state):
    """Right-preconditioned BiCGStab (``A M⁻¹ y = b``, ``x = M⁻¹ y``).
    Right preconditioning keeps ``r`` the TRUE residual, so stopping and
    iteration counts are directly comparable with ``bicgstab``; ``M`` need
    not be SPD-preserving.  Barrier structure unchanged (3 blocking
    reduction points) — the two ``M`` applies add stencil sweeps but no
    reductions for the built-in preconditioners."""
    x, r, rhat, p, rho, rr = state
    phat = ops.M(p)
    v = ops.matvec(phat)
    rhat_v = ops.dot(rhat, v)             # barrier 1
    alpha = rho / rhat_v
    s = r - alpha * v
    shat = ops.M(s)
    t = ops.matvec(shat)
    ts, tt = ops.dot2(t, s, t, t)         # barrier 2 (fused pair of dots)
    omega = ts / tt
    x = x + alpha * phat + omega * shat
    r = s - omega * t
    rho_new, rr_new = ops.dot2(rhat, r, r, r)   # barrier 3 (fused pair)
    beta = (rho_new / rho) * (alpha / omega)
    p = r + beta * (p - omega * v)
    return (x, r, rhat, p, rho_new, rr_new)


register_method(MethodDef(
    name="pbicgstab", vectors=("x", "r", "rhat", "p"),
    scalars=("rho", "rr"), res_scalar="rr",
    init=_bicgstab_init, step=_pbicgstab_step,
    variant_of="bicgstab", accepts_precond=True,
    guard=_rho_underflow_guard(4, 5)))


def _bicgstab_b1_init(ops, x0):
    r = ops.b - ops.matvec(x0)
    beta_rr = ops.dot(r, r)                    # β_0 = r_0·r_0
    rhat = r / jnp.sqrt(beta_rr)               # r'
    an = ops.dot(r, rhat)                      # α_{n,0} = sqrt(β_0)
    return (x0, r, r, rhat, an, beta_rr)


def _bicgstab_b1_step(ops, state):
    """BiCGStab one-blocking (paper Alg. 2) with the restart procedure.

    Only ``α_d = (A·p)·r'`` blocks; ω's pair of reductions overlaps the
    ``x_{j+1/2}`` update (Tk 3) and the ``α_n``/``β`` pair overlaps the
    ``p_{j+1/2}`` update (Tk 5).  Restart (lines 13-15) triggers on
    ``sqrt(|α_n|) < ε_restart·||b||`` and re-orthogonalises ``r'``,
    eliminating the near-breakdown amplification (and, in the paper's task
    world, accumulated nondeterministic rounding).  ``ε_restart`` comes
    from ``ops.params`` (default 1e-5, paper §4.1)."""
    x, r, p, rhat, an, beta_rr = state
    restart_thresh = ops.params.get("eps_restart", 1e-5) * ops.norm_ref
    Ap = ops.matvec(p)
    ad = ops.dot(Ap, rhat)                # Tk 0 (line 3) — the ONE blocking reduction
    alpha = an / ad
    s = r - alpha * Ap                    # Tk 1 (line 4)
    As = ops.matvec(s)
    ts, tt = ops.dot2(As, s, As, As)      # Tk 2 (line 5) — overlapped with...
    # optimization_barrier = the Tk-3-is-its-own-task constraint: without
    # it XLA fuses this update into the omega-dependent x_{j+1} and the
    # overlap window vanishes (measured: slack 4096 -> 0 bytes)
    x_half = lax.optimization_barrier(x + alpha * p)   # ...Tk 3 (line 6)
    omega = ts / tt
    x_new = x_half + omega * s            # Tk 4 (line 8; == line 18 on exit)
    r_new = s - omega * As                # Tk 4 (line 9)
    an_new, beta_rr_new = ops.dot2(r_new, rhat, r_new, r_new)   # Tk 4 — ...
    p_half = lax.optimization_barrier(p - omega * Ap)  # ...overlaps Tk 5 (line 12)
    restart = jnp.sqrt(jnp.abs(an_new)) < restart_thresh
    p_reg = r_new + (an_new / (ad * omega)) * p_half   # Tk 7 (line 17)
    p_new = jnp.where(restart, r_new, p_reg)           # Tk 6 (line 14)
    rhat_new = jnp.where(restart, r_new / jnp.sqrt(beta_rr_new), rhat)  # line 15
    an_next = jnp.where(restart, jnp.sqrt(beta_rr_new), an_new)
    return (x_new, r_new, p_new, rhat_new, an_next, beta_rr_new)


register_method(MethodDef(
    name="bicgstab_b1", vectors=("x", "r", "p", "rhat"),
    scalars=("an", "beta_rr"), res_scalar="beta_rr",
    init=_bicgstab_b1_init, step=_bicgstab_b1_step,
    variant_of="bicgstab", params=("eps_restart",)))


def _merged_bicgstab_matvec(ops, preconditioned: bool):
    if not preconditioned:
        return ops.matvec
    return lambda v: ops.matvec(ops.M(v))


def _make_bicgstab_merged_init(preconditioned: bool):
    def init(ops, x0):
        mv = _merged_bicgstab_matvec(ops, preconditioned)
        r0 = ops.b - ops.matvec(x0)
        y0 = jnp.zeros_like(ops.b) if preconditioned else x0
        w = mv(r0)
        t = mv(w)
        rho, rhw = ops.dotn((r0, r0), (r0, w))   # r̂ = r0
        alpha = rho / rhw
        rr = rho                           # r̂ = r0 ⇒ (r̂,r0) = ‖r0‖²
        return (y0, r0, w, t, r0, w, t, r0, rho, alpha, rr)
    return init


def _make_bicgstab_merged_step(preconditioned: bool):
    def step(ops, state):
        mv = _merged_bicgstab_matvec(ops, preconditioned)
        y, r, w, t, p, s, z, rhat, rho, alpha, rr = state
        q = r - alpha * s                  # classical s_j
        yv = w - alpha * z                 # = A q
        v = lax.optimization_barrier(mv(z))          # SpMV 1 — independent...
        (qy, yy, qq, rhq, rhy, rht, rhv, rhz, rhs) = ops.dotn(   # ...of the
            (q, yv), (yv, yv), (q, q), (rhat, q), (rhat, yv),    # ONE psum
            (rhat, t), (rhat, v), (rhat, z), (rhat, s))
        omega = qy / yy
        y = y + alpha * p + omega * q
        r = q - omega * yv
        # recurrence-based ‖r‖² (the stability caveat in docs/API.md):
        # ‖q − ωy‖² from pre-update dots; clamp the rounding negatives.
        rr_new = jnp.maximum(qq - 2.0 * omega * qy + omega * omega * yy, 0.0)
        rho_new = rhq - omega * rhy
        beta = (rho_new / rho) * (alpha / omega)
        w = yv - omega * (t - alpha * v)   # = A r_new
        t = mv(w)                          # SpMV 2
        rhw = rhy - omega * (rht - alpha * rhv)      # (r̂, w_new)
        alpha_new = rho_new / (rhw + beta * (rhs - omega * rhz))
        p = r + beta * (p - omega * s)
        s = w + beta * (s - omega * z)     # = A p_new
        z = t + beta * (z - omega * v)     # = A s_new
        return (y, r, w, t, p, s, z, rhat, rho_new, alpha_new, rr_new)
    return step


_BICGSTAB_MERGED_DOC = """Single-reduction BiCGStab (cf. Cools–Vanroose).

Auxiliary images ``w = A r``, ``t = A w``, ``s = A p``, ``z = A s`` are
maintained by recurrence so that ω's pair, ρ, the α denominator
``r̂·(A p)`` and ‖r‖² are all linear in dots of vectors available BEFORE ω
— nine dots, ONE stacked psum per iteration.  Two SpMVs remain (``v = A z``
and ``t = A w_new``); ``v`` is dataflow-independent of the reduction, so
the scheduler can hide the psum behind it (the ``optimization_barrier``
pins it as its own task).  The preconditioned form runs the same core on
the right-preconditioned operator ``B = A∘M⁻¹`` with a zero initial guess
and recovers ``x = x0 + M⁻¹ y`` once at exit — the residual is unchanged
by right preconditioning, so stopping stays TRUE-residual."""


def _make_bicgstab_merged_fused_step(preconditioned: bool):
    def fused_step(ops, state):
        """Single-reduction BiCGStab as THREE fused HBM passes: SpMV 1
        (``v = A z̃``) + the intermediates ``q``/``y`` + all NINE dot
        partials in one slab sweep (``bicgstab_spmv_dots``; partials on
        the iteration's ONE stacked psum), the ω-half x/r/w updates in one
        VMEM pass (``bicgstab_update1``), then SpMV 2 fused with the three
        direction recurrences (``bicgstab_spmv_update``).  Identical
        recurrence to the unfused step; the preconditioned form applies
        ``M`` to each SpMV operand (right preconditioning)."""
        y, r, w, t, p, s, z, rhat, rho, alpha, rr = state
        zi = ops.M(z) if preconditioned else z
        v, q, yv, parts = ops.A.bicgstab_spmv_dots(
            zi, z, r, w, s, rhat, t, alpha)                      # pass 1
        qy, yy, qq, rhq, rhy, rht, rhv, rhz, rhs = parts
        omega = qy / yy
        rr_new = jnp.maximum(qq - 2.0 * omega * qy + omega * omega * yy, 0.0)
        rho_new = rhq - omega * rhy
        beta = (rho_new / rho) * (alpha / omega)
        y, r, w = ops.A.bicgstab_update1(
            alpha, omega, y, p, q, yv, t, v)                     # pass 2
        wi = ops.M(w) if preconditioned else w
        t, p, s, z = ops.A.bicgstab_spmv_update(
            wi, w, r, p, s, z, v, omega, beta)                   # pass 3
        rhw = rhy - omega * (rht - alpha * rhv)
        alpha_new = rho_new / (rhw + beta * (rhs - omega * rhz))
        return (y, r, w, t, p, s, z, rhat, rho_new, alpha_new, rr_new)
    return fused_step


def _pbicgstab_merged_finalize(ops, x0, state):
    # the loop iterates in the preconditioned ŷ space; recover x once
    return x0 + ops.M(state[0])


def _make_bicgstab_merged_refresh(preconditioned: bool):
    def refresh(ops, x0, state):
        """Residual replacement for single-reduction BiCGStab: recover the
        TRUE residual from the iterate (via ``finalize`` in the
        preconditioned ŷ space), rebuild every recurrence image ``w,t,s,z``
        from it and recompute ρ, α and ‖r‖² in one stacked reduction."""
        mv = _merged_bicgstab_matvec(ops, preconditioned)
        y, r, w, t, p, s, z, rhat, rho, alpha, rr = state
        x = x0 + ops.M(y) if preconditioned else y
        r = ops.b - ops.matvec(x)
        w = mv(r)
        t = mv(w)
        s = mv(p)
        z = mv(s)
        rho, rr, rhs = ops.dotn((rhat, r), (r, r), (rhat, s))
        alpha = rho / rhs                  # α = ρ / r̂·(B p)
        return (y, r, w, t, p, s, z, rhat, rho, alpha, rr)
    return refresh


register_method(MethodDef(
    name="bicgstab_merged",
    vectors=("x", "r", "w", "t", "p", "s", "z", "rhat"),
    scalars=("rho", "alpha", "rr"), res_scalar="rr",
    init=_make_bicgstab_merged_init(False),
    step=_make_bicgstab_merged_step(False),
    variant_of="bicgstab", reduce_hide="merged",
    fused_kernels=("bicgstab_spmv_dots", "bicgstab_update1",
                   "bicgstab_spmv_update"),
    fused_init=_make_bicgstab_merged_init(False),
    fused_step=_make_bicgstab_merged_fused_step(False),
    guard=_rho_underflow_guard(8, 10),
    refresh=_make_bicgstab_merged_refresh(False), refresh_spmvs=5))

register_method(MethodDef(
    name="pbicgstab_merged",
    vectors=("x", "r", "w", "t", "p", "s", "z", "rhat"),
    scalars=("rho", "alpha", "rr"), res_scalar="rr",
    init=_make_bicgstab_merged_init(True),
    step=_make_bicgstab_merged_step(True),
    finalize=_pbicgstab_merged_finalize,
    variant_of="pbicgstab", reduce_hide="merged", accepts_precond=True,
    fused_kernels=("bicgstab_spmv_dots", "bicgstab_update1",
                   "bicgstab_spmv_update"),
    fused_init=_make_bicgstab_merged_init(True),
    fused_step=_make_bicgstab_merged_fused_step(True),
    guard=_rho_underflow_guard(8, 10),
    refresh=_make_bicgstab_merged_refresh(True), refresh_spmvs=5))


# =============================================================================
# Stationary methods
# =============================================================================

def _jacobi_init(ops, x0):
    r = ops.b - ops.matvec(x0)
    rr = ops.dot(r, r)
    return (x0, r, rr)


def _jacobi_step(ops, state):
    """Jacobi: x += D⁻¹ r; one SpMV + one reduction per iteration."""
    x, r, rr = state
    x = x + r / ops.diag
    r = ops.b - ops.matvec(x)
    rr = ops.dot(r, r)
    return (x, r, rr)


register_method(MethodDef(
    name="jacobi", vectors=("x", "r"), scalars=("rr",), res_scalar="rr",
    init=_jacobi_init, step=_jacobi_step, stationary=True,
    default_maxiter=1000))


def _plane_sweep(A, b, x, *, forward: bool) -> jax.Array:
    """One relaxed Gauss-Seidel sweep: GS-fresh across z-planes, Jacobi within
    a plane, stale across device blocks (halos exchanged once per sweep)."""
    nz = x.shape[2]

    def step(i, xp):
        k = i if forward else nz - 1 - i
        off = A.stencil.plane_offdiag_apply(xp, k)
        plane = (b[:, :, k] - off) / A.diag
        return lax.dynamic_update_slice(xp, plane[:, :, None], (1, 1, k + 1))

    xp = A.pad_exchange(x)
    xp = lax.fori_loop(0, nz, step, xp)
    return xp[1:-1, 1:-1, 1:-1]


def _stationary_init(ops, x0):
    r = ops.b - ops.matvec(x0)
    rr = ops.dot(r, r)
    return (x0, rr)


def _gauss_seidel_step(ops, state):
    """Relaxed symmetric GS (paper §3.4 Code 4, TPU adaptation): forward
    sweep (ascending z-planes) then backward sweep (descending), each using
    the freshest available plane values — the deterministic analogue of the
    paper's benign data races that "mimic the Gauss-Seidel behaviour"."""
    x, rr = state
    x = _plane_sweep(ops.A, ops.b, x, forward=True)
    x = _plane_sweep(ops.A, ops.b, x, forward=False)
    r = ops.b - ops.matvec(x)
    rr = ops.dot(r, r)
    return (x, rr)


def _colour_mask(shape: tuple[int, int, int], colour: int) -> jax.Array:
    i = lax.broadcasted_iota(jnp.int32, shape, 0)
    j = lax.broadcasted_iota(jnp.int32, shape, 1)
    k = lax.broadcasted_iota(jnp.int32, shape, 2)
    return ((i + j + k) % 2) == colour


def _rb_half_sweep(A, b, x, colour_mask) -> jax.Array:
    off = A.stencil.offdiag_apply_padded(A.pad_exchange(x))
    return jnp.where(colour_mask, (b - off) / A.diag, x)


def _gauss_seidel_rb_step(ops, state):
    """Red-black coloured symmetric GS (paper §3.4): forward = red, black;
    backward = black, red.  Exact GS reordering for the 7-pt stencil
    (bipartite); a coloured relaxation for the 27-pt one, with
    correspondingly different convergence (the effect the paper measures)."""
    x, rr = state
    red = _colour_mask(x.shape, 0)
    black = _colour_mask(x.shape, 1)
    x = _rb_half_sweep(ops.A, ops.b, x, red)      # forward
    x = _rb_half_sweep(ops.A, ops.b, x, black)
    x = _rb_half_sweep(ops.A, ops.b, x, black)    # backward
    x = _rb_half_sweep(ops.A, ops.b, x, red)
    r = ops.b - ops.matvec(x)
    rr = ops.dot(r, r)
    return (x, rr)


register_method(MethodDef(
    name="gauss_seidel_rb", vectors=("x",), scalars=("rr",),
    res_scalar="rr", init=_stationary_init, step=_gauss_seidel_rb_step,
    stationary=True, default_maxiter=1000))

register_method(MethodDef(
    name="gauss_seidel", vectors=("x",), scalars=("rr",),
    res_scalar="rr", init=_stationary_init, step=_gauss_seidel_step,
    variant_of="gauss_seidel_rb", stationary=True, default_maxiter=1000))
