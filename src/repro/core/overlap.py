"""Communication-overlap analysis for the solver programs.

The paper demonstrates barrier elimination with Paraver traces (Fig. 1).  The
TPU-side equivalent is structural: we lower one solver iteration and measure,
for every collective, how much independent work the schedule has available
(``repro.analysis.hlo.overlap_slack``).  A blocking barrier shows ~0 slack;
an overlapped reduction shows a SpMV's worth.

Also exposes ``blocking_reductions``: the number of all-reduces whose slack is
below a threshold — the per-iteration "barrier count" that the paper's
variants reduce (CG 2 -> CG-NB 0; BiCGStab 3 -> B1 1).
"""

from __future__ import annotations

import jax

from repro.analysis.hlo import (
    collective_bytes,
    count_collectives,
    overlap_slack,
    parse_computations,
)

__all__ = [
    "count_collectives",
    "collective_bytes",
    "overlap_slack",
    "parse_computations",
    "iteration_overlap_report",
    "blocking_reductions",
    "halo_slack",
    "blocking_halos",
]


def iteration_overlap_report(step_fn, *example_args) -> list[dict]:
    """Lower one solver iteration and return per-collective overlap slack."""
    lowered = jax.jit(step_fn).lower(*example_args)
    txt = lowered.compile().as_text()
    return overlap_slack(txt)


def blocking_reductions(report: list[dict], vector_bytes: float) -> int:
    """All-reduces with less hideable work than one vector's traffic.

    An 8-byte all-reduce's latency is hidden iff the schedule has at least a
    vector-update's worth of independent work to run under it (the paper's
    overlap condition in §3.1: "only possible if the computation times ...
    remain larger than those of collective communications").  ppermutes (halo
    traffic) are excluded: the paper's barrier discussion is about *global*
    reductions, not point-to-point neighbour traffic.
    """
    return sum(
        1
        for r in report
        if r["op"].startswith("all-reduce") and r["slack_bytes"] < vector_bytes
    )


def halo_slack(report: list[dict]) -> list[dict]:
    """The ``collective-permute`` (halo-exchange) entries of a slack report.

    The halo-side counterpart of the all-reduce barrier accounting: under
    ``halo_mode="overlap"`` each ppermute should show an interior-SpMV's
    worth of hideable work; under the monolithic ``"concat"``/``"scatter"``
    exchanges the whole SpMV (and everything after it) depends on the
    received planes, so slack collapses to at most the opposite-direction
    plane's traffic.
    """
    return [r for r in report if r["op"].startswith("collective-permute")]


def blocking_halos(report: list[dict], plane_bytes: float) -> int:
    """Halo exchanges with less hideable work than one boundary plane —
    ppermutes the schedule cannot hide behind interior compute (the
    fork-join pattern the paper's Fig. 1 shows losing, applied to the
    point-to-point traffic instead of the global reductions)."""
    return sum(1 for r in halo_slack(report)
               if r["slack_bytes"] < plane_bytes)
