"""Sparse operators for HPCG-class problems, in a TPU-native formulation.

The paper (Martinez-Ferrer et al., JPDC 2023) works on the HPCCG/HPCG sparse
system: a 7-point or 27-point centred stencil on a 3-D hexahedral grid, stored
in CSR and applied with an irregular-gather SpMV (their Code 1/3).

TPU adaptation (DESIGN.md §2): irregular gathers are hostile to the VPU, but
the HPCG operator *is* a constant-coefficient stencil, so we keep the grid
dense, shaped ``(nx, ny, nz)``, and apply the operator as shifted adds over a
zero-padded array.  Zero halos reproduce the HPCG boundary treatment exactly
because the matrix keeps a constant diagonal and simply drops out-of-domain
neighbours (``-1 * 0 == dropped``).

An ELLPACK path (`ELLOperator`) is retained for generality (any bounded-row
sparse matrix) and doubles as the cross-check oracle for the stencil path.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp


def _offsets_7pt() -> tuple[tuple[int, int, int], ...]:
    return (
        (-1, 0, 0), (1, 0, 0),
        (0, -1, 0), (0, 1, 0),
        (0, 0, -1), (0, 0, 1),
    )


def _offsets_27pt() -> tuple[tuple[int, int, int], ...]:
    offs = []
    for dx in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dz in (-1, 0, 1):
                if (dx, dy, dz) != (0, 0, 0):
                    offs.append((dx, dy, dz))
    return tuple(offs)


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class Stencil:
    """Constant-coefficient centred stencil operator on a 3-D grid.

    ``A x`` for row (i,j,k):  ``diag * x[i,j,k] + off_coeff * sum(neigh x)``
    with out-of-domain neighbours dropped (== zero-padded halo).
    """

    name: str
    offsets: tuple[tuple[int, int, int], ...]
    diag: float
    off_coeff: float = -1.0

    @property
    def npoint(self) -> int:
        return len(self.offsets) + 1

    @property
    def nbar(self) -> int:
        """Average nonzeros per row (paper's n̄): 7 or 27 for interior rows."""
        return self.npoint

    def matvec_padded(self, xp: jax.Array) -> jax.Array:
        """Apply to a halo-padded array ``(nx+2, ny+2, nz+2)`` -> ``(nx, ny, nz)``.

        This is the pure-jnp oracle; kernels/stencil_spmv.py is the Pallas
        version with explicit VMEM tiling.
        """
        nx, ny, nz = xp.shape[0] - 2, xp.shape[1] - 2, xp.shape[2] - 2
        acc = self.diag * xp[1:-1, 1:-1, 1:-1]
        for dx, dy, dz in self.offsets:
            acc = acc + self.off_coeff * jax.lax.slice(
                xp, (1 + dx, 1 + dy, 1 + dz), (1 + dx + nx, 1 + dy + ny, 1 + dz + nz)
            )
        return acc

    def matvec(self, x: jax.Array) -> jax.Array:
        """Apply to an unpadded grid array ``(nx, ny, nz)`` with zero boundary."""
        return self.matvec_padded(jnp.pad(x, 1))

    def conv_matvec_padded(self):
        """Matrix-free stencil apply as a 3x3x3 convolution.

        One streaming pass over the padded input: measured 47.3 -> 23.5
        r-units of HBM traffic per CG iteration at the 27-pt stencil
        (EXPERIMENTS.md §Perf) — matrix-FREE beats the paper's CSR
        accounting because the constant coefficients live in the kernel,
        eliminating the (n̄+1)·r matrix-value reads entirely.  The stencil
        is symmetric, so cross-correlation == convolution.
        """
        k = np.zeros((3, 3, 3), np.float64)
        k[1, 1, 1] = self.diag
        for dx, dy, dz in self.offsets:
            k[1 + dx, 1 + dy, 1 + dz] = self.off_coeff

        def mv(xp: jax.Array) -> jax.Array:
            kern = jnp.asarray(k, xp.dtype)[None, None]  # (O=1, I=1, 3, 3, 3)
            x4 = xp[None, None]                          # (N=1, C=1, X, Y, Z)
            y = jax.lax.conv_general_dilated(x4, kern, (1, 1, 1), "VALID")
            return y[0, 0]

        return mv

    # --- Gauss-Seidel helpers -------------------------------------------------
    def offdiag_apply_padded(self, xp: jax.Array) -> jax.Array:
        """(A - D) x on a padded array."""
        nx, ny, nz = xp.shape[0] - 2, xp.shape[1] - 2, xp.shape[2] - 2
        acc = jnp.zeros((nx, ny, nz), xp.dtype)
        for dx, dy, dz in self.offsets:
            acc = acc + self.off_coeff * jax.lax.slice(
                xp, (1 + dx, 1 + dy, 1 + dz), (1 + dx + nx, 1 + dy + ny, 1 + dz + nz)
            )
        return acc

    def plane_offdiag_apply(self, xp: jax.Array, k: jax.Array) -> jax.Array:
        """(A - D) x restricted to z-plane ``k`` of the interior.

        ``xp`` is the fully padded array; ``k`` may be traced (used inside the
        plane-sweep relaxed Gauss-Seidel loops).
        """
        nx, ny = xp.shape[0] - 2, xp.shape[1] - 2
        acc = jnp.zeros((nx, ny), xp.dtype)
        for dx, dy, dz in self.offsets:
            plane = jax.lax.dynamic_slice(
                xp, (1 + dx, 1 + dy, k + 1 + dz), (nx, ny, 1)
            )[:, :, 0]
            acc = acc + self.off_coeff * plane
        return acc


# -----------------------------------------------------------------------------
# Interior/boundary-shell split (the overlapped halo-exchange SpMV)
# -----------------------------------------------------------------------------
# The split is the task-based stencil decomposition of the paper's
# exchange_externals + SpMV: output cells at distance >= 1 from every
# decomposed face read no exchanged halo, so they can be computed while the
# ppermutes are in flight; only the one-cell-thick boundary shell waits for
# the received planes.  Both functions delegate the actual apply to a
# ``matvec_padded`` callable, so the slice-add, conv and Pallas formulations
# all split the same way.  Each output element's arithmetic is
# position-independent, so the split reproduces the monolithic apply exactly
# up to the compiler's per-shape FMA contraction choices; in the solver
# programs the results are bit-for-bit identical across halo modes
# (asserted by tests/test_halo_overlap.py on 7pt/27pt × 1-D/3-D layouts).

def interior_matvec(mv_padded, x: jax.Array,
                    split_dims: Sequence[int]) -> jax.Array:
    """Apply the stencil to the halo-independent interior of a local block.

    ``x`` is the UNPADDED local block.  Along each dim in ``split_dims`` the
    block itself provides the one-cell support of its interior (output extent
    ``n-2``); unsplit dims get the usual zero halo (physical boundary).
    """
    pad = [(0, 0) if d in split_dims else (1, 1) for d in range(3)]
    return mv_padded(jnp.pad(x, pad))


def shell_assemble(mv_padded, xp: jax.Array, y_interior: jax.Array,
                   split_dims: Sequence[int]) -> jax.Array:
    """Finish the split apply: boundary-shell slabs from the exchanged
    padded array ``xp``, concatenated around ``y_interior``.

    Slabs are computed per split dim (outermost last) over the still-interior
    extent of the dims assembled before them, so edge/corner cells are
    produced exactly once per assembly step from the same ``xp`` values the
    monolithic apply reads.
    """
    y = y_interior
    done: set[int] = set()
    for d in sorted(split_dims, reverse=True):
        def slab(lo: bool) -> jax.Array:
            starts, limits = [], []
            for e in range(3):
                pe = xp.shape[e]
                if e == d:                     # 3 planes -> 1 output plane
                    s = 0 if lo else pe - 3
                    starts.append(s)
                    limits.append(s + 3)
                elif e in split_dims and e not in done:
                    starts.append(1)           # dim still at interior extent
                    limits.append(pe - 1)
                else:
                    starts.append(0)           # assembled/unsplit: full extent
                    limits.append(pe)
            return mv_padded(jax.lax.slice(xp, starts, limits))

        y = jnp.concatenate([slab(True), y, slab(False)], axis=d)
        done.add(d)
    return y


# HPCCG's generator (the paper's host code) puts 27.0 on the diagonal and -1
# on every neighbour, for BOTH sparsity levels.  This makes the 7-pt matrix
# strongly diagonally dominant (27 vs 6), which is what yields the paper's
# §4.1 iteration counts (e.g. Jacobi converging in 18 iterations at 128^3);
# the 27-pt matrix is near-marginally dominant (27 vs 26) and converges slowly
# (515 Jacobi iterations).  Validated in benchmarks/table_iterations.py.
STENCIL_7PT = Stencil(name="7pt", offsets=_offsets_7pt(), diag=27.0)
STENCIL_27PT = Stencil(name="27pt", offsets=_offsets_27pt(), diag=27.0)

STENCILS = {"7pt": STENCIL_7PT, "27pt": STENCIL_27PT}


# -----------------------------------------------------------------------------
# ELLPACK general-sparse path (oracle + unstructured matrices)
# -----------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ELLOperator:
    """ELLPACK sparse matrix: fixed nonzeros-per-row, masked.

    ``indices``: (rows, k) int32 column ids (any value where mask is 0).
    ``values`` : (rows, k) float coefficients (0 where masked out).
    TPU note: the gather in ``matvec`` lowers to ``jnp.take`` — acceptable for
    moderate k, but the stencil path should be preferred for HPCG matrices.
    """

    indices: jax.Array
    values: jax.Array

    def tree_flatten(self):
        return (self.indices, self.values), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def rows(self) -> int:
        return self.indices.shape[0]

    def matvec(self, x: jax.Array) -> jax.Array:
        flat = x.reshape(-1)
        gathered = jnp.take(flat, self.indices, axis=0)  # (rows, k)
        y = jnp.sum(self.values * gathered, axis=1)
        return y.reshape(x.shape)


def build_ell_from_stencil(stencil: Stencil, shape: tuple[int, int, int]) -> ELLOperator:
    """Materialise the stencil on ``shape`` as an ELL matrix (host-side)."""
    nx, ny, nz = shape
    n = nx * ny * nz
    k = stencil.npoint
    idx = np.zeros((n, k), dtype=np.int32)
    val = np.zeros((n, k), dtype=np.float64)
    grid = np.arange(n).reshape(shape)
    # slot 0: diagonal
    idx[:, 0] = np.arange(n)
    val[:, 0] = stencil.diag
    for s, (dx, dy, dz) in enumerate(stencil.offsets, start=1):
        I, J, K = np.meshgrid(np.arange(nx), np.arange(ny), np.arange(nz), indexing="ij")
        In, Jn, Kn = I + dx, J + dy, K + dz
        ok = (
            (In >= 0) & (In < nx) & (Jn >= 0) & (Jn < ny) & (Kn >= 0) & (Kn < nz)
        )
        neigh = grid[np.clip(In, 0, nx - 1), np.clip(Jn, 0, ny - 1), np.clip(Kn, 0, nz - 1)]
        idx[:, s] = np.where(ok, neigh, 0).reshape(-1)
        val[:, s] = np.where(ok, stencil.off_coeff, 0.0).reshape(-1)
    return ELLOperator(indices=jnp.asarray(idx), values=jnp.asarray(val))


def build_dense_from_stencil(stencil: Stencil, shape: tuple[int, int, int]) -> np.ndarray:
    """Dense matrix for tiny grids — used by tests against numpy/scipy solves."""
    ell = build_ell_from_stencil(stencil, shape)
    n = int(np.prod(shape))
    A = np.zeros((n, n))
    idx = np.asarray(ell.indices)
    val = np.asarray(ell.values)
    for r in range(n):
        for c, v in zip(idx[r], val[r]):
            A[r, c] += v
    return A


def touched_elements_per_iter(method: str, nbar: int) -> int:
    """Paper §3.1 analytic memory-traffic model, elements touched per row.

    CG: (12+n̄)r, CG-NB: (15+n̄)r, BiCGStab: (21+2n̄)r, BiCGStab-B1: (24+2n̄)r.
    Jacobi/GS counts derived with the same accounting (SpMV reads n̄+1 per row
    incl. the row of coefficients, plus the vector traffic of the updates).
    """
    table = {
        "cg": 12 + nbar,
        "cg_nb": 15 + nbar,
        "bicgstab": 21 + 2 * nbar,
        "bicgstab_b1": 24 + 2 * nbar,
        # preconditioned forms: the baseline's traffic + the z (pcg) or
        # phat/shat (pbicgstab) vector updates; the preconditioner apply's
        # own traffic is accounted separately (Preconditioner.
        # touched_elements_per_apply × SolverSpec.precond_applies_per_iter)
        "pcg": 16 + nbar,
        "pbicgstab": 27 + 2 * nbar,
        # reduction-hiding variants (PR 4), same accounting (3 per
        # two-operand vector update, dot reads folded in like cg's 12):
        # merged CG adds the s = A p recurrence (+3 over cg); pipelined CG
        # adds z and the w recurrence on top (+6 over merged); the
        # preconditioned forms add the u/q image traffic like pcg does;
        # merged BiCGStab streams 8 recurrence updates + 9 fused dots.
        "cg_merged": 15 + nbar,
        "cg_pipe": 21 + nbar,
        "pcg_merged": 19 + nbar,
        "pcg_pipe": 28 + nbar,
        "bicgstab_merged": 33 + 2 * nbar,
        "pbicgstab_merged": 33 + 2 * nbar,
        "jacobi": 4 + nbar,
        "gauss_seidel": 6 + 2 * nbar,
        # red-black symmetric GS: 4 coloured half-sweeps + residual, each
        # half-sweep streams the full offdiag stencil (same accounting as
        # the relaxed variant; the colouring changes convergence, not the
        # per-sweep traffic)
        "gauss_seidel_rb": 6 + 2 * nbar,
    }
    return table[method]
