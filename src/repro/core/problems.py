"""HPCG/HPCCG problem generation (paper §4.1).

The linear system is the standard HPCG one: a centred stencil on a 3-D
hexahedral mesh, with the right-hand side defined analytically for the exact
solution ``x* = 1`` and the iterate initialised to ``x0 = 0``.  Convergence is
declared at ``||r||_2 < eps * ||b||_2`` with ``eps = 1e-6`` (x0 = 0 makes this
identical to the relative-to-r0 criterion), and the BiCGStab restart threshold
is ``1e-5`` (paper §4.1).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.operators import STENCILS, Stencil


def enable_f64() -> None:
    """Paper runs in double precision; call before building f64 problems."""
    jax.config.update("jax_enable_x64", True)


def default_dtype():
    """float64 when x64 is enabled (solver/benchmark paths), else float32."""
    return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32


@dataclasses.dataclass(frozen=True)
class HPCGProblem:
    stencil: Stencil
    shape: tuple[int, int, int]
    dtype: object

    @property
    def rows(self) -> int:
        nx, ny, nz = self.shape
        return nx * ny * nz

    def b(self) -> jax.Array:
        """RHS for x* = 1: b = A @ ones (zero in the interior for HPCG-27)."""
        ones = jnp.ones(self.shape, self.dtype)
        return self.stencil.matvec(ones)

    def x0(self) -> jax.Array:
        return jnp.zeros(self.shape, self.dtype)

    def x_true(self) -> jax.Array:
        return jnp.ones(self.shape, self.dtype)


def make_problem(
    shape: tuple[int, int, int] = (128, 128, 128),
    stencil: str = "27pt",
    dtype=None,
) -> HPCGProblem:
    if stencil not in STENCILS:
        raise ValueError(f"unknown stencil {stencil!r}; options: {sorted(STENCILS)}")
    return HPCGProblem(
        stencil=STENCILS[stencil], shape=tuple(shape), dtype=dtype or default_dtype()
    )
