"""Solver functions — the callable surface over ``repro.core.methods``.

Since PR 5 every algorithm is defined exactly ONCE as a
``repro.core.methods.MethodDef`` (init/step/finalize + declared state
layout) and executed by the generic ``run_method`` driver; this module
derives the familiar solver functions

    cg(A, b, x0, *, tol=1e-6, maxiter=500, dot=None, norm_ref=None)

from those definitions, so existing callers (and the paper-faithful
``SOLVERS`` / ``VARIANT_OF`` tables) keep working unchanged.  The same
definitions drive ``core.distributed.solve_shardmap`` /
``solve_step_shardmap`` and the fused Pallas path — the paper's design
where the algorithm is written once and the parallelisation (MPI /
MPI+tasks) is swapped underneath.

``LocalOp`` is the single-device operator (zero-padded halos == physical
boundary); its distributed counterpart is
``repro.core.distributed.DistributedOp`` (halos via ``lax.ppermute``,
reductions via ``lax.psum``) — both satisfy the operator protocol the
method definitions are written against.

The algorithmic commentary (barrier structure per §3.1/Fig. 1, the Alg. 1
sign-convention note, the reduction-hiding recurrences and their numerical
caveats) lives with the definitions in ``repro.core.methods``.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.methods import (  # noqa: F401  (compat re-exports)
    METHODS,
    MethodDef,
    Ops,
    SolveResult,
    _cg_merged_scalars,
    _colour_mask,
    _default_dot,
    _hist_init,
    _plane_sweep,
    _rb_half_sweep,
    _stacked_dot,
    get_method,
    run_method,
)
from repro.core.operators import Stencil


class LocalOp:
    """Single-device stencil operator (zero halos == physical boundary)."""

    def __init__(self, stencil: Stencil, matvec_padded: Callable | None = None):
        self.stencil = stencil
        self._mv_padded = matvec_padded or stencil.matvec_padded

    @property
    def diag(self) -> float:
        return self.stencil.diag

    def pad_exchange(self, x: jax.Array) -> jax.Array:
        return jnp.pad(x, 1)

    def matvec(self, x: jax.Array) -> jax.Array:
        return self._mv_padded(self.pad_exchange(x))

    def matvec_local(self, x: jax.Array) -> jax.Array:
        """Zero-halo apply on the local block (block-Jacobi's inner operator).
        On a single device the block IS the domain, so this == matvec."""
        return self.matvec(x)

    def dotn(self, *pairs) -> tuple:
        """Stacked dot products — locally just the dots (no collective to
        fuse); ``DistributedOp.dotn`` is the one-psum version."""
        return tuple(jnp.vdot(a, b) for a, b in pairs)

    def sum_partials(self, *vals) -> tuple:
        """Reduce already-computed local partial scalars globally — locally
        the identity (``DistributedOp.sum_partials`` is the one-psum
        version); the fused kernels' dot partials ride this."""
        return vals


def make_solver(name: str) -> Callable:
    """The classic ``solver(A, b, x0, *, tol, maxiter, dot, norm_ref, ...)``
    callable for one registered MethodDef (plus ``M=`` for the
    preconditioned methods and the definition's declared tuning knobs —
    e.g. ``eps_restart=`` for bicgstab_b1 — threaded through
    ``Ops.params``).  This is the ``fn`` a registry entry for a new method
    should point at (docs/API.md §"Authoring a new method").
    """
    mdef = get_method(name)

    def solver(A, b, x0, *, tol=1e-6, maxiter=None, dot=None, norm_ref=None,
               M=None, telemetry=0, guard_spec=None, refresh_every=0,
               **params) -> SolveResult:
        if M is not None and not mdef.accepts_precond:
            raise TypeError(f"{name!r} takes no preconditioner (M=)")
        unknown = set(params) - set(mdef.params)
        if unknown:
            raise TypeError(
                f"{name}() got unexpected keyword argument(s) "
                f"{sorted(unknown)}; this method accepts "
                f"{sorted(mdef.params) or 'no extra parameters'}")
        ops = Ops(A, b, M=M, dot=dot, norm_ref=norm_ref, params=params)
        return run_method(mdef, ops, x0, tol=tol, maxiter=maxiter,
                          telemetry=telemetry, guard_spec=guard_spec,
                          refresh_every=refresh_every)

    solver.__name__ = name
    solver.__qualname__ = name
    solver.__doc__ = (mdef.step.__doc__ or "") + (
        "\n\n(Defined once in repro.core.methods; this callable runs the "
        "definition on the local/LocalOp protocol via run_method.)")
    solver.method_def = mdef
    return solver


cg = make_solver("cg")
cg_nb = make_solver("cg_nb")
pcg = make_solver("pcg")
cg_merged = make_solver("cg_merged")
pcg_merged = make_solver("pcg_merged")
cg_pipe = make_solver("cg_pipe")
pcg_pipe = make_solver("pcg_pipe")
bicgstab = make_solver("bicgstab")
pbicgstab = make_solver("pbicgstab")
bicgstab_b1 = make_solver("bicgstab_b1")
bicgstab_merged = make_solver("bicgstab_merged")
pbicgstab_merged = make_solver("pbicgstab_merged")
jacobi = make_solver("jacobi")
sym_gauss_seidel_relaxed = make_solver("gauss_seidel")
sym_gauss_seidel_rb = make_solver("gauss_seidel_rb")

SOLVERS: dict[str, Callable] = {
    "jacobi": jacobi,
    "gauss_seidel": sym_gauss_seidel_relaxed,
    "gauss_seidel_rb": sym_gauss_seidel_rb,
    "cg": cg,
    "cg_nb": cg_nb,
    "cg_merged": cg_merged,
    "cg_pipe": cg_pipe,
    "pcg": pcg,
    "pcg_merged": pcg_merged,
    "pcg_pipe": pcg_pipe,
    "bicgstab": bicgstab,
    "bicgstab_b1": bicgstab_b1,
    "bicgstab_merged": bicgstab_merged,
    "pbicgstab": pbicgstab,
    "pbicgstab_merged": pbicgstab_merged,
}

#: methods refining a classical baseline mapped to that baseline — derived
#: from the MethodDefs (single source); the registry cross-checks it.
VARIANT_OF = {name: m.variant_of for name, m in METHODS.items()
              if m.variant_of is not None}
