"""The paper's iterative methods (Alg. 1-2 + Jacobi + symmetric Gauss-Seidel).

Every solver is a pure, jittable JAX function built on ``lax.while_loop``.
They are written against a small operator protocol so the *same* code runs:

  * single-device  — ``LocalOp`` (zero-padded halos), and
  * multi-device   — ``repro.core.distributed.DistributedOp`` (halos via
    ``lax.ppermute``, reductions via ``lax.psum``) inside ``shard_map``.

That mirrors the paper's design where the algorithm is written once and the
parallelisation (MPI / MPI+tasks) is swapped underneath.

Barrier structure reproduced from the paper (§3.1, Fig. 1):

  * ``cg``            — 2 blocking reductions / iteration.
  * ``cg_nb``         — Alg. 1: the SpMV is applied to ``r`` so ``A·p`` becomes a
                        vector update; both reductions leave the critical path
                        (the ``r·r`` reduction overlaps the SpMV, the ``Ap·p``
                        reduction overlaps the lagged ``x`` update).  NOTE:
                        Alg. 1 line 9 is implemented with the sign convention
                        that keeps ``x_j = x_{j-1} + α_{j-1} p_{j-1}`` (the
                        printed minus sign is a typo — with it the recursion
                        contradicts line 4).  Equivalence with classical CG is
                        asserted by tests/test_solvers.py.
  * ``bicgstab``      — 3 blocking reductions / iteration.
  * ``bicgstab_b1``   — Alg. 2: ω's reductions overlap the ``x_{j+1/2}`` update,
                        the ``α_n``/``β`` reductions overlap the ``p_{j+1/2}``
                        update; one blocking reduction (``α_d``) remains.
                        Includes the restart procedure (lines 13-15).
  * ``jacobi``        — 1 reduction (the residual norm).
  * ``sym_gauss_seidel_relaxed`` — the paper's *relaxed* tasked GS adapted to
                        TPU: GS-fresh across z-planes inside a block, stale
                        across blocks (the role the benign data races play in
                        the paper's Code 4).
  * ``sym_gauss_seidel_rb``      — red-black coloured symmetric GS (§3.4).

Beyond the paper (PR 3): ``pcg`` / ``pbicgstab`` are the preconditioned
forms of the classical methods, written against the same operator protocol
plus one extra hook — ``M``, the bound ``z = M^{-1} r`` apply built by
``repro.precond`` (point-Jacobi, block-Jacobi, SSOR, Chebyshev).  With
``M=None`` they reduce arithmetically to ``cg`` / ``bicgstab``; convergence
is always judged on the TRUE residual so iteration counts stay comparable
across preconditioners.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.operators import Stencil


class SolveResult(NamedTuple):
    x: jax.Array
    iters: jax.Array          # number of completed iterations
    res_norm: jax.Array       # final ||r||_2 (method's own residual estimate)
    history: jax.Array        # (maxiter+1,) residual-norm history, NaN-padded


class LocalOp:
    """Single-device stencil operator (zero halos == physical boundary)."""

    def __init__(self, stencil: Stencil, matvec_padded: Callable | None = None):
        self.stencil = stencil
        self._mv_padded = matvec_padded or stencil.matvec_padded

    @property
    def diag(self) -> float:
        return self.stencil.diag

    def pad_exchange(self, x: jax.Array) -> jax.Array:
        return jnp.pad(x, 1)

    def matvec(self, x: jax.Array) -> jax.Array:
        return self._mv_padded(self.pad_exchange(x))

    def matvec_local(self, x: jax.Array) -> jax.Array:
        """Zero-halo apply on the local block (block-Jacobi's inner operator).
        On a single device the block IS the domain, so this == matvec."""
        return self.matvec(x)


def _default_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.vdot(a, b)


def _prepare(A, b, dot, norm_ref, tol):
    dot = dot or _default_dot
    if norm_ref is None:
        norm_ref = jnp.sqrt(dot(b, b))
    thresh2 = (tol * norm_ref) ** 2
    return dot, norm_ref, thresh2


def _hist_init(maxiter: int, v0, dtype) -> jax.Array:
    h = jnp.full((maxiter + 1,), jnp.nan, dtype=dtype)
    return h.at[0].set(v0.astype(dtype))


# =============================================================================
# Krylov methods
# =============================================================================

def cg(A, b, x0, *, tol=1e-6, maxiter=500, dot=None, norm_ref=None) -> SolveResult:
    """Classical conjugate gradient (HPCCG reference; 2 blocking reductions)."""
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    r = b - A.matvec(x0)
    p = r
    rr = dot(r, r)
    hist = _hist_init(maxiter, jnp.sqrt(rr), b.dtype)

    def cond(c):
        _, _, _, rr, k, _ = c
        return (rr >= thresh2) & (k < maxiter)

    def body(c):
        x, r, p, rr, k, hist = c
        Ap = A.matvec(p)
        pAp = dot(p, Ap)              # blocking: feeds alpha immediately
        alpha = rr / pAp
        x = x + alpha * p
        r = r - alpha * Ap
        rr_new = dot(r, r)            # blocking: feeds beta before next SpMV
        beta = rr_new / rr
        p = r + beta * p
        hist = hist.at[k + 1].set(jnp.sqrt(rr_new).astype(hist.dtype))
        return (x, r, p, rr_new, k + 1, hist)

    x, r, p, rr, k, hist = lax.while_loop(cond, body, (x0, r, p, rr, 0, hist))
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(rr), history=hist)


def cg_nb(A, b, x0, *, tol=1e-6, maxiter=500, dot=None, norm_ref=None) -> SolveResult:
    """Nonblocking CG (paper Alg. 1).

    The SpMV is applied to ``r_j``; ``A·p_j`` is reconstructed as a vector
    update (line 6).  Both reductions are off the critical path: the dataflow
    successor of ``α_n = r·r`` is line 6 which *follows* the SpMV, and the
    successor of ``α_d`` is the *next* iteration's ``α``, past the lagged
    ``x`` update (line 9).  Costs (15+n̄)r touched elements vs CG's (12+n̄)r.
    """
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    r = b - A.matvec(x0)
    p = r
    Ap = A.matvec(p)
    an = dot(r, r)
    ad = dot(Ap, p)
    hist = _hist_init(maxiter, jnp.sqrt(an), b.dtype)

    def cond(c):
        _, _, _, _, an, _, k, _ = c
        return (an >= thresh2) & (k < maxiter)

    def body(c):
        x, r, p, Ap, an, ad, k, hist = c
        alpha = an / ad                       # α_{j-1}
        r_new = r - alpha * Ap                # Tk 0 (line 4)
        an_new = dot(r_new, r_new)            # Tk 0 (line 5) — reduction in flight...
        Ar = A.matvec(r_new)                  # ...overlapped with this SpMV
        beta = an_new / an
        Ap_new = Ar + beta * Ap               # Tk 1 & 2 (line 6) — no SpMV on p!
        p_new = r_new + beta * p              # Tk 2 (line 7)
        ad_new = dot(Ap_new, p_new)           # Tk 2 (line 8) — overlapped with...
        x = x + alpha * p                     # Tk 3 (line 9, sign-fixed; uses OLD p)
        hist = hist.at[k + 1].set(jnp.sqrt(an_new).astype(hist.dtype))
        return (x, r_new, p_new, Ap_new, an_new, ad_new, k + 1, hist)

    x, r, p, Ap, an, ad, k, hist = lax.while_loop(
        cond, body, (x0, r, p, Ap, an, ad, 0, hist)
    )
    # The x update lags one iteration; apply the final correction term.
    x = x + (an / ad) * p
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(an), history=hist)


def pcg(A, b, x0, *, tol=1e-6, maxiter=500, dot=None, norm_ref=None,
        M=None) -> SolveResult:
    """Preconditioned CG.

    ``M`` is the bound ``z = M^{-1} r`` apply (``repro.precond``; must be
    SPD-preserving — the registry's ``spd_preserving`` flag).  ``M=None``
    is the identity, which makes pcg arithmetically identical to ``cg``.
    3 reductions/iter: ``p·Ap`` blocks, ``r·z`` blocks (feeds β), ``r·r``
    only feeds the convergence check and overlaps the next apply.  The
    check stays on the TRUE residual ``||r||`` (not the M-norm), so
    iteration counts are comparable with ``cg`` at the same tolerance.
    """
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    apply_M = M if M is not None else (lambda v: v)
    r = b - A.matvec(x0)
    z = apply_M(r)
    p = z
    rz = dot(r, z)
    rr = dot(r, r)
    hist = _hist_init(maxiter, jnp.sqrt(rr), b.dtype)

    def cond(c):
        _, _, _, _, rr, k, _ = c
        return (rr >= thresh2) & (k < maxiter)

    def body(c):
        x, r, p, rz, rr, k, hist = c
        Ap = A.matvec(p)
        pAp = dot(p, Ap)              # blocking: feeds alpha immediately
        alpha = rz / pAp
        x = x + alpha * p
        r = r - alpha * Ap
        z = apply_M(r)
        rz_new = dot(r, z)            # blocking: feeds beta
        rr_new = dot(r, r)            # check only: overlaps the next apply
        beta = rz_new / rz
        p = z + beta * p
        hist = hist.at[k + 1].set(jnp.sqrt(rr_new).astype(hist.dtype))
        return (x, r, p, rz_new, rr_new, k + 1, hist)

    x, r, p, rz, rr, k, hist = lax.while_loop(
        cond, body, (x0, r, p, rz, rr, 0, hist))
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(rr), history=hist)


def bicgstab(A, b, x0, *, tol=1e-6, maxiter=500, dot=None, norm_ref=None) -> SolveResult:
    """Classical BiCGStab (3 blocking reductions per iteration)."""
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    r = b - A.matvec(x0)
    rhat = r
    p = r
    rho = dot(rhat, r)
    rr = dot(r, r)
    hist = _hist_init(maxiter, jnp.sqrt(rr), b.dtype)

    def cond(c):
        _, _, _, _, rho, rr, k, _ = c
        return (rr >= thresh2) & (k < maxiter)

    def body(c):
        x, r, rhat, p, rho, rr, k, hist = c
        v = A.matvec(p)
        rhat_v = dot(rhat, v)                 # barrier 1
        alpha = rho / rhat_v
        s = r - alpha * v
        t = A.matvec(s)
        ts = dot(t, s)                        # barrier 2 (fused pair of dots)
        tt = dot(t, t)
        omega = ts / tt
        x = x + alpha * p + omega * s
        r = s - omega * t
        rho_new = dot(rhat, r)                # barrier 3 (fused pair of dots)
        rr_new = dot(r, r)
        beta = (rho_new / rho) * (alpha / omega)
        p = r + beta * (p - omega * v)
        hist = hist.at[k + 1].set(jnp.sqrt(rr_new).astype(hist.dtype))
        return (x, r, rhat, p, rho_new, rr_new, k + 1, hist)

    x, r, rhat, p, rho, rr, k, hist = lax.while_loop(
        cond, body, (x0, r, rhat, p, rho, rr, 0, hist)
    )
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(rr), history=hist)


def pbicgstab(A, b, x0, *, tol=1e-6, maxiter=500, dot=None, norm_ref=None,
              M=None) -> SolveResult:
    """Right-preconditioned BiCGStab (``A M^{-1} y = b``, ``x = M^{-1} y``).

    Right preconditioning keeps ``r`` the TRUE residual, so the stopping
    criterion and iteration counts are directly comparable with
    ``bicgstab``; ``M`` need not be SPD-preserving.  ``M=None`` reduces
    arithmetically to classical BiCGStab.  Barrier structure unchanged
    (3 blocking reduction points) — the two ``M`` applies add stencil
    sweeps but no reductions for the built-in preconditioners.
    """
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    apply_M = M if M is not None else (lambda v: v)
    r = b - A.matvec(x0)
    rhat = r
    p = r
    rho = dot(rhat, r)
    rr = dot(r, r)
    hist = _hist_init(maxiter, jnp.sqrt(rr), b.dtype)

    def cond(c):
        _, _, _, _, rho, rr, k, _ = c
        return (rr >= thresh2) & (k < maxiter)

    def body(c):
        x, r, rhat, p, rho, rr, k, hist = c
        phat = apply_M(p)
        v = A.matvec(phat)
        rhat_v = dot(rhat, v)                 # barrier 1
        alpha = rho / rhat_v
        s = r - alpha * v
        shat = apply_M(s)
        t = A.matvec(shat)
        ts = dot(t, s)                        # barrier 2 (fused pair of dots)
        tt = dot(t, t)
        omega = ts / tt
        x = x + alpha * phat + omega * shat
        r = s - omega * t
        rho_new = dot(rhat, r)                # barrier 3 (fused pair of dots)
        rr_new = dot(r, r)
        beta = (rho_new / rho) * (alpha / omega)
        p = r + beta * (p - omega * v)
        hist = hist.at[k + 1].set(jnp.sqrt(rr_new).astype(hist.dtype))
        return (x, r, rhat, p, rho_new, rr_new, k + 1, hist)

    x, r, rhat, p, rho, rr, k, hist = lax.while_loop(
        cond, body, (x0, r, rhat, p, rho, rr, 0, hist)
    )
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(rr), history=hist)


def bicgstab_b1(
    A, b, x0, *, tol=1e-6, maxiter=500, dot=None, norm_ref=None,
    eps_restart=1e-5,
) -> SolveResult:
    """BiCGStab one-blocking (paper Alg. 2) with the restart procedure.

    Only ``α_d = (A·p)·r'`` blocks; ω's pair of reductions overlaps the
    ``x_{j+1/2}`` update (Tk 3) and the ``α_n``/``β`` pair overlaps the
    ``p_{j+1/2}`` update (Tk 5).  Restart (lines 13-15) triggers on
    ``sqrt(|α_n|) < ε_restart·||b||`` and re-orthogonalises ``r'``,
    eliminating the near-breakdown amplification (and, in the paper's task
    world, accumulated nondeterministic rounding).
    """
    dot, norm_ref, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    restart_thresh = eps_restart * norm_ref
    r = b - A.matvec(x0)
    p = r
    beta_rr = dot(r, r)                        # β_0 = r_0·r_0
    rhat = r / jnp.sqrt(beta_rr)               # r'
    an = dot(r, rhat)                          # α_{n,0} = sqrt(β_0)
    hist = _hist_init(maxiter, jnp.sqrt(beta_rr), b.dtype)

    def cond(c):
        _, _, _, _, an, beta_rr, k, _, _ = c
        return (beta_rr >= thresh2) & (k < maxiter)     # line 7 check

    def body(c):
        x, r, p, rhat, an, beta_rr, k, hist, nrestart = c
        Ap = A.matvec(p)
        ad = dot(Ap, rhat)                    # Tk 0 (line 3) — the ONE blocking reduction
        alpha = an / ad
        s = r - alpha * Ap                    # Tk 1 (line 4)
        As = A.matvec(s)
        ts = dot(As, s)                       # Tk 2 (line 5) — overlapped with...
        tt = dot(As, As)
        # optimization_barrier = the Tk-3-is-its-own-task constraint: without
        # it XLA fuses this update into the omega-dependent x_{j+1} and the
        # overlap window vanishes (measured: slack 4096 -> 0 bytes)
        x_half = lax.optimization_barrier(x + alpha * p)   # ...Tk 3 (line 6)
        omega = ts / tt
        x_new = x_half + omega * s            # Tk 4 (line 8; == line 18 on exit)
        r_new = s - omega * As                # Tk 4 (line 9)
        an_new = dot(r_new, rhat)             # Tk 4 (line 10) — overlapped with...
        beta_rr_new = dot(r_new, r_new)       # Tk 4 (line 11)
        p_half = lax.optimization_barrier(p - omega * Ap)  # ...Tk 5 (line 12)
        restart = jnp.sqrt(jnp.abs(an_new)) < restart_thresh
        p_reg = r_new + (an_new / (ad * omega)) * p_half   # Tk 7 (line 17)
        p_new = jnp.where(restart, r_new, p_reg)           # Tk 6 (line 14)
        rhat_new = jnp.where(restart, r_new / jnp.sqrt(beta_rr_new), rhat)  # line 15
        an_next = jnp.where(restart, jnp.sqrt(beta_rr_new), an_new)
        hist = hist.at[k + 1].set(jnp.sqrt(beta_rr_new).astype(hist.dtype))
        return (x_new, r_new, p_new, rhat_new, an_next, beta_rr_new, k + 1,
                hist, nrestart + restart.astype(jnp.int32))

    x, r, p, rhat, an, beta_rr, k, hist, nrestart = lax.while_loop(
        cond, body, (x0, r, p, rhat, an, beta_rr, 0, hist, jnp.int32(0))
    )
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(beta_rr), history=hist)


# =============================================================================
# Stationary methods
# =============================================================================

def jacobi(A, b, x0, *, tol=1e-6, maxiter=1000, dot=None, norm_ref=None) -> SolveResult:
    """Jacobi: x += D^{-1} r; one SpMV + one reduction per iteration."""
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    r = b - A.matvec(x0)
    rr = dot(r, r)
    hist = _hist_init(maxiter, jnp.sqrt(rr), b.dtype)

    def cond(c):
        _, _, rr, k, _ = c
        return (rr >= thresh2) & (k < maxiter)

    def body(c):
        x, r, rr, k, hist = c
        x = x + r / A.diag
        r = b - A.matvec(x)
        rr = dot(r, r)
        hist = hist.at[k + 1].set(jnp.sqrt(rr).astype(hist.dtype))
        return (x, r, rr, k + 1, hist)

    x, r, rr, k, hist = lax.while_loop(cond, body, (x0, r, rr, 0, hist))
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(rr), history=hist)


def _plane_sweep(A, b, x, *, forward: bool) -> jax.Array:
    """One relaxed Gauss-Seidel sweep: GS-fresh across z-planes, Jacobi within
    a plane, stale across device blocks (halos exchanged once per sweep)."""
    nz = x.shape[2]

    def step(i, xp):
        k = i if forward else nz - 1 - i
        off = A.stencil.plane_offdiag_apply(xp, k)
        plane = (b[:, :, k] - off) / A.diag
        return lax.dynamic_update_slice(xp, plane[:, :, None], (1, 1, k + 1))

    xp = A.pad_exchange(x)
    xp = lax.fori_loop(0, nz, step, xp)
    return xp[1:-1, 1:-1, 1:-1]


def sym_gauss_seidel_relaxed(
    A, b, x0, *, tol=1e-6, maxiter=1000, dot=None, norm_ref=None
) -> SolveResult:
    """Relaxed symmetric GS (paper §3.4 Code 4, TPU adaptation).

    Forward sweep (ascending z-planes) then backward sweep (descending), each
    using the freshest available plane values — the deterministic analogue of
    the paper's benign data races that "mimic the Gauss-Seidel behaviour".
    """
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    r = b - A.matvec(x0)
    rr = dot(r, r)
    hist = _hist_init(maxiter, jnp.sqrt(rr), b.dtype)

    def cond(c):
        _, rr, k, _ = c
        return (rr >= thresh2) & (k < maxiter)

    def body(c):
        x, rr, k, hist = c
        x = _plane_sweep(A, b, x, forward=True)
        x = _plane_sweep(A, b, x, forward=False)
        r = b - A.matvec(x)
        rr = dot(r, r)
        hist = hist.at[k + 1].set(jnp.sqrt(rr).astype(hist.dtype))
        return (x, rr, k + 1, hist)

    x, rr, k, hist = lax.while_loop(cond, body, (x0, rr, 0, hist))
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(rr), history=hist)


def _colour_mask(shape: tuple[int, int, int], colour: int) -> jax.Array:
    i = lax.broadcasted_iota(jnp.int32, shape, 0)
    j = lax.broadcasted_iota(jnp.int32, shape, 1)
    k = lax.broadcasted_iota(jnp.int32, shape, 2)
    return ((i + j + k) % 2) == colour


def _rb_half_sweep(A, b, x, colour_mask) -> jax.Array:
    off = A.stencil.offdiag_apply_padded(A.pad_exchange(x))
    return jnp.where(colour_mask, (b - off) / A.diag, x)


def sym_gauss_seidel_rb(
    A, b, x0, *, tol=1e-6, maxiter=1000, dot=None, norm_ref=None
) -> SolveResult:
    """Red-black coloured symmetric GS (paper §3.4).

    Forward = red, black; backward = black, red.  Exact GS reordering for the
    7-pt stencil (bipartite); a coloured relaxation for the 27-pt one, with
    correspondingly different convergence (the effect the paper measures).
    """
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    red = _colour_mask(x0.shape, 0)
    black = _colour_mask(x0.shape, 1)
    r = b - A.matvec(x0)
    rr = dot(r, r)
    hist = _hist_init(maxiter, jnp.sqrt(rr), b.dtype)

    def cond(c):
        _, rr, k, _ = c
        return (rr >= thresh2) & (k < maxiter)

    def body(c):
        x, rr, k, hist = c
        x = _rb_half_sweep(A, b, x, red)      # forward
        x = _rb_half_sweep(A, b, x, black)
        x = _rb_half_sweep(A, b, x, black)    # backward
        x = _rb_half_sweep(A, b, x, red)
        r = b - A.matvec(x)
        rr = dot(r, r)
        hist = hist.at[k + 1].set(jnp.sqrt(rr).astype(hist.dtype))
        return (x, rr, k + 1, hist)

    x, rr, k, hist = lax.while_loop(cond, body, (x0, rr, 0, hist))
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(rr), history=hist)


SOLVERS: dict[str, Callable] = {
    "jacobi": jacobi,
    "gauss_seidel": sym_gauss_seidel_relaxed,
    "gauss_seidel_rb": sym_gauss_seidel_rb,
    "cg": cg,
    "cg_nb": cg_nb,
    "pcg": pcg,
    "bicgstab": bicgstab,
    "bicgstab_b1": bicgstab_b1,
    "pbicgstab": pbicgstab,
}

#: methods refining a classical baseline (the paper's variants + the
#: preconditioned forms) mapped to that baseline
VARIANT_OF = {"cg_nb": "cg", "bicgstab_b1": "bicgstab",
              "gauss_seidel": "gauss_seidel_rb",
              "pcg": "cg", "pbicgstab": "bicgstab"}
