"""The paper's iterative methods (Alg. 1-2 + Jacobi + symmetric Gauss-Seidel).

Every solver is a pure, jittable JAX function built on ``lax.while_loop``.
They are written against a small operator protocol so the *same* code runs:

  * single-device  — ``LocalOp`` (zero-padded halos), and
  * multi-device   — ``repro.core.distributed.DistributedOp`` (halos via
    ``lax.ppermute``, reductions via ``lax.psum``) inside ``shard_map``.

That mirrors the paper's design where the algorithm is written once and the
parallelisation (MPI / MPI+tasks) is swapped underneath.

Barrier structure reproduced from the paper (§3.1, Fig. 1):

  * ``cg``            — 2 blocking reductions / iteration.
  * ``cg_nb``         — Alg. 1: the SpMV is applied to ``r`` so ``A·p`` becomes a
                        vector update; both reductions leave the critical path
                        (the ``r·r`` reduction overlaps the SpMV, the ``Ap·p``
                        reduction overlaps the lagged ``x`` update).  NOTE:
                        Alg. 1 line 9 is implemented with the sign convention
                        that keeps ``x_j = x_{j-1} + α_{j-1} p_{j-1}`` (the
                        printed minus sign is a typo — with it the recursion
                        contradicts line 4).  Equivalence with classical CG is
                        asserted by tests/test_solvers.py.
  * ``bicgstab``      — 3 blocking reductions / iteration.
  * ``bicgstab_b1``   — Alg. 2: ω's reductions overlap the ``x_{j+1/2}`` update,
                        the ``α_n``/``β`` reductions overlap the ``p_{j+1/2}``
                        update; one blocking reduction (``α_d``) remains.
                        Includes the restart procedure (lines 13-15).
  * ``jacobi``        — 1 reduction (the residual norm).
  * ``sym_gauss_seidel_relaxed`` — the paper's *relaxed* tasked GS adapted to
                        TPU: GS-fresh across z-planes inside a block, stale
                        across blocks (the role the benign data races play in
                        the paper's Code 4).
  * ``sym_gauss_seidel_rb``      — red-black coloured symmetric GS (§3.4).

Beyond the paper (PR 3): ``pcg`` / ``pbicgstab`` are the preconditioned
forms of the classical methods, written against the same operator protocol
plus one extra hook — ``M``, the bound ``z = M^{-1} r`` apply built by
``repro.precond`` (point-Jacobi, block-Jacobi, SSOR, Chebyshev).  With
``M=None`` they reduce arithmetically to ``cg`` / ``bicgstab``; convergence
is always judged on the TRUE residual so iteration counts stay comparable
across preconditioners.

Beyond the paper (PR 4) — reduction-hiding variants.  The paper's Alg. 1/2
move reductions *off the critical path* but keep one ``psum`` per dot
product; at scale the per-collective latency itself dominates.  Two further
restructurings (both classical, see Chronopoulos & Gear 1989, Ghysels &
Vanroose 2014, Cools & Vanroose 2017):

  * ``cg_merged`` / ``pcg_merged``       — Chronopoulos–Gear CG: the SpMV is
                        applied to ``r`` (``w = A r``) and ``p·Ap`` is
                        recovered from the Saad recurrence
                        ``α = γ/(δ − βγ/α_prev)`` with ``γ = r·u``,
                        ``δ = w·u``, so ALL dot products of an iteration
                        stack into ONE ``psum``.
  * ``bicgstab_merged`` / ``pbicgstab_merged`` — single-reduction BiCGStab:
                        auxiliary recurrences for ``s = A p``, ``z = A s``,
                        ``w = A r``, ``t = A w`` let every scalar an
                        iteration needs (ω's pair, ρ, ‖r‖² and the α
                        denominator) be formed from NINE dots on vectors
                        already available *before* ω — one stacked ``psum``
                        per iteration (cf. Cools–Vanroose p-BiCGStab).
                        ``pbicgstab_merged`` runs the same core on the
                        right-preconditioned operator ``B = A∘M⁻¹`` with a
                        zero initial guess and recovers ``x = x0 + M⁻¹ y``
                        once at the end (the residual is unchanged by right
                        preconditioning, so stopping stays TRUE-residual).
  * ``cg_pipe`` / ``pcg_pipe``           — Ghysels–Vanroose pipelined CG:
                        the merged reduction is issued at the TOP of the
                        body and the SpMV of the same body (``n = A M w``,
                        on carried state) is dataflow-independent of it, so
                        the latency-hiding scheduler runs the SpMV while
                        the ``psum`` is in flight (the same
                        ``optimization_barrier`` idiom as ``bicgstab_b1``).
                        The price: the convergence check lags one iteration
                        (the freshest ‖r‖ is the previous body's) and two
                        (four, preconditioned) extra vector recurrences.

Numerical caveat: the merged/pipelined forms replace ``p·Ap`` (and, for
BiCGStab, ‖r‖²) with recurrences; rounding makes them drift from the
classics by O(ε·κ) per iteration, which can cost a few extra iterations
near tight tolerances (asserted ≤ +10% by tests/test_reduction_hiding.py)
and puts an O(ε·κ·‖b‖) floor on the attainable residual — in float32 the
pipelined/merged-BiCGStab variants stall near ``1e-6·‖b‖``, so solve in
f64 (the paper's setting) for tight absolute tolerances.
The returned ``res_norm`` is each method's own estimate, like the classics.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.operators import Stencil


class SolveResult(NamedTuple):
    x: jax.Array
    iters: jax.Array          # number of completed iterations
    res_norm: jax.Array       # final ||r||_2 (method's own residual estimate)
    history: jax.Array        # (maxiter+1,) residual-norm history, NaN-padded


class LocalOp:
    """Single-device stencil operator (zero halos == physical boundary)."""

    def __init__(self, stencil: Stencil, matvec_padded: Callable | None = None):
        self.stencil = stencil
        self._mv_padded = matvec_padded or stencil.matvec_padded

    @property
    def diag(self) -> float:
        return self.stencil.diag

    def pad_exchange(self, x: jax.Array) -> jax.Array:
        return jnp.pad(x, 1)

    def matvec(self, x: jax.Array) -> jax.Array:
        return self._mv_padded(self.pad_exchange(x))

    def matvec_local(self, x: jax.Array) -> jax.Array:
        """Zero-halo apply on the local block (block-Jacobi's inner operator).
        On a single device the block IS the domain, so this == matvec."""
        return self.matvec(x)

    def dotn(self, *pairs) -> tuple:
        """Stacked dot products — locally just the dots (no collective to
        fuse); ``DistributedOp.dotn`` is the one-psum version."""
        return tuple(jnp.vdot(a, b) for a, b in pairs)


def _default_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.vdot(a, b)


def _stacked_dot(A, dot):
    """The fused-reduction hook of the merged/pipelined variants.

    Returns ``dotn(*pairs) -> tuple`` computing every pair in ONE global
    reduction.  When the caller passes the operator's own ``dot`` (or none),
    the operator's ``dotn`` is used — ``DistributedOp.dotn`` stacks the
    partials into a single ``psum``, which is the whole point of the merged
    variants.  A foreign ``dot`` override (``SolverOptions.dot``) falls back
    to per-pair calls, preserving its semantics at the cost of the fusion.
    """
    if dot is None or getattr(dot, "__self__", None) is A:
        dn = getattr(A, "dotn", None)
        if dn is not None:
            return dn
    d = dot or _default_dot

    def dotn(*pairs):
        return tuple(d(a, b) for a, b in pairs)

    return dotn


def _prepare(A, b, dot, norm_ref, tol):
    dot = dot or _default_dot
    if norm_ref is None:
        norm_ref = jnp.sqrt(dot(b, b))
    thresh2 = (tol * norm_ref) ** 2
    return dot, norm_ref, thresh2


def _hist_init(maxiter: int, v0, dtype) -> jax.Array:
    h = jnp.full((maxiter + 1,), jnp.nan, dtype=dtype)
    return h.at[0].set(v0.astype(dtype))


# =============================================================================
# Krylov methods
# =============================================================================

def cg(A, b, x0, *, tol=1e-6, maxiter=500, dot=None, norm_ref=None) -> SolveResult:
    """Classical conjugate gradient (HPCCG reference; 2 blocking reductions)."""
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    r = b - A.matvec(x0)
    p = r
    rr = dot(r, r)
    hist = _hist_init(maxiter, jnp.sqrt(rr), b.dtype)

    def cond(c):
        _, _, _, rr, k, _ = c
        return (rr >= thresh2) & (k < maxiter)

    def body(c):
        x, r, p, rr, k, hist = c
        Ap = A.matvec(p)
        pAp = dot(p, Ap)              # blocking: feeds alpha immediately
        alpha = rr / pAp
        x = x + alpha * p
        r = r - alpha * Ap
        rr_new = dot(r, r)            # blocking: feeds beta before next SpMV
        beta = rr_new / rr
        p = r + beta * p
        hist = hist.at[k + 1].set(jnp.sqrt(rr_new).astype(hist.dtype))
        return (x, r, p, rr_new, k + 1, hist)

    x, r, p, rr, k, hist = lax.while_loop(cond, body, (x0, r, p, rr, 0, hist))
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(rr), history=hist)


def cg_nb(A, b, x0, *, tol=1e-6, maxiter=500, dot=None, norm_ref=None) -> SolveResult:
    """Nonblocking CG (paper Alg. 1).

    The SpMV is applied to ``r_j``; ``A·p_j`` is reconstructed as a vector
    update (line 6).  Both reductions are off the critical path: the dataflow
    successor of ``α_n = r·r`` is line 6 which *follows* the SpMV, and the
    successor of ``α_d`` is the *next* iteration's ``α``, past the lagged
    ``x`` update (line 9).  Costs (15+n̄)r touched elements vs CG's (12+n̄)r.
    """
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    r = b - A.matvec(x0)
    p = r
    Ap = A.matvec(p)
    an = dot(r, r)
    ad = dot(Ap, p)
    hist = _hist_init(maxiter, jnp.sqrt(an), b.dtype)

    def cond(c):
        _, _, _, _, an, _, k, _ = c
        return (an >= thresh2) & (k < maxiter)

    def body(c):
        x, r, p, Ap, an, ad, k, hist = c
        alpha = an / ad                       # α_{j-1}
        r_new = r - alpha * Ap                # Tk 0 (line 4)
        an_new = dot(r_new, r_new)            # Tk 0 (line 5) — reduction in flight...
        Ar = A.matvec(r_new)                  # ...overlapped with this SpMV
        beta = an_new / an
        Ap_new = Ar + beta * Ap               # Tk 1 & 2 (line 6) — no SpMV on p!
        p_new = r_new + beta * p              # Tk 2 (line 7)
        ad_new = dot(Ap_new, p_new)           # Tk 2 (line 8) — overlapped with...
        x = x + alpha * p                     # Tk 3 (line 9, sign-fixed; uses OLD p)
        hist = hist.at[k + 1].set(jnp.sqrt(an_new).astype(hist.dtype))
        return (x, r_new, p_new, Ap_new, an_new, ad_new, k + 1, hist)

    x, r, p, Ap, an, ad, k, hist = lax.while_loop(
        cond, body, (x0, r, p, Ap, an, ad, 0, hist)
    )
    # The x update lags one iteration; apply the final correction term.
    x = x + (an / ad) * p
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(an), history=hist)


def pcg(A, b, x0, *, tol=1e-6, maxiter=500, dot=None, norm_ref=None,
        M=None) -> SolveResult:
    """Preconditioned CG.

    ``M`` is the bound ``z = M^{-1} r`` apply (``repro.precond``; must be
    SPD-preserving — the registry's ``spd_preserving`` flag).  ``M=None``
    is the identity, which makes pcg arithmetically identical to ``cg``.
    3 reductions/iter: ``p·Ap`` blocks, ``r·z`` blocks (feeds β), ``r·r``
    only feeds the convergence check and overlaps the next apply.  The
    check stays on the TRUE residual ``||r||`` (not the M-norm), so
    iteration counts are comparable with ``cg`` at the same tolerance.
    """
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    apply_M = M if M is not None else (lambda v: v)
    r = b - A.matvec(x0)
    z = apply_M(r)
    p = z
    rz = dot(r, z)
    rr = dot(r, r)
    hist = _hist_init(maxiter, jnp.sqrt(rr), b.dtype)

    def cond(c):
        _, _, _, _, rr, k, _ = c
        return (rr >= thresh2) & (k < maxiter)

    def body(c):
        x, r, p, rz, rr, k, hist = c
        Ap = A.matvec(p)
        pAp = dot(p, Ap)              # blocking: feeds alpha immediately
        alpha = rz / pAp
        x = x + alpha * p
        r = r - alpha * Ap
        z = apply_M(r)
        rz_new = dot(r, z)            # blocking: feeds beta
        rr_new = dot(r, r)            # check only: overlaps the next apply
        beta = rz_new / rz
        p = z + beta * p
        hist = hist.at[k + 1].set(jnp.sqrt(rr_new).astype(hist.dtype))
        return (x, r, p, rz_new, rr_new, k + 1, hist)

    x, r, p, rz, rr, k, hist = lax.while_loop(
        cond, body, (x0, r, p, rz, rr, 0, hist))
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(rr), history=hist)


def _cg_merged_scalars(gamma, delta, gamma_prev, alpha_prev):
    """β and the Saad-recurrence α of merged/pipelined CG.

    ``α = γ/(δ − βγ/α_prev)`` equals classical CG's ``γ/(p·Ap)`` in exact
    arithmetic; seeding ``γ_prev = inf, α_prev = 1`` makes the first pass
    degenerate to ``β = 0, α = γ/δ`` without a cond.
    """
    beta = gamma / gamma_prev
    alpha = gamma / (delta - beta * gamma / alpha_prev)
    return alpha, beta


def cg_merged(A, b, x0, *, tol=1e-6, maxiter=500, dot=None,
              norm_ref=None) -> SolveResult:
    """Merged-reduction CG (Chronopoulos–Gear): ONE stacked psum/iteration.

    The SpMV is applied to ``r`` (``w = A r``) and both scalars the
    iteration needs — ``γ = r·r`` and ``δ = w·r`` — come out of a single
    stacked reduction; ``p·Ap`` is recovered by the Saad recurrence (see
    ``_cg_merged_scalars``).  Arithmetically equivalent to ``cg`` (checked
    by tests/test_reduction_hiding.py), one extra vector recurrence
    (``s = A p``) of memory traffic.
    """
    dotn = _stacked_dot(A, dot)
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    r = b - A.matvec(x0)
    w = A.matvec(r)
    gamma, delta = dotn((r, r), (w, r))
    hist = _hist_init(maxiter, jnp.sqrt(gamma), b.dtype)
    zero = jnp.zeros_like(b)
    inf = jnp.asarray(jnp.inf, gamma.dtype)
    one = jnp.asarray(1.0, gamma.dtype)

    def cond(c):
        _, _, _, _, _, gamma, _, _, _, k, _ = c
        return (gamma >= thresh2) & (k < maxiter)

    def body(c):
        x, r, p, s, w, gamma, delta, gamma_prev, alpha_prev, k, hist = c
        alpha, beta = _cg_merged_scalars(gamma, delta, gamma_prev, alpha_prev)
        p = r + beta * p
        s = w + beta * s                  # s = A p by recurrence — no SpMV on p
        x = x + alpha * p
        r = r - alpha * s
        w = A.matvec(r)
        gamma_new, delta_new = dotn((r, r), (w, r))   # the ONE reduction
        hist = hist.at[k + 1].set(jnp.sqrt(gamma_new).astype(hist.dtype))
        return (x, r, p, s, w, gamma_new, delta_new, gamma, alpha, k + 1, hist)

    x, r, p, s, w, gamma, delta, _, _, k, hist = lax.while_loop(
        cond, body, (x0, r, zero, zero, w, gamma, delta, inf, one, 0, hist))
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(gamma), history=hist)


def pcg_merged(A, b, x0, *, tol=1e-6, maxiter=500, dot=None, norm_ref=None,
               M=None) -> SolveResult:
    """Merged-reduction preconditioned CG (Chronopoulos–Gear PCG).

    Same recurrence as :func:`cg_merged` with ``u = M⁻¹ r``, ``w = A u``,
    ``γ = r·u``, ``δ = w·u``; the TRUE-residual ``r·r`` rides in the same
    stacked reduction (3 scalars, ONE psum), so stopping matches ``pcg``.
    ``M`` must be SPD-preserving, like ``pcg``'s.
    """
    dotn = _stacked_dot(A, dot)
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    apply_M = M if M is not None else (lambda v: v)
    r = b - A.matvec(x0)
    u = apply_M(r)
    w = A.matvec(u)
    gamma, delta, rr = dotn((r, u), (w, u), (r, r))
    hist = _hist_init(maxiter, jnp.sqrt(rr), b.dtype)
    zero = jnp.zeros_like(b)
    inf = jnp.asarray(jnp.inf, gamma.dtype)
    one = jnp.asarray(1.0, gamma.dtype)

    def cond(c):
        _, _, _, _, _, _, _, _, rr, _, _, k, _ = c
        return (rr >= thresh2) & (k < maxiter)

    def body(c):
        x, r, u, p, s, w, gamma, delta, rr, gamma_prev, alpha_prev, k, hist = c
        alpha, beta = _cg_merged_scalars(gamma, delta, gamma_prev, alpha_prev)
        p = u + beta * p
        s = w + beta * s
        x = x + alpha * p
        r = r - alpha * s
        u = apply_M(r)
        w = A.matvec(u)
        gamma_new, delta_new, rr_new = dotn((r, u), (w, u), (r, r))
        hist = hist.at[k + 1].set(jnp.sqrt(rr_new).astype(hist.dtype))
        return (x, r, u, p, s, w, gamma_new, delta_new, rr_new,
                gamma, alpha, k + 1, hist)

    x, r, u, p, s, w, gamma, delta, rr, _, _, k, hist = lax.while_loop(
        cond, body,
        (x0, r, u, zero, zero, w, gamma, delta, rr, inf, one, 0, hist))
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(rr), history=hist)


def cg_pipe(A, b, x0, *, tol=1e-6, maxiter=500, dot=None,
            norm_ref=None) -> SolveResult:
    """Pipelined CG (Ghysels–Vanroose): the ONE stacked reduction is issued
    at the top of the body and the body's SpMV (``n = A w``, on carried
    state) is dataflow-independent of it — the latency-hiding scheduler
    runs the SpMV while the psum is in flight.  The ``optimization_barrier``
    pins the SpMV as its own schedulable task (the ``bicgstab_b1`` idiom;
    without it XLA may fuse the stencil apply into the reduction consumers
    and close the window).

    The freshest residual norm available to ``cond`` is the previous
    body's, so the method typically reports one more iteration than ``cg``
    at the same tolerance; two extra vector recurrences (``s = A p``,
    ``z = A s``) pay for the hiding.
    """
    dotn = _stacked_dot(A, dot)
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    r = b - A.matvec(x0)
    w = A.matvec(r)
    (rr0,) = dotn((r, r))
    hist = _hist_init(maxiter, jnp.sqrt(rr0), b.dtype)
    zero = jnp.zeros_like(b)
    inf = jnp.asarray(jnp.inf, rr0.dtype)
    one = jnp.asarray(1.0, rr0.dtype)

    def cond(c):
        _, _, _, _, _, _, _, _, rr, k, _ = c
        return (rr >= thresh2) & (k < maxiter)

    def body(c):
        x, r, w, p, s, z, gamma_prev, alpha_prev, rr, k, hist = c
        gamma, delta = dotn((r, r), (w, r))           # issued...
        n = lax.optimization_barrier(A.matvec(w))     # ...hidden behind this
        alpha, beta = _cg_merged_scalars(gamma, delta, gamma_prev, alpha_prev)
        z = n + beta * z                  # z = A s by recurrence
        s = w + beta * s                  # s = A p by recurrence
        p = r + beta * p
        x = x + alpha * p
        r = r - alpha * s
        w = w - alpha * z                 # w = A r by recurrence
        hist = hist.at[k + 1].set(jnp.sqrt(gamma).astype(hist.dtype))
        return (x, r, w, p, s, z, gamma, alpha, gamma, k + 1, hist)

    x, r, w, p, s, z, _, _, rr, k, hist = lax.while_loop(
        cond, body, (x0, r, w, zero, zero, zero, inf, one, rr0, 0, hist))
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(rr), history=hist)


def pcg_pipe(A, b, x0, *, tol=1e-6, maxiter=500, dot=None, norm_ref=None,
             M=None) -> SolveResult:
    """Pipelined preconditioned CG (Ghysels–Vanroose Alg. 3).

    Like :func:`cg_pipe` with ``u = M⁻¹ r`` maintained by recurrence: the
    stacked reduction (``γ = r·u``, ``δ = w·u``, TRUE ``r·r`` — ONE psum)
    overlaps both the preconditioner apply ``m = M⁻¹ w`` and the SpMV
    ``n = A m``.  Four extra recurrences (``s, q, z, u``); stopping lags one
    iteration like the unpreconditioned pipeline.
    """
    dotn = _stacked_dot(A, dot)
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    apply_M = M if M is not None else (lambda v: v)
    r = b - A.matvec(x0)
    u = apply_M(r)
    w = A.matvec(u)
    (rr0,) = dotn((r, r))
    hist = _hist_init(maxiter, jnp.sqrt(rr0), b.dtype)
    zero = jnp.zeros_like(b)
    inf = jnp.asarray(jnp.inf, rr0.dtype)
    one = jnp.asarray(1.0, rr0.dtype)

    def cond(c):
        _, _, _, _, _, _, _, _, _, _, rr, k, _ = c
        return (rr >= thresh2) & (k < maxiter)

    def body(c):
        x, r, u, w, p, s, q, z, gamma_prev, alpha_prev, rr, k, hist = c
        gamma, delta, rr_new = dotn((r, u), (w, u), (r, r))   # issued...
        m = apply_M(w)                                # ...hidden behind the
        n = lax.optimization_barrier(A.matvec(m))     # apply and the SpMV
        alpha, beta = _cg_merged_scalars(gamma, delta, gamma_prev, alpha_prev)
        z = n + beta * z                  # z = A q by recurrence
        q = m + beta * q                  # q = M⁻¹ s by recurrence
        s = w + beta * s                  # s = A p by recurrence
        p = u + beta * p
        x = x + alpha * p
        r = r - alpha * s
        u = u - alpha * q                 # u = M⁻¹ r by recurrence
        w = w - alpha * z                 # w = A u by recurrence
        hist = hist.at[k + 1].set(jnp.sqrt(rr_new).astype(hist.dtype))
        return (x, r, u, w, p, s, q, z, gamma, alpha, rr_new, k + 1, hist)

    x, r, u, w, p, s, q, z, _, _, rr, k, hist = lax.while_loop(
        cond, body,
        (x0, r, u, w, zero, zero, zero, zero, inf, one, rr0, 0, hist))
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(rr), history=hist)


def bicgstab(A, b, x0, *, tol=1e-6, maxiter=500, dot=None, norm_ref=None) -> SolveResult:
    """Classical BiCGStab (3 blocking reductions per iteration)."""
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    r = b - A.matvec(x0)
    rhat = r
    p = r
    rho = dot(rhat, r)
    rr = dot(r, r)
    hist = _hist_init(maxiter, jnp.sqrt(rr), b.dtype)

    def cond(c):
        _, _, _, _, rho, rr, k, _ = c
        return (rr >= thresh2) & (k < maxiter)

    def body(c):
        x, r, rhat, p, rho, rr, k, hist = c
        v = A.matvec(p)
        rhat_v = dot(rhat, v)                 # barrier 1
        alpha = rho / rhat_v
        s = r - alpha * v
        t = A.matvec(s)
        ts = dot(t, s)                        # barrier 2 (fused pair of dots)
        tt = dot(t, t)
        omega = ts / tt
        x = x + alpha * p + omega * s
        r = s - omega * t
        rho_new = dot(rhat, r)                # barrier 3 (fused pair of dots)
        rr_new = dot(r, r)
        beta = (rho_new / rho) * (alpha / omega)
        p = r + beta * (p - omega * v)
        hist = hist.at[k + 1].set(jnp.sqrt(rr_new).astype(hist.dtype))
        return (x, r, rhat, p, rho_new, rr_new, k + 1, hist)

    x, r, rhat, p, rho, rr, k, hist = lax.while_loop(
        cond, body, (x0, r, rhat, p, rho, rr, 0, hist)
    )
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(rr), history=hist)


def pbicgstab(A, b, x0, *, tol=1e-6, maxiter=500, dot=None, norm_ref=None,
              M=None) -> SolveResult:
    """Right-preconditioned BiCGStab (``A M^{-1} y = b``, ``x = M^{-1} y``).

    Right preconditioning keeps ``r`` the TRUE residual, so the stopping
    criterion and iteration counts are directly comparable with
    ``bicgstab``; ``M`` need not be SPD-preserving.  ``M=None`` reduces
    arithmetically to classical BiCGStab.  Barrier structure unchanged
    (3 blocking reduction points) — the two ``M`` applies add stencil
    sweeps but no reductions for the built-in preconditioners.
    """
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    apply_M = M if M is not None else (lambda v: v)
    r = b - A.matvec(x0)
    rhat = r
    p = r
    rho = dot(rhat, r)
    rr = dot(r, r)
    hist = _hist_init(maxiter, jnp.sqrt(rr), b.dtype)

    def cond(c):
        _, _, _, _, rho, rr, k, _ = c
        return (rr >= thresh2) & (k < maxiter)

    def body(c):
        x, r, rhat, p, rho, rr, k, hist = c
        phat = apply_M(p)
        v = A.matvec(phat)
        rhat_v = dot(rhat, v)                 # barrier 1
        alpha = rho / rhat_v
        s = r - alpha * v
        shat = apply_M(s)
        t = A.matvec(shat)
        ts = dot(t, s)                        # barrier 2 (fused pair of dots)
        tt = dot(t, t)
        omega = ts / tt
        x = x + alpha * phat + omega * shat
        r = s - omega * t
        rho_new = dot(rhat, r)                # barrier 3 (fused pair of dots)
        rr_new = dot(r, r)
        beta = (rho_new / rho) * (alpha / omega)
        p = r + beta * (p - omega * v)
        hist = hist.at[k + 1].set(jnp.sqrt(rr_new).astype(hist.dtype))
        return (x, r, rhat, p, rho_new, rr_new, k + 1, hist)

    x, r, rhat, p, rho, rr, k, hist = lax.while_loop(
        cond, body, (x0, r, rhat, p, rho, rr, 0, hist)
    )
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(rr), history=hist)


def bicgstab_b1(
    A, b, x0, *, tol=1e-6, maxiter=500, dot=None, norm_ref=None,
    eps_restart=1e-5,
) -> SolveResult:
    """BiCGStab one-blocking (paper Alg. 2) with the restart procedure.

    Only ``α_d = (A·p)·r'`` blocks; ω's pair of reductions overlaps the
    ``x_{j+1/2}`` update (Tk 3) and the ``α_n``/``β`` pair overlaps the
    ``p_{j+1/2}`` update (Tk 5).  Restart (lines 13-15) triggers on
    ``sqrt(|α_n|) < ε_restart·||b||`` and re-orthogonalises ``r'``,
    eliminating the near-breakdown amplification (and, in the paper's task
    world, accumulated nondeterministic rounding).
    """
    dot, norm_ref, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    restart_thresh = eps_restart * norm_ref
    r = b - A.matvec(x0)
    p = r
    beta_rr = dot(r, r)                        # β_0 = r_0·r_0
    rhat = r / jnp.sqrt(beta_rr)               # r'
    an = dot(r, rhat)                          # α_{n,0} = sqrt(β_0)
    hist = _hist_init(maxiter, jnp.sqrt(beta_rr), b.dtype)

    def cond(c):
        _, _, _, _, an, beta_rr, k, _, _ = c
        return (beta_rr >= thresh2) & (k < maxiter)     # line 7 check

    def body(c):
        x, r, p, rhat, an, beta_rr, k, hist, nrestart = c
        Ap = A.matvec(p)
        ad = dot(Ap, rhat)                    # Tk 0 (line 3) — the ONE blocking reduction
        alpha = an / ad
        s = r - alpha * Ap                    # Tk 1 (line 4)
        As = A.matvec(s)
        ts = dot(As, s)                       # Tk 2 (line 5) — overlapped with...
        tt = dot(As, As)
        # optimization_barrier = the Tk-3-is-its-own-task constraint: without
        # it XLA fuses this update into the omega-dependent x_{j+1} and the
        # overlap window vanishes (measured: slack 4096 -> 0 bytes)
        x_half = lax.optimization_barrier(x + alpha * p)   # ...Tk 3 (line 6)
        omega = ts / tt
        x_new = x_half + omega * s            # Tk 4 (line 8; == line 18 on exit)
        r_new = s - omega * As                # Tk 4 (line 9)
        an_new = dot(r_new, rhat)             # Tk 4 (line 10) — overlapped with...
        beta_rr_new = dot(r_new, r_new)       # Tk 4 (line 11)
        p_half = lax.optimization_barrier(p - omega * Ap)  # ...Tk 5 (line 12)
        restart = jnp.sqrt(jnp.abs(an_new)) < restart_thresh
        p_reg = r_new + (an_new / (ad * omega)) * p_half   # Tk 7 (line 17)
        p_new = jnp.where(restart, r_new, p_reg)           # Tk 6 (line 14)
        rhat_new = jnp.where(restart, r_new / jnp.sqrt(beta_rr_new), rhat)  # line 15
        an_next = jnp.where(restart, jnp.sqrt(beta_rr_new), an_new)
        hist = hist.at[k + 1].set(jnp.sqrt(beta_rr_new).astype(hist.dtype))
        return (x_new, r_new, p_new, rhat_new, an_next, beta_rr_new, k + 1,
                hist, nrestart + restart.astype(jnp.int32))

    x, r, p, rhat, an, beta_rr, k, hist, nrestart = lax.while_loop(
        cond, body, (x0, r, p, rhat, an, beta_rr, 0, hist, jnp.int32(0))
    )
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(beta_rr), history=hist)


def _bicgstab_merged_loop(matvec, dotn, r0, y0, *, thresh2, maxiter,
                          hist_dtype):
    """The single-reduction BiCGStab iteration, shared by the plain and the
    right-preconditioned form (which passes ``matvec = A∘M⁻¹``).

    Auxiliary images ``w = A r``, ``t = A w``, ``s = A p``, ``z = A s`` are
    maintained by recurrence so that ω's pair, ρ, the α denominator
    ``r̂·(A p)`` and ‖r‖² are all linear in dots of vectors available
    BEFORE ω — nine dots, ONE stacked psum per iteration.  Two SpMVs
    remain (``v = A z`` and ``t = A w_new``); ``v`` is dataflow-independent
    of the reduction, so the scheduler can hide the psum behind it (the
    ``optimization_barrier`` pins it as its own task).
    """
    w = matvec(r0)
    t = matvec(w)
    rhat = r0
    rho, rhw = dotn((rhat, r0), (rhat, w))
    alpha = rho / rhw
    rr = rho                               # r̂ = r0 ⇒ (r̂,r0) = ‖r0‖²
    hist = _hist_init(maxiter, jnp.sqrt(rr), hist_dtype)

    def cond(c):
        rr, k = c[10], c[11]
        return (rr >= thresh2) & (k < maxiter)

    def body(c):
        y, r, w, t, p, s, z, rhat, rho, alpha, rr, k, hist = c
        q = r - alpha * s                  # classical s_j
        yv = w - alpha * z                 # = A q
        v = lax.optimization_barrier(matvec(z))      # SpMV 1 — independent...
        (qy, yy, qq, rhq, rhy, rht, rhv, rhz, rhs) = dotn(   # ...of the ONE
            (q, yv), (yv, yv), (q, q), (rhat, q), (rhat, yv),  # stacked psum
            (rhat, t), (rhat, v), (rhat, z), (rhat, s))
        omega = qy / yy
        y = y + alpha * p + omega * q
        r = q - omega * yv
        # recurrence-based ‖r‖² (the stability caveat in docs/API.md):
        # ‖q − ωy‖² from pre-update dots; clamp the rounding negatives.
        rr_new = jnp.maximum(qq - 2.0 * omega * qy + omega * omega * yy, 0.0)
        rho_new = rhq - omega * rhy
        beta = (rho_new / rho) * (alpha / omega)
        w = yv - omega * (t - alpha * v)   # = A r_new
        t = matvec(w)                      # SpMV 2
        rhw = rhy - omega * (rht - alpha * rhv)      # (r̂, w_new)
        alpha_new = rho_new / (rhw + beta * (rhs - omega * rhz))
        p = r + beta * (p - omega * s)
        s = w + beta * (s - omega * z)     # = A p_new
        z = t + beta * (z - omega * v)     # = A s_new
        hist = hist.at[k + 1].set(jnp.sqrt(rr_new).astype(hist.dtype))
        return (y, r, w, t, p, s, z, rhat, rho_new, alpha_new, rr_new,
                k + 1, hist)

    init = (y0, r0, w, t, r0, w, t, rhat, rho, alpha, rr, 0, hist)
    y, r, w, t, p, s, z, rhat, rho, alpha, rr, k, hist = lax.while_loop(
        cond, body, init)
    return y, rr, k, hist


def bicgstab_merged(A, b, x0, *, tol=1e-6, maxiter=500, dot=None,
                    norm_ref=None) -> SolveResult:
    """Merged-reduction BiCGStab: ONE stacked psum per iteration (vs the
    classic's 3 barriers), two SpMVs, at the cost of four auxiliary
    Krylov-image recurrences.  See ``_bicgstab_merged_loop``."""
    dotn = _stacked_dot(A, dot)
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    r0 = b - A.matvec(x0)
    x, rr, k, hist = _bicgstab_merged_loop(
        A.matvec, dotn, r0, x0, thresh2=thresh2, maxiter=maxiter,
        hist_dtype=b.dtype)
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(rr), history=hist)


def pbicgstab_merged(A, b, x0, *, tol=1e-6, maxiter=500, dot=None,
                     norm_ref=None, M=None) -> SolveResult:
    """Right-preconditioned merged BiCGStab.

    Runs the single-reduction core on ``B = A∘M⁻¹`` with rhs ``r0`` and a
    ZERO initial guess, then recovers ``x = x0 + M⁻¹ y`` with one final
    apply — right preconditioning leaves the residual untouched, so the
    stopping criterion (and iteration counts) stay TRUE-residual like
    ``pbicgstab``'s, and the per-iteration reduction count stays ONE.
    ``M`` need not be SPD-preserving.
    """
    dotn = _stacked_dot(A, dot)
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    apply_M = M if M is not None else (lambda v: v)

    def matvec_B(v):
        return A.matvec(apply_M(v))

    r0 = b - A.matvec(x0)
    y, rr, k, hist = _bicgstab_merged_loop(
        matvec_B, dotn, r0, jnp.zeros_like(b), thresh2=thresh2,
        maxiter=maxiter, hist_dtype=b.dtype)
    return SolveResult(x=x0 + apply_M(y), iters=k, res_norm=jnp.sqrt(rr),
                       history=hist)


# =============================================================================
# Stationary methods
# =============================================================================

def jacobi(A, b, x0, *, tol=1e-6, maxiter=1000, dot=None, norm_ref=None) -> SolveResult:
    """Jacobi: x += D^{-1} r; one SpMV + one reduction per iteration."""
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    r = b - A.matvec(x0)
    rr = dot(r, r)
    hist = _hist_init(maxiter, jnp.sqrt(rr), b.dtype)

    def cond(c):
        _, _, rr, k, _ = c
        return (rr >= thresh2) & (k < maxiter)

    def body(c):
        x, r, rr, k, hist = c
        x = x + r / A.diag
        r = b - A.matvec(x)
        rr = dot(r, r)
        hist = hist.at[k + 1].set(jnp.sqrt(rr).astype(hist.dtype))
        return (x, r, rr, k + 1, hist)

    x, r, rr, k, hist = lax.while_loop(cond, body, (x0, r, rr, 0, hist))
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(rr), history=hist)


def _plane_sweep(A, b, x, *, forward: bool) -> jax.Array:
    """One relaxed Gauss-Seidel sweep: GS-fresh across z-planes, Jacobi within
    a plane, stale across device blocks (halos exchanged once per sweep)."""
    nz = x.shape[2]

    def step(i, xp):
        k = i if forward else nz - 1 - i
        off = A.stencil.plane_offdiag_apply(xp, k)
        plane = (b[:, :, k] - off) / A.diag
        return lax.dynamic_update_slice(xp, plane[:, :, None], (1, 1, k + 1))

    xp = A.pad_exchange(x)
    xp = lax.fori_loop(0, nz, step, xp)
    return xp[1:-1, 1:-1, 1:-1]


def sym_gauss_seidel_relaxed(
    A, b, x0, *, tol=1e-6, maxiter=1000, dot=None, norm_ref=None
) -> SolveResult:
    """Relaxed symmetric GS (paper §3.4 Code 4, TPU adaptation).

    Forward sweep (ascending z-planes) then backward sweep (descending), each
    using the freshest available plane values — the deterministic analogue of
    the paper's benign data races that "mimic the Gauss-Seidel behaviour".
    """
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    r = b - A.matvec(x0)
    rr = dot(r, r)
    hist = _hist_init(maxiter, jnp.sqrt(rr), b.dtype)

    def cond(c):
        _, rr, k, _ = c
        return (rr >= thresh2) & (k < maxiter)

    def body(c):
        x, rr, k, hist = c
        x = _plane_sweep(A, b, x, forward=True)
        x = _plane_sweep(A, b, x, forward=False)
        r = b - A.matvec(x)
        rr = dot(r, r)
        hist = hist.at[k + 1].set(jnp.sqrt(rr).astype(hist.dtype))
        return (x, rr, k + 1, hist)

    x, rr, k, hist = lax.while_loop(cond, body, (x0, rr, 0, hist))
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(rr), history=hist)


def _colour_mask(shape: tuple[int, int, int], colour: int) -> jax.Array:
    i = lax.broadcasted_iota(jnp.int32, shape, 0)
    j = lax.broadcasted_iota(jnp.int32, shape, 1)
    k = lax.broadcasted_iota(jnp.int32, shape, 2)
    return ((i + j + k) % 2) == colour


def _rb_half_sweep(A, b, x, colour_mask) -> jax.Array:
    off = A.stencil.offdiag_apply_padded(A.pad_exchange(x))
    return jnp.where(colour_mask, (b - off) / A.diag, x)


def sym_gauss_seidel_rb(
    A, b, x0, *, tol=1e-6, maxiter=1000, dot=None, norm_ref=None
) -> SolveResult:
    """Red-black coloured symmetric GS (paper §3.4).

    Forward = red, black; backward = black, red.  Exact GS reordering for the
    7-pt stencil (bipartite); a coloured relaxation for the 27-pt one, with
    correspondingly different convergence (the effect the paper measures).
    """
    dot, _, thresh2 = _prepare(A, b, dot, norm_ref, tol)
    red = _colour_mask(x0.shape, 0)
    black = _colour_mask(x0.shape, 1)
    r = b - A.matvec(x0)
    rr = dot(r, r)
    hist = _hist_init(maxiter, jnp.sqrt(rr), b.dtype)

    def cond(c):
        _, rr, k, _ = c
        return (rr >= thresh2) & (k < maxiter)

    def body(c):
        x, rr, k, hist = c
        x = _rb_half_sweep(A, b, x, red)      # forward
        x = _rb_half_sweep(A, b, x, black)
        x = _rb_half_sweep(A, b, x, black)    # backward
        x = _rb_half_sweep(A, b, x, red)
        r = b - A.matvec(x)
        rr = dot(r, r)
        hist = hist.at[k + 1].set(jnp.sqrt(rr).astype(hist.dtype))
        return (x, rr, k + 1, hist)

    x, rr, k, hist = lax.while_loop(cond, body, (x0, rr, 0, hist))
    return SolveResult(x=x, iters=k, res_norm=jnp.sqrt(rr), history=hist)


SOLVERS: dict[str, Callable] = {
    "jacobi": jacobi,
    "gauss_seidel": sym_gauss_seidel_relaxed,
    "gauss_seidel_rb": sym_gauss_seidel_rb,
    "cg": cg,
    "cg_nb": cg_nb,
    "cg_merged": cg_merged,
    "cg_pipe": cg_pipe,
    "pcg": pcg,
    "pcg_merged": pcg_merged,
    "pcg_pipe": pcg_pipe,
    "bicgstab": bicgstab,
    "bicgstab_b1": bicgstab_b1,
    "bicgstab_merged": bicgstab_merged,
    "pbicgstab": pbicgstab,
    "pbicgstab_merged": pbicgstab_merged,
}

#: methods refining a classical baseline (the paper's variants + the
#: preconditioned forms + the PR-4 reduction-hiding restructurings)
#: mapped to that baseline
VARIANT_OF = {"cg_nb": "cg", "bicgstab_b1": "bicgstab",
              "gauss_seidel": "gauss_seidel_rb",
              "pcg": "cg", "pbicgstab": "bicgstab",
              "cg_merged": "cg", "cg_pipe": "cg",
              "pcg_merged": "pcg", "pcg_pipe": "pcg",
              "bicgstab_merged": "bicgstab",
              "pbicgstab_merged": "pbicgstab"}
