# The paper's primary contribution: classical iterative methods (Jacobi,
# symmetric Gauss-Seidel, CG, BiCGStab) plus the communication-reducing
# variants (CG-NB, BiCGStab-B1, relaxed GS), written once and parallelised
# underneath via shard_map (DESIGN.md sections 2-3).
from repro.core.operators import (
    STENCIL_7PT,
    STENCIL_27PT,
    STENCILS,
    ELLOperator,
    Stencil,
    build_ell_from_stencil,
    touched_elements_per_iter,
)
from repro.core.methods import (
    METHODS,
    MethodDef,
    Ops,
    get_method,
    method_names,
    register_method,
    run_method,
)
from repro.core.problems import HPCGProblem, default_dtype, enable_f64, make_problem
from repro.core.solvers import (
    SOLVERS,
    VARIANT_OF,
    LocalOp,
    SolveResult,
    bicgstab,
    bicgstab_b1,
    cg,
    cg_nb,
    jacobi,
    sym_gauss_seidel_rb,
    sym_gauss_seidel_relaxed,
)
from repro.core.distributed import (
    DistributedOp,
    GridLayout,
    make_layout,
    solve_shardmap,
    solve_step_shardmap,
)
