"""Fig. 3: weak scalability of the Krylov methods (CG/CG-NB, BiCGStab/B1).

Relative parallel efficiency vs chip count for both stencils, from the
roofline-based iteration-time model (benchmarks/scaling_model.py), normalised
like the paper to the classical method at one node.  The paper's claim to
reproduce: the nonblocking variants hold efficiency at scale because their
reductions ride behind the SpMV / vector updates (CG-NB +19.7%/+25% over
blocking CG at 64 nodes; here the analogue at 512-4096 chips).

Beyond the paper: the preconditioned curves (pcg + each repro.precond
implementation, t_precond term included) quantify the reductions-vs-
iterations trade-off.  Per curve we emit the weak-scaling efficiency AND
the break-even factor — how much the preconditioner must cut the iteration
count to beat plain cg wall-clock at that chip count.  The built-ins add
zero reductions per iteration, so the break-even factor *shrinks* as the
all-reduce latency grows with scale: preconditioning pays off more, not
less, at 4096 chips.
"""

from __future__ import annotations

from benchmarks.common import csv
from benchmarks.scaling_model import iteration_time
from repro.api import REGISTRY, variant_pairs
from repro.precond import PRECONDITIONERS

CHIPS = (1, 8, 64, 256, 512, 1024, 4096)
PRECONDS = tuple(sorted(PRECONDITIONERS))


def main() -> None:
    # the Krylov (classical, nonblocking-variant) pairs from the registry;
    # the preconditioned forms get their own dedicated curves below, not
    # the paper's variant slots (unpreconditioned pcg is just cg + one
    # extra blocking reduction — not a communication-hiding variant)
    pairs = [p for p in variant_pairs()
             if p[0] in ("cg", "bicgstab")
             and not REGISTRY[p[1]].accepts_precond]
    for noise in ("tpu", "noisy"):
        for stencil, nbar in (("7pt", 7), ("27pt", 27)):
            for pair in pairs:
                # three curves like the paper: MPI-only classical, task-based
                # classical, task-based nonblocking variant
                t_ref = iteration_time(pair[0], nbar, (128, 128, 128), 1,
                                       noise=noise, execution="mpi")
                runs = [(pair[0], "mpi"), (pair[0], "dataflow"),
                        (pair[1], "dataflow")]
                ts = {}
                for method, ex in runs:
                    halo = "overlap" if ex == "dataflow" else "concat"
                    effs = []
                    for n in CHIPS:
                        t = iteration_time(method, nbar, (128, 128, 128), n,
                                           noise=noise, execution=ex,
                                           halo_mode=halo)
                        effs.append(round(t_ref / t, 4))
                        ts[(method, ex, n)] = t
                    csv(f"fig3_{noise}_{stencil}_{method}_{ex}", 0.0,
                        "eff@" + "/".join(map(str, CHIPS)) + "="
                        + "/".join(map(str, effs)))
                # headline: nonblocking-task vs MPI-only classical (the
                # paper's +19.7%/+25% comparison at 64 nodes)
                for n in (512, 4096):
                    t_c = ts[(pair[0], "mpi", n)]
                    t_v = ts[(pair[1], "dataflow", n)]
                    csv(f"fig3_{noise}_{stencil}_{pair[1]}_vs_mpi_at_{n}",
                        0.0, f"{(t_c / t_v - 1) * 100:.1f}%")
            # preconditioned weak scaling: efficiency curves with t_precond,
            # plus the break-even iteration-reduction factor vs plain cg
            t_ref = iteration_time("cg", nbar, (128, 128, 128), 1,
                                   noise=noise, execution="mpi")
            t_cg = {n: iteration_time("cg", nbar, (128, 128, 128), n,
                                      noise=noise, halo_mode="overlap")
                    for n in CHIPS}
            for M in PRECONDS:
                effs, brk = [], []
                for n in CHIPS:
                    t = iteration_time("pcg", nbar, (128, 128, 128), n,
                                       noise=noise, halo_mode="overlap",
                                       precond=M)
                    effs.append(round(t_ref / t, 4))
                    brk.append(round(t / t_cg[n], 3))
                csv(f"fig3_{noise}_{stencil}_pcg+{M}", 0.0,
                    "eff@" + "/".join(map(str, CHIPS)) + "="
                    + "/".join(map(str, effs)))
                csv(f"fig3_{noise}_{stencil}_pcg+{M}_breakeven", 0.0,
                    "iters_factor@" + "/".join(map(str, CHIPS)) + "="
                    + "/".join(map(str, brk)))


if __name__ == "__main__":
    main()
