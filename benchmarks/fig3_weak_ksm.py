"""Fig. 3: weak scalability of the Krylov methods (CG/CG-NB, BiCGStab/B1).

Relative parallel efficiency vs chip count for both stencils, from the
roofline-based iteration-time model (benchmarks/scaling_model.py), normalised
like the paper to the classical method at one node.  The paper's claim to
reproduce: the nonblocking variants hold efficiency at scale because their
reductions ride behind the SpMV / vector updates (CG-NB +19.7%/+25% over
blocking CG at 64 nodes; here the analogue at 512-4096 chips).
"""

from __future__ import annotations

from benchmarks.common import csv
from benchmarks.scaling_model import iteration_time
from repro.api import variant_pairs

CHIPS = (1, 8, 64, 256, 512, 1024, 4096)


def main() -> None:
    # the Krylov (classical, nonblocking-variant) pairs from the registry
    pairs = [p for p in variant_pairs() if p[0] in ("cg", "bicgstab")]
    for noise in ("tpu", "noisy"):
        for stencil, nbar in (("7pt", 7), ("27pt", 27)):
            for pair in pairs:
                # three curves like the paper: MPI-only classical, task-based
                # classical, task-based nonblocking variant
                t_ref = iteration_time(pair[0], nbar, (128, 128, 128), 1,
                                       noise=noise, execution="mpi")
                runs = [(pair[0], "mpi"), (pair[0], "dataflow"),
                        (pair[1], "dataflow")]
                ts = {}
                for method, ex in runs:
                    halo = "overlap" if ex == "dataflow" else "concat"
                    effs = []
                    for n in CHIPS:
                        t = iteration_time(method, nbar, (128, 128, 128), n,
                                           noise=noise, execution=ex,
                                           halo_mode=halo)
                        effs.append(round(t_ref / t, 4))
                        ts[(method, ex, n)] = t
                    csv(f"fig3_{noise}_{stencil}_{method}_{ex}", 0.0,
                        "eff@" + "/".join(map(str, CHIPS)) + "="
                        + "/".join(map(str, effs)))
                # headline: nonblocking-task vs MPI-only classical (the
                # paper's +19.7%/+25% comparison at 64 nodes)
                for n in (512, 4096):
                    t_c = ts[(pair[0], "mpi", n)]
                    t_v = ts[(pair[1], "dataflow", n)]
                    csv(f"fig3_{noise}_{stencil}_{pair[1]}_vs_mpi_at_{n}",
                        0.0, f"{(t_c / t_v - 1) * 100:.1f}%")


if __name__ == "__main__":
    main()
