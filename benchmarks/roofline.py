"""§Roofline: three-term analysis of every dry-run cell (deliverable g).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and emits,
per (arch × shape × mesh):

  compute_s    = per-device HLO flops / peak
  memory_s     = per-device HLO bytes / HBM bw  (CPU backend legalises bf16
                 compute to f32 — the bf16_corrected column halves byte terms
                 for bf16 programs; both are reported)
  collective_s = per-device collective send bytes / ICI bw
  dominant term, MODEL_FLOPS / (HLO flops × chips) useful-compute ratio,
  and the roofline fraction  (model-flop time / dominant-term time).

Also writes the markdown table consumed by EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import HBM_BW, ICI_BW, PEAK_FLOPS, csv

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
OUT_MD = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "roofline.md")


def analyse(rec: dict) -> dict:
    chips = rec["chips"]
    flops_dev = rec["hlo_flops"]             # per-device (SPMD module)
    bytes_dev = rec["hlo_bytes"]
    coll_dev = rec["collective_bytes"]
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    memory_s_bf16 = memory_s / 2             # CPU f32-legalisation correction
    collective_s = coll_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s_bf16,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    model = rec.get("model_flops", 0.0)
    if "useful_bytes" in rec:    # memory-bound cells: bandwidth utilisation
        # bf16 LM cells keep the legalisation correction; f32 solver cells
        # don't need one
        mem_term = memory_s_bf16 if rec.get("bf16") else memory_s
        eff_bytes = bytes_dev / 2 if rec.get("bf16") else bytes_dev
        useful = rec["useful_bytes"] / (eff_bytes * chips) if bytes_dev else 0.0
        model_time = rec["useful_bytes"] / chips / HBM_BW
        terms["memory"] = mem_term
        dominant = max(terms, key=terms.get)
    else:
        useful = model / (flops_dev * chips) if flops_dev else 0.0
        model_time = model / chips / PEAK_FLOPS
    roofline_fraction = model_time / max(terms.values()) if max(
        terms.values()) else 0.0
    return dict(
        compute_s=compute_s, memory_s=memory_s, memory_s_bf16=memory_s_bf16,
        collective_s=collective_s, dominant=dominant, useful_ratio=useful,
        roofline_fraction=roofline_fraction,
    )


def main() -> None:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("kind") == "decode":
            # decode is memory-bound: useful traffic = active params + the
            # KV/SSM cache slab read once per token
            from repro.configs.base import get_config
            cfg = get_config(rec["arch"])
            S, B = rec["seq_len"], rec["batch"]
            KV, hd, L = cfg.n_kv_heads, cfg.hd, cfg.n_layers
            cache = 0.0
            if cfg.has_attn:
                C = min(S, cfg.sliding_window) if cfg.sliding_window else S
                cache = 2.0 * B * C * KV * hd * 2 * L
                if cfg.local_global:   # half the layers use the window
                    Cw = min(S, cfg.sliding_window)
                    cache = (B * Cw * KV * hd + B * S * KV * hd) * 2 * L
            if cfg.has_ssm:
                cache += (B * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
                          * 4 * L)
            rec = dict(rec)
            rec["useful_bytes"] = 2.0 * cfg.active_param_count() + cache
            rec["bf16"] = True
        if "arch" not in rec:        # solver cells: memory-bound accounting
            from repro.core.operators import touched_elements_per_iter
            a = dict(rec)
            a["arch"] = f"hpcg-{rec['method']}-{rec['stencil']}"
            a["shape"] = "weak_128^3"
            nbar = 7 if rec["stencil"] == "7pt" else 27
            r_global = 1
            for d in rec["global_grid"]:
                r_global *= d
            touched = touched_elements_per_iter(rec["method"], nbar)
            # solvers are memory-bound: "useful flops" ~ 2 flops/element;
            # the meaningful roofline number is bandwidth utilisation
            # (useful bytes / HLO bytes) — recorded in useful_ratio below.
            a["model_flops"] = 2.0 * touched * r_global
            a["useful_bytes"] = 4.0 * touched * r_global   # f32 cells
            rec = a
        r = analyse(rec)
        tag = f"{rec['arch']}|{rec['shape']}|{rec['mesh']}"
        rows.append((tag, rec, r))
        csv(f"roofline_{tag}", max(r['compute_s'], r['memory_s_bf16'],
                                   r['collective_s']) * 1e6,
            f"dom={r['dominant']};frac={r['roofline_fraction']:.3f};"
            f"useful={r['useful_ratio']:.2f};"
            f"c={r['compute_s']*1e3:.2f}ms;m={r['memory_s_bf16']*1e3:.2f}ms;"
            f"x={r['collective_s']*1e3:.2f}ms")

    os.makedirs(os.path.dirname(OUT_MD), exist_ok=True)
    with open(OUT_MD, "w") as f:
        f.write("| cell | mesh | compute_s | memory_s(bf16) | collective_s |"
                " dominant | useful | roofline frac |\n|---|---|---|---|---|"
                "---|---|---|\n")
        for tag, rec, r in rows:
            arch, shape, mesh = tag.split("|")
            f.write(
                f"| {arch} × {shape} | {mesh} | {r['compute_s']:.2e} |"
                f" {r['memory_s_bf16']:.2e} | {r['collective_s']:.2e} |"
                f" {r['dominant']} | {r['useful_ratio']:.2f} |"
                f" {r['roofline_fraction']:.3f} |\n")
    print(f"# wrote {OUT_MD} ({len(rows)} cells)")


if __name__ == "__main__":
    main()
