"""Benchmark harness — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only NAME]``
prints ``name,us_per_call,derived`` CSV lines per the repo contract.

  table_iterations  — §4.1 iteration-count table (vs the paper's values)
  fig2_variants     — Fig. 2 execution-time box stats + Fig. 1 barrier traces
  fig3_weak_ksm     — Fig. 3 weak-scaling efficiencies (KSMs)
  fig4_weak_stationary — Fig. 4 weak scaling + GS-variant iteration effect
  fig56_strong      — Figs. 5-6 strong scaling
  roofline          — §Roofline terms for every dry-run cell
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (
    fig2_variants,
    fig3_weak_ksm,
    fig4_weak_stationary,
    fig56_strong,
    roofline,
    table_iterations,
)

MODULES = {
    "table_iterations": table_iterations,
    "fig2_variants": fig2_variants,
    "fig3_weak_ksm": fig3_weak_ksm,
    "fig4_weak_stationary": fig4_weak_stationary,
    "fig56_strong": fig56_strong,
    "roofline": roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(MODULES))
    ap.add_argument("--list-methods", action="store_true",
                    help="print the solver registry (methods + per-iteration "
                         "communication metadata) and exit")
    args = ap.parse_args()
    if args.list_methods:
        from repro.api import REGISTRY
        for name in sorted(REGISTRY):
            s = REGISTRY[name]
            print(f"{name},reductions={s.reductions_per_iter},"
                  f"blocking={s.blocking_reductions},spmvs={s.spmvs_per_iter},"
                  f"variant_of={s.variant_of or '-'},"
                  f"{'stationary' if s.stationary else 'krylov'}")
        return
    names = [args.only] if args.only else list(MODULES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        print(f"# --- {name} ---")
        try:
            MODULES[name].main()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
