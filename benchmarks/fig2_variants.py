"""Fig. 2: classical vs proposed variants — execution time + barrier traces.

Two parts:
  (a) box-whisker execution times (median/q1/q3 of 10 runs) of CG vs CG-NB
      and BiCGStab vs B1 on one device (the paper's same-resources protocol),
  (b) the Fig. 1 trace argument, structurally: an 8-device subprocess lowers
      one iteration of each method and reports per-all-reduce overlap slack
      from the compiled HLO (zero-slack == the blocking barriers the arrows
      mark in the paper's Paraver traces).

Both parts route through ``repro.api``: part (a) uses ``SolverSession`` with
the facade's warm-up/blocked timing; part (b) uses ``SolverSession.step_fn``
with the paper-faithful operator options.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import csv
from repro.api import SolverOptions, SolverSession, variant_pairs
from repro.core.problems import enable_f64

# The "algo" (fusion-disabled) view needs --xla_disable_hlo_passes, which
# this jaxlib cannot take per-compile (repeated proto field); the parent runs
# this script twice with the passes disabled via XLA_FLAGS instead.
_TRACE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8").strip()
import sys, json
sys.path.insert(0, "src")
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.api import SolverOptions, SolverSession
from repro.analysis.hlo import overlap_slack
from repro.core.compat import make_mesh
from repro.core.distributed import step_state_layout
from repro.core.problems import make_problem

view = os.environ.get("TRACE_VIEW", "fused")
mesh = make_mesh((2, 4), ("data", "model"))
prob = make_problem((32, 32, 32), "27pt", dtype=jnp.float32)
b = prob.b()
out = {}
for m in ("cg", "cg_nb", "bicgstab", "bicgstab_b1"):
    # paper-faithful implementation for the structural trace (the conv/concat
    # traffic optimisations shift XLA fusion boundaries and obscure the
    # algorithm-level dependence structure)
    sess = SolverSession(prob, method=m, mesh=mesh, options=SolverOptions(
        f64=False, halo_mode="scatter",
        matvec_padded=prob.stencil.matvec_padded))
    fn, layout = sess.step_fn()
    sh = NamedSharding(mesh, layout.spec())
    vecs, scals = step_state_layout(m)   # derived from the MethodDef
    args = ([jax.device_put(b, sh)] * (1 + len(vecs))
            + [jnp.array(1.0, jnp.float32)] * len(scals))
    c = jax.jit(fn).lower(*args).compile()
    rep = [r for r in overlap_slack(c.as_text())
           if r["op"].startswith("all-reduce")]
    out[m] = {view: [round(r["slack_bytes"]) for r in rep]}
print(json.dumps(out))
"""


def _run_trace(view: str) -> dict | None:
    env = dict(os.environ)
    env["TRACE_VIEW"] = view
    if view == "algo":   # algorithm-level dependence structure, unfused
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            " --xla_disable_hlo_passes="
                            "fusion,cpu-instruction-fusion").strip()
    proc = subprocess.run(
        [sys.executable, "-c", _TRACE_SCRIPT], capture_output=True, text=True,
        timeout=560, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if proc.returncode != 0:
        csv(f"fig1_trace_{view}", 0.0, f"subprocess_failed:{proc.stderr[-200:]}")
        return None
    return json.loads(proc.stdout.strip().splitlines()[-1])


def main() -> None:
    enable_f64()      # paper precision; owned by the driver, not the facade
    n = 64
    # the paper's (classical, nonblocking-variant) comparisons only — the
    # preconditioned forms are variants in lineage, not in barrier structure
    from repro.api import REGISTRY
    krylov_pairs = [(base, var) for base, var in variant_pairs()
                    if base in ("cg", "bicgstab")
                    and not REGISTRY[var].accepts_precond]
    for stencil in ("7pt",):
        base = {}
        for classical, variant in krylov_pairs:
            for method in (classical, variant):
                sess = SolverSession(
                    method=method, grid=(n, n, n), stencil=stencil,
                    options=SolverOptions(tol=1e-6, maxiter=700,
                                          layout="local"))
                res, t = sess.timed_solve(repeats=10)
                per_iter = t["median"] / max(int(res.iters), 1)
                base[method] = t["median"]
                csv(f"fig2_{stencil}_{method}", t["median"] * 1e6,
                    f"iters={int(res.iters)};per_iter_us={per_iter*1e6:.1f};"
                    f"q1={t['q1']*1e6:.0f};q3={t['q3']*1e6:.0f}")
        csv("fig2_cgnb_vs_cg_ratio", 0.0,
            f"ratio={base['cg_nb']/base['cg']:.3f}")
        csv("fig2_b1_vs_bicgstab_ratio", 0.0,
            f"ratio={base['bicgstab_b1']/base['bicgstab']:.3f}")

    # structural barrier trace (Fig. 1 analogue): one subprocess per view
    slacks: dict = {}
    for view in ("algo", "fused"):
        part = _run_trace(view)
        for m, views in (part or {}).items():
            slacks.setdefault(m, {}).update(views)
    vec = 32 ** 3 * 4 // 8
    for m, views in slacks.items():
        for view, sl in views.items():
            hard = sum(1 for s in sl if s < vec)
            csv(f"fig1_trace_{m}_{view}", 0.0,
                f"allreduce_slack_bytes={sl};hard_barriers={hard}")


if __name__ == "__main__":
    main()
