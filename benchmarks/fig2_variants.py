"""Fig. 2: classical vs proposed variants — execution time + barrier traces.

Two parts:
  (a) box-whisker execution times (median/q1/q3 of 10 runs) of CG vs CG-NB
      and BiCGStab vs B1 on one device (the paper's same-resources protocol),
  (b) the Fig. 1 trace argument, structurally: an 8-device subprocess lowers
      one iteration of each method and reports per-all-reduce overlap slack
      from the compiled HLO (zero-slack == the blocking barriers the arrows
      mark in the paper's Paraver traces).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import jax

from benchmarks.common import csv, timed
from repro.core.problems import enable_f64, make_problem
from repro.core.solvers import SOLVERS, LocalOp

_TRACE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "src")
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.core.problems import make_problem
from repro.core.distributed import solve_step_shardmap
from repro.analysis.hlo import overlap_slack

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
prob = make_problem((32, 32, 32), "27pt", dtype=jnp.float32)
b = prob.b()
out = {}
for m in ("cg", "cg_nb", "bicgstab", "bicgstab_b1"):
    # paper-faithful implementation for the structural trace (the conv/concat
    # traffic optimisations shift XLA fusion boundaries and obscure the
    # algorithm-level dependence structure)
    fn, layout = solve_step_shardmap(prob, m, mesh, halo_mode="scatter",
                                     matvec_padded=prob.stencil.matvec_padded)
    sh = NamedSharding(mesh, layout.spec())
    args = [jax.device_put(b, sh)] * 5 + [jnp.array(1.0, jnp.float32)] * 2
    lowered = jax.jit(fn).lower(*args)
    res = {}
    # algorithm-level (fusion-disabled) and compiled-schedule views
    for view, opts in (("algo", {"xla_disable_hlo_passes":
                                 "fusion,cpu-instruction-fusion"}),
                       ("fused", None)):
        c = lowered.compile(compiler_options=opts) if opts else lowered.compile()
        rep = [r for r in overlap_slack(c.as_text())
               if r["op"].startswith("all-reduce")]
        res[view] = [round(r["slack_bytes"]) for r in rep]
    out[m] = res
print(json.dumps(out))
"""


def main() -> None:
    enable_f64()
    n = 64
    for stencil in ("7pt",):
        prob = make_problem((n, n, n), stencil)
        A = LocalOp(prob.stencil)
        b, x0 = prob.b(), prob.x0()
        base = {}
        for method in ("cg", "cg_nb", "bicgstab", "bicgstab_b1"):
            fn = jax.jit(lambda b, x0, m=method: SOLVERS[m](
                A, b, x0, tol=1e-6, maxiter=700, norm_ref=1.0))
            res = fn(b, x0)
            t = timed(fn, b, x0, repeats=10)
            per_iter = t["median"] / max(int(res.iters), 1)
            base[method] = t["median"]
            csv(f"fig2_{stencil}_{method}", t["median"] * 1e6,
                f"iters={int(res.iters)};per_iter_us={per_iter*1e6:.1f};"
                f"q1={t['q1']*1e6:.0f};q3={t['q3']*1e6:.0f}")
        csv("fig2_cgnb_vs_cg_ratio", 0.0,
            f"ratio={base['cg_nb']/base['cg']:.3f}")
        csv("fig2_b1_vs_bicgstab_ratio", 0.0,
            f"ratio={base['bicgstab_b1']/base['bicgstab']:.3f}")

    # structural barrier trace (Fig. 1 analogue)
    proc = subprocess.run(
        [sys.executable, "-c", _TRACE_SCRIPT], capture_output=True, text=True,
        timeout=560,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if proc.returncode == 0:
        slacks = json.loads(proc.stdout.strip().splitlines()[-1])
        vec = 32 ** 3 * 4 // 8
        for m, views in slacks.items():
            for view, sl in views.items():
                hard = sum(1 for s in sl if s < vec)
                csv(f"fig1_trace_{m}_{view}", 0.0,
                    f"allreduce_slack_bytes={sl};hard_barriers={hard}")
    else:
        csv("fig1_trace", 0.0, f"subprocess_failed:{proc.stderr[-200:]}")


if __name__ == "__main__":
    main()
