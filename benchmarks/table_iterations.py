"""Paper §4.1 table: iterations to convergence on the HPCG system.

Paper (128^3, one MareNostrum4 node, eps=1e-6 absolute):
  7pt : BiCGStab 8,  CG 12, symGS 9,   Jacobi 18
  27pt: BiCGStab 45, CG 72, symGS 142, Jacobi 515

Set BENCH_FULL=1 to run the exact 128^3 sizes (≈2 min on CPU); the default
64^3 shows the same structure at ~1/8 the cost.
"""

from __future__ import annotations

import os

from benchmarks.common import csv
from repro.api import SolverOptions, SolverSession
from repro.core.problems import enable_f64

PAPER = {
    ("7pt", "bicgstab"): 8, ("7pt", "cg"): 12,
    ("7pt", "gauss_seidel"): 9, ("7pt", "jacobi"): 18,
    ("27pt", "bicgstab"): 45, ("27pt", "cg"): 72,
    ("27pt", "gauss_seidel"): 142, ("27pt", "jacobi"): 515,
}


def main() -> None:
    enable_f64()      # paper precision; owned by the driver, not the facade
    n = 128 if os.environ.get("BENCH_FULL") else 64
    opts = SolverOptions(tol=1e-6, maxiter=700, layout="local")
    for stencil in ("7pt", "27pt"):
        for method in ("bicgstab", "cg", "gauss_seidel", "jacobi"):
            sess = SolverSession(method=method, grid=(n, n, n),
                                 stencil=stencil, options=opts)
            res, t = sess.timed_solve(repeats=3)
            csv(f"iters_{stencil}_{method}_{n}^3",
                t["median"] * 1e6,
                f"iters={int(res.iters)};paper128={PAPER[(stencil, method)]};"
                f"res={float(res.res_norm):.2e}")


if __name__ == "__main__":
    main()
