"""Paper §4.1 table: iterations to convergence on the HPCG system.

Paper (128^3, one MareNostrum4 node, eps=1e-6 absolute):
  7pt : BiCGStab 8,  CG 12, symGS 9,   Jacobi 18
  27pt: BiCGStab 45, CG 72, symGS 142, Jacobi 515

Set BENCH_FULL=1 to run the exact 128^3 sizes (≈2 min on CPU); the default
64^3 shows the same structure at ~1/8 the cost.

``--precond`` (or ``make bench-precond``) additionally runs pcg/pbicgstab
with every repro.precond implementation and reports the iteration count
next to the plain method's — the measured side of the reductions-vs-
iterations trade-off the scaling model prices.
"""

from __future__ import annotations

import os

from benchmarks.common import csv
from repro.api import SolverOptions, SolverSession
from repro.core.problems import enable_f64
from repro.precond import PRECONDITIONERS

PRECONDS = tuple(sorted(PRECONDITIONERS))

PAPER = {
    ("7pt", "bicgstab"): 8, ("7pt", "cg"): 12,
    ("7pt", "gauss_seidel"): 9, ("7pt", "jacobi"): 18,
    ("27pt", "bicgstab"): 45, ("27pt", "cg"): 72,
    ("27pt", "gauss_seidel"): 142, ("27pt", "jacobi"): 515,
}


def main(precond: bool = False) -> None:
    enable_f64()      # paper precision; owned by the driver, not the facade
    n = 128 if os.environ.get("BENCH_FULL") else 64
    opts = SolverOptions(tol=1e-6, maxiter=700, layout="local")
    plain: dict[tuple[str, str], int] = {}
    for stencil in ("7pt", "27pt"):
        for method in ("bicgstab", "cg", "gauss_seidel", "jacobi"):
            sess = SolverSession(method=method, grid=(n, n, n),
                                 stencil=stencil, options=opts)
            res, t = sess.timed_solve(repeats=3)
            plain[(stencil, method)] = int(res.iters)
            csv(f"iters_{stencil}_{method}_{n}^3",
                t["median"] * 1e6,
                f"iters={int(res.iters)};paper128={PAPER[(stencil, method)]};"
                f"res={float(res.res_norm):.2e}")
    if not precond:
        return
    for stencil in ("7pt", "27pt"):
        for method, base in (("pcg", "cg"), ("pbicgstab", "bicgstab")):
            for p in PRECONDS:
                sess = SolverSession(method=method, grid=(n, n, n),
                                     stencil=stencil,
                                     options=opts.replace(precond=p))
                res, t = sess.timed_solve(repeats=3)
                csv(f"iters_{stencil}_{method}+{p}_{n}^3",
                    t["median"] * 1e6,
                    f"iters={int(res.iters)};"
                    f"plain_{base}={plain[(stencil, base)]};"
                    f"res={float(res.res_norm):.2e}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--precond", action="store_true",
                    help="also run pcg/pbicgstab with every preconditioner")
    main(precond=ap.parse_args().precond)
